#!/usr/bin/env bash
# Run the micro + serving benchmarks and record the machine-readable
# results at the repo root (BENCH_micro.json / BENCH_serve.json) so
# future PRs can track the perf trajectory.
#
# Bench *parameters* live in versioned run-config files —
# scripts/bench_micro.json and scripts/bench_serve.json — not in shell
# flags; edit those (or point GS_BENCH_CONF_MICRO / GS_BENCH_CONF_SERVE
# elsewhere) to change workloads.  Usage: scripts/bench.sh [extra cargo args...]
#
#   GS_BENCH_FAST=1 scripts/bench.sh    # shrunken workloads (smoke)
#
# The harness runs without AOT artifacts (PJRT step benches are
# skipped and the pipeline bench uses a simulated device step); build
# artifacts first for the full set.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
export GS_BENCH_OUT="${GS_BENCH_OUT:-$ROOT/BENCH_micro.json}"
export GS_SERVE_BENCH_OUT="${GS_SERVE_BENCH_OUT:-$ROOT/BENCH_serve.json}"

# Gate step: docs lint + tier-1 build/tests must pass before we spend
# bench time (scripts/test.sh; set GS_BENCH_SKIP_TESTS=1 to bench a
# tree whose tests are already known green).
if [ "${GS_BENCH_SKIP_TESTS:-0}" != "1" ]; then
    "$ROOT/scripts/test.sh"
else
    "$ROOT/scripts/check_docs.sh"
fi
echo

cd "$ROOT/rust"
GS_BENCH_CONF="${GS_BENCH_CONF_MICRO:-$ROOT/scripts/bench_micro.json}" \
    cargo bench --bench micro "$@"

echo
# Serving benches: run end-to-end without AOT artifacts/PJRT (the
# engine falls back to the deterministic surrogate backend), so this
# never needs to skip — it just reports which backend executed.
GS_BENCH_CONF="${GS_BENCH_CONF_SERVE:-$ROOT/scripts/bench_serve.json}" \
    cargo bench --bench serve "$@"

echo
echo "results: $GS_BENCH_OUT"
echo "         $GS_SERVE_BENCH_OUT"
