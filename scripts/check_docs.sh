#!/usr/bin/env bash
# Docs lint: every repo path, `gs` subcommand, `--flag` and serve/run
# config key that README.md or docs/*.md mentions must actually exist
# in the tree.  Wired into scripts/bench.sh as its lint step so the
# docs can't rot silently when code moves.
#
# Sources of truth:
#   * repo paths      -> the filesystem
#   * gs subcommands  -> `gs help` when a toolchain is available, else
#                        the command table in rust/src/config/cli.rs
#   * --flags         -> same (plus a small allowlist of cargo/shell
#                        flags that appear in build instructions)
#   * config keys     -> the KEYS tables in rust/src/config/mod.rs
#
# Usage: scripts/check_docs.sh   (exits non-zero on any dangling ref)
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT"

CLI_SRC="rust/src/config/cli.rs"
CFG_SRC="rust/src/config/mod.rs"
fail=0
err() { echo "check_docs: $1: $2" >&2; fail=1; }

# Flags that legitimately appear in docs but belong to other tools (or
# to `gs lint`, whose flags live outside the cli.rs command table).
FLAG_ALLOW=" help release bench example features offline quiet dump-names "

GS_HELP=""
NAME_TABLE=""
if command -v cargo >/dev/null 2>&1; then
    GS_HELP="$(cd rust && cargo run -q 2>/dev/null -- help || true)"
    # Span/metric names the production tree can emit (`*` wildcards for
    # format! holes) — the source of truth for instrumentation names in
    # docs, extracted by the lint pass (docs/LINTS.md).
    NAME_TABLE="$(cd rust && cargo run -q 2>/dev/null -- lint --dump-names src || true)"
fi

shopt -s nullglob
docs=(README.md docs/*.md)
[ ${#docs[@]} -gt 0 ] || { echo "check_docs: no docs found" >&2; exit 1; }

for doc in "${docs[@]}"; do
    [ -f "$doc" ] || { err "$doc" "listed doc missing"; continue; }
    # 1. Backticked repo paths (with optional :line suffix) must exist.
    while IFS= read -r p; do
        base="${p%%:*}"
        [ -e "$base" ] || err "$doc" "missing path '$base'"
    done < <(grep -o '`[A-Za-z0-9_./-]*/[A-Za-z0-9_.-]*\.\(rs\|sh\|json\|md\|py\|csv\|toml\)\(:[0-9]*\)\?`' "$doc" \
             | tr -d '`' | sort -u)

    # 2. Backticked --flags must exist in the gs flag table (or the
    #    allowlist for non-gs tools).
    while IFS= read -r f; do
        name="${f#--}"
        case "$FLAG_ALLOW" in *" $name "*) continue ;; esac
        if [ -n "$GS_HELP" ] && printf '%s\n' "$GS_HELP" | grep -q -- "--$name"; then
            continue
        fi
        grep -q "name: \"$name\"" "$CLI_SRC" && continue
        err "$doc" "unknown CLI flag '--$name'"
    done < <(grep -o '`--[a-z][a-z-]*' "$doc" | tr -d '`' | sort -u)

    # 3. `gs <subcommand>` mentions must be real subcommands.
    while IFS= read -r c; do
        case "$c" in smoke|help|stats|trace-check|lint|"") continue ;; esac
        if [ -n "$GS_HELP" ] && printf '%s\n' "$GS_HELP" | grep -q "gs $c"; then
            continue
        fi
        grep -q "name: \"$c\"" "$CLI_SRC" && continue
        err "$doc" "unknown gs subcommand '$c'"
    done < <(grep -o '`gs [a-z][a-z-]*' "$doc" | sed 's/^`gs //' | sort -u)

    # 4. Backticked stage.key config paths (e.g. `serve.pool_workers`,
    #    `tasks.0.weight`) must appear as keys in the typed config
    #    structs.  Numeric segments are array indices; the final
    #    alphabetic segment is the key to check.  Dotted names that are
    #    not config keys (span names like `serve.batch.forward`, metric
    #    names like `serve.pool.batches` — docs/OBSERVABILITY.md) must
    #    instead exist verbatim somewhere under rust/ (source literal
    #    or golden fixture), so renamed instrumentation can't leave
    #    stale docs behind.
    while IFS= read -r sk; do
        key="${sk##*.}"
        # `lm.rs` and friends are file names, not config paths;
        # empty / numeric tails are array indices, not keys.
        case "$key" in rs|sh|json|md|py|csv|toml|''|*[!a-z_]*) continue ;; esac
        grep -q "\"$key\"" "$CFG_SRC" && continue
        if [ -n "$NAME_TABLE" ]; then
            # Instrumentation names match the lint-extracted name table
            # (wildcard patterns from format! call sites glob-match).
            hit=0
            while IFS= read -r pat; do
                # shellcheck disable=SC2254  # $pat is a glob on purpose
                case "$sk" in $pat) hit=1; break ;; esac
            done <<< "$NAME_TABLE"
            [ "$hit" -eq 1 ] && continue
        else
            # No toolchain: fall back to a verbatim source/fixture grep.
            grep -rqF "$sk" "$ROOT/rust" && continue
        fi
        err "$doc" "unknown config key or instrumentation name '$sk'"
    done < <(grep -o '`\(loader\|data\|partition\|lm\|task\|tasks\|encoder\|infer\|serve\|obs\)\.[a-z0-9_.]*`' "$doc" \
             | tr -d '`' | sort -u)
done

if [ "$fail" -ne 0 ]; then
    echo "check_docs: FAILED — fix the dangling references above" >&2
    exit 1
fi
echo "check_docs: OK (${#docs[@]} files)"
