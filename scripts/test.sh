#!/usr/bin/env bash
# The tier-1 gate in one entry point: docs lint, release build, full
# test suite.  Called by scripts/bench.sh before any bench time is
# spent, and usable standalone in CI or locally.
#
#   scripts/test.sh [extra cargo test args...]
#
# Artifact-gated tests (anything executing AOT artifacts through PJRT)
# self-skip via `runtime_if_available()` when artifacts/ is absent —
# this script just reports which mode the run was in.  On a machine
# without a Rust toolchain only the docs lint runs (hand-verify Rust
# changes there; see ROADMAP.md).
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

# Docs must reference real paths/flags/keys before anything builds.
"$ROOT/scripts/check_docs.sh"
echo

if ! command -v cargo >/dev/null 2>&1; then
    echo "test.sh: cargo not found — docs lint only (gs lint + tier-1 build/tests need a Rust toolchain)" >&2
    exit 0
fi

cd "$ROOT/rust"
cargo build --release

# Static-analysis gate (docs/LINTS.md): determinism, panic-clean,
# lock-order, salt-unique and name-registry rules over rust/src.  This
# replaced the old awk panic-clean grep — the tokenizer is comment/
# string/#[cfg(test)]-aware, so a production `fn` after a test module
# is still linted and prose mentions of `.unwrap()` are not findings.
cargo run --release -q -- lint src
echo

cargo test -q "$@"

# Fault-injection sweep gate (always on, surrogate backend): the bench
# must report bit-identical replies with a fault schedule injected
# into its uncached arm, or the supervision layer regressed.
echo
echo "test.sh: fault-injection sweep (gs serve-bench --faults)"
# Small batches + a short fault list keep the plan horizon (distinct
# keys / max_batch) comfortably above the fault count for any Zipf
# draw.
sweep_out=$(cargo run --release -q -- serve-bench \
    --dataset mag --size 400 --requests 600 --max-batch 8 \
    --faults "panics=1,transient=1,slow=1,slow_ms=2")
printf '%s\n' "$sweep_out" | tail -n 6
if ! printf '%s\n' "$sweep_out" | grep -q "bit-identical across arms + repeats: true"; then
    echo "test.sh: fault sweep FAILED — faulted replies diverged" >&2
    exit 1
fi

# Shard-sweep gate (always on, surrogate backend): the same faulted
# bench over a striped cache and parallel engine sessions must stay
# bit-identical — striping the hot path may never change a reply bit
# (docs/SERVING.md, rust/tests/sharding.rs).
echo
echo "test.sh: shard-sweep gate (gs serve-bench --shards 4 --sessions 2)"
shard_out=$(cargo run --release -q -- serve-bench \
    --dataset mag --size 400 --requests 600 --max-batch 8 \
    --pool-workers 2 --shards 4 --sessions 2 \
    --faults "panics=1,transient=1,slow=1,slow_ms=2")
printf '%s\n' "$shard_out" | tail -n 6
if ! printf '%s\n' "$shard_out" | grep -q "bit-identical across arms + repeats: true"; then
    echo "test.sh: shard sweep FAILED — sharded replies diverged" >&2
    exit 1
fi

# Trace-schema gate: a traced bench must emit a JSONL trace that its
# own validator accepts (docs/OBSERVABILITY.md), and the metrics table
# must carry the per-arm serve counters.
echo
echo "test.sh: trace-schema gate (gs serve-bench --trace + gs trace-check)"
trace_tmp="$(mktemp -d)"
trap 'rm -rf "$trace_tmp"' EXIT
obs_out=$(cargo run --release -q -- serve-bench \
    --dataset mag --size 400 --requests 300 --max-batch 8 \
    --trace "$trace_tmp/bench.trace.jsonl" --stats)
cargo run --release -q -- trace-check "$trace_tmp/bench.trace.jsonl"
if ! printf '%s\n' "$obs_out" | grep -q "serve.uncached.requests"; then
    echo "test.sh: trace-schema gate FAILED — --stats table missing serve counters" >&2
    exit 1
fi

# HTTP smoke gate: a real `gs serve` on a loopback socket must answer
# a closed-loop `gs load-bench` replay with zero 5xx / transport
# errors, confirm byte-identical repeated replies, and drain cleanly
# on POST /shutdown (docs/SERVING.md).
echo
echo "test.sh: HTTP smoke gate (gs serve --listen + gs load-bench --shutdown)"
http_tmp="$(mktemp -d)"
trap 'rm -rf "$trace_tmp" "$http_tmp"' EXIT
./target/release/gs serve \
    --dataset mag --size 400 --listen 127.0.0.1:0 --http-workers 4 \
    --max-batch 8 --queue-depth 256 \
    > "$http_tmp/serve.log" 2>&1 &
serve_pid=$!
addr=""
for _ in $(seq 1 100); do
    addr=$(sed -n 's/.*listening on \([0-9.:]*\) .*/\1/p' "$http_tmp/serve.log" | head -n 1)
    [ -n "$addr" ] && break
    if ! kill -0 "$serve_pid" 2>/dev/null; then
        echo "test.sh: HTTP smoke gate FAILED — gs serve exited before binding" >&2
        cat "$http_tmp/serve.log" >&2
        exit 1
    fi
    sleep 0.1
done
if [ -z "$addr" ]; then
    echo "test.sh: HTTP smoke gate FAILED — no 'listening on' line from gs serve" >&2
    cat "$http_tmp/serve.log" >&2
    kill "$serve_pid" 2>/dev/null || true
    exit 1
fi
load_out=$(./target/release/gs load-bench \
    --addr "$addr" --connections 4 --requests 200 \
    --bench-out "$http_tmp/BENCH_http.json" --shutdown)
printf '%s\n' "$load_out" | tail -n 3
if ! wait "$serve_pid"; then
    echo "test.sh: HTTP smoke gate FAILED — gs serve exited non-zero after drain" >&2
    cat "$http_tmp/serve.log" >&2
    exit 1
fi
if ! printf '%s\n' "$load_out" | grep -q "| 5xx 0 | transport 0 |"; then
    echo "test.sh: HTTP smoke gate FAILED — 5xx or transport errors in load-bench output" >&2
    exit 1
fi
if ! printf '%s\n' "$load_out" | grep -q "replies bit-identical: true"; then
    echo "test.sh: HTTP smoke gate FAILED — socket replies not byte-identical" >&2
    exit 1
fi
if ! grep -q '"http"' "$http_tmp/BENCH_http.json"; then
    echo "test.sh: HTTP smoke gate FAILED — bench-out missing the http key" >&2
    exit 1
fi

if [ -e "$ROOT/artifacts" ]; then
    echo "test.sh: OK (artifacts/ present — gated tests executed)"
else
    echo "test.sh: OK (artifacts/ absent — artifact-gated tests skipped cleanly)"
fi
