#!/usr/bin/env bash
# The tier-1 gate in one entry point: docs lint, release build, full
# test suite.  Called by scripts/bench.sh before any bench time is
# spent, and usable standalone in CI or locally.
#
#   scripts/test.sh [extra cargo test args...]
#
# Artifact-gated tests (anything executing AOT artifacts through PJRT)
# self-skip via `runtime_if_available()` when artifacts/ is absent —
# this script just reports which mode the run was in.  On a machine
# without a Rust toolchain only the docs lint runs (hand-verify Rust
# changes there; see ROADMAP.md).
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

# Docs must reference real paths/flags/keys before anything builds.
"$ROOT/scripts/check_docs.sh"
echo

if ! command -v cargo >/dev/null 2>&1; then
    echo "test.sh: cargo not found — docs lint only (tier-1 build/tests need a Rust toolchain)" >&2
    exit 0
fi

cd "$ROOT/rust"
cargo build --release
cargo test -q "$@"

if [ -e "$ROOT/artifacts" ]; then
    echo "test.sh: OK (artifacts/ present — gated tests executed)"
else
    echo "test.sh: OK (artifacts/ absent — artifact-gated tests skipped cleanly)"
fi
