"""GSTF — the tiny tensor-file format shared between Python and Rust.

Used for initial parameter values (written at AOT time) and model
checkpoints (written by the Rust trainer).  Layout, little-endian:

    magic   b"GSTF"
    version u32 (=1)
    count   u32
    per tensor:
        name_len u32, name utf-8,
        dtype    u8  (0=f32, 1=i32),
        ndim     u32, dims u64[ndim],
        data     raw LE bytes (prod(dims) * itemsize)

Mirrored by ``rust/src/runtime/gstf.rs``.
"""

import struct

import numpy as np

MAGIC = b"GSTF"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_REV = {0: np.float32, 1: np.int32}


def write(path, tensors):
    """tensors: list of (name, np.ndarray)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", DTYPES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def read(path):
    """Returns list of (name, np.ndarray)."""
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad GSTF magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (dt,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(ndim)]
            dtype = np.dtype(DTYPES_REV[dt])
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            out.append((name, data.reshape(dims)))
    return out
