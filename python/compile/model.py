"""Step-function assembly: configs, batch specs, train/infer steps.

Every artifact the Rust runtime loads is one function lowered here:

* ``*_train``  — ``(state…, lr, [loss_sel], batch…) → (state…, loss,
  metric, [grad_lemb])`` with Adam folded in.  ``state`` is the flat
  ``[params, m, v, t]`` list in manifest order.
* ``*_infer`` — ``(params…, batch…) → outputs``.

Flat ordering is ``sorted(param_names)``; the manifest
(`artifacts/manifest.json`) records every name/shape/dtype so the Rust
side is entirely manifest-driven.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .models import decoders, gnn, lm, losses, optim
from .models.common import ParamBuilder

# ------------------------------------------------------------------ configs


@dataclasses.dataclass(frozen=True)
class BlockShape:
    """Padded block sizes: ns[0] ≥ ns[1] ≥ … ≥ ns[L] (targets)."""

    ns: Tuple[int, ...]
    es: Tuple[int, ...]

    @property
    def num_layers(self):
        return len(self.es)


def block_for(batch, fanout, num_layers, extra_seeds=0, round_to=8):
    """Worst-case block shape for `batch` targets (+`extra_seeds` slots)."""
    def rnd(x):
        return (x + round_to - 1) // round_to * round_to

    ns = [rnd(batch + extra_seeds)]
    es = []
    for _ in range(num_layers):
        es.append(ns[-1] * fanout)
        ns.append(rnd(ns[-1] * (fanout + 1)))
    return BlockShape(ns=tuple(reversed(ns)), es=tuple(reversed(es)))


@dataclasses.dataclass(frozen=True)
class GnnConfig:
    arch: str = "rgcn"
    num_layers: int = 2
    hidden: int = 64
    feat_dim: int = 64
    text_dim: int = 64
    lemb_dim: int = 64
    num_ntypes: int = 4
    num_etypes: int = 8
    num_classes: int = 16
    impl: str = "pallas"
    block: BlockShape = None
    use_lemb: bool = True
    num_neg: int = 0  # LP only: K negative slots per positive
    lp_batch: int = 0  # LP only: positive edges per batch


@dataclasses.dataclass(frozen=True)
class LmConfig:
    vocab: int = 1024
    seq_len: int = 32
    lm_hidden: int = 64
    lm_heads: int = 2
    num_lm_layers: int = 2
    num_classes: int = 16
    batch: int = 64
    num_neg: int = 8  # LP fine-tuning negatives
    hidden: int = 64  # pooled-embedding dim (matches GNN hidden)


# --------------------------------------------------------------- batch specs

F32, I32 = "f32", "i32"


def gnn_block_spec(cfg: GnnConfig) -> List[Tuple[str, tuple, str]]:
    b = cfg.block
    spec = [
        ("feat", (b.ns[0], cfg.feat_dim), F32),
        ("text", (b.ns[0], cfg.text_dim), F32),
        ("lemb", (b.ns[0], cfg.lemb_dim), F32),
        ("src_sel", (b.ns[0], 3), F32),
        ("ntype", (b.ns[0],), I32),
    ]
    for l in range(b.num_layers):
        spec += [
            (f"src{l}", (b.es[l],), I32),
            (f"dst{l}", (b.es[l],), I32),
            (f"etype{l}", (b.es[l],), I32),
            (f"emask{l}", (b.es[l],), F32),
        ]
    return spec


def nc_batch_spec(cfg: GnnConfig):
    nt = cfg.block.ns[-1]
    return gnn_block_spec(cfg) + [
        ("labels", (nt,), I32),
        ("lmask", (nt,), F32),
    ]


def lp_batch_spec(cfg: GnnConfig):
    b, k = cfg.lp_batch, cfg.num_neg
    return gnn_block_spec(cfg) + [
        ("pos_src", (b,), I32),
        ("pos_dst", (b,), I32),
        ("neg_dst", (b, k), I32),
        ("rel", (b,), I32),
        ("pmask", (b,), F32),
        ("eweight", (b,), F32),
    ]


def spec_to_args(spec):
    """ShapeDtypeStructs for jit.lower."""
    dt = {F32: jnp.float32, I32: jnp.int32}
    return [jax.ShapeDtypeStruct(shape, dt[d]) for _, shape, d in spec]


def batch_dict(spec, args):
    return {name: a for (name, _, _), a in zip(spec, args)}


# ----------------------------------------------------------- param builders


def build_gnn_params(cfg: GnnConfig, task: str, seed: int = 0):
    pb = ParamBuilder(jax.random.PRNGKey(seed))
    gnn.build_gnn(pb, cfg)
    if task == "nc":
        decoders.build_nc_decoder(pb, cfg)
    elif task == "lp":
        decoders.build_lp_decoder(pb, cfg)
    elif task == "emb":
        pass
    else:
        raise ValueError(task)
    return pb.params


def build_lm_params(cfg: LmConfig, heads=("mlm", "nc"), seed: int = 1):
    pb = ParamBuilder(jax.random.PRNGKey(seed))
    lm.build_lm(pb, cfg)
    if "mlm" in heads:
        lm.build_mlm_head(pb, cfg)
    if "nc" in heads:
        pb.dense("lm.cls", cfg.lm_hidden, cfg.num_classes)
    if "distill" in heads:
        pb.dense("lm.proj", cfg.lm_hidden, cfg.hidden)
    return pb.params


# ------------------------------------------------------------ step assembly


def flat_names(params: Dict):
    return sorted(params.keys())


def make_train_step(params0, loss_fn, batch_spec, *, grad_lemb=False, extra_scalars=()):
    """Build the flat train-step callable plus its manifest metadata.

    loss_fn(params, batch, scalars) -> (loss, metric)
    Returns (flat_fn, in_specs, meta) where meta describes state inputs,
    scalar inputs, batch inputs and outputs.
    """
    names = flat_names(params0)
    P = len(names)

    def flat_fn(*args):
        i = 0
        params = {n: a for n, a in zip(names, args[i : i + P])}
        i += P
        m = {n: a for n, a in zip(names, args[i : i + P])}
        i += P
        v = {n: a for n, a in zip(names, args[i : i + P])}
        i += P
        t = args[i]
        i += 1
        lr = args[i]
        i += 1
        scalars = args[i : i + len(extra_scalars)]
        i += len(extra_scalars)
        batch = batch_dict(batch_spec, args[i:])

        if grad_lemb:

            def L(p, lemb_in):
                b2 = dict(batch)
                b2["lemb"] = lemb_in
                loss, metric = loss_fn(p, b2, scalars)
                return loss, metric

            (loss, metric), (gp, glemb) = jax.value_and_grad(
                L, argnums=(0, 1), has_aux=True
            )(params, batch["lemb"])
        else:

            def L(p):
                return loss_fn(p, batch, scalars)

            (loss, metric), gp = jax.value_and_grad(L, has_aux=True)(params)
            glemb = None

        params, m, v, t = optim.adam_update(params, gp, m, v, t, lr)
        out = (
            [params[n] for n in names]
            + [m[n] for n in names]
            + [v[n] for n in names]
            + [t, loss, metric]
        )
        if grad_lemb:
            out.append(glemb)
        return tuple(out)

    m0, v0, t0 = optim.adam_init(params0)
    state0 = (
        [params0[n] for n in names]
        + [m0[n] for n in names]
        + [v0[n] for n in names]
        + [t0]
    )
    state_spec = (
        [(f"p:{n}", tuple(params0[n].shape), F32) for n in names]
        + [(f"m:{n}", tuple(params0[n].shape), F32) for n in names]
        + [(f"v:{n}", tuple(params0[n].shape), F32) for n in names]
        + [("t", (), F32)]
    )
    scalar_spec = [("lr", (), F32)] + [(s, (), F32) for s in extra_scalars]
    out_spec = state_spec + [("loss", (), F32), ("metric", (), F32)]
    if grad_lemb:
        lemb_shape = next(s for n, s, _ in batch_spec if n == "lemb")
        out_spec = out_spec + [("grad_lemb", lemb_shape, F32)]
    meta = {
        "n_params": P,
        "param_names": names,
        "state": state_spec,
        "scalars": scalar_spec,
        "batch": batch_spec,
        "outputs": out_spec,
    }
    return flat_fn, state0, meta


def make_infer_step(params0, infer_fn, batch_spec, out_spec):
    names = flat_names(params0)
    P = len(names)

    def flat_fn(*args):
        params = {n: a for n, a in zip(names, args[:P])}
        batch = batch_dict(batch_spec, args[P:])
        out = infer_fn(params, batch)
        return out if isinstance(out, tuple) else (out,)

    meta = {
        "n_params": P,
        "param_names": names,
        "state": [(f"p:{n}", tuple(params0[n].shape), F32) for n in names],
        "scalars": [],
        "batch": batch_spec,
        "outputs": out_spec,
    }
    return flat_fn, [params0[n] for n in names], meta


# ----------------------------------------------------------------- GNN tasks


def gnn_nc_loss(cfg):
    def loss_fn(params, batch, scalars):
        h = gnn.gnn_forward(params, batch, cfg)
        logits = decoders.nc_logits(params, h)
        return losses.masked_softmax_xent(logits, batch["labels"], batch["lmask"])

    return loss_fn


def gnn_lp_loss(cfg):
    def loss_fn(params, batch, scalars):
        (loss_sel,) = scalars
        h = gnn.gnn_forward(params, batch, cfg)
        hs, hd = h[batch["pos_src"]], h[batch["pos_dst"]]
        pos = decoders.distmult_score(params, hs, hd, batch["rel"])
        hneg = h[batch["neg_dst"]]  # [B, K, H]
        r = params["lp.rel"][batch["rel"]][:, None, :]
        neg = (hs[:, None, :] * r * hneg).sum(axis=-1)
        loss = losses.lp_select_loss(
            loss_sel, pos, neg, batch["pmask"], batch["eweight"]
        )
        metric = losses.lp_mrr_sum(pos, neg, batch["pmask"])
        return loss, metric

    return loss_fn


def gnn_nc_logits_infer(cfg):
    def infer_fn(params, batch):
        h = gnn.gnn_forward(params, batch, cfg)
        return decoders.nc_logits(params, h)

    return infer_fn


def gnn_emb_infer(cfg, with_rel=False):
    def infer_fn(params, batch):
        h = gnn.gnn_forward(params, batch, cfg)
        if with_rel:
            return h, params["lp.rel"]
        return h

    return infer_fn


# ------------------------------------------------------------------ LM tasks


def lm_token_spec(cfg: LmConfig, name="tokens", batch=None):
    return (name, (batch or cfg.batch, cfg.seq_len), I32)


def lm_mlm_loss(cfg):
    def loss_fn(params, batch, scalars):
        logits = lm.mlm_logits(params, batch["tokens"], batch["positions"], cfg)
        return losses.masked_softmax_xent(logits, batch["labels"], batch["lmask"])

    return loss_fn


def lm_nc_loss(cfg):
    def loss_fn(params, batch, scalars):
        emb = lm.lm_embed(params, batch["tokens"], cfg)
        logits = emb @ params["lm.cls.w"] + params["lm.cls.b"]
        return losses.masked_softmax_xent(logits, batch["labels"], batch["lmask"])

    return loss_fn


def lm_lp_loss(cfg):
    """Contrastive LP fine-tuning over (src, dst, joint negatives) text."""

    def loss_fn(params, batch, scalars):
        es = lm.lm_embed(params, batch["src_tokens"], cfg)
        ed = lm.lm_embed(params, batch["dst_tokens"], cfg)
        en = lm.lm_embed(params, batch["neg_tokens"], cfg)  # [K, H]
        pos = (es * ed).sum(axis=1)
        neg = es @ en.T  # [B, K]
        loss = losses.lp_contrastive_loss(pos, neg, batch["pmask"])
        metric = losses.lp_mrr_sum(pos, neg, batch["pmask"])
        return loss, metric

    return loss_fn


def lm_distill_loss(cfg):
    """MSE between projected student embeddings and teacher GNN embeddings."""

    def loss_fn(params, batch, scalars):
        emb = lm.lm_embed(params, batch["tokens"], cfg)
        proj = emb @ params["lm.proj.w"] + params["lm.proj.b"]
        loss = losses.mse_loss(proj, batch["teacher"], batch["lmask"])
        return loss, loss  # metric = loss for distillation

    return loss_fn


# ----------------------------------------------------------------- MLP probe


def build_probe_params(in_dim, hidden, num_classes, seed=2):
    pb = ParamBuilder(jax.random.PRNGKey(seed))
    decoders.build_mlp_decoder(pb, in_dim, hidden, num_classes)
    return pb.params


def probe_loss():
    def loss_fn(params, batch, scalars):
        logits = decoders.mlp_logits(params, batch["emb"])
        return losses.masked_softmax_xent(logits, batch["labels"], batch["lmask"])

    return loss_fn
