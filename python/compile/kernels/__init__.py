"""L1 Pallas kernels: the GNN message-passing hot spot.

Two kernels cover every model in the zoo:

* :func:`segment_sum.segment_sum` — masked scatter-add of per-edge
  messages into per-destination accumulators (GCN / GraphSage / RGCN
  sum & mean aggregation).
* :func:`softmax_agg.segment_softmax_agg` — masked per-destination
  softmax over edge logits followed by the weighted aggregate
  (GAT / RGAT / HGT attention).

Both are authored as Pallas kernels (``interpret=True`` — the CPU PJRT
plugin cannot execute Mosaic custom-calls) and validated against the
pure-jnp oracles in :mod:`ref`.  ``impl='xla'`` selects the oracle path
instead so large parameter sweeps can use XLA's native scatter on CPU;
the canonical artifacts use the Pallas path.
"""

from .segment_sum import segment_sum, segment_mean
from .softmax_agg import segment_softmax_agg, segment_softmax_agg_diff, segment_max
from . import ref

__all__ = [
    "segment_sum",
    "segment_mean",
    "segment_softmax_agg",
    "segment_softmax_agg_diff",
    "segment_max",
    "ref",
]
