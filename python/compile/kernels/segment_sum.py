"""Masked segment-sum (scatter-add) as a Pallas kernel.

Hardware adaptation (DESIGN.md §7): GraphStorm's DGL backend performs
neighbor aggregation with CUDA scatter atomics.  TPUs have no cheap
atomics, so the kernel re-expresses scatter-add as a **one-hot matmul on
the MXU**: the padded edge list is tiled along E; each tile builds a
``[TE, N]`` one-hot destination matrix in VMEM and contracts it against
the ``[TE, D]`` message tile, accumulating into an ``[N, D]`` VMEM
accumulator that the grid revisits.  HBM traffic is ``E*D + N*D`` per
layer instead of per-edge gathers, and the inner op is an MXU-shaped
``N×TE×D`` matmul.

VMEM budget at canonical shapes (TE=256, N≤4096, D≤128, f32):
one-hot tile 256*4096*4 = 4 MiB, accumulator 4096*128*4 = 2 MiB,
msg tile 256*128*4 = 128 KiB → ≈6.1 MiB, comfortably under 16 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Edge-tile size: multiple of 8 sublanes; 256 keeps the one-hot tile
# within the VMEM budget at N=4096.
DEFAULT_BLOCK_E = 256


def _segment_sum_kernel(dst_ref, mask_ref, msg_ref, out_ref):
    """One grid step: accumulate one E-tile into the [N, D] output.

    dst_ref:  i32[TE]    destination slots for this tile.
    mask_ref: f32[TE]    edge validity (0 for padding).
    msg_ref:  f32[TE, D] message tile.
    out_ref:  f32[N, D]  shared accumulator (same block every grid step).
    """
    # Zero the accumulator on the first visit only.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    n = out_ref.shape[0]
    dst = dst_ref[...]
    mask = mask_ref[...]
    # One-hot scatter matrix [TE, N]: row e lights column dst[e] iff the
    # edge is real.  broadcasted_iota is 2D as required on TPU.
    cols = jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], n), 1)
    onehot = jnp.where(cols == dst[:, None], mask[:, None], 0.0)
    # MXU contraction: [N, TE] @ [TE, D] -> [N, D].
    out_ref[...] += jnp.dot(
        onehot.T, msg_ref[...], preferred_element_type=jnp.float32
    )


def _pad_edges(msg, dst, mask, block_e):
    e = msg.shape[0]
    pe = (e + block_e - 1) // block_e * block_e
    if pe != e:
        pad = pe - e
        msg = jnp.pad(msg, ((0, pad), (0, 0)))
        dst = jnp.pad(dst, (0, pad))
        mask = jnp.pad(mask, (0, pad))
    return msg, dst, mask


def _segment_sum_pallas(msg, dst, mask, num_segments, block_e):
    msg, dst, mask = _pad_edges(
        msg.astype(jnp.float32), dst.astype(jnp.int32), mask.astype(jnp.float32), block_e
    )
    e, d = msg.shape
    grid = (e // block_e,)
    return pl.pallas_call(
        _segment_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(dst, mask, msg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _segment_sum_p(msg, dst, mask, num_segments, block_e):
    return _segment_sum_pallas(msg, dst, mask, num_segments, block_e)


def _segment_sum_fwd(msg, dst, mask, num_segments, block_e):
    return _segment_sum_p(msg, dst, mask, num_segments, block_e), (dst, mask)


def _segment_sum_bwd(num_segments, block_e, res, g):
    # Backward of a masked scatter-add is the masked gather g[dst]*mask
    # (a native XLA gather; no kernel needed).  dst is integer-typed so
    # its cotangent is float0; mask is non-differentiated by convention.
    import numpy as np

    dst, mask = res
    d_msg = (g[dst] * mask[:, None]).astype(g.dtype)
    d_dst = np.zeros(dst.shape, dtype=jax.dtypes.float0)
    d_mask = jnp.zeros_like(mask)
    return (d_msg, d_dst, d_mask)


_segment_sum_p.defvjp(_segment_sum_fwd, _segment_sum_bwd)


@functools.partial(
    jax.jit, static_argnames=("num_segments", "impl", "block_e")
)
def segment_sum(
    msg, dst, mask, num_segments, *, impl="pallas", block_e=DEFAULT_BLOCK_E
):
    """Masked scatter-add of edge messages into destination slots.

    Differentiable w.r.t. ``msg``: Pallas kernels have no autodiff rule,
    so the Pallas path carries a custom VJP — the backward of a masked
    scatter-add is the masked gather ``g[dst] * mask``.

    Args:
      msg:  f32[E, D] per-edge messages.
      dst:  i32[E] destination slot per edge, in [0, num_segments).
      mask: f32[E] 1.0 for real edges, 0.0 for padding.
      num_segments: static number of destination slots N.
      impl: 'pallas' (the kernel) or 'xla' (native scatter; used by the
        CPU-throughput artifact variants — same math, same tests).
      block_e: E-tile size for the Pallas grid.

    Returns:
      f32[num_segments, D].
    """
    if impl == "xla":
        return ref.segment_sum_ref(msg, dst, mask, num_segments)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    return _segment_sum_p(
        msg.astype(jnp.float32), dst.astype(jnp.int32), mask.astype(jnp.float32),
        num_segments, block_e,
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "impl", "block_e")
)
def segment_mean(
    msg, dst, mask, num_segments, *, impl="pallas", block_e=DEFAULT_BLOCK_E
):
    """Masked scatter-mean; empty segments are all-zero.

    Mean = segment_sum(msg) / segment_sum(1), both via the same kernel:
    the count is the sum of a constant-1 message column, so no second
    kernel is needed.
    """
    d = msg.shape[1]
    # Append a ones column so one kernel pass yields sum and count.
    aug = jnp.concatenate([msg, jnp.ones((msg.shape[0], 1), msg.dtype)], axis=1)
    s = segment_sum(aug, dst, mask, num_segments, impl=impl, block_e=block_e)
    total, count = s[:, :d], s[:, d]
    count = jnp.where(count == 0.0, 1.0, count)
    return total / count[:, None]
