"""Masked per-destination softmax-aggregate as Pallas kernels.

Attention models (GAT / RGAT / HGT-lite) need, per destination node,
a numerically-stable softmax over the logits of its incoming edges
followed by the attention-weighted aggregate of the edge values.

On GPU this is done with segment-sorted scans or atomics; on TPU we use
the same one-hot-matmul trick as :mod:`segment_sum`, in two grid passes:

  pass 1  — per-segment max of the edge logits (running ``max`` into an
            ``[N]`` VMEM accumulator);
  pass 2  — ``w_e = exp(logit_e - m[dst_e]) * mask_e`` (the gather
            ``m[dst]`` is itself the one-hot matmul ``onehot @ m``),
            then one fused contraction accumulates both the weighted
            value sum ``[N, D]`` and the denominator ``[N]`` by
            augmenting the value tile with a ones column.

The final divide happens outside the kernels (it is a trivially fused
elementwise op).  Oracle: :func:`ref.segment_softmax_agg_ref`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import NEG_INF
from .segment_sum import DEFAULT_BLOCK_E, _pad_edges


def _segment_max_kernel(dst_ref, mask_ref, logit_ref, out_ref):
    """Running per-segment max over E-tiles; out_ref is f32[N]."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, NEG_INF)

    n = out_ref.shape[0]
    dst = dst_ref[...]
    mask = mask_ref[...]
    logit = logit_ref[...]
    cols = jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], n), 1)
    hit = (cols == dst[:, None]) & (mask[:, None] > 0)
    contrib = jnp.where(hit, logit[:, None], NEG_INF).max(axis=0)
    out_ref[...] = jnp.maximum(out_ref[...], contrib)


def _weighted_agg_kernel(dst_ref, mask_ref, logit_ref, val_ref, m_ref, out_ref):
    """Accumulate exp-weighted values + denominator into f32[N, D+1]."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    n = out_ref.shape[0]
    dst = dst_ref[...]
    mask = mask_ref[...]
    logit = logit_ref[...]
    cols = jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], n), 1)
    onehot = jnp.where(cols == dst[:, None], mask[:, None], 0.0)
    # Gather of the per-segment max, expressed as a matmul.
    m_dst = jnp.dot(onehot, m_ref[...], preferred_element_type=jnp.float32)
    w = jnp.exp(logit - m_dst) * mask
    # Augment values with a ones column: one contraction produces both
    # the weighted sum (cols 0..D) and the softmax denominator (col D).
    vals = val_ref[...]
    aug = jnp.concatenate([vals, jnp.ones((vals.shape[0], 1), vals.dtype)], axis=1)
    out_ref[...] += jnp.dot(
        onehot.T, aug * w[:, None], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "impl", "block_e")
)
def segment_max(logits, dst, mask, num_segments, *, impl="pallas", block_e=DEFAULT_BLOCK_E):
    """Masked per-segment max of edge logits; empty segments get 0.

    Used under ``stop_gradient`` for numerically-stable softmax (the
    standard max-shift trick), so no VJP is needed.
    """
    if impl == "xla":
        return ref.segment_max_ref(logits, dst, mask, num_segments)
    e = logits.shape[0]
    pe = (e + block_e - 1) // block_e * block_e
    if pe != e:
        logits = jnp.pad(logits, (0, pe - e))
        dst = jnp.pad(dst, (0, pe - e))
        mask = jnp.pad(mask, (0, pe - e))
    grid = (pe // block_e,)
    m = pl.pallas_call(
        _segment_max_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_segments,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), jnp.float32),
        interpret=True,
    )(dst.astype(jnp.int32), mask.astype(jnp.float32), logits.astype(jnp.float32))
    return jnp.where(m <= NEG_INF / 2, 0.0, m)


def segment_softmax_agg_diff(
    logits, msg, dst, mask, num_segments, *, impl="pallas", block_e=DEFAULT_BLOCK_E
):
    """Differentiable softmax-aggregate used on the training path.

    Composed from the differentiable :func:`segment_sum` kernel plus the
    (stop-gradient) Pallas :func:`segment_max`, so autodiff flows through
    standard jnp ops while the scatter contractions still run on the
    one-hot-matmul kernel.  The fused two-pass kernel below
    (:func:`segment_softmax_agg`) is the inference-path variant.
    """
    from .segment_sum import segment_sum

    # stop_gradient on the *input*: the max-shift is gradient-free by the
    # standard softmax identity, and zero tangents keep JAX from trying
    # to JVP-trace the (rule-less) Pallas call.
    m = segment_max(
        jax.lax.stop_gradient(logits), dst, mask, num_segments,
        impl=impl, block_e=block_e,
    )
    w = jnp.exp(logits - m[dst]) * mask
    ones = jnp.ones_like(mask)
    aug = jnp.concatenate([msg * w[:, None], w[:, None]], axis=1)
    s = segment_sum(aug, dst, ones, num_segments, impl=impl, block_e=block_e)
    total, denom = s[:, :-1], s[:, -1]
    denom = jnp.where(denom == 0.0, 1.0, denom)
    return total / denom[:, None]


@functools.partial(
    jax.jit, static_argnames=("num_segments", "impl", "block_e")
)
def segment_softmax_agg(
    logits, msg, dst, mask, num_segments, *, impl="pallas", block_e=DEFAULT_BLOCK_E
):
    """Per-destination masked softmax over edge logits, then aggregate.

    Args:
      logits: f32[E] attention logits.
      msg:    f32[E, D] edge values.
      dst:    i32[E] destination slots.
      mask:   f32[E] edge validity.
      num_segments: static N.
      impl: 'pallas' or 'xla' (oracle path).

    Returns:
      f32[num_segments, D]; empty segments are all-zero.
    """
    if impl == "xla":
        return ref.segment_softmax_agg_ref(logits, msg, dst, mask, num_segments)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    msg, dst, mask = _pad_edges(
        msg.astype(jnp.float32), dst.astype(jnp.int32), mask.astype(jnp.float32), block_e
    )
    e, d = msg.shape
    pe = e - logits.shape[0]
    if pe:
        logits = jnp.pad(logits, (0, pe))
    logits = logits.astype(jnp.float32)
    grid = (e // block_e,)

    m = pl.pallas_call(
        _segment_max_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_segments,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), jnp.float32),
        interpret=True,
    )(dst, mask, logits)
    # Empty segments: clamp to 0 so exp() stays finite in pass 2.
    m = jnp.where(m <= NEG_INF / 2, 0.0, m)

    agg = pl.pallas_call(
        _weighted_agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e, d), lambda i: (i, 0)),
            pl.BlockSpec((num_segments,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((num_segments, d + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d + 1), jnp.float32),
        interpret=True,
    )(dst, mask, logits, msg, m)

    total, denom = agg[:, :d], agg[:, d]
    denom = jnp.where(denom == 0.0, 1.0, denom)
    return total / denom[:, None]
