"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth: every Pallas kernel must match its oracle to
float32 tolerance over the hypothesis shape sweep in
``python/tests/test_kernels.py``.  They are also the ``impl='xla'`` fast
path used by the large parameter-sweep artifacts (XLA CPU lowers
``.at[].add`` to a native scatter, which beats an interpreted Pallas loop
on this backend).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def segment_sum_ref(msg, dst, mask, num_segments):
    """Masked scatter-add: out[n] = sum_{e : dst[e]==n, mask[e]>0} msg[e].

    Args:
      msg:  f32[E, D] per-edge messages.
      dst:  i32[E] destination slot per edge (< num_segments).
      mask: f32[E] 1.0 for real edges, 0.0 for padding.
      num_segments: static int, number of destination slots.

    Returns:
      f32[num_segments, D].
    """
    msg = msg * mask[:, None]
    out = jnp.zeros((num_segments, msg.shape[1]), dtype=msg.dtype)
    return out.at[dst].add(msg)


def segment_max_ref(logits, dst, mask, num_segments):
    """Masked per-segment max of edge logits; empty segments get 0.

    Returns f32[num_segments].
    """
    masked = jnp.where(mask > 0, logits, NEG_INF)
    out = jnp.full((num_segments,), NEG_INF, dtype=logits.dtype)
    out = out.at[dst].max(masked)
    # Empty segments: leave a finite value so exp() downstream is safe.
    return jnp.where(out <= NEG_INF / 2, 0.0, out)


def segment_softmax_agg_ref(logits, msg, dst, mask, num_segments):
    """Masked per-destination softmax over edge logits, then aggregate.

    out[n] = sum_e softmax_{e' : dst[e']==n}(logits)[e] * msg[e]

    Args:
      logits: f32[E] attention logits per edge.
      msg:    f32[E, D] per-edge messages (values).
      dst:    i32[E] destination slot per edge.
      mask:   f32[E] edge validity mask.
      num_segments: static int.

    Returns:
      f32[num_segments, D]; empty segments are all-zero.
    """
    m = segment_max_ref(logits, dst, mask, num_segments)
    w = jnp.exp(logits - m[dst]) * mask
    denom = jnp.zeros((num_segments,), dtype=logits.dtype).at[dst].add(w)
    out = segment_sum_ref(msg * w[:, None], dst, jnp.ones_like(mask), num_segments)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    return out / denom[:, None]


def segment_count_ref(dst, mask, num_segments):
    """Number of real edges per destination. Returns f32[num_segments]."""
    return jnp.zeros((num_segments,), dtype=jnp.float32).at[dst].add(mask)


def segment_mean_ref(msg, dst, mask, num_segments):
    """Masked scatter-mean; empty segments are all-zero."""
    s = segment_sum_ref(msg, dst, mask, num_segments)
    c = segment_count_ref(dst, mask, num_segments)
    c = jnp.where(c == 0.0, 1.0, c)
    return s / c[:, None]
