"""GNN model zoo over padded mini-batch blocks.

Block layout (DESIGN.md §4): one node-slot array shared by all layers
with the subset property — the first ``ns[l+1]`` slots of layer *l* are
exactly the nodes of layer *l+1*; targets are the first ``ns[L]`` slots.
Edges at hop *l* connect src slots (< ns[l]) to dst slots (< ns[l+1]).
Padding edges carry ``emask=0`` and point at slot 0.

Every aggregation goes through the L1 Pallas kernels
(:func:`kernels.segment_sum` / :func:`kernels.segment_softmax_agg_diff`),
so the paper's compute hot spot lowers into the same HLO as the rest of
the model.
"""

import jax.numpy as jnp

from ..kernels import segment_sum, segment_softmax_agg_diff
from .common import ParamBuilder, dense, per_type_dense, layer_norm, leaky_relu


def _segment_mean_diff(msg, dst, mask, n, impl):
    """Differentiable masked scatter-mean via the segment_sum kernel."""
    aug = jnp.concatenate([msg, jnp.ones((msg.shape[0], 1), msg.dtype)], axis=1)
    s = segment_sum(aug, dst, mask, n, impl=impl)
    total, count = s[:, :-1], s[:, -1]
    count = jnp.where(count == 0.0, 1.0, count)
    return total / count[:, None]


# --------------------------------------------------------------- input layer


def build_input_encoder(pb: ParamBuilder, cfg):
    """Per-source input projections (GraphStorm's node input encoder).

    Three feature sources share the hidden space: dense numeric features
    (type-conditioned projection), cached LM text embeddings, and
    gathered learnable-embedding rows for featureless node types.
    """
    pb.per_type_dense("in.feat", cfg.num_ntypes, cfg.feat_dim, cfg.hidden)
    pb.dense("in.text", cfg.text_dim, cfg.hidden)
    pb.dense("in.lemb", cfg.lemb_dim, cfg.hidden)
    pb.layer_norm("in.ln", cfg.hidden)


def input_encoder(params, batch, cfg):
    h = (
        per_type_dense(params, "in.feat", batch["feat"], batch["ntype"])
        * batch["src_sel"][:, 0:1]
        + dense(params, "in.text", batch["text"]) * batch["src_sel"][:, 1:2]
        + dense(params, "in.lemb", batch["lemb"]) * batch["src_sel"][:, 2:3]
    )
    return jnp.tanh(layer_norm(params, "in.ln", h))


# ---------------------------------------------------------------- GNN layers
#
# Every layer fn has signature (params, prefix, h, src, dst, etype, emask,
# n_dst, ntype, cfg) -> f32[n_dst, H] where h is f32[n_src, H].
#
# NOTE: non-relational layers must still *consume* `etype`: XLA prunes
# entirely-unused parameters when converting StableHLO → XlaComputation,
# which would desynchronize the artifact from the manifest's input list.
# `_touch` adds a zero-valued dependence.


def _touch(emask, etype):
    return emask + 0.0 * etype.astype(jnp.float32)


def build_gcn_layer(pb, prefix, cfg):
    pb.dense(f"{prefix}.w", cfg.hidden, cfg.hidden)
    pb.dense(f"{prefix}.self", cfg.hidden, cfg.hidden)
    pb.layer_norm(f"{prefix}.ln", cfg.hidden)


def gcn_layer(params, prefix, h, src, dst, etype, emask, n_dst, ntype, cfg):
    # Sampled-graph GCN: mean aggregation stands in for the symmetric
    # 1/sqrt(d_u d_v) norm (degrees are capped by the fanout anyway).
    agg = _segment_mean_diff(h[src], dst, _touch(emask, etype), n_dst, cfg.impl)
    out = dense(params, f"{prefix}.w", agg) + dense(params, f"{prefix}.self", h[:n_dst])
    return jnp.tanh(layer_norm(params, f"{prefix}.ln", out))


def build_sage_layer(pb, prefix, cfg):
    pb.dense(f"{prefix}.w", 2 * cfg.hidden, cfg.hidden)
    pb.layer_norm(f"{prefix}.ln", cfg.hidden)


def sage_layer(params, prefix, h, src, dst, etype, emask, n_dst, ntype, cfg):
    agg = _segment_mean_diff(h[src], dst, _touch(emask, etype), n_dst, cfg.impl)
    out = dense(params, f"{prefix}.w", jnp.concatenate([h[:n_dst], agg], axis=1))
    return jnp.tanh(layer_norm(params, f"{prefix}.ln", out))


def build_gat_layer(pb, prefix, cfg):
    pb.dense(f"{prefix}.w", cfg.hidden, cfg.hidden)
    pb.normal(f"{prefix}.asrc", (cfg.hidden,), 0.1)
    pb.normal(f"{prefix}.adst", (cfg.hidden,), 0.1)
    pb.layer_norm(f"{prefix}.ln", cfg.hidden)


def gat_layer(params, prefix, h, src, dst, etype, emask, n_dst, ntype, cfg):
    z = dense(params, f"{prefix}.w", h)
    logit = leaky_relu(
        z[src] @ params[f"{prefix}.asrc"] + z[:n_dst][dst] @ params[f"{prefix}.adst"]
    )
    agg = segment_softmax_agg_diff(
        logit, z[src], dst, _touch(emask, etype), n_dst, impl=cfg.impl
    )
    return jnp.tanh(layer_norm(params, f"{prefix}.ln", agg + z[:n_dst]))


def build_rgcn_layer(pb, prefix, cfg):
    pb.per_type_dense(f"{prefix}.rel", cfg.num_etypes, cfg.hidden, cfg.hidden)
    pb.dense(f"{prefix}.self", cfg.hidden, cfg.hidden)
    pb.layer_norm(f"{prefix}.ln", cfg.hidden)


def rgcn_layer(params, prefix, h, src, dst, etype, emask, n_dst, ntype, cfg):
    msg = per_type_dense(params, f"{prefix}.rel", h[src], etype)
    agg = _segment_mean_diff(msg, dst, emask, n_dst, cfg.impl)
    out = agg + dense(params, f"{prefix}.self", h[:n_dst])
    return jnp.tanh(layer_norm(params, f"{prefix}.ln", out))


def build_rgat_layer(pb, prefix, cfg):
    pb.dense(f"{prefix}.w", cfg.hidden, cfg.hidden)
    pb.per_type_dense(f"{prefix}.rel", cfg.num_etypes, cfg.hidden, cfg.hidden)
    pb.normal(f"{prefix}.asrc", (cfg.hidden,), 0.1)
    pb.normal(f"{prefix}.adst", (cfg.hidden,), 0.1)
    pb.normal(f"{prefix}.arel", (cfg.num_etypes,), 0.1)
    pb.layer_norm(f"{prefix}.ln", cfg.hidden)


def rgat_layer(params, prefix, h, src, dst, etype, emask, n_dst, ntype, cfg):
    z = dense(params, f"{prefix}.w", h)
    msg = per_type_dense(params, f"{prefix}.rel", z[src], etype)
    logit = leaky_relu(
        z[src] @ params[f"{prefix}.asrc"]
        + z[:n_dst][dst] @ params[f"{prefix}.adst"]
        + params[f"{prefix}.arel"][etype]
    )
    agg = segment_softmax_agg_diff(logit, msg, dst, emask, n_dst, impl=cfg.impl)
    return jnp.tanh(layer_norm(params, f"{prefix}.ln", agg + z[:n_dst]))


def build_hgt_layer(pb, prefix, cfg):
    for nm in ("q", "k", "v", "out"):
        pb.per_type_dense(f"{prefix}.{nm}", cfg.num_ntypes, cfg.hidden, cfg.hidden)
    pb.normal(f"{prefix}.prior", (cfg.num_etypes,), 0.1)
    pb.layer_norm(f"{prefix}.ln", cfg.hidden)


def hgt_layer(params, prefix, h, src, dst, etype, emask, n_dst, ntype, cfg):
    # Single-head HGT-lite: type-conditioned Q/K/V, per-etype scalar
    # prior in the logit, type-conditioned output projection + residual.
    q = per_type_dense(params, f"{prefix}.q", h[:n_dst], ntype[:n_dst])
    k = per_type_dense(params, f"{prefix}.k", h, ntype)
    v = per_type_dense(params, f"{prefix}.v", h, ntype)
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.hidden))
    logit = (k[src] * q[dst]).sum(axis=1) * scale + params[f"{prefix}.prior"][etype]
    agg = segment_softmax_agg_diff(logit, v[src], dst, emask, n_dst, impl=cfg.impl)
    out = per_type_dense(params, f"{prefix}.out", agg, ntype[:n_dst])
    return jnp.tanh(layer_norm(params, f"{prefix}.ln", out + h[:n_dst]))


LAYERS = {
    "gcn": (build_gcn_layer, gcn_layer),
    "sage": (build_sage_layer, sage_layer),
    "gat": (build_gat_layer, gat_layer),
    "rgcn": (build_rgcn_layer, rgcn_layer),
    "rgat": (build_rgat_layer, rgat_layer),
    "hgt": (build_hgt_layer, hgt_layer),
}


def build_gnn(pb: ParamBuilder, cfg):
    build_input_encoder(pb, cfg)
    build_layer, _ = LAYERS[cfg.arch]
    for l in range(cfg.num_layers):
        build_layer(pb, f"l{l}", cfg)


def gnn_forward(params, batch, cfg):
    """Run the message-passing stack; returns target embeddings [ns[L], H]."""
    _, layer = LAYERS[cfg.arch]
    h = input_encoder(params, batch, cfg)
    ntype = batch["ntype"]
    for l in range(cfg.num_layers):
        n_dst = cfg.block.ns[l + 1]
        h = layer(
            params,
            f"l{l}",
            h[: cfg.block.ns[l]],
            batch[f"src{l}"],
            batch[f"dst{l}"],
            batch[f"etype{l}"],
            batch[f"emask{l}"],
            n_dst,
            ntype,
            cfg,
        )
        ntype = ntype[:n_dst]
    return h
