"""Mini-BERT: the language-model half of the LM+GNN experiments.

Stands in for HuggingFace BERT / DistilBERT (DESIGN.md §1): a token +
position embedding, ``num_lm_layers`` post-LN transformer blocks, and a
mean-pool + tanh pooler.  The *pre-training* task is single-position
masked-token prediction (the Rust trainer masks one position per
sequence); fine-tuning heads cover node classification and contrastive
link prediction, matching the paper's Table 2 / Figure 5 pipelines.

Token id 0 is PAD (attention-masked), id 1 is [MASK].
"""

import jax
import jax.numpy as jnp

from .common import ParamBuilder, dense, layer_norm

PAD_ID = 0
MASK_ID = 1


def build_lm(pb: ParamBuilder, cfg, prefix="lm"):
    pb.normal(f"{prefix}.tok", (cfg.vocab, cfg.lm_hidden), 0.02)
    pb.normal(f"{prefix}.pos", (cfg.seq_len, cfg.lm_hidden), 0.02)
    for l in range(cfg.num_lm_layers):
        p = f"{prefix}.t{l}"
        for nm in ("q", "k", "v", "o"):
            pb.dense(f"{p}.{nm}", cfg.lm_hidden, cfg.lm_hidden)
        pb.dense(f"{p}.ff1", cfg.lm_hidden, 4 * cfg.lm_hidden)
        pb.dense(f"{p}.ff2", 4 * cfg.lm_hidden, cfg.lm_hidden)
        pb.layer_norm(f"{p}.ln1", cfg.lm_hidden)
        pb.layer_norm(f"{p}.ln2", cfg.lm_hidden)
    pb.dense(f"{prefix}.pool", cfg.lm_hidden, cfg.lm_hidden)


def _attention(params, p, h, attn_mask, cfg):
    """Multi-head self-attention; attn_mask is f32[B, S] (1 = real)."""
    b, s, d = h.shape
    nh = cfg.lm_heads
    hd = d // nh

    def split(x):
        return x.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)  # [B, nh, S, hd]

    q = split(dense(params, f"{p}.q", h))
    k = split(dense(params, f"{p}.k", h))
    v = split(dense(params, f"{p}.v", h))
    logits = jnp.einsum("bhid,bhjd->bhij", q, k) / jnp.sqrt(jnp.float32(hd))
    bias = (1.0 - attn_mask)[:, None, None, :] * -1e9
    w = jax.nn.softmax(logits + bias, axis=-1)
    ctx = jnp.einsum("bhij,bhjd->bhid", w, v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return dense(params, f"{p}.o", ctx)


def lm_encode(params, tokens, cfg, prefix="lm"):
    """tokens i32[B, S] -> hidden f32[B, S, H], attn_mask f32[B, S]."""
    attn_mask = (tokens != PAD_ID).astype(jnp.float32)
    pos = jnp.arange(cfg.seq_len)
    h = params[f"{prefix}.tok"][tokens] + params[f"{prefix}.pos"][pos][None]
    for l in range(cfg.num_lm_layers):
        p = f"{prefix}.t{l}"
        h = layer_norm(params, f"{p}.ln1", h + _attention(params, p, h, attn_mask, cfg))
        ff = dense(params, f"{p}.ff2", jax.nn.gelu(dense(params, f"{p}.ff1", h)))
        h = layer_norm(params, f"{p}.ln2", h + ff)
    return h, attn_mask


def lm_pool(params, hidden, attn_mask, cfg, prefix="lm"):
    """Masked mean-pool + tanh pooler -> f32[B, H] sequence embedding."""
    denom = jnp.maximum(attn_mask.sum(axis=1, keepdims=True), 1.0)
    pooled = (hidden * attn_mask[:, :, None]).sum(axis=1) / denom
    return jnp.tanh(dense(params, f"{prefix}.pool", pooled))


def lm_embed(params, tokens, cfg, prefix="lm"):
    hidden, attn_mask = lm_encode(params, tokens, cfg, prefix)
    return lm_pool(params, hidden, attn_mask, cfg, prefix)


def build_mlm_head(pb: ParamBuilder, cfg, prefix="lm"):
    pb.dense(f"{prefix}.mlm", cfg.lm_hidden, cfg.vocab)


def mlm_logits(params, tokens, positions, cfg, prefix="lm"):
    """Vocabulary logits at one masked position per sequence.

    positions: i32[B] — the masked index in each sequence.
    """
    hidden, _ = lm_encode(params, tokens, cfg, prefix)
    at = jnp.take_along_axis(hidden, positions[:, None, None], axis=1)[:, 0]
    return dense(params, f"{prefix}.mlm", at)
