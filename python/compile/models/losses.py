"""Task losses.

Link-prediction losses follow the paper's Appendix A exactly:
cross entropy (eq. 4), weighted cross entropy (eq. 5) and contrastive
(eq. 7, an InfoNCE over one positive and its N negatives).  The LP train
artifacts take a runtime scalar ``loss_sel`` selecting contrastive (1.0)
vs (weighted) cross entropy (0.0) so one artifact serves both rows of
Table 6.
"""

import jax
import jax.numpy as jnp


def masked_softmax_xent(logits, labels, lmask):
    """Multi-class CE over valid rows; returns (mean loss, correct count).

    logits: f32[B, C]; labels: i32[B]; lmask: f32[B].
    """
    logz = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logz, labels[:, None], axis=1)[:, 0]
    denom = jnp.maximum(lmask.sum(), 1.0)
    loss = -(picked * lmask).sum() / denom
    correct = ((jnp.argmax(logits, axis=-1) == labels) * lmask).sum()
    return loss, correct


def lp_contrastive_loss(pos_score, neg_score, pmask):
    """InfoNCE (paper eq. 7): softmax of the positive among its negatives.

    pos_score: f32[B]; neg_score: f32[B, K]; pmask: f32[B].
    """
    all_scores = jnp.concatenate([pos_score[:, None], neg_score], axis=1)
    logz = jax.nn.log_softmax(all_scores, axis=1)
    denom = jnp.maximum(pmask.sum(), 1.0)
    return -(logz[:, 0] * pmask).sum() / denom


def lp_cross_entropy_loss(pos_score, neg_score, pmask, edge_weight):
    """Binary CE (paper eq. 4/5): positives→1, negatives→0.

    ``edge_weight`` implements the weighted variant (eq. 5); pass ones
    for the unweighted loss.  Negatives are averaged per positive so the
    loss scale is comparable across K.
    """
    pos_term = jax.nn.softplus(-pos_score) * edge_weight
    neg_term = jax.nn.softplus(neg_score).mean(axis=1)
    denom = jnp.maximum(pmask.sum(), 1.0)
    return (((pos_term + neg_term) * pmask).sum()) / denom


def lp_select_loss(loss_sel, pos_score, neg_score, pmask, edge_weight):
    """Runtime-selected LP loss: loss_sel=1 → contrastive, 0 → CE."""
    c = lp_contrastive_loss(pos_score, neg_score, pmask)
    x = lp_cross_entropy_loss(pos_score, neg_score, pmask, edge_weight)
    return loss_sel * c + (1.0 - loss_sel) * x


def lp_mrr_sum(pos_score, neg_score, pmask):
    """Sum of reciprocal ranks of each positive among its K negatives.

    Ties count against the positive so a constant scorer reports
    ~1/(K+1) (matches the Rust evaluator).
    """
    rank = 1.0 + (neg_score >= pos_score[:, None]).sum(axis=1).astype(jnp.float32)
    return ((1.0 / rank) * pmask).sum()


def mse_loss(pred, target, mask):
    """Row-masked MSE — the distillation objective (paper §4.4.2)."""
    per_row = ((pred - target) ** 2).mean(axis=-1)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (per_row * mask).sum() / denom
