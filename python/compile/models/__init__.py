"""L2 model zoo: JAX implementations of every GraphStorm model.

GNNs for homogeneous graphs (GCN, GraphSage, GAT), relational GNNs for
heterogeneous graphs (RGCN, RGAT, HGT-lite), a mini-BERT language model
for text-rich graphs, task decoders (node classification, DistMult /
dot-product link prediction) and the three link-prediction losses from
the paper's Appendix A.  Everything consumes padded fixed-shape
mini-batch blocks (DESIGN.md §4) so the whole step AOT-lowers to HLO.
"""
