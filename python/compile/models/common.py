"""Shared parameter containers and dense building blocks."""

import jax
import jax.numpy as jnp


class ParamBuilder:
    """Collects named parameters with deterministic PRNG splitting.

    Parameters live in a flat dict keyed by dotted names; the AOT
    manifest sorts keys lexicographically, which fixes the flat argument
    order shared with the Rust runtime.
    """

    def __init__(self, key):
        self.key = key
        self.params = {}

    def _next(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def glorot(self, name, shape):
        fan_in, fan_out = shape[-2], shape[-1]
        scale = jnp.sqrt(2.0 / (fan_in + fan_out))
        self.params[name] = jax.random.normal(self._next(), shape, jnp.float32) * scale
        return self.params[name]

    def normal(self, name, shape, stddev=0.02):
        self.params[name] = jax.random.normal(self._next(), shape, jnp.float32) * stddev
        return self.params[name]

    def zeros(self, name, shape):
        self.params[name] = jnp.zeros(shape, jnp.float32)
        return self.params[name]

    def ones(self, name, shape):
        self.params[name] = jnp.ones(shape, jnp.float32)
        return self.params[name]

    def dense(self, name, fan_in, fan_out):
        self.glorot(f"{name}.w", (fan_in, fan_out))
        self.zeros(f"{name}.b", (fan_out,))

    def per_type_dense(self, name, num_types, fan_in, fan_out):
        self.glorot(f"{name}.w", (num_types, fan_in, fan_out))
        self.zeros(f"{name}.b", (num_types, fan_out))

    def layer_norm(self, name, dim):
        self.ones(f"{name}.g", (dim,))
        self.zeros(f"{name}.o", (dim,))


def dense(params, name, x):
    """Affine map with parameters ``{name}.w`` / ``{name}.b``."""
    return x @ params[f"{name}.w"] + params[f"{name}.b"]


def per_type_dense(params, name, x, type_ids):
    """Type-conditioned affine map: row i uses weight block type_ids[i].

    Implemented as a stacked einsum followed by a take-along-axis select
    — T is small (≤8) so the extra FLOPs stay cheap and everything is a
    dense MXU-shaped contraction (no gather of weight matrices).
    """
    w = params[f"{name}.w"]  # [T, F, H]
    b = params[f"{name}.b"]  # [T, H]
    proj = jnp.einsum("nf,tfh->nth", x, w) + b[None, :, :]
    return jnp.take_along_axis(proj, type_ids[:, None, None], axis=1)[:, 0]


def layer_norm(params, name, x, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * params[f"{name}.g"] + params[f"{name}.o"]


def leaky_relu(x, slope=0.2):
    return jnp.where(x >= 0, x, slope * x)
