"""Adam, folded into the AOT train step.

The optimizer state (first/second moments + step counter) travels with
the parameters through the HLO boundary: the Rust runtime holds the
whole `[params, m, v, t]` state as device-resident PJRT buffers and the
train step returns the updated state, so a training step never copies
parameters across the host boundary.
"""

import jax
import jax.numpy as jnp


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    m = dict(zeros)
    v = jax.tree.map(jnp.zeros_like, params)
    t = jnp.zeros((), jnp.float32)
    return m, v, t


def adam_update(params, grads, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step over parameter pytrees. Returns (params', m', v', t')."""
    t = t + 1.0
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, v, grads)
    mhat_scale = 1.0 / (1.0 - b1**t)
    vhat_scale = 1.0 / (1.0 - b2**t)

    def upd(p, mm, vv):
        return p - lr * (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + eps)

    params = jax.tree.map(upd, params, m, v)
    return params, m, v, t
