"""Task decoders: classification heads and link-prediction scorers."""

import jax.numpy as jnp

from .common import ParamBuilder, dense


def build_nc_decoder(pb: ParamBuilder, cfg, prefix="dec"):
    pb.dense(f"{prefix}.cls", cfg.hidden, cfg.num_classes)


def nc_logits(params, h, prefix="dec"):
    return dense(params, f"{prefix}.cls", h)


def build_mlp_decoder(pb: ParamBuilder, in_dim, hidden, num_classes, prefix="mlp"):
    pb.dense(f"{prefix}.h", in_dim, hidden)
    pb.dense(f"{prefix}.out", hidden, num_classes)


def mlp_logits(params, x, prefix="mlp"):
    return dense(params, f"{prefix}.out", jnp.tanh(dense(params, f"{prefix}.h", x)))


def build_lp_decoder(pb: ParamBuilder, cfg, prefix="lp"):
    # DistMult relation embeddings (paper eq. 3).  Initialised at 1 so an
    # untrained scorer degrades to the dot product (paper eq. 2) — the
    # single-edge-type case.
    pb.ones(f"{prefix}.rel", (cfg.num_etypes, cfg.hidden))


def distmult_score(params, h_src, h_dst, rel_ids, prefix="lp"):
    """score(u, r, v) = sum_i emb_u[i] * emb_r[i] * emb_v[i] (eq. 3)."""
    r = params[f"{prefix}.rel"][rel_ids]
    return (h_src * r * h_dst).sum(axis=-1)
