"""AOT pipeline: lower every model variant to HLO text + manifest.

HLO *text* (not ``.serialize()``) is the interchange format — the
``xla`` crate's xla_extension 0.5.1 rejects jax≥0.5 protos with 64-bit
instruction ids, while the text parser reassigns ids cleanly (see
/opt/xla-example/README.md).

Run once via ``make artifacts``:

    python -m compile.aot --out-dir ../artifacts [--only PREFIX] [--list]

Outputs, per variant: ``<name>.hlo.txt`` (the step function),
``<name>.init.gstf`` (initial parameters), and a shared
``manifest.json`` describing the flat input/output layout that drives
the Rust runtime.
"""

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import gstf, model as M
from .models import lm as lm_mod


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# --------------------------------------------------------------- block sizes
# Canonical shapes (DESIGN.md §4).  NC: 2 hops, 64 targets, fanout 5.
# LP: 1 hop, 32 positives; seed slots = 2B + K (joint/in-batch) or
# 2B + B*K (uniform) — the uniform blow-up *is* the paper's Table 6
# data-movement argument.

NC_BATCH, NC_FANOUT, NC_LAYERS = 64, 5, 2
LP_BATCH, LP_FANOUT, LP_LAYERS = 16, 4, 2

NC_BLOCK = M.block_for(NC_BATCH, NC_FANOUT, NC_LAYERS)


def lp_block(k, uniform):
    seeds = 2 * LP_BATCH + (LP_BATCH * k if uniform else k)
    return M.block_for(seeds, LP_FANOUT, LP_LAYERS)


def gnn_cfg(arch, impl="pallas", **kw):
    return M.GnnConfig(arch=arch, impl=impl, block=NC_BLOCK, **kw)


def lp_cfg(arch, k, uniform=False, impl="xla"):
    # LP sweep variants use impl='xla' (native scatter) so the Table 6
    # epoch-time comparison isn't dominated by the interpreter; the
    # canonical Pallas path is exercised by the NC artifacts + pytest.
    return M.GnnConfig(
        arch=arch,
        impl=impl,
        num_layers=LP_LAYERS,
        block=lp_block(k, uniform),
        num_neg=k,
        lp_batch=LP_BATCH,
    )


LM_CFG = M.LmConfig()
STUDENT_CFG = M.LmConfig(num_lm_layers=1)  # the "DistilBERT" student
PROBE_B, PROBE_H = 256, 64


# ----------------------------------------------------------------- variants


def build_variants():
    """Returns {name: callable() -> (flat_fn, init_flat, meta, config)}."""
    v = {}

    def gnn_nc_train(arch, impl):
        cfg = gnn_cfg(arch, impl)
        params = M.build_gnn_params(cfg, "nc")
        spec = M.nc_batch_spec(cfg)
        fn, state0, meta = M.make_train_step(
            params, M.gnn_nc_loss(cfg), spec, grad_lemb=cfg.use_lemb
        )
        return fn, state0, meta, {"task": "nc", "arch": arch, "impl": impl,
                                  "block": {"ns": cfg.block.ns, "es": cfg.block.es},
                                  "batch": NC_BATCH, "fanout": NC_FANOUT}

    def gnn_nc_infer(arch, impl, emb=False):
        cfg = gnn_cfg(arch, impl)
        # Embedding extractors must not carry the (unused) decoder head:
        # XLA prunes unused parameters at lowering, which would desync
        # the artifact from the manifest (params matched by name, so the
        # smaller set restores fine from NC-trained checkpoints).
        params = M.build_gnn_params(cfg, "emb" if emb else "nc")
        spec = M.gnn_block_spec(cfg)
        nt = cfg.block.ns[-1]
        if emb:
            out = [("emb", (nt, cfg.hidden), M.F32)]
            fn, p0, meta = M.make_infer_step(params, M.gnn_emb_infer(cfg), spec, out)
        else:
            out = [("logits", (nt, cfg.num_classes), M.F32)]
            fn, p0, meta = M.make_infer_step(
                params, M.gnn_nc_logits_infer(cfg), spec, out
            )
        return fn, p0, meta, {"task": "nc_infer", "arch": arch, "impl": impl,
                              "block": {"ns": cfg.block.ns, "es": cfg.block.es},
                              "batch": NC_BATCH, "fanout": NC_FANOUT}

    def gnn_lp_train(arch, k, uniform):
        cfg = lp_cfg(arch, k, uniform)
        params = M.build_gnn_params(cfg, "lp")
        spec = M.lp_batch_spec(cfg)
        fn, state0, meta = M.make_train_step(
            params, M.gnn_lp_loss(cfg), spec, grad_lemb=True,
            extra_scalars=("loss_sel",),
        )
        return fn, state0, meta, {
            "task": "lp", "arch": arch, "impl": cfg.impl, "k": k,
            "uniform": uniform, "lp_batch": LP_BATCH, "fanout": LP_FANOUT,
            "block": {"ns": cfg.block.ns, "es": cfg.block.es},
        }

    def gnn_lp_emb(arch, k):
        cfg = lp_cfg(arch, k)
        params = M.build_gnn_params(cfg, "lp")
        spec = M.gnn_block_spec(cfg)
        nt = cfg.block.ns[-1]
        out = [("emb", (nt, cfg.hidden), M.F32),
               ("rel", (cfg.num_etypes, cfg.hidden), M.F32)]
        fn, p0, meta = M.make_infer_step(
            params, M.gnn_emb_infer(cfg, with_rel=True), spec, out
        )
        return fn, p0, meta, {"task": "lp_infer", "arch": arch, "impl": cfg.impl,
                              "k": k, "lp_batch": LP_BATCH, "fanout": LP_FANOUT,
                              "block": {"ns": cfg.block.ns, "es": cfg.block.es}}

    # GNN zoo: train + logits for every architecture (Pallas path), plus
    # 'fast' XLA-scatter twins of the two canonical models for the big
    # parameter sweeps (Table 3 trains thousands of steps).
    for arch in ("gcn", "sage", "gat", "rgcn", "rgat", "hgt"):
        v[f"{arch}_nc_train"] = lambda a=arch: gnn_nc_train(a, "pallas")
        v[f"{arch}_nc_logits"] = lambda a=arch: gnn_nc_infer(a, "pallas")
    for arch in ("gcn", "rgcn"):
        v[f"{arch}_nc_train_fast"] = lambda a=arch: gnn_nc_train(a, "xla")
        v[f"{arch}_nc_logits_fast"] = lambda a=arch: gnn_nc_infer(a, "xla")
    v["rgcn_nc_emb"] = lambda: gnn_nc_infer("rgcn", "pallas", emb=True)
    v["rgcn_nc_emb_fast"] = lambda: gnn_nc_infer("rgcn", "xla", emb=True)

    for k in (4, 32, 256):
        v[f"rgcn_lp_joint_k{k}_train"] = lambda kk=k: gnn_lp_train("rgcn", kk, False)
    v["rgcn_lp_uniform_k32_train"] = lambda: gnn_lp_train("rgcn", 32, True)
    v["rgcn_lp_emb"] = lambda: gnn_lp_emb("rgcn", 32)

    # ------------------------------------------------------------- LM tasks
    def lm_mlm_train():
        cfg = LM_CFG
        params = M.build_lm_params(cfg, heads=("mlm",))
        spec = [
            ("tokens", (cfg.batch, cfg.seq_len), M.I32),
            ("positions", (cfg.batch,), M.I32),
            ("labels", (cfg.batch,), M.I32),
            ("lmask", (cfg.batch,), M.F32),
        ]
        fn, s0, meta = M.make_train_step(params, M.lm_mlm_loss(cfg), spec)
        return fn, s0, meta, {"task": "lm_mlm", "batch": cfg.batch,
                              "seq_len": cfg.seq_len, "vocab": cfg.vocab}

    def lm_nc_train():
        cfg = LM_CFG
        params = M.build_lm_params(cfg, heads=("nc",))
        spec = [
            ("tokens", (cfg.batch, cfg.seq_len), M.I32),
            ("labels", (cfg.batch,), M.I32),
            ("lmask", (cfg.batch,), M.F32),
        ]
        fn, s0, meta = M.make_train_step(params, M.lm_nc_loss(cfg), spec)
        return fn, s0, meta, {"task": "lm_nc", "batch": cfg.batch,
                              "seq_len": cfg.seq_len}

    def lm_lp_train():
        cfg = M.LmConfig(batch=32)
        params = M.build_lm_params(cfg, heads=())
        spec = [
            ("src_tokens", (cfg.batch, cfg.seq_len), M.I32),
            ("dst_tokens", (cfg.batch, cfg.seq_len), M.I32),
            ("neg_tokens", (cfg.num_neg, cfg.seq_len), M.I32),
            ("pmask", (cfg.batch,), M.F32),
        ]
        fn, s0, meta = M.make_train_step(params, M.lm_lp_loss(cfg), spec)
        return fn, s0, meta, {"task": "lm_lp", "batch": cfg.batch,
                              "k": cfg.num_neg, "seq_len": cfg.seq_len}

    def lm_embed(cfg, heads, name):
        params = M.build_lm_params(cfg, heads=heads)
        spec = [("tokens", (cfg.batch, cfg.seq_len), M.I32)]

        def infer(p, b):
            emb = lm_mod.lm_embed(p, b["tokens"], cfg)
            if "distill" in heads:
                emb = emb @ p["lm.proj.w"] + p["lm.proj.b"]
            return emb

        out = [("emb", (cfg.batch, cfg.hidden if "distill" in heads
                        else cfg.lm_hidden), M.F32)]
        fn, p0, meta = M.make_infer_step(params, infer, spec, out)
        return fn, p0, meta, {"task": name, "batch": cfg.batch,
                              "seq_len": cfg.seq_len}

    def lm_nc_logits():
        cfg = LM_CFG
        params = M.build_lm_params(cfg, heads=("nc",))
        spec = [("tokens", (cfg.batch, cfg.seq_len), M.I32)]

        def infer(p, b):
            emb = lm_mod.lm_embed(p, b["tokens"], cfg)
            return emb @ p["lm.cls.w"] + p["lm.cls.b"]

        out = [("logits", (cfg.batch, cfg.num_classes), M.F32)]
        fn, p0, meta = M.make_infer_step(params, infer, spec, out)
        return fn, p0, meta, {"task": "lm_nc_logits", "batch": cfg.batch,
                              "seq_len": cfg.seq_len}

    def distill_train():
        cfg = STUDENT_CFG
        params = M.build_lm_params(cfg, heads=("distill",))
        spec = [
            ("tokens", (cfg.batch, cfg.seq_len), M.I32),
            ("teacher", (cfg.batch, cfg.hidden), M.F32),
            ("lmask", (cfg.batch,), M.F32),
        ]
        fn, s0, meta = M.make_train_step(params, M.lm_distill_loss(cfg), spec)
        return fn, s0, meta, {"task": "distill", "batch": cfg.batch,
                              "seq_len": cfg.seq_len}

    def student_nc_train():
        cfg = STUDENT_CFG
        params = M.build_lm_params(cfg, heads=("nc",))
        spec = [
            ("tokens", (cfg.batch, cfg.seq_len), M.I32),
            ("labels", (cfg.batch,), M.I32),
            ("lmask", (cfg.batch,), M.F32),
        ]
        fn, s0, meta = M.make_train_step(params, M.lm_nc_loss(cfg), spec)
        return fn, s0, meta, {"task": "student_nc", "batch": cfg.batch,
                              "seq_len": cfg.seq_len}

    v["lm_mlm_train"] = lm_mlm_train
    v["lm_nc_train"] = lm_nc_train
    v["lm_lp_train"] = lm_lp_train
    v["lm_embed"] = lambda: lm_embed(LM_CFG, (), "lm_embed")
    v["lm_nc_logits"] = lm_nc_logits
    v["student_nc_train"] = student_nc_train
    v["student_embed"] = lambda: lm_embed(STUDENT_CFG, (), "student_embed")
    v["distill_train"] = distill_train
    v["distill_embed"] = lambda: lm_embed(STUDENT_CFG, ("distill",), "distill_embed")

    # ------------------------------------------------------------ MLP probe
    def mlp_train():
        params = M.build_probe_params(PROBE_H, PROBE_H, 16)
        spec = [
            ("emb", (PROBE_B, PROBE_H), M.F32),
            ("labels", (PROBE_B,), M.I32),
            ("lmask", (PROBE_B,), M.F32),
        ]
        fn, s0, meta = M.make_train_step(params, M.probe_loss(), spec)
        return fn, s0, meta, {"task": "mlp_probe", "batch": PROBE_B}

    def mlp_logits():
        params = M.build_probe_params(PROBE_H, PROBE_H, 16)
        spec = [("emb", (PROBE_B, PROBE_H), M.F32)]

        def infer(p, b):
            from .models import decoders

            return decoders.mlp_logits(p, b["emb"])

        out = [("logits", (PROBE_B, 16), M.F32)]
        fn, p0, meta = M.make_infer_step(params, infer, spec, out)
        return fn, p0, meta, {"task": "mlp_logits", "batch": PROBE_B}

    v["mlp_train"] = mlp_train
    v["mlp_logits"] = mlp_logits

    # Runtime smoke test: fn(x, y) = (x@y + 2,)
    def smoke():
        def fn(x, y):
            return (x @ y + 2.0,)

        meta = {
            "n_params": 0,
            "param_names": [],
            "state": [],
            "scalars": [],
            "batch": [("x", (2, 2), M.F32), ("y", (2, 2), M.F32)],
            "outputs": [("z", (2, 2), M.F32)],
        }
        return fn, [], meta, {"task": "smoke"}

    v["smoke"] = smoke
    return v


def emit(name, builder, out_dir):
    fn, init_flat, meta, config = builder()
    in_specs = M.spec_to_args(meta["state"] + meta["scalars"] + meta["batch"])
    lowered = jax.jit(fn).lower(*in_specs)
    hlo = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    kind = "train" if any(n == "lr" for n, _, _ in meta["scalars"]) else "infer"
    init_file = None
    if meta["n_params"]:
        # Params only — Rust builds the zero Adam moments from the spec.
        names = meta["param_names"]
        init_file = f"{name}.init.gstf"
        gstf.write(
            os.path.join(out_dir, init_file),
            [(f"p:{n}", np.asarray(init_flat[i])) for i, n in enumerate(names)],
        )

    def specs(lst):
        return [{"name": n, "shape": list(s), "dtype": d} for n, s, d in lst]

    entry = {
        "file": f"{name}.hlo.txt",
        "init_file": init_file,
        "kind": kind,
        "n_params": meta["n_params"],
        "state": specs(meta["state"]),
        "scalars": specs(meta["scalars"]),
        "batch": specs(meta["batch"]),
        "outputs": specs(meta["outputs"]),
        "config": config,
    }
    return entry, len(hlo)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="prefix filter")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    variants = build_variants()
    if args.list:
        for n in variants:
            print(n)
        return

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"artifacts": {}}
    if args.only and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    for name, builder in variants.items():
        if args.only and not name.startswith(args.only):
            continue
        import time

        t0 = time.time()
        entry, hlo_len = emit(name, builder, args.out_dir)
        manifest["artifacts"][name] = entry
        print(
            f"[aot] {name}: {hlo_len/1e6:.2f} MB HLO, "
            f"{entry['n_params']} params, {time.time()-t0:.1f}s",
            file=sys.stderr,
        )

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {manifest_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
