"""L2 model zoo tests: shapes, gradient flow, loss semantics, and the
AOT manifest's consistency with the step functions."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.models import gnn, lm, losses, optim
from compile.models.common import ParamBuilder


def tiny_cfg(arch="rgcn", impl="xla"):
    return M.GnnConfig(
        arch=arch,
        impl=impl,
        block=M.block_for(8, 3, 2),
        hidden=16,
        feat_dim=8,
        text_dim=8,
        lemb_dim=8,
        num_classes=4,
    )


def random_batch(cfg, rng, with_labels=True):
    spec = M.nc_batch_spec(cfg) if with_labels else M.gnn_block_spec(cfg)
    args = []
    for name, shape, dt in spec:
        if dt == M.I32:
            hi = 4
            if name.startswith(("src", "dst")):
                l = int(name[3:])
                hi = cfg.block.ns[l if name.startswith("src") else l + 1]
            elif name == "etype":
                hi = cfg.num_etypes
            elif name == "labels":
                hi = cfg.num_classes
            args.append(jnp.asarray(rng.integers(0, max(hi, 1), size=shape), jnp.int32))
        else:
            args.append(jnp.asarray(rng.random(shape), jnp.float32))
    return M.batch_dict(spec, args)


@pytest.mark.parametrize("arch", list(gnn.LAYERS.keys()))
def test_gnn_forward_shapes(arch):
    cfg = tiny_cfg(arch)
    params = M.build_gnn_params(cfg, "nc")
    rng = np.random.default_rng(0)
    batch = random_batch(cfg, rng)
    h = gnn.gnn_forward(params, batch, cfg)
    assert h.shape == (cfg.block.ns[-1], cfg.hidden)
    assert np.isfinite(np.asarray(h)).all()


@pytest.mark.parametrize("arch", list(gnn.LAYERS.keys()))
def test_gnn_loss_grads_finite_and_nonzero(arch):
    cfg = tiny_cfg(arch)
    params = M.build_gnn_params(cfg, "nc")
    rng = np.random.default_rng(1)
    batch = random_batch(cfg, rng)
    loss_fn = M.gnn_nc_loss(cfg)
    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, ()), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    total = sum(float(jnp.abs(g).sum()) for g in grads.values())
    assert total > 0, f"{arch}: all-zero gradients"


def test_nc_train_step_reduces_loss():
    """The assembled train step must optimize a learnable toy problem."""
    cfg = tiny_cfg("gcn")
    params = M.build_gnn_params(cfg, "nc")
    spec = M.nc_batch_spec(cfg)
    fn, state0, meta = M.make_train_step(params, M.gnn_nc_loss(cfg), spec, grad_lemb=True)
    rng = np.random.default_rng(2)
    batch = random_batch(cfg, rng)
    # Make labels depend on feat: class = argmax of first 4 feat dims.
    feat = np.asarray(batch["feat"])
    nt = cfg.block.ns[-1]
    labels = feat[:nt, :4].argmax(axis=1).astype(np.int32)
    batch["labels"] = jnp.asarray(labels)
    batch["src_sel"] = jnp.zeros_like(batch["src_sel"]).at[:, 0].set(1.0)
    flat_batch = [batch[n] for n, _, _ in spec]
    state = list(state0)
    first = last = None
    for _ in range(30):
        out = fn(*state, jnp.float32(0.01), *flat_batch)
        ns = len(state)
        state = list(out[:ns])
        loss = float(out[ns])
        first = first or loss
        last = loss
    assert last < first * 0.7, f"{first} -> {last}"


def test_lp_loss_selection():
    """loss_sel must switch between contrastive and CE."""
    rng = np.random.default_rng(3)
    pos = jnp.asarray(rng.standard_normal(8), jnp.float32)
    neg = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    pm = jnp.ones(8)
    ew = jnp.ones(8)
    c = losses.lp_contrastive_loss(pos, neg, pm)
    x = losses.lp_cross_entropy_loss(pos, neg, pm, ew)
    assert float(losses.lp_select_loss(1.0, pos, neg, pm, ew)) == pytest.approx(float(c))
    assert float(losses.lp_select_loss(0.0, pos, neg, pm, ew)) == pytest.approx(float(x))


def test_contrastive_loss_decreases_with_separation():
    pm = jnp.ones(4)
    neg = jnp.zeros((4, 8))
    l_small = losses.lp_contrastive_loss(jnp.full(4, 0.1), neg, pm)
    l_big = losses.lp_contrastive_loss(jnp.full(4, 3.0), neg, pm)
    assert float(l_big) < float(l_small)


def test_weighted_ce_respects_edge_weight():
    pos = jnp.asarray([0.5, 0.5])
    neg = jnp.zeros((2, 4))
    pm = jnp.ones(2)
    l1 = losses.lp_cross_entropy_loss(pos, neg, pm, jnp.asarray([1.0, 1.0]))
    l2 = losses.lp_cross_entropy_loss(pos, neg, pm, jnp.asarray([0.0, 0.0]))
    # Zero-weight positives remove the positive term only.
    assert float(l2) < float(l1)


def test_mrr_sum_matches_manual():
    pos = jnp.asarray([2.0, 0.0])
    neg = jnp.asarray([[1.0, 3.0], [1.0, -1.0]])
    pm = jnp.ones(2)
    # pos0: one neg above -> rank 2 -> 0.5; pos1: one above -> rank 2 -> 0.5
    assert float(losses.lp_mrr_sum(pos, neg, pm)) == pytest.approx(1.0)


def test_masked_xent_ignores_masked_rows():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]])
    labels = jnp.asarray([0, 0])
    l_full, c_full = losses.masked_softmax_xent(logits, labels, jnp.ones(2))
    l_mask, c_mask = losses.masked_softmax_xent(logits, labels, jnp.asarray([1.0, 0.0]))
    assert float(l_mask) < float(l_full)
    assert int(c_full) == 1 and int(c_mask) == 1


def test_adam_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    m, v, t = optim.adam_init(params)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, m, v, t = optim.adam_update(params, g, m, v, t, 0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_lm_embed_shapes_and_padding_invariance():
    cfg = M.LmConfig(vocab=64, seq_len=8, lm_hidden=16, num_lm_layers=1, batch=4)
    pb = ParamBuilder(jax.random.PRNGKey(0))
    lm.build_lm(pb, cfg)
    tokens = jnp.asarray(
        [[5, 6, 7, 0, 0, 0, 0, 0], [9, 0, 0, 0, 0, 0, 0, 0]] * 2, jnp.int32
    )
    emb = lm.lm_embed(pb.params, tokens, cfg)
    assert emb.shape == (4, 16)
    # Changing a PAD position's (masked) token must not change the row...
    # note PAD id participates in embedding lookup only if unmasked; row 0
    # has pads at positions 3+.
    tokens2 = tokens.at[0, 7].set(0)  # no-op change
    emb2 = lm.lm_embed(pb.params, tokens2, cfg)
    np.testing.assert_allclose(np.asarray(emb), np.asarray(emb2), rtol=1e-6)


def test_mlm_logits_pick_position():
    cfg = M.LmConfig(vocab=32, seq_len=4, lm_hidden=8, num_lm_layers=1, batch=2)
    pb = ParamBuilder(jax.random.PRNGKey(1))
    lm.build_lm(pb, cfg)
    lm.build_mlm_head(pb, cfg)
    tokens = jnp.asarray([[2, 1, 3, 0], [1, 5, 6, 7]], jnp.int32)
    pos = jnp.asarray([1, 0], jnp.int32)
    logits = lm.mlm_logits(pb.params, tokens, pos, cfg)
    assert logits.shape == (2, 32)
    assert np.isfinite(np.asarray(logits)).all()


def test_manifest_matches_emitted_files():
    import json
    import os

    mdir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.exists(os.path.join(mdir, "manifest.json")):
        pytest.skip("artifacts not built")
    with open(os.path.join(mdir, "manifest.json")) as f:
        manifest = json.load(f)
    arts = manifest["artifacts"]
    assert "rgcn_nc_train" in arts and "smoke" in arts
    for name, a in arts.items():
        assert os.path.exists(os.path.join(mdir, a["file"])), name
        if a["init_file"]:
            assert os.path.exists(os.path.join(mdir, a["init_file"])), name
        assert len(a["state"]) == (3 * a["n_params"] + 1 if a["kind"] == "train" else a["n_params"])
        if a["kind"] == "train":
            assert a["scalars"][0]["name"] == "lr"
            assert [o["name"] for o in a["outputs"][len(a["state"]):]][:2] == ["loss", "metric"]


def test_init_gstf_roundtrip_matches_params():
    from compile import gstf
    import os

    mdir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    p = os.path.join(mdir, "mlp_train.init.gstf")
    if not os.path.exists(p):
        pytest.skip("artifacts not built")
    tensors = gstf.read(p)
    assert all(n.startswith("p:") for n, _ in tensors)
    params = M.build_probe_params(64, 64, 16)
    by_name = {f"p:{k}": v for k, v in params.items()}
    for n, arr in tensors:
        np.testing.assert_allclose(arr, np.asarray(by_name[n]), rtol=1e-6)
