"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, segment counts, mask densities and value
ranges; every property asserts allclose between the interpret-mode
Pallas kernel and the ref oracle, plus hand-checked fixtures.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import segment_sum, segment_mean, segment_softmax_agg
from compile.kernels import ref


def _rand_case(rng, e, n, d, mask_density):
    msg = rng.standard_normal((e, d), dtype=np.float32)
    dst = rng.integers(0, n, size=e).astype(np.int32)
    mask = (rng.random(e) < mask_density).astype(np.float32)
    return msg, dst, mask


# ---------------------------------------------------------------- fixtures


def test_segment_sum_tiny_fixture():
    msg = jnp.array([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0], [7.0, 8.0]])
    dst = jnp.array([0, 2, 0, 1], dtype=jnp.int32)
    mask = jnp.array([1.0, 1.0, 1.0, 0.0])
    out = segment_sum(msg, dst, mask, 3)
    expect = np.array([[6.0, 8.0], [0.0, 0.0], [3.0, 4.0]])
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6)


def test_segment_sum_all_masked():
    msg = jnp.ones((8, 4))
    dst = jnp.zeros(8, dtype=jnp.int32)
    mask = jnp.zeros(8)
    out = segment_sum(msg, dst, mask, 5)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((5, 4)))


def test_segment_sum_single_segment():
    rng = np.random.default_rng(0)
    msg, dst, mask = _rand_case(rng, 300, 1, 16, 1.0)
    out = segment_sum(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), 1)
    np.testing.assert_allclose(
        np.asarray(out)[0], msg.sum(axis=0), rtol=1e-4, atol=1e-4
    )


def test_segment_mean_fixture():
    msg = jnp.array([[2.0], [4.0], [10.0]])
    dst = jnp.array([0, 0, 1], dtype=jnp.int32)
    mask = jnp.ones(3)
    out = segment_mean(msg, dst, mask, 3)
    expect = np.array([[3.0], [10.0], [0.0]])  # empty segment -> 0
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6)


def test_softmax_agg_uniform_logits_is_mean():
    """Equal logits must reduce softmax-agg to a masked mean."""
    rng = np.random.default_rng(1)
    msg, dst, mask = _rand_case(rng, 100, 7, 8, 0.8)
    logits = np.zeros(100, dtype=np.float32)
    out = segment_softmax_agg(
        jnp.asarray(logits), jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), 7
    )
    expect = ref.segment_mean_ref(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), 7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-5)


def test_softmax_agg_one_dominant_logit():
    """A huge logit must select exactly that edge's value."""
    msg = jnp.array([[1.0, 0.0], [5.0, 5.0], [9.0, 9.0]])
    dst = jnp.array([0, 0, 0], dtype=jnp.int32)
    mask = jnp.ones(3)
    logits = jnp.array([0.0, 50.0, 0.0])
    out = segment_softmax_agg(logits, msg, dst, mask, 2)
    np.testing.assert_allclose(np.asarray(out)[0], [5.0, 5.0], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out)[1], [0.0, 0.0])


def test_softmax_agg_large_logits_stable():
    """Stability: logits near 1e4 must not produce inf/nan."""
    rng = np.random.default_rng(2)
    msg, dst, mask = _rand_case(rng, 64, 4, 4, 1.0)
    logits = rng.uniform(9000, 10000, 64).astype(np.float32)
    out = segment_softmax_agg(
        jnp.asarray(logits), jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), 4
    )
    assert np.isfinite(np.asarray(out)).all()


def test_impl_xla_matches_pallas():
    rng = np.random.default_rng(3)
    msg, dst, mask = _rand_case(rng, 500, 33, 24, 0.7)
    a = segment_sum(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), 33, impl="pallas")
    b = segment_sum(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), 33, impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_bad_impl_raises():
    with pytest.raises(ValueError):
        segment_sum(jnp.ones((4, 2)), jnp.zeros(4, jnp.int32), jnp.ones(4), 2, impl="cuda")


# ---------------------------------------------------------- hypothesis sweeps

shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=700),    # E
    st.integers(min_value=1, max_value=50),     # N
    st.sampled_from([1, 3, 8, 17, 64]),         # D
    st.sampled_from([0.0, 0.3, 0.9, 1.0]),      # mask density
    st.integers(min_value=0, max_value=2**31 - 1),
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_segment_sum_matches_ref(case):
    e, n, d, density, seed = case
    rng = np.random.default_rng(seed)
    msg, dst, mask = _rand_case(rng, e, n, d, density)
    got = segment_sum(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    want = ref.segment_sum_ref(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_segment_mean_matches_ref(case):
    e, n, d, density, seed = case
    rng = np.random.default_rng(seed)
    msg, dst, mask = _rand_case(rng, e, n, d, density)
    got = segment_mean(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    want = ref.segment_mean_ref(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(shape_strategy)
def test_softmax_agg_matches_ref(case):
    e, n, d, density, seed = case
    rng = np.random.default_rng(seed)
    msg, dst, mask = _rand_case(rng, e, n, d, density)
    logits = rng.standard_normal(e).astype(np.float32) * 3.0
    got = segment_softmax_agg(
        jnp.asarray(logits), jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n
    )
    want = ref.segment_softmax_agg_ref(
        jnp.asarray(logits), jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=1, max_value=300),
    st.integers(min_value=1, max_value=20),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_segment_sum_block_size_invariant(e, n, seed):
    """Result must not depend on the E-tile size."""
    rng = np.random.default_rng(seed)
    msg, dst, mask = _rand_case(rng, e, n, 8, 0.9)
    a = segment_sum(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n, block_e=64)
    b = segment_sum(jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask), n, block_e=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- gradients


def test_segment_sum_grad_matches_ref():
    import jax

    rng = np.random.default_rng(7)
    msg, dst, mask = _rand_case(rng, 120, 9, 6, 0.8)
    msg, dst, mask = jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask)
    cotangent = jnp.asarray(rng.standard_normal((9, 6)).astype(np.float32))
    g1 = jax.grad(lambda m: (segment_sum(m, dst, mask, 9) * cotangent).sum())(msg)
    g2 = jax.grad(lambda m: (ref.segment_sum_ref(m, dst, mask, 9) * cotangent).sum())(msg)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-5)


def test_softmax_agg_diff_grad_matches_ref():
    import jax
    from compile.kernels import segment_softmax_agg_diff

    rng = np.random.default_rng(8)
    msg, dst, mask = _rand_case(rng, 80, 6, 5, 0.9)
    logits = rng.standard_normal(80).astype(np.float32)
    args = (jnp.asarray(logits), jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask))
    f1 = lambda l, m: segment_softmax_agg_diff(l, m, args[2], args[3], 6).sum()
    f2 = lambda l, m: ref.segment_softmax_agg_ref(l, m, args[2], args[3], 6).sum()
    ga = jax.grad(f1, argnums=(0, 1))(args[0], args[1])
    gb = jax.grad(f2, argnums=(0, 1))(args[0], args[1])
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_softmax_agg_diff_forward_matches_fused():
    from compile.kernels import segment_softmax_agg_diff

    rng = np.random.default_rng(9)
    msg, dst, mask = _rand_case(rng, 90, 8, 4, 0.7)
    logits = rng.standard_normal(90).astype(np.float32) * 2
    args = (jnp.asarray(logits), jnp.asarray(msg), jnp.asarray(dst), jnp.asarray(mask))
    a = segment_softmax_agg_diff(*args, 8)
    b = segment_softmax_agg(*args, 8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
