//! End-to-end driver (DESIGN.md §3, EXPERIMENTS.md §E2E): the full
//! stack on a real small workload.
//!
//!   synthetic MAG-like graph (~6.6K nodes, ~90K edges)
//!   → METIS-like partition into 4 parts
//!   → LM pre-train (masked token) + task fine-tune
//!   → LM embeddings for all 4K papers
//!   → RGCN node classification, 10 epochs (≈380 train steps),
//!     loss curve logged every 10 steps
//!   → accuracy + cross-partition traffic + cluster cost estimate.
//!
//! Run: `cargo run --release --example mag_nc`

use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::{apply_lemb_grads, NodeDataLoader, Split};
use graphstorm::dist::CostModel;
use graphstorm::partition::metis_like_partition;
use graphstorm::runtime::{Runtime, TrainState};
use graphstorm::trainer::{LmTrainer, NodeTrainer, TrainOptions};
use graphstorm::util::Rng;

fn main() -> anyhow::Result<()> {
    let t_all = std::time::Instant::now();
    let rt = Runtime::from_default_dir()?;

    // ---- stage 1: data + partition -------------------------------------
    let t0 = std::time::Instant::now();
    let raw = mag::generate(&mag::MagConfig { n_papers: 4000, ..Default::default() });
    let book = metis_like_partition(&raw.graph, 4, 7);
    let cut = graphstorm::partition::edge_cut(&raw.graph, &book);
    let mut ds = datagen::build_dataset(raw, book, 64, 7);
    let s = ds.graph.stats();
    println!(
        "[data] {} nodes, {} edges, {}/{} types; METIS-like 4 parts, edge-cut {:.1}% ({:.2}s)",
        s.num_nodes, s.num_edges, s.num_ntypes, s.num_etypes, cut * 100.0, t0.elapsed().as_secs_f64()
    );

    // ---- stage 2: LM ----------------------------------------------------
    let lm = LmTrainer::default();
    let t1 = std::time::Instant::now();
    let (mlm_loss, st) = lm.pretrain_mlm(&rt, &ds, 0, &TrainOptions { epochs: 1, ..Default::default() })?;
    let (ft_loss, st) = lm.finetune_nc(&rt, &ds, &st.params_host()?, &TrainOptions { epochs: 2, ..Default::default() })?;
    let embed_s = lm.embed_all(&rt, &mut ds, &st.params_host()?, &TrainOptions::default())?;
    println!(
        "[lm] mlm loss {:.3}, ftnc loss {:.3}, embed 4000 papers in {:.1}s (stage {:.1}s)",
        mlm_loss, ft_loss, embed_s, t1.elapsed().as_secs_f64()
    );

    // ---- stage 3: RGCN training with a logged loss curve ----------------
    let spec = rt.manifest.get("rgcn_nc_train")?.clone();
    let loader = NodeDataLoader::new(&spec)?;
    let mut st = TrainState::new(&rt, "rgcn_nc_train")?;
    let ldim = spec.batch_spec("lemb").map(|t| t.shape[1]).unwrap_or(0);
    let train_ids = ds.node_labels().ids_in(Split::Train);
    let mut rng = Rng::seed_from(7);
    ds.engine.counters.reset();
    let t2 = std::time::Instant::now();
    let mut step = 0usize;
    println!("[train] RGCN 2-layer, fanout 5/5, batch 64, lr 3e-3, 10 epochs over {} train nodes", train_ids.len());
    for epoch in 0..10 {
        let mut ids = train_ids.clone();
        rng.shuffle(&mut ids);
        for (bi, chunk) in ids.chunks(loader.batch_size()).enumerate() {
            let worker = (bi % 4) as u32;
            let (batch, touch, _) = loader.batch(&ds, chunk, &mut rng, worker)?;
            let out = st.step(&rt, &[3e-3], &batch)?;
            if let Some(g) = &out.grad_lemb {
                apply_lemb_grads(&mut ds.engine, &touch, g, ldim, 3e-3);
            }
            if step % 10 == 0 {
                println!("  step {step:>4}  epoch {epoch}  loss {:.4}", out.loss);
            }
            step += 1;
        }
    }
    let train_s = t2.elapsed().as_secs_f64();
    let traffic = ds.engine.counters.snapshot();

    // ---- stage 4: evaluation + cost model -------------------------------
    let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
    let opts = TrainOptions::default();
    let val = trainer.evaluate(&rt, &ds, &st, Split::Val, &opts)?;
    let test = trainer.evaluate(&rt, &ds, &st, Split::Test, &opts)?;
    let cm = CostModel::default();
    let est4 = cm.estimate(train_s, traffic.remote_bytes, step as u64, 4);
    println!("[eval] val acc {val:.4}, test acc {test:.4} (chance {:.3})", 1.0 / ds.num_classes as f64);
    println!(
        "[dist] {} steps, remote traffic {:.1} MB ({:.0}% of gathers remote); est. 4-instance wall {:.1}s",
        step,
        traffic.remote_bytes as f64 / 1e6,
        100.0 * traffic.remote_elems as f64 / (traffic.remote_elems + traffic.local_elems).max(1) as f64,
        est4
    );
    println!("[total] {:.1}s end-to-end", t_all.elapsed().as_secs_f64());
    assert!(test > 2.0 / ds.num_classes as f64, "model failed to beat 2x chance");
    println!("mag_nc OK");
    Ok(())
}
