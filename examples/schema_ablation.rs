//! Graph-schema ablation (the paper's §4.3 story, interactive size):
//! render the same customer-log "world" as three schemas and watch the
//! metrics move — LP improves with every schema addition, NC only with
//! reviews.
//!
//! Run: `cargo run --release --example schema_ablation`

use graphstorm::datagen::{self, amazon};
use graphstorm::partition::PartitionBook;
use graphstorm::runtime::Runtime;
use graphstorm::sampling::NegSampler;
use graphstorm::trainer::lp::{LpLoss, LpTrainer};
use graphstorm::trainer::{NodeTrainer, TrainOptions};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_dir()?;
    let world = amazon::generate_world(&amazon::ArConfig { n_items: 1500, ..Default::default() });
    let opts = TrainOptions { epochs: 3, verbose: false, ..Default::default() };

    println!("{:<30} {:>10} {:>10}", "schema", "LP MRR", "NC Acc");
    for (variant, name) in [
        (amazon::ArVariant::Homogeneous, "item only"),
        (amazon::ArVariant::HeteroV1, "+ review"),
        (amazon::ArVariant::HeteroV2, "+ customer (featureless)"),
    ] {
        let build = || {
            let raw = amazon::build_variant(&world, variant);
            let book = PartitionBook::single(&raw.graph.num_nodes);
            let mut ds = datagen::build_dataset(raw, book, 64, 7);
            ds.ensure_text_features(64);
            ds
        };
        let mut ds = build();
        let mut lp = LpTrainer::new(
            "rgcn_lp_joint_k32_train",
            "rgcn_lp_emb",
            LpLoss::Contrastive,
            NegSampler::Joint { k: 32 },
        );
        lp.max_train_edges = Some(1600);
        let (lp_rep, _) = lp.fit(&rt, &mut ds, &opts)?;

        let mut ds = build();
        let nc = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
        let (nc_rep, _) = nc.fit(&rt, &mut ds, &opts)?;
        println!("{:<30} {:>10.4} {:>10.4}", name, lp_rep.test_mrr, nc_rep.test_acc);
    }
    println!("\nschema_ablation OK");
    Ok(())
}
