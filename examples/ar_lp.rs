//! Link prediction on the Amazon-Review-like graph: compares negative
//! samplers and losses on one run and prints the traffic counters —
//! a minimal interactive version of the Table 6 bench.
//!
//! Run: `cargo run --release --example ar_lp`

use graphstorm::datagen::{self, amazon};
use graphstorm::partition::random_partition;
use graphstorm::runtime::Runtime;
use graphstorm::sampling::NegSampler;
use graphstorm::trainer::lp::{LpLoss, LpTrainer};
use graphstorm::trainer::TrainOptions;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::from_default_dir()?;
    let world = amazon::generate_world(&amazon::ArConfig { n_items: 2000, ..Default::default() });

    println!("LP on (item, also_buy, item); batch 32, contrastive vs CE, 3 epochs\n");
    println!("{:<14} {:<12} {:>8} {:>8} {:>12}", "loss", "sampler", "MRR", "s/epoch", "remote MB");
    for (loss, sampler) in [
        (LpLoss::Contrastive, NegSampler::InBatch { k: 32 }),
        (LpLoss::Contrastive, NegSampler::Joint { k: 32 }),
        (LpLoss::Contrastive, NegSampler::Uniform { k: 32 }),
        (LpLoss::CrossEntropy, NegSampler::Joint { k: 4 }),
        (LpLoss::CrossEntropy, NegSampler::Joint { k: 32 }),
    ] {
        let raw = amazon::build_variant(&world, amazon::ArVariant::HeteroV2);
        let book = random_partition(&raw.graph, 2, 7);
        let mut ds = datagen::build_dataset(raw, book, 64, 7);
        ds.ensure_text_features(64);
        let artifact = match sampler {
            NegSampler::Uniform { k } => format!("rgcn_lp_uniform_k{k}_train"),
            s => format!("rgcn_lp_joint_k{}_train", s.k()),
        };
        let mut tr = LpTrainer::new(&artifact, "rgcn_lp_emb", loss, sampler);
        tr.max_train_edges = Some(1600);
        ds.engine.counters.reset();
        let opts = TrainOptions { epochs: 3, n_workers: 2, verbose: false, ..Default::default() };
        let (rep, _) = tr.fit(&rt, &mut ds, &opts)?;
        let traffic = ds.engine.counters.snapshot();
        println!(
            "{:<14} {:<12} {:>8.4} {:>8.2} {:>12.1}",
            loss.label(),
            sampler.label(),
            rep.val_mrr,
            rep.epoch_times.iter().sum::<f64>() / rep.epoch_times.len() as f64,
            traffic.remote_bytes as f64 / 1e6
        );
    }
    println!("\nar_lp OK");
    Ok(())
}
