//! Quickstart: the paper's "single command" path, end to end.
//!
//! Writes a tiny tabular dataset + the Fig.-6-style JSON schema to a
//! temp dir, runs `gconstruct` on it, then trains and evaluates an
//! RGCN node-classification model — the same flow as
//!
//!   gs gconstruct --conf schema.json --dir data
//!   gs train-nc ...
//!
//! Run: `cargo run --release --example quickstart`

use graphstorm::gconstruct::{self, GConstructConfig};
use graphstorm::runtime::Runtime;
use graphstorm::trainer::{NodeTrainer, TrainOptions};
use graphstorm::util::Rng;

fn write_fixture(dir: &std::path::Path) {
    std::fs::create_dir_all(dir).unwrap();
    let mut rng = Rng::seed_from(42);
    // 200 papers over 2 venues with venue-flavoured text; citations are
    // homophilous so the GNN has signal.
    let venues: Vec<usize> = (0..200).map(|_| rng.gen_range(2)).collect();
    let mut papers = String::from("node_id,text,venue\n");
    for (i, &v) in venues.iter().enumerate() {
        let words: Vec<String> = (0..6)
            .map(|_| format!("w{}_{}", v, rng.gen_range(20)))
            .collect();
        papers += &format!("p{i},{},venue{v}\n", words.join(" "));
    }
    let mut cites = String::from("src,dst\n");
    for i in 0..200usize {
        for _ in 0..4 {
            let j = loop {
                let j = rng.gen_range(200);
                if venues[j] == venues[i] && j != i {
                    break j;
                }
                if rng.gen_f64() < 0.1 {
                    break j;
                }
            };
            cites += &format!("p{i},p{j}\n");
        }
    }
    let mut authors = String::from("node_id\n");
    let mut writes = String::from("src,dst\n");
    for a in 0..60usize {
        authors += &format!("a{a}\n");
        for _ in 0..3 {
            writes += &format!("a{a},p{}\n", rng.gen_range(200));
        }
    }
    std::fs::write(dir.join("papers.csv"), papers).unwrap();
    std::fs::write(dir.join("cites.csv"), cites).unwrap();
    std::fs::write(dir.join("authors.csv"), authors).unwrap();
    std::fs::write(dir.join("writes.csv"), writes).unwrap();
    std::fs::write(dir.join("schema.json"), gconstruct::config::EXAMPLE_SCHEMA).unwrap();
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join("gs_quickstart");
    write_fixture(&dir);
    println!("[1/3] wrote tabular data + schema.json to {}", dir.display());

    let cfg = GConstructConfig::load(&dir.join("schema.json"))?;
    let mut ds = gconstruct::construct_dataset(&cfg, &dir, 2, true)?;
    ds.ensure_text_features(64);
    let s = ds.graph.stats();
    println!(
        "[2/3] gconstruct: {} nodes, {} edges, {} ntypes, {} etypes, 2 METIS-like parts",
        s.num_nodes, s.num_edges, s.num_ntypes, s.num_etypes
    );

    let rt = Runtime::from_default_dir()?;
    let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
    let opts = TrainOptions { epochs: 8, verbose: false, n_workers: 2, ..Default::default() };
    let (report, _) = trainer.fit(&rt, &mut ds, &opts)?;
    println!(
        "[3/3] trained RGCN: losses {:?}",
        report.epoch_losses.iter().map(|l| (l * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    println!("      val acc {:.3}, test acc {:.3} (chance = 0.5)", report.val_acc, report.test_acc);
    assert!(report.test_acc > 0.6, "quickstart model failed to learn");
    println!("quickstart OK");
    Ok(())
}
