//! Serving quickstart: the end-to-end online-inference path.
//!
//! 1. Build a small MAG-style dataset.
//! 2. Create an `InferenceEngine` (real `rgcn_nc_logits` artifact when
//!    PJRT is available, deterministic surrogate otherwise — same
//!    gating as the rest of the repo).
//! 3. Precompute every node's prediction offline (`OfflineInference`)
//!    into GSTF shards, GiGL-style.
//! 4. Warm an `EmbeddingCache` from the shards and serve Zipf request
//!    traffic through a two-worker engine *pool* (one shared
//!    micro-batcher queue) with four concurrent clients.
//! 5. Print latency percentiles, hit rate and throughput.
//!
//! Run: `cargo run --release --example serve_quickstart`

use std::sync::Mutex;

use graphstorm::datagen::{self, mag};
use graphstorm::partition::PartitionBook;
use graphstorm::serve::{
    closed_loop, EmbeddingCache, EnginePoolCfg, InferenceEngine, MicroBatcherCfg,
    OfflineInference, Zipf,
};
use graphstorm::util::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Dataset.
    let raw = mag::generate(&mag::MagConfig { n_papers: 1500, ..Default::default() });
    let book = PartitionBook::single(&raw.graph.num_nodes);
    let mut ds = datagen::build_dataset(raw, book, 64, 7);
    ds.ensure_text_features(64);
    let nt = ds.target_ntype as u32;
    let n_nodes = ds.graph.num_nodes[nt as usize];

    // 2. Engine (artifact-gated backend: real `rgcn_nc_logits` when
    // PJRT can execute it, deterministic surrogate otherwise).
    let (engine, backend) = InferenceEngine::auto(&ds, "rgcn", 8, 7)?;
    println!("engine backend: {backend} (out_dim {})", engine.out_dim());

    // 3. Offline precompute: every node's canonical prediction.
    let dir = std::env::temp_dir().join(format!("gs_serve_quickstart_{}", std::process::id()));
    let off = OfflineInference::default();
    let rep = off.run(&engine, nt, &dir)?;
    println!(
        "offline: {} rows x {} dims in {:.2}s -> {} shards",
        rep.rows,
        rep.dim,
        rep.secs,
        rep.shards.len()
    );

    // 4. Warm the cache and serve Zipf traffic.  Capacity covers the
    // whole node set here; a smaller LRU would need hottest-last warm
    // order to keep the Zipf head resident (see `EmbeddingCache::
    // warm_from_dir`).
    let cache = Mutex::new(EmbeddingCache::new(n_nodes));
    let warmed = cache.lock().unwrap().warm_from_dir(&dir, nt, engine.generation())?;
    println!("cache warmed with {warmed} rows (capacity {n_nodes})");

    let zipf = Zipf::new(n_nodes, 1.1);
    let mut rng = Rng::seed_from(11);
    let trace: Vec<(u32, u32)> = (0..2000).map(|_| (nt, zipf.sample(&mut rng) as u32)).collect();
    let cfg = EnginePoolCfg {
        workers: 2,
        batcher: MicroBatcherCfg {
            max_batch: 32,
            deadline: std::time::Duration::from_micros(200),
        },
        ..Default::default()
    };
    let (stats, replies) = closed_loop(&engine, cfg, &cache, &trace, 4)?;

    // 5. Report.
    println!(
        "served {} requests from 4 clients in {:.2}s:",
        stats.requests, stats.wall_s
    );
    println!("  p50 {:.0}us  p99 {:.0}us", stats.p50_us, stats.p99_us);
    println!("  {:.0} req/s, cache hit rate {:.1}%", stats.rps, 100.0 * stats.hit_rate);
    let (seed, row) = &replies[0];
    println!("  e.g. node {:?} -> {:?}", seed, &row[..row.len().min(4)]);

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
