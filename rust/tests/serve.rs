//! Serving-path tests: cache hit/miss correctness against uncached
//! recompute (bit-identical), micro-batcher deadline flush, offline
//! shard round-trip + cache warming, and determinism under concurrent
//! requests.  The engine runs the deterministic surrogate backend, so
//! everything here works without AOT artifacts or PJRT.

use std::sync::mpsc::{channel, sync_channel};
use std::time::Duration;

use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::GsDataset;
use graphstorm::partition::PartitionBook;
use graphstorm::runtime::ArtifactSpec;
use graphstorm::serve::{
    cache_key, closed_loop, offline::read_shards, EmbeddingCache, InferenceEngine, MicroBatcher,
    MicroBatcherCfg, OfflineInference, ServeMetrics, ServeRequest,
};
use graphstorm::util::Rng;

fn mag_ds(n: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = PartitionBook::single(&raw.graph.num_nodes);
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    ds
}

fn spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
        .with_output("logits", &[64, 8])
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("gs_serve_test_{tag}_{}", std::process::id()))
}

/// Cache hits must be bit-identical to uncached recompute, across
/// micro-batch compositions and request order.
#[test]
fn cache_hits_match_uncached_recompute() {
    let ds = mag_ds(500);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 42).unwrap();
    let mut sc = engine.make_scratch();
    let trace: Vec<(u32, u32)> = (0..40u32).map(|i| (0u32, i * 7 % 400)).collect();

    // Uncached pass, one request per forward.
    let mut fresh: Vec<Vec<f32>> = vec![];
    for &(nt, id) in &trace {
        fresh.push(engine.predict_one(&mut sc, nt, id).unwrap());
    }

    // Cached pass: first fill via a coalesced batch forward, then hit.
    let mut cache = EmbeddingCache::new(64);
    cache.set_generation(engine.generation());
    let mut distinct: Vec<(u32, u32)> = vec![];
    for &s in &trace {
        if !distinct.contains(&s) {
            distinct.push(s);
        }
    }
    let c = engine.out_dim();
    let rows = engine.forward(&mut sc, &distinct).unwrap().to_vec();
    for (i, &(nt, id)) in distinct.iter().enumerate() {
        cache.put(cache_key(nt, id), &rows[i * c..(i + 1) * c]);
    }
    for (i, &(nt, id)) in trace.iter().enumerate() {
        let hit = cache.get(cache_key(nt, id)).expect("warmed").to_vec();
        assert_eq!(hit, fresh[i], "cached row diverged for request {i} ({nt},{id})");
    }
}

/// A partially-filled micro-batch must flush once the deadline
/// passes — requests never wait for a full batch.
#[test]
fn micro_batcher_flushes_on_deadline() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 7).unwrap();
    let metrics = ServeMetrics::new();
    let cfg = MicroBatcherCfg { max_batch: 64, deadline: Duration::from_millis(5) };
    let (tx, rx) = sync_channel::<ServeRequest>(16);
    let mut cache = EmbeddingCache::new(16);

    std::thread::scope(|scope| {
        let metrics = &metrics;
        let engine = &engine;
        let cache = &mut cache;
        let batcher = MicroBatcher::new(cfg);
        let handle = scope.spawn(move || batcher.run(engine, cache, rx, metrics));

        // Three requests — far fewer than max_batch.
        let mut rxs = vec![];
        for id in 0..3u32 {
            let (rtx, rrx) = channel();
            tx.send(ServeRequest::new(0, id, rtx)).unwrap();
            rxs.push(rrx);
        }
        for (i, rrx) in rxs.into_iter().enumerate() {
            let row = rrx
                .recv_timeout(Duration::from_secs(5))
                .unwrap_or_else(|_| panic!("request {i} not flushed by deadline"))
                .unwrap();
            assert_eq!(row.len(), engine.out_dim());
        }
        drop(tx);
        handle.join().unwrap().unwrap();
    });
    assert_eq!(metrics.served(), 3);
    assert_eq!(metrics.latency.count(), 3);
}

/// Offline shards round-trip exactly, cover every node once, and a
/// cache warmed from them serves bit-identical predictions.
#[test]
fn offline_shards_round_trip_and_warm_cache() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 5).unwrap();
    let nt = ds.target_ntype as u32;
    let n = ds.graph.num_nodes[nt as usize];
    let dir = tmp_dir("shards");
    std::fs::remove_dir_all(&dir).ok();

    let off = OfflineInference { shard_size: 70, ..Default::default() };
    let rep = off.run(&engine, nt, &dir).unwrap();
    assert_eq!(rep.rows, n);
    assert_eq!(rep.dim, engine.out_dim());
    assert_eq!(rep.shards.len(), n.div_ceil(70));

    let rows = read_shards(&dir, nt).unwrap();
    assert_eq!(rows.len(), n);
    // Every id exactly once, in order.
    for (i, ((rnt, id), _)) in rows.iter().enumerate() {
        assert_eq!((*rnt, *id), (nt, i as u32));
    }
    // Shard rows == online recompute (canonical sampling).
    let mut sc = engine.make_scratch();
    for &((rnt, id), ref row) in rows.iter().step_by(37) {
        let fresh = engine.predict_one(&mut sc, rnt, id).unwrap();
        assert_eq!(row, &fresh, "shard row for node {id} diverged from online path");
    }

    // Warm a cache and serve through it.
    let mut cache = EmbeddingCache::new(n);
    let warmed = cache.warm_from_dir(&dir, nt, engine.generation()).unwrap();
    assert_eq!(warmed, n);
    let hit = cache.get(cache_key(nt, 123)).expect("warmed row").to_vec();
    assert_eq!(hit, engine.predict_one(&mut sc, nt, 123).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}

/// Concurrent clients hammering the micro-batcher get deterministic
/// replies: whatever micro-batches requests land in, every reply
/// equals the canonical single-request prediction.
#[test]
fn concurrent_requests_are_deterministic() {
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 13).unwrap();
    let nt = ds.target_ntype as u32;
    let n_nodes = ds.graph.num_nodes[nt as usize];
    let mut rng = Rng::seed_from(77);
    let trace: Vec<(u32, u32)> =
        (0..600).map(|_| (nt, rng.gen_range(n_nodes) as u32)).collect();
    let cfg = MicroBatcherCfg { max_batch: 16, deadline: Duration::from_micros(300) };

    // Two runs with different cache settings + 4 concurrent clients.
    let mut uncached = EmbeddingCache::new(0);
    let (s0, replies0) = closed_loop(&engine, cfg.clone(), &mut uncached, &trace, 4).unwrap();
    let mut cached = EmbeddingCache::new(512);
    let (s1, replies1) = closed_loop(&engine, cfg, &mut cached, &trace, 4).unwrap();
    assert_eq!(s0.requests, 600);
    assert_eq!(replies0.len(), 600);
    assert_eq!(replies1.len(), 600);
    assert!(s1.hit_rate > 0.0, "repeated seeds must hit the warm cache");
    assert!((0.0..=1.0).contains(&s1.hit_rate));

    // Every reply — across runs, arms and batch compositions — equals
    // the canonical prediction.
    let mut sc = engine.make_scratch();
    let mut canon: std::collections::HashMap<(u32, u32), Vec<f32>> = Default::default();
    for (k, v) in replies0.into_iter().chain(replies1) {
        let expect = canon
            .entry(k)
            .or_insert_with(|| engine.predict_one(&mut sc, k.0, k.1).unwrap());
        assert_eq!(expect, &v, "reply for {k:?} not canonical");
    }
}

/// Bumping the engine generation (model update) invalidates cached
/// predictions at the batcher level.
#[test]
fn generation_bump_invalidates_serving_cache() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 3).unwrap();
    let trace: Vec<(u32, u32)> = vec![(0, 1), (0, 1), (0, 1)];
    let cfg = MicroBatcherCfg { max_batch: 4, deadline: Duration::from_micros(100) };
    let mut cache = EmbeddingCache::new(8);
    let (s0, _) = closed_loop(&engine, cfg.clone(), &mut cache, &trace, 1).unwrap();
    assert!(s0.hit_rate > 0.0);
    engine.bump_generation();
    // The cached rows are stale now; the first request recomputes.
    let (s1, _) = closed_loop(&engine, cfg, &mut cache, &trace, 1).unwrap();
    assert!(s1.hit_rate < 1.0);
}
