//! Serving-path tests: cache hit/miss correctness against uncached
//! recompute (bit-identical), micro-batcher deadline flush, offline
//! shard round-trip + cache warming, determinism under concurrent
//! requests, engine-pool size invariance, and background cache
//! refresh after generation bumps.  The engine runs the deterministic
//! surrogate backend, so everything here works without AOT artifacts
//! or PJRT.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel};
use std::sync::Arc;
use std::time::{Duration, Instant};

use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::GsDataset;
use graphstorm::dist::{EmbTable, TrafficCounters};
use graphstorm::partition::PartitionBook;
use graphstorm::runtime::ArtifactSpec;
use graphstorm::serve::{
    cache_key, closed_loop, offline::read_shards, refresh_hot_rows, refresh_loop, run_serve_bench,
    Admission, EmbTableSource, EmbeddingCache, EnginePool, EnginePoolCfg, FaultKind, FaultPlan,
    InferenceEngine, MicroBatcher, MicroBatcherCfg, OfflineInference, RefreshCfg, RefreshStats,
    RowSource, ServeBenchParams, ServeError, ServeMetrics, ServeRequest, ShardedCache,
};
use graphstorm::util::Rng;

fn mag_ds(n: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = PartitionBook::single(&raw.graph.num_nodes);
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    ds
}

fn spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
        .with_output("logits", &[64, 8])
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("gs_serve_test_{tag}_{}", std::process::id()))
}

/// Cache hits must be bit-identical to uncached recompute, across
/// micro-batch compositions and request order.
#[test]
fn cache_hits_match_uncached_recompute() {
    let ds = mag_ds(500);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 42).unwrap();
    let mut sc = engine.make_scratch();
    let trace: Vec<(u32, u32)> = (0..40u32).map(|i| (0u32, i * 7 % 400)).collect();

    // Uncached pass, one request per forward.
    let mut fresh: Vec<Vec<f32>> = vec![];
    for &(nt, id) in &trace {
        fresh.push(engine.predict_one(&mut sc, nt, id).unwrap());
    }

    // Cached pass: first fill via a coalesced batch forward, then hit.
    let mut cache = EmbeddingCache::new(64);
    cache.set_generation(engine.generation());
    let mut distinct: Vec<(u32, u32)> = vec![];
    for &s in &trace {
        if !distinct.contains(&s) {
            distinct.push(s);
        }
    }
    let c = engine.out_dim();
    let rows = engine.forward(&mut sc, &distinct).unwrap().to_vec();
    for (i, &(nt, id)) in distinct.iter().enumerate() {
        cache.put(cache_key(nt, id), &rows[i * c..(i + 1) * c]);
    }
    for (i, &(nt, id)) in trace.iter().enumerate() {
        let hit = cache.get(cache_key(nt, id)).expect("warmed").to_vec();
        assert_eq!(hit, fresh[i], "cached row diverged for request {i} ({nt},{id})");
    }
}

/// A partially-filled micro-batch must flush once the deadline
/// passes — requests never wait for a full batch.
#[test]
fn micro_batcher_flushes_on_deadline() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 7).unwrap();
    let metrics = ServeMetrics::new();
    let cfg = MicroBatcherCfg { max_batch: 64, deadline: Duration::from_millis(5) };
    let (tx, rx) = sync_channel::<ServeRequest>(16);
    let mut cache = EmbeddingCache::new(16);

    std::thread::scope(|scope| {
        let metrics = &metrics;
        let engine = &engine;
        let cache = &mut cache;
        let batcher = MicroBatcher::new(cfg);
        let handle = scope.spawn(move || batcher.run(engine, cache, rx, metrics));

        // Three requests — far fewer than max_batch.
        let mut rxs = vec![];
        for id in 0..3u32 {
            let (rtx, rrx) = channel();
            tx.send(ServeRequest::new(0, id, rtx)).unwrap();
            rxs.push(rrx);
        }
        for (i, rrx) in rxs.into_iter().enumerate() {
            let row = rrx
                .recv_timeout(Duration::from_secs(5))
                .unwrap_or_else(|_| panic!("request {i} not flushed by deadline"))
                .unwrap();
            assert_eq!(row.len(), engine.out_dim());
        }
        drop(tx);
        handle.join().unwrap().unwrap();
    });
    assert_eq!(metrics.served(), 3);
    assert_eq!(metrics.latency.count(), 3);
}

/// Offline shards round-trip exactly, cover every node once, and a
/// cache warmed from them serves bit-identical predictions.
#[test]
fn offline_shards_round_trip_and_warm_cache() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 5).unwrap();
    let nt = ds.target_ntype as u32;
    let n = ds.graph.num_nodes[nt as usize];
    let dir = tmp_dir("shards");
    std::fs::remove_dir_all(&dir).ok();

    let off = OfflineInference { shard_size: 70, ..Default::default() };
    let rep = off.run(&engine, nt, &dir).unwrap();
    assert_eq!(rep.rows, n);
    assert_eq!(rep.dim, engine.out_dim());
    assert_eq!(rep.shards.len(), n.div_ceil(70));

    let rows = read_shards(&dir, nt).unwrap();
    assert_eq!(rows.len(), n);
    // Every id exactly once, in order.
    for (i, ((rnt, id), _)) in rows.iter().enumerate() {
        assert_eq!((*rnt, *id), (nt, i as u32));
    }
    // Shard rows == online recompute (canonical sampling).
    let mut sc = engine.make_scratch();
    for &((rnt, id), ref row) in rows.iter().step_by(37) {
        let fresh = engine.predict_one(&mut sc, rnt, id).unwrap();
        assert_eq!(row, &fresh, "shard row for node {id} diverged from online path");
    }

    // Warm a cache and serve through it.
    let mut cache = EmbeddingCache::new(n);
    let warmed = cache.warm_from_dir(&dir, nt, engine.generation()).unwrap();
    assert_eq!(warmed, n);
    let hit = cache.get(cache_key(nt, 123)).expect("warmed row").to_vec();
    assert_eq!(hit, engine.predict_one(&mut sc, nt, 123).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}

/// Concurrent clients hammering the micro-batcher get deterministic
/// replies: whatever micro-batches requests land in, every reply
/// equals the canonical single-request prediction.
#[test]
fn concurrent_requests_are_deterministic() {
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 13).unwrap();
    let nt = ds.target_ntype as u32;
    let n_nodes = ds.graph.num_nodes[nt as usize];
    let mut rng = Rng::seed_from(77);
    let trace: Vec<(u32, u32)> =
        (0..600).map(|_| (nt, rng.gen_range(n_nodes) as u32)).collect();
    let cfg = EnginePoolCfg {
        workers: 2,
        batcher: MicroBatcherCfg { max_batch: 16, deadline: Duration::from_micros(300) },
        ..Default::default()
    };

    // Two runs with different cache settings + 4 concurrent clients.
    let uncached = ShardedCache::new(0, 1);
    let (s0, replies0) = closed_loop(&engine, cfg.clone(), &uncached, &trace, 4).unwrap();
    let cached = ShardedCache::new(512, 2);
    let (s1, replies1) = closed_loop(&engine, cfg, &cached, &trace, 4).unwrap();
    assert_eq!(s0.requests, 600);
    assert_eq!(replies0.len(), 600);
    assert_eq!(replies1.len(), 600);
    assert!(s1.hit_rate > 0.0, "repeated seeds must hit the warm cache");
    assert!((0.0..=1.0).contains(&s1.hit_rate));

    // Every reply — across runs, arms and batch compositions — equals
    // the canonical prediction.
    let mut sc = engine.make_scratch();
    let mut canon: std::collections::HashMap<(u32, u32), Vec<f32>> = Default::default();
    for (k, v) in replies0.into_iter().chain(replies1) {
        let expect = canon
            .entry(k)
            .or_insert_with(|| engine.predict_one(&mut sc, k.0, k.1).unwrap());
        assert_eq!(expect, &v, "reply for {k:?} not canonical");
    }
}

/// Bumping the engine generation (model update) invalidates cached
/// predictions at the batcher level.
#[test]
fn generation_bump_invalidates_serving_cache() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 3).unwrap();
    let trace: Vec<(u32, u32)> = vec![(0, 1), (0, 1), (0, 1)];
    let cfg = EnginePoolCfg {
        workers: 1,
        batcher: MicroBatcherCfg { max_batch: 4, deadline: Duration::from_micros(100) },
        ..Default::default()
    };
    let cache = ShardedCache::new(8, 1);
    let (s0, _) = closed_loop(&engine, cfg.clone(), &cache, &trace, 1).unwrap();
    assert!(s0.hit_rate > 0.0);
    engine.bump_generation();
    // The cached rows are stale now; the first request recomputes.
    let (s1, _) = closed_loop(&engine, cfg, &cache, &trace, 1).unwrap();
    assert!(s1.hit_rate < 1.0);
}

/// The tentpole contract: one fixed request stream drained through
/// engine pools of size 1, 2 and 8 produces bit-identical replies AND
/// identical hit/miss accounting (the cache never evicts here, so
/// accounting is a pure function of request order).
#[test]
fn pool_sizes_are_bit_identical() {
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 21).unwrap();
    let nt = ds.target_ntype as u32;
    let mut rng = Rng::seed_from(99);
    // 60 distinct keys over 300 requests: hits, misses and in-flight
    // coalescing all occur.
    let trace: Vec<(u32, u32)> = (0..300).map(|_| (nt, rng.gen_range(60) as u32)).collect();
    let distinct: std::collections::HashSet<(u32, u32)> = trace.iter().copied().collect();

    let mut baseline: Option<(Vec<Vec<f32>>, u64, u64)> = None;
    for workers in [1usize, 2, 8] {
        let pool = EnginePool::new(EnginePoolCfg {
            workers,
            batcher: MicroBatcherCfg { max_batch: 8, deadline: Duration::from_micros(200) },
            ..Default::default()
        });
        let cache = ShardedCache::new(1024, 1); // never evicts
        let metrics = ServeMetrics::new();
        // Open loop: queue the whole stream up-front in a fixed order,
        // then drain — queue order is identical for every pool size.
        let (tx, rx) = channel::<ServeRequest>();
        let mut reply_rxs = Vec::with_capacity(trace.len());
        for &(nt, id) in &trace {
            let (rtx, rrx) = channel();
            tx.send(ServeRequest::new(nt, id, rtx)).unwrap();
            reply_rxs.push(rrx);
        }
        drop(tx);
        let replies: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let metrics = &metrics;
            let cache = &cache;
            let engine = &engine;
            let handle = scope.spawn(move || pool.run(engine, cache, rx, metrics));
            let replies: Vec<Vec<f32>> =
                reply_rxs.iter().map(|r| r.recv().unwrap().unwrap()).collect();
            handle.join().expect("pool thread panicked").unwrap();
            replies
        });
        assert_eq!(metrics.served(), trace.len() as u64, "workers={workers}");
        assert_eq!(
            metrics.misses() as usize,
            distinct.len(),
            "workers={workers}: every distinct key misses exactly once"
        );
        assert!(metrics.coalesced() <= metrics.hits());
        match &baseline {
            None => baseline = Some((replies, metrics.hits(), metrics.misses())),
            Some((expect, hits, misses)) => {
                assert_eq!(&replies, expect, "replies diverged at pool size {workers}");
                assert_eq!(metrics.hits(), *hits, "hit accounting diverged at {workers}");
                assert_eq!(metrics.misses(), *misses, "miss accounting diverged at {workers}");
            }
        }
    }
}

/// After an embedding-table update bumps the generation, one refresh
/// pass re-reads the hot rows: every subsequent lookup hits at the new
/// generation with the post-update bytes — no stale row is ever
/// served.
#[test]
fn refresh_rewarms_hot_rows_after_generation_bump() {
    let book = Arc::new(PartitionBook::single(&[50]));
    let counters = Arc::new(TrafficCounters::new());
    let table = EmbTable::new(0, 50, 4, 7, book, counters);
    let cache = ShardedCache::new(32, 2);

    // Warm 8 hot rows through the read-through path.
    {
        let mut src = EmbTableSource { table: &table, worker: 0 };
        let mut row = Vec::new();
        for id in 0..8u32 {
            assert!(!cache.get_through(0, id, &mut src, &mut row).unwrap());
        }
    }
    // A sparse update moves rows 0..8 and bumps the generation.
    let ids: Vec<u32> = (0..8).collect();
    table.sparse_adam(&ids, &[0.5; 32], 1e-2);
    let snap = table.weights_snapshot();

    let mut src = EmbTableSource { table: &table, worker: 0 };
    let refreshed = refresh_hot_rows(&cache, &mut src, 8).unwrap();
    assert_eq!(refreshed, 8);
    // A second pass is a no-op: the cache is current again.
    assert_eq!(refresh_hot_rows(&cache, &mut src, 8).unwrap(), 0);

    cache.set_generation(table.generation());
    for id in 0..8u32 {
        let row = cache.get(cache_key(0, id)).expect("refreshed row resident");
        let base = id as usize * 4;
        assert_eq!(row, &snap[base..base + 4], "stale row served for node {id}");
    }
}

/// The background refresh loop notices a generation bump on its own
/// and re-warms the hot set while the cache stays shared.
#[test]
fn background_refresh_loop_tracks_updates() {
    let book = Arc::new(PartitionBook::single(&[20]));
    let counters = Arc::new(TrafficCounters::new());
    let table = EmbTable::new(0, 20, 3, 11, book, counters);
    let cache = ShardedCache::new(16, 2);
    {
        let mut src = EmbTableSource { table: &table, worker: 0 };
        let mut row = Vec::new();
        for id in 0..5u32 {
            cache.get_through(0, id, &mut src, &mut row).unwrap();
        }
    }
    let stop = AtomicBool::new(false);
    let stats = RefreshStats::new();
    std::thread::scope(|scope| {
        let handle = {
            let (cache, table, stop, stats) = (&cache, &table, &stop, &stats);
            scope.spawn(move || {
                let mut src = EmbTableSource { table, worker: 0 };
                let cfg = RefreshCfg { poll: Duration::from_millis(1), limit: 8, ..Default::default() };
                refresh_loop(cache, &mut src, &cfg, stop, stats)
            })
        };
        table.sparse_adam(&[1, 2], &[1.0; 6], 1e-2);
        // Wait (bounded) for a refresh pass to land.
        let deadline = Instant::now() + Duration::from_secs(10);
        while stats.rows() == 0 {
            assert!(Instant::now() < deadline, "refresher never noticed the bump");
            std::thread::sleep(Duration::from_millis(1));
        }
        stop.store(true, Ordering::Release);
        handle.join().expect("refresh thread panicked").unwrap();
    });
    assert!(stats.passes() >= 1);
    // The re-warmed rows are the post-update bytes at the current
    // generation.
    let snap = table.weights_snapshot();
    cache.set_generation(table.generation());
    for id in [1u32, 2] {
        let row = cache.get(cache_key(0, id)).expect("hot row re-warmed");
        let base = id as usize * 3;
        assert_eq!(row, &snap[base..base + 3], "stale row served for node {id}");
    }
}

/// Full three-arm serve bench: engine pool + TinyLFU admission +
/// post-bump refresh, bit-identical across every arm.
#[test]
fn serve_bench_three_arms_bit_identical() {
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 17).unwrap();
    let rep = run_serve_bench(
        &engine,
        &ServeBenchParams {
            seed: 7,
            requests: 300,
            alpha: 1.1,
            clients: 3,
            cache: 512,
            shards: 2,
            admission: Admission::TinyLfu,
            pool: EnginePoolCfg {
                workers: 2,
                sessions: 2,
                batcher: MicroBatcherCfg { max_batch: 8, deadline: Duration::from_micros(200) },
                ..Default::default()
            },
            refresh: 64,
            faults: None,
        },
    )
    .unwrap();
    assert!(rep.identical, "predictions diverged across arms");
    assert!(rep.distinct > 0 && rep.warmed.hit_rate > 0.0);
    assert!(rep.refreshed_rows > 0, "refresh pass re-read nothing");
    let r = rep.refreshed.expect("refresh arm ran");
    assert!(r.hit_rate > 0.0, "post-bump replay should still hit refreshed rows");
}

/// Overload shedding at the queue boundary: with a bounded queue and a
/// slow worker, excess arrivals get a typed `Overloaded` rejection —
/// never a hang — and served + shed accounts for every request.
#[test]
fn queue_full_requests_are_shed() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 31).unwrap();
    let nt = ds.target_ntype as u32;
    let pool = EnginePool::new(EnginePoolCfg {
        workers: 1,
        batcher: MicroBatcherCfg { max_batch: 4, deadline: Duration::from_micros(100) },
        queue_depth: 4,
        ..Default::default()
    });
    // The first two batches each sleep 100ms, so the 36 requests
    // behind them arrive against a full queue.
    let plan = FaultPlan::precise(
        &[(0, FaultKind::SlowRead), (1, FaultKind::SlowRead)],
        Duration::from_millis(100),
    );
    let metrics = ServeMetrics::new();
    let cache = ShardedCache::new(0, 1);
    let total = 40u32;
    let (tx, rx) = channel::<ServeRequest>();
    let mut reply_rxs = Vec::new();
    for id in 0..total {
        let (rtx, rrx) = channel();
        tx.send(ServeRequest::new(nt, id, rtx)).unwrap();
        reply_rxs.push(rrx);
    }
    drop(tx);
    std::thread::scope(|scope| {
        let (metrics, cache, engine, plan) = (&metrics, &cache, &engine, &plan);
        let h = scope.spawn(move || pool.run_with_faults(engine, cache, rx, metrics, Some(plan)));
        let mut served = 0u64;
        let mut shed = 0u64;
        for (i, rrx) in reply_rxs.iter().enumerate() {
            match rrx.recv().unwrap_or_else(|_| panic!("request {i}: reply channel hung up")) {
                Ok(row) => {
                    assert_eq!(row.len(), engine.out_dim());
                    served += 1;
                }
                Err(ServeError::Overloaded { depth }) => {
                    assert!(depth >= 4, "shed below the queue bound (depth {depth})");
                    shed += 1;
                }
                Err(e) => panic!("request {i}: unexpected serve error: {e}"),
            }
        }
        h.join().expect("pool thread panicked").unwrap();
        assert_eq!(served + shed, total as u64, "every request answered exactly once");
        assert!(shed >= 1, "tiny queue behind a slow worker must shed");
        assert_eq!(metrics.shed(), shed);
        assert_eq!(metrics.served(), served);
    });
}

/// A batch stuck behind an injected slow read answers its waiters with
/// a typed `DeadlineExceeded` once their per-request deadline has
/// passed — counted, never hung, never half-served.
#[test]
fn slow_batch_misses_request_deadline() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 37).unwrap();
    let nt = ds.target_ntype as u32;
    let pool = EnginePool::new(EnginePoolCfg {
        workers: 1,
        batcher: MicroBatcherCfg { max_batch: 4, deadline: Duration::from_micros(100) },
        request_deadline: Duration::from_millis(10),
        ..Default::default()
    });
    let plan = FaultPlan::precise(&[(0, FaultKind::SlowRead)], Duration::from_millis(200));
    let metrics = ServeMetrics::new();
    let cache = ShardedCache::new(64, 1);
    let (tx, rx) = channel::<ServeRequest>();
    let mut reply_rxs = Vec::new();
    for id in 0..4u32 {
        let (rtx, rrx) = channel();
        tx.send(ServeRequest::new(nt, id, rtx)).unwrap();
        reply_rxs.push(rrx);
    }
    drop(tx);
    std::thread::scope(|scope| {
        let (metrics, cache, engine, plan) = (&metrics, &cache, &engine, &plan);
        let h = scope.spawn(move || pool.run_with_faults(engine, cache, rx, metrics, Some(plan)));
        let mut missed = 0u64;
        for (i, rrx) in reply_rxs.iter().enumerate() {
            match rrx.recv().unwrap_or_else(|_| panic!("request {i}: reply channel hung up")) {
                Ok(_) => {}
                Err(ServeError::DeadlineExceeded { waited_ms }) => {
                    assert!(waited_ms >= 10, "rejected before the deadline ({waited_ms}ms)");
                    missed += 1;
                }
                Err(e) => panic!("request {i}: unexpected serve error: {e}"),
            }
        }
        h.join().expect("pool thread panicked").unwrap();
        assert!(missed >= 1, "a 200ms batch behind a 10ms deadline must miss");
        assert_eq!(metrics.deadline_misses(), missed);
    });
}

/// A transiently failing row source must not kill the background
/// refresher: failed attempts are counted and retried with backoff,
/// and the pass still lands once the source recovers.
#[test]
fn refresh_loop_survives_flaky_source() {
    struct Flaky<'a> {
        inner: EmbTableSource<'a>,
        failures_left: usize,
    }
    impl RowSource for Flaky<'_> {
        fn row_dim(&self) -> usize {
            self.inner.row_dim()
        }
        fn source_generation(&self) -> u64 {
            self.inner.source_generation()
        }
        fn fetch_row(&mut self, nt: u32, id: u32, out: &mut Vec<f32>) -> anyhow::Result<()> {
            if self.failures_left > 0 {
                self.failures_left -= 1;
                anyhow::bail!("injected transient row-source failure");
            }
            self.inner.fetch_row(nt, id, out)
        }
    }

    let book = Arc::new(PartitionBook::single(&[20]));
    let counters = Arc::new(TrafficCounters::new());
    let table = EmbTable::new(0, 20, 3, 19, book, counters);
    let cache = ShardedCache::new(16, 2);
    {
        let mut src = EmbTableSource { table: &table, worker: 0 };
        let mut row = Vec::new();
        for id in 0..5u32 {
            cache.get_through(0, id, &mut src, &mut row).unwrap();
        }
    }
    let stop = AtomicBool::new(false);
    let stats = RefreshStats::new();
    std::thread::scope(|scope| {
        let handle = {
            let (cache, table, stop, stats) = (&cache, &table, &stop, &stats);
            scope.spawn(move || {
                let mut src =
                    Flaky { inner: EmbTableSource { table, worker: 0 }, failures_left: 2 };
                let cfg = RefreshCfg {
                    poll: Duration::from_millis(1),
                    limit: 8,
                    max_retries: 5,
                    backoff: Duration::from_micros(200),
                };
                refresh_loop(cache, &mut src, &cfg, stop, stats)
            })
        };
        table.sparse_adam(&[1, 2], &[1.0; 6], 1e-2);
        let deadline = Instant::now() + Duration::from_secs(10);
        while stats.rows() == 0 {
            assert!(Instant::now() < deadline, "refresher never recovered from the faults");
            std::thread::sleep(Duration::from_millis(1));
        }
        stop.store(true, Ordering::Release);
        handle.join().expect("refresh thread panicked").expect("refresh loop must not abort");
    });
    assert_eq!(stats.errors(), 2, "both injected failures counted");
    assert!(stats.passes() >= 1);
    // The pass that finally landed re-read the post-update bytes.
    let snap = table.weights_snapshot();
    cache.set_generation(table.generation());
    for id in [1u32, 2] {
        let row = cache.get(cache_key(0, id)).expect("hot row re-warmed");
        let base = id as usize * 3;
        assert_eq!(row, &snap[base..base + 3], "stale row served for node {id}");
    }
}

/// Crash-safe offline writes: a directory polluted by a simulated
/// mid-write crash (stale `.tmp` shard + truncated committed shard,
/// no manifest) recovers with a plain re-run — atomic tmp+rename
/// replaces the truncated shard, the sweep removes stale tmps, and
/// the manifest written last certifies the complete set.
#[test]
fn offline_rerun_recovers_from_partial_write() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 11).unwrap();
    let nt = ds.target_ntype as u32;
    let n = ds.graph.num_nodes[nt as usize];
    let dir = tmp_dir("crash");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("shard_00000.gstf.tmp"), b"GSTF\x01 interrupted write").unwrap();
    std::fs::write(dir.join("shard_00001.gstf"), b"GSTF").unwrap();

    let off = OfflineInference { shard_size: 70, ..Default::default() };
    let rep = off.run(&engine, nt, &dir).unwrap();
    assert_eq!(rep.rows, n);
    for e in std::fs::read_dir(&dir).unwrap() {
        let name = e.unwrap().file_name().into_string().unwrap();
        assert!(!name.ends_with(".tmp"), "stale tmp survived the re-run: {name}");
    }
    assert!(dir.join("manifest.json").is_file(), "manifest written last is missing");

    let rows = read_shards(&dir, nt).unwrap();
    assert_eq!(rows.len(), n);
    let mut sc = engine.make_scratch();
    for &((rnt, id), ref row) in rows.iter().step_by(41) {
        assert_eq!(
            row,
            &engine.predict_one(&mut sc, rnt, id).unwrap(),
            "recovered shard row for node {id} diverged"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
