//! Config-layer integration tests: the declarative run-config API,
//! the CLI adapters over it, and the `gs run` single-command pipeline.
//!
//! The headline acceptance test: a `gs run` pipeline must report
//! metrics bit-identical to the same stages invoked as separate
//! subcommands with matching seeds.  The always-on variant covers
//! data -> partition -> infer (surrogate backend, no artifacts
//! needed); the train-including variant gates on the PJRT runtime
//! like every other executing test.

use graphstorm::config::{cli, Pipeline, RunConfig};
use graphstorm::serve::read_shards;

fn argv(args: &[&str]) -> Vec<String> {
    args.iter().map(|s| s.to_string()).collect()
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("gs_cfg_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// `gs run --conf F` == `gs gen-data ...` + `gs infer ...`: same
/// stats, same report, bit-identical shard bytes.
#[test]
fn run_conf_matches_multi_command_invocation() {
    let dir = tmp_dir("e2e");
    let out_run = dir.join("emb_run");
    let out_cli = dir.join("emb_cli");
    let conf = dir.join("pipeline.json");
    std::fs::write(
        &conf,
        format!(
            r#"{{"seed": 7,
                "data": {{"dataset": "mag", "size": 600}},
                "partition": {{"parts": 2, "method": "metis"}},
                "infer": {{"out": "{}", "shard_size": 256}}}}"#,
            out_run.display()
        ),
    )
    .unwrap();

    // Single command: gs run --conf pipeline.json
    let run = cli::find_command("run").unwrap();
    let cfg = cli::build_config(run, &argv(&["--conf", conf.to_str().unwrap()])).unwrap();
    let one = Pipeline::new(cfg).unwrap().run().unwrap();

    // Multi command: gs gen-data ... then gs infer ... (same seeds).
    let gen = cli::find_command("gen-data").unwrap();
    let gen_cfg = cli::build_config(
        gen,
        &argv(&["--dataset", "mag", "--size", "600", "--num-parts", "2", "--metis"]),
    )
    .unwrap();
    let a = Pipeline::new(gen_cfg).unwrap().run().unwrap();

    let infer = cli::find_command("infer").unwrap();
    let infer_cfg = cli::build_config(
        infer,
        &argv(&[
            "--dataset", "mag", "--size", "600", "--num-parts", "2", "--metis",
            "--out", out_cli.to_str().unwrap(), "--shard-size", "256",
        ]),
    )
    .unwrap();
    let b = Pipeline::new(infer_cfg).unwrap().run().unwrap();

    // Reported metrics are identical...
    assert_eq!(one.stats, a.stats);
    assert_eq!(one.stats, b.stats);
    let (r1, r2) = (one.infer.unwrap(), b.infer.unwrap());
    assert_eq!(r1.rows, r2.rows);
    assert_eq!(r1.dim, r2.dim);
    assert_eq!(r1.shards.len(), r2.shards.len());
    // ...and the written predictions are bit-identical.
    let s1 = read_shards(&out_run, r1.ntype).unwrap();
    let s2 = read_shards(&out_cli, r2.ntype).unwrap();
    assert!(!s1.is_empty());
    assert_eq!(s1, s2, "gs run shards diverge from multi-command shards");
    std::fs::remove_dir_all(&dir).ok();
}

/// Same acceptance test including the train stage — gated on PJRT
/// like every executing test (`runtime_if_available`).
#[test]
fn run_conf_with_train_matches_separate_train() {
    if graphstorm::runtime::runtime_if_available().is_none() {
        eprintln!("skipping: AOT artifacts / PJRT backend unavailable");
        return;
    }
    let dir = tmp_dir("e2e_train");
    let conf = dir.join("pipeline.json");
    std::fs::write(
        &conf,
        format!(
            r#"{{"seed": 7,
                "data": {{"dataset": "mag", "size": 600}},
                "partition": {{"parts": 2}},
                "task": {{"kind": "nc", "epochs": 2}},
                "infer": {{"out": "{}", "shard_size": 256}}}}"#,
            dir.join("emb").display()
        ),
    )
    .unwrap();
    let run = cli::find_command("run").unwrap();
    let cfg = cli::build_config(run, &argv(&["--conf", conf.to_str().unwrap()])).unwrap();
    let one = Pipeline::new(cfg).unwrap().run().unwrap();

    let tr = cli::find_command("train-nc").unwrap();
    let tr_cfg = cli::build_config(
        tr,
        &argv(&["--dataset", "mag", "--size", "600", "--num-parts", "2", "--epochs", "2"]),
    )
    .unwrap();
    let b = Pipeline::new(tr_cfg).unwrap().run().unwrap();

    let (n1, n2) = (one.nc.unwrap(), b.nc.unwrap());
    assert_eq!(n1.epoch_losses, n2.epoch_losses, "train losses diverge");
    assert_eq!(n1.val_acc, n2.val_acc);
    assert_eq!(n1.test_acc, n2.test_acc);
    let r1 = one.infer.unwrap();
    assert!(r1.rows > 0 && r1.dim > 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance run: `gs run --conf examples/pipeline_multitask.json`
/// trains nc+distill in one run and reports per-task metrics in the
/// `PipelineOutcome` — gated on PJRT like every training test (shrunk
/// via --set so the gated suite stays fast).
#[test]
fn run_conf_multitask_reports_per_task_metrics() {
    if graphstorm::runtime::runtime_if_available().is_none() {
        eprintln!("skipping: AOT artifacts / PJRT backend unavailable");
        return;
    }
    let run = cli::find_command("run").unwrap();
    let cfg = cli::build_config(
        run,
        &argv(&[
            "--conf", "../examples/pipeline_multitask.json",
            "--set", "data.size=400",
            "--set", "encoder.epochs=1",
            "--set", "loader.workers=2",
        ]),
    )
    .unwrap();
    let out = Pipeline::new(cfg).unwrap().run().unwrap();
    let m = out.multi.expect("multi-task stage must report per-task metrics");
    assert_eq!(m.names, vec!["nc", "distill"]);
    assert_eq!(m.epoch_losses.len(), 2);
    assert!(m.steps.iter().all(|&s| s > 0), "every task must take steps: {:?}", m.steps);
    assert!(m.nc.is_some(), "nc head must report val/test accuracy");
    assert!(m.distill_mse.is_some(), "distill head must report its mse");
    assert!(out.stage_secs.iter().any(|(n, _)| n == "tasks(nc+distill)"));
}

/// The serve stage runs end-to-end through the pipeline (surrogate
/// backend) with an engine pool, TinyLFU admission and the post-bump
/// refresh arm, and its internal bit-identity gate holds.  The
/// outcome also carries per-stage wall-clock.
#[test]
fn pipeline_serve_stage_runs() {
    let cfg = RunConfig::parse_str(
        r#"{"seed": 7,
            "data": {"dataset": "mag", "size": 400},
            "serve": {"requests": 200, "clients": 2, "cache": 256,
                      "pool_workers": 2, "admission": "tinylfu", "refresh": 64,
                      "max_batch": 8, "deadline_us": 200}}"#,
    )
    .unwrap();
    let out = Pipeline::new(cfg).unwrap().run().unwrap();
    let (u, w) = (out.serve_uncached.unwrap(), out.serve_warmed.unwrap());
    assert_eq!(u.requests, 200);
    assert_eq!(w.requests, 200);
    assert!(w.hit_rate > 0.0, "warmed arm must hit the cache");
    let r = out.serve_refreshed.expect("serve.refresh > 0 adds the refreshed arm");
    assert_eq!(r.requests, 200);
    assert!(r.hit_rate > 0.0, "refresh must prevent the post-bump miss storm");
    // Per-stage wall-clock, in execution order.
    let names: Vec<&str> = out.stage_secs.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names, vec!["data+partition", "serve"]);
    assert!(out.stage_secs.iter().all(|&(_, s)| s >= 0.0));
}

/// `--shards`/`--sessions` plumb through the serve-bench flag table
/// into the serve stage config, and inconsistent combinations die at
/// build time with an actionable message.
#[test]
fn serve_bench_sharding_flags_and_validation() {
    let sb = cli::find_command("serve-bench").unwrap();
    let cfg = cli::build_config(
        sb,
        &argv(&["--pool-workers", "4", "--shards", "4", "--sessions", "2"]),
    )
    .unwrap();
    let s = cfg.serve.as_ref().unwrap();
    assert_eq!(s.shards, 4);
    let pool = s.pool();
    assert_eq!(pool.workers, 4);
    assert_eq!(pool.sessions, 2);

    // More fixed sessions than fixed workers cannot execute: each
    // session needs a worker to drive it.
    let e = cli::build_config(sb, &argv(&["--pool-workers", "2", "--sessions", "4"]))
        .unwrap_err()
        .to_string();
    assert!(e.contains("exceeds serve.pool_workers"), "{e}");
    // Zero stripes is meaningless (1 = unsharded).
    let e = cli::build_config(sb, &argv(&["--shards", "0"])).unwrap_err().to_string();
    assert!(e.contains("serve.shards must be >= 1"), "{e}");
    // "auto" sessions always resolve within the pool width.
    let cfg = cli::build_config(
        sb,
        &argv(&["--pool-workers", "2", "--sessions", "auto"]),
    )
    .unwrap();
    assert!(cfg.serve.unwrap().pool().sessions <= 2);
}

/// The shipped example run configs must parse, validate and resolve.
#[test]
fn shipped_examples_are_valid() {
    for name in ["pipeline_nc.json", "pipeline_lp_serve.json", "pipeline_multitask.json"] {
        let path = std::path::Path::new("../examples").join(name);
        let cfg = RunConfig::load(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        cfg.validate().unwrap();
        let resolved = cfg.resolved();
        // Resolution is a fixed point and round-trips through JSON.
        let back = RunConfig::parse_str(&resolved.to_json().to_string_pretty()).unwrap();
        assert_eq!(resolved, back, "{name} does not round-trip");
    }
    // pipeline_nc.json must declare the paper's single-command
    // sequence: data -> partition -> train -> offline infer.
    let nc = RunConfig::load(std::path::Path::new("../examples/pipeline_nc.json")).unwrap();
    assert_eq!(nc.stage_names(), vec!["data", "partition", "task(nc)", "infer"]);
    // pipeline_multitask.json must declare the chained nc+distill run.
    let mt =
        RunConfig::load(std::path::Path::new("../examples/pipeline_multitask.json")).unwrap();
    assert_eq!(mt.stage_names(), vec!["data", "partition", "tasks(nc+distill)"]);
    let m = mt.multi.as_ref().unwrap();
    assert!((m.tasks[0].weight - 2.0).abs() < 1e-12);
}

/// Golden snapshots: the parsed-and-serialized form of every shipped
/// example (defaults materialized by `to_json`, `"auto"` preserved so
/// the snapshot is machine-independent).  A changed stage default or
/// serialization shows up as a reviewable fixture diff instead of
/// drifting silently.  Regenerate after auditing with
/// `GS_WRITE_FIXTURES=1 cargo test -q run_config_golden`.
#[test]
fn run_config_golden_snapshots() {
    for name in ["pipeline_nc", "pipeline_lp_serve", "pipeline_multitask"] {
        let path = std::path::PathBuf::from(format!("../examples/{name}.json"));
        let cfg = RunConfig::load(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut pretty = cfg.to_json().to_string_pretty();
        pretty.push('\n');
        let gpath = format!("tests/fixtures/{name}.golden.json");
        if std::env::var("GS_WRITE_FIXTURES").is_ok() {
            std::fs::write(&gpath, &pretty).unwrap();
            continue;
        }
        let want = std::fs::read_to_string(&gpath)
            .unwrap_or_else(|e| panic!("{gpath}: {e} (GS_WRITE_FIXTURES=1 to bootstrap)"));
        assert_eq!(
            pretty, want,
            "{name}: config defaults/serialization drifted from the golden fixture; if \
             intended, audit the diff and regenerate with GS_WRITE_FIXTURES=1"
        );
        // The golden text also parses back to the identical config
        // (structural check, independent of float formatting).
        assert_eq!(RunConfig::parse_str(&want).unwrap(), cfg, "{name} golden must re-parse");
        // And resolution stays a fixed point that round-trips ("auto"
        // resolves machine-locally, so it is not snapshotted).
        let r = cfg.resolved();
        let back = RunConfig::parse_str(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(r, back);
        assert_eq!(back.resolved(), back);
    }
}

/// Override precedence end-to-end: file < --set, applied in order.
#[test]
fn set_overrides_file_values() {
    let dir = tmp_dir("set");
    let conf = dir.join("c.json");
    std::fs::write(&conf, r#"{"seed": 3, "task": {"kind": "nc", "epochs": 2}}"#).unwrap();
    let run = cli::find_command("run").unwrap();
    let cfg = cli::build_config(
        run,
        &argv(&[
            "--conf", conf.to_str().unwrap(),
            "--set", "task.epochs=5",
            "--set", "seed=11",
            "--set", "task.epochs=8",
        ]),
    )
    .unwrap();
    assert_eq!(cfg.seed, 11);
    assert_eq!(cfg.task.as_ref().unwrap().epochs, 8);
    // Unknown keys through --set still die with a suggestion.
    let e = cli::build_config(
        run,
        &argv(&["--conf", conf.to_str().unwrap(), "--set", "task.epcohs=9"]),
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("did you mean 'epochs'"), "{e}");
    std::fs::remove_dir_all(&dir).ok();
}

/// A dataset built by the pipeline is the same dataset the legacy
/// gconstruct single-call path builds (shared bind step).
#[test]
fn gconstruct_through_pipeline_matches_direct() {
    let dir = tmp_dir("gc");
    let mut rng = graphstorm::util::Rng::seed_from(5);
    let venues: Vec<usize> = (0..60).map(|_| rng.gen_range(2)).collect();
    let mut papers = String::from("node_id,text,venue\n");
    for (i, &v) in venues.iter().enumerate() {
        papers += &format!("p{i},w{v}a w{v}b,venue{v}\n");
    }
    let mut cites = String::from("src,dst\n");
    for i in 0..60usize {
        cites += &format!("p{i},p{}\n", (i + 1) % 60);
    }
    std::fs::write(dir.join("papers.csv"), papers).unwrap();
    std::fs::write(dir.join("cites.csv"), cites).unwrap();
    std::fs::write(dir.join("authors.csv"), "node_id\na0\n").unwrap();
    std::fs::write(dir.join("writes.csv"), "src,dst\na0,p0\n").unwrap();
    std::fs::write(dir.join("schema.json"), graphstorm::gconstruct::config::EXAMPLE_SCHEMA)
        .unwrap();

    let gc = cli::find_command("gconstruct").unwrap();
    let cfg = cli::build_config(
        gc,
        &argv(&[
            "--conf", dir.join("schema.json").to_str().unwrap(),
            "--dir", dir.to_str().unwrap(),
            "--num-parts", "2",
        ]),
    )
    .unwrap();
    let ds = Pipeline::new(cfg).unwrap().build_dataset().unwrap();

    let gcfg =
        graphstorm::gconstruct::GConstructConfig::load(&dir.join("schema.json")).unwrap();
    let direct =
        graphstorm::gconstruct::construct_dataset(&gcfg, &dir, 2, false).unwrap();
    assert_eq!(ds.graph.stats(), direct.graph.stats());
    assert_eq!(ds.engine.book.assignments, direct.engine.book.assignments);
    std::fs::remove_dir_all(&dir).ok();
}
