//! Observability acceptance suite (docs/OBSERVABILITY.md):
//!
//! * `gs serve-bench --trace` produces a schema-valid JSONL trace with
//!   the per-batch dispatch → forward → reply span taxonomy, and the
//!   metrics registry's `serve.<arm>.*` counters exactly match the
//!   bench's `ClosedLoopStats`.
//! * Tracing is determinism-neutral: replies are bit-identical with
//!   the tracer on and off.
//! * The set of `serve.*` metric *names* is pool-size invariant and
//!   pinned by a golden fixture (`GS_WRITE_FIXTURES=1` regenerates).
//!
//! The tracer and the metrics registry are process-global, so every
//! test here serializes on `GATE` (cargo runs tests in one binary on
//! parallel threads).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use graphstorm::config::ObsCfg;
use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::GsDataset;
use graphstorm::obs::{self, metrics, trace};
use graphstorm::partition::PartitionBook;
use graphstorm::runtime::ArtifactSpec;
use graphstorm::serve::{
    closed_loop, run_serve_bench, Admission, EnginePoolCfg, InferenceEngine, MicroBatcherCfg,
    ServeBenchParams, ShardedCache,
};
use graphstorm::util::json::Json;

static GATE: Mutex<()> = Mutex::new(());

fn mag_ds(n: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = PartitionBook::single(&raw.graph.num_nodes);
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    ds
}

fn spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
        .with_output("logits", &[64, 8])
}

fn pool_cfg(workers: usize) -> EnginePoolCfg {
    EnginePoolCfg {
        workers,
        batcher: MicroBatcherCfg { max_batch: 8, deadline: Duration::from_micros(200) },
        ..Default::default()
    }
}

fn bench_params(seed: u64, workers: usize) -> ServeBenchParams {
    ServeBenchParams {
        seed,
        requests: 300,
        alpha: 1.1,
        clients: 3,
        cache: 512,
        shards: 2,
        admission: Admission::TinyLfu,
        pool: pool_cfg(workers),
        refresh: 8,
        faults: None,
    }
}

/// The acceptance criterion end-to-end: serve-bench under `--trace`
/// writes a schema-valid JSONL trace carrying the batch span taxonomy,
/// and the registry's per-arm counters equal the `ClosedLoopStats` the
/// bench reports — same numbers, two surfaces.
#[test]
fn serve_bench_trace_schema_and_registry_match() {
    let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    metrics::reset();
    trace::set_enabled(false);
    trace::drain(); // discard anything a previous test buffered
    let dir = std::env::temp_dir().join(format!("gs_obs_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let tpath = dir.join("bench.trace.jsonl");
    let cfg = ObsCfg { trace: Some(tpath.to_str().unwrap().to_string()), ..Default::default() };
    obs::init(&cfg);

    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 11).unwrap();
    let rep = run_serve_bench(&engine, &bench_params(5, 2)).unwrap();
    assert!(rep.identical, "bench arms diverged under tracing");

    let written = obs::finish(&cfg).unwrap();
    trace::set_enabled(false);
    assert!(written > 0, "a traced bench must record events");
    let validated = graphstorm::obs::validate_jsonl(tpath.to_str().unwrap()).unwrap();
    assert_eq!(validated, written, "every written event must validate");
    let text = std::fs::read_to_string(&tpath).unwrap();
    for name in
        ["serve.batch.dispatch", "serve.batch.forward", "serve.batch.reply", "serve.refresh.pass"]
    {
        assert!(text.contains(&format!("\"name\":\"{name}\"")), "trace missing span {name}");
    }

    let snap = metrics::snapshot();
    let get = |k: &str| {
        snap.get(k).and_then(Json::as_f64).unwrap_or_else(|| panic!("metric {k} not registered"))
    };
    let refreshed = rep.refreshed.as_ref().expect("refresh > 0 must produce a third arm");
    for (arm, s) in
        [("uncached", &rep.uncached), ("warmed", &rep.warmed), ("refreshed", refreshed)]
    {
        assert_eq!(get(&format!("serve.{arm}.requests")) as usize, s.requests, "{arm} requests");
        assert_eq!(get(&format!("serve.{arm}.hits")) as u64, s.hits, "{arm} hits");
        assert_eq!(get(&format!("serve.{arm}.misses")) as u64, s.misses, "{arm} misses");
        assert_eq!(get(&format!("serve.{arm}.coalesced")) as u64, s.coalesced, "{arm} coalesced");
        assert_eq!(get(&format!("serve.{arm}.restarts")) as u64, s.restarts, "{arm} restarts");
        assert_eq!(get(&format!("serve.{arm}.retries")) as u64, s.retries, "{arm} retries");
        assert_eq!(get(&format!("serve.{arm}.shed")) as u64, s.shed, "{arm} shed");
        assert_eq!(
            get(&format!("serve.{arm}.deadline_misses")) as u64,
            s.deadline_misses,
            "{arm} deadline_misses"
        );
    }
    assert_eq!(get("serve.refreshed.rows_refreshed") as usize, rep.refreshed_rows);
    assert!(get("serve.pool.batches") >= 1.0, "the pool must have cut at least one batch");
    std::fs::remove_dir_all(&dir).ok();
}

/// Collapse a reply list (completion order, timing-dependent) into a
/// canonical per-key bit pattern, asserting every repeat of a key got
/// the identical row within the run.
fn canon(replies: Vec<((u32, u32), Vec<f32>)>) -> BTreeMap<(u32, u32), Vec<u32>> {
    let mut m: BTreeMap<(u32, u32), Vec<u32>> = BTreeMap::new();
    for (k, v) in replies {
        let bits: Vec<u32> = v.iter().map(|f| f.to_bits()).collect();
        match m.get(&k) {
            Some(prev) => assert_eq!(prev, &bits, "key {k:?} answered inconsistently in-run"),
            None => {
                m.insert(k, bits);
            }
        }
    }
    m
}

/// Determinism neutrality: the same closed-loop workload answers with
/// bit-identical rows whether the tracer is recording or not.
#[test]
fn replies_bit_identical_with_tracing_on_and_off() {
    let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 13).unwrap();
    let nt = ds.target_ntype as u32;
    let reqs: Vec<(u32, u32)> = (0..200).map(|i| (nt, (i % 40) as u32)).collect();
    let run = || {
        let cache = ShardedCache::new(1024, 2);
        let (_stats, replies) = closed_loop(&engine, pool_cfg(2), &cache, &reqs, 3).unwrap();
        canon(replies)
    };

    trace::set_enabled(false);
    trace::drain();
    let off = run();
    trace::set_enabled(true);
    let on = run();
    trace::set_enabled(false);
    let events = trace::drain();
    assert!(!events.is_empty(), "the traced run must have recorded spans");
    assert_eq!(off.len(), 40, "every distinct key must be answered");
    assert_eq!(off, on, "enabling tracing changed a reply bit pattern");
}

/// The registry *names* a serve-bench run registers are a stable,
/// pool-size-invariant surface — dashboards key on them.  Golden-pinned
/// so a renamed or dropped metric is a reviewable fixture diff.
/// Regenerate with `GS_WRITE_FIXTURES=1 cargo test -q serve_metric_names`.
#[test]
fn serve_metric_names_are_pool_size_invariant_and_golden() {
    let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    trace::set_enabled(false);
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 19).unwrap();
    let mut per_pool: Vec<Vec<String>> = Vec::new();
    for workers in [1usize, 4] {
        metrics::reset();
        let rep = run_serve_bench(&engine, &bench_params(9, workers)).unwrap();
        assert!(rep.identical, "workers={workers}: bench arms diverged");
        per_pool
            .push(metrics::names().into_iter().filter(|n| n.starts_with("serve.")).collect());
    }
    assert_eq!(per_pool[0], per_pool[1], "metric names must not depend on pool size");

    let mut got = per_pool.pop().unwrap().join("\n");
    got.push('\n');
    let gpath = "tests/fixtures/serve_metrics_names.golden.txt";
    if std::env::var("GS_WRITE_FIXTURES").is_ok() {
        std::fs::write(gpath, &got).unwrap();
        return;
    }
    let want = std::fs::read_to_string(gpath)
        .unwrap_or_else(|e| panic!("{gpath}: {e} (GS_WRITE_FIXTURES=1 to bootstrap)"));
    assert_eq!(
        got, want,
        "serve metric names drifted from the golden fixture; if intended, audit the \
         diff and regenerate with GS_WRITE_FIXTURES=1"
    );
}
