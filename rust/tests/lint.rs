//! Tier-1 tests for `gs lint` (docs/LINTS.md): one triggering and one
//! non-triggering fixture per rule, the waiver syntax, and the
//! self-clean gate — the lint run over this repo's own `rust/src` must
//! come back clean, so a regression in the tree fails here even before
//! scripts/test.sh runs the CLI gate.

use std::path::{Path, PathBuf};

use graphstorm::lint::{lint_path, name_table};

/// Fresh fixture tree under the system temp dir.  `files` are
/// (relative path, contents); parents are created as needed.
fn fixture(tag: &str, files: &[(&str, &str)]) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gs_lint_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    for (rel, body) in files {
        let p = dir.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, body).unwrap();
    }
    dir
}

/// Rules of the findings from linting `root/src` in a fixture.
fn lint_rules(root: &Path) -> Vec<String> {
    lint_path(&root.join("src"))
        .unwrap()
        .findings
        .iter()
        .map(|f| f.rule.to_string())
        .collect()
}

#[test]
fn determinism_rule_pos_and_neg() {
    let bad = fixture(
        "det_pos",
        &[("src/sampling/walk.rs", "fn f() { let m = std::collections::HashMap::new(); }")],
    );
    assert_eq!(lint_rules(&bad), ["determinism"]);

    let good = fixture(
        "det_neg",
        &[
            // Fx collections are fine, and out-of-scope dirs are not linted.
            ("src/sampling/walk.rs", "fn f() { let m = crate::util::FxHashMap::default(); }"),
            ("src/eval/x.rs", "fn f() { let m = std::collections::HashMap::new(); }"),
        ],
    );
    assert!(lint_rules(&good).is_empty());
}

#[test]
fn panic_clean_rule_pos_and_neg() {
    let bad = fixture("panic_pos", &[("src/serve/x.rs", "fn f(x: Option<u32>) { x.unwrap(); }")]);
    assert_eq!(lint_rules(&bad), ["panic-clean"]);

    let good = fixture(
        "panic_neg",
        &[(
            "src/serve/x.rs",
            // unwrap_or is fine; test modules and string/comment
            // mentions of .unwrap( are exempt.
            "fn f(x: Option<u32>) { x.unwrap_or(0); let s = \".unwrap()\"; }\n\
             // .unwrap( in prose\n\
             #[cfg(test)]\nmod tests { fn t(x: Option<u32>) { x.unwrap(); } }\n",
        )],
    );
    assert!(lint_rules(&good).is_empty());
}

#[test]
fn lock_order_rule_pos_and_neg() {
    let bad = fixture(
        "lock_pos",
        &[(
            "src/dist/x.rs",
            "fn f(t: &T, m: &M) { let rows = t.read_inner(); let c = lock_cache(m); }",
        )],
    );
    assert_eq!(lint_rules(&bad), ["lock-order"]);

    let good = fixture(
        "lock_neg",
        &[(
            "src/dist/x.rs",
            // Declared order, scoped release, and a transient guard.
            "fn a(t: &T, m: &M) { let c = lock_cache(m); let rows = t.read_inner(); }\n\
             fn b(t: &T, m: &M) { { let rows = t.read_inner(); } let c = lock_cache(m); }\n\
             fn c(rx: &M, m: &M) { let j = lock_clean(rx).recv(); let g = lock_cache(m); }\n",
        )],
    );
    assert!(lint_rules(&good).is_empty());
}

#[test]
fn shard_lock_rank_pos_and_neg() {
    // Two cache stripe guards held together: ascending-shard nesting
    // cannot be proven statically, so it is a finding.
    let bad = fixture(
        "shard_pos",
        &[(
            "src/serve/x.rs",
            "fn f(c: &C) { let a = c.lock_key(k1); let b = c.lock_key(k2); }",
        )],
    );
    assert_eq!(lint_rules(&bad), ["lock-order"]);

    let good = fixture(
        "shard_neg",
        &[(
            "src/serve/x.rs",
            // Scoped release, one-stripe-at-a-time iteration, and the
            // declared cache -> session order.
            "fn a(c: &C) { { let g = c.lock_key(k1); } let h = c.lock_at(1); }\n\
             fn b(c: &C) { for i in 0..n { let g = c.lock_at(i); g.put(i, &row); } }\n\
             fn c(c: &C, e: &E) { let g = c.lock_key(k); e.forward_locked(sc, s, l); }\n",
        )],
    );
    assert!(lint_rules(&good).is_empty());

    // An EmbTable stripe guard (rank 2) held across a cache stripe
    // acquisition (rank 0) inverts the declared order.
    let inverted = fixture(
        "shard_inverted",
        &[(
            "src/dist/x.rs",
            "fn f(t: &T, c: &C) { let g = t.read_shard(s); let a = c.lock_key(k); }",
        )],
    );
    assert_eq!(lint_rules(&inverted), ["lock-order"]);
}

#[test]
fn raw_lock_banned_in_serve_only() {
    let bad = fixture("rawlock_pos", &[("src/serve/x.rs", "fn f(m: &M) { let g = m.lock(); }")]);
    assert_eq!(lint_rules(&bad), ["lock-order"]);

    let good = fixture("rawlock_neg", &[("src/obs/x.rs", "fn f(m: &M) { let g = m.lock(); }")]);
    assert!(lint_rules(&good).is_empty());
}

#[test]
fn salt_unique_rule_pos_and_neg() {
    let bad = fixture(
        "salt_pos",
        &[
            ("src/trainer/a.rs", "const NC_SALT: u64 = 0x6e63;"),
            ("src/trainer/b.rs", "const LP_SALT: u64 = 0x6e63;"),
        ],
    );
    assert_eq!(lint_rules(&bad), ["salt-unique"]);

    let good = fixture(
        "salt_neg",
        &[("src/trainer/a.rs", "const NC_SALT: u64 = 0x6e63;\nconst LP_SALT: u64 = 0x1b9;")],
    );
    assert!(lint_rules(&good).is_empty());
}

#[test]
fn name_registry_rule_pos_and_neg() {
    let emits = "fn f() { crate::span!(\"serve.batch.forward\", seq = 1); \
                 metrics::gauge_set(&format!(\"pipeline.stage_secs.{name}\"), 0.0); }";
    let bad = fixture(
        "names_pos",
        &[
            ("src/obs/x.rs", emits),
            ("tests/fixtures/serve_metrics_names.golden.txt", "serve.batch.forward\nserve.gone\n"),
            ("docs/OBSERVABILITY.md", "The `serve.renamed.span` span.\n"),
        ],
    );
    let rules = lint_rules(&bad);
    assert_eq!(rules, ["name-registry", "name-registry"], "golden + doc stale names: {rules:?}");

    let good = fixture(
        "names_neg",
        &[
            ("src/obs/x.rs", emits),
            ("tests/fixtures/serve_metrics_names.golden.txt", "serve.batch.forward\n"),
            // `<stage>` placeholders match format! holes as wildcards.
            ("docs/OBSERVABILITY.md", "`serve.batch.forward` and `pipeline.stage_secs.<stage>`.\n"),
        ],
    );
    assert!(lint_rules(&good).is_empty());
}

#[test]
fn waiver_suppresses_and_is_itself_linted() {
    let waived = fixture(
        "waiver_ok",
        &[(
            "src/trainer/x.rs",
            "fn f() { let t0 = Instant::now(); // lint:allow(determinism): wall-time only\n}",
        )],
    );
    let report = lint_path(&waived.join("src")).unwrap();
    assert!(report.findings.is_empty());
    assert_eq!(report.waivers_used, 1);

    // A waiver on its own line covers the next line.
    let above = fixture(
        "waiver_above",
        &[(
            "src/trainer/x.rs",
            "fn f() {\n // lint:allow(determinism): wall-time only\n let t0 = Instant::now();\n}",
        )],
    );
    assert!(lint_rules(&above).is_empty());

    // No reason, unknown rule, and wrong rule are all findings.
    let bad = fixture(
        "waiver_bad",
        &[(
            "src/trainer/x.rs",
            "fn f() { let t0 = Instant::now(); // lint:allow(determinism)\n\
             // lint:allow(speling): typo\n}",
        )],
    );
    let mut rules = lint_rules(&bad);
    rules.sort();
    assert_eq!(rules, ["determinism", "waiver", "waiver"]);
}

#[test]
fn self_clean_gate() {
    // The repo's own production tree lints clean — same gate
    // scripts/test.sh enforces via `gs lint`.
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_path(&src).unwrap();
    let msgs: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg))
        .collect();
    assert!(msgs.is_empty(), "gs lint rust/src must be clean:\n{}", msgs.join("\n"));
    assert!(report.files > 30, "scanned only {} files", report.files);
    assert!(report.waivers_used > 0, "the timing waivers should be exercised");
}

#[test]
fn name_table_covers_golden_fixture() {
    // Every golden metric name must be compatible with the extracted
    // name table — the same property check_docs.sh consumes through
    // `gs lint --dump-names`.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let table = name_table(&root.join("src")).unwrap();
    assert!(table.iter().any(|n| n == "serve.pool.batches"));
    assert!(table.iter().any(|n| n == "serve.uncached.*"));
    assert!(table.iter().any(|n| n == "pipeline.stage_secs.*"));
    let golden = std::fs::read_to_string(
        root.join("tests/fixtures/serve_metrics_names.golden.txt"),
    )
    .unwrap();
    for name in golden.lines().map(str::trim).filter(|l| !l.is_empty()) {
        assert!(
            table.iter().any(|n| graphstorm::lint::rules::patterns_compatible(name, n)),
            "golden `{name}` missing from the name table"
        );
    }
}
