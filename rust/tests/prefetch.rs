//! Determinism tests for the pipelined mini-batch engine: the
//! prefetching loader must yield byte-identical batches to the serial
//! loader for any worker count, and training output must not depend on
//! `loader_workers`.  Batch-shape specs are synthesized locally so
//! these tests run without AOT artifacts.

use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::{
    batch_seed, build_lp_batch, fill_lemb, run_pipeline, BatchFactory, GsDataset,
    LinkPredictionDataLoader, NodeDataLoader, PrefetchConfig, PrefetchingLoader, Split,
};
use graphstorm::partition::{random_partition, PartitionBook};
use graphstorm::runtime::ArtifactSpec;
use graphstorm::sampling::NegSampler;
use graphstorm::trainer::{NodeTrainer, TrainOptions};
use graphstorm::util::Rng;

fn mag_ds(n: usize, parts: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = if parts <= 1 {
        PartitionBook::single(&raw.graph.num_nodes)
    } else {
        random_partition(&raw.graph, parts, 3)
    };
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    ds
}

fn nc_spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
}

fn lp_spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[1800, 300, 48], &[1500, 240], 5, r#","lp_batch":16,"k":8"#)
}

/// The prefetching loader must produce the same batch sequence for any
/// worker count, and — after `fill_lemb` — exactly what the serial
/// `NodeDataLoader::batch` path produces.
#[test]
fn prefetch_matches_serial_nc_loader() {
    let ds = mag_ds(600, 2);
    let spec = nc_spec();
    let loader = NodeDataLoader::new(&spec).unwrap();
    let ids = ds.node_labels().ids_in(Split::Train);
    let ids: Vec<u32> = ids.into_iter().take(200).collect();
    let chunks: Vec<&[u32]> = ids.chunks(64).collect();
    let seed = 0xabcdu64;

    let mut per_workers = vec![];
    for workers in [1usize, 4] {
        let mut pfl = PrefetchingLoader::new(
            &loader,
            &ds,
            PrefetchConfig { n_workers: workers, depth: 2 },
        );
        // Two epochs through the same loader: pinned factories must
        // yield the same batches on reuse as on first build.
        let first = pfl.collect(&chunks, seed, 0, 2).unwrap();
        let mut batches = pfl.collect(&chunks, seed, 0, 2).unwrap();
        for (i, (x, y)) in first.iter().zip(batches.iter()).enumerate() {
            assert_eq!(x.0, y.0, "pooled factory reuse changed batch {i}");
            assert_eq!(x.1, y.1);
        }
        // Fill the deferred embedding rows, as the trainer does.
        for (bi, (batch, touch)) in batches.iter_mut().enumerate() {
            fill_lemb(&ds, batch, touch, (bi % 2) as u32).unwrap();
        }
        per_workers.push(batches);
    }
    let [a, b] = &per_workers[..] else { unreachable!() };
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.1, y.1, "touch list differs at batch {i}");
        assert_eq!(x.0, y.0, "tensors differ at batch {i}");
    }

    // And both equal the serial (non-deferred) loader path.
    for (bi, chunk) in chunks.iter().enumerate() {
        let mut rng = Rng::seed_from(batch_seed(seed, 0, bi as u64));
        let (batch, touch, _) = loader.batch(&ds, chunk, &mut rng, (bi % 2) as u32).unwrap();
        assert_eq!(batch, a[bi].0, "serial loader differs at batch {bi}");
        assert_eq!(touch, a[bi].1);
    }
}

/// Same property for link-prediction batches (negatives + exclusion).
#[test]
fn prefetch_matches_serial_lp_loader() {
    let ds = mag_ds(500, 2);
    assert!(ds.lp.is_some(), "mag dataset must carry an LP task");
    let spec = lp_spec();
    let seed = 0x11f9u64;
    let train = ds.lp.as_ref().unwrap().edge_ids_in(Split::Train);
    let ids: Vec<u32> = train.into_iter().take(96).collect();
    let chunks: Vec<&[u32]> = ids.chunks(16).collect();

    let mut per_workers = vec![];
    for workers in [1usize, 4] {
        // Fresh loader per run: the cached exclusion must not leak
        // state across worker counts.
        let loader = LinkPredictionDataLoader::new(&spec, NegSampler::Joint { k: 8 }).unwrap();
        let cfg = PrefetchConfig { n_workers: workers, depth: 2 };
        let mut collected = vec![];
        run_pipeline(
            &chunks,
            &cfg,
            || BatchFactory::new(&ds, &loader.shape),
            |f, bi, chunk| {
                let mut rng = Rng::seed_from(batch_seed(seed, 0, bi as u64));
                build_lp_batch(f, &loader, chunk, &mut rng, (bi % 2) as u32, true)
            },
            |bi, (mut batch, touch)| {
                fill_lemb(&ds, &mut batch, &touch, (bi % 2) as u32)?;
                collected.push((batch, touch));
                Ok(())
            },
        )
        .unwrap();
        per_workers.push(collected);
    }
    let [a, b] = &per_workers[..] else { unreachable!() };
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.0, y.0, "LP tensors differ at batch {i}");
        assert_eq!(x.1, y.1, "LP touch differs at batch {i}");
    }

    // Serial loader equivalence.
    let loader = LinkPredictionDataLoader::new(&spec, NegSampler::Joint { k: 8 }).unwrap();
    for (bi, chunk) in chunks.iter().enumerate() {
        let mut rng = Rng::seed_from(batch_seed(seed, 0, bi as u64));
        let (batch, touch) = loader.batch(&ds, chunk, &mut rng, (bi % 2) as u32).unwrap();
        assert_eq!(batch, a[bi].0, "serial LP loader differs at batch {bi}");
        assert_eq!(touch, a[bi].1);
    }
}

/// Full training runs must be bit-identical across loader worker
/// counts: same epoch losses, same final evaluation.  Needs a real
/// PJRT backend + artifacts; skipped otherwise.
#[test]
fn epoch_losses_identical_across_worker_counts() {
    let Some(rt) = graphstorm::runtime::runtime_if_available() else {
        eprintln!("skipping: AOT artifacts / PJRT backend unavailable");
        return;
    };
    let mut runs = vec![];
    for workers in [1usize, 4] {
        let mut ds = mag_ds(400, 2);
        let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
        let opts = TrainOptions {
            epochs: 2,
            n_workers: 2,
            loader_workers: workers,
            prefetch: 2,
            verbose: false,
            ..Default::default()
        };
        let (rep, _) = trainer.fit(&rt, &mut ds, &opts).unwrap();
        runs.push((rep.epoch_losses.clone(), rep.val_acc, rep.test_acc));
    }
    assert_eq!(
        runs[0].0, runs[1].0,
        "epoch losses must be bit-identical for loader_workers 1 vs 4"
    );
    assert_eq!(runs[0].1, runs[1].1);
    assert_eq!(runs[0].2, runs[1].2);
}

/// The pipeline primitive keeps item order under adversarial build
/// latencies (fast/slow alternation across workers).
#[test]
fn pipeline_orders_under_skew() {
    let items: Vec<usize> = (0..64).collect();
    let mut seen = vec![];
    run_pipeline(
        &items,
        &PrefetchConfig { n_workers: 3, depth: 1 },
        || (),
        |_, i, &x| {
            if x % 3 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Ok(i)
        },
        |i, v| {
            assert_eq!(i, v);
            seen.push(i);
            Ok(())
        },
    )
    .unwrap();
    assert_eq!(seen, (0..64).collect::<Vec<_>>());
}
