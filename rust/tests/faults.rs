//! Fault-injection headline suite (the PR's acceptance contract):
//! one fixed request stream drained through supervised engine pools of
//! size 1, 2 and 8, with and without a deterministic fault schedule —
//! worker panics, transient errors and slow reads.  Replies AND
//! hit/miss accounting must be **bit-identical** across every pool
//! size, both schedules, and every cache shard count, and the
//! supervision counters must equal the plan exactly (restarts ==
//! panics, retries == transients).  Shedding and deadlines stay off
//! here — those rejections are deliberately timing-dependent and
//! tested in `tests/serve.rs`.  The clean shard/session sweep lives in
//! `tests/sharding.rs`.

use std::sync::mpsc::channel;
use std::time::Duration;

use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::GsDataset;
use graphstorm::partition::PartitionBook;
use graphstorm::runtime::ArtifactSpec;
use graphstorm::serve::{
    run_serve_bench, Admission, EnginePool, EnginePoolCfg, FaultKind, FaultPlan, FaultSpec,
    InferenceEngine, MicroBatcherCfg, ServeBenchParams, ServeError, ServeMetrics, ServeRequest,
    ShardedCache,
};

fn mag_ds(n: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = PartitionBook::single(&raw.graph.num_nodes);
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    ds
}

fn spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
        .with_output("logits", &[64, 8])
}

fn pool_cfg(workers: usize) -> EnginePoolCfg {
    EnginePoolCfg {
        workers,
        batcher: MicroBatcherCfg { max_batch: 8, deadline: Duration::from_micros(200) },
        ..Default::default()
    }
}

struct RunOut {
    replies: Vec<Result<Vec<f32>, ServeError>>,
    hits: u64,
    misses: u64,
    restarts: u64,
    retries: u64,
    shed: u64,
    deadline_misses: u64,
}

/// Open-loop drain: queue the whole trace up-front in a fixed order
/// (so arrival order — and therefore accounting — is identical for
/// every pool size), run the supervised pool over it, collect every
/// typed reply plus the counters.  `shards` stripes the cache; the
/// headline contract says it can never change what comes back.
fn drain(
    engine: &InferenceEngine,
    cfg: EnginePoolCfg,
    trace: &[(u32, u32)],
    plan: Option<&FaultPlan>,
    shards: usize,
) -> RunOut {
    let pool = EnginePool::new(cfg);
    let metrics = ServeMetrics::new();
    let cache = ShardedCache::new(1024, shards); // never evicts
    let (tx, rx) = channel::<ServeRequest>();
    let mut reply_rxs = Vec::with_capacity(trace.len());
    for &(nt, id) in trace {
        let (rtx, rrx) = channel();
        tx.send(ServeRequest::new(nt, id, rtx)).unwrap();
        reply_rxs.push(rrx);
    }
    drop(tx);
    let replies = std::thread::scope(|scope| {
        let (metrics, cache) = (&metrics, &cache);
        let h = scope.spawn(move || pool.run_with_faults(engine, cache, rx, metrics, plan));
        let replies: Vec<Result<Vec<f32>, ServeError>> = reply_rxs
            .iter()
            .enumerate()
            .map(|(i, r)| r.recv().unwrap_or_else(|_| panic!("request {i}: reply hung up")))
            .collect();
        h.join().expect("pool thread panicked").expect("pool run failed");
        replies
    });
    RunOut {
        replies,
        hits: metrics.hits(),
        misses: metrics.misses(),
        restarts: metrics.restarts(),
        retries: metrics.retries(),
        shed: metrics.shed(),
        deadline_misses: metrics.deadline_misses(),
    }
}

/// The headline: {1, 2, 8} workers × {clean, faulted} × cache shards
/// {1, 4} — replies and hit/miss accounting bit-identical everywhere,
/// counters exactly the plan's.  Replaying the *same* fault schedule
/// at different stripe counts is the sharpest probe: supervision
/// (restarts, retries, degraded dispatch) must not observe the cache
/// topology at all.
#[test]
fn faulted_runs_are_bit_identical_across_pool_sizes() {
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 23).unwrap();
    let nt = ds.target_ntype as u32;
    // 60 distinct keys, every one requested 5 times: misses, hits and
    // in-flight coalescing all occur, and the distinct count is exact.
    let trace: Vec<(u32, u32)> = (0..300).map(|i| (nt, (i % 60) as u32)).collect();
    let spec = FaultSpec::parse("panics=2,transient=3,slow=1,slow_ms=2").unwrap();
    // Guaranteed lower bound on batches cut: 60 distinct misses, at
    // most 8 seeds per batch.
    let horizon = 60u64.div_ceil(8);

    let mut baseline: Option<(Vec<Vec<f32>>, u64, u64)> = None;
    for workers in [1usize, 2, 8] {
        for faulted in [false, true] {
            for shards in [1usize, 4] {
                let plan = if faulted {
                    Some(FaultPlan::generate(23, horizon, &spec).unwrap())
                } else {
                    None
                };
                let tag = format!("workers={workers} faulted={faulted} shards={shards}");
                let out = drain(&engine, pool_cfg(workers), &trace, plan.as_ref(), shards);
                let rows: Vec<Vec<f32>> = out
                    .replies
                    .into_iter()
                    .enumerate()
                    .map(|(i, r)| r.unwrap_or_else(|e| panic!("{tag}: request {i} failed: {e}")))
                    .collect();
                if let Some(plan) = &plan {
                    assert_eq!(plan.fired(), plan.planned(), "{tag}: every planned fault fires");
                }
                assert_eq!(out.restarts, if faulted { 2 } else { 0 }, "{tag}: restarts == panics");
                assert_eq!(
                    out.retries,
                    if faulted { 3 } else { 0 },
                    "{tag}: retries == transients"
                );
                assert_eq!(out.shed, 0, "{tag}: shedding disabled");
                assert_eq!(out.deadline_misses, 0, "{tag}: deadlines disabled");
                assert_eq!(out.misses, 60, "{tag}: every distinct key misses exactly once");
                assert_eq!(out.hits, 240, "{tag}: every repeat is a hit (or coalesces)");
                match &baseline {
                    None => baseline = Some((rows, out.hits, out.misses)),
                    Some((expect, hits, misses)) => {
                        assert_eq!(&rows, expect, "{tag}: replies diverged");
                        assert_eq!(out.hits, *hits, "{tag}: hit accounting diverged");
                        assert_eq!(out.misses, *misses, "{tag}: miss accounting diverged");
                    }
                }
            }
        }
    }
}

/// A fatal (non-retryable) batch error fails exactly its own waiters
/// with the typed error — every other request is served and the pool
/// finishes cleanly.
#[test]
fn fatal_batch_error_is_contained() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 29).unwrap();
    let nt = ds.target_ntype as u32;
    // 24 distinct pre-queued keys cut into batches of 8: batch 1 is
    // deterministically keys 8..16.
    let trace: Vec<(u32, u32)> = (0..24).map(|i| (nt, i as u32)).collect();
    let plan = FaultPlan::precise(&[(1, FaultKind::Fatal)], Duration::from_millis(1));
    let out = drain(&engine, pool_cfg(2), &trace, Some(&plan), 1);
    for (i, r) in out.replies.iter().enumerate() {
        if (8..16).contains(&i) {
            assert!(
                matches!(r, Err(ServeError::Fatal(_))),
                "request {i} should carry the fatal batch error, got {r:?}"
            );
        } else {
            assert!(r.is_ok(), "request {i} outside the fatal batch must be served: {r:?}");
        }
    }
    // The fatal error discarded one worker scratch.
    assert_eq!(out.restarts, 1);
    assert_eq!(out.retries, 0, "fatal errors are not retried");
}

/// Restart-budget exhaustion retires the workers but never the pool:
/// the coordinator finishes the stream inline (degraded mode) and the
/// re-dispatched batch is answered — slower, never down, still
/// bit-identical.
#[test]
fn restart_budget_exhaustion_degrades_but_serves() {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 41).unwrap();
    let nt = ds.target_ntype as u32;
    let trace: Vec<(u32, u32)> = (0..24).map(|i| (nt, i as u32)).collect();
    // Budget 0: the single worker's first panic retires it for good.
    // Degraded mode pins execution to session lock 0 whatever the
    // cache topology, so replaying the collapse at shards {1, 4} must
    // not move a single bit.
    let mut degraded_baseline: Option<Vec<Result<Vec<f32>, ServeError>>> = None;
    for shards in [1usize, 4] {
        let cfg = EnginePoolCfg { max_worker_restarts: 0, ..pool_cfg(1) };
        let plan = FaultPlan::precise(&[(0, FaultKind::WorkerPanic)], Duration::from_millis(1));
        let out = drain(&engine, cfg, &trace, Some(&plan), shards);

        let mut sc = engine.make_scratch();
        for (i, r) in out.replies.iter().enumerate() {
            let row = r
                .as_ref()
                .unwrap_or_else(|e| panic!("degraded pool dropped request {i}: {e}"));
            let (nt, id) = trace[i];
            assert_eq!(
                row,
                &engine.predict_one(&mut sc, nt, id).unwrap(),
                "degraded-mode reply for node {id} not canonical (shards={shards})"
            );
        }
        assert_eq!(out.restarts, 1, "one panic, one supervision event (shards={shards})");
        assert_eq!(out.misses, 24, "shards={shards}");
        match &degraded_baseline {
            None => degraded_baseline = Some(out.replies),
            Some(expect) => {
                assert_eq!(&out.replies, expect, "degraded replies diverged at shards={shards}")
            }
        }
    }
}

/// End-to-end through the bench driver (`gs serve-bench --faults`
/// exercises this same path): the faulted uncached arm still matches
/// the clean warmed arm bit-for-bit, and the counters match the spec.
#[test]
fn serve_bench_with_faults_stays_bit_identical() {
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 17).unwrap();
    let spec = FaultSpec::parse("panics=1,transient=2,slow=1,slow_ms=2").unwrap();
    let rep = run_serve_bench(
        &engine,
        &ServeBenchParams {
            seed: 7,
            requests: 300,
            alpha: 1.1,
            clients: 3,
            cache: 512,
            shards: 2,
            admission: Admission::TinyLfu,
            pool: pool_cfg(2),
            refresh: 0,
            faults: Some(spec.clone()),
        },
    )
    .unwrap();
    assert!(rep.identical, "faulted uncached arm diverged from the warmed arm");
    assert_eq!(rep.planned_faults, spec.total());
    assert_eq!(rep.uncached.restarts, 1, "restarts == planned panics");
    assert_eq!(rep.uncached.retries, 2, "retries == planned transients");
    // The clean warmed arm saw no supervision events.
    assert_eq!(rep.warmed.restarts, 0);
    assert_eq!(rep.warmed.retries, 0);
}
