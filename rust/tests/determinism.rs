//! The determinism test harness: one parameterized sweep asserting
//! bit-identical output across loader worker counts {1, 2, 4, 8} for
//! every trainer — NC, LP, distill and the multi-task trainer — plus
//! a regression pin for the still-serial METIS-like matching +
//! refinement sweeps.
//!
//! Two layers, matching the repo's artifact-gating convention:
//!
//! * **Batch-stream identity (always on).**  The full interleaved
//!   multi-task stream (which routes NC, LP *and* distill batches
//!   through one pipeline) is collected for each worker count and
//!   compared byte-for-byte, and each task's sub-stream is compared
//!   against what the standalone serial loader builds from the same
//!   seed — the "single-task runs are thin wrappers" contract.
//! * **Metric identity (artifact-gated).**  Full training runs per
//!   trainer, skipped without AOT artifacts / PJRT like every other
//!   executing test.

use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::{
    batch_seed, fill_lemb, BatchFactory, GsDataset, IdChunks, LinkPredictionDataLoader,
    NodeDataLoader, Split,
};
use graphstorm::partition::{metis_like_partition, random_partition, PartitionBook};
use graphstorm::runtime::{ArtifactSpec, TensorSpec};
use graphstorm::sampling::NegSampler;
use graphstorm::trainer::lp::LpLoss;
use graphstorm::trainer::multi::{
    build_schedule, DistillSpecs, HeadKind, MultiBatch, MultiSpecs, MultiTaskTrainer, TaskSpec,
};
use graphstorm::trainer::{DistillTrainer, LpTrainer, NodeTrainer, TrainOptions};
use graphstorm::util::json::Json;
use graphstorm::util::Rng;

const WORKER_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn mag_ds(n: usize, parts: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = if parts <= 1 {
        PartitionBook::single(&raw.graph.num_nodes)
    } else {
        random_partition(&raw.graph, parts, 3)
    };
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    ds
}

fn nc_spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
}

fn lp_spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[1800, 300, 48], &[1500, 240], 5, r#","lp_batch":16,"k":8"#)
}

/// Synthetic distill specs: a 32-target GNN teacher emitting 8-dim
/// embeddings + a 32-row student token batch over `seq_len` tokens.
fn distill_specs(seq_len: usize) -> DistillSpecs {
    let tspec = ArtifactSpec::synthetic_block(&[1152, 192, 32], &[960, 160], 5, r#","batch":32"#)
        .with_output("emb", &[32, 8]);
    let t = |name: &str, shape: Vec<usize>, dtype: &str| TensorSpec {
        name: name.to_string(),
        shape,
        dtype: dtype.to_string(),
    };
    let spec = ArtifactSpec {
        file: "synthetic_distill".to_string(),
        init_file: None,
        kind: "train".to_string(),
        n_params: 0,
        state: vec![],
        scalars: vec![],
        batch: vec![
            t("tokens", vec![32, seq_len], "i32"),
            t("teacher", vec![32, 8], "f32"),
            t("lmask", vec![32], "f32"),
        ],
        outputs: vec![],
        config: Json::parse("{}").unwrap(),
    };
    DistillSpecs::derive(&spec, tspec).unwrap()
}

fn multi_trainer() -> MultiTaskTrainer {
    let mut nc = TaskSpec::new(HeadKind::Nc);
    nc.weight = 2.0;
    let lp = TaskSpec::new(HeadKind::Lp {
        loss: LpLoss::Contrastive,
        sampler: NegSampler::Joint { k: 8 },
        max_edges: Some(64),
    });
    let distill = TaskSpec::new(HeadKind::Distill);
    MultiTaskTrainer::new("rgcn", vec![nc, lp, distill])
}

fn multi_specs(ds: &GsDataset) -> MultiSpecs {
    let seq_len = ds.tokens[ds.target_ntype].as_ref().unwrap().seq_len;
    MultiSpecs {
        nc: Some(NodeDataLoader::new(&nc_spec()).unwrap()),
        lp: Some(LinkPredictionDataLoader::new(&lp_spec(), NegSampler::Joint { k: 8 }).unwrap()),
        distill: Some(distill_specs(seq_len)),
    }
}

fn opts_with_workers(workers: usize) -> TrainOptions {
    TrainOptions {
        seed: 0xa11,
        n_workers: 2,
        loader_workers: workers,
        prefetch: 2,
        verbose: false,
        ..Default::default()
    }
}

/// Collect the full interleaved stream for `epochs` epochs, with the
/// deferred learnable-embedding rows filled like the trainers fill
/// them (tables are not updated here, so fill order cannot matter).
fn collect_stream(
    trainer: &MultiTaskTrainer,
    ds: &GsDataset,
    specs: &MultiSpecs,
    opts: &TrainOptions,
    epochs: usize,
) -> Vec<(usize, usize, MultiBatch)> {
    let mut shuffles = trainer.shuffle_rngs(opts.seed);
    let mut out = vec![];
    for epoch in 0..epochs {
        trainer
            .epoch_batches(ds, specs, opts, epoch, &mut shuffles, |t, bi, mut mb| {
                let worker = (bi % opts.n_workers.max(1)) as u32;
                match &mut mb {
                    MultiBatch::Nc(batch, touch) | MultiBatch::Lp(batch, touch) => {
                        fill_lemb(ds, batch, touch, worker)?;
                    }
                    MultiBatch::Distill(_) => {}
                }
                out.push((t, bi, mb));
                Ok(())
            })
            .unwrap();
    }
    out
}

/// The tentpole sweep: the interleaved nc+lp+distill batch stream must
/// be bit-identical for loader worker counts {1, 2, 4, 8}.
#[test]
fn multi_task_stream_identical_across_worker_counts() {
    let ds = mag_ds(500, 2);
    let trainer = multi_trainer();
    let specs = multi_specs(&ds);
    let base = collect_stream(&trainer, &ds, &specs, &opts_with_workers(1), 2);
    assert!(
        base.iter().any(|(t, _, _)| *t == 0)
            && base.iter().any(|(t, _, _)| *t == 1)
            && base.iter().any(|(t, _, _)| *t == 2),
        "stream must interleave all three tasks"
    );
    for workers in WORKER_SWEEP {
        let got = collect_stream(&trainer, &ds, &specs, &opts_with_workers(workers), 2);
        assert_eq!(got.len(), base.len(), "workers={workers}");
        for (i, (a, b)) in base.iter().zip(&got).enumerate() {
            assert_eq!(a.0, b.0, "schedule diverged at item {i} (workers={workers})");
            assert_eq!(a.1, b.1, "task batch index diverged at item {i} (workers={workers})");
            assert_eq!(a.2, b.2, "batch bytes diverged at item {i} (workers={workers})");
        }
    }
}

/// The thin-wrapper contract: each task's sub-stream inside the
/// multi-task run equals what the standalone serial loaders build
/// from the same seed.
#[test]
fn multi_substreams_match_single_task_loaders() {
    let ds = mag_ds(500, 2);
    let trainer = multi_trainer();
    let specs = multi_specs(&ds);
    let opts = opts_with_workers(1);
    let epochs = 2usize;
    let stream = collect_stream(&trainer, &ds, &specs, &opts, epochs);
    let seed = opts.seed;
    let rotate = opts.n_workers;

    // NC: the standalone trainer's exact recipe (persistent shuffle
    // stream seeded seed ^ 0x6e63; per-batch RNG from batch_seed).
    let nc_loader = specs.nc.as_ref().unwrap();
    let mut expected_nc = vec![];
    let mut rng = Rng::seed_from(seed ^ 0x6e63);
    for epoch in 0..epochs {
        let chunks = IdChunks::new(
            ds.node_labels().ids_in(Split::Train),
            nc_loader.batch_size(),
            None,
            &mut rng,
        );
        for bi in 0..chunks.len() {
            let mut brng = Rng::seed_from(batch_seed(seed ^ 0x6e63, epoch as u64, bi as u64));
            let (batch, touch, _) = nc_loader
                .batch(&ds, chunks.get(bi), &mut brng, (bi % rotate) as u32)
                .unwrap();
            expected_nc.push((batch, touch));
        }
    }
    let got_nc: Vec<_> = stream
        .iter()
        .filter_map(|(t, _, mb)| match (t, mb) {
            (0, MultiBatch::Nc(b, to)) => Some((b.clone(), to.clone())),
            _ => None,
        })
        .collect();
    assert_eq!(got_nc.len(), expected_nc.len());
    for (i, (a, b)) in expected_nc.iter().zip(&got_nc).enumerate() {
        assert_eq!(a.0, b.0, "nc sub-stream tensors diverge at batch {i}");
        assert_eq!(a.1, b.1, "nc sub-stream touch diverges at batch {i}");
    }

    // LP: standalone recipe (seed ^ 0x1b9, shuffle → cap → chunk).
    let lp_loader = specs.lp.as_ref().unwrap();
    let mut expected_lp = vec![];
    let mut rng = Rng::seed_from(seed ^ 0x1b9);
    for epoch in 0..epochs {
        let ids = ds.lp.as_ref().unwrap().edge_ids_in(Split::Train);
        let chunks = IdChunks::new(ids, lp_loader.batch_size(), Some(64), &mut rng);
        for bi in 0..chunks.len() {
            let mut brng = Rng::seed_from(batch_seed(seed ^ 0x1b9, epoch as u64, bi as u64));
            let (batch, touch) = lp_loader
                .batch(&ds, chunks.get(bi), &mut brng, (bi % rotate) as u32)
                .unwrap();
            expected_lp.push((batch, touch));
        }
    }
    let got_lp: Vec<_> = stream
        .iter()
        .filter_map(|(t, _, mb)| match (t, mb) {
            (1, MultiBatch::Lp(b, to)) => Some((b.clone(), to.clone())),
            _ => None,
        })
        .collect();
    assert_eq!(got_lp.len(), expected_lp.len());
    for (i, (a, b)) in expected_lp.iter().zip(&got_lp).enumerate() {
        assert_eq!(a.0, b.0, "lp sub-stream tensors diverge at batch {i}");
        assert_eq!(a.1, b.1, "lp sub-stream touch diverges at batch {i}");
    }

    // Distill: standalone recipe (seed ^ 0xd157, 2048-node subsample).
    let dsp = specs.distill.as_ref().unwrap();
    let store = ds.tokens[ds.target_ntype].as_ref().unwrap();
    let mut expected_d = vec![];
    let mut rng = Rng::seed_from(seed ^ 0xd157);
    for epoch in 0..epochs {
        let ids: Vec<u32> = (0..store.num_rows() as u32).collect();
        let chunks = IdChunks::new(ids, dsp.dims.b, Some(2048), &mut rng);
        let mut f = BatchFactory::new(&ds, &dsp.tshape);
        for bi in 0..chunks.len() {
            let mut brng = Rng::seed_from(batch_seed(seed ^ 0xd157, epoch as u64, bi as u64));
            let db = graphstorm::trainer::distill::build_distill_batch(
                &mut f,
                store,
                ds.target_ntype,
                chunks.get(bi),
                &mut brng,
                &dsp.tshape,
                &dsp.tspec,
                &dsp.dims,
            )
            .unwrap();
            expected_d.push(db);
        }
    }
    let got_d: Vec<_> = stream
        .iter()
        .filter_map(|(t, _, mb)| match (t, mb) {
            (2, MultiBatch::Distill(db)) => Some(db.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(got_d.len(), expected_d.len());
    for (i, (a, b)) in expected_d.iter().zip(&got_d).enumerate() {
        assert_eq!(a, b, "distill sub-stream diverges at batch {i}");
    }
}

/// The schedule itself is a pure function of (seed, epoch, counts,
/// weights): same inputs → same interleaving, exhaustive budgets.
#[test]
fn schedule_pure_and_budget_exact() {
    let counts = [9usize, 4, 6];
    let weights = [2.0, 1.0, 0.5];
    let a = build_schedule(0xa11, 3, &counts, &weights);
    assert_eq!(a, build_schedule(0xa11, 3, &counts, &weights));
    assert_eq!(a.len(), 19);
    for (t, &c) in counts.iter().enumerate() {
        assert_eq!(a.iter().filter(|&&x| x == t).count(), c);
    }
    assert_ne!(a, build_schedule(0xa11, 4, &counts, &weights));
}

/// Metric-level sweep over all four trainers — full training runs must
/// report bit-identical metrics for any loader worker count.  Gated on
/// AOT artifacts / PJRT like every executing test.
#[test]
fn trainer_metrics_identical_across_worker_counts() {
    let Some(rt) = graphstorm::runtime::runtime_if_available() else {
        eprintln!("skipping: AOT artifacts / PJRT backend unavailable");
        return;
    };

    // --- NC ---------------------------------------------------------
    let mut base = None;
    for workers in WORKER_SWEEP {
        let mut ds = mag_ds(400, 2);
        let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
        let opts = TrainOptions { epochs: 2, ..opts_with_workers(workers) };
        let (rep, _) = trainer.fit(&rt, &mut ds, &opts).unwrap();
        let key = (rep.epoch_losses.clone(), rep.val_acc, rep.test_acc);
        match &base {
            None => base = Some(key),
            Some(b) => assert_eq!(b, &key, "nc metrics diverge at workers={workers}"),
        }
    }

    // --- LP ---------------------------------------------------------
    let mut base = None;
    for workers in WORKER_SWEEP {
        let mut ds = mag_ds(400, 2);
        let mut trainer = LpTrainer::new(
            "rgcn_lp_joint_k32_train",
            "rgcn_lp_emb",
            LpLoss::Contrastive,
            NegSampler::Joint { k: 32 },
        );
        trainer.max_train_edges = Some(128);
        trainer.eval_every_epoch = false;
        let opts = TrainOptions { epochs: 1, ..opts_with_workers(workers) };
        let (rep, _) = trainer.fit(&rt, &mut ds, &opts).unwrap();
        let key = (rep.epoch_losses.clone(), rep.val_mrr, rep.test_mrr);
        match &base {
            None => base = Some(key),
            Some(b) => assert_eq!(b, &key, "lp metrics diverge at workers={workers}"),
        }
    }

    // --- Distill (teacher + student chain) --------------------------
    let mut base = None;
    for workers in WORKER_SWEEP {
        let mut ds = mag_ds(400, 2);
        let opts = TrainOptions { epochs: 1, ..opts_with_workers(workers) };
        let teacher = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
        let (_, tst) = teacher.fit(&rt, &mut ds, &opts).unwrap();
        let dt = DistillTrainer::default();
        let (mse, _) = dt.distill(&rt, &ds, &tst.params_host().unwrap(), &opts).unwrap();
        match &base {
            None => base = Some(mse.to_bits()),
            Some(b) => {
                assert_eq!(*b, mse.to_bits(), "distill mse diverges at workers={workers}")
            }
        }
    }

    // --- Multi-task (nc + distill over the shared trunk) ------------
    let mut base = None;
    for workers in WORKER_SWEEP {
        let mut ds = mag_ds(400, 2);
        let mut nc = TaskSpec::new(HeadKind::Nc);
        nc.weight = 2.0;
        let trainer = MultiTaskTrainer::new("rgcn", vec![nc, TaskSpec::new(HeadKind::Distill)]);
        let opts = TrainOptions { epochs: 1, ..opts_with_workers(workers) };
        let rep = trainer.fit(&rt, &mut ds, &opts).unwrap();
        let ncr = rep.nc.as_ref().unwrap();
        let key = (
            rep.epoch_losses.clone(),
            ncr.val_acc,
            ncr.test_acc,
            rep.distill_mse.map(f32::to_bits),
        );
        match &base {
            None => base = Some(key),
            Some(b) => assert_eq!(b, &key, "multi-task metrics diverge at workers={workers}"),
        }
    }
}

// ------------------------------------------------------------ metis pin

/// A fixed deterministic graph (ring + chords, no RNG) for the
/// partition pin: big enough that one heavy-edge-matching coarsening
/// level runs, small enough that the fixture stays reviewable.
fn pin_graph() -> graphstorm::graph::HeteroGraph {
    use graphstorm::graph::{EdgeTypeDef, HeteroGraph, Schema};
    let n = 600u32;
    let schema = Schema::new(
        vec!["v".into()],
        vec![EdgeTypeDef { name: "e".into(), src_ntype: 0, dst_ntype: 0 }],
    );
    let mut g = HeteroGraph::new(schema, vec![n as usize]);
    let (mut src, mut dst) = (vec![], vec![]);
    for i in 0..n {
        src.push(i);
        dst.push((i + 1) % n);
    }
    for i in (0..n).step_by(2) {
        src.push(i);
        dst.push((i + 37) % n);
    }
    g.set_edges(0, src, dst);
    g
}

/// Regression pin: `metis_like_partition` on a fixed graph must keep
/// producing exactly the assignments recorded in
/// `tests/fixtures/metis_pin.json`.  The matching + refinement sweeps
/// are still serial (ROADMAP); this locks their current output so a
/// future parallelization shows up as a reviewed diff, not silent
/// drift.  Regenerate (after auditing!) with
/// `GS_WRITE_FIXTURES=1 cargo test -q metis_partition`.
#[test]
fn metis_partition_matches_pinned_fixture() {
    let g = pin_graph();
    let book = metis_like_partition(&g, 3, 11);
    let got: Vec<usize> = book.assignments[0].iter().map(|&p| p as usize).collect();
    assert_eq!(got.len(), 600);
    assert!(got.iter().all(|&p| p < 3));
    for part in 0..3 {
        assert!(got.iter().any(|&p| p == part), "part {part} is empty");
    }

    let path = std::path::Path::new("tests/fixtures/metis_pin.json");
    let payload = format!(
        "{{\"n\": 600, \"parts\": 3, \"seed\": 11, \"assignments\": {got:?}}}\n"
    );
    if std::env::var("GS_WRITE_FIXTURES").is_ok() {
        std::fs::write(path, payload).unwrap();
        return;
    }
    let text = std::fs::read_to_string(path)
        .expect("tests/fixtures/metis_pin.json missing — GS_WRITE_FIXTURES=1 to bootstrap");
    let j = Json::parse(&text).unwrap();
    let want: Vec<usize> = j
        .get("assignments")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    assert_eq!(
        got, want,
        "metis_like_partition output drifted from the pinned fixture; if the change is \
         intended, audit it and regenerate with GS_WRITE_FIXTURES=1"
    );
}
