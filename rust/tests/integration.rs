//! Integration tests across modules: gconstruct → partition → engine →
//! sampling → AOT runtime → trainers, plus randomized property tests
//! (a light in-tree stand-in for proptest — offline build, DESIGN.md §1:
//! each property runs over many seeded random cases).

use graphstorm::datagen::{self, amazon, mag, scale_free};
use graphstorm::dataloader::{
    assemble_block_inputs, LinkPredictionDataLoader, NodeDataLoader, Split,
};
use graphstorm::partition::{edge_cut, metis_like_partition, random_partition, PartitionBook};
use graphstorm::runtime::{Runtime, TrainState};
use graphstorm::sampling::{BlockShape, EdgeExclusion, NegSampler, NeighborSampler};
use graphstorm::trainer::{NodeTrainer, TrainOptions};
use graphstorm::util::Rng;

/// The runtime if the manifest loads (batch-shape tests don't execute).
fn manifest_rt() -> Option<Runtime> {
    match Runtime::from_default_dir() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: AOT artifacts unavailable ({e})");
            None
        }
    }
}

/// The runtime only if PJRT can actually execute artifacts.
fn exec_rt() -> Option<Runtime> {
    let rt = graphstorm::runtime::runtime_if_available();
    if rt.is_none() {
        eprintln!("skipping: AOT artifacts / PJRT backend unavailable");
    }
    rt
}

fn mag_ds(n: usize, parts: usize) -> graphstorm::dataloader::GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = if parts <= 1 {
        PartitionBook::single(&raw.graph.num_nodes)
    } else {
        random_partition(&raw.graph, parts, 3)
    };
    datagen::build_dataset(raw, book, 64, 3)
}

// ---------------------------------------------------------- properties

/// Property: every partitioner covers every node exactly once and
/// respects the part-count bound, over random graphs.
#[test]
fn prop_partition_coverage() {
    for seed in 0..8u64 {
        let mut rng = Rng::seed_from(seed);
        let raw = scale_free::generate(&scale_free::ScaleFreeConfig {
            n_edges: 2000 + rng.gen_range(8000),
            seed,
            ..Default::default()
        });
        for k in [2, 3, 5] {
            for book in [
                random_partition(&raw.graph, k, seed),
                metis_like_partition(&raw.graph, k, seed),
            ] {
                assert_eq!(book.n_parts, k);
                let total: usize = book.part_sizes().iter().sum();
                assert_eq!(total, raw.graph.total_nodes());
                assert!(book.assignments.iter().flatten().all(|&p| (p as usize) < k));
            }
        }
    }
}

/// Property: METIS-like cut ≤ random cut on clustered graphs.
#[test]
fn prop_metis_beats_random_on_clusters() {
    use graphstorm::graph::{EdgeTypeDef, HeteroGraph, Schema};
    for seed in 0..5u64 {
        let mut rng = Rng::seed_from(seed ^ 0xc1);
        let k = 4;
        let per = 80;
        let n = k * per;
        let schema = Schema::new(
            vec!["v".into()],
            vec![EdgeTypeDef { name: "e".into(), src_ntype: 0, dst_ntype: 0 }],
        );
        let mut g = HeteroGraph::new(schema, vec![n]);
        let (mut src, mut dst) = (vec![], vec![]);
        for c in 0..k {
            for _ in 0..per * 12 {
                src.push((c * per + rng.gen_range(per)) as u32);
                dst.push((c * per + rng.gen_range(per)) as u32);
            }
        }
        for _ in 0..20 {
            src.push(rng.gen_range(n) as u32);
            dst.push(rng.gen_range(n) as u32);
        }
        g.set_edges(0, src, dst);
        let mc = edge_cut(&g, &metis_like_partition(&g, k, seed));
        let rc = edge_cut(&g, &random_partition(&g, k, seed));
        assert!(mc < rc, "seed {seed}: metis {mc} !< random {rc}");
    }
}

/// Property: sampled blocks always validate, respect fanout and the
/// subset property, across random seeds / seed-set sizes.
#[test]
fn prop_blocks_always_valid() {
    let ds = mag_ds(800, 2);
    let sampler = NeighborSampler::new(&ds.graph);
    let shape = BlockShape {
        ns: vec![2304, 384, 64],
        es: vec![1920, 320],
        fanout: 5,
    };
    for seed in 0..12u64 {
        let mut rng = Rng::seed_from(seed);
        let n_seeds = 1 + rng.gen_range(64);
        let seeds: Vec<(u32, u32)> =
            (0..n_seeds).map(|_| (0u32, rng.gen_range(800) as u32)).collect();
        let block = sampler.sample_block(&seeds, &shape, &mut rng, &EdgeExclusion::new());
        block.validate().unwrap();
        // Per-dst fanout bound on the innermost hop.
        let mut per_dst = std::collections::HashMap::new();
        let le = &block.layers[1];
        for i in 0..le.dst.len() {
            if le.emask[i] > 0.0 {
                *per_dst.entry(le.dst[i]).or_insert(0usize) += 1;
            }
        }
        assert!(per_dst.values().all(|&c| c <= 5));
    }
}

/// Property: excluded edges never appear in sampled blocks, including
/// through the reverse edge type.
#[test]
fn prop_exclusion_holds_with_reverse() {
    let ds = mag_ds(400, 1);
    let lp = ds.lp.as_ref().unwrap();
    let et = lp.etype as u32;
    let rev = ds.rev_map[&(et as usize)] as u32;
    let es = &ds.graph.edges[et as usize];
    let sampler = NeighborSampler::new(&ds.graph);
    let shape = BlockShape { ns: vec![432, 72], es: vec![360], fanout: 5 };
    for seed in 0..10u64 {
        let mut rng = Rng::seed_from(seed);
        let eid = rng.gen_range(es.src.len());
        let (s, d) = (es.src[eid], es.dst[eid]);
        let mut ex = EdgeExclusion::new();
        ex.insert_with_reverse(et, Some(rev), s, d);
        let block = sampler.sample_block(&[(0, s), (0, d)], &shape, &mut rng, &ex);
        // The excluded pair must not be connected by etype et/rev in the block.
        let le = &block.layers[0];
        for i in 0..le.src.len() {
            if le.emask[i] == 0.0 {
                continue;
            }
            let sp = block.nodes[le.src[i] as usize];
            let dp = block.nodes[le.dst[i] as usize];
            let et_i = le.etype[i] as u32;
            assert!(
                !(et_i == et && sp == (0, s) && dp == (0, d))
                    && !(et_i == rev && sp == (0, d) && dp == (0, s)),
                "excluded edge sampled (seed {seed})"
            );
        }
    }
}

/// Property: batch assembly is deterministic given the RNG seed and
/// produces manifest-conforming shapes.
#[test]
fn prop_batch_assembly_deterministic() {
    let Some(rt) = manifest_rt() else { return };
    let spec = rt.manifest.get("rgcn_nc_train").unwrap().clone();
    let mut ds = mag_ds(600, 2);
    ds.ensure_text_features(64);
    let loader = NodeDataLoader::new(&spec).unwrap();
    let ids: Vec<u32> = (0..64).collect();
    for seed in 0..4u64 {
        let mut r1 = Rng::seed_from(seed);
        let mut r2 = Rng::seed_from(seed);
        let (b1, _, _) = loader.batch(&ds, &ids, &mut r1, 0).unwrap();
        let (b2, _, _) = loader.batch(&ds, &ids, &mut r2, 0).unwrap();
        assert_eq!(b1.len(), spec.batch.len());
        for ((t1, t2), ts) in b1.iter().zip(&b2).zip(&spec.batch) {
            assert_eq!(t1.shape(), ts.shape.as_slice(), "{}", ts.name);
            assert_eq!(t1, t2, "nondeterministic batch for {}", ts.name);
        }
    }
}

/// Property: LP batches index only valid seed slots and in-batch
/// negatives reference other positives' destinations.
#[test]
fn prop_lp_batch_slots_valid() {
    let Some(rt) = manifest_rt() else { return };
    let spec = rt.manifest.get("rgcn_lp_joint_k32_train").unwrap().clone();
    let world = amazon::generate_world(&amazon::ArConfig { n_items: 500, ..Default::default() });
    let raw = amazon::build_variant(&world, amazon::ArVariant::HeteroV2);
    let book = PartitionBook::single(&raw.graph.num_nodes);
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    for (si, sampler) in [
        NegSampler::Joint { k: 32 },
        NegSampler::InBatch { k: 32 },
        NegSampler::LocalJoint { k: 32 },
    ]
    .into_iter()
    .enumerate()
    {
        let loader = LinkPredictionDataLoader::new(&spec, sampler).unwrap();
        let train = ds.lp.as_ref().unwrap().edge_ids_in(Split::Train);
        let mut rng = Rng::seed_from(si as u64);
        let chunk: Vec<u32> = train.iter().take(loader.batch_size()).copied().collect();
        let (batch, _) = loader.batch(&ds, &chunk, &mut rng, 0).unwrap();
        let nt = spec.block().unwrap().0.last().copied().unwrap();
        // pos_src/pos_dst/neg_dst are the last 6 tensors, indices into targets.
        let n = batch.len();
        for t in &batch[n - 6..n - 2] {
            if let graphstorm::runtime::Tensor::I32 { data, .. } = t {
                assert!(data.iter().all(|&x| (x as usize) < nt));
            }
        }
    }
}

// ----------------------------------------------------------- end-to-end

/// The whole pipeline: gconstruct fixture → partition → train → eval →
/// checkpoint save/restore round-trip.
#[test]
fn end_to_end_gconstruct_train_checkpoint() {
    let dir = std::env::temp_dir().join(format!("gs_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // Small venue-separable fixture.
    let mut rng = Rng::seed_from(5);
    let venues: Vec<usize> = (0..120).map(|_| rng.gen_range(2)).collect();
    let mut papers = String::from("node_id,text,venue\n");
    for (i, &v) in venues.iter().enumerate() {
        papers += &format!("p{i},w{v}a w{v}b w{v}c,venue{v}\n");
    }
    let mut cites = String::from("src,dst\n");
    for i in 0..120usize {
        for _ in 0..3 {
            let j = (0..)
                .map(|_| rng.gen_range(120))
                .find(|&j| venues[j] == venues[i] && j != i)
                .unwrap();
            cites += &format!("p{i},p{j}\n");
        }
    }
    std::fs::write(dir.join("papers.csv"), papers).unwrap();
    std::fs::write(dir.join("cites.csv"), cites).unwrap();
    std::fs::write(dir.join("authors.csv"), "node_id\na0\n").unwrap();
    std::fs::write(dir.join("writes.csv"), "src,dst\na0,p0\n").unwrap();
    std::fs::write(dir.join("schema.json"), graphstorm::gconstruct::config::EXAMPLE_SCHEMA).unwrap();

    let cfg = graphstorm::gconstruct::GConstructConfig::load(&dir.join("schema.json")).unwrap();
    let mut ds = graphstorm::gconstruct::construct_dataset(&cfg, &dir, 2, false).unwrap();
    ds.ensure_text_features(64);

    let Some(rt) = exec_rt() else {
        std::fs::remove_dir_all(&dir).ok();
        return;
    };
    let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
    let opts = TrainOptions { epochs: 6, n_workers: 2, verbose: false, ..Default::default() };
    let (rep, st) = trainer.fit(&rt, &mut ds, &opts).unwrap();
    assert!(
        rep.epoch_losses.last().unwrap() < &rep.epoch_losses[0],
        "loss must drop: {:?}",
        rep.epoch_losses
    );
    assert!(rep.test_acc > 0.55, "acc {}", rep.test_acc);

    // Checkpoint round-trip: restore into a new state, same eval result.
    let ckpt = dir.join("model.gstf");
    st.save(&ckpt).unwrap();
    let params = graphstorm::runtime::gstf::read_gstf(&ckpt).unwrap();
    let st2 = TrainState::with_params(&rt, "rgcn_nc_train", &params).unwrap();
    let acc1 = trainer.evaluate(&rt, &ds, &st, Split::Test, &opts).unwrap();
    let acc2 = trainer.evaluate(&rt, &ds, &st2, Split::Test, &opts).unwrap();
    assert!((acc1 - acc2).abs() < 1e-9, "restore changed eval: {acc1} vs {acc2}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Multi-worker traffic accounting: with 4 partitions a training run
/// must record remote accesses; with 1 partition it must not.
#[test]
fn traffic_counters_reflect_partitioning() {
    let Some(rt) = exec_rt() else { return };
    for (parts, expect_remote) in [(1usize, false), (4, true)] {
        let mut ds = mag_ds(500, parts);
        ds.ensure_text_features(64);
        ds.engine.counters.reset();
        let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
        let opts = TrainOptions { epochs: 1, n_workers: parts, verbose: false, ..Default::default() };
        trainer.fit(&rt, &mut ds, &opts).unwrap();
        let s = ds.engine.counters.snapshot();
        assert_eq!(s.remote_elems > 0, expect_remote, "parts={parts}: {s:?}");
        assert!(s.local_elems > 0);
    }
}

/// Learnable-embedding path: author embeddings must move during training.
#[test]
fn embedding_table_learns() {
    let Some(rt) = exec_rt() else { return };
    let mut ds = mag_ds(400, 1);
    ds.ensure_text_features(64);
    let nt_author = 1;
    let before = ds.engine.embeds[nt_author].as_ref().unwrap().weights_snapshot();
    let trainer = NodeTrainer::new("rgcn_nc_train", "rgcn_nc_logits");
    let opts = TrainOptions { epochs: 2, verbose: false, ..Default::default() };
    trainer.fit(&rt, &mut ds, &opts).unwrap();
    let after = ds.engine.embeds[nt_author].as_ref().unwrap().weights_snapshot();
    let changed = before.iter().zip(&after).filter(|(a, b)| a != b).count();
    assert!(changed > 0, "no embedding rows were updated");
}
