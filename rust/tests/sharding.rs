//! Shard-sweep determinism harness (the tentpole contract): striping
//! the serving hot path N ways — cache shards, parallel engine
//! sessions, EmbTable row stripes — must never change a single bit of
//! what comes back.  One fixed request stream is drained at every
//! `(shards, sessions, pool_workers)` combination and compared against
//! the single-shard single-session baseline: replies AND hit/miss/shed
//! accounting bit-identical everywhere (coalescing is a subset of hits
//! whose split is timing-dependent by design, so it is bounded, not
//! pinned).  The same sweep is replayed under a deterministic fault
//! schedule, the merged `hot_keys` view is proven equivalent to the
//! single-cache recency order, and per-stripe EmbTable generations are
//! proven to compose with `put_if_current` and the hot-row refresher.
//!
//! Everything runs the deterministic surrogate backend — no AOT
//! artifacts or PJRT needed.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::GsDataset;
use graphstorm::dist::{EmbTable, TrafficCounters};
use graphstorm::partition::PartitionBook;
use graphstorm::runtime::ArtifactSpec;
use graphstorm::serve::{
    cache_key, refresh_hot_rows, shard_of, EmbTableSource, EnginePool, EnginePoolCfg, FaultPlan,
    FaultSpec, InferenceEngine, MicroBatcherCfg, ServeMetrics, ServeRequest, ShardedCache,
};

fn mag_ds(n: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = PartitionBook::single(&raw.graph.num_nodes);
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    ds
}

fn spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
        .with_output("logits", &[64, 8])
}

struct RunOut {
    replies: Vec<Vec<f32>>,
    hits: u64,
    misses: u64,
    coalesced: u64,
    shed: u64,
}

/// Open-loop drain at one `(workers, sessions, shards)` point: queue
/// the whole trace up-front in a fixed order (so arrival order — and
/// therefore accounting — is identical for every topology), run the
/// supervised pool over a never-evicting striped cache, collect every
/// reply plus the counters.
fn drain(
    engine: &InferenceEngine,
    workers: usize,
    sessions: usize,
    shards: usize,
    trace: &[(u32, u32)],
    plan: Option<&FaultPlan>,
) -> RunOut {
    let pool = EnginePool::new(EnginePoolCfg {
        workers,
        sessions,
        batcher: MicroBatcherCfg { max_batch: 8, deadline: Duration::from_micros(200) },
        ..Default::default()
    });
    let metrics = ServeMetrics::new();
    let cache = ShardedCache::new(1024, shards); // never evicts
    let (tx, rx) = channel::<ServeRequest>();
    let mut reply_rxs = Vec::with_capacity(trace.len());
    for &(nt, id) in trace {
        let (rtx, rrx) = channel();
        tx.send(ServeRequest::new(nt, id, rtx)).unwrap();
        reply_rxs.push(rrx);
    }
    drop(tx);
    let replies = std::thread::scope(|scope| {
        let (metrics, cache) = (&metrics, &cache);
        let h = scope.spawn(move || pool.run_with_faults(engine, cache, rx, metrics, plan));
        let replies: Vec<Vec<f32>> = reply_rxs
            .iter()
            .enumerate()
            .map(|(i, r)| {
                r.recv()
                    .unwrap_or_else(|_| panic!("request {i}: reply hung up"))
                    .unwrap_or_else(|e| panic!("request {i} failed: {e}"))
            })
            .collect();
        h.join().expect("pool thread panicked").expect("pool run failed");
        replies
    });
    RunOut {
        replies,
        hits: metrics.hits(),
        misses: metrics.misses(),
        coalesced: metrics.coalesced(),
        shed: metrics.shed(),
    }
}

/// The headline sweep: cache shards {1, 2, 4, 8} × engine topologies
/// {(1,1), (2,1), (2,2), (8,4), (8,8)} (workers, sessions) against the
/// single-everything baseline.  Replies, hits, misses and shed are
/// bit-identical at every point; coalesced stays a subset of hits.
#[test]
fn shard_session_sweep_is_bit_identical() {
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 23).unwrap();
    let nt = ds.target_ntype as u32;
    // 60 distinct keys, every one requested 5 times: misses, hits and
    // in-flight coalescing all occur, and the counters are exact.
    let trace: Vec<(u32, u32)> = (0..300).map(|i| (nt, (i % 60) as u32)).collect();

    let mut baseline: Option<RunOut> = None;
    for shards in [1usize, 2, 4, 8] {
        for (workers, sessions) in [(1usize, 1usize), (2, 1), (2, 2), (8, 4), (8, 8)] {
            let tag = format!("shards={shards} workers={workers} sessions={sessions}");
            let out = drain(&engine, workers, sessions, shards, &trace, None);
            assert_eq!(out.misses, 60, "{tag}: every distinct key misses exactly once");
            assert_eq!(out.hits, 240, "{tag}: every repeat is a hit (or coalesces)");
            assert_eq!(out.shed, 0, "{tag}: shedding disabled");
            assert!(out.coalesced <= out.hits, "{tag}: coalesced replies are hits");
            match &baseline {
                None => baseline = Some(out),
                Some(base) => {
                    assert_eq!(out.replies, base.replies, "{tag}: replies diverged");
                    assert_eq!(out.hits, base.hits, "{tag}: hit accounting diverged");
                    assert_eq!(out.misses, base.misses, "{tag}: miss accounting diverged");
                    assert_eq!(out.shed, base.shed, "{tag}: shed accounting diverged");
                }
            }
        }
    }
}

/// The same sweep under fault injection: one deterministic schedule
/// (worker panics + transient errors + slow reads) replayed at shards
/// {1, 4} × sessions {1, 2} keeps replies bit-identical and the
/// supervision counters exactly the plan's — recovery never observes
/// the cache or session topology.
#[test]
fn faulted_shard_sweep_replays_identically() {
    let ds = mag_ds(400);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 23).unwrap();
    let nt = ds.target_ntype as u32;
    let trace: Vec<(u32, u32)> = (0..300).map(|i| (nt, (i % 60) as u32)).collect();
    let fspec = FaultSpec::parse("panics=2,transient=3,slow=1,slow_ms=2").unwrap();
    // Guaranteed lower bound on batches cut: 60 distinct misses, at
    // most 8 seeds per batch.
    let horizon = 60u64.div_ceil(8);

    let mut baseline: Option<Vec<Vec<f32>>> = None;
    for shards in [1usize, 4] {
        for sessions in [1usize, 2] {
            let plan = FaultPlan::generate(23, horizon, &fspec).unwrap();
            let tag = format!("shards={shards} sessions={sessions}");
            let out = drain(&engine, 2, sessions, shards, &trace, Some(&plan));
            assert_eq!(plan.fired(), plan.planned(), "{tag}: every planned fault fires");
            assert_eq!(out.misses, 60, "{tag}");
            assert_eq!(out.hits, 240, "{tag}");
            match &baseline {
                None => baseline = Some(out.replies),
                Some(expect) => {
                    assert_eq!(&out.replies, expect, "{tag}: faulted replies diverged")
                }
            }
        }
    }
}

/// The merged `hot_keys` view of a striped cache equals the recency
/// order a single-shard cache produces under the same touch sequence —
/// the property the background refresher's hot-set selection rests on.
#[test]
fn merged_hot_keys_match_single_shard_order() {
    let single = ShardedCache::new(256, 1);
    let striped = ShardedCache::new(256, 4);
    let row = [1.0f32, 2.0, 3.0];
    // Same deterministic op sequence against both: inserts, repeated
    // touches, an overwrite — every operation bumps the shared touch
    // ticker identically.
    for c in [&single, &striped] {
        for id in 0..64u32 {
            c.put(cache_key(0, id), &row);
        }
        for id in [7u32, 3, 7, 41, 3, 63, 0, 17, 7] {
            assert!(c.get(cache_key(0, id)).is_some(), "warmed key {id} missing");
        }
        c.put(cache_key(0, 41), &row);
    }
    assert_eq!(single.len(), striped.len());
    for limit in [1usize, 4, 8, 64, 1000] {
        assert_eq!(
            single.hot_keys(limit),
            striped.hot_keys(limit),
            "merged hot set diverged at limit {limit}"
        );
    }
    // The global head is the most recent touch.
    assert_eq!(single.hot_keys(1), vec![cache_key(0, 41)]);
}

/// Per-stripe EmbTable generations compose with the cache's
/// `put_if_current` and the hot-row refresher: an update to one stripe
/// bumps only that stripe (the aggregate generation still moves, so
/// the refresher notices), a refresh pass re-reads the post-update
/// bytes, stale writers are refused, and a full `bump_generation`
/// invalidates every stripe at once.
#[test]
fn per_stripe_generations_compose_with_refresh() {
    let book = Arc::new(PartitionBook::single(&[40]));
    let counters = Arc::new(TrafficCounters::new());
    let table = EmbTable::new_sharded(0, 40, 4, 7, 4, book, counters);
    let stripe = |id: u32| shard_of(id as u64, 4);
    let id_a = 0u32;
    let id_b = (1..40u32).find(|&i| stripe(i) != stripe(id_a)).expect("two stripes in use");

    // Warm 8 hot rows through the striped read-through path.
    let cache = ShardedCache::new(64, 4);
    {
        let mut src = EmbTableSource { table: &table, worker: 0 };
        let mut row = Vec::new();
        for id in 0..8u32 {
            assert!(!cache.get_through(0, id, &mut src, &mut row).unwrap());
        }
    }
    let before = table.weights_snapshot();

    // An update touching only id_a's stripe bumps only that stripe —
    // but the aggregate generation still moves, which is what the
    // refresher keys on.
    table.sparse_adam(&[id_a], &[0.5; 4], 1e-2);
    assert_eq!(table.shard_generation(stripe(id_a)), 1, "touched stripe bumped");
    assert_eq!(table.shard_generation(stripe(id_b)), 0, "untouched stripe unmoved");
    assert_eq!(table.generation(), 1, "aggregate generation is the stripe sum");

    // One refresh pass re-reads the hot rows at the new generation.
    let mut src = EmbTableSource { table: &table, worker: 0 };
    let refreshed = refresh_hot_rows(&cache, &mut src, 8).unwrap();
    assert_eq!(refreshed, 8);
    assert_eq!(refresh_hot_rows(&cache, &mut src, 8).unwrap(), 0, "second pass is a no-op");

    let snap = table.weights_snapshot();
    cache.set_generation(table.generation());
    for id in 0..8u32 {
        let row = cache.get(cache_key(0, id)).expect("refreshed row resident");
        let base = id as usize * 4;
        assert_eq!(row, &snap[base..base + 4], "stale row served for node {id}");
    }
    // The updated row moved; rows on other stripes kept their bytes.
    let a = id_a as usize * 4;
    let b = id_b as usize * 4;
    assert_ne!(&snap[a..a + 4], &before[a..a + 4], "update must move id_a's row");
    assert_eq!(&snap[b..b + 4], &before[b..b + 4], "id_b's stripe was never written");

    // Stale writers are refused: a put pinned to an old generation is
    // dropped once the stripe has moved on.
    let cur = cache.generation();
    let key = cache_key(0, id_a);
    assert!(cache.put_if_current(key, &snap[a..a + 4], cur));
    assert!(!cache.put_if_current(key, &before[a..a + 4], cur + 7), "stale write accepted");

    // A full invalidation bumps every stripe: the sharded generation
    // jumps by the stripe count.
    let g = table.generation();
    table.bump_generation();
    assert_eq!(table.generation(), g + 4, "bump_generation bumps all four stripes");
}
