//! HTTP front-end acceptance suite (docs/SERVING.md): a real
//! `HttpServer` on an ephemeral loopback port, driven by raw
//! `TcpStream` clients.
//!
//! * protocol edges: malformed request line → 400, unknown route →
//!   404, Content-Length mismatch → 400, oversized body → 413 — all
//!   answered, never a panic or a silent hangup;
//! * keep-alive sequencing, including two pipelined requests in one
//!   TCP segment;
//! * queue-boundary overload → 429 with the pool still serving;
//! * the determinism contract across the wire: repeated identical
//!   requests yield byte-identical replies, and the JSON row carries
//!   the engine's f32 bits exactly.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::time::Duration;

use graphstorm::datagen::{self, mag};
use graphstorm::dataloader::GsDataset;
use graphstorm::partition::PartitionBook;
use graphstorm::runtime::ArtifactSpec;
use graphstorm::serve::http::proto::{parse_response, Parse, Response};
use graphstorm::serve::{
    EnginePoolCfg, HttpReport, HttpServer, HttpServerCfg, InferenceEngine, MicroBatcherCfg,
    ShardedCache,
};
use graphstorm::util::json::Json;

fn mag_ds(n: usize) -> GsDataset {
    let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
    let book = PartitionBook::single(&raw.graph.num_nodes);
    let mut ds = datagen::build_dataset(raw, book, 64, 3);
    ds.ensure_text_features(64);
    ds
}

fn spec() -> ArtifactSpec {
    ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
        .with_output("logits", &[64, 8])
}

fn http_cfg() -> HttpServerCfg {
    HttpServerCfg {
        listen: "127.0.0.1:0".to_string(),
        workers: 8,
        max_body: 4096,
        read_timeout: Duration::from_millis(300),
        write_timeout: Duration::from_secs(2),
    }
}

fn pool_cfg() -> EnginePoolCfg {
    EnginePoolCfg {
        workers: 2,
        batcher: MicroBatcherCfg { max_batch: 8, deadline: Duration::from_micros(200) },
        ..Default::default()
    }
}

/// Run `f` against a live server (surrogate engine over a small MAG
/// graph), then drain it and return the traffic report alongside `f`'s
/// result.
fn serve_scope<T>(
    pool: EnginePoolCfg,
    http: HttpServerCfg,
    f: impl FnOnce(SocketAddr, &InferenceEngine) -> T,
) -> (HttpReport, T) {
    let ds = mag_ds(300);
    let engine = InferenceEngine::surrogate(&ds, &spec(), 7).unwrap();
    let cache = ShardedCache::new(1024, 2);
    cache.set_generation(engine.generation());
    let server = HttpServer::bind(http).unwrap();
    let addr = server.local_addr();
    let handle = server.shutdown_handle();
    std::thread::scope(|scope| {
        let serving = scope.spawn(|| server.serve(&engine, &cache, pool));
        let out = f(addr, &engine);
        handle.trigger();
        let report = serving.join().unwrap().unwrap();
        (report, out)
    })
}

fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.set_nodelay(true).unwrap();
    s
}

/// Read exactly one response off the stream (which may already hold
/// buffered bytes in `buf` from pipelined reads).
fn read_response(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Response {
    let mut chunk = [0u8; 4096];
    loop {
        match parse_response(buf, 1 << 20) {
            Parse::Ready(resp, used) => {
                buf.drain(..used);
                return resp;
            }
            Parse::Bad(bad) => panic!("unparseable response: {}", bad.message()),
            Parse::Incomplete => {
                let n = stream.read(&mut chunk).expect("read response");
                assert!(n > 0, "connection closed mid-response (have {} bytes)", buf.len());
                buf.extend_from_slice(&chunk[..n]);
            }
        }
    }
}

fn call(stream: &mut TcpStream, raw: &[u8]) -> Response {
    stream.write_all(raw).unwrap();
    read_response(stream, &mut Vec::new())
}

fn predict_raw(nt: u32, id: u32) -> Vec<u8> {
    let body = format!("{{\"nt\": {nt}, \"id\": {id}}}");
    format!(
        "POST /predict HTTP/1.1\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn body_json(resp: &Response) -> Json {
    Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
}

#[test]
fn malformed_request_line_gets_400_then_close() {
    let (report, ()) = serve_scope(pool_cfg(), http_cfg(), |addr, _| {
        let mut s = connect(addr);
        let resp = call(&mut s, b"NOT_A_REQUEST\r\n\r\n");
        assert_eq!(resp.status, 400);
        assert!(!resp.keep_alive);
        let err = body_json(&resp);
        assert_eq!(err.usize_of("status").unwrap(), 400);
        assert!(err.str_of("error").unwrap().contains("request line"));
        // Framing is unrecoverable: the server closes after answering.
        let mut rest = Vec::new();
        assert_eq!(s.read_to_end(&mut rest).unwrap(), 0);
    });
    assert_eq!(report.responses_4xx, 1);
    assert_eq!(report.responses_2xx, 0);
}

#[test]
fn unknown_route_gets_404_and_connection_survives() {
    let (report, ()) = serve_scope(pool_cfg(), http_cfg(), |addr, _| {
        let mut s = connect(addr);
        let resp = call(&mut s, b"GET /nope HTTP/1.1\r\n\r\n");
        assert_eq!(resp.status, 404);
        // 404 is a routing miss, not a framing failure: keep-alive
        // holds and the same connection serves the next request.
        assert!(resp.keep_alive);
        let resp = call(&mut s, b"GET /healthz HTTP/1.1\r\n\r\n");
        assert_eq!(resp.status, 200);
        assert_eq!(body_json(&resp).get("ok").and_then(Json::as_bool), Some(true));
    });
    assert_eq!(report.connections, 1);
    assert_eq!(report.requests, 2);
}

#[test]
fn keep_alive_sequences_and_pipelines() {
    let (report, ()) = serve_scope(pool_cfg(), http_cfg(), |addr, _| {
        let mut s = connect(addr);
        // Three sequential predicts on one connection.
        for id in [1u32, 2, 3] {
            let resp = call(&mut s, &predict_raw(0, id));
            assert_eq!(resp.status, 200, "id {id}");
            assert!(resp.keep_alive);
            assert_eq!(body_json(&resp).usize_of("id").unwrap(), id as usize);
        }
        // Two requests in one TCP segment: both must be answered, in
        // order, off the same buffered bytes.
        let mut two = Vec::new();
        two.extend_from_slice(b"GET /healthz HTTP/1.1\r\n\r\n");
        two.extend_from_slice(b"GET /info HTTP/1.1\r\n\r\n");
        s.write_all(&two).unwrap();
        let mut buf = Vec::new();
        let first = read_response(&mut s, &mut buf);
        let second = read_response(&mut s, &mut buf);
        assert_eq!(first.status, 200);
        assert_eq!(second.status, 200);
        let info = body_json(&second);
        assert_eq!(info.usize_of("out_dim").unwrap(), 8);
        assert!(info.usize_of("nodes").unwrap() > 0);
    });
    assert_eq!(report.connections, 1);
    assert_eq!(report.requests, 5);
    assert_eq!(report.responses_2xx, 5);
}

#[test]
fn content_length_mismatch_gets_400() {
    let (report, ()) = serve_scope(pool_cfg(), http_cfg(), |addr, _| {
        let mut s = connect(addr);
        // Promise 50 body bytes, deliver 5, hang up the write side:
        // the server sees EOF with a partial message and must answer
        // deterministically instead of hanging or dropping silently.
        s.write_all(b"POST /predict HTTP/1.1\r\nContent-Length: 50\r\n\r\nhello").unwrap();
        s.shutdown(Shutdown::Write).unwrap();
        let resp = read_response(&mut s, &mut Vec::new());
        assert_eq!(resp.status, 400);
        assert!(body_json(&resp).str_of("error").unwrap().contains("incomplete"));
    });
    assert_eq!(report.responses_4xx, 1);
}

#[test]
fn oversized_body_gets_413_before_the_body_is_read() {
    let (report, ()) = serve_scope(pool_cfg(), http_cfg(), |addr, _| {
        let mut s = connect(addr);
        // Head only — the declared length alone must trip the limit.
        s.write_all(b"POST /predict HTTP/1.1\r\nContent-Length: 999999\r\n\r\n").unwrap();
        let resp = read_response(&mut s, &mut Vec::new());
        assert_eq!(resp.status, 413);
        assert!(!resp.keep_alive);
        assert!(body_json(&resp).str_of("error").unwrap().contains("exceeds"));
    });
    assert_eq!(report.responses_4xx, 1);
}

#[test]
fn bad_predict_bodies_get_400_not_truncation() {
    let (_, ()) = serve_scope(pool_cfg(), http_cfg(), |addr, _| {
        let mut s = connect(addr);
        for (body, needle) in [
            ("{\"id\": 2.7}", "integer 'id'"),    // strict as_usize: no silent floor
            ("{\"id\": -1}", "integer 'id'"),
            ("not json", "valid JSON"),
            ("{\"id\": 999999999}", "out of range"),
            ("{\"id\": 1, \"nt\": 99}", "unknown node type"),
        ] {
            let raw = format!(
                "POST /predict HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let resp = call(&mut s, raw.as_bytes());
            assert_eq!(resp.status, 400, "body {body}");
            let err = body_json(&resp);
            assert!(
                err.str_of("error").unwrap().contains(needle),
                "body {body}: {}",
                err.str_of("error").unwrap()
            );
        }
    });
}

#[test]
fn queue_pressure_sheds_with_429_and_keeps_serving() {
    // queue_depth 1 + a 100ms batch deadline: the first miss sits in
    // the forming batch holding the only queue slot, so concurrent
    // distinct requests landing inside the window are shed with 429 at
    // the queue boundary (never a hang, never a 5xx).
    let pool = EnginePoolCfg {
        workers: 1,
        queue_depth: 1,
        batcher: MicroBatcherCfg { max_batch: 32, deadline: Duration::from_millis(100) },
        ..Default::default()
    };
    let (report, (ok, shed)) = serve_scope(pool, http_cfg(), |addr, _| {
        let results = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for id in 0..6u32 {
                let results = &results;
                scope.spawn(move || {
                    let mut s = connect(addr);
                    let resp = call(&mut s, &predict_raw(0, 40 + id));
                    results.lock().unwrap().push(resp.status);
                });
            }
        });
        let statuses = results.into_inner().unwrap();
        assert_eq!(statuses.len(), 6);
        let ok = statuses.iter().filter(|&&s| s == 200).count();
        let shed = statuses.iter().filter(|&&s| s == 429).count();
        assert_eq!(ok + shed, 6, "only 200/429 expected, got {statuses:?}");
        assert!(ok >= 1, "at least the slot-holder is served: {statuses:?}");
        assert!(shed >= 1, "concurrent arrivals inside the 100ms batch window must shed: {statuses:?}");
        (ok, shed)
    });
    assert_eq!(report.responses_2xx, ok as u64);
    assert_eq!(report.responses_429, shed as u64);
    assert_eq!(report.responses_5xx + report.responses_503, 0);
}

#[test]
fn socket_replies_are_bit_identical_to_the_engine() {
    let (_, ()) = serve_scope(pool_cfg(), http_cfg(), |addr, engine| {
        // In-process ground truth, computed on a private scratch.
        let mut sc = engine.make_scratch();
        let expected = engine.predict_one(&mut sc, 0, 17).unwrap();

        let mut s = connect(addr);
        let raw = predict_raw(0, 17);
        // Repeated identical request ⇒ byte-identical reply (BTreeMap
        // key order + shortest-round-trip floats + Content-Length
        // framing pin every byte).
        s.write_all(&raw).unwrap();
        let mut buf = Vec::new();
        let mut chunk = [0u8; 4096];
        let first_bytes = loop {
            match parse_response(&buf, 1 << 20) {
                Parse::Ready(_, used) => break buf.drain(..used).collect::<Vec<u8>>(),
                Parse::Incomplete => {
                    let n = s.read(&mut chunk).unwrap();
                    assert!(n > 0);
                    buf.extend_from_slice(&chunk[..n]);
                }
                Parse::Bad(b) => panic!("{}", b.message()),
            }
        };
        s.write_all(&raw).unwrap();
        let second_bytes = loop {
            match parse_response(&buf, 1 << 20) {
                Parse::Ready(_, used) => break buf.drain(..used).collect::<Vec<u8>>(),
                Parse::Incomplete => {
                    let n = s.read(&mut chunk).unwrap();
                    assert!(n > 0);
                    buf.extend_from_slice(&chunk[..n]);
                }
                Parse::Bad(b) => panic!("{}", b.message()),
            }
        };
        assert_eq!(first_bytes, second_bytes, "replies must be byte-identical");

        // And the payload carries the engine's f32 bits exactly:
        // f32 → f64 → shortest-round-trip text → f64 → f32 is lossless.
        let Parse::Ready(resp, _) = parse_response(&first_bytes, 1 << 20) else {
            panic!("reparse")
        };
        assert_eq!(resp.status, 200);
        let json = body_json(&resp);
        let row: Vec<f32> = json
            .get("row")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(row.len(), expected.len());
        for (i, (a, b)) in row.iter().zip(&expected).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "row[{i}]: {a} vs {b}");
        }
    });
}

#[test]
fn shutdown_drains_in_flight_requests() {
    let (report, ()) = serve_scope(pool_cfg(), http_cfg(), |addr, _| {
        let mut s = connect(addr);
        let resp = call(&mut s, &predict_raw(0, 5));
        assert_eq!(resp.status, 200);
        // POST /shutdown answers 200 and withdraws keep-alive: the
        // drain is visible on the very reply that acknowledges it.
        let resp = call(&mut s, b"POST /shutdown HTTP/1.1\r\nContent-Length: 0\r\n\r\n");
        assert_eq!(resp.status, 200);
        assert_eq!(body_json(&resp).get("draining").and_then(Json::as_bool), Some(true));
        assert!(!resp.keep_alive);
    });
    // The wake-up connection from trigger() is never counted: the
    // acceptor checks the stop flag before accounting.
    assert_eq!(report.connections, 1);
    assert_eq!(report.responses_2xx, 2);
}
