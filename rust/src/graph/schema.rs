//! Graph schema: node types, edge types, feature sources.

/// One edge type: `(src_ntype, name, dst_ntype)` triple, by type index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EdgeTypeDef {
    pub name: String,
    pub src_ntype: usize,
    pub dst_ntype: usize,
}

/// The feature source a node type feeds into the model's input encoder
/// (DESIGN.md §4: dense features, LM text embeddings, or the learnable
/// embedding table for featureless types).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FeatureSource {
    #[default]
    Dense,
    Text,
    /// Featureless: rows come from the distributed embedding table.
    Learnable,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    pub ntypes: Vec<String>,
    pub etypes: Vec<EdgeTypeDef>,
    /// Per-ntype feature source (defaults to Dense).
    pub feature_sources: Vec<FeatureSource>,
}

impl Schema {
    pub fn new(ntypes: Vec<String>, etypes: Vec<EdgeTypeDef>) -> Schema {
        let n = ntypes.len();
        for e in &etypes {
            assert!(e.src_ntype < n && e.dst_ntype < n, "etype references unknown ntype");
        }
        Schema { ntypes, etypes, feature_sources: vec![FeatureSource::Dense; n] }
    }

    pub fn with_sources(mut self, sources: Vec<FeatureSource>) -> Schema {
        assert_eq!(sources.len(), self.ntypes.len());
        self.feature_sources = sources;
        self
    }

    pub fn ntype_id(&self, name: &str) -> Option<usize> {
        self.ntypes.iter().position(|n| n == name)
    }

    pub fn etype_id(&self, name: &str) -> Option<usize> {
        self.etypes.iter().position(|e| e.name == name)
    }

    /// Add the reverse of every edge type (GraphStorm's `rev-` edges) so
    /// messages flow both directions during sampling.  Skips self-symmetric
    /// types that already have a reverse.
    pub fn add_reverse_etypes(&mut self) -> Vec<(usize, usize)> {
        let orig = self.etypes.clone();
        let mut mapping = vec![];
        for (i, e) in orig.iter().enumerate() {
            let rev_name = format!("rev-{}", e.name);
            if self.etype_id(&rev_name).is_some() {
                continue;
            }
            self.etypes.push(EdgeTypeDef {
                name: rev_name,
                src_ntype: e.dst_ntype,
                dst_ntype: e.src_ntype,
            });
            mapping.push((i, self.etypes.len() - 1));
        }
        mapping
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reverse_etypes() {
        let mut s = Schema::new(
            vec!["paper".into(), "author".into()],
            vec![
                EdgeTypeDef { name: "writes".into(), src_ntype: 1, dst_ntype: 0 },
                EdgeTypeDef { name: "cites".into(), src_ntype: 0, dst_ntype: 0 },
            ],
        );
        let map = s.add_reverse_etypes();
        assert_eq!(map.len(), 2);
        let rev = s.etype_id("rev-writes").unwrap();
        assert_eq!(s.etypes[rev].src_ntype, 0);
        assert_eq!(s.etypes[rev].dst_ntype, 1);
    }

    #[test]
    fn lookup() {
        let s = Schema::new(vec!["item".into()], vec![]);
        assert_eq!(s.ntype_id("item"), Some(0));
        assert_eq!(s.ntype_id("nope"), None);
    }
}
