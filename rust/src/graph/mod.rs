//! Heterogeneous graph store: schema, per-edge-type CSR/CSC adjacency.
//!
//! The in-memory analogue of DistDGL's graph structure: nodes are
//! `(ntype, local_id)` pairs, edges live in per-edge-type lists with
//! CSC (in-edge) indexes for on-the-fly inbound neighbor sampling.

pub mod schema;

pub use schema::{EdgeTypeDef, FeatureSource, Schema};

/// Compressed sparse rows over one edge type.
#[derive(Debug, Clone, Default)]
pub struct Csr {
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
}

impl Csr {
    /// Build from parallel (key, value) slices where every key < n_keys.
    /// Slices instead of a `Clone` iterator: the counting and filling
    /// passes index the same memory, so full edge lists are never
    /// traversed twice through iterator re-evaluation during graph
    /// build.
    pub fn from_pairs(n_keys: usize, keys: &[u32], vals: &[u32]) -> Csr {
        assert_eq!(keys.len(), vals.len());
        let mut counts = vec![0usize; n_keys + 1];
        for &k in keys {
            counts[k as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let mut indices = vec![0u32; counts[n_keys]];
        let mut cursor = counts.clone();
        for (&k, &v) in keys.iter().zip(vals) {
            indices[cursor[k as usize]] = v;
            cursor[k as usize] += 1;
        }
        Csr { indptr: counts, indices }
    }

    #[inline]
    pub fn neighbors(&self, key: usize) -> &[u32] {
        &self.indices[self.indptr[key]..self.indptr[key + 1]]
    }

    #[inline]
    pub fn degree(&self, key: usize) -> usize {
        self.indptr[key + 1] - self.indptr[key]
    }
}

/// One edge type's storage: raw edge list + in/out CSR indexes.
#[derive(Debug, Clone, Default)]
pub struct EdgeStore {
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    /// in-CSC: for each dst node, incoming src neighbors (sampling path).
    pub in_csr: Csr,
    /// out-CSR: for each src node, outgoing dst neighbors.
    pub out_csr: Csr,
}

/// Heterogeneous graph: schema + per-ntype node counts + per-etype edges.
#[derive(Debug, Clone)]
pub struct HeteroGraph {
    pub schema: Schema,
    pub num_nodes: Vec<usize>,
    pub edges: Vec<EdgeStore>,
}

impl HeteroGraph {
    pub fn new(schema: Schema, num_nodes: Vec<usize>) -> HeteroGraph {
        assert_eq!(schema.ntypes.len(), num_nodes.len());
        let n_et = schema.etypes.len();
        HeteroGraph { schema, num_nodes, edges: vec![EdgeStore::default(); n_et] }
    }

    /// Set one edge type's edge list and build its indexes.
    /// Panics on out-of-range endpoints (construction-time invariant).
    pub fn set_edges(&mut self, etype: usize, src: Vec<u32>, dst: Vec<u32>) {
        assert_eq!(src.len(), dst.len());
        let def = &self.schema.etypes[etype];
        let n_src = self.num_nodes[def.src_ntype];
        let n_dst = self.num_nodes[def.dst_ntype];
        debug_assert!(src.iter().all(|&s| (s as usize) < n_src), "src id out of range");
        debug_assert!(dst.iter().all(|&d| (d as usize) < n_dst), "dst id out of range");
        let in_csr = Csr::from_pairs(n_dst, &dst, &src);
        let out_csr = Csr::from_pairs(n_src, &src, &dst);
        self.edges[etype] = EdgeStore { src, dst, in_csr, out_csr };
    }

    pub fn num_edges(&self, etype: usize) -> usize {
        self.edges[etype].src.len()
    }

    pub fn total_nodes(&self) -> usize {
        self.num_nodes.iter().sum()
    }

    pub fn total_edges(&self) -> usize {
        self.edges.iter().map(|e| e.src.len()).sum()
    }

    /// Edge types whose destination is `ntype` (inbound message sources).
    pub fn etypes_into(&self, ntype: usize) -> Vec<usize> {
        (0..self.schema.etypes.len())
            .filter(|&e| self.schema.etypes[e].dst_ntype == ntype)
            .collect()
    }

    /// Paper-Table-1-style statistics row.
    pub fn stats(&self) -> GraphStats {
        GraphStats {
            num_nodes: self.total_nodes(),
            num_edges: self.total_edges(),
            num_ntypes: self.schema.ntypes.len(),
            num_etypes: self.schema.etypes.len(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphStats {
    pub num_nodes: usize,
    pub num_edges: usize,
    pub num_ntypes: usize,
    pub num_etypes: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> HeteroGraph {
        let schema = Schema::new(
            vec!["a".into(), "b".into()],
            vec![EdgeTypeDef { name: "ab".into(), src_ntype: 0, dst_ntype: 1 }],
        );
        let mut g = HeteroGraph::new(schema, vec![3, 2]);
        g.set_edges(0, vec![0, 1, 2, 0], vec![0, 0, 1, 1]);
        g
    }

    #[test]
    fn csr_inverts_edge_list() {
        let g = toy();
        let es = &g.edges[0];
        assert_eq!(es.in_csr.neighbors(0), &[0, 1]);
        assert_eq!(es.in_csr.neighbors(1), &[2, 0]);
        assert_eq!(es.out_csr.neighbors(0), &[0, 1]);
        assert_eq!(es.out_csr.degree(1), 1);
    }

    #[test]
    fn csr_csc_transpose_involution() {
        // Rebuilding the edge list from in_csr must reproduce out_csr.
        let g = toy();
        let es = &g.edges[0];
        let (mut keys, mut vals) = (vec![], vec![]);
        for d in 0..g.num_nodes[1] {
            for &s in es.in_csr.neighbors(d) {
                keys.push(s);
                vals.push(d as u32);
            }
        }
        let rebuilt = Csr::from_pairs(g.num_nodes[0], &keys, &vals);
        let mut a: Vec<Vec<u32>> = (0..3).map(|s| rebuilt.neighbors(s).to_vec()).collect();
        let mut b: Vec<Vec<u32>> = (0..3).map(|s| es.out_csr.neighbors(s).to_vec()).collect();
        for (x, y) in a.iter_mut().zip(b.iter_mut()) {
            x.sort();
            y.sort();
        }
        assert_eq!(a, b);
    }

    #[test]
    fn stats_counts() {
        let g = toy();
        let s = g.stats();
        assert_eq!(s.num_nodes, 5);
        assert_eq!(s.num_edges, 4);
        assert_eq!((s.num_ntypes, s.num_etypes), (2, 1));
    }
}
