//! `gs` — the GraphStorm-rs command line (paper Appendix B).
//!
//!   gs gconstruct --conf schema.json --dir DATA [--num-parts N] [--metis]
//!   gs gen-data   --dataset mag|amazon|scale-free [--size N]
//!   gs train-nc   --dataset mag|amazon [--arch rgcn] [--epochs E] [--num-parts N]
//!   gs train-lp   --dataset amazon [--loss contrastive|ce] [--neg joint-32|...]
//!   gs smoke      # runtime sanity check
//!
//! Argument parsing is hand-rolled (offline build — DESIGN.md §1).

use anyhow::{bail, Context, Result};
use graphstorm::datagen::{amazon, mag, scale_free};
use graphstorm::dataloader::{GsDataset, PrefetchConfig};
use graphstorm::partition::{metis_like_partition, random_partition, PartitionBook};
use graphstorm::runtime::Runtime;
use graphstorm::sampling::NegSampler;
use graphstorm::serve::{
    cache_key, closed_loop, EmbeddingCache, InferenceEngine, MicroBatcherCfg, OfflineInference,
    Zipf,
};
use graphstorm::trainer::lp::LpLoss;
use graphstorm::trainer::{LmTrainer, LpTrainer, NodeTrainer, TrainOptions};
use graphstorm::util::Rng;

struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = std::collections::HashMap::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = it.next().unwrap_or_else(|| "true".to_string());
                flags.insert(name.to_string(), val);
            } else {
                bail!("unexpected argument '{a}'");
            }
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn parse_neg(s: &str) -> Result<NegSampler> {
    if s == "in-batch" {
        return Ok(NegSampler::InBatch { k: 32 });
    }
    let (kind, k) = s.rsplit_once('-').context("neg sampler like joint-32")?;
    let k: usize = k.parse()?;
    Ok(match kind {
        "joint" => NegSampler::Joint { k },
        "local-joint" => NegSampler::LocalJoint { k },
        "uniform" => NegSampler::Uniform { k },
        _ => bail!("unknown sampler '{kind}'"),
    })
}

fn make_dataset(args: &Args) -> Result<GsDataset> {
    let n_parts = args.get_usize("num-parts", 1);
    let seed = args.get_usize("seed", 7) as u64;
    let raw = match args.get("dataset", "mag").as_str() {
        "mag" => mag::generate(&mag::MagConfig {
            n_papers: args.get_usize("size", 4000),
            ..Default::default()
        }),
        "amazon" => {
            let world = amazon::generate_world(&amazon::ArConfig {
                n_items: args.get_usize("size", 3000),
                ..Default::default()
            });
            amazon::build_variant(&world, amazon::ArVariant::HeteroV2)
        }
        "scale-free" => scale_free::generate(&scale_free::ScaleFreeConfig {
            n_edges: args.get_usize("size", 100_000),
            ..Default::default()
        }),
        other => bail!("unknown dataset '{other}'"),
    };
    let book = if n_parts <= 1 {
        PartitionBook::single(&raw.graph.num_nodes)
    } else if args.flags.contains_key("metis") {
        metis_like_partition(&raw.graph, n_parts, seed)
    } else {
        random_partition(&raw.graph, n_parts, seed)
    };
    let mut ds = graphstorm::datagen::build_dataset(raw, book, 64, seed);
    // Without an LM stage, text nodes get hashed bag-of-tokens features.
    ds.ensure_text_features(64);
    Ok(ds)
}

/// The serving engine for a dataset: the real `{arch}_nc_logits`
/// artifact when PJRT can execute it, else the deterministic surrogate
/// over a synthetic spec — so `infer` / `serve-bench` run end-to-end
/// on machines without artifacts (execution gated as everywhere else).
fn serve_engine<'a>(args: &Args, ds: &'a GsDataset) -> Result<(InferenceEngine<'a>, &'static str)> {
    InferenceEngine::auto(
        ds,
        &args.get("arch", "rgcn"),
        args.get_usize("out-dim", 8),
        args.get_usize("seed", 7) as u64,
    )
}

fn opts(args: &Args) -> TrainOptions {
    TrainOptions {
        lr: args.get("lr", "3e-3").parse().unwrap_or(3e-3),
        epochs: args.get_usize("epochs", 3),
        seed: args.get_usize("seed", 7) as u64,
        n_workers: args.get_usize("num-parts", 1).max(1),
        loader_workers: args.get_usize("num-workers", 1).max(1),
        prefetch: args.get_usize("prefetch", 2).max(1),
        log_every: 0,
        verbose: true,
    }
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "smoke" => {
            let rt = Runtime::from_default_dir()?;
            let exe = rt.load("smoke")?;
            println!(
                "platform={} artifacts ok ({} outputs)",
                rt.client.platform_name(),
                exe.spec.outputs.len()
            );
        }
        "gen-data" => {
            let ds = make_dataset(&args)?;
            let s = ds.graph.stats();
            println!(
                "dataset={} nodes={} edges={} ntypes={} etypes={}",
                args.get("dataset", "mag"),
                s.num_nodes,
                s.num_edges,
                s.num_ntypes,
                s.num_etypes
            );
        }
        "gconstruct" => {
            let conf = args.get("conf", "schema.json");
            let dir = args.get("dir", ".");
            let cfg = graphstorm::gconstruct::GConstructConfig::load(std::path::Path::new(&conf))?;
            let ds = graphstorm::gconstruct::construct_dataset(
                &cfg,
                std::path::Path::new(&dir),
                args.get_usize("num-parts", 1),
                args.flags.contains_key("metis"),
            )?;
            let s = ds.graph.stats();
            println!(
                "constructed: nodes={} edges={} ntypes={} etypes={} parts={}",
                s.num_nodes, s.num_edges, s.num_ntypes, s.num_etypes, ds.engine.book.n_parts
            );
        }
        "train-nc" => {
            let rt = Runtime::from_default_dir()?;
            let mut ds = make_dataset(&args)?;
            let arch = args.get("arch", "rgcn");
            // Optional LM stage: --lm pretrained|finetuned|none
            let lm_mode = args.get("lm", "none");
            if lm_mode != "none" {
                let lm = LmTrainer::default();
                let o = opts(&args);
                let (_, st) = lm.pretrain_mlm(
                    &rt,
                    &ds,
                    ds.target_ntype,
                    &TrainOptions { epochs: 1, ..o.clone() },
                )?;
                let params = if lm_mode == "finetuned" {
                    let (_, st2) = lm.finetune_nc(
                        &rt,
                        &ds,
                        &st.params_host()?,
                        &TrainOptions { epochs: 2, ..o.clone() },
                    )?;
                    st2.params_host()?
                } else {
                    st.params_host()?
                };
                let secs = lm.embed_all(&rt, &mut ds, &params, &o)?;
                println!("lm embed stage: {secs:.1}s");
            }
            let trainer =
                NodeTrainer::new(&format!("{arch}_nc_train"), &format!("{arch}_nc_logits"));
            let (report, st) = trainer.fit(&rt, &mut ds, &opts(&args))?;
            println!(
                "val_acc={:.4} test_acc={:.4} losses={:?}",
                report.val_acc, report.test_acc, report.epoch_losses
            );
            if let Some(path) = args.flags.get("save-model-path") {
                st.save(std::path::Path::new(path))?;
                println!("saved model to {path}");
            }
        }
        "train-lp" => {
            let rt = Runtime::from_default_dir()?;
            let mut ds = make_dataset(&args)?;
            let loss = match args.get("loss", "contrastive").as_str() {
                "contrastive" => LpLoss::Contrastive,
                "ce" | "cross-entropy" => LpLoss::CrossEntropy,
                other => bail!("unknown loss '{other}'"),
            };
            let neg = parse_neg(&args.get("neg", "joint-32"))?;
            let artifact = match neg {
                NegSampler::Uniform { k } => format!("rgcn_lp_uniform_k{k}_train"),
                s => format!("rgcn_lp_joint_k{}_train", s.k()),
            };
            let mut trainer = LpTrainer::new(&artifact, "rgcn_lp_emb", loss, neg);
            trainer.max_train_edges = Some(args.get_usize("max-edges-per-epoch", 3200));
            let (report, _) = trainer.fit(&rt, &mut ds, &opts(&args))?;
            println!(
                "val_mrr={:.4} test_mrr={:.4} best_epoch={} epoch_time={:.1}s",
                report.val_mrr,
                report.test_mrr,
                report.best_epoch,
                report.epoch_times.iter().sum::<f64>() / report.epoch_times.len().max(1) as f64
            );
        }
        "infer" => {
            // Offline full-graph inference: stream every node of the
            // target type through the engine and write GSTF shards
            // (the precompute the serving cache warms from).
            let ds = make_dataset(&args)?;
            let (engine, backend) = serve_engine(&args, &ds)?;
            let out = args.get("out", "offline_emb");
            let off = OfflineInference {
                shard_size: args.get_usize("shard-size", 4096),
                prefetch: PrefetchConfig {
                    n_workers: args.get_usize("num-workers", 1).max(1),
                    depth: args.get_usize("prefetch", 2).max(1),
                },
            };
            let ntype = args.get_usize("ntype", ds.target_ntype) as u32;
            let rep = off.run(&engine, ntype, std::path::Path::new(&out))?;
            println!(
                "offline inference [{backend}]: {} rows x {} dims in {:.2}s ({:.0} rows/s) -> {} shards under {out}",
                rep.rows,
                rep.dim,
                rep.secs,
                rep.rows as f64 / rep.secs.max(1e-9),
                rep.shards.len(),
            );
        }
        "serve-bench" => {
            // Closed-loop synthetic serving traffic (Zipf-distributed
            // seeds) through the micro-batcher: an uncached arm, then
            // a warmed-cache arm over the same trace; predictions must
            // be bit-identical across arms.
            let ds = make_dataset(&args)?;
            let (engine, backend) = serve_engine(&args, &ds)?;
            let seed = args.get_usize("seed", 7) as u64;
            let n_req = args.get_usize("requests", 4000);
            let alpha: f64 = args.get("alpha", "1.1").parse().unwrap_or(1.1);
            let clients = args.get_usize("clients", 4);
            let cap = args.get_usize("cache", 4096);
            let cfg = MicroBatcherCfg {
                max_batch: args.get_usize("max-batch", 32),
                deadline: std::time::Duration::from_micros(
                    args.get_usize("deadline-us", 200) as u64
                ),
            };
            let nt = ds.target_ntype as u32;
            let n_nodes = ds.graph.num_nodes[nt as usize];
            let zipf = Zipf::new(n_nodes, alpha);
            let mut rng = Rng::seed_from(seed ^ 0x5e12);
            let trace: Vec<(u32, u32)> =
                (0..n_req).map(|_| (nt, zipf.sample(&mut rng) as u32)).collect();
            println!(
                "serve-bench [{backend}]: {n_req} requests, zipf(a={alpha}) over {n_nodes} nodes, {clients} clients, max_batch={}, deadline={}us",
                cfg.max_batch,
                cfg.deadline.as_micros()
            );

            let mut nocache = EmbeddingCache::new(0);
            let (s0, replies0) = closed_loop(&engine, cfg.clone(), &mut nocache, &trace, clients)?;
            println!(
                "  uncached: p50 {:>7.0}us  p99 {:>7.0}us  {:>8.0} req/s  hit {:>5.1}%",
                s0.p50_us, s0.p99_us, s0.rps, 100.0 * s0.hit_rate
            );

            // Warm the cache with the canonical prediction of every
            // distinct node in the trace (what `gs infer` shards
            // hold), batching distinct seeds to engine capacity —
            // canonical sampling makes the batched rows bit-identical
            // to per-node recompute.
            let mut cache = EmbeddingCache::new(cap);
            cache.set_generation(engine.generation());
            let mut sc = engine.make_scratch();
            let mut seen = std::collections::HashSet::new();
            let distinct: Vec<(u32, u32)> =
                trace.iter().filter(|&&p| seen.insert(p)).copied().collect();
            let c = engine.out_dim();
            for chunk in distinct.chunks(engine.capacity()) {
                let rows = engine.forward(&mut sc, chunk)?;
                for (i, &(nt, id)) in chunk.iter().enumerate() {
                    cache.put(cache_key(nt, id), &rows[i * c..(i + 1) * c]);
                }
            }
            let (s1, replies1) = closed_loop(&engine, cfg, &mut cache, &trace, clients)?;
            println!(
                "  warmed:   p50 {:>7.0}us  p99 {:>7.0}us  {:>8.0} req/s  hit {:>5.1}%  (cache cap {cap}, {} distinct)",
                s1.p50_us, s1.p99_us, s1.rps, 100.0 * s1.hit_rate, seen.len()
            );

            let mut expected: std::collections::HashMap<(u32, u32), Vec<f32>> =
                std::collections::HashMap::new();
            let mut identical = true;
            for (k, v) in replies0.into_iter().chain(replies1) {
                identical &= expected.entry(k).or_insert_with(|| v.clone()) == &v;
            }
            println!(
                "  bit-identical across arms + repeats: {identical}; warmed speedup {:.2}x",
                s1.rps / s0.rps.max(1e-9)
            );
            if !identical {
                bail!("cached serving diverged from uncached recompute");
            }
        }
        _ => {
            println!("gs — GraphStorm-rs (see README.md)\n");
            println!("  gs smoke");
            println!("  gs gen-data --dataset mag|amazon|scale-free [--size N]");
            println!("  gs gconstruct --conf schema.json --dir DATA [--num-parts N] [--metis]");
            println!("  gs train-nc --dataset mag [--arch rgcn|gcn|sage|gat|rgat|hgt] [--lm none|pretrained|finetuned]");
            println!("  gs train-lp --dataset amazon [--loss contrastive|ce] [--neg in-batch|joint-K|uniform-K]");
            println!("  gs infer --dataset mag [--out DIR] [--shard-size N]   offline full-graph inference shards");
            println!("  gs serve-bench --dataset mag [--requests N] [--alpha A] [--clients C]");
            println!("              [--cache CAP] [--max-batch B] [--deadline-us US]");
            println!("              closed-loop Zipf traffic through the micro-batcher + embedding cache");
            println!("  common:     [--num-workers N] [--prefetch D]   pipelined batch building");
            println!("              (N loader threads sample+assemble ahead of the device step;");
            println!("               output is bit-identical for any N — default 1 = serial)");
        }
    }
    Ok(())
}
