//! `gs` — the GraphStorm-rs command line (paper §2 / Appendix B).
//!
//! The CLI is a thin shell over the declarative run-config API
//! (`graphstorm::config`): a JSON file declares the whole pipeline
//!
//!   gs run --conf examples/pipeline_nc.json [--set stage.key=value]
//!
//! and every classic subcommand (`gen-data`, `train-nc`, `train-lp`,
//! `distill`, `infer`, `serve-bench`, `gconstruct`) is an adapter that
//! builds the same config from flags — each flag is just an override
//! path into the document, so defaults live in exactly one place (the
//! config structs) and a typo'd flag or config key is a hard error
//! with a suggestion.  `gs validate-conf` dry-runs a file and prints
//! the fully-resolved config.  See docs/CONFIG.md for the schema.

use anyhow::{bail, Result};
use graphstorm::config::{cli, Pipeline};
use graphstorm::runtime::Runtime;

// Allocation profiling (`gs ... --stats` reports alloc.count /
// alloc.bytes) — opt-in because the hooks cost an atomic RMW per
// allocation:  cargo build --release --features count-alloc
#[cfg(feature = "count-alloc")]
#[global_allocator]
static GLOBAL: graphstorm::obs::CountingAlloc = graphstorm::obs::CountingAlloc;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{}", cli::help_text());
        }
        "smoke" => {
            let rt = Runtime::from_default_dir()?;
            let exe = rt.load("smoke")?;
            println!(
                "platform={} artifacts ok ({} outputs)",
                rt.client.platform_name(),
                exe.spec.outputs.len()
            );
        }
        // Observability report commands (docs/OBSERVABILITY.md):
        // render a metrics snapshot / validate a trace file.
        "stats" => {
            let Some(path) = rest.first() else {
                bail!("usage: gs stats PATH (a metrics snapshot from --report or --stats)");
            };
            print!("{}", graphstorm::obs::metrics::render_file(path)?);
        }
        // Static-analysis gate over the repo's own source tree
        // (docs/LINTS.md) — the blocking lint in scripts/test.sh.
        "lint" => {
            graphstorm::lint::run_cli(rest)?;
        }
        "trace-check" => {
            let Some(path) = rest.first() else {
                bail!("usage: gs trace-check PATH (a JSONL trace from --trace)");
            };
            let n = graphstorm::obs::validate_jsonl(path)?;
            println!("{path}: {n} events, schema ok");
        }
        "validate-conf" => {
            let spec = cli::find_command("validate-conf")?;
            let cfg = cli::build_config(spec, rest)?.resolved();
            println!("stages: {}", cfg.stage_names().join(" -> "));
            println!("{}", cfg.to_json().to_string_pretty());
        }
        // The HTTP front end blocks until drained, so it gets its own
        // arm instead of a pipeline stage (docs/SERVING.md).
        "serve" => {
            let spec = cli::find_command("serve")?;
            let cfg = cli::build_config(spec, rest)?;
            let pipeline = Pipeline::new(cfg)?;
            run_http_serve(&pipeline)?;
        }
        "load-bench" => {
            let spec = cli::find_command("load-bench")?;
            let cfg = cli::build_config(spec, rest)?;
            cfg.validate()?;
            let addr = cli::flag_value(spec, rest, "addr")?
                .unwrap_or_else(|| "127.0.0.1:8080".to_string());
            let bench_out = cli::flag_value(spec, rest, "bench-out")?;
            let shutdown = cli::flag_value(spec, rest, "shutdown")?.is_some();
            run_http_load(&cfg, addr, shutdown, bench_out.as_deref())?;
        }
        name => {
            let spec = cli::find_command(name)?;
            let cfg = cli::build_config(spec, rest)?;
            let pipeline = Pipeline::new(cfg)?;
            // `gs run --dump-conf PATH` records the fully-resolved
            // config next to the run outputs, for reproducibility.
            if let Some(path) = cli::flag_value(spec, rest, "dump-conf")? {
                let mut body = pipeline.cfg.to_json().to_string_pretty();
                body.push('\n');
                std::fs::write(&path, body)?;
                println!("resolved config -> {path}");
            }
            pipeline.run()?;
        }
    }
    Ok(())
}

/// `gs serve`: build the dataset + engine the same way the `serve`
/// pipeline stage does, then hand them to the HTTP front end until a
/// drain is triggered (`POST /shutdown`).
fn run_http_serve(pipeline: &Pipeline) -> Result<()> {
    use graphstorm::serve::{HttpServer, InferenceEngine, ShardedCache};
    let cfg = &pipeline.cfg;
    let Some(sc) = &cfg.serve else {
        bail!("'gs serve' needs a serve stage in the config");
    };
    let Some(hc) = &sc.http else {
        bail!("'gs serve' needs a serve.http object (pass --listen ADDR)");
    };
    graphstorm::obs::init(&cfg.obs);
    graphstorm::obs::metrics::reset();
    let ds = pipeline.build_dataset()?;
    let arch = sc.arch.as_deref().expect("resolved() fills serve.arch");
    let (engine, backend) = InferenceEngine::auto(&ds, arch, sc.out_dim, cfg.seed)?;
    let cache = ShardedCache::with_admission(sc.cache, sc.shards, sc.admission);
    cache.set_generation(engine.generation());
    let pool = sc.pool();
    let server = HttpServer::bind(hc.server_cfg())?;
    // The smoke gate greps for this line to learn the ephemeral port —
    // keep the "listening on ADDR" shape.
    println!(
        "serve [{backend}]: listening on {} ({} http workers, pool={} workers x {} sessions, cache={} rows x {} shards)",
        server.local_addr(),
        hc.workers,
        pool.workers,
        pool.sessions,
        sc.cache,
        sc.shards,
    );
    let rep = server.serve(&engine, &cache, pool)?;
    println!(
        "serve: drained after {} connections, {} requests (2xx {} | 4xx {} | 429 {} | 5xx {} | 503 {})",
        rep.connections,
        rep.requests,
        rep.responses_2xx,
        rep.responses_4xx,
        rep.responses_429,
        rep.responses_5xx,
        rep.responses_503,
    );
    if cfg.obs.stats {
        print!(
            "{}",
            graphstorm::obs::metrics::render_table(&graphstorm::obs::metrics::snapshot())
        );
    }
    let n = graphstorm::obs::finish(&cfg.obs)?;
    if n > 0 {
        if let Some(p) = &cfg.obs.trace {
            println!("trace: {n} events -> {p}");
        }
    }
    Ok(())
}

/// `gs load-bench`: replay the canonical Zipf trace over N persistent
/// HTTP connections and (optionally) merge `http_*` results into a
/// BENCH_serve.json.
fn run_http_load(
    cfg: &graphstorm::config::RunConfig,
    addr: String,
    shutdown: bool,
    bench_out: Option<&str>,
) -> Result<()> {
    use graphstorm::serve::{run_load_bench, LoadBenchCfg};
    use graphstorm::util::json::{obj, Json};
    let Some(sc) = &cfg.serve else {
        bail!("'gs load-bench' needs a serve stage in the config");
    };
    // Client-side reply timeout: at least 10s — a saturated closed
    // loop legitimately queues longer than the server's socket knobs.
    let read_timeout_ms =
        sc.http.as_ref().map(|h| h.read_timeout_ms).unwrap_or(5000).max(10_000);
    let lcfg = LoadBenchCfg {
        addr,
        connections: sc.clients,
        requests: sc.requests,
        alpha: sc.alpha,
        seed: cfg.seed,
        shutdown,
        read_timeout: std::time::Duration::from_millis(read_timeout_ms),
    };
    println!(
        "load-bench: {} requests, zipf(a={}) over {} connections against {}{}",
        lcfg.requests,
        lcfg.alpha,
        lcfg.connections,
        lcfg.addr,
        if shutdown { ", then drain" } else { "" },
    );
    let rep = run_load_bench(&lcfg)?;
    println!(
        "  {:>8.0} req/s  p50 {:>7.0}us  p99 {:>7.0}us  ({:.2}s wall)",
        rep.rps, rep.p50_us, rep.p99_us, rep.wall_s,
    );
    println!(
        "  ok {} | 429 {} | 503 {} | 4xx {} | 5xx {} | transport {} | replies bit-identical: {}",
        rep.ok,
        rep.rejected_429,
        rep.rejected_503,
        rep.failed_4xx,
        rep.failed_5xx,
        rep.transport_errors,
        rep.identical,
    );
    if let Some(path) = bench_out {
        // Merge (not overwrite): `scripts/bench_serve.sh` owns the
        // pool_*/shard_* keys of the same file.
        let mut doc = match std::fs::read_to_string(path) {
            Ok(text) => Json::parse(&text)
                .ok()
                .and_then(|j| j.as_obj().cloned())
                .unwrap_or_default(),
            Err(_) => Default::default(),
        };
        let http = obj(vec![
            ("connections", Json::from(rep.connections)),
            ("requests", Json::from(rep.requests)),
            ("wall_s", Json::Num(rep.wall_s)),
            ("rps", Json::Num(rep.rps)),
            ("p50_us", Json::Num(rep.p50_us)),
            ("p99_us", Json::Num(rep.p99_us)),
            ("ok", Json::from(rep.ok as usize)),
            ("rejected_429", Json::from(rep.rejected_429 as usize)),
            ("rejected_503", Json::from(rep.rejected_503 as usize)),
            ("failed_4xx", Json::from(rep.failed_4xx as usize)),
            ("failed_5xx", Json::from(rep.failed_5xx as usize)),
            ("transport_errors", Json::from(rep.transport_errors as usize)),
            ("identical", Json::Bool(rep.identical)),
            ("nodes", Json::from(rep.nodes)),
            ("out_dim", Json::from(rep.out_dim)),
        ]);
        doc.insert("http".to_string(), http);
        let mut body = Json::Obj(doc).to_string_pretty();
        body.push('\n');
        std::fs::write(path, body)?;
        println!("load-bench results -> {path} (key: http)");
    }
    Ok(())
}
