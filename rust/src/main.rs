//! `gs` — the GraphStorm-rs command line (paper §2 / Appendix B).
//!
//! The CLI is a thin shell over the declarative run-config API
//! (`graphstorm::config`): a JSON file declares the whole pipeline
//!
//!   gs run --conf examples/pipeline_nc.json [--set stage.key=value]
//!
//! and every classic subcommand (`gen-data`, `train-nc`, `train-lp`,
//! `distill`, `infer`, `serve-bench`, `gconstruct`) is an adapter that
//! builds the same config from flags — each flag is just an override
//! path into the document, so defaults live in exactly one place (the
//! config structs) and a typo'd flag or config key is a hard error
//! with a suggestion.  `gs validate-conf` dry-runs a file and prints
//! the fully-resolved config.  See docs/CONFIG.md for the schema.

use anyhow::{bail, Result};
use graphstorm::config::{cli, Pipeline};
use graphstorm::runtime::Runtime;

// Allocation profiling (`gs ... --stats` reports alloc.count /
// alloc.bytes) — opt-in because the hooks cost an atomic RMW per
// allocation:  cargo build --release --features count-alloc
#[cfg(feature = "count-alloc")]
#[global_allocator]
static GLOBAL: graphstorm::obs::CountingAlloc = graphstorm::obs::CountingAlloc;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &args[..] } else { &args[1..] };
    match cmd {
        "help" | "--help" | "-h" => {
            println!("{}", cli::help_text());
        }
        "smoke" => {
            let rt = Runtime::from_default_dir()?;
            let exe = rt.load("smoke")?;
            println!(
                "platform={} artifacts ok ({} outputs)",
                rt.client.platform_name(),
                exe.spec.outputs.len()
            );
        }
        // Observability report commands (docs/OBSERVABILITY.md):
        // render a metrics snapshot / validate a trace file.
        "stats" => {
            let Some(path) = rest.first() else {
                bail!("usage: gs stats PATH (a metrics snapshot from --report or --stats)");
            };
            print!("{}", graphstorm::obs::metrics::render_file(path)?);
        }
        // Static-analysis gate over the repo's own source tree
        // (docs/LINTS.md) — the blocking lint in scripts/test.sh.
        "lint" => {
            graphstorm::lint::run_cli(rest)?;
        }
        "trace-check" => {
            let Some(path) = rest.first() else {
                bail!("usage: gs trace-check PATH (a JSONL trace from --trace)");
            };
            let n = graphstorm::obs::validate_jsonl(path)?;
            println!("{path}: {n} events, schema ok");
        }
        "validate-conf" => {
            let spec = cli::find_command("validate-conf")?;
            let cfg = cli::build_config(spec, rest)?.resolved();
            println!("stages: {}", cfg.stage_names().join(" -> "));
            println!("{}", cfg.to_json().to_string_pretty());
        }
        name => {
            let spec = cli::find_command(name)?;
            let cfg = cli::build_config(spec, rest)?;
            let pipeline = Pipeline::new(cfg)?;
            // `gs run --dump-conf PATH` records the fully-resolved
            // config next to the run outputs, for reproducibility.
            if let Some(path) = cli::flag_value(spec, rest, "dump-conf")? {
                let mut body = pipeline.cfg.to_json().to_string_pretty();
                body.push('\n');
                std::fs::write(&path, body)?;
                println!("resolved config -> {path}");
            }
            pipeline.run()?;
        }
    }
    Ok(())
}
