//! Synthetic dataset generators (the proprietary-data substitute,
//! DESIGN.md §1/§5).  Each generator plants the causal mechanism the
//! paper's corresponding experiment measures:
//!
//! * `mag`    — MAG-like citation graph: venue labels recoverable from
//!   text+structure but under-determined by text alone (Table 2 / Fig 5);
//!   featureless authors exercise the embedding table.
//! * `amazon` — Amazon-Review-like: brand from item+review text,
//!   co-purchase generated *through* customer baskets (Table 4 / 6).
//! * `scale_free` — Chung-Lu power-law homogeneous graphs (Table 3).

pub mod amazon;
pub mod mag;
pub mod scale_free;

use std::sync::Arc;

use crate::dataloader::{GsDataset, LpTask, NodeLabels, Split, TokenStore};
use crate::dist::{DistEngine, DistTensor};
use crate::graph::HeteroGraph;
use crate::partition::PartitionBook;
use crate::util::{FxHashMap, Rng};

/// Raw generator output, engine-agnostic.
pub struct RawData {
    pub graph: HeteroGraph,
    /// Per-ntype dense features (empty if none), row-major [n, dim].
    pub features: Vec<(usize, Vec<f32>)>,
    pub labels: Vec<Option<NodeLabels>>,
    pub tokens: Vec<Option<TokenStore>>,
    pub target_ntype: usize,
    pub num_classes: usize,
    pub lp_etype: Option<usize>,
    pub rev_map: FxHashMap<usize, usize>,
}

/// Split assignment: deterministic 80/10/10 by hash.
pub fn make_splits(n: usize, rng: &mut Rng, train: f64, val: f64) -> Vec<Split> {
    (0..n)
        .map(|_| {
            let u = rng.gen_f64();
            if u < train {
                Split::Train
            } else if u < train + val {
                Split::Val
            } else {
                Split::Test
            }
        })
        .collect()
}

/// Bind raw data to a partition book, producing the runnable dataset.
pub fn build_dataset(raw: RawData, book: PartitionBook, lemb_dim: usize, seed: u64) -> GsDataset {
    let book = Arc::new(book);
    let mut engine = DistEngine::new(book.clone(), &raw.graph.num_nodes);
    for (nt, (dim, data)) in raw.features.into_iter().enumerate() {
        if dim > 0 {
            engine.features[nt] = DistTensor::from_data(
                nt,
                dim,
                data,
                book.clone(),
                engine.counters.clone(),
            );
        }
    }
    for (nt, src) in raw.graph.schema.feature_sources.iter().enumerate() {
        if *src == crate::graph::FeatureSource::Learnable {
            engine.add_embed(nt, raw.graph.num_nodes[nt], lemb_dim, seed ^ nt as u64);
        }
    }
    let lp = raw.lp_etype.map(|et| {
        let n = raw.graph.num_edges(et);
        let mut rng = Rng::seed_from(seed ^ 0x1b);
        LpTask { etype: et, split: make_splits(n, &mut rng, 0.9, 0.05) }
    });
    GsDataset {
        graph: raw.graph,
        engine,
        labels: raw.labels,
        tokens: raw.tokens,
        target_ntype: raw.target_ntype,
        num_classes: raw.num_classes,
        lp,
        rev_map: raw.rev_map,
    }
}

/// Class-conditional token text: `seq_len` tokens, each drawn from the
/// owner class's vocabulary band w.p. `signal`, else uniform noise.
/// Token 0 = PAD, 1 = MASK; class bands start at 2.
pub fn class_tokens(
    class: usize,
    num_classes: usize,
    vocab: usize,
    seq_len: usize,
    signal: f64,
    rng: &mut Rng,
) -> Vec<i32> {
    let band = (vocab - 2) / num_classes;
    (0..seq_len)
        .map(|_| {
            if rng.gen_f64() < signal {
                (2 + class * band + rng.gen_range(band)) as i32
            } else {
                (2 + rng.gen_range(vocab - 2)) as i32
            }
        })
        .collect()
}

/// Class-correlated dense features: one-hot-ish bump plus noise.
pub fn class_features(class: usize, dim: usize, strength: f32, rng: &mut Rng) -> Vec<f32> {
    let mut f: Vec<f32> = (0..dim).map(|_| rng.gen_normal() * 0.3).collect();
    f[class % dim] += strength;
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_cover_and_ratio() {
        let mut rng = Rng::seed_from(0);
        let s = make_splits(10_000, &mut rng, 0.8, 0.1);
        let train = s.iter().filter(|&&x| x == Split::Train).count();
        let val = s.iter().filter(|&&x| x == Split::Val).count();
        assert!((train as f64 / 10_000.0 - 0.8).abs() < 0.02);
        assert!((val as f64 / 10_000.0 - 0.1).abs() < 0.01);
    }

    #[test]
    fn class_tokens_land_in_band() {
        let mut rng = Rng::seed_from(1);
        let toks = class_tokens(3, 16, 1024, 32, 1.0, &mut rng);
        let band = (1024 - 2) / 16;
        for &t in &toks {
            let t = t as usize;
            assert!(t >= 2 + 3 * band && t < 2 + 4 * band);
        }
    }
}
