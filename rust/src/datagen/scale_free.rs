//! Chung-Lu style power-law homogeneous graphs — the Table 3 workload
//! (paper: synthetic graphs of 1B/10B/100B edges, degree ≈ 100,
//! 64-dim features; here scaled by 10⁴ per DESIGN.md §1).


use crate::datagen::{make_splits, RawData};
use crate::dataloader::NodeLabels;
use crate::graph::{EdgeTypeDef, FeatureSource, HeteroGraph, Schema};
use crate::util::{FxHashMap, Rng};

#[derive(Debug, Clone)]
pub struct ScaleFreeConfig {
    pub n_edges: usize,
    pub avg_degree: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    /// Zipf exponent for endpoint popularity.
    pub alpha: f64,
    pub train_frac: f64,
    pub seed: u64,
}

impl Default for ScaleFreeConfig {
    fn default() -> Self {
        ScaleFreeConfig {
            n_edges: 100_000,
            avg_degree: 20,
            feat_dim: 64,
            num_classes: 16,
            alpha: 0.8,
            train_frac: 0.8,
            seed: 31,
        }
    }
}

/// Zipf-ish endpoint sampler via inverse-transform on u^(1/(1-alpha)).
#[inline]
fn zipf(n: usize, alpha: f64, rng: &mut Rng) -> u32 {
    let u = rng.gen_f64().max(1e-12);
    let x = u.powf(1.0 / (1.0 - alpha)); // heavy head at small x... invert
    let id = ((1.0 - x.min(1.0)) * n as f64) as usize;
    (n - 1 - id.min(n - 1)) as u32
}

pub fn generate(cfg: &ScaleFreeConfig) -> RawData {
    let mut rng = Rng::seed_from(cfg.seed);
    let n_nodes = (cfg.n_edges / cfg.avg_degree).max(2);
    let mut schema = Schema::new(
        vec!["node".into()],
        vec![EdgeTypeDef { name: "link".into(), src_ntype: 0, dst_ntype: 0 }],
    )
    .with_sources(vec![FeatureSource::Dense]);
    let rev_pairs = schema.add_reverse_etypes();
    let rev_map: FxHashMap<usize, usize> = rev_pairs.into_iter().collect();

    let mut src = Vec::with_capacity(cfg.n_edges);
    let mut dst = Vec::with_capacity(cfg.n_edges);
    for _ in 0..cfg.n_edges {
        src.push(zipf(n_nodes, cfg.alpha, &mut rng));
        dst.push(zipf(n_nodes, cfg.alpha, &mut rng));
    }
    let mut g = HeteroGraph::new(schema, vec![n_nodes]);
    g.set_edges(0, src.clone(), dst.clone());
    g.set_edges(1, dst, src);

    // Labels carried by a feature bump so GCN training converges.
    let mut labels = Vec::with_capacity(n_nodes);
    let mut feat = Vec::with_capacity(n_nodes * cfg.feat_dim);
    for _ in 0..n_nodes {
        let c = rng.gen_range(cfg.num_classes);
        labels.push(c as i32);
        feat.extend(crate::datagen::class_features(c, cfg.feat_dim, 2.0, &mut rng));
    }
    let mut split_rng = rng.fork(0x7e);
    let split = make_splits(n_nodes, &mut split_rng, cfg.train_frac, 0.1);

    RawData {
        graph: g,
        features: vec![(cfg.feat_dim, feat)],
        labels: vec![Some(NodeLabels { labels, split })],
        tokens: vec![None],
        target_ntype: 0,
        num_classes: cfg.num_classes,
        lp_etype: Some(0),
        rev_map,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_count_and_degree_skew() {
        let cfg = ScaleFreeConfig { n_edges: 50_000, avg_degree: 20, ..Default::default() };
        let raw = generate(&cfg);
        assert_eq!(raw.graph.num_edges(0), 50_000);
        let n = raw.graph.num_nodes[0];
        assert_eq!(n, 2500);
        // Power law: the top 1% of nodes should hold well above 1% of
        // the edges.
        let mut degs: Vec<usize> = (0..n).map(|i| raw.graph.edges[0].out_csr.degree(i)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        let top: usize = degs[..n / 100].iter().sum();
        assert!(
            top as f64 > 0.05 * 50_000.0,
            "degree distribution not skewed: top1%={top}"
        );
    }

    #[test]
    fn scales_linearly_in_memory() {
        for edges in [10_000, 40_000] {
            let raw = generate(&ScaleFreeConfig { n_edges: edges, ..Default::default() });
            assert_eq!(raw.graph.num_edges(0), edges);
        }
    }
}
