//! Amazon-Review-like generator + the Table-4 schema ablation variants.
//!
//! Mechanisms planted (DESIGN.md §5):
//! * brand (the NC label) shows weakly in item text and **strongly** in
//!   review text → adding review nodes helps NC (Table 4 v1);
//! * co-purchase (`also_buy`, the LP target) is generated *through*
//!   customer baskets: a customer samples items from a preference
//!   cluster and co-purchase edges connect basket-mates → adding
//!   featureless customer nodes helps LP but not NC (Table 4 v2);
//! * preference clusters are *not* brand-aligned, so customers carry no
//!   brand signal.


use crate::datagen::{make_splits, RawData};
use crate::dataloader::{NodeLabels, TokenStore};
use crate::graph::{EdgeTypeDef, FeatureSource, HeteroGraph, Schema};
use crate::util::{FxHashMap, Rng};

#[derive(Debug, Clone)]
pub struct ArConfig {
    pub n_items: usize,
    pub n_customers: usize,
    pub reviews_per_item: usize,
    pub baskets_per_customer: usize,
    pub basket_size: usize,
    pub n_clusters: usize,
    pub num_classes: usize, // brands
    pub vocab: usize,
    pub seq_len: usize,
    pub item_text_signal: f64,
    pub review_text_signal: f64,
    pub seed: u64,
}

impl Default for ArConfig {
    fn default() -> Self {
        ArConfig {
            n_items: 3000,
            n_customers: 1200,
            reviews_per_item: 3,
            baskets_per_customer: 1,
            basket_size: 3,
            n_clusters: 150,
            num_classes: 8,
            vocab: 1024,
            seq_len: 32,
            item_text_signal: 0.25,
            review_text_signal: 0.6,
            seed: 23,
        }
    }
}

/// The three Table-4 schemas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArVariant {
    /// Items + also_buy only.
    Homogeneous,
    /// + review nodes and (item, receives, review).
    HeteroV1,
    /// + featureless customer nodes and (customer, writes, review).
    HeteroV2,
}

pub const NT_ITEM: usize = 0;
pub const NT_REVIEW: usize = 1;
pub const NT_CUSTOMER: usize = 2;

/// Intermediate raw material shared by all three schema variants.
pub struct ArWorld {
    pub cfg: ArConfig,
    pub brands: Vec<usize>,
    pub also_buy: (Vec<u32>, Vec<u32>),
    /// review -> (item, customer)
    pub reviews: Vec<(u32, u32)>,
    pub item_tokens: Vec<i32>,
    pub review_tokens: Vec<i32>,
}

pub fn generate_world(cfg: &ArConfig) -> ArWorld {
    let mut rng = Rng::seed_from(cfg.seed);
    let n = cfg.n_items;
    let brands: Vec<usize> = (0..n).map(|_| rng.gen_range(cfg.num_classes)).collect();

    // Preference clusters orthogonal to brands.
    let clusters: Vec<usize> = (0..n).map(|_| rng.gen_range(cfg.n_clusters)).collect();
    let mut cluster_pool: Vec<Vec<u32>> = vec![vec![]; cfg.n_clusters];
    for (i, &c) in clusters.iter().enumerate() {
        cluster_pool[c].push(i as u32);
    }

    // Customers shop in 1-2 clusters; baskets produce co-purchases.
    let (mut absrc, mut abdst) = (vec![], vec![]);
    let mut customer_clusters = Vec::with_capacity(cfg.n_customers);
    for _ in 0..cfg.n_customers {
        let c1 = rng.gen_range(cfg.n_clusters);
        customer_clusters.push(c1);
        for _ in 0..cfg.baskets_per_customer {
            let pool = &cluster_pool[c1];
            if pool.len() < 2 {
                continue;
            }
            let basket: Vec<u32> = (0..cfg.basket_size)
                .map(|_| pool[rng.gen_range(pool.len())])
                .collect();
            for i in 0..basket.len() {
                for j in 0..basket.len() {
                    if i != j && basket[i] != basket[j] {
                        absrc.push(basket[i]);
                        abdst.push(basket[j]);
                    }
                }
            }
        }
    }

    // Reviews: written by customers who shop the item's cluster, so
    // co-purchased items share reviewers — the 2-hop LP signal that
    // featureless customer nodes add in Table 4's v2 schema.
    let mut customers_by_cluster: Vec<Vec<u32>> = vec![vec![]; cfg.n_clusters];
    for (c, &cl) in customer_clusters.iter().enumerate() {
        customers_by_cluster[cl].push(c as u32);
    }
    let mut reviews = vec![];
    for i in 0..n {
        let pool = &customers_by_cluster[clusters[i]];
        for _ in 0..cfg.reviews_per_item {
            let cust = if !pool.is_empty() && rng.gen_f64() < 0.9 {
                pool[rng.gen_range(pool.len())]
            } else {
                rng.gen_range(cfg.n_customers) as u32
            };
            reviews.push((i as u32, cust));
        }
    }

    // Text vocabulary layout: brand bands live in [2, vocab/2) (the NC
    // signal), cluster bands in [vocab/2, vocab) (the LP signal carried
    // by reviews — "product line" words).  Item text is weakly branded;
    // review text is strongly branded AND cluster-flavoured, which is
    // why +review helps both tasks in Table 4.
    let half = (cfg.vocab - 2) / 2;
    let bband = half / cfg.num_classes;
    let cband = (half / cfg.n_clusters).max(1);
    let brand_tok = |class: usize, rng: &mut Rng| (2 + class * bband + rng.gen_range(bband)) as i32;
    let cluster_tok = |cl: usize, rng: &mut Rng| {
        (2 + half + (cl * cband + rng.gen_range(cband)) % half) as i32
    };
    let noise_tok = |rng: &mut Rng| (2 + rng.gen_range(cfg.vocab - 2)) as i32;
    let mut item_tokens = Vec::with_capacity(n * cfg.seq_len);
    for i in 0..n {
        for _ in 0..cfg.seq_len {
            let u = rng.gen_f64();
            item_tokens.push(if u < cfg.item_text_signal {
                brand_tok(brands[i], &mut rng)
            } else {
                noise_tok(&mut rng)
            });
        }
    }
    let mut review_tokens = Vec::with_capacity(reviews.len() * cfg.seq_len);
    for &(item, _) in &reviews {
        for _ in 0..cfg.seq_len {
            let u = rng.gen_f64();
            review_tokens.push(if u < cfg.review_text_signal {
                brand_tok(brands[item as usize], &mut rng)
            } else if u < cfg.review_text_signal + 0.25 {
                cluster_tok(clusters[item as usize], &mut rng)
            } else {
                noise_tok(&mut rng)
            });
        }
    }

    ArWorld { cfg: cfg.clone(), brands, also_buy: (absrc, abdst), reviews, item_tokens, review_tokens }
}

/// Render one schema variant of the world as a dataset (Table 4 rows).
pub fn build_variant(world: &ArWorld, variant: ArVariant) -> RawData {
    let cfg = &world.cfg;
    let mut rng = Rng::seed_from(cfg.seed ^ 0xA5);
    let use_reviews = variant != ArVariant::Homogeneous;
    let use_customers = variant == ArVariant::HeteroV2;

    let mut ntypes = vec!["item".to_string()];
    let mut sources = vec![FeatureSource::Text];
    let mut etypes = vec![EdgeTypeDef { name: "also_buy".into(), src_ntype: NT_ITEM, dst_ntype: NT_ITEM }];
    if use_reviews {
        ntypes.push("review".into());
        sources.push(FeatureSource::Text);
        etypes.push(EdgeTypeDef { name: "receives".into(), src_ntype: NT_ITEM, dst_ntype: NT_REVIEW });
    }
    if use_customers {
        ntypes.push("customer".into());
        sources.push(FeatureSource::Learnable);
        etypes.push(EdgeTypeDef {
            name: "writes".into(),
            src_ntype: NT_CUSTOMER,
            dst_ntype: NT_REVIEW,
        });
    }
    let mut schema = Schema::new(ntypes, etypes).with_sources(sources);
    let rev_pairs = schema.add_reverse_etypes();
    let rev_map: FxHashMap<usize, usize> = rev_pairs.into_iter().collect();

    let mut num_nodes = vec![cfg.n_items];
    if use_reviews {
        num_nodes.push(world.reviews.len());
    }
    if use_customers {
        num_nodes.push(cfg.n_customers);
    }
    let mut g = HeteroGraph::new(schema, num_nodes);
    let ab = g.schema.etype_id("also_buy").unwrap();
    g.set_edges(ab, world.also_buy.0.clone(), world.also_buy.1.clone());
    if use_reviews {
        let rc = g.schema.etype_id("receives").unwrap();
        let src: Vec<u32> = world.reviews.iter().map(|&(i, _)| i).collect();
        let dst: Vec<u32> = (0..world.reviews.len() as u32).collect();
        g.set_edges(rc, src, dst);
    }
    if use_customers {
        let wr = g.schema.etype_id("writes").unwrap();
        let src: Vec<u32> = world.reviews.iter().map(|&(_, c)| c).collect();
        let dst: Vec<u32> = (0..world.reviews.len() as u32).collect();
        g.set_edges(wr, src, dst);
    }
    // Reverses.
    let fwd_names: Vec<String> = g
        .schema
        .etypes
        .iter()
        .map(|e| e.name.clone())
        .filter(|n| !n.starts_with("rev-"))
        .collect();
    for name in fwd_names {
        let fwd = g.schema.etype_id(&name).unwrap();
        if let Some(rid) = g.schema.etype_id(&format!("rev-{name}")) {
            let (s, d) = (g.edges[fwd].dst.clone(), g.edges[fwd].src.clone());
            g.set_edges(rid, s, d);
        }
    }

    let labels = NodeLabels {
        labels: world.brands.iter().map(|&b| b as i32).collect(),
        split: make_splits(cfg.n_items, &mut rng, 0.6, 0.2),
    };
    let mut tokens: Vec<Option<TokenStore>> = vec![Some(TokenStore {
        seq_len: cfg.seq_len,
        tokens: world.item_tokens.clone(),
    })];
    let mut features = vec![(0, vec![])];
    let mut labels_v = vec![Some(labels)];
    if use_reviews {
        tokens.push(Some(TokenStore { seq_len: cfg.seq_len, tokens: world.review_tokens.clone() }));
        features.push((0, vec![]));
        labels_v.push(None);
    }
    if use_customers {
        tokens.push(None);
        features.push((0, vec![]));
        labels_v.push(None);
    }

    RawData {
        graph: g,
        features,
        labels: labels_v,
        tokens,
        target_ntype: NT_ITEM,
        num_classes: cfg.num_classes,
        lp_etype: Some(ab),
        rev_map,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_nest() {
        let world = generate_world(&ArConfig { n_items: 400, n_customers: 150, ..Default::default() });
        let homo = build_variant(&world, ArVariant::Homogeneous);
        let v1 = build_variant(&world, ArVariant::HeteroV1);
        let v2 = build_variant(&world, ArVariant::HeteroV2);
        assert_eq!(homo.graph.schema.ntypes.len(), 1);
        assert_eq!(v1.graph.schema.ntypes.len(), 2);
        assert_eq!(v2.graph.schema.ntypes.len(), 3);
        // also_buy identical across variants.
        let ab = |r: &RawData| r.graph.num_edges(r.graph.schema.etype_id("also_buy").unwrap());
        assert_eq!(ab(&homo), ab(&v1));
        assert_eq!(ab(&v1), ab(&v2));
        // Customers are featureless in v2.
        assert_eq!(v2.graph.schema.feature_sources[NT_CUSTOMER], FeatureSource::Learnable);
    }

    #[test]
    fn copurchases_share_cluster_not_brand() {
        let world = generate_world(&ArConfig { n_items: 1000, ..Default::default() });
        let (src, dst) = &world.also_buy;
        let same_brand = src
            .iter()
            .zip(dst)
            .filter(|(&a, &b)| world.brands[a as usize] == world.brands[b as usize])
            .count() as f64
            / src.len().max(1) as f64;
        // Brands are orthogonal to baskets → near-chance same-brand rate.
        assert!(same_brand < 0.3, "brand leak into co-purchase: {same_brand}");
    }

    #[test]
    fn review_text_is_brand_informative() {
        let world = generate_world(&ArConfig { n_items: 500, ..Default::default() });
        let cfg = &world.cfg;
        // Brand bands occupy the lower half of the vocabulary (see the
        // generate_world layout comment).
        let half = (cfg.vocab - 2) / 2;
        let bband = half / cfg.num_classes;
        let mut hits = 0usize;
        let mut total = 0usize;
        for (r, &(item, _)) in world.reviews.iter().enumerate() {
            let brand = world.brands[item as usize];
            for &t in &world.review_tokens[r * cfg.seq_len..(r + 1) * cfg.seq_len] {
                let t = t as usize - 2;
                if t < half && t / bband == brand {
                    hits += 1;
                }
                total += 1;
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.5, "review text too weak: {frac}");
    }
}
