//! MAG-like citation graph generator.
//!
//! Mechanisms planted (DESIGN.md §5):
//! * paper *venue* (the NC label) drives citation homophily AND the
//!   venue-conditional token text, but each paper's own text mixes in
//!   its cited papers' vocabularies — text alone under-determines the
//!   venue while text+structure determines it (Figure 5's ordering);
//! * authors are featureless → the distributed embedding table path;
//! * `cites` is the LP target with ~90/5/5 edge splits.


use crate::datagen::{class_features, make_splits, RawData};
use crate::dataloader::{NodeLabels, TokenStore};
use crate::graph::{EdgeTypeDef, FeatureSource, HeteroGraph, Schema};
use crate::util::{FxHashMap, Rng};

#[derive(Debug, Clone)]
pub struct MagConfig {
    pub n_papers: usize,
    pub n_authors: usize,
    pub n_insts: usize,
    pub n_fields: usize,
    pub num_classes: usize,
    pub avg_cites: usize,
    pub papers_per_author: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub feat_dim: usize,
    /// P(citation links same-venue papers).
    pub homophily: f64,
    /// P(paper's latent topic == its venue): the text-only accuracy cap
    /// (kept weak so the LM alone cannot solve NC; the GNN denoises by
    /// aggregating topics over the homophilous neighborhood).
    pub own_text_signal: f64,
    /// P(token drawn from the topic band) — how decodable the topic is.
    pub cited_text_signal: f64,
    pub seed: u64,
}

impl Default for MagConfig {
    fn default() -> Self {
        MagConfig {
            n_papers: 4000,
            n_authors: 1500,
            n_insts: 60,
            n_fields: 32,
            num_classes: 8,
            avg_cites: 6,
            papers_per_author: 4,
            vocab: 1024,
            seq_len: 32,
            feat_dim: 64,
            homophily: 0.85,
            own_text_signal: 0.45,
            cited_text_signal: 0.70,
            seed: 17,
        }
    }
}

pub const NT_PAPER: usize = 0;
pub const NT_AUTHOR: usize = 1;
pub const NT_INST: usize = 2;
pub const NT_FIELD: usize = 3;

pub fn generate(cfg: &MagConfig) -> RawData {
    let mut rng = Rng::seed_from(cfg.seed);
    let mut schema = Schema::new(
        vec!["paper".into(), "author".into(), "institution".into(), "field".into()],
        vec![
            EdgeTypeDef { name: "cites".into(), src_ntype: NT_PAPER, dst_ntype: NT_PAPER },
            EdgeTypeDef { name: "writes".into(), src_ntype: NT_AUTHOR, dst_ntype: NT_PAPER },
            EdgeTypeDef { name: "affiliated".into(), src_ntype: NT_AUTHOR, dst_ntype: NT_INST },
            EdgeTypeDef { name: "has_topic".into(), src_ntype: NT_PAPER, dst_ntype: NT_FIELD },
        ],
    )
    .with_sources(vec![
        FeatureSource::Text,      // papers: token text
        FeatureSource::Learnable, // authors: featureless
        FeatureSource::Dense,     // institutions
        FeatureSource::Dense,     // fields
    ]);
    let rev_pairs = schema.add_reverse_etypes();
    let rev_map: FxHashMap<usize, usize> = rev_pairs.into_iter().collect();

    let n = cfg.n_papers;
    // Venues, with per-venue paper pools for homophilous citations.
    let venues: Vec<usize> = (0..n).map(|_| rng.gen_range(cfg.num_classes)).collect();
    let mut pools: Vec<Vec<u32>> = vec![vec![]; cfg.num_classes];
    for (i, &v) in venues.iter().enumerate() {
        pools[v].push(i as u32);
    }

    // Citations: mostly same-venue.  Each paper cites ~avg_cites others.
    let (mut csrc, mut cdst) = (vec![], vec![]);
    for i in 0..n {
        let cites = 1 + rng.gen_range(2 * cfg.avg_cites);
        for _ in 0..cites {
            let j = if rng.gen_f64() < cfg.homophily {
                let pool = &pools[venues[i]];
                pool[rng.gen_range(pool.len())]
            } else {
                rng.gen_range(n) as u32
            };
            if j as usize != i {
                csrc.push(i as u32);
                cdst.push(j);
            }
        }
    }

    // Authors: venue-affine, write several papers each.
    let (mut wsrc, mut wdst) = (vec![], vec![]);
    for a in 0..cfg.n_authors {
        let fav = rng.gen_range(cfg.num_classes);
        for _ in 0..cfg.papers_per_author {
            let p = if rng.gen_f64() < 0.7 {
                pools[fav][rng.gen_range(pools[fav].len())]
            } else {
                rng.gen_range(n) as u32
            };
            wsrc.push(a as u32);
            wdst.push(p);
        }
    }

    // Affiliations + topics.
    let (mut asrc, mut adst) = (vec![], vec![]);
    for a in 0..cfg.n_authors {
        asrc.push(a as u32);
        adst.push(rng.gen_range(cfg.n_insts) as u32);
    }
    let (mut tsrc, mut tdst) = (vec![], vec![]);
    for p in 0..n {
        // Fields venue-correlated: field = venue band with noise.
        let fields_per_class = (cfg.n_fields / cfg.num_classes).max(1);
        let f = if rng.gen_f64() < 0.7 {
            venues[p] * fields_per_class + rng.gen_range(fields_per_class)
        } else {
            rng.gen_range(cfg.n_fields)
        };
        tsrc.push(p as u32);
        tdst.push(f.min(cfg.n_fields - 1) as u32);
    }

    let num_nodes = vec![n, cfg.n_authors, cfg.n_insts, cfg.n_fields];
    let mut g = HeteroGraph::new(schema, num_nodes);
    let cites = g.schema.etype_id("cites").unwrap();
    let writes = g.schema.etype_id("writes").unwrap();
    let affiliated = g.schema.etype_id("affiliated").unwrap();
    let has_topic = g.schema.etype_id("has_topic").unwrap();
    g.set_edges(cites, csrc.clone(), cdst.clone());
    g.set_edges(writes, wsrc.clone(), wdst.clone());
    g.set_edges(affiliated, asrc.clone(), adst.clone());
    g.set_edges(has_topic, tsrc.clone(), tdst.clone());
    // Reverse edges.
    for (fwd, rev) in [
        (cites, "rev-cites"),
        (writes, "rev-writes"),
        (affiliated, "rev-affiliated"),
        (has_topic, "rev-has_topic"),
    ] {
        let rid = g.schema.etype_id(rev).unwrap();
        let (s, d) = (g.edges[fwd].dst.clone(), g.edges[fwd].src.clone());
        g.set_edges(rid, s, d);
    }

    // Paper text reveals a latent *topic*, and the topic only weakly
    // determines the venue (P(topic==venue) = own_text_signal).  A
    // text-only model therefore caps near own_text_signal accuracy,
    // while the GNN can majority-vote topics over the (homophilous)
    // citation neighborhood and recover the venue — the Figure 5
    // mechanism: BERT alone << BERT+GNN.
    let topics: Vec<usize> = (0..n)
        .map(|p| {
            if rng.gen_f64() < cfg.own_text_signal {
                venues[p]
            } else {
                rng.gen_range(cfg.num_classes)
            }
        })
        .collect();
    let band = (cfg.vocab - 2) / cfg.num_classes;
    let mut tokens = vec![0i32; n * cfg.seq_len];
    for p in 0..n {
        for j in 0..cfg.seq_len {
            tokens[p * cfg.seq_len + j] = if rng.gen_f64() < cfg.cited_text_signal {
                // Topic-band token (strongly decodable topic).
                (2 + topics[p] * band + rng.gen_range(band)) as i32
            } else {
                (2 + rng.gen_range(cfg.vocab - 2)) as i32
            };
        }
    }

    // Dense features for institutions (mild venue mix) and fields
    // (strongly venue-banded — the structural signal for the GNN).
    let mut inst_feat = vec![];
    for _ in 0..cfg.n_insts {
        inst_feat.extend(class_features(rng.gen_range(cfg.num_classes), cfg.feat_dim, 1.0, &mut rng));
    }
    let mut field_feat = vec![];
    let fields_per_class = (cfg.n_fields / cfg.num_classes).max(1);
    for f in 0..cfg.n_fields {
        let c = (f / fields_per_class).min(cfg.num_classes - 1);
        field_feat.extend(class_features(c, cfg.feat_dim, 3.0, &mut rng));
    }

    let mut split_rng = rng.fork(0x5eed);
    let labels = NodeLabels {
        labels: venues.iter().map(|&v| v as i32).collect(),
        split: make_splits(n, &mut split_rng, 0.6, 0.2),
    };

    RawData {
        graph: g,
        features: vec![
            (0, vec![]),
            (0, vec![]),
            (cfg.feat_dim, inst_feat),
            (cfg.feat_dim, field_feat),
        ],
        labels: vec![Some(labels), None, None, None],
        tokens: vec![
            Some(TokenStore { seq_len: cfg.seq_len, tokens }),
            None,
            None,
            None,
        ],
        target_ntype: NT_PAPER,
        num_classes: cfg.num_classes,
        lp_etype: Some(cites),
        rev_map,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_consistent_dataset() {
        let cfg = MagConfig { n_papers: 500, n_authors: 200, ..Default::default() };
        let raw = generate(&cfg);
        assert_eq!(raw.graph.schema.etypes.len(), 8);
        assert_eq!(raw.graph.num_nodes[NT_PAPER], 500);
        assert!(raw.graph.num_edges(0) > 500);
        // Reverse edges mirror forward edges.
        let cites = raw.graph.schema.etype_id("cites").unwrap();
        let rev = raw.graph.schema.etype_id("rev-cites").unwrap();
        assert_eq!(raw.graph.num_edges(cites), raw.graph.num_edges(rev));
        // Labels in range.
        let l = raw.labels[NT_PAPER].as_ref().unwrap();
        assert!(l.labels.iter().all(|&x| (x as usize) < cfg.num_classes));
        // Tokens padded/ranged.
        let t = raw.tokens[NT_PAPER].as_ref().unwrap();
        assert_eq!(t.num_rows(), 500);
        assert!(t.tokens.iter().all(|&x| (x as usize) < cfg.vocab));
    }

    #[test]
    fn citation_homophily_present() {
        let raw = generate(&MagConfig { n_papers: 1000, ..Default::default() });
        let l = raw.labels[NT_PAPER].as_ref().unwrap();
        let cites = raw.graph.schema.etype_id("cites").unwrap();
        let es = &raw.graph.edges[cites];
        let same = es
            .src
            .iter()
            .zip(&es.dst)
            .filter(|(&s, &d)| l.labels[s as usize] == l.labels[d as usize])
            .count();
        let frac = same as f64 / es.src.len() as f64;
        assert!(frac > 0.7, "homophily too weak: {frac}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&MagConfig { n_papers: 300, ..Default::default() });
        let b = generate(&MagConfig { n_papers: 300, ..Default::default() });
        assert_eq!(a.graph.edges[0].src, b.graph.edges[0].src);
        assert_eq!(
            a.tokens[0].as_ref().unwrap().tokens,
            b.tokens[0].as_ref().unwrap().tokens
        );
    }
}
