//! Graph construction pipeline (paper §3.1.2, Appendix B).
//!
//! Takes tabular node/edge files (CSV) plus the paper's JSON graph
//! schema (Fig. 6 dialect) and produces a runnable `GsDataset`:
//! feature transformation → string→int ID mapping → graph build →
//! partition → shuffle.  A multi-worker (thread) variant of the
//! transform stage stands in for the Spark-based GSProcessing.

pub mod config;
pub mod idmap;
pub mod transform;

pub use config::{EdgeConfig, FeatTransform, GConstructConfig, LabelConfig, NodeConfig};
pub use idmap::IdMap;

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use crate::dataloader::{GsDataset, LpTask, NodeLabels, Split, TokenStore};
use crate::datagen::{build_dataset, RawData};
use crate::graph::{EdgeTypeDef, FeatureSource, HeteroGraph, Schema};
use crate::partition::PartitionBook;
use crate::util::{FxHashMap, Rng};

/// Minimal CSV reader (header + rows, no quoting of separators needed
/// for our fixtures; quoted fields with commas are supported).
pub fn read_csv(path: &Path) -> Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let mut lines = text.lines();
    let header = match lines.next() {
        Some(h) => split_csv_line(h),
        None => bail!("{}: empty file", path.display()),
    };
    let mut rows = vec![];
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row = split_csv_line(line);
        if row.len() != header.len() {
            bail!("{}:{}: {} fields, header has {}", path.display(), ln + 2, row.len(), header.len());
        }
        rows.push(row);
    }
    Ok((header, rows))
}

fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = vec![];
    let mut cur = String::new();
    let mut quoted = false;
    for c in line.chars() {
        match c {
            '"' => quoted = !quoted,
            ',' if !quoted => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

/// Run the whole pipeline: parse config → read tables → transform
/// features → map IDs → build graph → attach labels/splits.
pub fn construct(cfg: &GConstructConfig, base_dir: &Path) -> Result<RawData> {
    let mut ntypes = vec![];
    let mut sources = vec![];
    for n in &cfg.nodes {
        ntypes.push(n.node_type.clone());
        sources.push(match n.feature_transform {
            Some(FeatTransform::Tokenize { .. }) => FeatureSource::Text,
            Some(_) => FeatureSource::Dense,
            None => FeatureSource::Learnable,
        });
    }
    let mut etypes = vec![];
    let nt_id = |name: &str| -> Result<usize> {
        ntypes
            .iter()
            .position(|n| n == name)
            .with_context(|| format!("unknown node type '{name}'"))
    };
    for e in &cfg.edges {
        etypes.push(EdgeTypeDef {
            name: e.relation.1.clone(),
            src_ntype: nt_id(&e.relation.0)?,
            dst_ntype: nt_id(&e.relation.2)?,
        });
    }
    let mut schema = Schema::new(ntypes.clone(), etypes).with_sources(sources);
    let rev_pairs = schema.add_reverse_etypes();
    let rev_map: FxHashMap<usize, usize> = rev_pairs.into_iter().collect();

    // Pass 1: nodes — ID maps, features, labels.
    let mut idmaps: Vec<IdMap> = (0..cfg.nodes.len()).map(|_| IdMap::new()).collect();
    let mut features: Vec<(usize, Vec<f32>)> = vec![(0, vec![]); cfg.nodes.len()];
    let mut tokens: Vec<Option<TokenStore>> = vec![None; cfg.nodes.len()];
    let mut labels: Vec<Option<NodeLabels>> = vec![None; cfg.nodes.len()];
    let mut target_ntype = 0usize;
    let mut num_classes = 2usize;
    let mut split_rng = Rng::seed_from(cfg.seed);

    for (nt, ncfg) in cfg.nodes.iter().enumerate() {
        let (header, rows) = read_csv(&base_dir.join(&ncfg.file))?;
        let col = |name: &str| -> Result<usize> {
            header
                .iter()
                .position(|h| h == name)
                .with_context(|| format!("{}: no column '{name}'", ncfg.file))
        };
        let idc = col(&ncfg.node_id_col)?;
        for row in &rows {
            idmaps[nt].get_or_insert(&row[idc]);
        }
        if let Some(t) = &ncfg.feature_transform {
            let fc = col(ncfg.feature_col.as_ref().context("feature transform needs feature_col")?)?;
            let vals: Vec<&str> = rows.iter().map(|r| r[fc].as_str()).collect();
            match transform::apply(t, &vals)? {
                transform::Transformed::Dense { dim, data } => features[nt] = (dim, data),
                transform::Transformed::Tokens { seq_len, data } => {
                    tokens[nt] = Some(TokenStore { seq_len, tokens: data })
                }
            }
        }
        if let Some(l) = &ncfg.label {
            let lc = col(&l.label_col)?;
            let mut classmap: HashMap<String, i32> = HashMap::new();
            let vals: Vec<i32> = rows
                .iter()
                .map(|r| {
                    let n = classmap.len() as i32;
                    *classmap.entry(r[lc].clone()).or_insert(n)
                })
                .collect();
            num_classes = classmap.len().max(2);
            target_ntype = nt;
            let split = crate::datagen::make_splits(
                vals.len(),
                &mut split_rng,
                l.split_pct[0],
                l.split_pct[1],
            );
            labels[nt] = Some(NodeLabels { labels: vals, split });
        }
    }

    // Pass 2: edges.
    let num_nodes: Vec<usize> = idmaps.iter().map(|m| m.len()).collect();
    let mut g = HeteroGraph::new(schema, num_nodes);
    let mut lp_etype = None;
    for ecfg in &cfg.edges {
        let et = g.schema.etype_id(&ecfg.relation.1).unwrap();
        let (header, rows) = read_csv(&base_dir.join(&ecfg.file))?;
        let col = |name: &str| -> Result<usize> {
            header
                .iter()
                .position(|h| h == name)
                .with_context(|| format!("{}: no column '{name}'", ecfg.file))
        };
        let sc = col(&ecfg.source_id_col)?;
        let dc = col(&ecfg.dest_id_col)?;
        let (snt, dnt) = (g.schema.etypes[et].src_ntype, g.schema.etypes[et].dst_ntype);
        let mut src = Vec::with_capacity(rows.len());
        let mut dst = Vec::with_capacity(rows.len());
        for row in &rows {
            let s = idmaps[snt]
                .get(&row[sc])
                .with_context(|| format!("{}: unknown src id '{}'", ecfg.file, row[sc]))?;
            let d = idmaps[dnt]
                .get(&row[dc])
                .with_context(|| format!("{}: unknown dst id '{}'", ecfg.file, row[dc]))?;
            src.push(s);
            dst.push(d);
        }
        g.set_edges(et, src.clone(), dst.clone());
        if let Some(rid) = g.schema.etype_id(&format!("rev-{}", ecfg.relation.1)) {
            g.set_edges(rid, dst, src);
        }
        if ecfg.link_prediction {
            lp_etype = Some(et);
        }
    }

    Ok(RawData {
        graph: g,
        features,
        labels,
        tokens,
        target_ntype,
        num_classes,
        lp_etype,
        rev_map,
    })
}

/// Bind constructed raw data to a partition book (the pipeline's
/// `partition` stage for gconstruct sources): `build_dataset` with the
/// schema's seed, then honor the schema's explicit LP split if given
/// (the default split came from `build_dataset`).
pub fn bind_dataset(
    cfg: &GConstructConfig,
    raw: RawData,
    book: PartitionBook,
    lemb_dim: usize,
) -> Result<GsDataset> {
    let mut ds = build_dataset(raw, book, lemb_dim, cfg.seed);
    if let (Some(lp), Some(pct)) = (&mut ds.lp, cfg.lp_split.as_ref()) {
        let mut rng = Rng::seed_from(cfg.seed ^ 0x1b);
        lp.split = crate::datagen::make_splits(lp.split.len(), &mut rng, pct[0], pct[1]);
    }
    Ok(ds)
}

/// construct + partition + bind: the single-command path
/// (`gs gconstruct --conf schema.json --num-parts 2`).
pub fn construct_dataset(
    cfg: &GConstructConfig,
    base_dir: &Path,
    n_parts: usize,
    metis: bool,
) -> Result<GsDataset> {
    let raw = construct(cfg, base_dir)?;
    let book = if n_parts <= 1 {
        PartitionBook::single(&raw.graph.num_nodes)
    } else if metis {
        crate::partition::metis_like_partition(&raw.graph, n_parts, cfg.seed)
    } else {
        crate::partition::random_partition(&raw.graph, n_parts, cfg.seed)
    };
    bind_dataset(cfg, raw, book, 64)
}

/// Convenience for tests: write a dataset's tabular form to a dir.
pub fn unused_split_marker() -> Split {
    Split::None
}

#[allow(unused)]
fn _silence(_: LpTask) {}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("papers.csv"),
            "node_id,text,venue\np1,token alpha beta,kdd\np2,gamma delta,kdd\np3,alpha beta,icml\np4,delta gamma,icml\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("authors.csv"),
            "node_id\na1\na2\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("cites.csv"),
            "src,dst\np1,p2\np2,p3\np3,p4\np4,p1\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("writes.csv"),
            "src,dst\na1,p1\na1,p2\na2,p3\n",
        )
        .unwrap();
        std::fs::write(dir.join("schema.json"), config::EXAMPLE_SCHEMA).unwrap();
    }

    #[test]
    fn end_to_end_construct() {
        let dir = std::env::temp_dir().join(format!("gc_test_{}", std::process::id()));
        write_fixture(&dir);
        let cfg = GConstructConfig::load(&dir.join("schema.json")).unwrap();
        let raw = construct(&cfg, &dir).unwrap();
        assert_eq!(raw.graph.num_nodes, vec![4, 2]);
        let cites = raw.graph.schema.etype_id("cites").unwrap();
        assert_eq!(raw.graph.num_edges(cites), 4);
        // Reverse edges exist.
        assert!(raw.graph.schema.etype_id("rev-writes").is_some());
        // Tokenized text on papers; authors featureless.
        assert!(raw.tokens[0].is_some());
        assert_eq!(raw.graph.schema.feature_sources[1], FeatureSource::Learnable);
        // Labels: two classes.
        assert_eq!(raw.num_classes, 2);
        assert!(raw.lp_etype.is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_edge_endpoint_rejected() {
        let dir = std::env::temp_dir().join(format!("gc_test2_{}", std::process::id()));
        write_fixture(&dir);
        std::fs::write(dir.join("cites.csv"), "src,dst\np1,NOPE\n").unwrap();
        let cfg = GConstructConfig::load(&dir.join("schema.json")).unwrap();
        assert!(construct(&cfg, &dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
