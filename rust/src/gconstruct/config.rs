//! The gconstruct JSON schema — the paper's Fig. 6 dialect.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub enum FeatTransform {
    /// Pass numeric columns through, optionally standardized.
    Numeric { normalize: bool },
    /// Map categories to one-hot vectors.
    Categorical,
    /// Whitespace tokenizer + hash vocabulary (PAD=0, MASK=1).
    Tokenize { vocab: usize, seq_len: usize },
}

#[derive(Debug, Clone)]
pub struct LabelConfig {
    pub label_col: String,
    pub task_type: String,
    pub split_pct: [f64; 3],
}

#[derive(Debug, Clone)]
pub struct NodeConfig {
    pub node_type: String,
    pub file: String,
    pub node_id_col: String,
    pub feature_col: Option<String>,
    pub feature_transform: Option<FeatTransform>,
    pub label: Option<LabelConfig>,
}

#[derive(Debug, Clone)]
pub struct EdgeConfig {
    /// (src type, relation name, dst type) — the paper's triple.
    pub relation: (String, String, String),
    pub file: String,
    pub source_id_col: String,
    pub dest_id_col: String,
    pub link_prediction: bool,
}

#[derive(Debug, Clone)]
pub struct GConstructConfig {
    pub nodes: Vec<NodeConfig>,
    pub edges: Vec<EdgeConfig>,
    pub seed: u64,
    pub lp_split: Option<[f64; 2]>,
}

impl GConstructConfig {
    pub fn load(path: &Path) -> Result<GConstructConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<GConstructConfig> {
        let j = Json::parse(text)?;
        let mut nodes = vec![];
        for n in j.get("nodes").and_then(Json::as_arr).context("missing 'nodes'")? {
            let transform = match n.get("features").and_then(Json::as_arr).and_then(|f| f.first()) {
                Some(f) => {
                    let name = f
                        .get("transform")
                        .and_then(|t| t.get("name"))
                        .and_then(Json::as_str)
                        .unwrap_or("numeric");
                    let tr = match name {
                        "numeric" => FeatTransform::Numeric {
                            normalize: f
                                .get("transform")
                                .and_then(|t| t.get("normalize"))
                                .and_then(Json::as_bool)
                                .unwrap_or(true),
                        },
                        "categorical" | "to_categorical" => FeatTransform::Categorical,
                        "tokenize" | "tokenize_hf" => FeatTransform::Tokenize {
                            vocab: f
                                .get("transform")
                                .and_then(|t| t.get("vocab"))
                                .and_then(Json::as_usize)
                                .unwrap_or(1024),
                            seq_len: f
                                .get("transform")
                                .and_then(|t| t.get("max_seq_length"))
                                .and_then(Json::as_usize)
                                .unwrap_or(32),
                        },
                        other => bail!("unknown transform '{other}'"),
                    };
                    Some((f.str_of("feature_col")?.to_string(), tr))
                }
                None => None,
            };
            let label = match n.get("labels").and_then(Json::as_arr).and_then(|l| l.first()) {
                Some(l) => {
                    let pct = l
                        .get("split_pct")
                        .and_then(Json::as_arr)
                        .map(|a| {
                            let v: Vec<f64> = a.iter().filter_map(Json::as_f64).collect();
                            [v[0], v[1], *v.get(2).unwrap_or(&0.0)]
                        })
                        .unwrap_or([0.8, 0.1, 0.1]);
                    Some(LabelConfig {
                        label_col: l.str_of("label_col")?.to_string(),
                        task_type: l.str_of("task_type")?.to_string(),
                        split_pct: pct,
                    })
                }
                None => None,
            };
            let files = n.get("files").and_then(Json::as_arr).context("node needs 'files'")?;
            nodes.push(NodeConfig {
                node_type: n.str_of("node_type")?.to_string(),
                file: files[0].as_str().context("bad file entry")?.to_string(),
                node_id_col: n.str_of("node_id_col")?.to_string(),
                feature_col: transform.as_ref().map(|(c, _)| c.clone()),
                feature_transform: transform.map(|(_, t)| t),
                label,
            });
        }
        let mut edges = vec![];
        for e in j.get("edges").and_then(Json::as_arr).context("missing 'edges'")? {
            let rel = e.get("relation").and_then(Json::as_arr).context("edge needs relation")?;
            if rel.len() != 3 {
                bail!("relation must be [src, name, dst]");
            }
            let lp = e
                .get("labels")
                .and_then(Json::as_arr)
                .map(|ls| {
                    ls.iter().any(|l| {
                        l.get("task_type").and_then(Json::as_str) == Some("link_prediction")
                    })
                })
                .unwrap_or(false);
            let files = e.get("files").and_then(Json::as_arr).context("edge needs 'files'")?;
            edges.push(EdgeConfig {
                relation: (
                    rel[0].as_str().unwrap().to_string(),
                    rel[1].as_str().unwrap().to_string(),
                    rel[2].as_str().unwrap().to_string(),
                ),
                file: files[0].as_str().context("bad file entry")?.to_string(),
                source_id_col: e.str_of("source_id_col")?.to_string(),
                dest_id_col: e.str_of("dest_id_col")?.to_string(),
                link_prediction: lp,
            });
        }
        Ok(GConstructConfig {
            nodes,
            edges,
            seed: j.get("seed").and_then(Json::as_usize).unwrap_or(7) as u64,
            lp_split: None,
        })
    }
}

/// Example schema used by the tests and the quickstart docs — the same
/// shape as the paper's Fig. 6.
pub const EXAMPLE_SCHEMA: &str = r#"{
 "version": "gconstruct-v0.1",
 "nodes": [
  {
   "node_type": "paper",
   "format": {"name": "csv"},
   "files": ["papers.csv"],
   "node_id_col": "node_id",
   "features": [
    {"feature_col": "text",
     "transform": {"name": "tokenize", "vocab": 256, "max_seq_length": 8}}
   ],
   "labels": [
    {"label_col": "venue", "task_type": "classification",
     "split_pct": [0.5, 0.25, 0.25]}
   ]
  },
  {
   "node_type": "author",
   "format": {"name": "csv"},
   "files": ["authors.csv"],
   "node_id_col": "node_id"
  }
 ],
 "edges": [
  {
   "relation": ["paper", "cites", "paper"],
   "format": {"name": "csv"},
   "files": ["cites.csv"],
   "source_id_col": "src",
   "dest_id_col": "dst",
   "labels": [{"task_type": "link_prediction", "split_pct": [0.8, 0.1, 0.1]}]
  },
  {
   "relation": ["author", "writes", "paper"],
   "format": {"name": "csv"},
   "files": ["writes.csv"],
   "source_id_col": "src",
   "dest_id_col": "dst"
  }
 ]
}"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_example_schema() {
        let cfg = GConstructConfig::parse(EXAMPLE_SCHEMA).unwrap();
        assert_eq!(cfg.nodes.len(), 2);
        assert_eq!(cfg.edges.len(), 2);
        assert!(matches!(
            cfg.nodes[0].feature_transform,
            Some(FeatTransform::Tokenize { vocab: 256, seq_len: 8 })
        ));
        assert!(cfg.nodes[1].feature_transform.is_none());
        assert!(cfg.edges[0].link_prediction);
        assert!(!cfg.edges[1].link_prediction);
        assert_eq!(cfg.nodes[0].label.as_ref().unwrap().split_pct[0], 0.5);
    }
}
