//! Feature transforms (paper §3.1.2): numeric standardization,
//! categorical one-hot, and a hash tokenizer for text columns.
//! The multi-worker path splits rows across threads (the Spark /
//! GSProcessing stand-in) and concatenates shards in order.

use anyhow::{Context, Result};

use super::config::FeatTransform;

pub enum Transformed {
    Dense { dim: usize, data: Vec<f32> },
    Tokens { seq_len: usize, data: Vec<i32> },
}

/// FNV-1a hash for the token vocabulary (stable across runs/platforms).
#[inline]
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub fn apply(t: &FeatTransform, vals: &[&str]) -> Result<Transformed> {
    match t {
        FeatTransform::Numeric { normalize } => {
            // Columns separated by spaces or ';' within the field.
            let rows: Vec<Vec<f32>> = vals
                .iter()
                .map(|v| {
                    v.split(|c| c == ' ' || c == ';')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse::<f32>().with_context(|| format!("bad number '{s}'")))
                        .collect()
                })
                .collect::<Result<_>>()?;
            let dim = rows.iter().map(Vec::len).max().unwrap_or(0);
            let mut data = vec![0.0f32; rows.len() * dim];
            for (i, r) in rows.iter().enumerate() {
                data[i * dim..i * dim + r.len()].copy_from_slice(r);
            }
            if *normalize && dim > 0 {
                for j in 0..dim {
                    let col: Vec<f32> = (0..rows.len()).map(|i| data[i * dim + j]).collect();
                    let mean = col.iter().sum::<f32>() / col.len().max(1) as f32;
                    let var =
                        col.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / col.len().max(1) as f32;
                    let sd = var.sqrt().max(1e-6);
                    for i in 0..rows.len() {
                        data[i * dim + j] = (data[i * dim + j] - mean) / sd;
                    }
                }
            }
            Ok(Transformed::Dense { dim, data })
        }
        FeatTransform::Categorical => {
            let mut cats = std::collections::HashMap::new();
            let idx: Vec<usize> = vals
                .iter()
                .map(|v| {
                    let n = cats.len();
                    *cats.entry(v.to_string()).or_insert(n)
                })
                .collect();
            let dim = cats.len().max(1);
            let mut data = vec![0.0f32; vals.len() * dim];
            for (i, &c) in idx.iter().enumerate() {
                data[i * dim + c] = 1.0;
            }
            Ok(Transformed::Dense { dim, data })
        }
        FeatTransform::Tokenize { vocab, seq_len } => {
            let mut data = vec![0i32; vals.len() * seq_len];
            for (i, v) in vals.iter().enumerate() {
                for (j, tok) in v.split_whitespace().take(*seq_len).enumerate() {
                    // Reserve 0 (PAD) and 1 (MASK).
                    data[i * seq_len + j] = (2 + (fnv1a(tok) as usize % (vocab - 2))) as i32;
                }
            }
            Ok(Transformed::Tokens { seq_len: *seq_len, data })
        }
    }
}

/// Multi-worker transform: shard rows, run `apply` per shard on a
/// thread, stitch results back in order.  Deterministic regardless of
/// worker count (the tests assert this).
pub fn apply_parallel(t: &FeatTransform, vals: &[&str], workers: usize) -> Result<Transformed> {
    if workers <= 1 || vals.len() < 2 * workers {
        return apply(t, vals);
    }
    // Categorical needs a global vocabulary — single-threaded by design.
    if matches!(t, FeatTransform::Categorical) {
        return apply(t, vals);
    }
    let chunk = vals.len().div_ceil(workers);
    let shards: Vec<Result<Transformed>> = std::thread::scope(|scope| {
        let handles: Vec<_> = vals
            .chunks(chunk)
            .map(|shard| scope.spawn(move || apply(t, shard)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Stitch.
    let mut out: Option<Transformed> = None;
    for s in shards {
        let s = s?;
        out = Some(match (out, s) {
            (None, s) => s,
            (Some(Transformed::Dense { dim, mut data }), Transformed::Dense { dim: d2, data: x }) => {
                assert_eq!(dim, d2, "shard dim mismatch");
                data.extend(x);
                Transformed::Dense { dim, data }
            }
            (
                Some(Transformed::Tokens { seq_len, mut data }),
                Transformed::Tokens { seq_len: s2, data: x },
            ) => {
                assert_eq!(seq_len, s2);
                data.extend(x);
                Transformed::Tokens { seq_len, data }
            }
            _ => anyhow::bail!("mixed shard kinds"),
        });
    }
    Ok(out.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_normalizes() {
        let t = FeatTransform::Numeric { normalize: true };
        let out = apply(&t, &["1 2", "3 4", "5 6"]).unwrap();
        if let Transformed::Dense { dim, data } = out {
            assert_eq!(dim, 2);
            // Each column ~zero mean.
            let m0: f32 = (0..3).map(|i| data[i * 2]).sum::<f32>() / 3.0;
            assert!(m0.abs() < 1e-5);
        } else {
            panic!("wrong kind");
        }
    }

    #[test]
    fn categorical_one_hot() {
        let out = apply(&FeatTransform::Categorical, &["a", "b", "a"]).unwrap();
        if let Transformed::Dense { dim, data } = out {
            assert_eq!(dim, 2);
            assert_eq!(data, vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        } else {
            panic!("wrong kind");
        }
    }

    #[test]
    fn tokenize_deterministic_and_padded() {
        let t = FeatTransform::Tokenize { vocab: 64, seq_len: 4 };
        let a = apply(&t, &["hello world", "x"]).unwrap();
        let b = apply(&t, &["hello world", "x"]).unwrap();
        if let (Transformed::Tokens { data: da, .. }, Transformed::Tokens { data: db, .. }) = (a, b) {
            assert_eq!(da, db);
            assert_eq!(da.len(), 8);
            assert_eq!(da[2], 0, "padding must be PAD=0");
            assert!(da.iter().all(|&t| t == 0 || (2..64).contains(&t)));
        } else {
            panic!("wrong kind");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let t = FeatTransform::Tokenize { vocab: 128, seq_len: 6 };
        let vals: Vec<String> = (0..200).map(|i| format!("tok{} common word{}", i, i % 7)).collect();
        let refs: Vec<&str> = vals.iter().map(String::as_str).collect();
        let a = apply(&t, &refs).unwrap();
        let b = apply_parallel(&t, &refs, 4).unwrap();
        if let (Transformed::Tokens { data: da, .. }, Transformed::Tokens { data: db, .. }) = (a, b) {
            assert_eq!(da, db);
        } else {
            panic!("wrong kind");
        }
    }
}
