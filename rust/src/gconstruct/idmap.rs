//! Distributed ID mapping: string node ids → dense integers.
//!
//! The paper's pipeline builds massive string→int tables; here the map
//! is hash-based with insertion-order assignment so ids are dense and
//! deterministic given row order.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct IdMap {
    map: HashMap<String, u32>,
    rev: Vec<String>,
}

impl IdMap {
    pub fn new() -> IdMap {
        IdMap::default()
    }

    pub fn get_or_insert(&mut self, key: &str) -> u32 {
        if let Some(&id) = self.map.get(key) {
            return id;
        }
        let id = self.rev.len() as u32;
        self.map.insert(key.to_string(), id);
        self.rev.push(key.to_string());
        id
    }

    pub fn get(&self, key: &str) -> Option<u32> {
        self.map.get(key).copied()
    }

    pub fn name_of(&self, id: u32) -> Option<&str> {
        self.rev.get(id as usize).map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.rev.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rev.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bijection() {
        let mut m = IdMap::new();
        let ids: Vec<u32> = ["a", "b", "a", "c", "b"].iter().map(|s| m.get_or_insert(s)).collect();
        assert_eq!(ids, vec![0, 1, 0, 2, 1]);
        assert_eq!(m.len(), 3);
        for i in 0..3u32 {
            let name = m.name_of(i).unwrap().to_string();
            assert_eq!(m.get(&name), Some(i));
        }
    }

    #[test]
    fn dense_ids() {
        let mut m = IdMap::new();
        for i in 0..1000 {
            m.get_or_insert(&format!("node-{i}"));
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get("node-999"), Some(999));
    }
}
