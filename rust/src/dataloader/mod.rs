//! Datasets and task data loaders.
//!
//! `GsDataset` bundles everything a task needs: the graph, the
//! distributed engine (features / text embeddings / learnable tables),
//! labels, token stores and split masks.  The loaders turn sampled
//! blocks into the exact manifest-ordered tensor lists the AOT
//! artifacts consume:
//!
//! * `NodeDataLoader` — node classification batches,
//! * `LinkPredictionDataLoader` — LP batches with negative sampling
//!   (a separate loader from edge-feature prediction, as in the paper
//!   §3: LP must construct negatives, so it gets its own path).

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::dist::{DistEngine, DistTensor};
use crate::graph::{FeatureSource, HeteroGraph};
use crate::runtime::{ArtifactSpec, Tensor};
use crate::sampling::{
    negative::sample_negatives, Block, BlockShape, EdgeExclusion, NegSampler, NeighborSampler,
};
use crate::util::Rng;

/// Train/val/test membership.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
    None,
}

/// Node-classification labels over one node type.
#[derive(Debug, Clone)]
pub struct NodeLabels {
    pub labels: Vec<i32>,
    pub split: Vec<Split>,
}

impl NodeLabels {
    pub fn ids_in(&self, s: Split) -> Vec<u32> {
        self.split
            .iter()
            .enumerate()
            .filter(|(_, &x)| x == s)
            .map(|(i, _)| i as u32)
            .collect()
    }
}

/// Token sequences over one node type ([n, seq_len], PAD=0).
#[derive(Debug, Clone)]
pub struct TokenStore {
    pub seq_len: usize,
    pub tokens: Vec<i32>,
}

impl TokenStore {
    pub fn row(&self, id: u32) -> &[i32] {
        &self.tokens[id as usize * self.seq_len..(id as usize + 1) * self.seq_len]
    }

    pub fn num_rows(&self) -> usize {
        self.tokens.len() / self.seq_len
    }
}

/// Link-prediction task: target edge type + per-edge split.
#[derive(Debug, Clone)]
pub struct LpTask {
    pub etype: usize,
    pub split: Vec<Split>,
}

impl LpTask {
    pub fn edge_ids_in(&self, s: Split) -> Vec<u32> {
        self.split
            .iter()
            .enumerate()
            .filter(|(_, &x)| x == s)
            .map(|(i, _)| i as u32)
            .collect()
    }
}

/// Everything one application dataset carries.
pub struct GsDataset {
    pub graph: HeteroGraph,
    pub engine: DistEngine,
    /// Per-ntype classification labels (at most one labelled type used).
    pub labels: Vec<Option<NodeLabels>>,
    /// Per-ntype token stores for text node types.
    pub tokens: Vec<Option<TokenStore>>,
    pub target_ntype: usize,
    pub num_classes: usize,
    pub lp: Option<LpTask>,
    /// etype -> reverse etype (for target-edge exclusion).
    pub rev_map: HashMap<usize, usize>,
}

impl GsDataset {
    pub fn node_labels(&self) -> &NodeLabels {
        self.labels[self.target_ntype].as_ref().expect("dataset has no labels")
    }

    /// Paper §3.3.2, option 1: construct features for a featureless
    /// node type from its neighbors that *have* features
    /// (`F'_v = f(F_u, u ∈ N(v))`, eq. 1, with f = mean).  The node
    /// type is switched to `Dense` afterwards, so the input encoder
    /// consumes the constructed features instead of the embedding
    /// table — the alternative to learnable embeddings the paper
    /// offers for massive featureless types.
    pub fn construct_neighbor_features(&mut self, ntype: usize, dim: usize) {
        let n = self.graph.num_nodes[ntype];
        let mut feat = vec![0.0f32; n * dim];
        let mut count = vec![0.0f32; n];
        for et in self.graph.etypes_into(ntype) {
            let src_nt = self.graph.schema.etypes[et].src_ntype;
            // Source rows come from dense features or text embeddings.
            let (rows, rdim): (&DistTensor, usize) =
                match self.graph.schema.feature_sources[src_nt] {
                    FeatureSource::Dense => {
                        let t = &self.engine.features[src_nt];
                        (t, t.dim)
                    }
                    FeatureSource::Text => {
                        let t = &self.engine.text_emb[src_nt];
                        (t, t.dim)
                    }
                    FeatureSource::Learnable => continue,
                };
            if rdim == 0 {
                continue;
            }
            let d = rdim.min(dim);
            let es = &self.graph.edges[et];
            for (&s, &dst) in es.src.iter().zip(&es.dst) {
                let row = rows.row(s);
                let base = dst as usize * dim;
                for j in 0..d {
                    feat[base + j] += row[j];
                }
                count[dst as usize] += 1.0;
            }
        }
        for i in 0..n {
            if count[i] > 0.0 {
                for j in 0..dim {
                    feat[i * dim + j] /= count[i];
                }
            }
        }
        self.engine.features[ntype] = DistTensor::from_data(
            ntype,
            dim,
            feat,
            self.engine.book.clone(),
            self.engine.counters.clone(),
        );
        self.graph.schema.feature_sources[ntype] = FeatureSource::Dense;
        self.engine.embeds[ntype] = None;
    }

    /// Populate text embeddings for any text node type that does not
    /// have LM embeddings yet, using a deterministic hashed
    /// bag-of-tokens projection.  This is the zero-cost stand-in used
    /// when no LM stage runs (the LM trainer's `embed_all` overwrites
    /// these with real encoder outputs).
    pub fn ensure_text_features(&mut self, dim: usize) {
        for nt in 0..self.graph.schema.ntypes.len() {
            if self.graph.schema.feature_sources[nt] != FeatureSource::Text {
                continue;
            }
            if self.engine.text_emb[nt].dim != 0 {
                continue;
            }
            let Some(store) = &self.tokens[nt] else { continue };
            let n = store.num_rows();
            let mut emb = vec![0.0f32; n * dim];
            for i in 0..n {
                let row = store.row(i as u32);
                let mut cnt = 0f32;
                for &t in row {
                    if t == 0 {
                        continue;
                    }
                    // Two hashed buckets per token with ± sign: a cheap
                    // random projection of the bag-of-tokens vector.
                    let mut h = t as u64;
                    let h1 = crate::util::splitmix64(&mut h);
                    let h2 = crate::util::splitmix64(&mut h);
                    emb[i * dim + (h1 as usize % dim)] += if h1 >> 63 == 0 { 1.0 } else { -1.0 };
                    emb[i * dim + (h2 as usize % dim)] += if h2 >> 63 == 0 { 1.0 } else { -1.0 };
                    cnt += 1.0;
                }
                if cnt > 0.0 {
                    for j in 0..dim {
                        emb[i * dim + j] /= cnt.sqrt();
                    }
                }
            }
            self.engine.text_emb[nt] = DistTensor::from_data(
                nt,
                dim,
                emb,
                self.engine.book.clone(),
                self.engine.counters.clone(),
            );
        }
    }
}

/// Which learnable-embedding rows a batch gathered: (slot, ntype, id).
pub type LembTouch = Vec<(usize, usize, u32)>;

/// Helper: BlockShape::from_spec with a useful error.
struct BlockSpecErr;

impl BlockSpecErr {
    fn from_spec(spec: &ArtifactSpec) -> Result<BlockShape> {
        BlockShape::from_spec(spec)
            .ok_or_else(|| anyhow::anyhow!("artifact '{}' has no block config", spec.file))
    }
}

/// Assemble the shared GNN block inputs (feat/text/lemb/src_sel/ntype +
/// per-layer edge arrays), in manifest order.
pub fn assemble_block_inputs(
    ds: &GsDataset,
    block: &Block,
    spec: &ArtifactSpec,
    worker: u32,
) -> Result<(Vec<Tensor>, LembTouch)> {
    let n0 = block.shape.ns[0];
    let fdim = spec.batch_spec("feat").map(|t| t.shape[1]).unwrap_or(0);
    let tdim = spec.batch_spec("text").map(|t| t.shape[1]).unwrap_or(0);
    let ldim = spec.batch_spec("lemb").map(|t| t.shape[1]).unwrap_or(0);

    let mut feat = vec![0.0f32; n0 * fdim];
    let mut text = vec![0.0f32; n0 * tdim];
    let mut lemb = vec![0.0f32; n0 * ldim];
    let mut src_sel = vec![0.0f32; n0 * 3];
    let mut ntype = vec![0i32; n0];
    let mut touch: LembTouch = Vec::new();

    // Group slots per node type for batched gathers.
    let mut per_nt: Vec<(Vec<usize>, Vec<u32>)> =
        vec![(vec![], vec![]); ds.graph.schema.ntypes.len()];
    for (i, &(nt, id)) in block.nodes.iter().enumerate() {
        if block.nmask[i] == 0.0 {
            continue;
        }
        ntype[i] = nt as i32;
        per_nt[nt as usize].0.push(i);
        per_nt[nt as usize].1.push(id);
    }

    for (nt, (slots, ids)) in per_nt.iter().enumerate() {
        if slots.is_empty() {
            continue;
        }
        match ds.graph.schema.feature_sources[nt] {
            FeatureSource::Dense => {
                let t = &ds.engine.features[nt];
                if t.dim == 0 {
                    bail!("ntype {nt} marked Dense but has no features");
                }
                let rows = t.gather(worker, ids);
                let d = t.dim.min(fdim);
                for (j, &slot) in slots.iter().enumerate() {
                    feat[slot * fdim..slot * fdim + d].copy_from_slice(&rows[j * t.dim..j * t.dim + d]);
                    src_sel[slot * 3] = 1.0;
                }
            }
            FeatureSource::Text => {
                let t = &ds.engine.text_emb[nt];
                if t.dim == 0 {
                    // Text embeddings not computed yet (LM stage pending):
                    // treat as zero-input but still select the text slot so
                    // the model shape stays consistent.
                    for &slot in slots {
                        src_sel[slot * 3 + 1] = 1.0;
                    }
                } else {
                    let rows = t.gather(worker, ids);
                    let d = t.dim.min(tdim);
                    for (j, &slot) in slots.iter().enumerate() {
                        text[slot * tdim..slot * tdim + d]
                            .copy_from_slice(&rows[j * t.dim..j * t.dim + d]);
                        src_sel[slot * 3 + 1] = 1.0;
                    }
                }
            }
            FeatureSource::Learnable => {
                let e = ds.engine.embeds[nt]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("ntype {nt} has no embedding table"))?;
                let mut rows = vec![0.0f32; ids.len() * e.dim];
                e.gather_into(worker, ids, &mut rows);
                let d = e.dim.min(ldim);
                for (j, &slot) in slots.iter().enumerate() {
                    lemb[slot * ldim..slot * ldim + d]
                        .copy_from_slice(&rows[j * e.dim..j * e.dim + d]);
                    src_sel[slot * 3 + 2] = 1.0;
                    touch.push((slot, nt, ids[j]));
                }
            }
        }
    }

    let mut out = vec![
        Tensor::F32 { shape: vec![n0, fdim], data: feat },
        Tensor::F32 { shape: vec![n0, tdim], data: text },
        Tensor::F32 { shape: vec![n0, ldim], data: lemb },
        Tensor::F32 { shape: vec![n0, 3], data: src_sel },
        Tensor::I32 { shape: vec![n0], data: ntype },
    ];
    for (l, le) in block.layers.iter().enumerate() {
        let e = block.shape.es[l];
        out.push(Tensor::I32 { shape: vec![e], data: le.src.clone() });
        out.push(Tensor::I32 { shape: vec![e], data: le.dst.clone() });
        out.push(Tensor::I32 { shape: vec![e], data: le.etype.clone() });
        out.push(Tensor::F32 { shape: vec![e], data: le.emask.clone() });
    }
    Ok((out, touch))
}

/// Apply the train step's `grad_lemb` back onto the embedding tables.
pub fn apply_lemb_grads(
    engine: &mut DistEngine,
    touch: &LembTouch,
    grad: &[f32],
    ldim: usize,
    lr: f32,
) {
    if touch.is_empty() {
        return;
    }
    // Group by ntype, then one sparse-Adam call per table.
    let mut per_nt: HashMap<usize, (Vec<u32>, Vec<f32>)> = HashMap::new();
    for &(slot, nt, id) in touch {
        let entry = per_nt.entry(nt).or_default();
        entry.0.push(id);
        entry.1.extend_from_slice(&grad[slot * ldim..(slot + 1) * ldim]);
    }
    for (nt, (ids, grads)) in per_nt {
        if let Some(e) = engine.embeds[nt].as_mut() {
            // Table dim == ldim by construction (engine.add_embed uses the
            // manifest's lemb dim).
            e.sparse_adam(&ids, &grads, lr);
        }
    }
}

/// Node-classification loader: seeds → block → manifest-ordered batch.
pub struct NodeDataLoader {
    pub spec: ArtifactSpec,
    pub shape: BlockShape,
}

impl NodeDataLoader {
    pub fn new(spec: &ArtifactSpec) -> Result<NodeDataLoader> {
        let shape = BlockSpecErr::from_spec(spec)?;
        Ok(NodeDataLoader { spec: spec.clone(), shape })
    }

    /// Max real seeds per batch (the artifact's padded target count).
    pub fn batch_size(&self) -> usize {
        self.spec.cfg_usize("batch").unwrap_or(self.shape.num_targets())
    }

    /// Build one batch for `seeds` (node ids of the target ntype).
    pub fn batch(
        &self,
        ds: &GsDataset,
        seeds: &[u32],
        rng: &mut Rng,
        worker: u32,
    ) -> Result<(Vec<Tensor>, LembTouch, Block)> {
        let nt = ds.target_ntype as u32;
        let seed_pairs: Vec<(u32, u32)> = seeds.iter().map(|&s| (nt, s)).collect();
        let sampler = NeighborSampler::new(&ds.graph);
        let block = sampler.sample_block(&seed_pairs, &self.shape, rng, &EdgeExclusion::new());
        let (mut batch, touch) = assemble_block_inputs(ds, &block, &self.spec, worker)?;

        let ntargets = self.shape.num_targets();
        let labels_store = ds.node_labels();
        let mut labels = vec![0i32; ntargets];
        let mut lmask = vec![0.0f32; ntargets];
        for (i, &(_, id)) in block.targets().iter().enumerate() {
            labels[i] = labels_store.labels[id as usize];
            lmask[i] = 1.0;
        }
        batch.push(Tensor::I32 { shape: vec![ntargets], data: labels });
        batch.push(Tensor::F32 { shape: vec![ntargets], data: lmask });
        Ok((batch, touch, block))
    }
}

/// Link-prediction loader: positive edges + negatives → batch.
pub struct LinkPredictionDataLoader {
    pub spec: ArtifactSpec,
    pub shape: BlockShape,
    pub sampler: NegSampler,
    /// Exclude validation/test edges from message passing (leak guard)
    /// and the batch's own positives (overfit guard) — paper §3.3.4.
    pub exclude_targets: bool,
}

impl LinkPredictionDataLoader {
    pub fn new(spec: &ArtifactSpec, sampler: NegSampler) -> Result<LinkPredictionDataLoader> {
        let shape = BlockSpecErr::from_spec(spec)?;
        Ok(LinkPredictionDataLoader {
            spec: spec.clone(),
            shape,
            sampler,
            exclude_targets: true,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.spec.cfg_usize("lp_batch").unwrap_or(32)
    }

    /// Build a batch for positive edge ids of the LP task's etype.
    pub fn batch(
        &self,
        ds: &GsDataset,
        edge_ids: &[u32],
        rng: &mut Rng,
        worker: u32,
    ) -> Result<(Vec<Tensor>, LembTouch)> {
        let lp = ds.lp.as_ref().expect("dataset has no LP task");
        let et = lp.etype;
        let def = &ds.graph.schema.etypes[et];
        let es = &ds.graph.edges[et];
        let b = self.batch_size();
        let k = self.spec.cfg_usize("k").unwrap_or(self.sampler.k());
        assert!(edge_ids.len() <= b);
        assert_eq!(self.sampler.k(), k, "sampler K must match the artifact");

        let n_dst = ds.graph.num_nodes[def.dst_ntype];
        let negs = sample_negatives(
            self.sampler,
            b,
            n_dst,
            def.dst_ntype,
            &ds.engine.book,
            worker,
            rng,
        );

        // Seed slots: [srcs | dsts | negs], padded with node 0.
        let mut seeds: Vec<(u32, u32)> = Vec::with_capacity(2 * b + negs.neg_nodes.len());
        let (snt, dnt) = (def.src_ntype as u32, def.dst_ntype as u32);
        for i in 0..b {
            let eid = edge_ids.get(i).copied().unwrap_or(edge_ids[0]);
            seeds.push((snt, es.src[eid as usize]));
        }
        for i in 0..b {
            let eid = edge_ids.get(i).copied().unwrap_or(edge_ids[0]);
            seeds.push((dnt, es.dst[eid as usize]));
        }
        for &n in &negs.neg_nodes {
            seeds.push((dnt, n));
        }

        // CAREFUL: seeds may contain duplicates; the block dedups, so we
        // must map each logical seed position to its slot.
        let exclusion = self.build_exclusion(ds, edge_ids, et);
        let nsampler = NeighborSampler::new(&ds.graph);
        let dedup: Vec<(u32, u32)> = {
            let mut seen = std::collections::HashMap::new();
            let mut out = vec![];
            for &s in &seeds {
                seen.entry(s).or_insert_with(|| {
                    out.push(s);
                    out.len() - 1
                });
            }
            out
        };
        let block = nsampler.sample_block(&dedup, &self.shape, rng, &exclusion);
        let slot_of: HashMap<(u32, u32), i32> = block
            .targets()
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as i32))
            .collect();
        let slot = |p: (u32, u32)| slot_of[&p];

        let (mut batch, touch) = assemble_block_inputs(ds, &block, &self.spec, worker)?;

        let mut pos_src = vec![0i32; b];
        let mut pos_dst = vec![0i32; b];
        let mut rel = vec![0i32; b];
        let mut pmask = vec![0.0f32; b];
        let mut eweight = vec![1.0f32; b];
        for i in 0..b {
            pos_src[i] = slot(seeds[i]);
            pos_dst[i] = slot(seeds[b + i]);
            rel[i] = et as i32;
            if i < edge_ids.len() {
                pmask[i] = 1.0;
            } else {
                eweight[i] = 0.0;
            }
        }
        let mut neg_dst = vec![0i32; b * k];
        for i in 0..b {
            for (j, &pos) in negs.neg_dst[i].iter().enumerate() {
                // pos indexes the logical seed array; map through dedup.
                neg_dst[i * k + j] = slot(seeds[pos as usize]);
            }
        }
        batch.push(Tensor::I32 { shape: vec![b], data: pos_src });
        batch.push(Tensor::I32 { shape: vec![b], data: pos_dst });
        batch.push(Tensor::I32 { shape: vec![b, k], data: neg_dst });
        batch.push(Tensor::I32 { shape: vec![b], data: rel });
        batch.push(Tensor::F32 { shape: vec![b], data: pmask });
        batch.push(Tensor::F32 { shape: vec![b], data: eweight });
        Ok((batch, touch))
    }

    fn build_exclusion(&self, ds: &GsDataset, edge_ids: &[u32], et: usize) -> EdgeExclusion {
        let mut ex = EdgeExclusion::new();
        if !self.exclude_targets {
            return ex;
        }
        let es = &ds.graph.edges[et];
        let rev = ds.rev_map.get(&et).map(|&r| r as u32);
        // The batch's own positives...
        for &eid in edge_ids {
            ex.insert_with_reverse(et as u32, rev, es.src[eid as usize], es.dst[eid as usize]);
        }
        // ...and every val/test edge (information-leak guard).
        if let Some(lp) = &ds.lp {
            for (eid, &s) in lp.split.iter().enumerate() {
                if s == Split::Val || s == Split::Test {
                    ex.insert_with_reverse(et as u32, rev, es.src[eid], es.dst[eid]);
                }
            }
        }
        ex
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{self, mag};
    use crate::partition::PartitionBook;

    fn mag_ds(n: usize) -> GsDataset {
        let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
        let book = PartitionBook::single(&raw.graph.num_nodes);
        datagen::build_dataset(raw, book, 64, 3)
    }

    #[test]
    fn text_fallback_fills_only_text_types() {
        let mut ds = mag_ds(300);
        assert_eq!(ds.engine.text_emb[0].dim, 0);
        ds.ensure_text_features(32);
        assert_eq!(ds.engine.text_emb[0].dim, 32); // papers
        assert_eq!(ds.engine.text_emb[1].dim, 0); // authors featureless
        // Rows are unit-ish normalized and non-zero for real text.
        let row = ds.engine.text_emb[0].row(0);
        assert!(row.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn neighbor_feature_construction_switches_source() {
        let mut ds = mag_ds(300);
        ds.ensure_text_features(64);
        let nt_author = 1;
        assert_eq!(ds.graph.schema.feature_sources[nt_author], FeatureSource::Learnable);
        ds.construct_neighbor_features(nt_author, 64);
        assert_eq!(ds.graph.schema.feature_sources[nt_author], FeatureSource::Dense);
        assert!(ds.engine.embeds[nt_author].is_none());
        let t = &ds.engine.features[nt_author];
        assert_eq!(t.dim, 64);
        // Authors with papers must have non-zero constructed features.
        let nonzero = (0..t.num_rows())
            .filter(|&i| t.row(i as u32).iter().any(|&x| x != 0.0))
            .count();
        assert!(nonzero > t.num_rows() / 2, "{nonzero}/{}", t.num_rows());
    }

    #[test]
    fn neighbor_features_are_neighbor_means() {
        // Hand-built: one featureless type fed by one dense type.
        use crate::graph::{EdgeTypeDef, HeteroGraph, Schema};
        let schema = Schema::new(
            vec!["a".into(), "b".into()],
            vec![EdgeTypeDef { name: "ab".into(), src_ntype: 0, dst_ntype: 1 }],
        )
        .with_sources(vec![FeatureSource::Dense, FeatureSource::Learnable]);
        let mut g = HeteroGraph::new(schema, vec![2, 1]);
        g.set_edges(0, vec![0, 1], vec![0, 0]);
        let raw = crate::datagen::RawData {
            graph: g,
            features: vec![(2, vec![1.0, 2.0, 3.0, 4.0]), (0, vec![])],
            labels: vec![None, None],
            tokens: vec![None, None],
            target_ntype: 0,
            num_classes: 2,
            lp_etype: None,
            rev_map: Default::default(),
        };
        let book = PartitionBook::single(&raw.graph.num_nodes);
        let mut ds = datagen::build_dataset(raw, book, 8, 0);
        ds.construct_neighbor_features(1, 2);
        assert_eq!(ds.engine.features[1].row(0), &[2.0, 3.0]); // mean of rows
    }

    #[test]
    fn splits_partition_ids() {
        let ds = mag_ds(500);
        let l = ds.node_labels();
        let (tr, va, te) = (
            l.ids_in(Split::Train).len(),
            l.ids_in(Split::Val).len(),
            l.ids_in(Split::Test).len(),
        );
        assert_eq!(tr + va + te, 500);
        assert!(tr > va && tr > te);
    }
}
