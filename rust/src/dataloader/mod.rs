//! Datasets and task data loaders.
//!
//! `GsDataset` bundles everything a task needs: the graph, the
//! distributed engine (features / text embeddings / learnable tables),
//! labels, token stores and split masks.  The loaders turn sampled
//! blocks into the exact manifest-ordered tensor lists the AOT
//! artifacts consume:
//!
//! * `NodeDataLoader` — node classification batches,
//! * `LinkPredictionDataLoader` — LP batches with negative sampling
//!   (a separate loader from edge-feature prediction, as in the paper
//!   §3: LP must construct negatives, so it gets its own path).

pub mod prefetch;

pub use prefetch::{
    autoscale_workers, batch_seed, run_pipeline, run_pipeline_pooled, PrefetchConfig,
    MAX_AUTO_WORKERS,
};

use anyhow::{anyhow, bail, Result};
use std::sync::{Arc, OnceLock};

use crate::dist::{DistEngine, DistTensor};
use crate::graph::{FeatureSource, HeteroGraph};
use crate::runtime::{ArtifactSpec, Tensor};
use crate::sampling::{
    negative::sample_negatives, Block, BlockShape, EdgeExclusion, NegSampler, NeighborSampler,
    SamplerScratch, SeedIndex,
};
use crate::util::{FxHashMap, Rng};

/// Train/val/test membership.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
    None,
}

/// Node-classification labels over one node type.
#[derive(Debug, Clone)]
pub struct NodeLabels {
    pub labels: Vec<i32>,
    pub split: Vec<Split>,
}

impl NodeLabels {
    pub fn ids_in(&self, s: Split) -> Vec<u32> {
        self.split
            .iter()
            .enumerate()
            .filter(|(_, &x)| x == s)
            .map(|(i, _)| i as u32)
            .collect()
    }
}

/// Token sequences over one node type ([n, seq_len], PAD=0).
#[derive(Debug, Clone)]
pub struct TokenStore {
    pub seq_len: usize,
    pub tokens: Vec<i32>,
}

impl TokenStore {
    pub fn row(&self, id: u32) -> &[i32] {
        &self.tokens[id as usize * self.seq_len..(id as usize + 1) * self.seq_len]
    }

    pub fn num_rows(&self) -> usize {
        self.tokens.len() / self.seq_len
    }
}

/// Link-prediction task: target edge type + per-edge split.
#[derive(Debug, Clone)]
pub struct LpTask {
    pub etype: usize,
    pub split: Vec<Split>,
}

impl LpTask {
    pub fn edge_ids_in(&self, s: Split) -> Vec<u32> {
        self.split
            .iter()
            .enumerate()
            .filter(|(_, &x)| x == s)
            .map(|(i, _)| i as u32)
            .collect()
    }
}

/// Everything one application dataset carries.
pub struct GsDataset {
    pub graph: HeteroGraph,
    pub engine: DistEngine,
    /// Per-ntype classification labels (at most one labelled type used).
    pub labels: Vec<Option<NodeLabels>>,
    /// Per-ntype token stores for text node types.
    pub tokens: Vec<Option<TokenStore>>,
    pub target_ntype: usize,
    pub num_classes: usize,
    pub lp: Option<LpTask>,
    /// etype -> reverse etype (for target-edge exclusion).
    pub rev_map: FxHashMap<usize, usize>,
}

impl GsDataset {
    pub fn node_labels(&self) -> &NodeLabels {
        self.labels[self.target_ntype].as_ref().expect("dataset has no labels")
    }

    /// Paper §3.3.2, option 1: construct features for a featureless
    /// node type from its neighbors that *have* features
    /// (`F'_v = f(F_u, u ∈ N(v))`, eq. 1, with f = mean).  The node
    /// type is switched to `Dense` afterwards, so the input encoder
    /// consumes the constructed features instead of the embedding
    /// table — the alternative to learnable embeddings the paper
    /// offers for massive featureless types.
    pub fn construct_neighbor_features(&mut self, ntype: usize, dim: usize) {
        let n = self.graph.num_nodes[ntype];
        let mut feat = vec![0.0f32; n * dim];
        let mut count = vec![0.0f32; n];
        for et in self.graph.etypes_into(ntype) {
            let src_nt = self.graph.schema.etypes[et].src_ntype;
            // Source rows come from dense features or text embeddings.
            let (rows, rdim): (&DistTensor, usize) =
                match self.graph.schema.feature_sources[src_nt] {
                    FeatureSource::Dense => {
                        let t = &self.engine.features[src_nt];
                        (t, t.dim)
                    }
                    FeatureSource::Text => {
                        let t = &self.engine.text_emb[src_nt];
                        (t, t.dim)
                    }
                    FeatureSource::Learnable => continue,
                };
            if rdim == 0 {
                continue;
            }
            let d = rdim.min(dim);
            let es = &self.graph.edges[et];
            for (&s, &dst) in es.src.iter().zip(&es.dst) {
                let row = rows.row(s);
                let base = dst as usize * dim;
                for j in 0..d {
                    feat[base + j] += row[j];
                }
                count[dst as usize] += 1.0;
            }
        }
        for i in 0..n {
            if count[i] > 0.0 {
                for j in 0..dim {
                    feat[i * dim + j] /= count[i];
                }
            }
        }
        self.engine.features[ntype] = DistTensor::from_data(
            ntype,
            dim,
            feat,
            self.engine.book.clone(),
            self.engine.counters.clone(),
        );
        self.graph.schema.feature_sources[ntype] = FeatureSource::Dense;
        self.engine.embeds[ntype] = None;
    }

    /// Populate text embeddings for any text node type that does not
    /// have LM embeddings yet, using a deterministic hashed
    /// bag-of-tokens projection.  This is the zero-cost stand-in used
    /// when no LM stage runs (the LM trainer's `embed_all` overwrites
    /// these with real encoder outputs).
    pub fn ensure_text_features(&mut self, dim: usize) {
        for nt in 0..self.graph.schema.ntypes.len() {
            if self.graph.schema.feature_sources[nt] != FeatureSource::Text {
                continue;
            }
            if self.engine.text_emb[nt].dim != 0 {
                continue;
            }
            let Some(store) = &self.tokens[nt] else { continue };
            let n = store.num_rows();
            let mut emb = vec![0.0f32; n * dim];
            for i in 0..n {
                let row = store.row(i as u32);
                let mut cnt = 0f32;
                for &t in row {
                    if t == 0 {
                        continue;
                    }
                    // Two hashed buckets per token with ± sign: a cheap
                    // random projection of the bag-of-tokens vector.
                    let mut h = t as u64;
                    let h1 = crate::util::splitmix64(&mut h);
                    let h2 = crate::util::splitmix64(&mut h);
                    emb[i * dim + (h1 as usize % dim)] += if h1 >> 63 == 0 { 1.0 } else { -1.0 };
                    emb[i * dim + (h2 as usize % dim)] += if h2 >> 63 == 0 { 1.0 } else { -1.0 };
                    cnt += 1.0;
                }
                if cnt > 0.0 {
                    for j in 0..dim {
                        emb[i * dim + j] /= cnt.sqrt();
                    }
                }
            }
            self.engine.text_emb[nt] = DistTensor::from_data(
                nt,
                dim,
                emb,
                self.engine.book.clone(),
                self.engine.counters.clone(),
            );
        }
    }
}

/// Which learnable-embedding rows a batch gathered: (slot, ntype, id).
pub type LembTouch = Vec<(usize, usize, u32)>;

/// One epoch's work list: ids shuffled, optionally capped, split into
/// fixed-size chunks.  Every trainer builds exactly this each epoch;
/// owning the backing ids lets heterogeneous (multi-task) schedules
/// hold several tasks' chunk lists at once and route batches by
/// schedule index.  The shuffle draws from the caller's RNG in the
/// same order the trainers always did (shuffle, then truncate), so
/// adopting `IdChunks` changes no batch stream.
pub struct IdChunks {
    ids: Vec<u32>,
    chunk: usize,
}

impl IdChunks {
    /// Shuffle `ids` with `rng`, keep at most `cap` (None = all), and
    /// expose them as `chunk`-sized batches.
    pub fn new(mut ids: Vec<u32>, chunk: usize, cap: Option<usize>, rng: &mut Rng) -> IdChunks {
        rng.shuffle(&mut ids);
        if let Some(c) = cap {
            ids.truncate(c);
        }
        IdChunks { ids, chunk: chunk.max(1) }
    }

    /// Number of batches.
    pub fn len(&self) -> usize {
        self.ids.len().div_ceil(self.chunk)
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Batch `i` (the last one may be short).
    pub fn get(&self, i: usize) -> &[u32] {
        let lo = i * self.chunk;
        &self.ids[lo..(lo + self.chunk).min(self.ids.len())]
    }

    /// All batches, in order — the `&[&[u32]]` shape `run_pipeline`
    /// and the prefetching loaders consume.
    pub fn chunks(&self) -> Vec<&[u32]> {
        self.ids.chunks(self.chunk).collect()
    }
}

/// Helper: BlockShape::from_spec with a useful error.
struct BlockSpecErr;

impl BlockSpecErr {
    fn from_spec(spec: &ArtifactSpec) -> Result<BlockShape> {
        BlockShape::from_spec(spec)
            .ok_or_else(|| anyhow::anyhow!("artifact '{}' has no block config", spec.file))
    }
}

/// Assemble the shared GNN block inputs (feat/text/lemb/src_sel/ntype +
/// per-layer edge arrays), in manifest order.
pub fn assemble_block_inputs(
    ds: &GsDataset,
    block: &Block,
    spec: &ArtifactSpec,
    worker: u32,
) -> Result<(Vec<Tensor>, LembTouch)> {
    assemble_block_inputs_ext(ds, block, spec, worker, false)
}

/// Like [`assemble_block_inputs`], but with `defer_lemb = true` the
/// learnable-embedding rows are left zero and only recorded in the
/// touch list, to be filled by [`fill_lemb`] on the training thread
/// right before the step.  This is what lets prefetch workers build
/// batches ahead without ever reading embedding rows that a
/// not-yet-applied sparse update would change — output stays
/// bit-identical to the serial loader for any worker count.
///
/// Convenience wrapper over [`assemble_block_inputs_into`] that
/// allocates fresh output tensors (pipelined loaders need owned
/// batches to send across the channel).
pub fn assemble_block_inputs_ext(
    ds: &GsDataset,
    block: &Block,
    spec: &ArtifactSpec,
    worker: u32,
    defer_lemb: bool,
) -> Result<(Vec<Tensor>, LembTouch)> {
    let mut out = Vec::new();
    let mut touch = LembTouch::new();
    let mut scratch = AssembleScratch::default();
    assemble_block_inputs_into(ds, block, spec, worker, defer_lemb, &mut scratch, &mut out, &mut touch)?;
    Ok((out, touch))
}

/// Reusable per-worker assembly buffers: per-ntype slot/id grouping and
/// the row-gather staging area.  Together with recycled output tensors
/// (see [`assemble_block_inputs_into`]) assembly performs zero heap
/// allocation in steady state — the serving engine's double-buffer
/// ring and `benches/serve.rs` assert this.
#[derive(Default)]
pub struct AssembleScratch {
    per_nt: Vec<(Vec<usize>, Vec<u32>)>,
    rows: Vec<f32>,
}

/// Recycle `t` as an f32 tensor of `shape`, zero-filled; reuses the
/// existing data allocation when the capacity suffices.
fn reuse_f32<'t>(t: &'t mut Tensor, shape: &[usize]) -> &'t mut Vec<f32> {
    let n: usize = shape.iter().product();
    if !matches!(t, Tensor::F32 { .. }) {
        *t = Tensor::F32 { shape: shape.to_vec(), data: Vec::new() };
    }
    let Tensor::F32 { shape: s, data } = t else { unreachable!() };
    if s.as_slice() != shape {
        s.clear();
        s.extend_from_slice(shape);
    }
    data.clear();
    data.resize(n, 0.0);
    data
}

/// Recycle `t` as an i32 tensor of `shape` filled from `src`.
fn copy_i32(t: &mut Tensor, shape: &[usize], src: &[i32]) {
    if !matches!(t, Tensor::I32 { .. }) {
        *t = Tensor::I32 { shape: shape.to_vec(), data: Vec::new() };
    }
    let Tensor::I32 { shape: s, data } = t else { unreachable!() };
    if s.as_slice() != shape {
        s.clear();
        s.extend_from_slice(shape);
    }
    data.clear();
    data.extend_from_slice(src);
}

/// Recycle `t` as an f32 tensor of `shape` filled from `src`.
fn copy_f32(t: &mut Tensor, shape: &[usize], src: &[f32]) {
    if !matches!(t, Tensor::F32 { .. }) {
        *t = Tensor::F32 { shape: shape.to_vec(), data: Vec::new() };
    }
    let Tensor::F32 { shape: s, data } = t else { unreachable!() };
    if s.as_slice() != shape {
        s.clear();
        s.extend_from_slice(shape);
    }
    data.clear();
    data.extend_from_slice(src);
}

/// Assemble the shared GNN block inputs into recycled buffers: `out`
/// and `touch` keep their allocations across batches (double-buffer
/// callers alternate two `out` vectors so the previous batch's
/// tensors stay intact while the next one assembles).  Produces
/// exactly the same tensor values as [`assemble_block_inputs_ext`].
#[allow(clippy::too_many_arguments)]
pub fn assemble_block_inputs_into(
    ds: &GsDataset,
    block: &Block,
    spec: &ArtifactSpec,
    worker: u32,
    defer_lemb: bool,
    scratch: &mut AssembleScratch,
    out: &mut Vec<Tensor>,
    touch: &mut LembTouch,
) -> Result<()> {
    let n0 = block.shape.ns[0];
    let fdim = spec.batch_spec("feat").map(|t| t.shape[1]).unwrap_or(0);
    let tdim = spec.batch_spec("text").map(|t| t.shape[1]).unwrap_or(0);
    let ldim = spec.batch_spec("lemb").map(|t| t.shape[1]).unwrap_or(0);
    touch.clear();

    let total = 5 + 4 * block.layers.len();
    if out.len() != total {
        out.clear();
        out.resize(total, Tensor::F32 { shape: vec![], data: vec![] });
    }
    let [t_feat, t_text, t_lemb, t_sel, t_nty, layer_slots @ ..] = out.as_mut_slice() else {
        unreachable!("out was just sized to >= 5 tensors");
    };
    let feat = reuse_f32(t_feat, &[n0, fdim]);
    let text = reuse_f32(t_text, &[n0, tdim]);
    let lemb = reuse_f32(t_lemb, &[n0, ldim]);
    let src_sel = reuse_f32(t_sel, &[n0, 3]);
    // ntype is filled during grouping, so recycle it by hand.
    if !matches!(t_nty, Tensor::I32 { .. }) {
        *t_nty = Tensor::I32 { shape: vec![n0], data: Vec::new() };
    }
    let Tensor::I32 { shape: nty_shape, data: ntype } = t_nty else { unreachable!() };
    if nty_shape.len() != 1 || nty_shape[0] != n0 {
        nty_shape.clear();
        nty_shape.push(n0);
    }
    ntype.clear();
    ntype.resize(n0, 0);

    // Group slots per node type for batched gathers.
    let n_ntypes = ds.graph.schema.ntypes.len();
    let per_nt = &mut scratch.per_nt;
    if per_nt.len() < n_ntypes {
        per_nt.resize_with(n_ntypes, Default::default);
    }
    for (slots, ids) in per_nt.iter_mut() {
        slots.clear();
        ids.clear();
    }
    for (i, &(nt, id)) in block.nodes.iter().enumerate() {
        if block.nmask[i] == 0.0 {
            continue;
        }
        ntype[i] = nt as i32;
        per_nt[nt as usize].0.push(i);
        per_nt[nt as usize].1.push(id);
    }

    let rows = &mut scratch.rows;
    for (nt, (slots, ids)) in per_nt.iter().enumerate().take(n_ntypes) {
        if slots.is_empty() {
            continue;
        }
        match ds.graph.schema.feature_sources[nt] {
            FeatureSource::Dense => {
                let t = &ds.engine.features[nt];
                if t.dim == 0 {
                    bail!("ntype {nt} marked Dense but has no features");
                }
                rows.clear();
                rows.resize(ids.len() * t.dim, 0.0);
                t.gather_into(worker, ids, rows);
                let d = t.dim.min(fdim);
                for (j, &slot) in slots.iter().enumerate() {
                    feat[slot * fdim..slot * fdim + d].copy_from_slice(&rows[j * t.dim..j * t.dim + d]);
                    src_sel[slot * 3] = 1.0;
                }
            }
            FeatureSource::Text => {
                let t = &ds.engine.text_emb[nt];
                if t.dim == 0 {
                    // Text embeddings not computed yet (LM stage pending):
                    // treat as zero-input but still select the text slot so
                    // the model shape stays consistent.
                    for &slot in slots {
                        src_sel[slot * 3 + 1] = 1.0;
                    }
                } else {
                    rows.clear();
                    rows.resize(ids.len() * t.dim, 0.0);
                    t.gather_into(worker, ids, rows);
                    let d = t.dim.min(tdim);
                    for (j, &slot) in slots.iter().enumerate() {
                        text[slot * tdim..slot * tdim + d]
                            .copy_from_slice(&rows[j * t.dim..j * t.dim + d]);
                        src_sel[slot * 3 + 1] = 1.0;
                    }
                }
            }
            FeatureSource::Learnable => {
                let e = ds.engine.embeds[nt]
                    .as_ref()
                    .ok_or_else(|| anyhow!("ntype {nt} has no embedding table"))?;
                for (j, &slot) in slots.iter().enumerate() {
                    src_sel[slot * 3 + 2] = 1.0;
                    touch.push((slot, nt, ids[j]));
                }
                if !defer_lemb {
                    rows.clear();
                    rows.resize(ids.len() * e.dim, 0.0);
                    e.gather_into(worker, ids, rows);
                    let d = e.dim.min(ldim);
                    for (j, &slot) in slots.iter().enumerate() {
                        lemb[slot * ldim..slot * ldim + d]
                            .copy_from_slice(&rows[j * e.dim..j * e.dim + d]);
                    }
                }
            }
        }
    }

    for (l, le) in block.layers.iter().enumerate() {
        let e = block.shape.es[l];
        copy_i32(&mut layer_slots[4 * l], &[e], &le.src);
        copy_i32(&mut layer_slots[4 * l + 1], &[e], &le.dst);
        copy_i32(&mut layer_slots[4 * l + 2], &[e], &le.etype);
        copy_f32(&mut layer_slots[4 * l + 3], &[e], &le.emask);
    }
    Ok(())
}

/// Fill the deferred learnable-embedding rows of an assembled batch
/// (`batch[2]`, see [`assemble_block_inputs_ext`]) from the current
/// tables, attributed to partition `worker` for traffic accounting.
pub fn fill_lemb(
    ds: &GsDataset,
    batch: &mut [Tensor],
    touch: &LembTouch,
    worker: u32,
) -> Result<()> {
    if touch.is_empty() {
        return Ok(());
    }
    let Tensor::F32 { shape, data } = &mut batch[2] else {
        bail!("batch[2] must be the f32 lemb tensor");
    };
    let ldim = shape[1];
    if ldim == 0 {
        return Ok(());
    }
    // Group touched slots by ntype for batched gathers.
    let mut per_nt: Vec<(Vec<usize>, Vec<u32>)> = vec![(vec![], vec![]); ds.engine.embeds.len()];
    for &(slot, nt, id) in touch {
        per_nt[nt].0.push(slot);
        per_nt[nt].1.push(id);
    }
    for (nt, (slots, ids)) in per_nt.iter().enumerate() {
        if slots.is_empty() {
            continue;
        }
        let e = ds.engine.embeds[nt]
            .as_ref()
            .ok_or_else(|| anyhow!("ntype {nt} has no embedding table"))?;
        let mut rows = vec![0.0f32; ids.len() * e.dim];
        e.gather_into(worker, ids, &mut rows);
        let d = e.dim.min(ldim);
        for (j, &slot) in slots.iter().enumerate() {
            data[slot * ldim..slot * ldim + d].copy_from_slice(&rows[j * e.dim..j * e.dim + d]);
        }
    }
    Ok(())
}

/// Apply the train step's `grad_lemb` back onto the embedding tables.
/// Takes `&DistEngine`: tables update through interior mutability, so
/// the engine can stay shared with prefetch workers.
pub fn apply_lemb_grads(
    engine: &DistEngine,
    touch: &LembTouch,
    grad: &[f32],
    ldim: usize,
    lr: f32,
) {
    if touch.is_empty() {
        return;
    }
    // Group by ntype (index-addressed: deterministic order), then one
    // sparse-Adam call per table.
    let mut per_nt: Vec<(Vec<u32>, Vec<f32>)> = vec![(vec![], vec![]); engine.embeds.len()];
    for &(slot, nt, id) in touch {
        per_nt[nt].0.push(id);
        per_nt[nt].1.extend_from_slice(&grad[slot * ldim..(slot + 1) * ldim]);
    }
    for (nt, (ids, grads)) in per_nt.iter().enumerate() {
        if ids.is_empty() {
            continue;
        }
        if let Some(e) = engine.embeds[nt].as_ref() {
            // Table dim == ldim by construction (engine.add_embed uses the
            // manifest's lemb dim).
            e.sparse_adam(ids, grads, lr);
        }
    }
}

/// Reusable per-worker batch-building state: sampler (with its cached
/// etype index), generation-stamped scratch, and a recycled block —
/// steady-state sampling does zero heap allocation.
pub struct BatchFactory<'a> {
    pub ds: &'a GsDataset,
    sampler: NeighborSampler<'a>,
    scratch: SamplerScratch,
    pub block: Block,
    seed_buf: Vec<(u32, u32)>,
    asm: AssembleScratch,
    /// Reusable first-seen seed index (LP dedup + slot lookup).
    pub seed_index: SeedIndex,
}

impl<'a> BatchFactory<'a> {
    pub fn new(ds: &'a GsDataset, shape: &BlockShape) -> BatchFactory<'a> {
        BatchFactory {
            ds,
            sampler: NeighborSampler::new(&ds.graph),
            scratch: SamplerScratch::new(),
            block: Block::empty(shape),
            seed_buf: vec![],
            asm: AssembleScratch::default(),
            seed_index: SeedIndex::new(),
        }
    }

    /// Sample a block for `seeds` and assemble the shared GNN inputs.
    /// The block stays in the factory (see [`Self::targets`]).
    pub fn sample_assemble(
        &mut self,
        seeds: &[(u32, u32)],
        shape: &BlockShape,
        spec: &ArtifactSpec,
        rng: &mut Rng,
        worker: u32,
        exclude: &EdgeExclusion,
        defer_lemb: bool,
    ) -> Result<(Vec<Tensor>, LembTouch)> {
        self.sampler
            .sample_block_with(seeds, shape, rng, exclude, &mut self.scratch, &mut self.block);
        let mut out = Vec::new();
        let mut touch = LembTouch::new();
        assemble_block_inputs_into(
            self.ds, &self.block, spec, worker, defer_lemb, &mut self.asm, &mut out, &mut touch,
        )?;
        Ok((out, touch))
    }

    /// Canonical-per-node sampling + assembly into recycled buffers
    /// (`out`/`touch` keep their allocations — the serving engine's
    /// double-buffer ring alternates two of them).  Seeds must be
    /// distinct; no edge exclusion (serving never leaks labels).
    #[allow(clippy::too_many_arguments)]
    pub fn sample_assemble_canonical_into(
        &mut self,
        seeds: &[(u32, u32)],
        shape: &BlockShape,
        spec: &ArtifactSpec,
        base_seed: u64,
        worker: u32,
        out: &mut Vec<Tensor>,
        touch: &mut LembTouch,
    ) -> Result<()> {
        self.sampler.sample_block_canonical(
            seeds,
            shape,
            base_seed,
            &EdgeExclusion::new(),
            &mut self.scratch,
            &mut self.block,
        );
        if self.block.n_real_targets != seeds.len() {
            bail!(
                "serving seeds must be distinct: {} seeds deduped to {} targets",
                seeds.len(),
                self.block.n_real_targets
            );
        }
        assemble_block_inputs_into(
            self.ds, &self.block, spec, worker, false, &mut self.asm, out, touch,
        )
    }

    /// Real targets of the most recently sampled block.
    pub fn targets(&self) -> &[(u32, u32)] {
        self.block.targets()
    }
}

/// Node-classification loader: seeds → block → manifest-ordered batch.
pub struct NodeDataLoader {
    pub spec: ArtifactSpec,
    pub shape: BlockShape,
}

impl NodeDataLoader {
    pub fn new(spec: &ArtifactSpec) -> Result<NodeDataLoader> {
        let shape = BlockSpecErr::from_spec(spec)?;
        Ok(NodeDataLoader { spec: spec.clone(), shape })
    }

    /// Max real seeds per batch (the artifact's padded target count).
    pub fn batch_size(&self) -> usize {
        self.spec.cfg_usize("batch").unwrap_or(self.shape.num_targets())
    }

    /// Build one batch for `seeds` (node ids of the target ntype).
    /// Convenience wrapper allocating fresh factory state; hot loops
    /// should reuse a [`BatchFactory`] via [`build_nc_batch`].
    pub fn batch(
        &self,
        ds: &GsDataset,
        seeds: &[u32],
        rng: &mut Rng,
        worker: u32,
    ) -> Result<(Vec<Tensor>, LembTouch, Block)> {
        let mut f = BatchFactory::new(ds, &self.shape);
        let (batch, touch) = build_nc_batch(&mut f, self, seeds, rng, worker, false)?;
        Ok((batch, touch, f.block))
    }
}

/// Node-classification batch through a reusable factory; with
/// `defer_lemb` the embedding rows are filled later by [`fill_lemb`].
pub fn build_nc_batch(
    f: &mut BatchFactory,
    loader: &NodeDataLoader,
    seeds: &[u32],
    rng: &mut Rng,
    worker: u32,
    defer_lemb: bool,
) -> Result<(Vec<Tensor>, LembTouch)> {
    let nt = f.ds.target_ntype as u32;
    let mut seed_pairs = std::mem::take(&mut f.seed_buf);
    seed_pairs.clear();
    seed_pairs.extend(seeds.iter().map(|&s| (nt, s)));
    let out = f.sample_assemble(
        &seed_pairs,
        &loader.shape,
        &loader.spec,
        rng,
        worker,
        &EdgeExclusion::new(),
        defer_lemb,
    );
    f.seed_buf = seed_pairs;
    let (mut batch, touch) = out?;

    let ntargets = loader.shape.num_targets();
    let labels_store = f.ds.node_labels();
    let mut labels = vec![0i32; ntargets];
    let mut lmask = vec![0.0f32; ntargets];
    for (i, &(_, id)) in f.targets().iter().enumerate() {
        labels[i] = labels_store.labels[id as usize];
        lmask[i] = 1.0;
    }
    batch.push(Tensor::I32 { shape: vec![ntargets], data: labels });
    batch.push(Tensor::F32 { shape: vec![ntargets], data: lmask });
    Ok((batch, touch))
}

/// The pipelined NC loader: shards seed chunks across worker threads
/// which sample + assemble ahead, while the calling thread consumes
/// batches in order (typically running the PJRT step).
///
/// Worker factories are **pinned across calls**: each worker slot's
/// `BatchFactory` (sampler scratch, block buffers, seed index) is
/// created on first use and reused on every later `for_each`, so the
/// per-epoch calls trainers make stop re-allocating scratch each
/// epoch.  Reuse cannot change batches — construction is seeded per
/// `(seed, epoch, batch_idx)` and the factory resets its scratch per
/// batch (`tests/prefetch.rs` pins bit-identity across worker counts).
pub struct PrefetchingLoader<'a> {
    pub loader: &'a NodeDataLoader,
    pub cfg: PrefetchConfig,
    ds: &'a GsDataset,
    pool: Vec<Option<BatchFactory<'a>>>,
}

impl<'a> PrefetchingLoader<'a> {
    pub fn new(
        loader: &'a NodeDataLoader,
        ds: &'a GsDataset,
        cfg: PrefetchConfig,
    ) -> PrefetchingLoader<'a> {
        PrefetchingLoader { loader, cfg, ds, pool: Vec::new() }
    }

    /// Build one batch per chunk; `consume(batch_idx, (tensors, touch))`
    /// runs on the calling thread, in chunk order.  Per-batch RNG is
    /// derived from `(seed, epoch, batch_idx)`, and lemb rows are
    /// deferred, so results are bit-identical for any worker count.
    /// `rotate_workers` picks the acting partition (`bi % rotate`) for
    /// feature-gather traffic accounting, as the serial loop did.
    pub fn for_each(
        &mut self,
        chunks: &[&[u32]],
        seed: u64,
        epoch: u64,
        rotate_workers: usize,
        consume: impl FnMut(usize, (Vec<Tensor>, LembTouch)) -> Result<()>,
    ) -> Result<()> {
        let ds = self.ds;
        let loader = self.loader;
        run_pipeline_pooled(
            chunks,
            &self.cfg,
            &mut self.pool,
            || BatchFactory::new(ds, &loader.shape),
            |f, bi, chunk| {
                let mut rng = Rng::seed_from(batch_seed(seed, epoch, bi as u64));
                let worker = (bi % rotate_workers.max(1)) as u32;
                build_nc_batch(f, loader, chunk, &mut rng, worker, true)
            },
            consume,
        )
    }

    /// Collect every batch (tests: compare against the serial loader).
    pub fn collect(
        &mut self,
        chunks: &[&[u32]],
        seed: u64,
        epoch: u64,
        rotate_workers: usize,
    ) -> Result<Vec<(Vec<Tensor>, LembTouch)>> {
        let mut out = Vec::with_capacity(chunks.len());
        self.for_each(chunks, seed, epoch, rotate_workers, |_, b| {
            out.push(b);
            Ok(())
        })?;
        Ok(out)
    }
}

/// Link-prediction loader: positive edges + negatives → batch.
pub struct LinkPredictionDataLoader {
    pub spec: ArtifactSpec,
    pub shape: BlockShape,
    pub sampler: NegSampler,
    /// Exclude validation/test edges from message passing (leak guard)
    /// and the batch's own positives (overfit guard) — paper §3.3.4.
    pub exclude_targets: bool,
    /// The val/test-edge exclusion triples, sorted once and shared by
    /// every batch (they never change within a run).
    static_exclusion: OnceLock<Arc<Vec<(u32, u32, u32)>>>,
}

impl LinkPredictionDataLoader {
    pub fn new(spec: &ArtifactSpec, sampler: NegSampler) -> Result<LinkPredictionDataLoader> {
        let shape = BlockSpecErr::from_spec(spec)?;
        Ok(LinkPredictionDataLoader {
            spec: spec.clone(),
            shape,
            sampler,
            exclude_targets: true,
            static_exclusion: OnceLock::new(),
        })
    }

    pub fn batch_size(&self) -> usize {
        self.spec.cfg_usize("lp_batch").unwrap_or(32)
    }

    /// Build a batch for positive edge ids of the LP task's etype.
    /// Convenience wrapper; hot loops reuse a factory via
    /// [`build_lp_batch`].
    pub fn batch(
        &self,
        ds: &GsDataset,
        edge_ids: &[u32],
        rng: &mut Rng,
        worker: u32,
    ) -> Result<(Vec<Tensor>, LembTouch)> {
        let mut f = BatchFactory::new(ds, &self.shape);
        build_lp_batch(&mut f, self, edge_ids, rng, worker, false)
    }

    fn build_exclusion(&self, ds: &GsDataset, edge_ids: &[u32], et: usize) -> EdgeExclusion {
        if !self.exclude_targets {
            return EdgeExclusion::new();
        }
        let es = &ds.graph.edges[et];
        let rev = ds.rev_map.get(&et).map(|&r| r as u32);
        // Every val/test edge (information-leak guard) — built once,
        // sorted, shared across batches.
        let base = self
            .static_exclusion
            .get_or_init(|| {
                let mut triples = vec![];
                if let Some(lp) = &ds.lp {
                    for (eid, &s) in lp.split.iter().enumerate() {
                        if s == Split::Val || s == Split::Test {
                            triples.push((et as u32, es.src[eid], es.dst[eid]));
                            if let Some(re) = rev {
                                triples.push((re, es.dst[eid], es.src[eid]));
                            }
                        }
                    }
                }
                EdgeExclusion::sorted_base(triples)
            })
            .clone();
        let mut ex = EdgeExclusion::with_base(base);
        // ...plus the batch's own positives (overfit guard).
        for &eid in edge_ids {
            ex.insert_with_reverse(et as u32, rev, es.src[eid as usize], es.dst[eid as usize]);
        }
        ex.seal();
        ex
    }
}

/// Link-prediction batch through a reusable factory; with `defer_lemb`
/// the embedding rows are filled later by [`fill_lemb`].
pub fn build_lp_batch(
    f: &mut BatchFactory,
    loader: &LinkPredictionDataLoader,
    edge_ids: &[u32],
    rng: &mut Rng,
    worker: u32,
    defer_lemb: bool,
) -> Result<(Vec<Tensor>, LembTouch)> {
    let ds = f.ds;
    let lp = ds.lp.as_ref().expect("dataset has no LP task");
    let et = lp.etype;
    let def = &ds.graph.schema.etypes[et];
    let es = &ds.graph.edges[et];
    let b = loader.batch_size();
    let k = loader.spec.cfg_usize("k").unwrap_or(loader.sampler.k());
    assert!(edge_ids.len() <= b);
    assert_eq!(loader.sampler.k(), k, "sampler K must match the artifact");

    let n_dst = ds.graph.num_nodes[def.dst_ntype];
    let negs = sample_negatives(
        loader.sampler,
        b,
        n_dst,
        def.dst_ntype,
        &ds.engine.book,
        worker,
        rng,
    );

    // Seed slots: [srcs | dsts | negs], padded with node 0.
    let mut seeds: Vec<(u32, u32)> = Vec::with_capacity(2 * b + negs.neg_nodes.len());
    let (snt, dnt) = (def.src_ntype as u32, def.dst_ntype as u32);
    for i in 0..b {
        let eid = edge_ids.get(i).copied().unwrap_or(edge_ids[0]);
        seeds.push((snt, es.src[eid as usize]));
    }
    for i in 0..b {
        let eid = edge_ids.get(i).copied().unwrap_or(edge_ids[0]);
        seeds.push((dnt, es.dst[eid as usize]));
    }
    for &n in &negs.neg_nodes {
        seeds.push((dnt, n));
    }

    // CAREFUL: seeds may contain duplicates; the block dedups, so we
    // must map each logical seed position to its slot.  The reusable
    // Fx seed index does first-seen dedup and O(1) slot lookup in one
    // pass (the block preserves seed insertion order, so dedup index
    // == target slot).
    let exclusion = loader.build_exclusion(ds, edge_ids, et);
    let mut si = std::mem::take(&mut f.seed_index);
    si.begin(seeds.len());
    let mut dedup: Vec<(u32, u32)> = Vec::with_capacity(seeds.len());
    for &s in &seeds {
        let (_, fresh) = si.get_or_insert(s.0, s.1, dedup.len());
        if fresh {
            dedup.push(s);
        }
    }
    let out =
        f.sample_assemble(&dedup, &loader.shape, &loader.spec, rng, worker, &exclusion, defer_lemb);
    let (mut batch, touch) = out?;
    debug_assert_eq!(f.targets(), &dedup[..]);
    let slot = |p: (u32, u32)| si.get(p.0, p.1).expect("seed indexed during dedup") as i32;

    let mut pos_src = vec![0i32; b];
    let mut pos_dst = vec![0i32; b];
    let mut rel = vec![0i32; b];
    let mut pmask = vec![0.0f32; b];
    let mut eweight = vec![1.0f32; b];
    for i in 0..b {
        pos_src[i] = slot(seeds[i]);
        pos_dst[i] = slot(seeds[b + i]);
        rel[i] = et as i32;
        if i < edge_ids.len() {
            pmask[i] = 1.0;
        } else {
            eweight[i] = 0.0;
        }
    }
    let mut neg_dst = vec![0i32; b * k];
    for i in 0..b {
        for (j, &pos) in negs.neg_dst[i].iter().enumerate() {
            // pos indexes the logical seed array; map through dedup.
            neg_dst[i * k + j] = slot(seeds[pos as usize]);
        }
    }
    f.seed_index = si; // return the index (and its table) to the factory
    batch.push(Tensor::I32 { shape: vec![b], data: pos_src });
    batch.push(Tensor::I32 { shape: vec![b], data: pos_dst });
    batch.push(Tensor::I32 { shape: vec![b, k], data: neg_dst });
    batch.push(Tensor::I32 { shape: vec![b], data: rel });
    batch.push(Tensor::F32 { shape: vec![b], data: pmask });
    batch.push(Tensor::F32 { shape: vec![b], data: eweight });
    Ok((batch, touch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{self, mag};
    use crate::partition::PartitionBook;

    fn mag_ds(n: usize) -> GsDataset {
        let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
        let book = PartitionBook::single(&raw.graph.num_nodes);
        datagen::build_dataset(raw, book, 64, 3)
    }

    #[test]
    fn text_fallback_fills_only_text_types() {
        let mut ds = mag_ds(300);
        assert_eq!(ds.engine.text_emb[0].dim, 0);
        ds.ensure_text_features(32);
        assert_eq!(ds.engine.text_emb[0].dim, 32); // papers
        assert_eq!(ds.engine.text_emb[1].dim, 0); // authors featureless
        // Rows are unit-ish normalized and non-zero for real text.
        let row = ds.engine.text_emb[0].row(0);
        assert!(row.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn neighbor_feature_construction_switches_source() {
        let mut ds = mag_ds(300);
        ds.ensure_text_features(64);
        let nt_author = 1;
        assert_eq!(ds.graph.schema.feature_sources[nt_author], FeatureSource::Learnable);
        ds.construct_neighbor_features(nt_author, 64);
        assert_eq!(ds.graph.schema.feature_sources[nt_author], FeatureSource::Dense);
        assert!(ds.engine.embeds[nt_author].is_none());
        let t = &ds.engine.features[nt_author];
        assert_eq!(t.dim, 64);
        // Authors with papers must have non-zero constructed features.
        let nonzero = (0..t.num_rows())
            .filter(|&i| t.row(i as u32).iter().any(|&x| x != 0.0))
            .count();
        assert!(nonzero > t.num_rows() / 2, "{nonzero}/{}", t.num_rows());
    }

    #[test]
    fn neighbor_features_are_neighbor_means() {
        // Hand-built: one featureless type fed by one dense type.
        use crate::graph::{EdgeTypeDef, HeteroGraph, Schema};
        let schema = Schema::new(
            vec!["a".into(), "b".into()],
            vec![EdgeTypeDef { name: "ab".into(), src_ntype: 0, dst_ntype: 1 }],
        )
        .with_sources(vec![FeatureSource::Dense, FeatureSource::Learnable]);
        let mut g = HeteroGraph::new(schema, vec![2, 1]);
        g.set_edges(0, vec![0, 1], vec![0, 0]);
        let raw = crate::datagen::RawData {
            graph: g,
            features: vec![(2, vec![1.0, 2.0, 3.0, 4.0]), (0, vec![])],
            labels: vec![None, None],
            tokens: vec![None, None],
            target_ntype: 0,
            num_classes: 2,
            lp_etype: None,
            rev_map: Default::default(),
        };
        let book = PartitionBook::single(&raw.graph.num_nodes);
        let mut ds = datagen::build_dataset(raw, book, 8, 0);
        ds.construct_neighbor_features(1, 2);
        assert_eq!(ds.engine.features[1].row(0), &[2.0, 3.0]); // mean of rows
    }

    #[test]
    fn splits_partition_ids() {
        let ds = mag_ds(500);
        let l = ds.node_labels();
        let (tr, va, te) = (
            l.ids_in(Split::Train).len(),
            l.ids_in(Split::Val).len(),
            l.ids_in(Split::Test).len(),
        );
        assert_eq!(tr + va + te, 500);
        assert!(tr > va && tr > te);
    }
}
