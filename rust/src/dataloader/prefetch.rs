//! The pipelined mini-batch engine: overlap batch construction with
//! device execution (paper §3.3 / Table 3; AGL- and PyG-2.0-style
//! pipelining).
//!
//! `run_pipeline` shards work items across `n_workers` scoped threads
//! (the same `std::thread::scope` + bounded-channel pattern
//! `gconstruct/transform.rs` uses for ETL).  Worker *w* builds items
//! `w, w+W, w+2W, …` ahead of the consumer through a bounded queue of
//! `depth` slots, while the calling thread consumes items **in order**
//! — so the PJRT step for batch *i* runs while batches *i+1 … i+W·d*
//! are being sampled and assembled.
//!
//! Determinism: callers derive each item's RNG from
//! [`batch_seed`]`(seed, epoch, batch_idx)`, never from a shared
//! stream, so output is bit-identical regardless of worker count —
//! including `n_workers = 1`, which runs fully inline.

use anyhow::{anyhow, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

use crate::util::splitmix64;

/// Pipelining knobs (CLI: `--num-workers`, `--prefetch`).
#[derive(Debug, Clone)]
pub struct PrefetchConfig {
    /// Batch-building threads; ≤ 1 means serial (no threads spawned).
    pub n_workers: usize,
    /// Bounded queue depth per worker (batches built ahead).
    pub depth: usize,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig { n_workers: 1, depth: 2 }
    }
}

/// Upper clamp for `--num-workers auto`: beyond this, batch building
/// saturates the device step and extra threads only add contention.
pub const MAX_AUTO_WORKERS: usize = 16;

/// Resolve `loader_workers: "auto"` (`--num-workers auto`) from the
/// machine: `available_parallelism`, clamped to
/// `[1, MAX_AUTO_WORKERS]`, with a log line so runs record what the
/// knob resolved to.  Output stays bit-identical for any value — only
/// throughput changes.
pub fn autoscale_workers() -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let n = cores.clamp(1, MAX_AUTO_WORKERS);
    crate::gs_info!("loader", "workers=auto -> {n} ({cores} cores, clamp [1, {MAX_AUTO_WORKERS}])");
    n
}

/// Deterministic per-batch RNG seed: depends only on
/// (seed, epoch, batch index), never on which thread builds the batch.
#[inline]
pub fn batch_seed(seed: u64, epoch: u64, batch_idx: u64) -> u64 {
    let mut s = seed
        ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ batch_idx.wrapping_mul(0xD1B5_4A32_D192_ED03);
    splitmix64(&mut s)
}

/// Run `build` over `items` on `cfg.n_workers` threads, handing each
/// result — in item order — to `consume` on the calling thread.
///
/// * `mk_state` is called once per worker to create its private
///   scratch (sampler buffers, reusable block, …).
/// * `build(state, idx, item)` must be deterministic given `idx`; it
///   must not rely on call order across items.
/// * `consume(idx, value)` runs on the calling thread only, so it may
///   freely touch `&mut` training state.
///
/// Errors from either side cancel the pipeline and propagate.
///
/// States are built fresh on every call; multi-epoch callers should
/// hold a pool across calls via [`run_pipeline_pooled`] so worker
/// scratch (factory buffers, reusable blocks) is paid for once, not
/// once per epoch.
pub fn run_pipeline<I, S, T, MK, B, C>(
    items: &[I],
    cfg: &PrefetchConfig,
    mk_state: MK,
    build: B,
    consume: C,
) -> Result<()>
where
    I: Sync,
    T: Send,
    S: Send,
    MK: Fn() -> S + Sync,
    B: Fn(&mut S, usize, &I) -> Result<T> + Sync,
    C: FnMut(usize, T) -> Result<()>,
{
    let mut pool: Vec<Option<S>> = Vec::new();
    run_pipeline_pooled(items, cfg, &mut pool, mk_state, build, consume)
}

/// [`run_pipeline`] with worker states **pinned across calls**: slot
/// `w` of `pool` holds worker `w`'s private state, lazily created by
/// `mk_state` on first use and reused verbatim on every later call —
/// so per-epoch invocations stop rebuilding `BatchFactory` scratch
/// (hash maps, CSR cursors, block buffers) from scratch each epoch.
///
/// Pass the same `pool` (starting empty) to every call; it grows to
/// the largest worker count seen.  Reuse cannot change results: the
/// `build` contract already requires determinism given `idx` alone,
/// independent of any state carried in the scratch (the determinism
/// suite pins this — outputs are bit-identical for any worker count,
/// pooled or not).
pub fn run_pipeline_pooled<I, S, T, MK, B, C>(
    items: &[I],
    cfg: &PrefetchConfig,
    pool: &mut Vec<Option<S>>,
    mk_state: MK,
    build: B,
    mut consume: C,
) -> Result<()>
where
    I: Sync,
    T: Send,
    S: Send,
    MK: Fn() -> S + Sync,
    B: Fn(&mut S, usize, &I) -> Result<T> + Sync,
    C: FnMut(usize, T) -> Result<()>,
{
    let w = cfg.n_workers.max(1).min(items.len().max(1));
    while pool.len() < w {
        pool.push(None);
    }
    if w <= 1 {
        // Serial path: same build/consume interleaving, no threads.
        // Span names match the threaded path exactly, so a trace of
        // the same workload has the same structure for any worker
        // count — only timing and thread ids differ.
        let state = pool[0].get_or_insert_with(&mk_state);
        for (i, item) in items.iter().enumerate() {
            let value = {
                let _s = crate::span!("loader.build", idx = i);
                build(state, i, item)?
            };
            let _s = crate::span!("loader.consume", idx = i);
            consume(i, value)?;
        }
        return Ok(());
    }
    let depth = cfg.depth.max(1);
    std::thread::scope(|scope| -> Result<()> {
        let mut rxs: Vec<Receiver<(usize, Result<T>)>> = Vec::with_capacity(w);
        // iter_mut hands each worker a disjoint &mut slot — worker wi
        // always reoccupies slot wi, keeping state ↔ residue-class
        // pairing stable across calls.
        for (wi, slot) in pool[..w].iter_mut().enumerate() {
            let (tx, rx): (SyncSender<(usize, Result<T>)>, _) = sync_channel(depth);
            rxs.push(rx);
            let mk = &mk_state;
            let bld = &build;
            scope.spawn(move || {
                let state = slot.get_or_insert_with(|| mk());
                for (i, item) in items.iter().enumerate().skip(wi).step_by(w) {
                    let out = {
                        let _s = crate::span!("loader.build", idx = i);
                        bld(state, i, item)
                    };
                    let failed = out.is_err();
                    // A closed channel means the consumer is done (or
                    // bailed): stop building.
                    if tx.send((i, out)).is_err() || failed {
                        return;
                    }
                }
            });
        }
        // Consume strictly in item order; worker w owns items ≡ w (mod W).
        let outcome = (|| -> Result<()> {
            for i in 0..items.len() {
                let (idx, value) = rxs[i % w]
                    .recv()
                    .map_err(|_| anyhow!("prefetch worker {} exited early", i % w))?;
                debug_assert_eq!(idx, i, "pipeline ordering violated");
                let _s = crate::span!("loader.consume", idx = i);
                consume(i, value?)?;
            }
            Ok(())
        })();
        // Unblock any worker parked on a full queue before joining.
        drop(rxs);
        outcome
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order_and_results() {
        let items: Vec<usize> = (0..57).collect();
        for workers in [1, 2, 4, 7] {
            let cfg = PrefetchConfig { n_workers: workers, depth: 2 };
            let mut got = vec![];
            run_pipeline(
                &items,
                &cfg,
                || 0usize,
                |_s, i, &x| Ok(i * 1000 + x),
                |i, v| {
                    assert_eq!(v, i * 1000 + i);
                    got.push(v);
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(got.len(), 57, "workers={workers}");
        }
    }

    #[test]
    fn per_worker_state_is_private() {
        let items: Vec<usize> = (0..40).collect();
        let states = AtomicUsize::new(0);
        run_pipeline(
            &items,
            &PrefetchConfig { n_workers: 4, depth: 1 },
            || {
                states.fetch_add(1, Ordering::SeqCst);
                Vec::<usize>::new()
            },
            |s, i, _| {
                s.push(i);
                // Each worker only ever sees its own residue class.
                assert!(s.iter().all(|&x| x % 4 == s[0] % 4));
                Ok(i)
            },
            |_, _| Ok(()),
        )
        .unwrap();
        assert_eq!(states.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn build_errors_propagate() {
        let items: Vec<usize> = (0..20).collect();
        let r = run_pipeline(
            &items,
            &PrefetchConfig { n_workers: 3, depth: 2 },
            || (),
            |_, i, _| {
                if i == 7 {
                    anyhow::bail!("boom at {i}")
                } else {
                    Ok(i)
                }
            },
            |_, _| Ok(()),
        );
        assert!(r.unwrap_err().to_string().contains("boom at 7"));
    }

    #[test]
    fn consume_errors_cancel_workers() {
        let items: Vec<usize> = (0..1000).collect();
        let r = run_pipeline(
            &items,
            &PrefetchConfig { n_workers: 4, depth: 1 },
            || (),
            |_, i, _| Ok(i),
            |i, _| {
                if i == 3 {
                    anyhow::bail!("stop")
                } else {
                    Ok(())
                }
            },
        );
        assert!(r.is_err()); // and no deadlock on the bounded queues
    }

    #[test]
    fn pooled_states_survive_across_calls() {
        let items: Vec<usize> = (0..40).collect();
        let made = AtomicUsize::new(0);
        let mut pool: Vec<Option<Vec<usize>>> = Vec::new();
        for _epoch in 0..3 {
            run_pipeline_pooled(
                &items,
                &PrefetchConfig { n_workers: 4, depth: 1 },
                &mut pool,
                || {
                    made.fetch_add(1, Ordering::SeqCst);
                    Vec::new()
                },
                |s, i, _| {
                    s.push(i);
                    Ok(i)
                },
                |_, _| Ok(()),
            )
            .unwrap();
        }
        assert_eq!(made.load(Ordering::SeqCst), 4, "one state per worker, not per epoch");
        let built: usize = pool.iter().flatten().map(Vec::len).sum();
        assert_eq!(built, 3 * 40);
        // Slot wi only ever builds its own residue class.
        for (wi, slot) in pool.iter().enumerate() {
            assert!(slot.as_ref().is_some_and(|v| v.iter().all(|&i| i % 4 == wi)));
        }
    }

    #[test]
    fn batch_seed_is_stable_and_spreads() {
        assert_eq!(batch_seed(7, 1, 2), batch_seed(7, 1, 2));
        let mut seen = std::collections::HashSet::new();
        for e in 0..8u64 {
            for b in 0..64u64 {
                seen.insert(batch_seed(7, e, b));
            }
        }
        assert_eq!(seen.len(), 8 * 64, "seed collisions");
    }
}
