//! graphstorm-rs — a reproduction of *GraphStorm: All-in-one Graph
//! Machine Learning Framework for Industry Applications* (KDD 2024) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! Layer 3 (this crate) owns everything on the hot path: graph
//! construction, partitioning, the simulated distributed engine,
//! on-the-fly mini-batch sampling, negative sampling, training loops,
//! the online inference-serving layer (`serve`) and the CLI.  Layers 2/1 (JAX models + Pallas kernels) are AOT-lowered at
//! build time to `artifacts/*.hlo.txt` and executed through the PJRT C
//! API (`runtime`); Python never runs at training/inference time.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod config;
pub mod datagen;
pub mod dataloader;
pub mod dist;
pub mod eval;
pub mod gconstruct;
pub mod graph;
pub mod lint;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod trainer;
pub mod util;

/// Default artifacts directory, overridable via `GS_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("GS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            // Walk up from CWD until we find artifacts/manifest.json so
            // examples, tests and benches work from any subdirectory.
            let mut dir = std::env::current_dir().unwrap();
            loop {
                let cand = dir.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !dir.pop() {
                    return std::path::PathBuf::from("artifacts");
                }
            }
        })
}
