//! The AOT manifest: shapes/dtypes/ordering of every artifact's
//! inputs and outputs.  Written by `python/compile/aot.py`; the Rust
//! runtime is entirely manifest-driven (no hard-coded shapes).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor spec missing shape")?
            .iter()
            .map(|v| v.as_usize().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: j.str_of("name")?.to_string(),
            shape,
            dtype: j.str_of("dtype")?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub init_file: Option<String>,
    pub kind: String, // "train" | "infer"
    pub n_params: usize,
    pub state: Vec<TensorSpec>,
    pub scalars: Vec<TensorSpec>,
    pub batch: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub config: Json,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<ArtifactSpec> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .with_context(|| format!("missing '{key}'"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ArtifactSpec {
            file: j.str_of("file")?.to_string(),
            init_file: j.get("init_file").and_then(Json::as_str).map(str::to_string),
            kind: j.str_of("kind")?.to_string(),
            n_params: j.usize_of("n_params")?,
            state: specs("state")?,
            scalars: specs("scalars")?,
            batch: specs("batch")?,
            outputs: specs("outputs")?,
            config: j.get("config").cloned().unwrap_or(Json::Null),
        })
    }

    /// Block shape (ns, es) for GNN artifacts.
    pub fn block(&self) -> Option<(Vec<usize>, Vec<usize>)> {
        let b = self.config.get("block")?;
        let take = |key: &str| -> Option<Vec<usize>> {
            b.get(key)?.as_arr()?.iter().map(Json::as_usize).collect()
        };
        Some((take("ns")?, take("es")?))
    }

    pub fn cfg_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key)?.as_usize()
    }

    /// Find a batch input's spec by name.
    pub fn batch_spec(&self, name: &str) -> Option<&TensorSpec> {
        self.batch.iter().find(|t| t.name == name)
    }

    /// A manifest-free spec with the given block shape and 64-dim
    /// feat/text/lemb batch inputs — lets loader tests and the
    /// sampling/pipeline benches run without AOT artifacts.
    /// `extra_cfg` is appended inside the config object, e.g.
    /// `,"batch":64` or `,"lp_batch":16,"k":8`.
    pub fn synthetic_block(
        ns: &[usize],
        es: &[usize],
        fanout: usize,
        extra_cfg: &str,
    ) -> ArtifactSpec {
        let n0 = ns[0];
        let t = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            shape,
            dtype: "f32".to_string(),
        };
        let cfg = format!(
            r#"{{"block":{{"ns":{ns:?},"es":{es:?}}},"fanout":{fanout}{extra_cfg}}}"#
        );
        ArtifactSpec {
            file: "synthetic".to_string(),
            init_file: None,
            kind: "train".to_string(),
            n_params: 0,
            state: vec![],
            scalars: vec![],
            batch: vec![
                t("feat", vec![n0, 64]),
                t("text", vec![n0, 64]),
                t("lemb", vec![n0, 64]),
            ],
            outputs: vec![],
            config: Json::parse(&cfg).expect("synthetic block config parses"),
        }
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|t| t.name == name)
    }

    /// Builder: append an f32 output spec.  Synthetic specs have no
    /// outputs by default; the serving engine reads its decode width
    /// from `outputs[0]`, so tests and benches that run without AOT
    /// artifacts attach one with this.
    pub fn with_output(mut self, name: &str, shape: &[usize]) -> ArtifactSpec {
        self.outputs.push(TensorSpec {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype: "f32".to_string(),
        });
        self
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text)?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest missing 'artifacts'")?;
        let mut artifacts = HashMap::new();
        for (name, spec) in arts {
            artifacts.insert(
                name.clone(),
                ArtifactSpec::from_json(spec).with_context(|| format!("artifact {name}"))?,
            );
        }
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_and_has_core_artifacts() {
        let Ok(m) = Manifest::load(&crate::artifacts_dir()) else {
            eprintln!("skipping: AOT artifacts unavailable");
            return;
        };
        for name in ["smoke", "rgcn_nc_train", "rgcn_lp_joint_k32_train", "lm_embed"] {
            let a = m.get(name).unwrap();
            assert!(!a.outputs.is_empty(), "{name} has outputs");
        }
        let t = m.get("rgcn_nc_train").unwrap();
        assert_eq!(t.kind, "train");
        assert_eq!(t.state.len(), 3 * t.n_params + 1);
        // grad_lemb must be the last output for embedding-table updates.
        assert_eq!(t.outputs.last().unwrap().name, "grad_lemb");
        let (ns, es) = t.block().unwrap();
        assert_eq!(ns.len(), es.len() + 1);
    }
}
