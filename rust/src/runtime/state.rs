//! Training state and inference sessions over the AOT artifacts.
//!
//! The `[params, m, v, t]` state lives as XLA literals that shuttle
//! through `execute` each step; on the CPU PJRT plugin literals are
//! host-resident device memory, so a step's only real copies are the
//! mini-batch in and two scalars out.

use anyhow::{bail, Context, Result};
use std::sync::Arc;

use super::exec::{tensor_to_literal, Executable, Runtime};
use super::gstf::Tensor;
use super::manifest::TensorSpec;

pub fn literal_to_tensor(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
    let t = match spec.dtype.as_str() {
        "f32" => Tensor::F32 { shape: spec.shape.clone(), data: lit.to_vec::<f32>()? },
        "i32" => Tensor::I32 { shape: spec.shape.clone(), data: lit.to_vec::<i32>()? },
        d => bail!("unknown dtype {d}"),
    };
    Ok(t)
}

/// Outputs of one train step.
#[derive(Debug, Clone)]
pub struct StepOut {
    pub loss: f32,
    pub metric: f32,
    /// d loss / d lemb rows, for the sparse embedding-table update.
    pub grad_lemb: Option<Vec<f32>>,
}

/// A training session: compiled train step + persistent state literals.
pub struct TrainState {
    pub exe: Arc<Executable>,
    state: Vec<xla::Literal>,
    pub steps_done: u64,
}

impl TrainState {
    /// Initialize from the artifact's AOT init params (Adam moments zeroed).
    pub fn new(rt: &Runtime, name: &str) -> Result<TrainState> {
        TrainState::with_params(rt, name, &[])
    }

    /// Initialize with explicit parameter values (checkpoint restore or
    /// stage-to-stage transfer, e.g. fine-tuned LM → embedding computer).
    /// `params` entries are matched to the manifest's `p:` specs by name;
    /// missing entries fall back to the artifact's init values.
    pub fn with_params(rt: &Runtime, name: &str, params: &[(String, Tensor)]) -> Result<TrainState> {
        let exe = rt.load(name)?;
        let spec = &exe.spec;
        if spec.kind != "train" {
            bail!("{name} is not a train artifact");
        }
        let init = rt.init_params(name)?;
        let by_name: std::collections::HashMap<&str, &Tensor> =
            params.iter().map(|(n, t)| (n.as_str(), t)).collect();
        let init_by_name: std::collections::HashMap<&str, &Tensor> =
            init.iter().map(|(n, t)| (n.as_str(), t)).collect();

        let mut state = Vec::with_capacity(spec.state.len());
        for ts in &spec.state {
            let tensor = if ts.name.starts_with("p:") {
                match by_name.get(ts.name.as_str()).or_else(|| init_by_name.get(ts.name.as_str())) {
                    Some(t) => (*t).clone(),
                    None => bail!("no init value for {}", ts.name),
                }
            } else {
                // Adam moments + step counter start at zero.
                Tensor::zeros_f32(&ts.shape)
            };
            state.push(
                tensor_to_literal(&tensor, ts).with_context(|| format!("state tensor {}", ts.name))?,
            );
        }
        Ok(TrainState { exe, state, steps_done: 0 })
    }

    /// Run one train step. `scalars` follow the manifest order
    /// (lr first, then e.g. loss_sel); `batch` follows `spec.batch`.
    pub fn step(&mut self, _rt: &Runtime, scalars: &[f32], batch: &[Tensor]) -> Result<StepOut> {
        let spec = self.exe.spec.clone();
        if scalars.len() != spec.scalars.len() {
            bail!("{}: got {} scalars, want {}", self.exe.name, scalars.len(), spec.scalars.len());
        }
        if batch.len() != spec.batch.len() {
            bail!("{}: got {} batch tensors, want {}", self.exe.name, batch.len(), spec.batch.len());
        }
        let mut extra = Vec::with_capacity(scalars.len() + batch.len());
        for (s, ts) in scalars.iter().zip(&spec.scalars) {
            let t = Tensor::F32 { shape: vec![], data: vec![*s] };
            extra.push(tensor_to_literal(&t, ts)?);
        }
        for (t, ts) in batch.iter().zip(&spec.batch) {
            extra.push(tensor_to_literal(t, ts).with_context(|| ts.name.clone())?);
        }
        // Ordering per the manifest: state ++ scalars ++ batch.
        let mut args: Vec<&xla::Literal> = self.state.iter().collect();
        args.extend(extra.iter());

        let mut outs = self.exe.run(&args)?;
        let n_state = spec.state.len();
        if outs.len() != spec.outputs.len() {
            bail!("{}: got {} outputs, want {}", self.exe.name, outs.len(), spec.outputs.len());
        }
        let rest = outs.split_off(n_state);
        self.state = outs;
        self.steps_done += 1;

        let loss = rest[0].to_vec::<f32>()?[0];
        let metric = rest[1].to_vec::<f32>()?[0];
        let grad_lemb = if rest.len() > 2 { Some(rest[2].to_vec::<f32>()?) } else { None };
        Ok(StepOut { loss, metric, grad_lemb })
    }

    /// Download current parameters (the `p:` prefix of the state).
    pub fn params_host(&self) -> Result<Vec<(String, Tensor)>> {
        let spec = &self.exe.spec;
        let mut out = Vec::with_capacity(spec.n_params);
        for (lit, ts) in self.state.iter().zip(&spec.state).take(spec.n_params) {
            out.push((ts.name.clone(), literal_to_tensor(lit, ts)?));
        }
        Ok(out)
    }

    /// Save a checkpoint (GSTF, readable from Python too).  Written
    /// atomically — a crash mid-save never clobbers the previous
    /// checkpoint at `path`.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        super::gstf::write_gstf_atomic(path, &self.params_host()?)
    }
}

/// An inference session with persistent parameter literals.
pub struct InferSession {
    pub exe: Arc<Executable>,
    params: Vec<xla::Literal>,
}

impl InferSession {
    /// `params` matched by `p:` name; missing names fall back to init.
    pub fn new(rt: &Runtime, name: &str, params: &[(String, Tensor)]) -> Result<InferSession> {
        let exe = rt.load(name)?;
        if exe.spec.kind != "infer" {
            bail!("{name} is not an infer artifact");
        }
        let by_name: std::collections::HashMap<&str, &Tensor> =
            params.iter().map(|(n, t)| (n.as_str(), t)).collect();
        let init = if exe.spec.init_file.is_some() { rt.init_params(name)? } else { vec![] };
        let init_by_name: std::collections::HashMap<&str, &Tensor> =
            init.iter().map(|(n, t)| (n.as_str(), t)).collect();
        let mut lits = Vec::with_capacity(exe.spec.state.len());
        for ts in &exe.spec.state {
            let t = by_name
                .get(ts.name.as_str())
                .or_else(|| init_by_name.get(ts.name.as_str()))
                .with_context(|| format!("no value for param {}", ts.name))?;
            lits.push(tensor_to_literal(t, ts)?);
        }
        Ok(InferSession { exe, params: lits })
    }

    /// Initialize straight from the artifact's init params (untrained).
    pub fn from_init(rt: &Runtime, name: &str) -> Result<InferSession> {
        InferSession::new(rt, name, &[])
    }

    pub fn infer(&self, _rt: &Runtime, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        self.infer_batch(batch)
    }

    /// Run inference on one batch.  The executable holds its own
    /// client handle, so no `Runtime` is needed — this is the entry
    /// the serving engine uses.
    pub fn infer_batch(&self, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = &self.exe.spec;
        if batch.len() != spec.batch.len() {
            bail!("{}: got {} batch tensors, want {}", self.exe.name, batch.len(), spec.batch.len());
        }
        let mut extra = Vec::with_capacity(batch.len());
        for (t, ts) in batch.iter().zip(&spec.batch) {
            extra.push(tensor_to_literal(t, ts).with_context(|| ts.name.clone())?);
        }
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.extend(extra.iter());
        let outs = self.exe.run(&args)?;
        outs.iter()
            .zip(&spec.outputs)
            .map(|(l, ts)| literal_to_tensor(l, ts))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end: the MLP probe must learn a linearly-separable toy
    /// problem through the full AOT train-step path.
    #[test]
    fn mlp_probe_learns() {
        let Some(rt) = super::super::exec::runtime_if_available() else {
            eprintln!("skipping: AOT artifacts / PJRT backend unavailable");
            return;
        };
        let mut st = TrainState::new(&rt, "mlp_train").unwrap();
        let spec = st.exe.spec.clone();
        let b = spec.batch_spec("emb").unwrap().shape[0];
        let d = spec.batch_spec("emb").unwrap().shape[1];
        let mut rng = crate::util::Rng::seed_from(0);
        let mut first_loss = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let mut emb = vec![0f32; b * d];
            let mut labels = vec![0i32; b];
            for i in 0..b {
                let c = rng.gen_range(4);
                labels[i] = c as i32;
                for j in 0..d {
                    emb[i * d + j] = rng.gen_normal() * 0.1;
                }
                emb[i * d + c] += 2.0; // class signal on dimension c
            }
            let batch = vec![
                Tensor::F32 { shape: vec![b, d], data: emb },
                Tensor::I32 { shape: vec![b], data: labels },
                Tensor::F32 { shape: vec![b], data: vec![1.0; b] },
            ];
            let out = st.step(&rt, &[1e-2], &batch).unwrap();
            first_loss.get_or_insert(out.loss);
            last = out.loss;
        }
        assert!(
            last < first_loss.unwrap() * 0.5,
            "loss did not drop: {first_loss:?} -> {last}"
        );
    }

    /// Param transfer: train-state params flow into an infer session and
    /// produce logits consistent with the training objective.
    #[test]
    fn train_params_flow_to_infer() {
        let Some(rt) = super::super::exec::runtime_if_available() else {
            eprintln!("skipping: AOT artifacts / PJRT backend unavailable");
            return;
        };
        let mut st = TrainState::new(&rt, "mlp_train").unwrap();
        let spec = st.exe.spec.clone();
        let b = spec.batch_spec("emb").unwrap().shape[0];
        let d = spec.batch_spec("emb").unwrap().shape[1];
        let mut rng = crate::util::Rng::seed_from(1);
        let make = |rng: &mut crate::util::Rng| {
            let mut emb = vec![0f32; b * d];
            let mut labels = vec![0i32; b];
            for i in 0..b {
                let c = rng.gen_range(4);
                labels[i] = c as i32;
                emb[i * d + c] = 3.0;
            }
            (emb, labels)
        };
        for _ in 0..80 {
            let (emb, labels) = make(&mut rng);
            let batch = vec![
                Tensor::F32 { shape: vec![b, d], data: emb },
                Tensor::I32 { shape: vec![b], data: labels },
                Tensor::F32 { shape: vec![b], data: vec![1.0; b] },
            ];
            st.step(&rt, &[1e-2], &batch).unwrap();
        }
        let params = st.params_host().unwrap();
        let sess = InferSession::new(&rt, "mlp_logits", &params).unwrap();
        let (emb, labels) = make(&mut rng);
        let out = sess
            .infer(&rt, &[Tensor::F32 { shape: vec![b, d], data: emb }])
            .unwrap();
        let logits = out[0].as_f32().unwrap();
        let c = sess.exe.spec.outputs[0].shape[1];
        let correct = labels
            .iter()
            .enumerate()
            .filter(|(i, &l)| {
                let row = &logits[i * c..(i + 1) * c];
                let am = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
                am as i32 == l
            })
            .count();
        assert!(correct as f64 > 0.9 * b as f64, "acc {}/{b}", correct);
    }
}
