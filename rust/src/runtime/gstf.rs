//! GSTF tensor files — the Python↔Rust tensor interchange.
//!
//! Mirrors `python/compile/gstf.py`: initial parameters are written at
//! AOT time and read here; checkpoints are written here and readable
//! from Python.  Little-endian throughout.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Host tensor: f32 or i32 payload plus shape.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }
}

pub fn write_gstf(path: &Path, tensors: &[(String, Tensor)]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_gstf_to(&mut f, tensors)?;
    f.flush()?;
    Ok(())
}

/// Crash-safe variant of [`write_gstf`]: the payload is written to
/// `<path>.tmp`, flushed and fsynced, then atomically renamed into
/// place — a reader never observes a half-written file at `path`, and
/// a crash mid-write leaves only a `.tmp` orphan (which writers like
/// `serve::offline` sweep before re-running).  Rename-over-existing is
/// atomic on POSIX, so re-runs are idempotent.
pub fn write_gstf_atomic(path: &Path, tensors: &[(String, Tensor)]) -> Result<()> {
    let tmp = tmp_path(path);
    let res = (|| -> Result<()> {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(file);
        write_gstf_to(&mut w, tensors)?;
        w.flush()?;
        // BufWriter::into_inner would re-flush; we already did, so
        // fsync through the inner handle it exposes.
        w.get_ref().sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
        Ok(())
    })();
    if let Err(e) = res {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))
}

/// The temporary sibling `write_gstf_atomic` stages into:
/// `<filename>.tmp` in the same directory (same filesystem, so the
/// final rename is atomic).
pub fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

fn write_gstf_to(f: &mut impl Write, tensors: &[(String, Tensor)]) -> Result<()> {
    f.write_all(b"GSTF")?;
    f.write_all(&1u32.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        match t {
            Tensor::F32 { shape, data } => {
                f.write_all(&[0u8])?;
                f.write_all(&(shape.len() as u32).to_le_bytes())?;
                for d in shape {
                    f.write_all(&(*d as u64).to_le_bytes())?;
                }
                for x in data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            Tensor::I32 { shape, data } => {
                f.write_all(&[1u8])?;
                f.write_all(&(shape.len() as u32).to_le_bytes())?;
                for d in shape {
                    f.write_all(&(*d as u64).to_le_bytes())?;
                }
                for x in data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Header-sanity caps for [`read_gstf`]: a corrupt or truncated file
/// must fail with an error naming the bad field, never drive a
/// multi-gigabyte allocation from an attacker- or bitrot-controlled
/// length prefix.  Generous vs. every real artifact (largest shipped
/// init file is ~10 MB).
const MAX_TENSORS: usize = 1 << 16;
const MAX_NAME_LEN: usize = 4096;
const MAX_NDIM: usize = 32;
const MAX_PAYLOAD_BYTES: usize = 1 << 34; // 16 GiB per tensor

pub fn read_gstf(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"GSTF" {
        bail!("bad GSTF magic in {}", path.display());
    }
    let version = read_u32(&mut f)?;
    if version != 1 {
        bail!("unsupported GSTF version {version}");
    }
    let count = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count.min(MAX_TENSORS));
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        // Bound header-driven allocations before trusting them: a
        // truncated or corrupt file must fail with a typed error, not
        // an abort inside `vec![0u8; huge]`.
        if name_len > MAX_NAME_LEN {
            bail!("GSTF tensor name length {name_len} exceeds cap {MAX_NAME_LEN}");
        }
        let mut nb = vec![0u8; name_len];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        let mut dt = [0u8; 1];
        f.read_exact(&mut dt)?;
        let ndim = read_u32(&mut f)? as usize;
        if ndim > MAX_NDIM {
            bail!("GSTF tensor '{name}' rank {ndim} exceeds cap {MAX_NDIM}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        // Checked element count: a corrupt shape like [2^40, 2^40]
        // overflows `iter().product()` in release mode to a small
        // number — validate each step instead.
        let n = shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d)).and_then(|n| {
            n.checked_mul(4).filter(|&bytes| bytes <= MAX_PAYLOAD_BYTES).map(|_| n)
        });
        let n: usize = match n {
            Some(n) => n,
            None => bail!("GSTF tensor '{name}' shape {shape:?} overflows the payload cap"),
        };
        let t = match dt[0] {
            0 => {
                let mut raw = vec![0u8; n * 4];
                f.read_exact(&mut raw)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Tensor::F32 { shape, data }
            }
            1 => {
                let mut raw = vec![0u8; n * 4];
                f.read_exact(&mut raw)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Tensor::I32 { shape, data }
            }
            d => bail!("unknown GSTF dtype {d}"),
        };
        out.push((name, t));
    }
    Ok(out)
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("gstf_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.gstf");
        let tensors = vec![
            (
                "a".to_string(),
                Tensor::F32 { shape: vec![2, 3], data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] },
            ),
            ("b".to_string(), Tensor::I32 { shape: vec![4], data: vec![7, -8, 9, 0] }),
            ("scalar".to_string(), Tensor::F32 { shape: vec![], data: vec![3.25] }),
        ];
        write_gstf(&path, &tensors).unwrap();
        let back = read_gstf(&path).unwrap();
        assert_eq!(tensors, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_roundtrip_leaves_no_tmp() {
        let dir = std::env::temp_dir().join(format!("gstf_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.gstf");
        let tensors =
            vec![("a".to_string(), Tensor::F32 { shape: vec![2], data: vec![1.0, 2.0] })];
        write_gstf_atomic(&path, &tensors).unwrap();
        assert_eq!(read_gstf(&path).unwrap(), tensors);
        assert!(!tmp_path(&path).exists(), "tmp must be renamed away");
        // Overwrite-in-place is atomic and idempotent.
        write_gstf_atomic(&path, &tensors).unwrap();
        assert_eq!(read_gstf(&path).unwrap(), tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_headers_error_instead_of_allocating() {
        let dir = std::env::temp_dir().join(format!("gstf_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let write = |name: &str, bytes: &[u8]| {
            let p = dir.join(name);
            std::fs::write(&p, bytes).unwrap();
            p
        };
        // Absurd name length prefix.
        let mut bad_name = Vec::new();
        bad_name.extend_from_slice(b"GSTF");
        bad_name.extend_from_slice(&1u32.to_le_bytes());
        bad_name.extend_from_slice(&1u32.to_le_bytes());
        bad_name.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_gstf(&write("name.gstf", &bad_name)).unwrap_err();
        assert!(err.to_string().contains("name length"), "{err}");
        // Shape whose element product overflows usize.
        let mut bad_shape = Vec::new();
        bad_shape.extend_from_slice(b"GSTF");
        bad_shape.extend_from_slice(&1u32.to_le_bytes());
        bad_shape.extend_from_slice(&1u32.to_le_bytes());
        bad_shape.extend_from_slice(&1u32.to_le_bytes());
        bad_shape.push(b'x');
        bad_shape.push(0u8); // f32
        bad_shape.extend_from_slice(&2u32.to_le_bytes());
        bad_shape.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bad_shape.extend_from_slice(&(1u64 << 40).to_le_bytes());
        let err = read_gstf(&write("shape.gstf", &bad_shape)).unwrap_err();
        assert!(err.to_string().contains("payload cap"), "{err}");
        // Truncated payload still errors cleanly (read_exact).
        let mut short = Vec::new();
        short.extend_from_slice(b"GSTF");
        short.extend_from_slice(&1u32.to_le_bytes());
        short.extend_from_slice(&1u32.to_le_bytes());
        short.extend_from_slice(&1u32.to_le_bytes());
        short.push(b'y');
        short.push(0u8);
        short.extend_from_slice(&1u32.to_le_bytes());
        short.extend_from_slice(&8u64.to_le_bytes());
        short.extend_from_slice(&[0u8; 5]); // 5 of 32 payload bytes
        assert!(read_gstf(&write("short.gstf", &short)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_python_written_init() {
        // The AOT pipeline writes init files; verify one parses if present.
        let dir = crate::artifacts_dir();
        let p = dir.join("mlp_train.init.gstf");
        if p.exists() {
            let ts = read_gstf(&p).unwrap();
            assert!(!ts.is_empty());
            assert!(ts.iter().all(|(n, _)| n.starts_with("p:")));
        }
    }
}
