//! PJRT execution: load HLO text, compile once, run many times.
//!
//! Train state (params + Adam moments) stays **device-resident**: the
//! train step runs via `execute_b` over `PjRtBuffer`s, so each step
//! copies only the mini-batch host→device and two scalars back.  This
//! is the L3 half of the perf story (EXPERIMENTS.md §Perf).

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::gstf::Tensor;
use super::manifest::{ArtifactSpec, Manifest, TensorSpec};

/// Convert a host tensor to an XLA literal, checking the spec's shape.
pub fn tensor_to_literal(t: &Tensor, spec: &TensorSpec) -> Result<xla::Literal> {
    if t.shape() != spec.shape.as_slice() {
        bail!(
            "shape mismatch for '{}': got {:?}, manifest wants {:?}",
            spec.name,
            t.shape(),
            spec.shape
        );
    }
    let dims: Vec<usize> = t.shape().to_vec();
    let lit = match (t, spec.dtype.as_str()) {
        (Tensor::F32 { data, .. }, "f32") => {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &dims, bytes)?
        }
        (Tensor::I32 { data, .. }, "i32") => {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, &dims, bytes)?
        }
        _ => bail!("dtype mismatch for '{}' (manifest {})", spec.name, spec.dtype),
    };
    Ok(lit)
}

pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// One compiled artifact.
pub struct Executable {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literals; returns the flat output literals.
    ///
    /// The AOT step returns a tuple root; the result comes back as one
    /// tuple literal which we decompose (`to_tuple`).  On the CPU PJRT
    /// plugin literals are already host/device-unified memory, so this
    /// path has no extra copies; note `execute_b` on tuple-rooted
    /// computations CHECK-fails inside xla_extension 0.5.1, hence the
    /// literal path (see DESIGN.md §8 L3 notes).
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let expected = self.spec.state.len() + self.spec.scalars.len() + self.spec.batch.len();
        if args.len() != expected {
            bail!("{}: got {} args, manifest wants {expected}", self.name, args.len());
        }
        let result = self.exe.execute::<&xla::Literal>(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    pub fn client(&self) -> &xla::PjRtClient {
        self.exe.client()
    }
}

/// The runtime: one PJRT CPU client + a compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn from_default_dir() -> Result<Runtime> {
        Runtime::new(&crate::artifacts_dir())
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let e = Arc::new(Executable { name: name.to_string(), spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Read the artifact's initial parameters (GSTF written at AOT time).
    pub fn init_params(&self, name: &str) -> Result<Vec<(String, Tensor)>> {
        let spec = self.manifest.get(name)?;
        let init = spec
            .init_file
            .as_ref()
            .with_context(|| format!("{name} has no init file"))?;
        super::gstf::read_gstf(&self.manifest.dir.join(init))
    }

    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }
}

/// The runtime, but only if the AOT artifacts exist *and* the PJRT
/// backend can actually execute them (the offline `xla` stub cannot).
/// Probes by running the `smoke` artifact on zero inputs.  Tests and
/// benches that need device execution call this and skip when `None`,
/// so the tree stays green on machines without artifacts or plugin.
pub fn runtime_if_available() -> Option<Runtime> {
    let rt = Runtime::from_default_dir().ok()?;
    let exe = rt.load("smoke").ok()?;
    let lits: Vec<xla::Literal> = exe
        .spec
        .batch
        .iter()
        .map(|ts| {
            let t = match ts.dtype.as_str() {
                "i32" => Tensor::I32 { shape: ts.shape.clone(), data: vec![0; ts.numel()] },
                _ => Tensor::F32 { shape: ts.shape.clone(), data: vec![0.0; ts.numel()] },
            };
            tensor_to_literal(&t, ts).ok()
        })
        .collect::<Option<Vec<_>>>()?;
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    exe.run(&refs).ok()?;
    Some(rt)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_artifact_round_trips() {
        let Some(rt) = runtime_if_available() else {
            eprintln!("skipping: AOT artifacts / PJRT backend unavailable");
            return;
        };
        let exe = rt.load("smoke").unwrap();
        let x = Tensor::F32 { shape: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] };
        let y = Tensor::F32 { shape: vec![2, 2], data: vec![1.0, 1.0, 1.0, 1.0] };
        let args = vec![
            tensor_to_literal(&x, &exe.spec.batch[0]).unwrap(),
            tensor_to_literal(&y, &exe.spec.batch[1]).unwrap(),
        ];
        let refs: Vec<&xla::Literal> = args.iter().collect();
        let out = exe.run(&refs).unwrap();
        assert_eq!(out.len(), 1);
        let z = literal_to_f32(&out[0]).unwrap();
        assert_eq!(z, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Ok(rt) = Runtime::from_default_dir() else {
            eprintln!("skipping: AOT artifacts unavailable");
            return;
        };
        let exe = rt.load("smoke").unwrap();
        let bad = Tensor::F32 { shape: vec![3], data: vec![0.0; 3] };
        assert!(tensor_to_literal(&bad, &exe.spec.batch[0]).is_err());
    }
}
