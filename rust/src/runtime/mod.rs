//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes train/infer steps with device-resident state.
//!
//! Pipeline: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute_b`.  HLO *text* is the interchange
//! format (xla_extension 0.5.1 rejects jax≥0.5 binary protos).

pub mod exec;
pub mod gstf;
pub mod manifest;
pub mod state;

pub use exec::{runtime_if_available, Executable, Runtime};
pub use gstf::Tensor;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
pub use state::{InferSession, StepOut, TrainState};
