//! Runtime lock-order tracker: asserts, in debug builds, the same
//! acquisition DAG the static `lock-order` lint rule checks —
//!
//!     cache mutex (shard 0 < 1 < …)  ->  PJRT session lock  ->  EmbTable row locks  ->  leaf mutexes
//!
//! The static rule (`rust/src/lint/rules.rs`) sees only intra-function
//! acquisition sequences; this tracker sees the *dynamic* stack, so an
//! acquisition path threaded through trait objects or closures that
//! the lint can't follow still trips an assert in `cargo test`.
//! Release builds compile the whole thing away: `acquire` returns a
//! zero-sized token and never touches thread-local state.
//!
//! Wire-up: `serve::error::{lock_cache, lock_shard, lock_clean,
//! lock_ranked}` stamp their guards with a token, `dist::EmbTable` row
//! guards carry one, and the PJRT serialization lock in `serve::engine`
//! acquires at `Rank::Session`.  See docs/LINTS.md (lock-order rule).

/// Lock ranks in declared acquisition order.  `Cache` and `Session`
/// are singletons per shard (re-entry on one thread self-deadlocks, so
/// same-rank same-shard re-acquisition asserts too); cache *shards*
/// (`serve::ShardedCache`) sub-rank the `Cache` level by shard index
/// and may only be acquired in ascending index order — the per-shard
/// DAG the sharded hot path relies on.  `EmbRows` covers every
/// `EmbTable`'s row lock (several tables, or several shards of one
/// table, may be read together) and `Leaf` the clean-state mutexes
/// (channels, counters, fault registries) that must always be
/// innermost.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Rank {
    Cache = 0,
    Session = 1,
    EmbRows = 2,
    Leaf = 3,
}

impl Rank {
    pub fn name(self) -> &'static str {
        match self {
            Rank::Cache => "cache mutex",
            Rank::Session => "PJRT session lock",
            Rank::EmbRows => "EmbTable row lock",
            Rank::Leaf => "leaf mutex",
        }
    }
}

#[cfg(debug_assertions)]
thread_local! {
    static HELD: std::cell::RefCell<Vec<(Rank, u32)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// RAII token recording one held lock; drop it when the guard drops
/// (embed it in the guard struct so the lifetimes can't diverge).
#[must_use]
pub struct Held {
    #[cfg(debug_assertions)]
    rank: Rank,
    #[cfg(debug_assertions)]
    shard: u32,
}

/// Record an acquisition *before* blocking on the lock itself — the
/// point of the tracker is to flag a deadlock-shaped ordering even on
/// runs where the timing happens to work out.  Non-sharded locks live
/// at shard 0 of their rank.
pub fn acquire(rank: Rank) -> Held {
    acquire_shard(rank, 0)
}

/// [`acquire`] for one shard of a striped lock (currently only
/// `Rank::Cache` is striped, by `serve::ShardedCache`): shards of the
/// same rank may nest, but only in ascending shard-index order, so
/// every thread walks the same per-shard DAG and two threads can never
/// hold each other's next shard.
pub fn acquire_shard(rank: Rank, shard: u32) -> Held {
    #[cfg(debug_assertions)]
    {
        HELD.with(|h| {
            for &(r, s) in h.borrow().iter() {
                let violates = r > rank
                    || (r == rank
                        && rank <= Rank::Session
                        && !(rank == Rank::Cache && s < shard));
                assert!(
                    !violates,
                    "lock-order violation: acquiring {} (shard {}) while holding {} (shard {}) — \
                     declared order is cache (ascending shards) -> session -> rows -> leaf \
                     (docs/LINTS.md)",
                    rank.name(),
                    shard,
                    r.name(),
                    s,
                );
            }
            h.borrow_mut().push((rank, shard));
        });
        Held { rank, shard }
    }
    #[cfg(not(debug_assertions))]
    {
        let _ = (rank, shard);
        Held {}
    }
}

#[cfg(debug_assertions)]
impl Drop for Held {
    fn drop(&mut self) {
        // try_with: tolerate thread-teardown order (a guard dropped
        // after the thread-local was destroyed just skips the pop).
        let _ = HELD.try_with(|h| {
            let mut v = h.borrow_mut();
            if let Some(pos) = v.iter().rposition(|&e| e == (self.rank, self.shard)) {
                v.remove(pos);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Rank stacks are thread-local; run each case on a fresh thread so
    // a panicking case can't leave state behind for the next.
    fn on_thread(f: impl FnOnce() + Send + 'static) -> std::thread::Result<()> {
        std::thread::spawn(f).join()
    }

    #[test]
    fn declared_order_is_clean() {
        on_thread(|| {
            let _c = acquire(Rank::Cache);
            let _s = acquire(Rank::Session);
            let _r = acquire(Rank::EmbRows);
            let _l = acquire(Rank::Leaf);
        })
        .unwrap();
    }

    #[test]
    fn release_resets_the_stack() {
        on_thread(|| {
            {
                let _r = acquire(Rank::EmbRows);
            }
            let _c = acquire(Rank::Cache); // fine: rows token dropped
        })
        .unwrap();
    }

    #[test]
    fn descending_acquisition_asserts() {
        let r = on_thread(|| {
            let _s = acquire(Rank::Session);
            let _c = acquire(Rank::Cache);
        });
        assert!(r.is_err(), "session -> cache must assert in debug builds");
    }

    #[test]
    fn singleton_reentry_asserts_but_rows_nest() {
        let r = on_thread(|| {
            let _a = acquire(Rank::Session);
            let _b = acquire(Rank::Session);
        });
        assert!(r.is_err(), "session re-entry self-deadlocks");
        on_thread(|| {
            let _a = acquire(Rank::EmbRows); // lemb table …
            let _b = acquire(Rank::EmbRows); // … and text table together
            let _l1 = acquire(Rank::Leaf);
            let _l2 = acquire(Rank::Leaf);
        })
        .unwrap();
    }

    #[test]
    fn cache_shards_nest_ascending_only() {
        on_thread(|| {
            let _a = acquire_shard(Rank::Cache, 0);
            let _b = acquire_shard(Rank::Cache, 1);
            let _c = acquire_shard(Rank::Cache, 5);
            let _s = acquire(Rank::Session); // downstream ranks still fine
        })
        .unwrap();
        let r = on_thread(|| {
            let _a = acquire_shard(Rank::Cache, 3);
            let _b = acquire_shard(Rank::Cache, 3);
        });
        assert!(r.is_err(), "same-shard re-entry self-deadlocks");
        let r = on_thread(|| {
            let _a = acquire_shard(Rank::Cache, 2);
            let _b = acquire_shard(Rank::Cache, 1);
        });
        assert!(r.is_err(), "descending shard order must assert");
        let r = on_thread(|| {
            let _r = acquire(Rank::EmbRows);
            let _c = acquire_shard(Rank::Cache, 7);
        });
        assert!(r.is_err(), "rows -> cache shard is still rank-descending");
    }
}
