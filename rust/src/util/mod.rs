//! Small shared utilities: deterministic RNG, FxHash-style hashing,
//! JSON, timers, padding helpers.

pub mod json;
pub mod lockorder;

/// FxHash-style multiply-rotate hasher (the rustc / firefox hash),
/// hand-rolled for the offline build.  Much cheaper than SipHash for
/// the small integer keys on the sampling hot path; NOT DoS-resistant,
/// which is fine for trusted in-process keys.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(FX_SEED);
    }
}

impl std::hash::Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rest.len()].copy_from_slice(rest);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }
}

/// `BuildHasher` for [`FxHasher`]; plugs into std collections.
#[derive(Default, Clone)]
pub struct FxBuildHasher;

impl std::hash::BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

/// HashMap/HashSet with the fast non-cryptographic hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;

/// Single-shot Fx hash of a u64 key (open-addressing tables).
#[inline]
pub fn fxhash64(key: u64) -> u64 {
    let h = (key ^ (key >> 32)).wrapping_mul(FX_SEED);
    h ^ (h >> 29)
}

/// SplitMix64 — seeds the main generator and hashes ids deterministically.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — the deterministic RNG used by every stochastic
/// component (generators, samplers, initializers).  No external crate:
/// determinism across the whole stack is an invariant the tests rely on.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). Unbiased enough for sampling (n ≪ 2^64).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        self.gen_f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn gen_normal(&mut self) -> f32 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Sample from an unnormalized discrete distribution.
    pub fn gen_categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork a child RNG (stable: depends only on parent state + tag).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

}

/// Wall-clock stopwatch that accumulates named stage timings.
#[derive(Default, Debug, Clone)]
pub struct StageTimer {
    pub stages: Vec<(String, f64)>,
}

impl StageTimer {
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        self.stages.push((name.to_string(), t0.elapsed().as_secs_f64()));
        out
    }

    pub fn get(&self, name: &str) -> f64 {
        self.stages
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, t)| *t)
            .sum()
    }

    pub fn total(&self) -> f64 {
        self.stages.iter().map(|(_, t)| *t).sum()
    }
}

/// Format seconds as the paper's H:MM:SS table entries.
pub fn fmt_hms(secs: f64) -> String {
    let s = secs.round() as u64;
    format!("{}:{:02}:{:02}", s / 3600, (s % 3600) / 60, s % 60)
}

/// Round up to a multiple.
#[inline]
pub fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fx_map_and_set_work() {
        let mut m: FxHashMap<(u32, u32), i32> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert((i % 7, i), i as i32);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&(3, 3)], 3);
        let mut s: FxHashSet<u64> = FxHashSet::default();
        for i in 0..100 {
            s.insert(i * 31);
        }
        assert!(s.contains(&62) && !s.contains(&63));
    }

    #[test]
    fn fxhash64_spreads_low_entropy_keys() {
        // Packed (ntype, id) keys differ only in low bits; their hashes
        // must still differ in the high bits used by the slot table.
        let mut seen = std::collections::HashSet::new();
        for id in 0..4096u64 {
            seen.insert(fxhash64(id) >> 52);
        }
        assert!(seen.len() > 256, "only {} distinct high-12-bit buckets", seen.len());
    }

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_range_bounds() {
        let mut r = Rng::seed_from(7);
        for _ in 0..1000 {
            assert!(r.gen_range(10) < 10);
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn categorical_respects_zero_weights() {
        let mut r = Rng::seed_from(9);
        for _ in 0..200 {
            let i = r.gen_categorical(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn fmt_hms_matches_paper_style() {
        assert_eq!(fmt_hms(3.5 * 3600.0), "3:30:00");
        assert_eq!(fmt_hms(61.0), "0:01:01");
    }

    #[test]
    fn normal_mean_near_zero() {
        let mut r = Rng::seed_from(11);
        let n = 20000;
        let mean: f32 = (0..n).map(|_| r.gen_normal()).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
    }
}
