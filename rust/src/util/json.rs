//! Minimal JSON parser/serializer (serde is unavailable in this
//! offline build — DESIGN.md §1).  Covers the full JSON grammar we
//! produce and consume: the AOT manifest, gconstruct schema configs,
//! and bench result dumps.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integral, non-negative numbers only: `2.7` and `-1` are `None`,
    /// not silently truncated/wrapped by an `as` cast — config keys
    /// like `serve.shards = 2.7` must fail validation, not coerce.
    /// The `9e15` bound keeps the f64 exactly representable as an
    /// integer (same bound the writer uses to emit integer syntax).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    /// u64 twin of [`Json::as_usize`] — same integrality and sign
    /// checks.
    pub fn as_u64(&self) -> Option<u64> {
        match self.as_f64() {
            Some(f) if f.fract() == 0.0 && f >= 0.0 && f < 9e15 => Some(f as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        match self.get(key).and_then(Json::as_str) {
            Some(s) => Ok(s),
            None => bail!("missing string field '{key}'"),
        }
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        match self.get(key).and_then(Json::as_usize) {
            Some(s) => Ok(s),
            None => bail!("missing numeric field '{key}'"),
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    v.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                let pad = " ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                if !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected byte at {}", self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = vec![];
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

/// Builder helpers for emitting JSON.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"artifacts": {"smoke": {"file": "smoke.hlo.txt",
            "n_params": 0, "state": [], "outputs":
            [{"name": "z", "shape": [2, 2], "dtype": "f32"}],
            "config": {"task": "smoke"}, "init_file": null}}}"#;
        let j = Json::parse(doc).unwrap();
        let smoke = j.get("artifacts").unwrap().get("smoke").unwrap();
        assert_eq!(smoke.str_of("file").unwrap(), "smoke.hlo.txt");
        assert_eq!(smoke.usize_of("n_params").unwrap(), 0);
        assert_eq!(smoke.get("init_file"), Some(&Json::Null));
        let out0 = &smoke.get("outputs").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> =
            out0.get("shape").unwrap().as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![2, 2]);
    }

    #[test]
    fn as_usize_rejects_non_integral_and_negative() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        // Fractional values must not silently truncate.
        assert_eq!(Json::Num(2.7).as_usize(), None);
        assert_eq!(Json::Num(0.5).as_usize(), None);
        // Negatives must not wrap or clamp.
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(-0.5).as_usize(), None);
        // Beyond exact-integer f64 range is rejected, not rounded.
        assert_eq!(Json::Num(1e16).as_usize(), None);
        // Non-numbers stay None.
        assert_eq!(Json::Str("3".into()).as_usize(), None);
        // usize_of surfaces the rejection as a hard error.
        let j = Json::parse(r#"{"shards": 2.7, "ok": 4}"#).unwrap();
        assert!(j.usize_of("shards").is_err());
        assert_eq!(j.usize_of("ok").unwrap(), 4);
    }

    #[test]
    fn as_u64_mirrors_usize_checks() {
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
        assert_eq!(Json::Num(2.7).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1e16).as_u64(), None);
        assert_eq!(Json::Null.as_u64(), None);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        let s = j.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }
}
