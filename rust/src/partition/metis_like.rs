//! Multilevel edge-cut partitioner in the METIS family [Karypis & Kumar
//! '98]: heavy-edge-matching coarsening → greedy BFS-grown initial
//! partition → boundary refinement at every uncoarsening level.
//!
//! Operates on the *homogenized* graph (all node types merged, edges
//! made undirected) exactly like GraphStorm's gconstruct does before
//! calling (Par)METIS.
//!
//! The coarse-edge accumulation pass — the O(E) hot loop of every
//! coarsening level — is sharded across `run_pipeline` workers.
//! Output is deterministic for any worker count: per-range partial
//! sums merge additively in range order and the merged edge list is
//! sorted before adjacency construction (the pre-parallel code
//! iterated a std `HashMap`, whose random per-instance seed made the
//! adjacency order — and thus the partition — vary run to run).

use crate::dataloader::{run_pipeline, PrefetchConfig};
use crate::graph::HeteroGraph;
use crate::partition::PartitionBook;
use crate::util::{FxHashMap, Rng};

/// Homogenized weighted graph used across the multilevel hierarchy.
struct Level {
    /// adjacency: per node, (neighbor, edge_weight).
    adj: Vec<Vec<(u32, u32)>>,
    /// node weight = number of fine nodes this vertex represents.
    vwgt: Vec<u32>,
    /// map fine node -> coarse node of the *next* level (filled on coarsen).
    fine_to_coarse: Vec<u32>,
}

fn homogenize(g: &HeteroGraph) -> (Vec<Vec<(u32, u32)>>, Vec<usize>) {
    // Global id = ntype offset + local id.
    let mut offsets = vec![0usize; g.num_nodes.len() + 1];
    for (i, &n) in g.num_nodes.iter().enumerate() {
        offsets[i + 1] = offsets[i] + n;
    }
    let total = offsets[g.num_nodes.len()];
    let mut adj: Vec<Vec<(u32, u32)>> = vec![Vec::new(); total];
    for (et, es) in g.edges.iter().enumerate() {
        let def = &g.schema.etypes[et];
        let so = offsets[def.src_ntype] as u32;
        let do_ = offsets[def.dst_ntype] as u32;
        for (&s, &d) in es.src.iter().zip(&es.dst) {
            let (u, v) = (so + s, do_ + d);
            if u != v {
                adj[u as usize].push((v, 1));
                adj[v as usize].push((u, 1));
            }
        }
    }
    (adj, offsets)
}

/// Batch-building threads for the coarse-edge accumulation pass.
/// Small graphs stay serial (thread setup would dominate).
fn coarsen_workers(n_nodes: usize) -> usize {
    if n_nodes < 20_000 {
        return 1;
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
}

/// Heavy-edge matching: visit nodes in random order, match each
/// unmatched node with its heaviest unmatched neighbor.
fn coarsen(level: &Level, rng: &mut Rng, workers: usize) -> Option<Level> {
    let n = level.adj.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut matched = vec![u32::MAX; n];
    let mut coarse_count = 0u32;
    for &u in &order {
        let u = u as usize;
        if matched[u] != u32::MAX {
            continue;
        }
        let mut best: Option<(u32, u32)> = None; // (neighbor, weight)
        for &(v, w) in &level.adj[u] {
            if matched[v as usize] == u32::MAX && v as usize != u {
                if best.map_or(true, |(_, bw)| w > bw) {
                    best = Some((v, w));
                }
            }
        }
        match best {
            Some((v, _)) => {
                matched[u] = coarse_count;
                matched[v as usize] = coarse_count;
            }
            None => matched[u] = coarse_count,
        }
        coarse_count += 1;
    }
    let cn = coarse_count as usize;
    if cn as f64 > 0.95 * n as f64 {
        return None; // diminishing returns — stop coarsening
    }
    // Build the coarse adjacency by merging parallel edges.
    let mut vwgt = vec![0u32; cn];
    for u in 0..n {
        vwgt[matched[u] as usize] += level.vwgt[u];
    }
    // Accumulate coarse edges sharded over fine-node ranges: workers
    // build per-range partial weight maps, the consumer merges them in
    // range order.  Addition is commutative, so the merged totals are
    // identical for any worker count.
    let chunk = n.div_ceil(workers.max(1) * 4).max(1);
    let ranges: Vec<(usize, usize)> = (0..n)
        .step_by(chunk)
        .map(|lo| (lo, (lo + chunk).min(n)))
        .collect();
    let mut edge_acc: FxHashMap<(u32, u32), u32> = FxHashMap::default();
    run_pipeline(
        &ranges,
        &PrefetchConfig { n_workers: workers, depth: 2 },
        || (),
        |_, _, &(lo, hi)| {
            let mut local: FxHashMap<(u32, u32), u32> = FxHashMap::default();
            for u in lo..hi {
                let cu = matched[u];
                for &(v, w) in &level.adj[u] {
                    let cv = matched[v as usize];
                    if cu != cv {
                        *local.entry((cu.min(cv), cu.max(cv))).or_insert(0) += w;
                    }
                }
            }
            Ok(local)
        },
        |_, local| {
            for (key, w) in local {
                *edge_acc.entry(key).or_insert(0) += w;
            }
            Ok(())
        },
    )
    .expect("coarse-edge accumulation cannot fail");
    // Sorted edge list → deterministic adjacency order for matching.
    let mut edges: Vec<((u32, u32), u32)> = edge_acc.into_iter().collect();
    edges.sort_unstable();
    let mut adj: Vec<Vec<(u32, u32)>> = vec![Vec::new(); cn];
    for ((a, b), w) in edges {
        // Each undirected fine edge was stored twice; weights double-count
        // consistently so relative magnitudes (all HEM needs) are intact.
        adj[a as usize].push((b, w));
        adj[b as usize].push((a, w));
    }
    Some(Level { adj, vwgt, fine_to_coarse: matched })
}

/// Greedy BFS region growing for the initial k-way partition.
fn initial_partition(level: &Level, k: usize, rng: &mut Rng) -> Vec<u32> {
    let n = level.adj.len();
    let total_w: u64 = level.vwgt.iter().map(|&w| w as u64).sum();
    let target = total_w.div_ceil(k as u64);
    let mut part = vec![u32::MAX; n];
    let mut part_w = vec![0u64; k];
    let mut unassigned: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut unassigned);
    let mut cursor = 0;
    for p in 0..k {
        // Seed from an unassigned node, grow a BFS frontier to target.
        let mut queue = std::collections::VecDeque::new();
        while cursor < unassigned.len() && part[unassigned[cursor] as usize] != u32::MAX {
            cursor += 1;
        }
        if cursor >= unassigned.len() {
            break;
        }
        queue.push_back(unassigned[cursor]);
        while let Some(u) = queue.pop_front() {
            let ui = u as usize;
            if part[ui] != u32::MAX {
                continue;
            }
            part[ui] = p as u32;
            part_w[p] += level.vwgt[ui] as u64;
            if part_w[p] >= target {
                break;
            }
            for &(v, _) in &level.adj[ui] {
                if part[v as usize] == u32::MAX {
                    queue.push_back(v);
                }
            }
        }
    }
    // Any leftovers go to the lightest part.
    for u in 0..n {
        if part[u] == u32::MAX {
            let p = (0..k).min_by_key(|&p| part_w[p]).unwrap();
            part[u] = p as u32;
            part_w[p] += level.vwgt[u] as u64;
        }
    }
    part
}

/// One boundary-refinement sweep (greedy KL/FM-style): move a node to
/// the neighboring part with the largest gain if balance allows.
fn refine(level: &Level, part: &mut [u32], k: usize) {
    let total_w: u64 = level.vwgt.iter().map(|&w| w as u64).sum();
    let max_w = (total_w.div_ceil(k as u64) as f64 * 1.1) as u64 + 1;
    let mut part_w = vec![0u64; k];
    for (u, &p) in part.iter().enumerate() {
        part_w[p as usize] += level.vwgt[u] as u64;
    }
    let mut gains = vec![0i64; k];
    for u in 0..level.adj.len() {
        let pu = part[u] as usize;
        // Connectivity to each part.
        for g in gains.iter_mut() {
            *g = 0;
        }
        let mut boundary = false;
        for &(v, w) in &level.adj[u] {
            let pv = part[v as usize] as usize;
            gains[pv] += w as i64;
            if pv != pu {
                boundary = true;
            }
        }
        if !boundary {
            continue;
        }
        let internal = gains[pu];
        if let Some((best_p, &best_gain)) = gains
            .iter()
            .enumerate()
            .filter(|&(p, _)| p != pu)
            .max_by_key(|&(_, g)| *g)
        {
            if best_gain > internal && part_w[best_p] + (level.vwgt[u] as u64) <= max_w {
                part_w[pu] -= level.vwgt[u] as u64;
                part_w[best_p] += level.vwgt[u] as u64;
                part[u] = best_p as u32;
            }
        }
    }
}

/// Multilevel k-way edge-cut partition of a heterogeneous graph.
/// Coarsening parallelism is auto-sized per level from available
/// cores (tiny coarse levels stay serial — thread setup would
/// dominate); output is identical for any worker count.
pub fn metis_like_partition(g: &HeteroGraph, n_parts: usize, seed: u64) -> PartitionBook {
    metis_like_partition_impl(g, n_parts, seed, &coarsen_workers)
}

/// [`metis_like_partition`] with an explicit coarsening worker count,
/// applied at every level (tests pin it to prove worker-count
/// independence).
pub fn metis_like_partition_with_workers(
    g: &HeteroGraph,
    n_parts: usize,
    seed: u64,
    workers: usize,
) -> PartitionBook {
    metis_like_partition_impl(g, n_parts, seed, &move |_| workers)
}

fn metis_like_partition_impl(
    g: &HeteroGraph,
    n_parts: usize,
    seed: u64,
    workers_for: &dyn Fn(usize) -> usize,
) -> PartitionBook {
    let mut rng = Rng::seed_from(seed ^ 0x4d45544953); // "METIS"
    let (adj, offsets) = homogenize(g);
    let n = adj.len();
    let mut levels = vec![Level { vwgt: vec![1; n], adj, fine_to_coarse: vec![] }];
    // Coarsen until small enough for a quality initial partition.
    while levels.last().unwrap().adj.len() > (n_parts * 128).max(256) {
        let workers = workers_for(levels.last().unwrap().adj.len());
        match coarsen(levels.last().unwrap(), &mut rng, workers) {
            Some(next) => {
                let f2c = next.fine_to_coarse.clone();
                levels.last_mut().unwrap().fine_to_coarse = f2c;
                levels.push(next);
            }
            None => break,
        }
    }
    // Initial partition on the coarsest level + refine.
    let coarsest = levels.len() - 1;
    let mut part = initial_partition(&levels[coarsest], n_parts, &mut rng);
    for _ in 0..4 {
        refine(&levels[coarsest], &mut part, n_parts);
    }
    // Uncoarsen: project + refine at each level.
    for li in (0..coarsest).rev() {
        let f2c = &levels[li].fine_to_coarse;
        let mut fine_part = vec![0u32; levels[li].adj.len()];
        for (u, p) in fine_part.iter_mut().enumerate() {
            *p = part[f2c[u] as usize];
        }
        part = fine_part;
        for _ in 0..2 {
            refine(&levels[li], &mut part, n_parts);
        }
    }
    // Split back per node type.
    let mut assignments = Vec::with_capacity(g.num_nodes.len());
    for (nt, &count) in g.num_nodes.iter().enumerate() {
        let off = offsets[nt];
        assignments.push(part[off..off + count].to_vec());
    }
    PartitionBook::new(n_parts, assignments)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeTypeDef, Schema};
    use crate::partition::{edge_cut, random_partition};

    /// Two dense clusters joined by one edge: the partitioner must find
    /// the natural cut.
    #[test]
    fn finds_planted_clusters() {
        let n = 200;
        let schema = Schema::new(
            vec!["v".into()],
            vec![EdgeTypeDef { name: "e".into(), src_ntype: 0, dst_ntype: 0 }],
        );
        let mut g = HeteroGraph::new(schema, vec![n]);
        let mut rng = Rng::seed_from(5);
        let (mut src, mut dst) = (vec![], vec![]);
        for cluster in 0..2u32 {
            let base = cluster * 100;
            for _ in 0..1000 {
                src.push(base + rng.gen_range(100) as u32);
                dst.push(base + rng.gen_range(100) as u32);
            }
        }
        src.push(0);
        dst.push(150);
        g.set_edges(0, src, dst);
        let book = metis_like_partition(&g, 2, 0);
        let cut = edge_cut(&g, &book);
        let rand_cut = edge_cut(&g, &random_partition(&g, 2, 0));
        assert!(cut < 0.15, "cut={cut}");
        assert!(cut < rand_cut / 3.0, "cut={cut} rand={rand_cut}");
    }

    /// Parallel coarsening must be deterministic: identical output
    /// across repeated runs and for any worker count (the partial
    /// weight maps merge additively and the edge list is sorted).
    #[test]
    fn partition_is_deterministic_and_worker_independent() {
        let n = 2000;
        let schema = Schema::new(
            vec!["v".into()],
            vec![EdgeTypeDef { name: "e".into(), src_ntype: 0, dst_ntype: 0 }],
        );
        let mut g = HeteroGraph::new(schema, vec![n]);
        let mut rng = Rng::seed_from(8);
        let (mut src, mut dst) = (vec![], vec![]);
        for _ in 0..12_000 {
            src.push(rng.gen_range(n) as u32);
            dst.push(rng.gen_range(n) as u32);
        }
        g.set_edges(0, src, dst);
        let base = metis_like_partition_with_workers(&g, 4, 5, 1);
        for workers in [1usize, 2, 4, 7] {
            let book = metis_like_partition_with_workers(&g, 4, 5, workers);
            assert_eq!(
                book.assignments, base.assignments,
                "workers={workers} changed the partition"
            );
        }
        // And the auto-sized entry point agrees with the pinned one.
        let auto = metis_like_partition(&g, 4, 5);
        assert_eq!(auto.assignments, base.assignments);
    }

    #[test]
    fn balance_holds_on_random_graph() {
        let n = 1000;
        let schema = Schema::new(
            vec!["v".into()],
            vec![EdgeTypeDef { name: "e".into(), src_ntype: 0, dst_ntype: 0 }],
        );
        let mut g = HeteroGraph::new(schema, vec![n]);
        let mut rng = Rng::seed_from(6);
        let (mut src, mut dst) = (vec![], vec![]);
        for _ in 0..5000 {
            src.push(rng.gen_range(n) as u32);
            dst.push(rng.gen_range(n) as u32);
        }
        g.set_edges(0, src, dst);
        for k in [2, 4, 8] {
            let book = metis_like_partition(&g, k, 1);
            let sizes = book.part_sizes();
            assert_eq!(sizes.iter().sum::<usize>(), n);
            let max = *sizes.iter().max().unwrap() as f64;
            assert!(max < 1.4 * n as f64 / k as f64, "k={k} sizes={sizes:?}");
        }
    }
}
