//! The partition book: node → partition mapping, per node type.

/// Maps every node to its owning partition (DistDGL's partition book).
#[derive(Debug, Clone)]
pub struct PartitionBook {
    pub n_parts: usize,
    /// assignments[ntype][local_id] = partition id.
    pub assignments: Vec<Vec<u32>>,
}

impl PartitionBook {
    pub fn new(n_parts: usize, assignments: Vec<Vec<u32>>) -> PartitionBook {
        debug_assert!(assignments.iter().flatten().all(|&p| (p as usize) < n_parts));
        PartitionBook { n_parts, assignments }
    }

    /// Single-partition book (single-machine mode).
    pub fn single(num_nodes: &[usize]) -> PartitionBook {
        PartitionBook::new(1, num_nodes.iter().map(|&n| vec![0u32; n]).collect())
    }

    #[inline]
    pub fn part_of(&self, ntype: usize, id: u32) -> u32 {
        self.assignments[ntype][id as usize]
    }

    /// Total nodes per partition (across node types).
    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.n_parts];
        for a in &self.assignments {
            for &p in a {
                sizes[p as usize] += 1;
            }
        }
        sizes
    }

    /// Nodes of `ntype` owned by `part`.
    pub fn nodes_of(&self, ntype: usize, part: u32) -> Vec<u32> {
        self.assignments[ntype]
            .iter()
            .enumerate()
            .filter(|(_, &p)| p == part)
            .map(|(i, _)| i as u32)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_node_exactly_once() {
        let book = PartitionBook::new(3, vec![vec![0, 1, 2, 0], vec![2, 2]]);
        let sizes = book.part_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 6);
        assert_eq!(book.nodes_of(0, 0), vec![0, 3]);
        assert_eq!(book.nodes_of(1, 2), vec![0, 1]);
    }

    #[test]
    fn single_book() {
        let book = PartitionBook::single(&[5, 3]);
        assert_eq!(book.n_parts, 1);
        assert_eq!(book.part_sizes(), vec![8]);
    }
}
