//! Graph partitioning: the partition book, random edge-cut, and a
//! multilevel METIS-like partitioner (DESIGN.md §1: METIS/ParMETIS
//! substitute).  Partition assignment is per node; edges live with
//! their destination (DistDGL's owner-computes rule for aggregation).

pub mod book;
pub mod metis_like;

pub use book::PartitionBook;
pub use metis_like::{metis_like_partition, metis_like_partition_with_workers};

use crate::graph::HeteroGraph;
use crate::util::Rng;

/// Random node partitioning (the paper's Table 3 setting).
pub fn random_partition(g: &HeteroGraph, n_parts: usize, seed: u64) -> PartitionBook {
    let mut rng = Rng::seed_from(seed);
    let assign = g
        .num_nodes
        .iter()
        .map(|&n| (0..n).map(|_| rng.gen_range(n_parts) as u32).collect())
        .collect();
    PartitionBook::new(n_parts, assign)
}

/// Edge-cut fraction: edges whose endpoints live in different parts.
pub fn edge_cut(g: &HeteroGraph, book: &PartitionBook) -> f64 {
    let mut cut = 0usize;
    let mut total = 0usize;
    for (et, es) in g.edges.iter().enumerate() {
        let def = &g.schema.etypes[et];
        for (&s, &d) in es.src.iter().zip(&es.dst) {
            total += 1;
            if book.part_of(def.src_ntype, s) != book.part_of(def.dst_ntype, d) {
                cut += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        cut as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeTypeDef, Schema};

    fn ring(n: usize) -> HeteroGraph {
        let schema = Schema::new(
            vec!["v".into()],
            vec![EdgeTypeDef { name: "e".into(), src_ntype: 0, dst_ntype: 0 }],
        );
        let mut g = HeteroGraph::new(schema, vec![n]);
        let src: Vec<u32> = (0..n as u32).collect();
        let dst: Vec<u32> = (0..n as u32).map(|i| (i + 1) % n as u32).collect();
        g.set_edges(0, src, dst);
        g
    }

    #[test]
    fn random_partition_covers_all_nodes() {
        let g = ring(100);
        let book = random_partition(&g, 4, 1);
        assert_eq!(book.assignments[0].len(), 100);
        assert!(book.assignments[0].iter().all(|&p| p < 4));
        // All parts non-empty at this size (probabilistic but safe at n=100).
        let mut seen = vec![false; 4];
        for &p in &book.assignments[0] {
            seen[p as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn metis_like_beats_random_on_ring() {
        let g = ring(256);
        let rand_book = random_partition(&g, 4, 1);
        let metis_book = metis_like_partition(&g, 4, 1);
        let rc = edge_cut(&g, &rand_book);
        let mc = edge_cut(&g, &metis_book);
        // A ring cuts only ~k edges under a contiguous partition.
        assert!(mc < rc * 0.5, "metis-like cut {mc} vs random {rc}");
        // Balance within 25%.
        let sizes = metis_book.part_sizes();
        let max = *sizes.iter().max().unwrap() as f64;
        let min = *sizes.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 1.6, "imbalanced: {sizes:?}");
    }
}
