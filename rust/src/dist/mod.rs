//! The simulated distributed engine (DistDGL stand-in, paper §3.3):
//! partition-aware feature/embedding storage with cross-partition
//! traffic accounting, plus the cluster cost model that turns measured
//! single-process stage times + counted traffic into Table-3-style
//! instance estimates.
//!
//! Every gather is attributed to an acting `worker` (partition id); a
//! row whose owner differs from the acting worker counts as remote
//! traffic.  Counters are atomic and embedding tables use interior
//! mutability, so the prefetching loader's worker threads can assemble
//! batches from `&GsDataset` while the main thread applies sparse
//! embedding updates between steps.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::partition::PartitionBook;
use crate::util::lockorder::{self, Rank};
use crate::util::Rng;

/// Cross-partition traffic totals (elements are f32 rows * dim).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    pub local_elems: u64,
    pub remote_elems: u64,
    pub remote_bytes: u64,
}

/// Shared atomic traffic counters; one instance per engine, cloned
/// (via `Arc`) into every distributed tensor.
#[derive(Debug, Default)]
pub struct TrafficCounters {
    local_elems: AtomicU64,
    remote_elems: AtomicU64,
    remote_bytes: AtomicU64,
}

impl TrafficCounters {
    pub fn new() -> TrafficCounters {
        TrafficCounters::default()
    }

    pub fn reset(&self) {
        self.local_elems.store(0, Ordering::Relaxed);
        self.remote_elems.store(0, Ordering::Relaxed);
        self.remote_bytes.store(0, Ordering::Relaxed);
    }

    #[inline]
    pub fn record(&self, is_local: bool, elems: u64) {
        if is_local {
            self.local_elems.fetch_add(elems, Ordering::Relaxed);
        } else {
            self.remote_elems.fetch_add(elems, Ordering::Relaxed);
            self.remote_bytes.fetch_add(elems * 4, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> Traffic {
        Traffic {
            local_elems: self.local_elems.load(Ordering::Relaxed),
            remote_elems: self.remote_elems.load(Ordering::Relaxed),
            remote_bytes: self.remote_bytes.load(Ordering::Relaxed),
        }
    }
}

/// A read-mostly distributed dense tensor ([n, dim], row-major) over
/// one node type; rows are owned by partitions per the book.
pub struct DistTensor {
    pub ntype: usize,
    pub dim: usize,
    data: Vec<f32>,
    book: Arc<PartitionBook>,
    counters: Arc<TrafficCounters>,
}

impl DistTensor {
    pub fn from_data(
        ntype: usize,
        dim: usize,
        data: Vec<f32>,
        book: Arc<PartitionBook>,
        counters: Arc<TrafficCounters>,
    ) -> DistTensor {
        if dim > 0 {
            assert_eq!(data.len() % dim, 0, "data length not a multiple of dim");
        }
        DistTensor { ntype, dim, data, book, counters }
    }

    /// Placeholder tensor for a node type with no data yet (dim 0).
    pub fn empty(ntype: usize, book: Arc<PartitionBook>, counters: Arc<TrafficCounters>) -> DistTensor {
        DistTensor { ntype, dim: 0, data: vec![], book, counters }
    }

    pub fn num_rows(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.data.len() / self.dim
        }
    }

    /// Direct row view (no traffic accounting — debugging / tests).
    #[inline]
    pub fn row(&self, id: u32) -> &[f32] {
        &self.data[id as usize * self.dim..(id as usize + 1) * self.dim]
    }

    /// Gather rows on behalf of partition `worker`, counting traffic.
    pub fn gather(&self, worker: u32, ids: &[u32]) -> Vec<f32> {
        let mut out = vec![0.0f32; ids.len() * self.dim];
        self.gather_into(worker, ids, &mut out);
        out
    }

    /// Allocation-free gather into a caller-owned buffer
    /// (`out.len() == ids.len() * dim`).
    pub fn gather_into(&self, worker: u32, ids: &[u32], out: &mut [f32]) {
        let d = self.dim;
        assert_eq!(out.len(), ids.len() * d);
        let (mut local, mut remote) = (0u64, 0u64);
        for (j, &id) in ids.iter().enumerate() {
            out[j * d..(j + 1) * d].copy_from_slice(self.row(id));
            if self.book.part_of(self.ntype, id) == worker {
                local += d as u64;
            } else {
                remote += d as u64;
            }
        }
        if local > 0 {
            self.counters.record(true, local);
        }
        if remote > 0 {
            self.counters.record(false, remote);
        }
    }
}

/// Rows + sparse-Adam moments of one learnable embedding table.
struct EmbInner {
    w: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Per-row update count (bias correction is per row, as in
    /// DGL's sparse Adam).
    t: Vec<u32>,
}

/// Poison-recovered row-lock guards stamped at `Rank::EmbRows` so the
/// debug-build lock-order tracker (`util::lockorder`) sees real hold
/// intervals; several tables may be read together (equal-rank nesting
/// is allowed for rows).
struct InnerRead<'a> {
    guard: RwLockReadGuard<'a, EmbInner>,
    _order: lockorder::Held,
}

impl std::ops::Deref for InnerRead<'_> {
    type Target = EmbInner;

    fn deref(&self) -> &EmbInner {
        &self.guard
    }
}

struct InnerWrite<'a> {
    guard: RwLockWriteGuard<'a, EmbInner>,
    _order: lockorder::Held,
}

impl std::ops::Deref for InnerWrite<'_> {
    type Target = EmbInner;

    fn deref(&self) -> &EmbInner {
        &self.guard
    }
}

impl std::ops::DerefMut for InnerWrite<'_> {
    fn deref_mut(&mut self) -> &mut EmbInner {
        &mut self.guard
    }
}

/// Learnable embedding table for a featureless node type
/// (paper §3.3.2, option 2).  Interior mutability: gathers take a read
/// lock, the sparse-Adam update a write lock, so prefetch workers and
/// the training thread can share the engine immutably.
pub struct EmbTable {
    pub ntype: usize,
    pub dim: usize,
    inner: RwLock<EmbInner>,
    book: Arc<PartitionBook>,
    counters: Arc<TrafficCounters>,
    /// Bumped by every sparse-Adam update; generation-stamped caches
    /// (`serve::EmbeddingCache`) compare against this to invalidate
    /// all cached rows in O(1) when the table moves.
    generation: AtomicU64,
    /// Set on the first poisoned-lock recovery, alongside a one-time
    /// generation bump (see [`Self::note_poison`]).
    poison_bumped: AtomicBool,
}

impl EmbTable {
    pub fn new(
        ntype: usize,
        n: usize,
        dim: usize,
        seed: u64,
        book: Arc<PartitionBook>,
        counters: Arc<TrafficCounters>,
    ) -> EmbTable {
        let mut rng = Rng::seed_from(seed ^ 0xe8b);
        let scale = 1.0 / (dim as f32).sqrt();
        let w: Vec<f32> = (0..n * dim).map(|_| rng.gen_normal() * scale).collect();
        let inner = EmbInner { w, m: vec![0.0; n * dim], v: vec![0.0; n * dim], t: vec![0; n] };
        EmbTable {
            ntype,
            dim,
            inner: RwLock::new(inner),
            book,
            counters,
            generation: AtomicU64::new(0),
            poison_bumped: AtomicBool::new(false),
        }
    }

    /// Recover the inner lock from poisoning.  A panicked writer can
    /// leave `w`/`m`/`v` half-updated; the data is still well-formed
    /// (every f32 is valid), so we adopt the mixed state as the new
    /// canonical weights and bump the generation **once** — rows
    /// cached before the panic can never be stamped current again,
    /// while rows re-gathered afterwards are stamped at the new
    /// generation and served consistently.  (The RwLock itself stays
    /// poisoned forever; the one-shot flag keeps the hot gather path
    /// from thrashing the cache with a bump per recovery.)
    fn note_poison(&self) {
        if !self.poison_bumped.swap(true, Ordering::AcqRel) {
            self.generation.fetch_add(1, Ordering::AcqRel);
        }
    }

    fn read_inner(&self) -> InnerRead<'_> {
        let _order = lockorder::acquire(Rank::EmbRows);
        let guard = match self.inner.read() {
            Ok(g) => g,
            Err(poisoned) => {
                self.note_poison();
                poisoned.into_inner()
            }
        };
        InnerRead { guard, _order }
    }

    fn write_inner(&self) -> InnerWrite<'_> {
        let _order = lockorder::acquire(Rank::EmbRows);
        let guard = match self.inner.write() {
            Ok(g) => g,
            Err(poisoned) => {
                self.note_poison();
                poisoned.into_inner()
            }
        };
        InnerWrite { guard, _order }
    }

    pub fn num_rows(&self) -> usize {
        self.read_inner().t.len()
    }

    /// Update generation: changes whenever any row is written.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Externally mark the table as updated (checkpoint restore, bulk
    /// weight swap — writes that bypass [`sparse_adam`](Self::sparse_adam)).
    /// Generation-stamped caches (`serve::EmbeddingCache`) invalidate
    /// on the next lookup and `serve::refresh` re-reads hot rows in
    /// the background instead of letting them turn into a miss storm.
    pub fn bump_generation(&self) {
        self.generation.fetch_add(1, Ordering::AcqRel);
    }

    /// Read one row on behalf of partition `worker`
    /// (`out.len() == dim`), counting traffic — the serving-side
    /// lookup the read-through cache wraps.
    pub fn row_into(&self, worker: u32, id: u32, out: &mut [f32]) {
        self.gather_into(worker, std::slice::from_ref(&id), out);
    }

    /// Copy of the current weights (tests / checkpointing).
    pub fn weights_snapshot(&self) -> Vec<f32> {
        self.read_inner().w.clone()
    }

    /// Gather rows into `out` (`out.len() == ids.len() * dim`) on
    /// behalf of partition `worker`, counting traffic.
    pub fn gather_into(&self, worker: u32, ids: &[u32], out: &mut [f32]) {
        let d = self.dim;
        assert_eq!(out.len(), ids.len() * d);
        let inner = self.read_inner();
        let (mut local, mut remote) = (0u64, 0u64);
        for (j, &id) in ids.iter().enumerate() {
            let base = id as usize * d;
            out[j * d..(j + 1) * d].copy_from_slice(&inner.w[base..base + d]);
            if self.book.part_of(self.ntype, id) == worker {
                local += d as u64;
            } else {
                remote += d as u64;
            }
        }
        if local > 0 {
            self.counters.record(true, local);
        }
        if remote > 0 {
            self.counters.record(false, remote);
        }
    }

    /// Sparse Adam over the touched rows (`grads.len() == ids.len() * dim`).
    /// Duplicate ids apply sequentially in order — deterministic.
    pub fn sparse_adam(&self, ids: &[u32], grads: &[f32], lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        let d = self.dim;
        assert_eq!(grads.len(), ids.len() * d);
        let mut inner = self.write_inner();
        for (j, &id) in ids.iter().enumerate() {
            let r = id as usize;
            inner.t[r] += 1;
            let t = inner.t[r] as f32;
            let bc1 = 1.0 - B1.powf(t);
            let bc2 = 1.0 - B2.powf(t);
            for k in 0..d {
                let i = r * d + k;
                let g = grads[j * d + k];
                inner.m[i] = B1 * inner.m[i] + (1.0 - B1) * g;
                inner.v[i] = B2 * inner.v[i] + (1.0 - B2) * g * g;
                let mhat = inner.m[i] / bc1;
                let vhat = inner.v[i] / bc2;
                inner.w[i] -= lr * mhat / (vhat.sqrt() + EPS);
            }
        }
        // Bump the generation while still holding the write lock: a
        // reader that stamps rows with the new generation can only
        // have gathered them *after* this update landed.  (Bumping
        // before the lock would let a concurrent read-through cache
        // stamp pre-update rows as current.)
        self.generation.fetch_add(1, Ordering::AcqRel);
    }
}

/// The per-process engine: features, text embeddings and learnable
/// tables for every node type, plus the shared traffic counters.
pub struct DistEngine {
    pub book: Arc<PartitionBook>,
    pub counters: Arc<TrafficCounters>,
    pub features: Vec<DistTensor>,
    pub text_emb: Vec<DistTensor>,
    pub embeds: Vec<Option<EmbTable>>,
}

impl DistEngine {
    pub fn new(book: Arc<PartitionBook>, num_nodes: &[usize]) -> DistEngine {
        let counters = Arc::new(TrafficCounters::new());
        let features = (0..num_nodes.len())
            .map(|nt| DistTensor::empty(nt, book.clone(), counters.clone()))
            .collect();
        let text_emb = (0..num_nodes.len())
            .map(|nt| DistTensor::empty(nt, book.clone(), counters.clone()))
            .collect();
        let embeds = num_nodes.iter().map(|_| None).collect();
        DistEngine { book, counters, features, text_emb, embeds }
    }

    /// Attach a learnable embedding table to a featureless node type.
    pub fn add_embed(&mut self, ntype: usize, n: usize, dim: usize, seed: u64) {
        self.embeds[ntype] = Some(EmbTable::new(
            ntype,
            n,
            dim,
            seed,
            self.book.clone(),
            self.counters.clone(),
        ));
    }
}

/// Cluster cost model (Table 3): turns a measured single-process stage
/// time plus counted cross-partition traffic into an estimated
/// wall-clock on `instances` machines.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Fraction of compute that parallelizes across instances.
    pub parallel_efficiency: f64,
    /// Cross-instance NIC bandwidth, bytes/s (10 Gb/s default).
    pub bandwidth_bps: f64,
    /// Per-step synchronization latency, seconds.
    pub step_latency_s: f64,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel {
            parallel_efficiency: 0.85,
            bandwidth_bps: 1.25e9,
            step_latency_s: 2e-3,
        }
    }
}

impl CostModel {
    /// Estimated wall-clock seconds on `instances` machines for a stage
    /// measured at `secs` single-process, moving `remote_bytes` across
    /// the network in `steps` synchronized steps.
    pub fn estimate(&self, secs: f64, remote_bytes: u64, steps: u64, instances: usize) -> f64 {
        let n = instances.max(1) as f64;
        let compute = secs * ((1.0 - self.parallel_efficiency) + self.parallel_efficiency / n);
        let network = remote_bytes as f64 / self.bandwidth_bps;
        let sync = steps as f64 * self.step_latency_s * n.log2().max(1.0);
        compute + network + sync
    }

    /// The paper's instance-minutes metric.
    pub fn instance_minutes(&self, secs: f64, instances: usize) -> f64 {
        secs * instances.max(1) as f64 / 60.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize, parts: usize) -> (Arc<PartitionBook>, Arc<TrafficCounters>) {
        let book = Arc::new(PartitionBook::new(
            parts,
            vec![(0..n).map(|i| (i % parts) as u32).collect()],
        ));
        (book, Arc::new(TrafficCounters::new()))
    }

    #[test]
    fn gather_counts_local_vs_remote() {
        let (book, counters) = setup(10, 2);
        let t = DistTensor::from_data(0, 4, vec![1.0; 40], book, counters.clone());
        // Worker 0 owns even ids; gather two even + one odd.
        let out = t.gather(0, &[0, 2, 3]);
        assert_eq!(out.len(), 12);
        let s = counters.snapshot();
        assert_eq!(s.local_elems, 8);
        assert_eq!(s.remote_elems, 4);
        assert_eq!(s.remote_bytes, 16);
        counters.reset();
        assert_eq!(counters.snapshot(), Traffic::default());
    }

    #[test]
    fn single_partition_never_remote() {
        let (book, counters) = setup(6, 1);
        let t = DistTensor::from_data(0, 2, vec![0.5; 12], book, counters.clone());
        t.gather(0, &[0, 1, 2, 3, 4, 5]);
        let s = counters.snapshot();
        assert_eq!(s.remote_elems, 0);
        assert_eq!(s.local_elems, 12);
    }

    #[test]
    fn emb_table_poison_recovery_bumps_generation_once() {
        let (book, counters) = setup(4, 1);
        let e = EmbTable::new(0, 4, 2, 7, book, counters);
        e.sparse_adam(&[0], &[1.0; 2], 1e-2);
        assert_eq!(e.generation(), 1);
        // Poison the inner lock the way a crashed updater would.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = e.inner.write().unwrap();
            panic!("die mid-update");
        }));
        assert!(e.inner.is_poisoned());
        // Every access recovers; only the first bumps the generation.
        let mut row = vec![0.0f32; 2];
        e.row_into(0, 1, &mut row);
        assert_eq!(e.generation(), 2, "first recovery invalidates cached rows");
        e.row_into(0, 2, &mut row);
        assert_eq!(e.num_rows(), 4);
        assert_eq!(e.generation(), 2, "later recoveries must not thrash the cache");
        // Updates still apply and still bump per update.
        e.sparse_adam(&[1], &[1.0; 2], 1e-2);
        assert_eq!(e.generation(), 3);
    }

    #[test]
    fn emb_table_adam_moves_touched_rows_only() {
        let (book, counters) = setup(5, 1);
        let e = EmbTable::new(0, 5, 4, 7, book, counters);
        let before = e.weights_snapshot();
        assert_eq!(e.generation(), 0);
        e.sparse_adam(&[1, 3], &[1.0; 8], 1e-2);
        assert_eq!(e.generation(), 1, "updates must bump the cache generation");
        let mut row = vec![0.0f32; 4];
        e.row_into(0, 1, &mut row);
        let after = e.weights_snapshot();
        assert_eq!(row, &after[4..8]);
        for r in 0..5 {
            let changed = (0..4).any(|k| before[r * 4 + k] != after[r * 4 + k]);
            assert_eq!(changed, r == 1 || r == 3, "row {r}");
        }
    }

    #[test]
    fn emb_gather_matches_snapshot() {
        let (book, counters) = setup(4, 2);
        let e = EmbTable::new(0, 4, 3, 9, book, counters);
        let snap = e.weights_snapshot();
        let mut out = vec![0.0; 6];
        e.gather_into(0, &[2, 0], &mut out);
        assert_eq!(&out[..3], &snap[6..9]);
        assert_eq!(&out[3..], &snap[0..3]);
    }

    #[test]
    fn cost_model_monotone() {
        let cm = CostModel::default();
        // More instances shrink compute-bound stages.
        let e1 = cm.estimate(100.0, 0, 0, 1);
        let e8 = cm.estimate(100.0, 0, 0, 8);
        assert!(e8 < e1);
        // Traffic adds time.
        assert!(cm.estimate(10.0, 5_000_000_000, 100, 4) > cm.estimate(10.0, 0, 100, 4));
        assert_eq!(cm.instance_minutes(120.0, 4), 8.0);
    }
}
