//! The simulated distributed engine (DistDGL stand-in, paper §3.3):
//! partition-aware feature/embedding storage with cross-partition
//! traffic accounting, plus the cluster cost model that turns measured
//! single-process stage times + counted traffic into Table-3-style
//! instance estimates.
//!
//! Every gather is attributed to an acting `worker` (partition id); a
//! row whose owner differs from the acting worker counts as remote
//! traffic.  Counters are atomic and embedding tables use interior
//! mutability, so the prefetching loader's worker threads can assemble
//! batches from `&GsDataset` while the main thread applies sparse
//! embedding updates between steps.  [`EmbTable`] rows can further be
//! striped N ways by the serving hash (`serve::shard_of`) with
//! per-stripe locks and generations — sparse-Adam writers and serve
//! readers on different stripes never contend, and every layout is
//! bit-identical to the single-stripe table.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::partition::PartitionBook;
use crate::serve::shard_of;
use crate::util::lockorder::{self, Rank};
use crate::util::Rng;

/// Cross-partition traffic totals (elements are f32 rows * dim).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    pub local_elems: u64,
    pub remote_elems: u64,
    pub remote_bytes: u64,
}

/// Shared atomic traffic counters; one instance per engine, cloned
/// (via `Arc`) into every distributed tensor.
#[derive(Debug, Default)]
pub struct TrafficCounters {
    local_elems: AtomicU64,
    remote_elems: AtomicU64,
    remote_bytes: AtomicU64,
}

impl TrafficCounters {
    pub fn new() -> TrafficCounters {
        TrafficCounters::default()
    }

    pub fn reset(&self) {
        self.local_elems.store(0, Ordering::Relaxed);
        self.remote_elems.store(0, Ordering::Relaxed);
        self.remote_bytes.store(0, Ordering::Relaxed);
    }

    #[inline]
    pub fn record(&self, is_local: bool, elems: u64) {
        if is_local {
            self.local_elems.fetch_add(elems, Ordering::Relaxed);
        } else {
            self.remote_elems.fetch_add(elems, Ordering::Relaxed);
            self.remote_bytes.fetch_add(elems * 4, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> Traffic {
        Traffic {
            local_elems: self.local_elems.load(Ordering::Relaxed),
            remote_elems: self.remote_elems.load(Ordering::Relaxed),
            remote_bytes: self.remote_bytes.load(Ordering::Relaxed),
        }
    }
}

/// A read-mostly distributed dense tensor ([n, dim], row-major) over
/// one node type; rows are owned by partitions per the book.
pub struct DistTensor {
    pub ntype: usize,
    pub dim: usize,
    data: Vec<f32>,
    book: Arc<PartitionBook>,
    counters: Arc<TrafficCounters>,
}

impl DistTensor {
    pub fn from_data(
        ntype: usize,
        dim: usize,
        data: Vec<f32>,
        book: Arc<PartitionBook>,
        counters: Arc<TrafficCounters>,
    ) -> DistTensor {
        if dim > 0 {
            assert_eq!(data.len() % dim, 0, "data length not a multiple of dim");
        }
        DistTensor { ntype, dim, data, book, counters }
    }

    /// Placeholder tensor for a node type with no data yet (dim 0).
    pub fn empty(ntype: usize, book: Arc<PartitionBook>, counters: Arc<TrafficCounters>) -> DistTensor {
        DistTensor { ntype, dim: 0, data: vec![], book, counters }
    }

    pub fn num_rows(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.data.len() / self.dim
        }
    }

    /// Direct row view (no traffic accounting — debugging / tests).
    #[inline]
    pub fn row(&self, id: u32) -> &[f32] {
        &self.data[id as usize * self.dim..(id as usize + 1) * self.dim]
    }

    /// Gather rows on behalf of partition `worker`, counting traffic.
    pub fn gather(&self, worker: u32, ids: &[u32]) -> Vec<f32> {
        let mut out = vec![0.0f32; ids.len() * self.dim];
        self.gather_into(worker, ids, &mut out);
        out
    }

    /// Allocation-free gather into a caller-owned buffer
    /// (`out.len() == ids.len() * dim`).
    pub fn gather_into(&self, worker: u32, ids: &[u32], out: &mut [f32]) {
        let d = self.dim;
        assert_eq!(out.len(), ids.len() * d);
        let (mut local, mut remote) = (0u64, 0u64);
        for (j, &id) in ids.iter().enumerate() {
            out[j * d..(j + 1) * d].copy_from_slice(self.row(id));
            if self.book.part_of(self.ntype, id) == worker {
                local += d as u64;
            } else {
                remote += d as u64;
            }
        }
        if local > 0 {
            self.counters.record(true, local);
        }
        if remote > 0 {
            self.counters.record(false, remote);
        }
    }
}

/// Rows + sparse-Adam moments of one learnable embedding table.
struct EmbInner {
    w: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Per-row update count (bias correction is per row, as in
    /// DGL's sparse Adam).
    t: Vec<u32>,
}

/// Poison-recovered row-lock guards stamped at `Rank::EmbRows` so the
/// debug-build lock-order tracker (`util::lockorder`) sees real hold
/// intervals; several tables may be read together (equal-rank nesting
/// is allowed for rows).
struct InnerRead<'a> {
    guard: RwLockReadGuard<'a, EmbInner>,
    _order: lockorder::Held,
}

impl std::ops::Deref for InnerRead<'_> {
    type Target = EmbInner;

    fn deref(&self) -> &EmbInner {
        &self.guard
    }
}

struct InnerWrite<'a> {
    guard: RwLockWriteGuard<'a, EmbInner>,
    _order: lockorder::Held,
}

impl std::ops::Deref for InnerWrite<'_> {
    type Target = EmbInner;

    fn deref(&self) -> &EmbInner {
        &self.guard
    }
}

impl std::ops::DerefMut for InnerWrite<'_> {
    fn deref_mut(&mut self) -> &mut EmbInner {
        &mut self.guard
    }
}

/// One stripe of a (possibly sharded) [`EmbTable`]: its rows' weights
/// and Adam moments behind their own `RwLock`, its own generation
/// counter, and its own one-shot poison flag — so sparse-Adam writers
/// and serving readers touching *different* stripes never contend.
struct EmbShard {
    inner: RwLock<EmbInner>,
    /// Bumped (while holding this stripe's write lock) by every
    /// sparse-Adam update that touched a row in this stripe.
    generation: AtomicU64,
    /// Set on the first poisoned-lock recovery, alongside a one-time
    /// generation bump (see [`EmbTable::note_poison`]).
    poison_bumped: AtomicBool,
}

/// Learnable embedding table for a featureless node type
/// (paper §3.3.2, option 2).  Interior mutability: gathers take a read
/// lock, the sparse-Adam update a write lock, so prefetch workers and
/// the training thread can share the engine immutably.
///
/// Rows are striped across `shards` independently locked stripes by
/// `serve::shard_of(id)` — the same hash the serving cache stripes
/// keys with, so one node's row and its cached prediction always live
/// in the same stripe index of their respective structures.
/// [`Self::new`] builds the classic single-stripe table; for any shard
/// count the initial weights, updates and gathers are **bit-identical**
/// (weights come from one RNG stream scattered to stripes; updates
/// apply in input order within each stripe and rows are independent).
/// The table [`Self::generation`] is the *sum* of per-stripe
/// generations: monotone, and for one stripe exactly the classic
/// per-update counter.
pub struct EmbTable {
    pub ntype: usize,
    pub dim: usize,
    n: usize,
    /// id → local row index within its stripe (`shard_of(id, shards)`).
    local: Vec<u32>,
    shards: Vec<EmbShard>,
    book: Arc<PartitionBook>,
    counters: Arc<TrafficCounters>,
}

impl EmbTable {
    /// Single-stripe table — the classic layout every trainer uses.
    pub fn new(
        ntype: usize,
        n: usize,
        dim: usize,
        seed: u64,
        book: Arc<PartitionBook>,
        counters: Arc<TrafficCounters>,
    ) -> EmbTable {
        EmbTable::new_sharded(ntype, n, dim, seed, 1, book, counters)
    }

    /// Table striped `shards` ways.  Weights come from the *same*
    /// single RNG stream regardless of shard count — generated in id
    /// order, then scattered to stripes — so a sharded table is
    /// bit-identical to the single-stripe one row for row.
    pub fn new_sharded(
        ntype: usize,
        n: usize,
        dim: usize,
        seed: u64,
        shards: usize,
        book: Arc<PartitionBook>,
        counters: Arc<TrafficCounters>,
    ) -> EmbTable {
        let nshards = shards.max(1);
        let mut rng = Rng::seed_from(seed ^ 0xe8b);
        let scale = 1.0 / (dim as f32).sqrt();
        let w: Vec<f32> = (0..n * dim).map(|_| rng.gen_normal() * scale).collect();
        let mut local = vec![0u32; n];
        let mut counts = vec![0usize; nshards];
        for id in 0..n {
            let s = shard_of(id as u64, nshards);
            local[id] = counts[s] as u32;
            counts[s] += 1;
        }
        // Ascending-id scatter matches the ascending local indices
        // assigned above, so each stripe's rows land in local order.
        let mut sw: Vec<Vec<f32>> =
            counts.iter().map(|&c| Vec::with_capacity(c * dim)).collect();
        for id in 0..n {
            sw[shard_of(id as u64, nshards)].extend_from_slice(&w[id * dim..(id + 1) * dim]);
        }
        let shards = sw
            .into_iter()
            .zip(&counts)
            .map(|(w, &c)| EmbShard {
                inner: RwLock::new(EmbInner {
                    w,
                    m: vec![0.0; c * dim],
                    v: vec![0.0; c * dim],
                    t: vec![0; c],
                }),
                generation: AtomicU64::new(0),
                poison_bumped: AtomicBool::new(false),
            })
            .collect();
        EmbTable { ntype, dim, n, local, shards, book, counters }
    }

    #[inline]
    fn shard_idx(&self, id: u32) -> usize {
        shard_of(id as u64, self.shards.len())
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Recover a stripe's lock from poisoning.  A panicked writer can
    /// leave `w`/`m`/`v` half-updated; the data is still well-formed
    /// (every f32 is valid), so we adopt the mixed state as the new
    /// canonical weights and bump that stripe's generation **once** —
    /// rows cached before the panic can never be stamped current
    /// again, while rows re-gathered afterwards are stamped at the new
    /// generation and served consistently.  (The RwLock itself stays
    /// poisoned forever; the one-shot flag keeps the hot gather path
    /// from thrashing the cache with a bump per recovery.)
    fn note_poison(&self, s: usize) {
        if !self.shards[s].poison_bumped.swap(true, Ordering::AcqRel) {
            self.shards[s].generation.fetch_add(1, Ordering::AcqRel);
        }
    }

    fn read_shard(&self, s: usize) -> InnerRead<'_> {
        let _order = lockorder::acquire(Rank::EmbRows);
        let guard = match self.shards[s].inner.read() {
            Ok(g) => g,
            Err(poisoned) => {
                self.note_poison(s);
                poisoned.into_inner()
            }
        };
        InnerRead { guard, _order }
    }

    fn write_shard(&self, s: usize) -> InnerWrite<'_> {
        let _order = lockorder::acquire(Rank::EmbRows);
        let guard = match self.shards[s].inner.write() {
            Ok(g) => g,
            Err(poisoned) => {
                self.note_poison(s);
                poisoned.into_inner()
            }
        };
        InnerWrite { guard, _order }
    }

    pub fn num_rows(&self) -> usize {
        self.n
    }

    /// Update generation: changes whenever any row is written.  The
    /// sum of per-stripe generations — monotone (each component only
    /// grows), and exactly the classic per-update counter for a
    /// single-stripe table.
    pub fn generation(&self) -> u64 {
        self.shards.iter().map(|s| s.generation.load(Ordering::Acquire)).sum()
    }

    /// One stripe's generation (`s < num_shards()`): bumped only by
    /// updates that touched *this* stripe's rows, so caches striped by
    /// the same hash can invalidate per stripe instead of table-wide.
    pub fn shard_generation(&self, s: usize) -> u64 {
        self.shards[s].generation.load(Ordering::Acquire)
    }

    /// Externally mark the table as updated (checkpoint restore, bulk
    /// weight swap — writes that bypass [`sparse_adam`](Self::sparse_adam)).
    /// Every stripe's generation is bumped: all cached rows go stale.
    /// Generation-stamped caches (`serve::EmbeddingCache`) invalidate
    /// on the next lookup and `serve::refresh` re-reads hot rows in
    /// the background instead of letting them turn into a miss storm.
    pub fn bump_generation(&self) {
        for s in &self.shards {
            s.generation.fetch_add(1, Ordering::AcqRel);
        }
    }

    /// Read one row on behalf of partition `worker`
    /// (`out.len() == dim`), counting traffic — the serving-side
    /// lookup the read-through cache wraps.
    pub fn row_into(&self, worker: u32, id: u32, out: &mut [f32]) {
        self.gather_into(worker, std::slice::from_ref(&id), out);
    }

    /// Copy of the current weights in id order (tests / checkpointing).
    pub fn weights_snapshot(&self) -> Vec<f32> {
        let d = self.dim;
        let mut out = vec![0.0f32; self.n * d];
        for s in 0..self.shards.len() {
            // One stripe lock at a time; ids not in this stripe are
            // filled by their own stripe's pass.
            let inner = self.read_shard(s);
            for id in 0..self.n {
                if self.shard_idx(id as u32) != s {
                    continue;
                }
                let base = self.local[id] as usize * d;
                out[id * d..(id + 1) * d].copy_from_slice(&inner.w[base..base + d]);
            }
        }
        out
    }

    /// Gather rows into `out` (`out.len() == ids.len() * dim`) on
    /// behalf of partition `worker`, counting traffic.  One stripe
    /// lock at a time, reacquired only when consecutive ids hop
    /// stripes — a single-stripe table locks exactly once, as before.
    pub fn gather_into(&self, worker: u32, ids: &[u32], out: &mut [f32]) {
        let d = self.dim;
        assert_eq!(out.len(), ids.len() * d);
        let (mut local, mut remote) = (0u64, 0u64);
        let mut cur: Option<(usize, InnerRead<'_>)> = None;
        for (j, &id) in ids.iter().enumerate() {
            let s = self.shard_idx(id);
            if cur.as_ref().map(|c| c.0) != Some(s) {
                cur = None; // release the previous stripe first
                cur = Some((s, self.read_shard(s)));
            }
            let inner = &cur.as_ref().unwrap().1;
            let base = self.local[id as usize] as usize * d;
            out[j * d..(j + 1) * d].copy_from_slice(&inner.w[base..base + d]);
            if self.book.part_of(self.ntype, id) == worker {
                local += d as u64;
            } else {
                remote += d as u64;
            }
        }
        drop(cur);
        if local > 0 {
            self.counters.record(true, local);
        }
        if remote > 0 {
            self.counters.record(false, remote);
        }
    }

    /// Sparse Adam over the touched rows (`grads.len() == ids.len() * dim`).
    /// Duplicate ids apply sequentially in order — deterministic.  On
    /// a sharded table updates are grouped by stripe with input order
    /// preserved within each; rows are independent, so the resulting
    /// weights are bit-identical to the single-stripe table for any
    /// shard count.  Each touched stripe's generation is bumped under
    /// that stripe's write lock; untouched stripes keep theirs, so
    /// their cached rows stay current (`put_if_current` and
    /// `serve::refresh` compose per stripe).
    pub fn sparse_adam(&self, ids: &[u32], grads: &[f32], lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        let d = self.dim;
        assert_eq!(grads.len(), ids.len() * d);
        for s in 0..self.shards.len() {
            // Lock lazily: stripes with no rows in this batch are
            // never locked and never bumped.
            let mut inner: Option<InnerWrite<'_>> = None;
            for (j, &id) in ids.iter().enumerate() {
                if self.shard_idx(id) != s {
                    continue;
                }
                let inner = inner.get_or_insert_with(|| self.write_shard(s));
                let r = self.local[id as usize] as usize;
                inner.t[r] += 1;
                let t = inner.t[r] as f32;
                let bc1 = 1.0 - B1.powf(t);
                let bc2 = 1.0 - B2.powf(t);
                for k in 0..d {
                    let i = r * d + k;
                    let g = grads[j * d + k];
                    inner.m[i] = B1 * inner.m[i] + (1.0 - B1) * g;
                    inner.v[i] = B2 * inner.v[i] + (1.0 - B2) * g * g;
                    let mhat = inner.m[i] / bc1;
                    let vhat = inner.v[i] / bc2;
                    inner.w[i] -= lr * mhat / (vhat.sqrt() + EPS);
                }
            }
            if inner.is_some() {
                // Bump while still holding the stripe's write lock: a
                // reader that stamps rows with the new generation can
                // only have gathered them *after* this update landed.
                // (Bumping before the lock would let a concurrent
                // read-through cache stamp pre-update rows as current.)
                self.shards[s].generation.fetch_add(1, Ordering::AcqRel);
            }
        }
    }
}

/// The per-process engine: features, text embeddings and learnable
/// tables for every node type, plus the shared traffic counters.
pub struct DistEngine {
    pub book: Arc<PartitionBook>,
    pub counters: Arc<TrafficCounters>,
    pub features: Vec<DistTensor>,
    pub text_emb: Vec<DistTensor>,
    pub embeds: Vec<Option<EmbTable>>,
}

impl DistEngine {
    pub fn new(book: Arc<PartitionBook>, num_nodes: &[usize]) -> DistEngine {
        let counters = Arc::new(TrafficCounters::new());
        let features = (0..num_nodes.len())
            .map(|nt| DistTensor::empty(nt, book.clone(), counters.clone()))
            .collect();
        let text_emb = (0..num_nodes.len())
            .map(|nt| DistTensor::empty(nt, book.clone(), counters.clone()))
            .collect();
        let embeds = num_nodes.iter().map(|_| None).collect();
        DistEngine { book, counters, features, text_emb, embeds }
    }

    /// Attach a learnable embedding table to a featureless node type.
    pub fn add_embed(&mut self, ntype: usize, n: usize, dim: usize, seed: u64) {
        self.embeds[ntype] = Some(EmbTable::new(
            ntype,
            n,
            dim,
            seed,
            self.book.clone(),
            self.counters.clone(),
        ));
    }

    /// [`add_embed`](Self::add_embed) with the table's rows striped
    /// `shards` ways (same hash as the serving cache) — bit-identical
    /// weights, per-stripe locks and generations.
    pub fn add_embed_sharded(&mut self, ntype: usize, n: usize, dim: usize, seed: u64, shards: usize) {
        self.embeds[ntype] = Some(EmbTable::new_sharded(
            ntype,
            n,
            dim,
            seed,
            shards,
            self.book.clone(),
            self.counters.clone(),
        ));
    }
}

/// Cluster cost model (Table 3): turns a measured single-process stage
/// time plus counted cross-partition traffic into an estimated
/// wall-clock on `instances` machines.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Fraction of compute that parallelizes across instances.
    pub parallel_efficiency: f64,
    /// Cross-instance NIC bandwidth, bytes/s (10 Gb/s default).
    pub bandwidth_bps: f64,
    /// Per-step synchronization latency, seconds.
    pub step_latency_s: f64,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel {
            parallel_efficiency: 0.85,
            bandwidth_bps: 1.25e9,
            step_latency_s: 2e-3,
        }
    }
}

impl CostModel {
    /// Estimated wall-clock seconds on `instances` machines for a stage
    /// measured at `secs` single-process, moving `remote_bytes` across
    /// the network in `steps` synchronized steps.
    pub fn estimate(&self, secs: f64, remote_bytes: u64, steps: u64, instances: usize) -> f64 {
        let n = instances.max(1) as f64;
        let compute = secs * ((1.0 - self.parallel_efficiency) + self.parallel_efficiency / n);
        let network = remote_bytes as f64 / self.bandwidth_bps;
        let sync = steps as f64 * self.step_latency_s * n.log2().max(1.0);
        compute + network + sync
    }

    /// The paper's instance-minutes metric.
    pub fn instance_minutes(&self, secs: f64, instances: usize) -> f64 {
        secs * instances.max(1) as f64 / 60.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize, parts: usize) -> (Arc<PartitionBook>, Arc<TrafficCounters>) {
        let book = Arc::new(PartitionBook::new(
            parts,
            vec![(0..n).map(|i| (i % parts) as u32).collect()],
        ));
        (book, Arc::new(TrafficCounters::new()))
    }

    #[test]
    fn gather_counts_local_vs_remote() {
        let (book, counters) = setup(10, 2);
        let t = DistTensor::from_data(0, 4, vec![1.0; 40], book, counters.clone());
        // Worker 0 owns even ids; gather two even + one odd.
        let out = t.gather(0, &[0, 2, 3]);
        assert_eq!(out.len(), 12);
        let s = counters.snapshot();
        assert_eq!(s.local_elems, 8);
        assert_eq!(s.remote_elems, 4);
        assert_eq!(s.remote_bytes, 16);
        counters.reset();
        assert_eq!(counters.snapshot(), Traffic::default());
    }

    #[test]
    fn single_partition_never_remote() {
        let (book, counters) = setup(6, 1);
        let t = DistTensor::from_data(0, 2, vec![0.5; 12], book, counters.clone());
        t.gather(0, &[0, 1, 2, 3, 4, 5]);
        let s = counters.snapshot();
        assert_eq!(s.remote_elems, 0);
        assert_eq!(s.local_elems, 12);
    }

    #[test]
    fn emb_table_poison_recovery_bumps_generation_once() {
        let (book, counters) = setup(4, 1);
        let e = EmbTable::new(0, 4, 2, 7, book, counters);
        e.sparse_adam(&[0], &[1.0; 2], 1e-2);
        assert_eq!(e.generation(), 1);
        // Poison the (single) stripe's lock the way a crashed updater
        // would.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = e.shards[0].inner.write().unwrap();
            panic!("die mid-update");
        }));
        assert!(e.shards[0].inner.is_poisoned());
        // Every access recovers; only the first bumps the generation.
        let mut row = vec![0.0f32; 2];
        e.row_into(0, 1, &mut row);
        assert_eq!(e.generation(), 2, "first recovery invalidates cached rows");
        e.row_into(0, 2, &mut row);
        assert_eq!(e.num_rows(), 4);
        assert_eq!(e.generation(), 2, "later recoveries must not thrash the cache");
        // Updates still apply and still bump per update.
        e.sparse_adam(&[1], &[1.0; 2], 1e-2);
        assert_eq!(e.generation(), 3);
    }

    #[test]
    fn emb_table_adam_moves_touched_rows_only() {
        let (book, counters) = setup(5, 1);
        let e = EmbTable::new(0, 5, 4, 7, book, counters);
        let before = e.weights_snapshot();
        assert_eq!(e.generation(), 0);
        e.sparse_adam(&[1, 3], &[1.0; 8], 1e-2);
        assert_eq!(e.generation(), 1, "updates must bump the cache generation");
        let mut row = vec![0.0f32; 4];
        e.row_into(0, 1, &mut row);
        let after = e.weights_snapshot();
        assert_eq!(row, &after[4..8]);
        for r in 0..5 {
            let changed = (0..4).any(|k| before[r * 4 + k] != after[r * 4 + k]);
            assert_eq!(changed, r == 1 || r == 3, "row {r}");
        }
    }

    #[test]
    fn emb_gather_matches_snapshot() {
        let (book, counters) = setup(4, 2);
        let e = EmbTable::new(0, 4, 3, 9, book, counters);
        let snap = e.weights_snapshot();
        let mut out = vec![0.0; 6];
        e.gather_into(0, &[2, 0], &mut out);
        assert_eq!(&out[..3], &snap[6..9]);
        assert_eq!(&out[3..], &snap[0..3]);
    }

    #[test]
    fn sharded_emb_table_matches_single_stripe() {
        let (book, counters) = setup(33, 2);
        let a = EmbTable::new(0, 33, 4, 11, book.clone(), counters.clone());
        let b = EmbTable::new_sharded(0, 33, 4, 11, 4, book, counters);
        assert_eq!(a.num_shards(), 1);
        assert_eq!(b.num_shards(), 4);
        assert_eq!(b.num_rows(), 33);
        assert_eq!(
            a.weights_snapshot(),
            b.weights_snapshot(),
            "initial weights are shard-count invariant"
        );
        // Duplicates and shard-hopping ids: updates must land
        // bit-identically on both layouts.
        let ids = [3u32, 17, 3, 8, 30, 17];
        let grads: Vec<f32> = (0..ids.len() * 4).map(|i| (i as f32 * 0.37).sin()).collect();
        a.sparse_adam(&ids, &grads, 1e-2);
        b.sparse_adam(&ids, &grads, 1e-2);
        assert_eq!(
            a.weights_snapshot(),
            b.weights_snapshot(),
            "sparse-Adam is shard-count invariant"
        );
        let mut oa = vec![0.0f32; 3 * 4];
        let mut ob = vec![0.0f32; 3 * 4];
        a.gather_into(0, &[30, 3, 17], &mut oa);
        b.gather_into(0, &[30, 3, 17], &mut ob);
        assert_eq!(oa, ob, "gathers are shard-count invariant");
    }

    #[test]
    fn sharded_generation_bumps_only_touched_stripes() {
        let (book, counters) = setup(16, 1);
        let e = EmbTable::new_sharded(0, 16, 2, 5, 4, book, counters);
        assert_eq!(e.generation(), 0);
        let id_a = 0u32;
        let sa = shard_of(id_a as u64, 4);
        let id_b = (1..16u32).find(|&i| shard_of(i as u64, 4) != sa).unwrap();
        let sb = shard_of(id_b as u64, 4);
        e.sparse_adam(&[id_a], &[1.0; 2], 1e-2);
        assert_eq!(e.shard_generation(sa), 1);
        assert_eq!(e.shard_generation(sb), 0, "untouched stripe keeps its generation");
        assert_eq!(e.generation(), 1, "table generation is the sum of stripe generations");
        e.sparse_adam(&[id_a, id_b], &[1.0; 4], 1e-2);
        assert_eq!(e.shard_generation(sa), 2);
        assert_eq!(e.shard_generation(sb), 1);
        assert_eq!(e.generation(), 3);
        // Bulk swap stales every stripe at once.
        e.bump_generation();
        assert_eq!(e.generation(), 3 + 4);
    }

    #[test]
    fn cost_model_monotone() {
        let cm = CostModel::default();
        // More instances shrink compute-bound stages.
        let e1 = cm.estimate(100.0, 0, 0, 1);
        let e8 = cm.estimate(100.0, 0, 0, 8);
        assert!(e8 < e1);
        // Traffic adds time.
        assert!(cm.estimate(10.0, 5_000_000_000, 100, 4) > cm.estimate(10.0, 0, 100, 4));
        assert_eq!(cm.instance_minutes(120.0, 4), 8.0);
    }
}
