//! Request coalescing: concurrent single-node prediction requests are
//! gathered into size/deadline-bounded micro-batches.
//!
//! The batcher blocks on the request queue.  Cache hits are answered
//! **on arrival** — a hot request never waits on the batch clock.  The
//! first cache *miss* opens a batch and starts its deadline; further
//! misses accumulate until either `max_batch` are pending or the
//! deadline passes — whichever comes first — then the batch flushes:
//! the distinct misses go through one engine forward pass (K-hop
//! sample → assemble → execute), results land in the cache, and every
//! reply is recorded in the latency histogram.  Because the engine
//! samples canonically per node, coalescing never changes a
//! prediction — only its latency.

use anyhow::Result;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use super::cache::{cache_key, EmbeddingCache};
use super::engine::{InferenceEngine, ServeScratch};
use super::error::ServeError;
use super::ServeMetrics;

/// One in-flight prediction request.  `reply` receives the decoded
/// row or a typed [`ServeError`] (failure, shed, deadline miss);
/// latency is measured from construction.
pub struct ServeRequest {
    pub nt: u32,
    pub id: u32,
    pub t_enq: Instant,
    pub reply: Sender<Result<Vec<f32>, ServeError>>,
}

impl ServeRequest {
    pub fn new(nt: u32, id: u32, reply: Sender<Result<Vec<f32>, ServeError>>) -> ServeRequest {
        ServeRequest { nt, id, t_enq: Instant::now(), reply } // lint:allow(determinism): queue-latency stamp only
    }
}

#[derive(Debug, Clone)]
pub struct MicroBatcherCfg {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// ...or when the oldest pending request has waited this long.
    pub deadline: Duration,
}

impl Default for MicroBatcherCfg {
    fn default() -> Self {
        MicroBatcherCfg { max_batch: 32, deadline: Duration::from_micros(500) }
    }
}

pub struct MicroBatcher {
    pub cfg: MicroBatcherCfg,
}

impl MicroBatcher {
    pub fn new(cfg: MicroBatcherCfg) -> MicroBatcher {
        MicroBatcher { cfg }
    }

    /// Blocking serve loop; returns once every request sender has been
    /// dropped and the last batch has flushed.
    pub fn run(
        &self,
        engine: &InferenceEngine,
        cache: &mut EmbeddingCache,
        rx: Receiver<ServeRequest>,
        metrics: &ServeMetrics,
    ) -> Result<()> {
        let mut sc = engine.make_scratch();
        let mut pend: Vec<ServeRequest> = Vec::new();
        let cap = self.cfg.max_batch.min(engine.capacity()).max(1);
        loop {
            // Serve hits on arrival; the first miss opens a batch.
            let first = loop {
                let Ok(req) = rx.recv() else { return Ok(()) };
                match Self::serve_hit(engine, cache, metrics, req) {
                    Some(miss) => break miss,
                    None => continue,
                }
            };
            pend.push(first);
            let deadline = Instant::now() + self.cfg.deadline; // lint:allow(determinism): deadline pacing; batch content is seq-deterministic
            while pend.len() < cap {
                let now = Instant::now(); // lint:allow(determinism): deadline pacing; batch content is seq-deterministic
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(req) => {
                        if let Some(miss) = Self::serve_hit(engine, cache, metrics, req) {
                            pend.push(miss);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            self.flush(engine, cache, &mut sc, metrics, &mut pend)?;
        }
    }

    /// Answer `req` from the cache if possible (recording the hit);
    /// otherwise record the miss and hand the request back for
    /// batching.
    fn serve_hit(
        engine: &InferenceEngine,
        cache: &mut EmbeddingCache,
        metrics: &ServeMetrics,
        req: ServeRequest,
    ) -> Option<ServeRequest> {
        cache.set_generation(engine.generation());
        if let Some(row) = cache.get(cache_key(req.nt, req.id)) {
            let val = row.to_vec();
            metrics.record_hit();
            metrics.latency.record(req.t_enq.elapsed());
            let _ = req.reply.send(Ok(val));
            None
        } else {
            metrics.record_miss();
            Some(req)
        }
    }

    /// Flush one micro-batch of known misses: one forward over the
    /// distinct seeds, cache insert, replies.
    fn flush<'a>(
        &self,
        engine: &InferenceEngine<'a>,
        cache: &mut EmbeddingCache,
        sc: &mut ServeScratch<'a>,
        metrics: &ServeMetrics,
        pend: &mut Vec<ServeRequest>,
    ) -> Result<()> {
        cache.set_generation(engine.generation());
        let mut seeds: Vec<(u32, u32)> = Vec::new();
        let mut waiting: Vec<(usize, ServeRequest)> = Vec::new();
        for req in pend.drain(..) {
            // Micro-batches are tiny (≤ max_batch), so a linear dedup
            // scan beats hashing here.
            let slot = match seeds.iter().position(|&s| s == (req.nt, req.id)) {
                Some(s) => s,
                None => {
                    seeds.push((req.nt, req.id));
                    seeds.len() - 1
                }
            };
            waiting.push((slot, req));
        }
        if seeds.is_empty() {
            return Ok(());
        }
        let _span = crate::span!("serve.batch.flush", rows = seeds.len(), waiters = waiting.len());
        let c = engine.out_dim();
        let rows = match engine.forward(sc, &seeds) {
            Ok(rows) => rows,
            Err(e) => {
                let se = ServeError::classify(&e);
                for (_, req) in waiting.drain(..) {
                    let _ = req.reply.send(Err(se.clone()));
                }
                return Err(e);
            }
        };
        for (i, &(nt, id)) in seeds.iter().enumerate() {
            cache.put(cache_key(nt, id), &rows[i * c..(i + 1) * c]);
        }
        for (slot, req) in waiting.drain(..) {
            let val = rows[slot * c..(slot + 1) * c].to_vec();
            metrics.latency.record(req.t_enq.elapsed());
            let _ = req.reply.send(Ok(val));
        }
        Ok(())
    }
}

/// Closed-loop serving stats (one bench/CLI arm).  `hits`/`misses`
/// are pool-size invariant under a non-evicting cache; `coalesced`
/// (a subset of `hits`: requests that joined an in-flight batch)
/// depends on completion timing.  The robustness counters mirror
/// [`ServeMetrics`]: supervision events (`restarts`), retried batch
/// attempts (`retries`), and the two typed rejections (`shed`,
/// `deadline_misses`) — all zero on a healthy, uncontended run.
#[derive(Debug, Clone, Default)]
pub struct ClosedLoopStats {
    pub requests: usize,
    pub wall_s: f64,
    pub rps: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub hit_rate: f64,
    pub hits: u64,
    pub misses: u64,
    pub coalesced: u64,
    pub restarts: u64,
    pub retries: u64,
    pub shed: u64,
    pub deadline_misses: u64,
}
