//! Typed serving errors and panic-safe lock helpers.
//!
//! The serving stack never answers a request with a bare panic or a
//! stringly-typed failure: every error that crosses the request/reply
//! boundary is a [`ServeError`], split along the axis the pool's
//! supervision logic actually branches on — **retryable** (transient
//! row-source / backend hiccups, worth a bounded backoff-retry) versus
//! **fatal** (bad artifact, corrupted scratch, injected hard faults;
//! retrying cannot help, the batch fails and the worker's scratch is
//! rebuilt).  Queue-boundary rejections ([`ServeError::Overloaded`])
//! and per-request deadline misses ([`ServeError::DeadlineExceeded`])
//! are their own variants so clients can tell "the system chose not
//! to serve you" apart from "the computation broke".
//!
//! The lock helpers implement the poisoning policy from
//! `docs/ROBUSTNESS.md`: a poisoned mutex means *some* thread panicked
//! while holding it, not that the protected data is unusable.
//! [`lock_clean`] recovers state that is consistent at every point
//! (channels, counters, scratch registries); [`lock_cache`] recovers
//! the serving cache and **bumps its generation**, so every row that
//! was resident when the panic happened reads as stale until a serving
//! path re-stamps the cache from its generation source — no row is
//! ever served out of a critical section that died halfway.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Mutex, MutexGuard};

use super::cache::EmbeddingCache;
use crate::util::lockorder::{self, Rank};

/// The serving stack's error taxonomy.  `retryable()` is the split
/// the pool's retry loop keys on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Transient backend / row-source failure: retrying with backoff
    /// is expected to succeed (network blip, racing generation bump,
    /// injected transient fault).
    Transient(String),
    /// Non-retryable failure: bad artifact, shape mismatch, a worker
    /// panic payload.  The batch fails; the worker scratch that
    /// produced it is discarded and rebuilt.
    Fatal(String),
    /// Shed at the queue boundary: the pool already had `depth`
    /// requests pending and admission would only add latency.  The
    /// request was never enqueued.
    Overloaded { depth: usize },
    /// The per-request deadline elapsed before a reply was produced.
    /// The computed row (if any) still lands in the cache; only the
    /// reply is a rejection.
    DeadlineExceeded { waited_ms: u64 },
    /// The pool shut down while the request was queued or in flight.
    Canceled(String),
}

impl ServeError {
    pub fn transient(msg: impl Into<String>) -> ServeError {
        ServeError::Transient(msg.into())
    }

    pub fn fatal(msg: impl Into<String>) -> ServeError {
        ServeError::Fatal(msg.into())
    }

    /// Whether the pool's bounded retry loop should try again.
    pub fn retryable(&self) -> bool {
        matches!(self, ServeError::Transient(_))
    }

    /// Typed rejections the pool issues on purpose (shedding,
    /// deadlines) as opposed to computation failures; closed-loop
    /// drivers count these in the metrics instead of aborting.
    pub fn is_rejection(&self) -> bool {
        matches!(
            self,
            ServeError::Overloaded { .. } | ServeError::DeadlineExceeded { .. }
        )
    }

    /// Classify an error coming back from the engine / row-source
    /// boundary: a typed [`ServeError`] anywhere in the chain passes
    /// through, anything untyped is conservatively fatal (retrying an
    /// unknown failure mode against a deterministic backend only
    /// repeats it).
    pub fn classify(e: &anyhow::Error) -> ServeError {
        match e.downcast_ref::<ServeError>() {
            Some(se) => se.clone(),
            None => ServeError::Fatal(e.to_string()),
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Transient(m) => write!(f, "transient serve error: {m}"),
            ServeError::Fatal(m) => write!(f, "fatal serve error: {m}"),
            ServeError::Overloaded { depth } => {
                write!(f, "overloaded: shed at queue depth {depth}")
            }
            ServeError::DeadlineExceeded { waited_ms } => {
                write!(f, "deadline exceeded after {waited_ms}ms")
            }
            ServeError::Canceled(m) => write!(f, "canceled: {m}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A poison-recovered mutex guard stamped with its lock-order rank:
/// the [`lockorder`] token lives exactly as long as the guard, so the
/// debug-build tracker sees real hold intervals (docs/LINTS.md,
/// lock-order rule).
pub struct RankedGuard<'a, T> {
    guard: MutexGuard<'a, T>,
    _order: lockorder::Held,
}

impl<T> Deref for RankedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> DerefMut for RankedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

/// Lock a mutex at an explicit [`Rank`], recovering from poisoning via
/// `PoisonError::into_inner`.  The rank is asserted against the
/// declared order (cache → session → rows → leaf) in debug builds.
pub fn lock_ranked<T>(m: &Mutex<T>, rank: Rank) -> RankedGuard<'_, T> {
    // Acquire the order token *before* blocking: a deadlock-shaped
    // ordering should assert even when the timing works out.
    let _order = lockorder::acquire(rank);
    // lint:allow(lock-order): this is the ranked helper the rule tells everyone else to call
    let guard = match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    RankedGuard { guard, _order }
}

/// Lock a leaf mutex, recovering from poisoning via
/// `PoisonError::into_inner`.  Use for state that is consistent at
/// every instruction boundary (channel receivers, one-shot fault
/// sets, counters); such mutexes are innermost in the declared lock
/// order.  The serving cache goes through [`lock_cache`] instead, and
/// the PJRT execution lock through [`lock_ranked`] at
/// [`Rank::Session`].
pub fn lock_clean<T>(m: &Mutex<T>) -> RankedGuard<'_, T> {
    lock_ranked(m, Rank::Leaf)
}

/// Lock the serving cache, recovering from poisoning with a
/// generation bump.  A panic inside a cache critical section can
/// leave a *batch* half-applied (some rows of the batch inserted,
/// some not); each individual row write is atomic under the lock, but
/// bumping the generation marks everything resident as stale so the
/// recovered cache starts from a clean "miss everything" state and
/// only rows re-stamped by a live serving path are served again.
pub fn lock_cache(m: &Mutex<EmbeddingCache>) -> RankedGuard<'_, EmbeddingCache> {
    lock_shard(m, 0)
}

/// [`lock_cache`] for one stripe of a [`super::ShardedCache`]: same
/// poison policy (recovery bumps that shard's generation), but the
/// lock-order token carries the shard index, so the debug tracker
/// enforces the per-shard DAG — shard locks may only nest in
/// ascending index order, and in practice the serving paths never
/// hold two at once (aggregation walks shards one at a time).
pub fn lock_shard(m: &Mutex<EmbeddingCache>, shard: u32) -> RankedGuard<'_, EmbeddingCache> {
    let _order = lockorder::acquire_shard(Rank::Cache, shard);
    // lint:allow(lock-order): the cache-ranked helper itself; poison recovery bumps the generation
    let guard = match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            let mut g = poisoned.into_inner();
            g.bump_generation();
            g
        }
    };
    RankedGuard { guard, _order }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_split() {
        assert!(ServeError::transient("x").retryable());
        assert!(!ServeError::fatal("x").retryable());
        assert!(!ServeError::Overloaded { depth: 4 }.retryable());
        assert!(!ServeError::DeadlineExceeded { waited_ms: 10 }.retryable());
        assert!(!ServeError::Canceled("bye".into()).retryable());
    }

    #[test]
    fn rejections_are_not_failures() {
        assert!(ServeError::Overloaded { depth: 1 }.is_rejection());
        assert!(ServeError::DeadlineExceeded { waited_ms: 1 }.is_rejection());
        assert!(!ServeError::transient("x").is_rejection());
        assert!(!ServeError::fatal("x").is_rejection());
    }

    #[test]
    fn classify_round_trips_typed_errors() {
        let e = anyhow::Error::new(ServeError::transient("blip"));
        assert_eq!(ServeError::classify(&e), ServeError::transient("blip"));
        let chained = e.context("while serving batch 3");
        assert_eq!(ServeError::classify(&chained), ServeError::transient("blip"));
        let untyped = anyhow::anyhow!("disk on fire");
        assert_eq!(
            ServeError::classify(&untyped),
            ServeError::fatal("disk on fire")
        );
    }

    #[test]
    fn lock_clean_recovers_poison() {
        let m = Mutex::new(7u32);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(m.is_poisoned());
        assert_eq!(*lock_clean(&m), 7);
    }

    #[test]
    fn lock_cache_bumps_generation_on_poison() {
        let m = Mutex::new(EmbeddingCache::new(4));
        {
            let mut g = m.lock().unwrap();
            g.set_generation(5);
            g.put(1, &[1.0]);
        }
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(m.is_poisoned());
        {
            let mut g = lock_cache(&m);
            assert_eq!(g.generation(), 6, "poison recovery must bump");
            assert_eq!(g.get(1), None, "resident rows read stale after recovery");
        }
        // The mutex stays poisoned (std never un-poisons), so every
        // recovery bumps again.  Harmless: bumps only move the
        // generation forward, and every serving path re-stamps it from
        // its generation source under this same lock.
        assert_eq!(lock_cache(&m).generation(), 7);
        // A never-poisoned mutex never bumps.
        let clean = Mutex::new(EmbeddingCache::new(4));
        lock_cache(&clean).set_generation(3);
        assert_eq!(lock_cache(&clean).generation(), 3);
    }
}
