//! Closed-loop HTTP load generator (`gs load-bench`): N persistent
//! connections replaying the canonical Zipf trace against a running
//! `gs serve` instance, measuring saturation throughput and latency
//! percentiles from the *client* side of the wire.
//!
//! The trace is constructed exactly as `run_serve_bench` constructs
//! its in-process trace — same seed mix (`seed ^ 0x5e12`), same
//! [`Zipf`] sampler over the node count learned from `GET /info` —
//! so a load run and a bench run with the same knobs request the same
//! node sequence, and the byte-identity probe below can hold socket
//! replies to the in-process determinism contract.
//!
//! Closed-loop means each connection waits for its reply before
//! sending the next request: concurrency is exactly the connection
//! count, and measured throughput is the *sustainable* rate at that
//! concurrency, not an open-loop arrival fantasy.

use anyhow::{anyhow, bail, Context as _, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::proto::{self, Parse, Response};
use crate::serve::{LatencyHistogram, Zipf};
use crate::util::json::Json;
use crate::util::Rng;

/// Client-side cap on response bodies — a row of a few thousand floats
/// fits with room to spare.
const MAX_RESPONSE_BODY: usize = 1 << 20;

#[derive(Debug, Clone)]
pub struct LoadBenchCfg {
    /// Server address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Persistent connections (closed-loop clients).
    pub connections: usize,
    /// Total requests across all connections.
    pub requests: usize,
    /// Zipf skew of the replayed trace.
    pub alpha: f64,
    /// Trace seed — match the server's `seed` to replay the exact
    /// `gs serve-bench` node sequence.
    pub seed: u64,
    /// Ask the server to drain and exit after the run
    /// (`POST /shutdown`).
    pub shutdown: bool,
    /// Socket read timeout per reply.
    pub read_timeout: Duration,
}

impl Default for LoadBenchCfg {
    fn default() -> Self {
        LoadBenchCfg {
            addr: "127.0.0.1:8080".to_string(),
            connections: 4,
            requests: 1000,
            alpha: 1.1,
            seed: 42,
            shutdown: false,
            read_timeout: Duration::from_secs(10),
        }
    }
}

/// Client-side view of one load run — the `http_*` keys of
/// `BENCH_serve.json`.
#[derive(Debug, Clone, Default)]
pub struct LoadBenchReport {
    pub connections: usize,
    pub requests: usize,
    pub wall_s: f64,
    /// Sustained closed-loop throughput (completed requests / wall).
    pub rps: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub ok: u64,
    pub rejected_429: u64,
    pub rejected_503: u64,
    pub failed_4xx: u64,
    pub failed_5xx: u64,
    /// Socket-level failures that survived one reconnect attempt.
    pub transport_errors: u64,
    /// Repeated identical request produced byte-identical replies.
    pub identical: bool,
    /// Learned from `GET /info`.
    pub ntype: usize,
    pub nodes: usize,
    pub out_dim: usize,
}

/// One persistent client connection with request/reply framing.
struct Conn {
    stream: TcpStream,
    read_timeout: Duration,
}

impl Conn {
    fn open(addr: &str, read_timeout: Duration) -> Result<Conn> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(read_timeout)).context("setting read timeout")?;
        Ok(Conn { stream, read_timeout })
    }

    fn request_bytes(method: &str, path: &str, body: &str) -> Vec<u8> {
        format!(
            "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
            body.len()
        )
        .into_bytes()
    }

    /// Send one request and block for its reply (closed loop).  Also
    /// returns the raw reply bytes for the byte-identity probe.
    fn call(&mut self, method: &str, path: &str, body: &str) -> Result<(Response, Vec<u8>)> {
        self.stream.write_all(&Self::request_bytes(method, path, body))?;
        let mut buf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 4096];
        loop {
            match proto::parse_response(&buf, MAX_RESPONSE_BODY) {
                Parse::Ready(resp, used) => {
                    let raw = buf[..used].to_vec();
                    return Ok((resp, raw));
                }
                Parse::Bad(bad) => bail!("unparseable response: {}", bad.message()),
                Parse::Incomplete => {
                    let n = self.stream.read(&mut chunk)?;
                    if n == 0 {
                        bail!("connection closed mid-response");
                    }
                    buf.extend_from_slice(&chunk[..n]);
                }
            }
        }
    }
}

fn predict_body(nt: u32, id: u32) -> String {
    format!("{{\"nt\": {nt}, \"id\": {id}}}")
}

/// Run the closed-loop load bench against a live server.
pub fn run_load_bench(cfg: &LoadBenchCfg) -> Result<LoadBenchReport> {
    let connections = cfg.connections.max(1);

    // ---- learn the trace domain from the server ----------------
    let mut probe = Conn::open(&cfg.addr, cfg.read_timeout)?;
    let (info, _) = probe.call("GET", "/info", "")?;
    if info.status != 200 {
        bail!("GET /info returned {}", info.status);
    }
    let info = Json::parse(std::str::from_utf8(&info.body).context("info body utf8")?)
        .context("parsing /info body")?;
    let ntype = info.usize_of("ntype")?;
    let nodes = info.usize_of("nodes")?;
    let out_dim = info.usize_of("out_dim")?;
    if nodes == 0 {
        bail!("server reports an empty node type");
    }

    // ---- canonical trace (same construction as run_serve_bench) -
    let nt = ntype as u32;
    let zipf = Zipf::new(nodes, cfg.alpha);
    let mut rng = Rng::seed_from(cfg.seed ^ 0x5e12);
    let trace: Vec<(u32, u32)> =
        (0..cfg.requests.max(1)).map(|_| (nt, zipf.sample(&mut rng) as u32)).collect();

    // ---- byte-identity probe ------------------------------------
    // The same request twice on the same connection must yield
    // byte-identical replies: the engine is deterministic, JSON object
    // keys are BTreeMap-ordered, float formatting is shortest
    // round-trip, and Content-Length pins the framing.
    let (nt0, id0) = trace[0];
    let body0 = predict_body(nt0, id0);
    let (r1, raw1) = probe.call("POST", "/predict", &body0)?;
    let (r2, raw2) = probe.call("POST", "/predict", &body0)?;
    if r1.status != 200 || r2.status != 200 {
        bail!("identity probe got {} / {} from /predict", r1.status, r2.status);
    }
    let identical = raw1 == raw2;
    drop(probe);

    // ---- closed-loop replay -------------------------------------
    let latency = LatencyHistogram::new();
    let ok = AtomicU64::new(0);
    let r429 = AtomicU64::new(0);
    let r503 = AtomicU64::new(0);
    let f4xx = AtomicU64::new(0);
    let f5xx = AtomicU64::new(0);
    let transport = AtomicU64::new(0);
    let t0 = Instant::now(); // lint:allow(determinism): bench wall-clock only
    let mut first_err: Option<anyhow::Error> = None;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(connections);
        for w in 0..connections {
            let share: Vec<(u32, u32)> =
                trace.iter().skip(w).step_by(connections).copied().collect();
            let (latency, ok, r429, r503, f4xx, f5xx, transport) =
                (&latency, &ok, &r429, &r503, &f4xx, &f5xx, &transport);
            handles.push(scope.spawn(move || -> Result<()> {
                let mut conn = Conn::open(&cfg.addr, cfg.read_timeout)?;
                for (nt, id) in share {
                    let body = predict_body(nt, id);
                    let t_req = Instant::now(); // lint:allow(determinism): client-side latency stamp only
                    let resp = match conn.call("POST", "/predict", &body) {
                        Ok((resp, _)) => resp,
                        Err(_) => {
                            // One reconnect per failure: keep-alive may
                            // have been withdrawn under our feet.
                            conn = Conn::open(&cfg.addr, cfg.read_timeout)?;
                            match conn.call("POST", "/predict", &body) {
                                Ok((resp, _)) => resp,
                                Err(_) => {
                                    transport.fetch_add(1, Ordering::Relaxed);
                                    continue;
                                }
                            }
                        }
                    };
                    latency.record(t_req.elapsed());
                    match resp.status {
                        200..=299 => ok.fetch_add(1, Ordering::Relaxed),
                        429 => r429.fetch_add(1, Ordering::Relaxed),
                        503 => r503.fetch_add(1, Ordering::Relaxed),
                        400..=499 => f4xx.fetch_add(1, Ordering::Relaxed),
                        _ => f5xx.fetch_add(1, Ordering::Relaxed),
                    };
                    if !resp.keep_alive {
                        conn = Conn::open(&cfg.addr, cfg.read_timeout)?;
                    }
                }
                Ok(())
            }));
        }
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert_with(|| anyhow!("load client thread panicked"));
                }
            }
        }
    });
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    if cfg.shutdown {
        let mut c = Conn::open(&cfg.addr, cfg.read_timeout)?;
        let (resp, _) = c.call("POST", "/shutdown", "")?;
        if resp.status != 200 {
            bail!("POST /shutdown returned {}", resp.status);
        }
    }

    let completed = ok.load(Ordering::Relaxed)
        + r429.load(Ordering::Relaxed)
        + r503.load(Ordering::Relaxed)
        + f4xx.load(Ordering::Relaxed)
        + f5xx.load(Ordering::Relaxed);
    Ok(LoadBenchReport {
        connections,
        requests: trace.len(),
        wall_s,
        rps: completed as f64 / wall_s.max(1e-9),
        p50_us: latency.p50_us(),
        p99_us: latency.p99_us(),
        ok: ok.load(Ordering::Relaxed),
        rejected_429: r429.load(Ordering::Relaxed),
        rejected_503: r503.load(Ordering::Relaxed),
        failed_4xx: f4xx.load(Ordering::Relaxed),
        failed_5xx: f5xx.load(Ordering::Relaxed),
        transport_errors: transport.load(Ordering::Relaxed),
        identical,
        ntype,
        nodes,
        out_dim,
    })
}
