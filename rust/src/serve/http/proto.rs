//! HTTP/1.1 wire parsing and formatting — no sockets, no state beyond
//! the caller's accumulation buffer, so every framing rule is unit
//! testable without a listener.
//!
//! The parser consumes from a byte buffer the connection loop appends
//! socket reads into, which makes short reads a non-event: a request
//! head split across TCP segments simply parses as [`Parse::Incomplete`]
//! until the terminator arrives.  Bodies are `Content-Length` framed
//! only (chunked transfer coding is rejected with 400 — nothing in
//! this protocol needs it), and an oversized declared length is
//! rejected *before* the body is read, so a hostile `Content-Length`
//! can never drive an allocation.

use std::fmt::Write as _;

use crate::util::json::Json;

/// Hard cap on the request-head block (request line + headers).  A
/// buffer that exceeds it without containing the `\r\n\r\n` terminator
/// is malformed, not merely incomplete.
pub const MAX_HEAD_BYTES: usize = 8192;

/// A parsed request: method + path + framing facts the server routes
/// on.  Header storage is not kept — the three headers this protocol
/// reacts to (`Content-Length`, `Connection`, `Transfer-Encoding`) are
/// folded into fields during parsing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Connection survives this exchange (HTTP/1.1 default, or an
    /// explicit `Connection: keep-alive` on 1.0).
    pub keep_alive: bool,
    pub body: Vec<u8>,
}

/// Why a request could not be parsed, paired with the status code the
/// server answers with before closing the connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Bad {
    /// 400: unparseable request line, header, length or version.
    Malformed(&'static str),
    /// 413: declared `Content-Length` exceeds the configured body cap.
    BodyTooLarge { declared: usize, limit: usize },
}

impl Bad {
    pub fn status(&self) -> u16 {
        match self {
            Bad::Malformed(_) => 400,
            Bad::BodyTooLarge { .. } => 413,
        }
    }

    pub fn message(&self) -> String {
        match self {
            Bad::Malformed(m) => (*m).to_string(),
            Bad::BodyTooLarge { declared, limit } => {
                format!("request body of {declared} bytes exceeds the {limit}-byte limit")
            }
        }
    }
}

/// One parse attempt over the accumulated bytes.
#[derive(Debug, PartialEq, Eq)]
pub enum Parse<T> {
    /// Not enough bytes yet — read more and retry.
    Incomplete,
    /// A complete message; the second field is how many bytes of the
    /// buffer it consumed (drain them — pipelined bytes may follow).
    Ready(T, usize),
    /// The bytes can never become a valid message.
    Bad(Bad),
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse one request from the front of `buf`.  `max_body` bounds the
/// declared `Content-Length`.
pub fn parse_request(buf: &[u8], max_body: usize) -> Parse<Request> {
    let Some(head_end) = find_head_end(buf) else {
        if buf.len() > MAX_HEAD_BYTES {
            return Parse::Bad(Bad::Malformed("request head exceeds the size limit"));
        }
        return Parse::Incomplete;
    };
    let Ok(head) = std::str::from_utf8(&buf[..head_end]) else {
        return Parse::Bad(Bad::Malformed("request head is not valid UTF-8"));
    };
    let mut lines = head.split("\r\n");
    let Some(request_line) = lines.next() else {
        return Parse::Bad(Bad::Malformed("empty request head"));
    };
    let mut parts = request_line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && !p.is_empty() => (m, p, v),
        _ => return Parse::Bad(Bad::Malformed("malformed request line (want 'METHOD /path HTTP/1.1')")),
    };
    if !path.starts_with('/') {
        return Parse::Bad(Bad::Malformed("request target must be an absolute path"));
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Parse::Bad(Bad::Malformed("unsupported HTTP version")),
    };

    let mut content_length: Option<usize> = None;
    let mut keep_alive = http11;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return Parse::Bad(Bad::Malformed("header line missing ':'"));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                let Ok(n) = value.parse::<usize>() else {
                    return Parse::Bad(Bad::Malformed("unparseable Content-Length"));
                };
                if content_length.is_some_and(|prev| prev != n) {
                    return Parse::Bad(Bad::Malformed("conflicting Content-Length headers"));
                }
                content_length = Some(n);
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "transfer-encoding" => {
                return Parse::Bad(Bad::Malformed(
                    "Transfer-Encoding is unsupported (use Content-Length framing)",
                ));
            }
            _ => {}
        }
    }

    let body_len = content_length.unwrap_or(0);
    if body_len > max_body {
        return Parse::Bad(Bad::BodyTooLarge { declared: body_len, limit: max_body });
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + body_len {
        return Parse::Incomplete;
    }
    Parse::Ready(
        Request {
            method: method.to_string(),
            path: path.to_string(),
            keep_alive,
            body: buf[body_start..body_start + body_len].to_vec(),
        },
        body_start + body_len,
    )
}

/// A parsed response (the load-generator side of the wire).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub status: u16,
    pub keep_alive: bool,
    pub body: Vec<u8>,
}

/// Parse one response from the front of `buf`.  `max_body` bounds the
/// declared `Content-Length` (the client trusts its own server only so
/// far).
pub fn parse_response(buf: &[u8], max_body: usize) -> Parse<Response> {
    let Some(head_end) = find_head_end(buf) else {
        if buf.len() > MAX_HEAD_BYTES {
            return Parse::Bad(Bad::Malformed("response head exceeds the size limit"));
        }
        return Parse::Incomplete;
    };
    let Ok(head) = std::str::from_utf8(&buf[..head_end]) else {
        return Parse::Bad(Bad::Malformed("response head is not valid UTF-8"));
    };
    let mut lines = head.split("\r\n");
    let Some(status_line) = lines.next() else {
        return Parse::Bad(Bad::Malformed("empty response head"));
    };
    let mut parts = status_line.splitn(3, ' ');
    let (version, code) = match (parts.next(), parts.next()) {
        (Some(v), Some(c)) => (v, c),
        _ => return Parse::Bad(Bad::Malformed("malformed status line")),
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Parse::Bad(Bad::Malformed("unsupported HTTP version"));
    }
    let Ok(status) = code.parse::<u16>() else {
        return Parse::Bad(Bad::Malformed("unparseable status code"));
    };
    let mut content_length = 0usize;
    let mut keep_alive = version == "HTTP/1.1";
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            return Parse::Bad(Bad::Malformed("header line missing ':'"));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                let Ok(n) = value.parse::<usize>() else {
                    return Parse::Bad(Bad::Malformed("unparseable Content-Length"));
                };
                content_length = n;
            }
            "connection" => {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                }
            }
            _ => {}
        }
    }
    if content_length > max_body {
        return Parse::Bad(Bad::BodyTooLarge { declared: content_length, limit: max_body });
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + content_length {
        return Parse::Incomplete;
    }
    Parse::Ready(
        Response {
            status,
            keep_alive,
            body: buf[body_start..body_start + content_length].to_vec(),
        },
        body_start + content_length,
    )
}

/// Canonical reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Serialize a JSON-bodied response.  Byte-determinism matters here:
/// identical `(status, body, keep_alive)` triples always produce
/// identical bytes ([`Json`] objects are `BTreeMap`-ordered and float
/// formatting is shortest-round-trip), which is what lets the loopback
/// tests assert bit-identical replies for repeated identical requests.
pub fn response_bytes(status: u16, body: &Json, keep_alive: bool) -> Vec<u8> {
    let payload = body.to_string_pretty();
    let mut head = String::with_capacity(128 + payload.len());
    let _ = write!(
        head,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason(status),
        payload.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    head.push_str(&payload);
    head.into_bytes()
}

/// The uniform JSON error body: `{"error": msg, "status": code}`.
pub fn error_body(status: u16, msg: &str) -> Json {
    crate::util::json::obj(vec![
        ("error", Json::from(msg)),
        ("status", Json::from(status as usize)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(bytes: &[u8]) -> Parse<Request> {
        parse_request(bytes, 1024)
    }

    #[test]
    fn parses_minimal_get() {
        let bytes = b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n";
        let Parse::Ready(r, used) = req(bytes) else {
            panic!("expected Ready");
        };
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert!(r.keep_alive);
        assert!(r.body.is_empty());
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn split_head_is_incomplete_until_terminator() {
        // A request head arriving one TCP segment at a time must parse
        // as Incomplete at every prefix, then Ready on the last byte.
        let full = b"POST /predict HTTP/1.1\r\nContent-Length: 9\r\n\r\n{\"id\": 3}";
        for cut in 1..full.len() {
            match req(&full[..cut]) {
                Parse::Incomplete => {}
                other => panic!("prefix {cut}: expected Incomplete, got {other:?}"),
            }
        }
        let Parse::Ready(r, used) = req(full) else { panic!("expected Ready") };
        assert_eq!(r.body, b"{\"id\": 3}");
        assert_eq!(used, full.len());
    }

    #[test]
    fn pipelined_requests_consume_exactly_one() {
        let two = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let Parse::Ready(r, used) = req(two) else { panic!("expected Ready") };
        assert_eq!(r.path, "/a");
        let Parse::Ready(r2, used2) = req(&two[used..]) else { panic!("expected Ready") };
        assert_eq!(r2.path, "/b");
        assert_eq!(used + used2, two.len());
    }

    #[test]
    fn malformed_request_lines_are_bad() {
        for bytes in [
            &b"NOT_A_REQUEST\r\n\r\n"[..],
            b"GET /x HTTP/1.1 extra\r\n\r\n",
            b"GET nopath HTTP/1.1\r\n\r\n",
            b"GET /x HTTP/2.0\r\n\r\n",
            b"GET /x HTTP/1.1\r\nbroken header line\r\n\r\n",
            b"GET /x HTTP/1.1\r\nContent-Length: abc\r\n\r\n",
            b"GET /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
            b"GET /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 5\r\n\r\n",
        ] {
            match req(bytes) {
                Parse::Bad(Bad::Malformed(_)) => {}
                other => panic!("{:?}: expected Malformed, got {other:?}", String::from_utf8_lossy(bytes)),
            }
        }
    }

    #[test]
    fn oversized_body_is_rejected_before_the_body_arrives() {
        // Only the head is present — the declared length alone trips
        // the 413, no body bytes needed (or allocated).
        let head = b"POST /predict HTTP/1.1\r\nContent-Length: 4096\r\n\r\n";
        match req(head) {
            Parse::Bad(Bad::BodyTooLarge { declared: 4096, limit: 1024 }) => {}
            other => panic!("expected BodyTooLarge, got {other:?}"),
        }
        assert_eq!(Bad::BodyTooLarge { declared: 4096, limit: 1024 }.status(), 413);
    }

    #[test]
    fn runaway_head_without_terminator_is_bad() {
        let junk = vec![b'a'; MAX_HEAD_BYTES + 1];
        match req(&junk) {
            Parse::Bad(Bad::Malformed(_)) => {}
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let Parse::Ready(r, _) = req(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n") else {
            panic!()
        };
        assert!(!r.keep_alive);
        let Parse::Ready(r, _) = req(b"GET / HTTP/1.0\r\n\r\n") else { panic!() };
        assert!(!r.keep_alive, "HTTP/1.0 defaults to close");
        let Parse::Ready(r, _) = req(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n") else {
            panic!()
        };
        assert!(r.keep_alive);
    }

    #[test]
    fn response_roundtrip() {
        let body = error_body(429, "overloaded: shed at queue depth 4");
        let bytes = response_bytes(429, &body, true);
        let Parse::Ready(resp, used) = parse_response(&bytes, 1 << 20) else {
            panic!("expected Ready");
        };
        assert_eq!(resp.status, 429);
        assert!(resp.keep_alive);
        assert_eq!(used, bytes.len());
        let parsed = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(parsed.usize_of("status").unwrap(), 429);
        // Byte determinism: same inputs, same bytes.
        assert_eq!(bytes, response_bytes(429, &body, true));
    }

    #[test]
    fn response_parser_handles_close_and_split() {
        let bytes = response_bytes(200, &Json::Bool(true), false);
        for cut in 1..bytes.len() {
            assert_eq!(parse_response(&bytes[..cut], 1024), Parse::Incomplete, "cut {cut}");
        }
        let Parse::Ready(resp, _) = parse_response(&bytes, 1024) else { panic!() };
        assert!(!resp.keep_alive);
    }
}
