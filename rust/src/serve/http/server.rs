//! The HTTP/1.1 listener: acceptor + connection workers in front of
//! one shared [`EnginePool`].
//!
//! ```text
//! clients ══▶ TcpListener ─▶ conn queue ─▶ http worker 0..N
//!                (acceptor)                     │ parse / route
//!                                               ▼
//!                                        ServeRequest queue ─▶ EnginePool
//! ```
//!
//! Each HTTP worker owns the connections it dequeues end-to-end: it
//! parses requests off the socket, turns `POST /predict` into the same
//! [`ServeRequest`] the in-process bench sends, blocks on the reply
//! channel, and frames the answer back.  The pool underneath batches
//! across connections exactly as it batches across bench clients —
//! the socket boundary adds no second batching policy and touches no
//! float, which is why socket replies are bit-identical to in-process
//! replies (asserted in `tests/http.rs`).
//!
//! Graceful shutdown (`POST /shutdown` or [`ShutdownHandle::trigger`])
//! is a drain, not a kill: the acceptor stops accepting, already
//! accepted connections finish their in-flight request (keep-alive is
//! withdrawn on the final reply via `Connection: close`), workers drop
//! their request senders, and the pool exits once the queue is empty —
//! the same all-senders-dropped convention every pool user relies on.

use anyhow::{anyhow, Context as _, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, SyncSender};
use std::sync::{Arc, Mutex};

use super::proto::{self, Parse};
use super::{status_for, HttpServerCfg};
use crate::obs::metrics;
use crate::serve::batcher::ServeRequest;
use crate::serve::cache::ShardedCache;
use crate::serve::engine::InferenceEngine;
use crate::serve::error::lock_clean;
use crate::serve::pool::{EnginePool, EnginePoolCfg};
use crate::serve::ServeMetrics;
use crate::util::json::{obj, Json};

/// Wire-side traffic counters, snapshotted into [`HttpReport`] and the
/// metrics registry when [`HttpServer::serve`] returns.  Status
/// classes are disjoint: 429 and 503 are broken out of their families
/// because they are the two *policy* rejections (shed, deadline/drain)
/// an operator alarms on separately.
#[derive(Default)]
struct Counters {
    connections: AtomicU64,
    requests: AtomicU64,
    responses_2xx: AtomicU64,
    /// 400/404/408/413 — protocol failures (excludes 429).
    responses_4xx: AtomicU64,
    responses_429: AtomicU64,
    /// 500 — compute failures (excludes 503).
    responses_5xx: AtomicU64,
    responses_503: AtomicU64,
}

impl Counters {
    fn count(&self, status: u16) {
        let c = match status {
            200..=299 => &self.responses_2xx,
            429 => &self.responses_429,
            400..=499 => &self.responses_4xx,
            503 => &self.responses_503,
            _ => &self.responses_5xx,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// What one `serve()` run handled, for the exit summary.
#[derive(Debug, Clone, Default)]
pub struct HttpReport {
    pub connections: u64,
    pub requests: u64,
    pub responses_2xx: u64,
    pub responses_4xx: u64,
    pub responses_429: u64,
    pub responses_5xx: u64,
    pub responses_503: u64,
}

/// Remote control for a running server: flip the stop flag and nudge
/// the blocking `accept` awake with a throwaway connection.
#[derive(Clone)]
pub struct ShutdownHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ShutdownHandle {
    /// Begin draining: no new connections are accepted, in-flight
    /// requests complete.  Idempotent.
    pub fn trigger(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // The acceptor blocks in accept(); a throwaway connection is
        // the portable way to wake it so it can observe the flag.
        let _ = TcpStream::connect(self.addr);
    }

    pub fn is_triggered(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// Everything a connection handler needs, shared across workers.
struct Ctx<'a, 'e> {
    cfg: &'a HttpServerCfg,
    engine: &'a InferenceEngine<'e>,
    req_tx: SyncSender<ServeRequest>,
    stop: &'a Arc<AtomicBool>,
    shutdown: ShutdownHandle,
    counters: &'a Counters,
}

pub struct HttpServer {
    cfg: HttpServerCfg,
    listener: TcpListener,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind `serve.http.listen`.  Port 0 resolves to an ephemeral port
    /// — read it back with [`local_addr`](Self::local_addr).
    pub fn bind(cfg: HttpServerCfg) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding serve.http.listen = {}", cfg.listen))?;
        let addr = listener.local_addr().context("reading bound address")?;
        Ok(HttpServer { cfg, listener, addr, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { stop: Arc::clone(&self.stop), addr: self.addr }
    }

    /// Serve until shutdown is triggered, then drain and return the
    /// traffic report.  Blocks the calling thread (the acceptor runs
    /// inline); workers and the engine pool live on scoped threads.
    pub fn serve(
        &self,
        engine: &InferenceEngine,
        cache: &ShardedCache,
        pool_cfg: EnginePoolCfg,
    ) -> Result<HttpReport> {
        let workers = self.cfg.workers.max(1);
        let _sp = crate::span!("serve.http.serve", workers = workers);
        let counters = Counters::default();
        let serve_metrics = ServeMetrics::new();
        let pool = EnginePool::new(pool_cfg);
        let (req_tx, req_rx) = sync_channel::<ServeRequest>(4096);
        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let conn_rx = Mutex::new(conn_rx);
        let shutdown = self.shutdown_handle();

        let mut pool_result: Result<()> = Ok(());
        std::thread::scope(|scope| {
            let pool_handle = {
                let serve_metrics = &serve_metrics;
                scope.spawn(move || pool.run(engine, cache, req_rx, serve_metrics))
            };
            for _ in 0..workers {
                let ctx = Ctx {
                    cfg: &self.cfg,
                    engine,
                    req_tx: req_tx.clone(),
                    stop: &self.stop,
                    shutdown: shutdown.clone(),
                    counters: &counters,
                };
                let conn_rx = &conn_rx;
                scope.spawn(move || {
                    // Workers drain the conn queue until the acceptor
                    // drops its sender; the trailing connections a
                    // drain leaves behind are still served.
                    loop {
                        let stream = match lock_clean(conn_rx).recv() {
                            Ok(s) => s,
                            Err(_) => return,
                        };
                        handle_connection(stream, &ctx);
                    }
                });
            }
            // req_tx clones live in the workers; dropping the original
            // here means the pool exits exactly when the last worker
            // does.
            drop(req_tx);

            // ---- acceptor (inline) --------------------------------
            for accepted in self.listener.incoming() {
                if self.stop.load(Ordering::SeqCst) {
                    break; // wake-up connection (or racing client) is dropped unserved
                }
                match accepted {
                    Ok(stream) => {
                        counters.connections.fetch_add(1, Ordering::Relaxed);
                        if conn_tx.send(stream).is_err() {
                            break;
                        }
                    }
                    // Transient accept errors (aborted handshakes,
                    // fd pressure) don't kill the listener.
                    Err(_) => continue,
                }
            }
            drop(conn_tx); // workers finish queued connections, then exit

            match pool_handle.join() {
                Ok(r) => pool_result = r,
                Err(_) => pool_result = Err(anyhow!("engine pool thread panicked")),
            }
        });
        pool_result?;

        let report = HttpReport {
            connections: counters.connections.load(Ordering::Relaxed),
            requests: counters.requests.load(Ordering::Relaxed),
            responses_2xx: counters.responses_2xx.load(Ordering::Relaxed),
            responses_4xx: counters.responses_4xx.load(Ordering::Relaxed),
            responses_429: counters.responses_429.load(Ordering::Relaxed),
            responses_5xx: counters.responses_5xx.load(Ordering::Relaxed),
            responses_503: counters.responses_503.load(Ordering::Relaxed),
        };
        metrics::counter_set("serve.http.connections", report.connections);
        metrics::counter_set("serve.http.requests", report.requests);
        metrics::counter_set("serve.http.responses_2xx", report.responses_2xx);
        metrics::counter_set("serve.http.responses_4xx", report.responses_4xx);
        metrics::counter_set("serve.http.responses_429", report.responses_429);
        metrics::counter_set("serve.http.responses_5xx", report.responses_5xx);
        metrics::counter_set("serve.http.responses_503", report.responses_503);
        metrics::gauge_set("serve.http.workers", workers as f64);
        Ok(report)
    }
}

/// Serve one connection to completion: parse → route → reply, looping
/// while keep-alive holds.  Never panics; every exit path either sent
/// a response or hit a dead socket.
fn handle_connection(stream: TcpStream, ctx: &Ctx) {
    let _ = stream.set_read_timeout(Some(ctx.cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(ctx.cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match proto::parse_request(&buf, ctx.cfg.max_body) {
            Parse::Ready(req, used) => {
                buf.drain(..used);
                ctx.counters.requests.fetch_add(1, Ordering::Relaxed);
                let (status, body) = route(&req, ctx);
                // Draining withdraws keep-alive: the client learns on
                // this reply that the connection is closing.
                let keep = req.keep_alive && !ctx.stop.load(Ordering::SeqCst);
                crate::event!("serve.http.request", status = status as u64, keep = keep);
                ctx.counters.count(status);
                if stream.write_all(&proto::response_bytes(status, &body, keep)).is_err() {
                    return;
                }
                if !keep {
                    return;
                }
                // Loop before reading: pipelined bytes may already be
                // buffered.
            }
            Parse::Bad(bad) => {
                ctx.counters.requests.fetch_add(1, Ordering::Relaxed);
                let status = bad.status();
                ctx.counters.count(status);
                let body = proto::error_body(status, &bad.message());
                let _ = stream.write_all(&proto::response_bytes(status, &body, false));
                return; // framing is unrecoverable — close
            }
            Parse::Incomplete => match stream.read(&mut chunk) {
                Ok(0) => {
                    if !buf.is_empty() {
                        // The peer promised more (e.g. a declared
                        // Content-Length it never sent) and hung up:
                        // answer the mismatch deterministically.
                        ctx.counters.requests.fetch_add(1, Ordering::Relaxed);
                        ctx.counters.count(400);
                        let body = proto::error_body(400, "incomplete request (connection closed mid-message)");
                        let _ = stream.write_all(&proto::response_bytes(400, &body, false));
                    }
                    return;
                }
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if !buf.is_empty() {
                        ctx.counters.requests.fetch_add(1, Ordering::Relaxed);
                        ctx.counters.count(408);
                        let body = proto::error_body(408, "timed out mid-request");
                        let _ = stream.write_all(&proto::response_bytes(408, &body, false));
                    }
                    return; // idle keep-alive timeout: quiet close
                }
                Err(_) => return,
            },
        }
    }
}

/// Dispatch one parsed request.  Returns `(status, json_body)`;
/// serialization and connection policy stay in the caller.
fn route(req: &proto::Request, ctx: &Ctx) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/info") => {
            let ds = ctx.engine.ds;
            let nt = ds.target_ntype;
            (
                200,
                obj(vec![
                    ("ntype", Json::from(nt)),
                    ("nodes", Json::from(ds.graph.num_nodes[nt])),
                    ("out_dim", Json::from(ctx.engine.out_dim())),
                ]),
            )
        }
        ("POST", "/predict") => predict(&req.body, ctx),
        ("POST", "/shutdown") => {
            ctx.shutdown.trigger();
            (200, obj(vec![("draining", Json::Bool(true))]))
        }
        _ => (404, proto::error_body(404, "no such route")),
    }
}

/// `POST /predict {"id": N[, "nt": T]}` → one embedding row through
/// the engine pool.
fn predict(body: &[u8], ctx: &Ctx) -> (u16, Json) {
    let parsed = std::str::from_utf8(body)
        .map_err(anyhow::Error::from)
        .and_then(|t| Json::parse(t));
    let json = match parsed {
        Ok(j) => j,
        Err(e) => return (400, proto::error_body(400, &format!("body is not valid JSON: {e}"))),
    };
    // Strict integers: `{"id": 2.7}` is a 400, not a truncation —
    // the same `as_usize` contract config validation relies on.
    let Ok(id) = json.usize_of("id") else {
        return (400, proto::error_body(400, "body needs an integer 'id'"));
    };
    let nt = match json.get("nt") {
        None => ctx.engine.ds.target_ntype,
        Some(_) => match json.usize_of("nt") {
            Ok(n) => n,
            Err(_) => return (400, proto::error_body(400, "'nt' must be an integer")),
        },
    };
    let num_nodes = &ctx.engine.ds.graph.num_nodes;
    if nt >= num_nodes.len() {
        return (400, proto::error_body(400, &format!("unknown node type {nt}")));
    }
    if id >= num_nodes[nt] {
        return (
            400,
            proto::error_body(
                400,
                &format!("node id {id} out of range (type {nt} has {} nodes)", num_nodes[nt]),
            ),
        );
    }

    let (reply_tx, reply_rx) = channel();
    if ctx.req_tx.send(ServeRequest::new(nt as u32, id as u32, reply_tx)).is_err() {
        return (503, proto::error_body(503, "serving pool is shut down"));
    }
    match reply_rx.recv() {
        Err(_) => (503, proto::error_body(503, "serving pool dropped the request")),
        Ok(Err(e)) => (status_for(&e), proto::error_body(status_for(&e), &e.to_string())),
        Ok(Ok(row)) => (
            200,
            obj(vec![
                ("nt", Json::from(nt)),
                ("id", Json::from(id)),
                // f32 → f64 is exact, and the JSON writer emits
                // shortest-round-trip floats: the row survives the
                // wire bit-identically.
                ("row", Json::Arr(row.iter().map(|&v| Json::from(v as f64)).collect())),
            ]),
        ),
    }
}
