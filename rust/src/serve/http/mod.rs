//! HTTP/1.1 network front end for the serving stack (`gs serve`).
//!
//! Everything below PR 3 speaks channels: clients hand
//! [`ServeRequest`](super::batcher::ServeRequest)s to an
//! [`EnginePool`](super::pool::EnginePool) and block on a reply
//! channel.  This module puts a socket boundary in front of that
//! queue — a hand-rolled HTTP/1.1 server on `std::net::TcpListener`
//! (no async runtime, no HTTP crate: the container is offline and the
//! protocol subset we need is small) — so the serving path can be
//! load-tested across a real network hop and exercised by anything
//! that speaks HTTP.
//!
//! Layout:
//!
//! * [`proto`] — pure request/response parsing and formatting.
//!   Content-Length framing only, keep-alive by HTTP/1.1 defaults,
//!   split-read tolerant, hostile-length safe.  All unit-testable
//!   without a socket.
//! * [`server`] — the listener: one acceptor + N connection workers
//!   ([`HttpServerCfg::workers`]) feeding one shared [`EnginePool`]
//!   through the same request queue `gs serve-bench` uses.  Replies
//!   are therefore **bit-identical** to in-process pool replies by
//!   construction — the socket layer only frames bytes, it never
//!   touches a float.
//! * [`load`] — the closed-loop load generator (`gs load-bench`):
//!   N persistent connections replaying the canonical Zipf trace,
//!   measuring saturation throughput and latency percentiles from the
//!   client side of the wire.
//!
//! Error taxonomy → status code, decided once here and used by both
//! sides of the wire:
//!
//! | [`ServeError`]             | HTTP status                         |
//! |----------------------------|-------------------------------------|
//! | `Overloaded`               | 429 (shed at the queue boundary)    |
//! | `DeadlineExceeded`         | 503 (expired before compute)        |
//! | `Canceled`                 | 503 (pool shutting down)            |
//! | `Transient` / `Fatal`      | 500 (compute failed for good)       |
//!
//! Protocol-level failures never reach the pool: unparseable requests
//! get 400, unknown routes 404, oversized bodies 413 — all with JSON
//! `{"error", "status"}` bodies.

pub mod load;
pub mod proto;
pub mod server;

pub use load::{run_load_bench, LoadBenchCfg, LoadBenchReport};
pub use proto::{parse_request, parse_response, response_bytes, Bad, Parse, Request, Response};
pub use server::{HttpReport, HttpServer, ShutdownHandle};

use super::error::ServeError;

/// Socket-facing knobs, resolved from `serve.http` config
/// ([`crate::config::HttpCfg::server_cfg`]).
#[derive(Debug, Clone)]
pub struct HttpServerCfg {
    /// Bind address (`serve.http.listen`), e.g. `127.0.0.1:8080`;
    /// port 0 asks the OS for an ephemeral port (tests, smoke gates).
    pub listen: String,
    /// Connection-handler threads (`serve.http.workers`) — bounds
    /// concurrently *served* connections; accepted connections beyond
    /// it wait their turn in the handoff queue.
    pub workers: usize,
    /// Request-body cap in bytes (`serve.http.max_body`); larger
    /// declared Content-Lengths are refused with 413 before the body
    /// is read.
    pub max_body: usize,
    /// Per-connection socket read timeout (`serve.http.read_timeout_ms`).
    pub read_timeout: std::time::Duration,
    /// Per-connection socket write timeout (`serve.http.write_timeout_ms`).
    pub write_timeout: std::time::Duration,
}

/// The one place a [`ServeError`] becomes an HTTP status (table in the
/// module docs).
pub fn status_for(e: &ServeError) -> u16 {
    match e {
        ServeError::Overloaded { .. } => 429,
        ServeError::DeadlineExceeded { .. } => 503,
        ServeError::Canceled(_) => 503,
        ServeError::Transient(_) | ServeError::Fatal(_) => 500,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping_matches_taxonomy() {
        assert_eq!(status_for(&ServeError::Overloaded { depth: 4 }), 429);
        assert_eq!(status_for(&ServeError::DeadlineExceeded { waited_ms: 9 }), 503);
        assert_eq!(status_for(&ServeError::Canceled("shutdown".into())), 503);
        assert_eq!(status_for(&ServeError::transient("row source hiccup")), 500);
        assert_eq!(status_for(&ServeError::fatal("scratch poisoned")), 500);
    }
}
