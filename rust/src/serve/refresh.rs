//! Background cache refresh: after a generation bump (sparse
//! embedding update via `dist::EmbTable::sparse_adam`, or a model
//! refresh via `InferenceEngine::bump_generation`), re-read the hot
//! rows through their [`RowSource`] instead of letting the whole
//! working set collapse into a miss storm.
//!
//! Generation stamping already guarantees **no stale row is ever
//! served**: a cached row whose stamp predates the source's current
//! generation reports a miss.  What stamping alone cannot prevent is
//! the latency cliff right after a bump — every hot key misses at
//! once and the serving path recomputes them inline.  The refresher
//! closes that gap: it walks the cache's merged recency view (most
//! recent first, [`ShardedCache::hot_keys`]), re-fetches up to `limit`
//! rows from the source, and re-stamps them at the generation the
//! fetch observed.  A fetch that races with *another* bump is retried,
//! so a re-stamped row is always consistent with its stamp.
//!
//! The cache is a [`ShardedCache`]: each stripe's lock is held only to
//! snapshot keys and to insert single rows — never across a fetch, and
//! never two stripes at once — so serving continues concurrently on
//! every stripe the refresher isn't touching at that instant.

use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use super::cache::{split_key, RowSource, ShardedCache};
use super::engine::{InferenceEngine, ServeScratch};

/// Knobs for [`refresh_loop`] (`serve.refresh` enables it in the
/// bench stage with `limit` hot rows).
#[derive(Debug, Clone)]
pub struct RefreshCfg {
    /// How often to compare the source generation with the cache's.
    pub poll: Duration,
    /// Most-recently-used rows re-read per refresh pass.
    pub limit: usize,
    /// Retries per refresh pass when the source errors; after the
    /// budget the pass is skipped (the serving path's miss handling
    /// re-reads rows on demand, so a failed refresh costs latency,
    /// never correctness).
    pub max_retries: usize,
    /// Base backoff before the first retry, doubled per attempt.
    pub backoff: Duration,
}

impl Default for RefreshCfg {
    fn default() -> Self {
        RefreshCfg {
            poll: Duration::from_millis(10),
            limit: 1024,
            max_retries: 3,
            backoff: Duration::from_millis(1),
        }
    }
}

/// Counters a refresh thread publishes (Relaxed; dashboard-grade).
#[derive(Debug, Default)]
pub struct RefreshStats {
    passes: AtomicU64,
    rows: AtomicU64,
    errors: AtomicU64,
}

impl RefreshStats {
    pub fn new() -> RefreshStats {
        RefreshStats::default()
    }

    /// Refresh passes that re-read at least one row.
    pub fn passes(&self) -> u64 {
        self.passes.load(Ordering::Relaxed)
    }

    /// Total rows re-read across all passes.
    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    /// Source errors observed (each failed attempt counts one; a pass
    /// that eventually succeeds still leaves its failed attempts
    /// here).
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }
}

/// Rows re-read per retry unit: small enough that a concurrent bump
/// only wastes one chunk of fetches, big enough to amortize batched
/// sources (`RowSource::fetch_rows`).
const REFRESH_CHUNK: usize = 64;

/// One refresh pass: if the source generation has moved past the
/// cache's, re-read up to `limit` of the most-recently-used rows and
/// re-stamp them at the generation each fetch observed.  Returns the
/// number of rows refreshed (0 when the cache is already current).
///
/// Rows are re-inserted coldest-first so the pass preserves the
/// cache's recency order (MRU-first insertion would invert it and
/// make the hottest row the next eviction victim).  The generation is
/// re-validated against the source *under each stripe's lock* before
/// stamping that stripe: generations are monotonic and every serving
/// path adopts them under the same per-stripe lock, so no stripe's
/// generation can ever move backwards — a refresh that lost a race
/// with a newer bump retries the chunk instead of un-staling older
/// rows (the rows already stamped this attempt were stamped while
/// their generation was still current, so they stay consistent).
pub fn refresh_hot_rows(
    cache: &ShardedCache,
    src: &mut impl RowSource,
    limit: usize,
) -> Result<usize> {
    // `generation()` is the min over stripes: if *any* stripe lags the
    // source, the pass runs and re-stamps every stripe it touches.
    let mut keys = cache.hot_keys(limit);
    if src.source_generation() == cache.generation() || keys.is_empty() {
        return Ok(0);
    }
    let _span = crate::span!("serve.refresh.pass", keys = keys.len());
    keys.reverse(); // coldest of the hot set first, MRU last
    let mut rows = Vec::new();
    let mut refreshed = 0usize;
    let mut adopted = None;
    let dim = src.row_dim();
    for chunk in keys.chunks(REFRESH_CHUNK) {
        let seeds: Vec<(u32, u32)> = chunk.iter().map(|&k| split_key(k)).collect();
        // Re-read until the generation is stable around the fetch, so
        // the stamp is consistent with the rows (bounded: a source
        // bumping faster than we can read isn't worth refreshing).
        for _attempt in 0..4 {
            let gen = src.source_generation();
            src.fetch_rows(&seeds, &mut rows)?;
            let mut moved = false;
            for (i, &key) in chunk.iter().enumerate() {
                // One stripe lock at a time (never two — the lock
                // order makes nesting ascending-only anyway).
                let mut c = cache.lock_key(key);
                // Validate under the stripe lock: if the source moved
                // on (and a serving thread may already have stamped
                // this stripe newer), retry the chunk rather than roll
                // any stripe's generation backwards.
                if src.source_generation() != gen {
                    moved = true;
                    break;
                }
                c.set_generation(gen);
                c.put(key, &rows[i * dim..(i + 1) * dim]);
            }
            if !moved {
                refreshed += chunk.len();
                adopted = Some(gen);
                break;
            }
        }
    }
    // Stamp the stripes the hot set never touched, so the aggregate
    // (min-over-stripes) generation converges to the source's and the
    // next pass is a no-op.  Safe because stamping a stripe forward
    // only *invalidates* its un-refreshed rows — they miss and
    // recompute instead of ever being served stale.
    if let Some(gen) = adopted {
        cache.set_generation(gen);
    }
    Ok(refreshed)
}

/// Blocking refresh loop for a background thread: poll the source
/// generation every `cfg.poll`, refreshing the hot set whenever it
/// moves, until `stop` is raised.  Spawn it in a `std::thread::scope`
/// next to the engine pool, sharing the pool's [`ShardedCache`].
///
/// **One generation domain per cache.**  A cache is stamped from
/// exactly one counter: the engine pool stamps its cache with
/// `InferenceEngine::generation()`, so a refresher sharing that cache
/// must use a source in the same domain ([`EngineSource`]).
/// [`EmbTableSource`](super::cache::EmbTableSource) pairs with
/// read-through embedding caches (`EmbeddingCache::get_through`),
/// which are stamped with the *table's* counter.  Mixing domains
/// makes the two writers fight over the stamp — every refresh is
/// immediately re-staled by the serving path and the loop re-fetches
/// the hot set on each poll tick.
pub fn refresh_loop(
    cache: &ShardedCache,
    src: &mut impl RowSource,
    cfg: &RefreshCfg,
    stop: &AtomicBool,
    stats: &RefreshStats,
) -> Result<()> {
    while !stop.load(Ordering::Acquire) {
        // Transient source errors must never kill the refresher: retry
        // with exponential backoff, and once the budget is spent skip
        // the pass entirely — stale rows stay stale-stamped, so the
        // serving path falls back to miss reads (latency, not
        // correctness).  Every failed attempt is counted in
        // `RefreshStats::errors`.
        let mut attempt = 0usize;
        loop {
            match refresh_hot_rows(cache, src, cfg.limit) {
                Ok(n) => {
                    if n > 0 {
                        stats.passes.fetch_add(1, Ordering::Relaxed);
                        stats.rows.fetch_add(n as u64, Ordering::Relaxed);
                    }
                    break;
                }
                Err(_) => {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    if attempt >= cfg.max_retries || stop.load(Ordering::Acquire) {
                        break;
                    }
                    let mul = 1u32 << attempt.min(16);
                    std::thread::sleep(cfg.backoff.saturating_mul(mul));
                    attempt += 1;
                }
            }
        }
        std::thread::sleep(cfg.poll);
    }
    // Lifetime totals → global registry, once at shutdown (the stats
    // themselves stay lock-free while the loop runs).
    crate::obs::metrics::counter_set("serve.refresh.passes", stats.passes());
    crate::obs::metrics::counter_set("serve.refresh.rows", stats.rows());
    crate::obs::metrics::counter_set("serve.refresh.errors", stats.errors());
    Ok(())
}

/// The inference engine as a [`RowSource`]: the canonical per-node
/// prediction is the row, the model generation is the source
/// generation.  This is what lets the refresher re-warm a *prediction*
/// cache after `bump_generation`, not just embedding-table caches.
pub struct EngineSource<'e, 'a> {
    engine: &'e InferenceEngine<'a>,
    sc: ServeScratch<'a>,
    /// When sharing a PJRT engine with a running pool, pass the pool's
    /// execution lock so the session never executes concurrently.
    exec_lock: Option<&'e Mutex<()>>,
}

impl<'e, 'a> EngineSource<'e, 'a> {
    pub fn new(engine: &'e InferenceEngine<'a>) -> EngineSource<'e, 'a> {
        EngineSource { engine, sc: engine.make_scratch(), exec_lock: None }
    }

    pub fn with_exec_lock(
        engine: &'e InferenceEngine<'a>,
        exec_lock: &'e Mutex<()>,
    ) -> EngineSource<'e, 'a> {
        EngineSource { engine, sc: engine.make_scratch(), exec_lock: Some(exec_lock) }
    }
}

impl RowSource for EngineSource<'_, '_> {
    fn row_dim(&self) -> usize {
        self.engine.out_dim()
    }

    fn source_generation(&self) -> u64 {
        self.engine.generation()
    }

    fn fetch_row(&mut self, nt: u32, id: u32, out: &mut Vec<f32>) -> Result<()> {
        let rows = match self.exec_lock {
            Some(lock) => self.engine.forward_locked(&mut self.sc, &[(nt, id)], lock)?,
            None => self.engine.forward(&mut self.sc, &[(nt, id)])?,
        };
        out.clear();
        out.extend_from_slice(rows);
        Ok(())
    }

    /// Batched forwards at engine capacity — one sample/assemble/
    /// execute per chunk instead of per row.
    fn fetch_rows(&mut self, seeds: &[(u32, u32)], out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        for chunk in seeds.chunks(self.engine.capacity().max(1)) {
            let rows = match self.exec_lock {
                Some(lock) => self.engine.forward_locked(&mut self.sc, chunk, lock)?,
                None => self.engine.forward(&mut self.sc, chunk)?,
            };
            out.extend_from_slice(rows);
        }
        Ok(())
    }
}
