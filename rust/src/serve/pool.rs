//! The serving engine *pool*: N [`InferenceEngine`] scratches drain
//! one shared micro-batcher queue — now under supervision.
//!
//! PR 2's `MicroBatcher::run` answers the queue with a single engine
//! scratch — one core against millions-of-users traffic.  The pool
//! keeps the same batching policy ([`MicroBatcherCfg`]) but splits the
//! work across scoped threads, the same worker/consumer shape as
//! `dataloader::run_pipeline`:
//!
//! ```text
//! clients ─▶ request queue ─▶ coordinator ─▶ job queue ─▶ worker 0..N
//!                                 ▲   (owns cache + batching policy)     │
//!                                 └── completions / worker obituaries ◀──┘
//! ```
//!
//! * The **coordinator** is the only *request-path* thread that
//!   touches the cache and the batching state: it answers hits on
//!   arrival, sheds requests at the queue boundary
//!   ([`EnginePoolCfg::queue_depth`]), coalesces duplicate in-flight
//!   keys, cuts size/deadline-bounded batches of distinct misses and
//!   hands them to the job queue.  The cache is a
//!   [`ShardedCache`] — per-key stripe locks — so the background
//!   refresher (`serve::refresh`) re-warms stripes concurrently
//!   without stalling the hit path behind one table-wide mutex.
//! * **Workers** each own a private [`ServeScratch`] and run the full
//!   sample → assemble → execute path per batch inside
//!   `catch_unwind`, with bounded backoff-retries for retryable
//!   errors ([`ServeError::retryable`]).  Worker `w` serializes
//!   backend execution behind session lock `w % sessions`
//!   ([`EnginePoolCfg::sessions`]), so forwards on distinct sessions
//!   run genuinely in parallel.  A panic or fatal error
//!   discards the scratch: the worker restarts with a fresh one while
//!   the pool-wide restart budget
//!   ([`EnginePoolCfg::max_worker_restarts`]) lasts, then exits.
//! * A dead worker's in-flight batch is **re-dispatched** by the
//!   coordinator (the `PendingBatch` table still holds its seeds), so
//!   no request is lost and — recomputation being canonical per node
//!   — its replies are bit-identical to the fault-free run.
//! * When every worker has exited (budget exhausted) the pool enters
//!   **degraded mode**: the coordinator executes remaining and future
//!   batches inline on its own lazily-built scratch.  Slower, never
//!   down.
//! * Completions are applied to the cache **in dispatch order** (a
//!   reorder buffer holds early finishers), so the cache's content
//!   evolves identically for any pool size and any fault schedule.
//!
//! Dispatch is non-blocking: the coordinator `try_send`s jobs and
//! parks overflow in a local backlog flushed as completions free
//! queue slots.  This is what makes re-dispatch deadlock-free — a
//! blocking send could wedge against a full job queue whose only
//! consumer just died, with that worker's `WorkerExit` obituary
//! sitting unread behind the send.
//!
//! Determinism contract (the pooled extension of PR 1's per-batch RNG
//! invariant): because the engine samples canonically per node, every
//! reply is bit-identical for any pool size, any session count, any
//! cache shard count, any batch composition, any worker interleaving
//! and any injected fault schedule ([`FaultPlan`]).  Hit/miss
//! *accounting* is also invariant across every `(shards, sessions,
//! pool_workers)` combination whenever the cache doesn't evict
//! (capacity ≥ working set) and the request order is fixed: a request
//! misses iff its key was never requested before, because keys move
//! atomically from forming batch → in-flight → cache under the
//! coordinator, and sharding only changes *which* stripe lock guards a
//! key, never whether it is resident.  Requests that find their key in
//! flight are counted as hits (and additionally as `coalesced`); the
//! hit/coalesced *split* depends on completion timing, the hit+miss
//! totals do not.  Shedding and deadline misses are deliberately
//! timing-dependent and excluded from that contract
//! (`tests/faults.rs` and `tests/sharding.rs` run their bit-identity
//! sweeps with both off; the faulted sweeps re-check the counters the
//! contract does cover).

use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::batcher::{ClosedLoopStats, MicroBatcherCfg, ServeRequest};
use super::cache::{cache_key, ShardedCache};
use super::engine::{InferenceEngine, ServeScratch};
use super::error::{lock_clean, ServeError};
use super::faults::{FaultKind, FaultPlan};
use super::ServeMetrics;
use crate::util::FxHashMap;

/// Engine-pool knobs: worker count, the shared batching policy, and
/// the fault-tolerance envelope.  `serve.pool_workers` resolves
/// `"auto"` before this struct exists.
#[derive(Debug, Clone)]
pub struct EnginePoolCfg {
    /// Engine scratches draining the queue (≥ 1).
    pub workers: usize,
    /// Independent engine execution sessions (`serve.sessions`):
    /// worker `w` serializes backend execution behind session lock
    /// `w % sessions`, so PJRT forwards across different sessions run
    /// genuinely in parallel instead of all queueing on one lock.
    /// Clamped to `[1, workers]` at pool start; the surrogate backend
    /// is lock-free either way.  Replies are bit-identical for any
    /// value — sessions only change *which* lock serializes a forward.
    pub sessions: usize,
    pub batcher: MicroBatcherCfg,
    /// Per-request deadline (`serve.deadline_ms`); a request older
    /// than this gets [`ServeError::DeadlineExceeded`] instead of a
    /// row.  Zero disables.
    pub request_deadline: Duration,
    /// Retries per batch for retryable errors (`serve.max_retries`).
    pub max_retries: usize,
    /// Base backoff before the first retry, doubled per attempt.
    pub retry_backoff: Duration,
    /// Queue-boundary bound on pending (admitted, unanswered)
    /// requests (`serve.queue_depth`); arrivals beyond it are shed
    /// with [`ServeError::Overloaded`].  Zero disables.  Cache hits
    /// are always served — they consume no queue slot.
    pub queue_depth: usize,
    /// Pool-wide budget of worker restarts
    /// (`serve.max_worker_restarts`) before dying workers stay dead
    /// and the pool degrades to coordinator-inline execution.
    pub max_worker_restarts: usize,
}

impl Default for EnginePoolCfg {
    fn default() -> Self {
        EnginePoolCfg {
            workers: 1,
            sessions: 1,
            batcher: MicroBatcherCfg::default(),
            request_deadline: Duration::ZERO,
            max_retries: 2,
            retry_backoff: Duration::from_millis(1),
            queue_depth: 0,
            max_worker_restarts: 8,
        }
    }
}

/// One dispatched micro-batch: distinct miss seeds, identified by a
/// dense sequence number.
struct Job {
    seq: u64,
    seeds: Vec<(u32, u32)>,
    /// When the coordinator cut the batch — workers record the
    /// dispatch → dequeue gap into [`ServeMetrics::queue_us`].
    t_disp: Instant,
}

/// What flows into the coordinator: forwarded client requests, worker
/// completions and obituaries, and the end-of-stream marker from the
/// forwarder.
enum Msg {
    Req(ServeRequest),
    Done {
        seq: u64,
        /// Engine generation observed *before* the forward ran; rows
        /// are cached only if this is still current at apply time.
        gen: u64,
        rows: Result<Vec<f32>, ServeError>,
    },
    /// A worker panicked while holding `seq`: the batch never
    /// completed and the coordinator must re-dispatch it.
    WorkerDied { seq: u64 },
    /// A worker exited for good (restart budget exhausted).
    WorkerExit,
    Eof,
}

/// A dispatched batch the coordinator is still tracking: its seed list
/// (for cache insertion *and* re-dispatch) and every request waiting
/// on it.
struct PendingBatch {
    seeds: Vec<(u32, u32)>,
    waiters: Vec<(usize, ServeRequest)>,
}

/// How one batch execution ended, after fault injection, retries and
/// panic capture.
enum BatchExec {
    Completed { gen: u64, rows: Result<Vec<f32>, ServeError> },
    /// The attempt panicked: the scratch can't be trusted and the
    /// batch must run again elsewhere.
    Panicked,
}

/// Execute one batch on `sc`: consult the fault plan (one-shot per
/// seq), run the forward under `catch_unwind`, and retry retryable
/// errors up to `max_retries` times with exponential backoff
/// (recording each retry).  Panics — injected or real — surface as
/// [`BatchExec::Panicked`] for the caller's supervision policy.
#[allow(clippy::too_many_arguments)]
fn execute_batch<'a>(
    engine: &InferenceEngine<'a>,
    sc: &mut ServeScratch<'a>,
    seq: u64,
    seeds: &[(u32, u32)],
    exec_lock: &Mutex<()>,
    metrics: &ServeMetrics,
    faults: Option<&FaultPlan>,
    max_retries: usize,
    retry_backoff: Duration,
) -> BatchExec {
    let _span = crate::span!("serve.batch.forward", seq = seq, rows = seeds.len());
    let t_exec = Instant::now(); // lint:allow(determinism): exec-latency histogram stamp only
    let mut attempt = 0usize;
    let out = loop {
        let injected = faults.and_then(|f| f.take(seq));
        let run = catch_unwind(AssertUnwindSafe(|| {
            match injected {
                Some(FaultKind::WorkerPanic) => {
                    // resume_unwind bypasses the panic hook, so an
                    // injected panic doesn't spam stderr the way
                    // `panic!` would — supervision catches it either
                    // way.
                    std::panic::resume_unwind(Box::new(format!(
                        "injected worker panic at batch {seq}"
                    )));
                }
                Some(FaultKind::Transient) => {
                    return (
                        engine.generation(),
                        Err(anyhow::Error::new(ServeError::transient(format!(
                            "injected transient row-source error at batch {seq}"
                        )))),
                    );
                }
                Some(FaultKind::Fatal) => {
                    return (
                        engine.generation(),
                        Err(anyhow::Error::new(ServeError::fatal(format!(
                            "injected fatal row-source error at batch {seq}"
                        )))),
                    );
                }
                Some(FaultKind::SlowRead) => {
                    std::thread::sleep(faults.map(|f| f.slow).unwrap_or_default());
                }
                None => {}
            }
            let gen = engine.generation();
            let rows = engine.forward_locked(sc, seeds, exec_lock).map(|r| r.to_vec());
            (gen, rows)
        }));
        match run {
            Err(_panic_payload) => break BatchExec::Panicked,
            Ok((gen, Ok(rows))) => break BatchExec::Completed { gen, rows: Ok(rows) },
            Ok((gen, Err(e))) => {
                let se = ServeError::classify(&e);
                if se.retryable() && attempt < max_retries {
                    attempt += 1;
                    metrics.record_retry();
                    crate::event!("serve.batch.retry", seq = seq, attempt = attempt);
                    let mul = 1u32 << (attempt - 1).min(16);
                    std::thread::sleep(retry_backoff.saturating_mul(mul));
                    continue;
                }
                break BatchExec::Completed { gen, rows: Err(se) };
            }
        }
    };
    // Execution time per batch, retries and backoff included: the
    // profile answers "what did serving this batch cost", not "what
    // did one clean forward cost".
    metrics.exec_us.record(t_exec.elapsed());
    metrics.record_batch();
    out
}

pub struct EnginePool {
    pub cfg: EnginePoolCfg,
}

impl EnginePool {
    pub fn new(cfg: EnginePoolCfg) -> EnginePool {
        EnginePool { cfg }
    }

    /// Blocking serve loop: drains `rx` until every request sender has
    /// been dropped and every dispatched batch has been applied.
    /// `cache` is a [`ShardedCache`] — per-key stripe locks — so a
    /// background refresher (`serve::refresh`) can re-warm it
    /// concurrently without contending with the whole hit path.
    pub fn run(
        &self,
        engine: &InferenceEngine,
        cache: &ShardedCache,
        rx: Receiver<ServeRequest>,
        metrics: &ServeMetrics,
    ) -> Result<()> {
        self.run_with_faults(engine, cache, rx, metrics, None)
    }

    /// [`run`](Self::run) with an optional deterministic fault plan
    /// consulted once per dispatched batch — the supervision test
    /// harness (`tests/faults.rs`, `gs serve-bench --faults`).
    pub fn run_with_faults(
        &self,
        engine: &InferenceEngine,
        cache: &ShardedCache,
        rx: Receiver<ServeRequest>,
        metrics: &ServeMetrics,
        faults: Option<&FaultPlan>,
    ) -> Result<()> {
        let workers = self.cfg.workers.max(1);
        let sessions = self.cfg.sessions.clamp(1, workers);
        let cap = self.cfg.batcher.max_batch.min(engine.capacity()).max(1);
        let c = engine.out_dim();
        let max_retries = self.cfg.max_retries;
        let retry_backoff = self.cfg.retry_backoff;
        let request_deadline = self.cfg.request_deadline;
        // One execution lock per session: worker w serializes its
        // backend forwards behind lock w % sessions, so distinct
        // sessions execute in parallel (`serve.sessions`).
        let exec_locks: Vec<Mutex<()>> = (0..sessions).map(|_| Mutex::new(())).collect();
        // Signed pool-wide budget: each restart event decrements; a
        // worker whose decrement observes an already-spent budget
        // exits instead of restarting.
        let restart_budget = AtomicI64::new(self.cfg.max_worker_restarts as i64);
        let (msg_tx, msg_rx) = channel::<Msg>();
        let (job_tx, job_rx) = std::sync::mpsc::sync_channel::<Job>(workers * 2);
        let job_rx = Mutex::new(job_rx);

        std::thread::scope(|scope| -> Result<()> {
            // Forwarder: client requests → merged coordinator queue.
            let fwd_tx = msg_tx.clone();
            scope.spawn(move || {
                for req in rx.iter() {
                    if fwd_tx.send(Msg::Req(req)).is_err() {
                        return;
                    }
                }
                let _ = fwd_tx.send(Msg::Eof);
            });
            // Workers: private scratch each, shared job queue, panics
            // contained per batch.
            for w in 0..workers {
                let done_tx = msg_tx.clone();
                let job_rx = &job_rx;
                let exec_lock = &exec_locks[w % sessions];
                let restart_budget = &restart_budget;
                scope.spawn(move || {
                    let mut sc: Option<ServeScratch> = None;
                    loop {
                        let job = match lock_clean(job_rx).recv() {
                            Ok(j) => j,
                            Err(_) => return, // coordinator done
                        };
                        metrics.queue_us.record(job.t_disp.elapsed());
                        let scratch = sc.get_or_insert_with(|| engine.make_scratch());
                        match execute_batch(
                            engine,
                            scratch,
                            job.seq,
                            &job.seeds,
                            exec_lock,
                            metrics,
                            faults,
                            max_retries,
                            retry_backoff,
                        ) {
                            BatchExec::Completed { gen, rows } => {
                                // A fatal failure taints the scratch
                                // that produced it; transient-budget
                                // exhaustion does not.
                                let fatal = matches!(&rows, Err(ServeError::Fatal(_)));
                                if done_tx.send(Msg::Done { seq: job.seq, gen, rows }).is_err() {
                                    return;
                                }
                                if fatal {
                                    sc = None;
                                    metrics.record_restart();
                                    if restart_budget.fetch_sub(1, Ordering::AcqRel) <= 0 {
                                        let _ = done_tx.send(Msg::WorkerExit);
                                        return;
                                    }
                                }
                            }
                            BatchExec::Panicked => {
                                sc = None;
                                metrics.record_restart();
                                if done_tx.send(Msg::WorkerDied { seq: job.seq }).is_err() {
                                    return;
                                }
                                if restart_budget.fetch_sub(1, Ordering::AcqRel) <= 0 {
                                    let _ = done_tx.send(Msg::WorkerExit);
                                    return;
                                }
                            }
                        }
                    }
                });
            }
            drop(msg_tx); // the coordinator only receives

            // ---- coordinator --------------------------------------
            let mut in_flight: FxHashMap<u64, (u64, usize)> = FxHashMap::default();
            let mut batches: FxHashMap<u64, PendingBatch> = FxHashMap::default();
            let mut reorder: BTreeMap<u64, (u64, Result<Vec<f32>, ServeError>)> = BTreeMap::new();
            let mut forming_seeds: Vec<(u32, u32)> = Vec::new();
            let mut forming_waiters: Vec<(usize, ServeRequest)> = Vec::new();
            let mut backlog: VecDeque<Job> = VecDeque::new();
            let mut deadline: Option<Instant> = None;
            let mut next_seq: u64 = 0; // next batch to dispatch
            let mut next_apply: u64 = 0; // next completion to apply
            let mut eof = false;
            let mut live = workers; // workers still serving the queue
            let mut pending: usize = 0; // admitted, unanswered requests
            let mut co_sc: Option<ServeScratch> = None; // degraded-mode scratch

            // Non-blocking backlog flush: move parked jobs into the
            // queue while there are workers to drain it and slots to
            // take them.
            macro_rules! flush_backlog {
                () => {{
                    while live > 0 {
                        let Some(job) = backlog.pop_front() else { break };
                        match job_tx.try_send(job) {
                            Ok(()) => {}
                            Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => {
                                backlog.push_front(j);
                                break;
                            }
                        }
                    }
                }};
            }

            // Apply one completion (and everything it unblocks) in
            // dispatch order, answering waiters with rows, typed
            // errors, or deadline rejections.
            macro_rules! apply_done {
                ($seq:expr, $gen:expr, $rows:expr) => {{
                    if batches.contains_key(&$seq) {
                        reorder.insert($seq, ($gen, $rows));
                    }
                    while let Some((gen, rows)) = reorder.remove(&next_apply) {
                        let seq = next_apply;
                        next_apply += 1;
                        let Some(PendingBatch { seeds, waiters }) = batches.remove(&seq) else {
                            continue;
                        };
                        crate::event!("serve.batch.reply", seq = seq, waiters = waiters.len());
                        for &(nt, id) in &seeds {
                            in_flight.remove(&cache_key(nt, id));
                        }
                        match rows {
                            Ok(rows) => {
                                {
                                    // Stripe-at-a-time insertion: each
                                    // row locks only the shard that
                                    // owns its key.
                                    let now_gen = engine.generation();
                                    for (i, &(nt, id)) in seeds.iter().enumerate() {
                                        let key = cache_key(nt, id);
                                        let mut shard = cache.lock_key(key);
                                        shard.set_generation(now_gen);
                                        shard.put_if_current(
                                            key,
                                            &rows[i * c..(i + 1) * c],
                                            gen,
                                        );
                                    }
                                }
                                for (slot, req) in waiters {
                                    pending = pending.saturating_sub(1);
                                    let waited = req.t_enq.elapsed();
                                    if !request_deadline.is_zero() && waited > request_deadline {
                                        metrics.record_deadline_miss();
                                        let _ = req.reply.send(Err(
                                            ServeError::DeadlineExceeded {
                                                waited_ms: waited.as_millis() as u64,
                                            },
                                        ));
                                        continue;
                                    }
                                    metrics.latency.record(waited);
                                    let _ = req
                                        .reply
                                        .send(Ok(rows[slot * c..(slot + 1) * c].to_vec()));
                                }
                            }
                            Err(se) => {
                                // The batch failed for good: its
                                // waiters get the typed error, the
                                // pool keeps serving everyone else.
                                for (_, req) in waiters {
                                    pending = pending.saturating_sub(1);
                                    let _ = req.reply.send(Err(se.clone()));
                                }
                            }
                        }
                        flush_backlog!();
                    }
                }};
            }

            // Degraded mode: no live workers — drain parked and
            // already-queued jobs and execute them inline on the
            // coordinator's own scratch.  Inline panics get the same
            // supervision treatment (bounded, then the batch fails).
            macro_rules! pump_degraded {
                () => {{
                    loop {
                        let job = match lock_clean(&job_rx).try_recv() {
                            Ok(j) => Some(j),
                            Err(_) => None,
                        }
                        .or_else(|| backlog.pop_front());
                        let Some(job) = job else { break };
                        metrics.queue_us.record(job.t_disp.elapsed());
                        let mut inline_panics = 0usize;
                        let (gen, rows) = loop {
                            let sc = co_sc.get_or_insert_with(|| engine.make_scratch());
                            match execute_batch(
                                engine,
                                sc,
                                job.seq,
                                &job.seeds,
                                &exec_locks[0],
                                metrics,
                                faults,
                                max_retries,
                                retry_backoff,
                            ) {
                                BatchExec::Completed { gen, rows } => break (gen, rows),
                                BatchExec::Panicked => {
                                    metrics.record_restart();
                                    co_sc = None;
                                    inline_panics += 1;
                                    if inline_panics > 2 {
                                        break (
                                            engine.generation(),
                                            Err(ServeError::fatal(
                                                "degraded-mode inline execution \
                                                 panicked repeatedly",
                                            )),
                                        );
                                    }
                                }
                            }
                        };
                        apply_done!(job.seq, gen, rows);
                    }
                }};
            }

            // Hand a job to the workers — or straight to the inline
            // path once none remain.  Never blocks: a full queue
            // parks the job in the backlog.
            macro_rules! enqueue {
                ($job:expr) => {{
                    backlog.push_back($job);
                    flush_backlog!();
                    if live == 0 {
                        pump_degraded!();
                    }
                }};
            }

            // Cut the forming batch over to the workers.
            macro_rules! dispatch {
                () => {{
                    let seq = next_seq;
                    next_seq += 1;
                    let seeds = std::mem::take(&mut forming_seeds);
                    let waiters = std::mem::take(&mut forming_waiters);
                    deadline = None;
                    for (slot, &(nt, id)) in seeds.iter().enumerate() {
                        in_flight.insert(cache_key(nt, id), (seq, slot));
                    }
                    let job_seeds = seeds.clone();
                    crate::event!("serve.batch.dispatch", seq = seq, rows = job_seeds.len());
                    batches.insert(seq, PendingBatch { seeds, waiters });
                    enqueue!(Job { seq, seeds: job_seeds, t_disp: Instant::now() }); // lint:allow(determinism): queue-latency stamp only
                }};
            }

            'serve: loop {
                if eof && forming_seeds.is_empty() && next_apply == next_seq {
                    break;
                }
                let msg = if let Some(dl) = deadline {
                    let now = Instant::now(); // lint:allow(determinism): deadline pacing; batch content is seq-deterministic
                    if now >= dl {
                        None
                    } else {
                        match msg_rx.recv_timeout(dl - now) {
                            Ok(m) => Some(m),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => break 'serve,
                        }
                    }
                } else {
                    match msg_rx.recv() {
                        Ok(m) => Some(m),
                        Err(_) => break 'serve,
                    }
                };
                match msg {
                    // Deadline fired: flush the partial batch.
                    None => dispatch!(),
                    Some(Msg::Req(req)) => {
                        let waited = req.t_enq.elapsed();
                        if !request_deadline.is_zero() && waited > request_deadline {
                            // Expired in the queue: reject before
                            // spending any compute on it.
                            metrics.record_deadline_miss();
                            let _ = req.reply.send(Err(ServeError::DeadlineExceeded {
                                waited_ms: waited.as_millis() as u64,
                            }));
                            continue;
                        }
                        let key = cache_key(req.nt, req.id);
                        let hit = {
                            let mut shard = cache.lock_key(key);
                            shard.set_generation(engine.generation());
                            shard.get(key).map(|row| row.to_vec())
                        };
                        if let Some(val) = hit {
                            metrics.record_hit();
                            metrics.latency.record(req.t_enq.elapsed());
                            let _ = req.reply.send(Ok(val));
                        } else if self.cfg.queue_depth > 0 && pending >= self.cfg.queue_depth {
                            // Queue boundary: admitting more than
                            // `queue_depth` unanswered requests only
                            // builds latency — shed instead.
                            metrics.record_shed();
                            let _ = req.reply.send(Err(ServeError::Overloaded { depth: pending }));
                        } else if let Some(&(seq, slot)) = in_flight.get(&key) {
                            // Already being computed: join that batch.
                            metrics.record_coalesced();
                            match batches.get_mut(&seq) {
                                Some(b) => {
                                    pending += 1;
                                    b.waiters.push((slot, req));
                                }
                                None => {
                                    // Unreachable by construction
                                    // (in-flight keys point at live
                                    // batches); answer rather than
                                    // hang if it ever isn't.
                                    let _ = req.reply.send(Err(ServeError::Canceled(
                                        "in-flight batch vanished".into(),
                                    )));
                                }
                            }
                        } else if let Some(slot) =
                            forming_seeds.iter().position(|&s| s == (req.nt, req.id))
                        {
                            metrics.record_coalesced();
                            pending += 1;
                            forming_waiters.push((slot, req));
                        } else {
                            metrics.record_miss();
                            pending += 1;
                            let slot = forming_seeds.len();
                            forming_seeds.push((req.nt, req.id));
                            forming_waiters.push((slot, req));
                            if forming_seeds.len() == 1 {
                                deadline = Some(Instant::now() + self.cfg.batcher.deadline); // lint:allow(determinism): deadline pacing; batch content is seq-deterministic
                            }
                            if forming_seeds.len() >= cap {
                                dispatch!();
                            }
                        }
                    }
                    Some(Msg::Done { seq, gen, rows }) => {
                        apply_done!(seq, gen, rows);
                    }
                    Some(Msg::WorkerDied { seq }) => {
                        // The batch never completed; hand it to
                        // another worker (or the inline path).  Seeds
                        // live in the pending table, so nothing was
                        // lost with the worker.
                        if let Some(b) = batches.get(&seq) {
                            enqueue!(Job { seq, seeds: b.seeds.clone(), t_disp: Instant::now() }); // lint:allow(determinism): queue-latency stamp only
                        }
                    }
                    Some(Msg::WorkerExit) => {
                        live = live.saturating_sub(1);
                        if live == 0 {
                            // Jobs parked in the backlog or sitting
                            // unclaimed in the queue now have no
                            // consumer: run them inline.
                            pump_degraded!();
                        }
                    }
                    Some(Msg::Eof) => {
                        eof = true;
                        if !forming_seeds.is_empty() {
                            dispatch!();
                        }
                    }
                }
            }
            // Dropping the job queue releases the workers.  Dropping
            // msg_rx discards any queued requests (their reply senders
            // drop, erroring the waiting clients) and fails the
            // forwarder's next send.  Waiters still tracked get a
            // typed cancellation instead of a silent hangup.
            drop(job_tx);
            drop(msg_rx);
            for (_, b) in batches.drain() {
                for (_, req) in b.waiters {
                    let _ = req.reply.send(Err(ServeError::Canceled("pool shut down".into())));
                }
            }
            for (_, req) in forming_waiters.drain(..) {
                let _ = req.reply.send(Err(ServeError::Canceled("pool shut down".into())));
            }
            Ok(())
        })
    }
}

/// Drive `trace` through an engine pool from `clients` closed-loop
/// client threads (each waits for its reply before sending the next
/// request).  Returns the stats plus every `(seed, prediction)` reply
/// in completion order, for determinism / bit-identity checks.
///
/// Typed rejections (shed, deadline-missed) are counted in the stats
/// and skipped in the reply list; computation failures abort.
pub fn closed_loop(
    engine: &InferenceEngine,
    cfg: EnginePoolCfg,
    cache: &ShardedCache,
    trace: &[(u32, u32)],
    clients: usize,
) -> Result<(ClosedLoopStats, Vec<((u32, u32), Vec<f32>)>)> {
    closed_loop_with_faults(engine, cfg, cache, trace, clients, None)
}

/// [`closed_loop`] under an optional deterministic [`FaultPlan`].
pub fn closed_loop_with_faults(
    engine: &InferenceEngine,
    cfg: EnginePoolCfg,
    cache: &ShardedCache,
    trace: &[(u32, u32)],
    clients: usize,
    faults: Option<&FaultPlan>,
) -> Result<(ClosedLoopStats, Vec<((u32, u32), Vec<f32>)>)> {
    let metrics = ServeMetrics::new();
    let sessions = cfg.sessions.clamp(1, cfg.workers.max(1));
    let pool = EnginePool::new(cfg);
    let (tx, rx) = std::sync::mpsc::sync_channel::<ServeRequest>(4096);
    let clients = clients.max(1);
    let t0 = Instant::now(); // lint:allow(determinism): bench wall-clock only
    let mut replies: Vec<((u32, u32), Vec<f32>)> = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    std::thread::scope(|scope| {
        let pool_handle = {
            let metrics = &metrics;
            scope.spawn(move || pool.run_with_faults(engine, cache, rx, metrics, faults))
        };
        let mut client_handles = Vec::with_capacity(clients);
        for w in 0..clients {
            let tx: SyncSender<ServeRequest> = tx.clone();
            let share: Vec<(u32, u32)> = trace.iter().skip(w).step_by(clients).copied().collect();
            client_handles.push(scope.spawn(move || -> Result<Vec<((u32, u32), Vec<f32>)>> {
                let mut out = Vec::with_capacity(share.len());
                for (nt, id) in share {
                    let (rtx, rrx): (Sender<_>, Receiver<_>) = channel();
                    tx.send(ServeRequest::new(nt, id, rtx))
                        .map_err(|_| anyhow!("engine pool exited early"))?;
                    match rrx.recv() {
                        Err(_) => return Err(anyhow!("reply channel dropped")),
                        Ok(Ok(val)) => out.push(((nt, id), val)),
                        // Typed rejections are expected under
                        // overload/deadline pressure: the metrics
                        // count them, the client moves on.
                        Ok(Err(e)) if e.is_rejection() => {}
                        Ok(Err(e)) => return Err(anyhow!("serve error: {e}")),
                    }
                }
                Ok(out)
            }));
        }
        drop(tx); // the pool drains and exits once the clients are done
        for h in client_handles {
            match h.join() {
                Ok(Ok(r)) => replies.extend(r),
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert_with(|| anyhow!("client thread panicked"));
                }
            }
        }
        match pool_handle.join() {
            Ok(Err(e)) => {
                first_err.get_or_insert(e);
            }
            Ok(Ok(())) => {}
            Err(_) => {
                first_err.get_or_insert_with(|| anyhow!("pool thread panicked"));
            }
        }
    });
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let stats = ClosedLoopStats {
        requests: trace.len(),
        wall_s,
        rps: trace.len() as f64 / wall_s.max(1e-9),
        p50_us: metrics.latency.p50_us(),
        p99_us: metrics.latency.p99_us(),
        hit_rate: metrics.hit_rate(),
        hits: metrics.hits(),
        misses: metrics.misses(),
        coalesced: metrics.coalesced(),
        restarts: metrics.restarts(),
        retries: metrics.retries(),
        shed: metrics.shed(),
        deadline_misses: metrics.deadline_misses(),
    };
    // Pool-internal profile → global registry (`gs stats`): batch
    // count plus the dispatch→dequeue and execute stage percentiles.
    // Each closed-loop run overwrites these, so after `serve-bench`
    // they describe the last arm.
    crate::obs::metrics::counter_set("serve.pool.batches", metrics.batches());
    crate::obs::metrics::gauge_set("serve.pool.queue_p50_us", metrics.queue_us.p50_us());
    crate::obs::metrics::gauge_set("serve.pool.queue_p99_us", metrics.queue_us.p99_us());
    crate::obs::metrics::gauge_set("serve.pool.exec_p50_us", metrics.exec_us.p50_us());
    crate::obs::metrics::gauge_set("serve.pool.exec_p99_us", metrics.exec_us.p99_us());
    // Sharding topology of this run — aggregated, shard-count-stable
    // names (the per-arm serve counters above already aggregate over
    // shards by construction: the coordinator counts them).
    crate::obs::metrics::gauge_set("serve.pool.sessions", sessions as f64);
    crate::obs::metrics::gauge_set("serve.cache.shard.count", cache.num_shards() as f64);
    crate::obs::metrics::gauge_set("serve.cache.shard.entries", cache.len() as f64);
    Ok((stats, replies))
}
