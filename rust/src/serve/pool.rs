//! The serving engine *pool*: N [`InferenceEngine`] scratches drain
//! one shared micro-batcher queue.
//!
//! PR 2's `MicroBatcher::run` answers the queue with a single engine
//! scratch — one core against millions-of-users traffic.  The pool
//! keeps the same batching policy ([`MicroBatcherCfg`]) but splits the
//! work across scoped threads, the same worker/consumer shape as
//! `dataloader::run_pipeline`:
//!
//! ```text
//! clients ─▶ request queue ─▶ coordinator ─▶ job queue ─▶ worker 0..N
//!                                 ▲   (owns cache + batching policy)     │
//!                                 └────────── completions ◀──────────────┘
//! ```
//!
//! * The **coordinator** is the only thread that touches the cache and
//!   the batching state: it answers hits on arrival, coalesces
//!   duplicate in-flight keys, cuts size/deadline-bounded batches of
//!   distinct misses and hands them to the job queue.
//! * **Workers** each own a private [`ServeScratch`] and run the full
//!   sample → assemble → execute path per batch.  With a PJRT backend
//!   the execute step is serialized through one `Mutex`
//!   ([`InferenceEngine::forward_locked`]) so a single session never
//!   runs concurrently; the deterministic surrogate executes
//!   lock-free.
//! * Completions are applied to the cache **in dispatch order** (a
//!   reorder buffer holds early finishers), so the cache's content
//!   evolves identically for any pool size.
//!
//! Determinism contract (the pooled extension of PR 1's per-batch RNG
//! invariant): because the engine samples canonically per node, every
//! reply is bit-identical for any pool size, any batch composition and
//! any worker interleaving.  Hit/miss *accounting* is also pool-size
//! invariant whenever the cache doesn't evict (capacity ≥ working set)
//! and the request order is fixed: a request misses iff its key was
//! never requested before, because keys move atomically from forming
//! batch → in-flight → cache under the coordinator.  Requests that
//! find their key in flight are counted as hits (and additionally as
//! `coalesced`); the hit/coalesced *split* depends on completion
//! timing, the hit+miss totals do not.  `tests/serve.rs`
//! (`pool_sizes_are_bit_identical`) drains one stream through pools of
//! 1, 2 and 8 and asserts identical replies and identical counters.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Mutex;
use std::time::Instant;

use super::batcher::{ClosedLoopStats, MicroBatcherCfg, ServeRequest};
use super::cache::{cache_key, EmbeddingCache};
use super::engine::InferenceEngine;
use super::ServeMetrics;
use crate::util::FxHashMap;

/// Engine-pool knobs: worker count plus the shared batching policy.
/// `serve.pool_workers` resolves `"auto"` before this struct exists.
#[derive(Debug, Clone)]
pub struct EnginePoolCfg {
    /// Engine scratches draining the queue (≥ 1).
    pub workers: usize,
    pub batcher: MicroBatcherCfg,
}

impl Default for EnginePoolCfg {
    fn default() -> Self {
        EnginePoolCfg { workers: 1, batcher: MicroBatcherCfg::default() }
    }
}

/// One dispatched micro-batch: distinct miss seeds, identified by a
/// dense sequence number.
struct Job {
    seq: u64,
    seeds: Vec<(u32, u32)>,
}

/// What flows into the coordinator: forwarded client requests, worker
/// completions, and the end-of-stream marker from the forwarder.
enum Msg {
    Req(ServeRequest),
    Done {
        seq: u64,
        /// Engine generation observed *before* the forward ran; rows
        /// are cached only if this is still current at apply time.
        gen: u64,
        rows: Result<Vec<f32>, String>,
    },
    Eof,
}

/// A dispatched batch the coordinator is still tracking: its seed list
/// (for cache insertion) and every request waiting on it.
struct PendingBatch {
    seeds: Vec<(u32, u32)>,
    waiters: Vec<(usize, ServeRequest)>,
}

pub struct EnginePool {
    pub cfg: EnginePoolCfg,
}

impl EnginePool {
    pub fn new(cfg: EnginePoolCfg) -> EnginePool {
        EnginePool { cfg }
    }

    /// Blocking serve loop: drains `rx` until every request sender has
    /// been dropped and every dispatched batch has been applied.
    /// `cache` is shared behind a `Mutex` so a background refresher
    /// (`serve::refresh`) can re-warm it concurrently.
    pub fn run(
        &self,
        engine: &InferenceEngine,
        cache: &Mutex<EmbeddingCache>,
        rx: Receiver<ServeRequest>,
        metrics: &ServeMetrics,
    ) -> Result<()> {
        let workers = self.cfg.workers.max(1);
        let cap = self.cfg.batcher.max_batch.min(engine.capacity()).max(1);
        let c = engine.out_dim();
        let exec_lock = Mutex::new(());
        let (msg_tx, msg_rx) = channel::<Msg>();
        let (job_tx, job_rx) = std::sync::mpsc::sync_channel::<Job>(workers * 2);
        let job_rx = Mutex::new(job_rx);

        std::thread::scope(|scope| -> Result<()> {
            // Forwarder: client requests → merged coordinator queue.
            let fwd_tx = msg_tx.clone();
            scope.spawn(move || {
                for req in rx.iter() {
                    if fwd_tx.send(Msg::Req(req)).is_err() {
                        return;
                    }
                }
                let _ = fwd_tx.send(Msg::Eof);
            });
            // Workers: private scratch each, shared job queue.
            for _ in 0..workers {
                let done_tx = msg_tx.clone();
                let job_rx = &job_rx;
                let exec_lock = &exec_lock;
                scope.spawn(move || {
                    let mut sc = engine.make_scratch();
                    loop {
                        let job = match job_rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => return, // coordinator done
                        };
                        let gen = engine.generation();
                        let rows = engine
                            .forward_locked(&mut sc, &job.seeds, exec_lock)
                            .map(|r| r.to_vec())
                            .map_err(|e| e.to_string());
                        if done_tx.send(Msg::Done { seq: job.seq, gen, rows }).is_err() {
                            return;
                        }
                    }
                });
            }
            drop(msg_tx); // the coordinator only receives

            // ---- coordinator --------------------------------------
            let mut in_flight: FxHashMap<u64, (u64, usize)> = FxHashMap::default();
            let mut batches: FxHashMap<u64, PendingBatch> = FxHashMap::default();
            let mut reorder: BTreeMap<u64, (u64, Result<Vec<f32>, String>)> = BTreeMap::new();
            let mut forming_seeds: Vec<(u32, u32)> = Vec::new();
            let mut forming_waiters: Vec<(usize, ServeRequest)> = Vec::new();
            let mut deadline: Option<Instant> = None;
            let mut next_seq: u64 = 0; // next batch to dispatch
            let mut next_apply: u64 = 0; // next completion to apply
            let mut eof = false;
            let mut first_err: Option<anyhow::Error> = None;

            // Cut the forming batch over to the workers.
            macro_rules! dispatch {
                () => {{
                    let seq = next_seq;
                    next_seq += 1;
                    let seeds = std::mem::take(&mut forming_seeds);
                    let waiters = std::mem::take(&mut forming_waiters);
                    deadline = None;
                    for (slot, &(nt, id)) in seeds.iter().enumerate() {
                        in_flight.insert(cache_key(nt, id), (seq, slot));
                    }
                    let job_seeds = seeds.clone();
                    batches.insert(seq, PendingBatch { seeds, waiters });
                    if job_tx.send(Job { seq, seeds: job_seeds }).is_err() {
                        first_err
                            .get_or_insert_with(|| anyhow!("engine-pool workers exited early"));
                    }
                }};
            }

            'serve: loop {
                if first_err.is_some() || (eof && forming_seeds.is_empty() && next_apply == next_seq)
                {
                    break;
                }
                let msg = if let Some(dl) = deadline {
                    let now = Instant::now();
                    if now >= dl {
                        None
                    } else {
                        match msg_rx.recv_timeout(dl - now) {
                            Ok(m) => Some(m),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => break 'serve,
                        }
                    }
                } else {
                    match msg_rx.recv() {
                        Ok(m) => Some(m),
                        Err(_) => break 'serve,
                    }
                };
                match msg {
                    // Deadline fired: flush the partial batch.
                    None => dispatch!(),
                    Some(Msg::Req(req)) => {
                        let key = cache_key(req.nt, req.id);
                        let hit = {
                            let mut cache = cache.lock().unwrap();
                            cache.set_generation(engine.generation());
                            cache.get(key).map(|row| row.to_vec())
                        };
                        if let Some(val) = hit {
                            metrics.record_hit();
                            metrics.latency.record(req.t_enq.elapsed());
                            let _ = req.reply.send(Ok(val));
                        } else if let Some(&(seq, slot)) = in_flight.get(&key) {
                            // Already being computed: join that batch.
                            metrics.record_coalesced();
                            batches
                                .get_mut(&seq)
                                .expect("in-flight key points at a live batch")
                                .waiters
                                .push((slot, req));
                        } else if let Some(slot) =
                            forming_seeds.iter().position(|&s| s == (req.nt, req.id))
                        {
                            metrics.record_coalesced();
                            forming_waiters.push((slot, req));
                        } else {
                            metrics.record_miss();
                            let slot = forming_seeds.len();
                            forming_seeds.push((req.nt, req.id));
                            forming_waiters.push((slot, req));
                            if forming_seeds.len() == 1 {
                                deadline = Some(Instant::now() + self.cfg.batcher.deadline);
                            }
                            if forming_seeds.len() >= cap {
                                dispatch!();
                            }
                        }
                    }
                    Some(Msg::Done { seq, gen, rows }) => {
                        reorder.insert(seq, (gen, rows));
                        // Apply strictly in dispatch order so cache
                        // content is pool-size invariant.
                        while let Some((gen, rows)) = reorder.remove(&next_apply) {
                            let seq = next_apply;
                            next_apply += 1;
                            let PendingBatch { seeds, waiters } =
                                batches.remove(&seq).expect("completion for a live batch");
                            for &(nt, id) in &seeds {
                                in_flight.remove(&cache_key(nt, id));
                            }
                            match rows {
                                Ok(rows) => {
                                    {
                                        let mut cache = cache.lock().unwrap();
                                        cache.set_generation(engine.generation());
                                        for (i, &(nt, id)) in seeds.iter().enumerate() {
                                            cache.put_if_current(
                                                cache_key(nt, id),
                                                &rows[i * c..(i + 1) * c],
                                                gen,
                                            );
                                        }
                                    }
                                    for (slot, req) in waiters {
                                        metrics.latency.record(req.t_enq.elapsed());
                                        let _ = req
                                            .reply
                                            .send(Ok(rows[slot * c..(slot + 1) * c].to_vec()));
                                    }
                                }
                                Err(msg) => {
                                    for (_, req) in waiters {
                                        let _ = req.reply.send(Err(msg.clone()));
                                    }
                                    first_err.get_or_insert_with(|| anyhow!("{msg}"));
                                }
                            }
                        }
                    }
                    Some(Msg::Eof) => {
                        eof = true;
                        if !forming_seeds.is_empty() {
                            dispatch!();
                        }
                    }
                }
            }
            // Dropping the job queue releases the workers.  Dropping
            // msg_rx discards any queued requests (their reply senders
            // drop, erroring the waiting clients) and fails the
            // forwarder's next send — without this, an early error
            // exit would strand clients whose requests sit unread in
            // the merged queue.  Outstanding batch waiters drop with
            // `batches`.
            drop(job_tx);
            drop(msg_rx);
            match first_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })
    }
}

/// Drive `trace` through an engine pool from `clients` closed-loop
/// client threads (each waits for its reply before sending the next
/// request).  Returns the stats plus every `(seed, prediction)` reply
/// in completion order, for determinism / bit-identity checks.
pub fn closed_loop(
    engine: &InferenceEngine,
    cfg: EnginePoolCfg,
    cache: &Mutex<EmbeddingCache>,
    trace: &[(u32, u32)],
    clients: usize,
) -> Result<(ClosedLoopStats, Vec<((u32, u32), Vec<f32>)>)> {
    let metrics = ServeMetrics::new();
    let pool = EnginePool::new(cfg);
    let (tx, rx) = std::sync::mpsc::sync_channel::<ServeRequest>(4096);
    let clients = clients.max(1);
    let t0 = Instant::now();
    let mut replies: Vec<((u32, u32), Vec<f32>)> = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    std::thread::scope(|scope| {
        let pool_handle = {
            let metrics = &metrics;
            scope.spawn(move || pool.run(engine, cache, rx, metrics))
        };
        let mut client_handles = Vec::with_capacity(clients);
        for w in 0..clients {
            let tx: SyncSender<ServeRequest> = tx.clone();
            let share: Vec<(u32, u32)> = trace.iter().skip(w).step_by(clients).copied().collect();
            client_handles.push(scope.spawn(move || -> Result<Vec<((u32, u32), Vec<f32>)>> {
                let mut out = Vec::with_capacity(share.len());
                for (nt, id) in share {
                    let (rtx, rrx): (Sender<_>, Receiver<_>) = channel();
                    tx.send(ServeRequest::new(nt, id, rtx))
                        .map_err(|_| anyhow!("engine pool exited early"))?;
                    let val = rrx
                        .recv()
                        .map_err(|_| anyhow!("reply channel dropped"))?
                        .map_err(|e| anyhow!("serve error: {e}"))?;
                    out.push(((nt, id), val));
                }
                Ok(out)
            }));
        }
        drop(tx); // the pool drains and exits once the clients are done
        for h in client_handles {
            match h.join().expect("client thread panicked") {
                Ok(r) => replies.extend(r),
                Err(e) => {
                    first_err.get_or_insert(e);
                }
            }
        }
        if let Err(e) = pool_handle.join().expect("pool thread panicked") {
            first_err.get_or_insert(e);
        }
    });
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let stats = ClosedLoopStats {
        requests: trace.len(),
        wall_s,
        rps: trace.len() as f64 / wall_s.max(1e-9),
        p50_us: metrics.latency.p50_us(),
        p99_us: metrics.latency.p99_us(),
        hit_rate: metrics.hit_rate(),
        hits: metrics.hits(),
        misses: metrics.misses(),
        coalesced: metrics.coalesced(),
    };
    Ok((stats, replies))
}
