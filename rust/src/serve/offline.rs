//! Offline full-graph inference: stream every node of a type through
//! the prefetch pipeline and write sharded GSTF prediction/embedding
//! files — the GiGL-style precompute the online cache warms from.
//!
//! Because the engine samples canonically per node, an offline shard
//! row is bit-identical to what the online path would compute for the
//! same node, so `EmbeddingCache::warm_from_dir` can preload hot nodes
//! without ever serving a stale prediction (as long as the engine
//! generation matches).

use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::dataloader::PrefetchConfig;
use crate::runtime::gstf::{read_gstf, tmp_path, write_gstf_atomic};
use crate::runtime::Tensor;
use crate::util::json::Json;

use super::cache::{cache_key, EmbeddingCache};
use super::engine::InferenceEngine;

/// Sharded full-node-set inference driver.
pub struct OfflineInference {
    /// Rows per output shard file.
    pub shard_size: usize,
    /// Pipelining knobs for block construction (`run_pipeline`).
    pub prefetch: PrefetchConfig,
}

impl Default for OfflineInference {
    fn default() -> Self {
        OfflineInference { shard_size: 4096, prefetch: PrefetchConfig::default() }
    }
}

#[derive(Debug, Clone, Default)]
pub struct OfflineReport {
    pub ntype: u32,
    pub rows: usize,
    pub dim: usize,
    pub shards: Vec<PathBuf>,
    pub secs: f64,
}

impl OfflineInference {
    /// Run inference over every node of `ntype`, writing
    /// `shard_NNNNN.gstf` files (`ids` i32 `[n]`, `emb` f32 `[n, dim]`)
    /// into `out_dir`.
    pub fn run(
        &self,
        engine: &InferenceEngine,
        ntype: u32,
        out_dir: &Path,
    ) -> Result<OfflineReport> {
        let t0 = std::time::Instant::now(); // lint:allow(determinism): stage wall-time for the report only
        std::fs::create_dir_all(out_dir)
            .with_context(|| format!("create {}", out_dir.display()))?;
        sweep_stale_tmp(out_dir)?;
        let n = engine.ds.graph.num_nodes[ntype as usize];
        let c = engine.out_dim();
        let b = engine.capacity();
        let ids: Vec<u32> = (0..n as u32).collect();
        let chunks: Vec<&[u32]> = ids.chunks(b).collect();

        let mut report = OfflineReport { ntype, dim: c, ..Default::default() };
        let mut shard_ids: Vec<i32> = Vec::with_capacity(self.shard_size);
        let mut shard_emb: Vec<f32> = Vec::with_capacity(self.shard_size * c);

        // Sampling + assembly pipelines across workers; backend
        // execution and shard writing stay on this thread, in node
        // order — the same worker/consumer split the trainers use, so
        // a single PJRT session never executes concurrently.
        let mut exec_sc = engine.make_scratch();
        crate::dataloader::run_pipeline(
            &chunks,
            &self.prefetch,
            || crate::dataloader::BatchFactory::new(engine.ds, &engine.shape),
            |f, _bi, chunk| {
                let seeds: Vec<(u32, u32)> = chunk.iter().map(|&i| (ntype, i)).collect();
                let mut batch = Vec::new();
                let mut touch = crate::dataloader::LembTouch::new();
                f.sample_assemble_canonical_into(
                    &seeds,
                    &engine.shape,
                    &engine.spec,
                    engine.sample_seed,
                    0,
                    &mut batch,
                    &mut touch,
                )?;
                // Only the surrogate backend reads the block; skip the
                // per-batch clone when PJRT executes.
                let block = engine.needs_block().then(|| f.block.clone());
                Ok((seeds, batch, block))
            },
            |_bi, (seeds, batch, block)| {
                let rows =
                    engine.execute_block(&mut exec_sc, block.as_ref(), &batch, seeds.len())?;
                for (i, &(_, id)) in seeds.iter().enumerate() {
                    shard_ids.push(id as i32);
                    shard_emb.extend_from_slice(&rows[i * c..(i + 1) * c]);
                    if shard_ids.len() >= self.shard_size {
                        flush_shard(out_dir, &mut report, &mut shard_ids, &mut shard_emb, c)?;
                    }
                }
                report.rows += seeds.len();
                Ok(())
            },
        )?;
        if !shard_ids.is_empty() {
            flush_shard(out_dir, &mut report, &mut shard_ids, &mut shard_emb, c)?;
        }
        // Manifest last: its presence certifies that every shard it
        // names was fully written and renamed into place.  A crash
        // anywhere above leaves either no manifest or the previous
        // run's (whose shards are intact — shards are themselves
        // atomic), never a manifest naming a torn file.
        write_manifest(out_dir, &report)?;
        report.secs = t0.elapsed().as_secs_f64();
        Ok(report)
    }
}

/// Remove `*.tmp` staging orphans left by a crashed writer so a re-run
/// starts from renamed-only state.  Never touches completed shards.
fn sweep_stale_tmp(dir: &Path) -> Result<()> {
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))?
    {
        let p = entry?.path();
        let is_tmp = p
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.ends_with(".tmp"))
            .unwrap_or(false);
        if is_tmp {
            std::fs::remove_file(&p)
                .with_context(|| format!("sweep stale {}", p.display()))?;
        }
    }
    Ok(())
}

/// Write `manifest.json` (atomically: tmp + fsync + rename) naming the
/// completed shards in order.
fn write_manifest(dir: &Path, report: &OfflineReport) -> Result<()> {
    let names: Vec<String> = report
        .shards
        .iter()
        .filter_map(|p| p.file_name().and_then(|n| n.to_str()).map(String::from))
        .collect();
    let shards_json: Vec<String> = names.iter().map(|n| format!("\"{n}\"")).collect();
    let text = format!(
        "{{\n  \"ntype\": {},\n  \"rows\": {},\n  \"dim\": {},\n  \"shards\": [{}]\n}}\n",
        report.ntype,
        report.rows,
        report.dim,
        shards_json.join(", ")
    );
    let path = dir.join("manifest.json");
    let tmp = tmp_path(&path);
    let res = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(text.as_bytes())?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
        Ok(())
    })();
    if let Err(e) = res {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))
}

fn flush_shard(
    out_dir: &Path,
    report: &mut OfflineReport,
    ids: &mut Vec<i32>,
    emb: &mut Vec<f32>,
    dim: usize,
) -> Result<()> {
    let path = out_dir.join(format!("shard_{:05}.gstf", report.shards.len()));
    let n = ids.len();
    write_gstf_atomic(
        &path,
        &[
            ("ids".to_string(), Tensor::I32 { shape: vec![n], data: std::mem::take(ids) }),
            ("emb".to_string(), Tensor::F32 { shape: vec![n, dim], data: std::mem::take(emb) }),
        ],
    )?;
    report.shards.push(path);
    Ok(())
}

/// Read back every shard in `dir`, returning `(id, row)` pairs — the
/// round-trip reader tests and cache warming share.
///
/// When `manifest.json` is present (written last by
/// [`OfflineInference::run`]), its shard list is authoritative: a
/// crash between shard writes and the manifest write is detected as a
/// missing-manifest dir, and files from a newer partial re-run are
/// never mixed with an older complete set.  Directories without a
/// manifest (pre-manifest writers, hand-assembled fixtures) fall back
/// to a `shard_*.gstf` glob.
pub fn read_shards(dir: &Path, ntype: u32) -> Result<Vec<((u32, u32), Vec<f32>)>> {
    let manifest = dir.join("manifest.json");
    let mut files: Vec<PathBuf> = if manifest.exists() {
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {}", manifest.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parse {}", manifest.display()))?;
        let arr = j
            .get("shards")
            .and_then(|s| s.as_arr())
            .with_context(|| format!("{}: no shards array", manifest.display()))?;
        let mut v = Vec::with_capacity(arr.len());
        for s in arr {
            let name = s
                .as_str()
                .with_context(|| format!("{}: non-string shard entry", manifest.display()))?;
            let p = dir.join(name);
            if !p.exists() {
                bail!("{}: manifest names missing shard {}", dir.display(), name);
            }
            v.push(p);
        }
        v
    } else {
        std::fs::read_dir(dir)
            .with_context(|| format!("read {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("shard_") && n.ends_with(".gstf"))
                    .unwrap_or(false)
            })
            .collect()
    };
    files.sort();
    let mut out = vec![];
    for f in files {
        let tensors = read_gstf(&f)?;
        let ids = tensors
            .iter()
            .find(|(n, _)| n.as_str() == "ids")
            .with_context(|| format!("{}: no ids tensor", f.display()))?;
        let emb = tensors
            .iter()
            .find(|(n, _)| n.as_str() == "emb")
            .with_context(|| format!("{}: no emb tensor", f.display()))?;
        let Tensor::I32 { data: ids, .. } = &ids.1 else { bail!("ids must be i32") };
        let Tensor::F32 { shape, data } = &emb.1 else { bail!("emb must be f32") };
        let dim = shape[1];
        if ids.len() * dim != data.len() {
            bail!("{}: ids/emb length mismatch", f.display());
        }
        for (i, &id) in ids.iter().enumerate() {
            out.push(((ntype, id as u32), data[i * dim..(i + 1) * dim].to_vec()));
        }
    }
    Ok(out)
}

impl EmbeddingCache {
    /// Warm the cache from offline shards written by
    /// [`OfflineInference::run`].  `generation` must be the engine
    /// generation the shards were computed at; rows are inserted in
    /// file order, so with a bounded cache the *last* rows read stay
    /// resident — pass a capacity ≥ the hot set you want pinned.
    pub fn warm_from_dir(&mut self, dir: &Path, ntype: u32, generation: u64) -> Result<usize> {
        self.set_generation(generation);
        let rows = read_shards(dir, ntype)?;
        let n = rows.len();
        for ((nt, id), row) in rows {
            self.put(cache_key(nt, id), &row);
        }
        Ok(n)
    }
}

impl super::cache::ShardedCache {
    /// [`EmbeddingCache::warm_from_dir`] for a striped cache: same
    /// file-order insertion, but each row locks only the stripe that
    /// owns its key, so a pool can keep serving while the warm-up
    /// streams in.
    pub fn warm_from_dir(&self, dir: &Path, ntype: u32, generation: u64) -> Result<usize> {
        self.set_generation(generation);
        let rows = read_shards(dir, ntype)?;
        let n = rows.len();
        for ((nt, id), row) in rows {
            self.put(cache_key(nt, id), &row);
        }
        Ok(n)
    }
}
