//! Generation-stamped LRU embedding/prediction cache with an optional
//! TinyLFU-style admission gate.
//!
//! Serving traffic is power-law: a small set of hot nodes dominates
//! requests, so caching their decoded predictions (or embedding rows)
//! lets them skip K-hop sampling entirely.  Entries are stamped with a
//! generation; bumping the generation (model update, embedding-table
//! write) invalidates the whole cache in O(1) without touching any
//! entry.  Eviction reuses the evicted entry's row allocation, so a
//! full cache performs no steady-state allocation on `put` of
//! same-width rows.
//!
//! The admission gate ([`Admission::TinyLfu`]) protects the hot set
//! from Zipf-tail scan traffic: every lookup feeds a tiny
//! aged-count-min frequency sketch, and a *new* key may evict the LRU
//! victim only if its estimated frequency is at least the victim's —
//! a one-shot scan key loses that comparison against any genuinely
//! hot row, so a full cache of hot rows survives arbitrarily long
//! cold scans (see `tinylfu_admission_resists_scans`).
//!
//! [`ShardedCache`] stripes all of the above `serve.shards` ways by
//! the [`shard_of`] hash, removing the single cache mutex from the
//! serving hot path while keeping replies and hit/miss accounting
//! bit-identical for any shard count (docs/SERVING.md, sharding
//! section).

use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::error::lock_shard;
use crate::dist::EmbTable;
use crate::util::{fxhash64, FxHashMap};

/// Cache key for a `(ntype, node id)` pair.
#[inline]
pub fn cache_key(nt: u32, id: u32) -> u64 {
    ((nt as u64) << 32) | id as u64
}

/// Inverse of [`cache_key`].
#[inline]
pub fn split_key(key: u64) -> (u32, u32) {
    ((key >> 32) as u32, key as u32)
}

/// The stripe a key belongs to, out of `shards`.  One hash routes the
/// whole hot path: [`ShardedCache`] stripes by it, and
/// `dist::EmbTable` shards its rows by the same function, so a key's
/// cache stripe and a row's table shard are both pure functions of the
/// id — deterministic for any shard count.
#[inline]
pub fn shard_of(key: u64, shards: usize) -> usize {
    if shards <= 1 {
        0
    } else {
        (fxhash64(key) % shards as u64) as usize
    }
}

/// Admission policy for a full cache: plain LRU, or an LRU whose
/// evictions are gated by a frequency sketch (TinyLFU-style).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Admission {
    /// Every `put` of a new key evicts the LRU victim (classic LRU).
    #[default]
    Always,
    /// A new key is admitted only if its sketch frequency is at least
    /// the LRU victim's — scan traffic can't flush the hot set.
    TinyLfu,
}

impl Admission {
    pub fn name(self) -> &'static str {
        match self {
            Admission::Always => "always",
            Admission::TinyLfu => "tinylfu",
        }
    }
}

/// Aged count-min frequency sketch (4-bit counters, two probes per
/// key).  After `16 * capacity` touches every counter is halved, so
/// estimates decay and yesterday's hot set can't pin the cache
/// forever — the standard TinyLFU aging rule.
struct FreqSketch {
    counters: Vec<u8>,
    mask: usize,
    ops: u64,
    age_every: u64,
}

impl FreqSketch {
    fn new(cap: usize) -> FreqSketch {
        // 16 one-byte counters per cached row (~64 KiB at the default
        // serve.cache=4096).  Wider than classic nibble-packed TinyLFU
        // (4-8 counters/row) to keep probe collisions with the
        // resident set rare without bit-packing complexity; still a
        // fraction of the row payload it protects.
        let width = (cap.max(16) * 16).next_power_of_two();
        FreqSketch {
            counters: vec![0; width],
            mask: width - 1,
            ops: 0,
            age_every: (cap.max(16) as u64) * 16,
        }
    }

    #[inline]
    fn slot(&self, key: u64, probe: u64) -> usize {
        fxhash64(key ^ probe.wrapping_mul(0x9E37_79B9_7F4A_7C15)) as usize & self.mask
    }

    fn touch(&mut self, key: u64) {
        for p in 0..2u64 {
            let i = self.slot(key, p);
            if self.counters[i] < 15 {
                self.counters[i] += 1;
            }
        }
        self.ops += 1;
        if self.ops >= self.age_every {
            self.ops = 0;
            for c in &mut self.counters {
                *c >>= 1;
            }
        }
    }

    fn estimate(&self, key: u64) -> u8 {
        (0..2u64).map(|p| self.counters[self.slot(key, p)]).min().unwrap_or(0)
    }
}

const NIL: u32 = u32::MAX;

struct Entry {
    key: u64,
    gen: u64,
    val: Vec<f32>,
    prev: u32,
    next: u32,
    /// Monotone recency stamp from the (possibly shard-shared)
    /// ticker, refreshed whenever the entry moves to the LRU head —
    /// what makes per-shard recency lists mergeable into one global
    /// hot-key order ([`ShardedCache::hot_keys`]).
    touch: u64,
}

/// Bounded LRU over f32 rows, keyed by [`cache_key`].  Capacity 0
/// disables the cache (every `get` misses, `put` is a no-op) — the
/// "uncached arm" of serve-bench.
pub struct EmbeddingCache {
    cap: usize,
    gen: u64,
    map: FxHashMap<u64, u32>,
    entries: Vec<Entry>,
    free: Vec<u32>,
    head: u32,
    tail: u32,
    sketch: Option<FreqSketch>,
    /// Recency-tick source; shards of one [`ShardedCache`] share it so
    /// their stamps form a single global order.
    ticker: Arc<AtomicU64>,
}

impl EmbeddingCache {
    pub fn new(cap: usize) -> EmbeddingCache {
        EmbeddingCache::with_admission(cap, Admission::Always)
    }

    /// Cache with an explicit admission policy (`serve.admission`).
    pub fn with_admission(cap: usize, admission: Admission) -> EmbeddingCache {
        EmbeddingCache::with_ticker(cap, admission, Arc::new(AtomicU64::new(0)))
    }

    fn with_ticker(cap: usize, admission: Admission, ticker: Arc<AtomicU64>) -> EmbeddingCache {
        EmbeddingCache {
            cap,
            gen: 0,
            map: FxHashMap::default(),
            entries: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            sketch: match admission {
                Admission::TinyLfu if cap > 0 => Some(FreqSketch::new(cap)),
                _ => None,
            },
            ticker,
        }
    }

    #[inline]
    fn tick(&self) -> u64 {
        self.ticker.fetch_add(1, Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn admission(&self) -> Admission {
        if self.sketch.is_some() {
            Admission::TinyLfu
        } else {
            Admission::Always
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// Adopt an external generation (e.g. an `EmbTable`'s update
    /// counter); entries stamped with any other generation become
    /// misses.
    pub fn set_generation(&mut self, gen: u64) {
        self.gen = gen;
    }

    /// Invalidate every entry in O(1).
    pub fn bump_generation(&mut self) {
        self.gen = self.gen.wrapping_add(1);
        // Rare + load-bearing: a whole-cache invalidation is exactly
        // the event a latency cliff in a trace correlates with.
        crate::event!("serve.cache.invalidate", gen = self.gen);
    }

    fn detach(&mut self, i: u32) {
        let (prev, next) = {
            let e = &self.entries[i as usize];
            (e.prev, e.next)
        };
        if prev != NIL {
            self.entries[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.entries[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, i: u32) {
        let old = self.head;
        {
            let e = &mut self.entries[i as usize];
            e.prev = NIL;
            e.next = old;
        }
        if old != NIL {
            self.entries[old as usize].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Current-generation row for `key`, refreshing its recency.
    /// Stale-generation entries are removed lazily and report a miss.
    /// Every lookup — hit or miss — feeds the admission sketch.
    pub fn get(&mut self, key: u64) -> Option<&[f32]> {
        if let Some(s) = &mut self.sketch {
            s.touch(key);
        }
        let &i = self.map.get(&key)?;
        if self.entries[i as usize].gen != self.gen {
            self.map.remove(&key);
            self.detach(i);
            self.free.push(i);
            return None;
        }
        self.detach(i);
        self.push_front(i);
        let touch = self.tick();
        let e = &mut self.entries[i as usize];
        e.touch = touch;
        Some(&self.entries[i as usize].val)
    }

    /// Insert/overwrite `key` at the current generation, evicting the
    /// least-recently-used entry when full.  Under
    /// [`Admission::TinyLfu`] a *new* key is dropped instead of
    /// evicting a victim whose sketch frequency beats it.
    pub fn put(&mut self, key: u64, val: &[f32]) {
        if self.cap == 0 {
            return;
        }
        if let Some(&i) = self.map.get(&key) {
            let touch = self.tick();
            let e = &mut self.entries[i as usize];
            e.gen = self.gen;
            e.val.clear();
            e.val.extend_from_slice(val);
            e.touch = touch;
            self.detach(i);
            self.push_front(i);
            return;
        }
        let i = if let Some(i) = self.free.pop() {
            i
        } else if self.map.len() >= self.cap {
            let i = self.tail;
            debug_assert_ne!(i, NIL, "full cache must have a tail");
            let old_key = self.entries[i as usize].key;
            if let Some(s) = &self.sketch {
                // Frequency gate: the incoming key must be at least as
                // hot as the victim, or it isn't worth a slot.
                if s.estimate(key) < s.estimate(old_key) {
                    return;
                }
            }
            self.detach(i);
            self.map.remove(&old_key);
            i
        } else {
            self.entries.push(Entry {
                key: 0,
                gen: 0,
                val: Vec::new(),
                prev: NIL,
                next: NIL,
                touch: 0,
            });
            (self.entries.len() - 1) as u32
        };
        let touch = self.tick();
        {
            let e = &mut self.entries[i as usize];
            e.key = key;
            e.gen = self.gen;
            e.val.clear();
            e.val.extend_from_slice(val);
            e.touch = touch;
        }
        self.map.insert(key, i);
        self.push_front(i);
    }

    /// `put`, but only if `gen` is still the cache's current
    /// generation — the insert path for rows computed asynchronously
    /// (engine-pool batches, background refresh): a row computed
    /// before a generation bump must never be stamped current.
    /// Returns whether the row is resident afterwards (false when the
    /// generation was stale, the admission gate dropped it, or the
    /// cache is disabled).
    pub fn put_if_current(&mut self, key: u64, val: &[f32], gen: u64) -> bool {
        if gen != self.gen {
            return false;
        }
        self.put(key, val);
        self.map.contains_key(&key)
    }

    /// Resident keys in recency order (most-recently-used first), up
    /// to `limit` — the hot set a background refresher re-reads after
    /// a generation bump.  Stale-generation entries are included on
    /// purpose: they *are* the rows worth re-reading.
    pub fn hot_keys(&self, limit: usize) -> Vec<u64> {
        let mut out = Vec::with_capacity(limit.min(self.map.len()));
        let mut i = self.head;
        while i != NIL && out.len() < limit {
            let e = &self.entries[i as usize];
            out.push(e.key);
            i = e.next;
        }
        out
    }

    /// Every resident `(touch, key)` pair, appended to `out` — the
    /// per-shard raw material [`ShardedCache::hot_keys`] merges into a
    /// global recency order.  Touch stamps are refreshed exactly when
    /// an entry moves to the LRU head, so sorting by stamp reproduces
    /// the recency list.
    fn touched(&self, out: &mut Vec<(u64, u64)>) {
        let mut i = self.head;
        while i != NIL {
            let e = &self.entries[i as usize];
            out.push((e.touch, e.key));
            i = e.next;
        }
    }
}

/// The serving cache striped `N` ways: each shard is an independent
/// [`EmbeddingCache`] behind its own mutex — its own LRU list, TinyLFU
/// [`FreqSketch`] and hot-key tracker — and a key's shard is the pure
/// hash [`shard_of`]`(key, N)`.  Readers and writers touching
/// different stripes never contend; aggregate views (`len`,
/// `generation`, the merged [`hot_keys`](ShardedCache::hot_keys) the
/// background refresher consumes) lock shards one at a time, never two
/// together, so the per-shard lock-order DAG (`lockorder::Rank::Cache`
/// with ascending shard sub-ranks) is trivially respected.
///
/// Determinism contract: because routing is a pure function of the key
/// and each shard preserves the exact single-cache semantics
/// (generation stamping, LRU, admission), replies and hit/miss
/// accounting through the serving pool are bit-identical for any shard
/// count whenever they are for one (see `rust/tests/sharding.rs`).
/// With a bounded capacity the *eviction* pattern depends on the shard
/// count (capacity splits `cap.div_ceil(N)` per stripe), exactly like
/// it already depends on request interleaving.
pub struct ShardedCache {
    shards: Vec<Mutex<EmbeddingCache>>,
}

impl ShardedCache {
    /// `cap` total rows striped over `shards` plain-LRU stripes
    /// (capacity 0 disables every stripe — the uncached arm).
    pub fn new(cap: usize, shards: usize) -> ShardedCache {
        ShardedCache::with_admission(cap, shards, Admission::Always)
    }

    /// [`new`](ShardedCache::new) with an explicit admission policy
    /// (`serve.admission`); every stripe gets its own frequency
    /// sketch sized to its share of the capacity.
    pub fn with_admission(cap: usize, shards: usize, admission: Admission) -> ShardedCache {
        let n = shards.max(1);
        let per = if cap == 0 { 0 } else { cap.div_ceil(n) };
        let ticker = Arc::new(AtomicU64::new(0));
        ShardedCache {
            shards: (0..n)
                .map(|_| Mutex::new(EmbeddingCache::with_ticker(per, admission, ticker.clone())))
                .collect(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total capacity across stripes (0 = disabled).  Striping rounds
    /// per-shard capacity up (`cap.div_ceil(shards)` each), so this
    /// can slightly exceed the requested total.
    pub fn capacity(&self) -> usize {
        (0..self.shards.len()).map(|i| self.lock_at(i).capacity()).sum()
    }

    pub fn admission(&self) -> Admission {
        self.lock_at(0).admission()
    }

    /// The stripe index for `key`.
    #[inline]
    pub fn shard_index(&self, key: u64) -> usize {
        shard_of(key, self.shards.len())
    }

    /// The raw mutex of stripe `i` — for callers that need to compose
    /// several operations under one shard lock (lock it through
    /// [`super::error::lock_shard`] with the same index).
    pub fn shard(&self, i: usize) -> &Mutex<EmbeddingCache> {
        &self.shards[i]
    }

    /// Lock the stripe owning `key` (rank-tracked, poison recovery
    /// bumps that shard's generation).
    pub fn lock_key(&self, key: u64) -> super::error::RankedGuard<'_, EmbeddingCache> {
        let i = self.shard_index(key);
        lock_shard(&self.shards[i], i as u32)
    }

    /// Lock stripe `i` directly.
    pub fn lock_at(&self, i: usize) -> super::error::RankedGuard<'_, EmbeddingCache> {
        lock_shard(&self.shards[i], i as u32)
    }

    /// Resident rows across all stripes.
    pub fn len(&self) -> usize {
        (0..self.shards.len()).map(|i| self.lock_at(i).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The oldest stripe generation — the conservative aggregate the
    /// refresher compares against a source generation: equality means
    /// *every* stripe has adopted it.
    pub fn generation(&self) -> u64 {
        (0..self.shards.len()).map(|i| self.lock_at(i).generation()).min().unwrap_or(0)
    }

    /// Adopt `gen` on every stripe.
    pub fn set_generation(&self, gen: u64) {
        for i in 0..self.shards.len() {
            self.lock_at(i).set_generation(gen);
        }
    }

    /// Invalidate every stripe in O(shards).
    pub fn bump_generation(&self) {
        for i in 0..self.shards.len() {
            self.lock_at(i).bump_generation();
        }
    }

    /// `get` through the owning stripe (feeds its admission sketch,
    /// refreshes recency), copying the row out of the lock.
    pub fn get(&self, key: u64) -> Option<Vec<f32>> {
        self.lock_key(key).get(key).map(|r| r.to_vec())
    }

    /// `put` into the owning stripe at its current generation.
    pub fn put(&self, key: u64, val: &[f32]) {
        self.lock_key(key).put(key, val);
    }

    /// [`EmbeddingCache::put_if_current`] on the owning stripe.
    pub fn put_if_current(&self, key: u64, val: &[f32], gen: u64) -> bool {
        self.lock_key(key).put_if_current(key, val, gen)
    }

    /// Read-through lookup on the owning stripe (the stripe lock is
    /// held across the fetch, like the single-cache
    /// [`EmbeddingCache::get_through`]).
    pub fn get_through(
        &self,
        nt: u32,
        id: u32,
        src: &mut impl RowSource,
        out: &mut Vec<f32>,
    ) -> Result<bool> {
        self.lock_key(cache_key(nt, id)).get_through(nt, id, src, out)
    }

    /// The merged global hot set: per-shard recency lists zipped by
    /// their shared touch ticker into one most-recently-used-first
    /// order, truncated to `limit`.  For a single shard this is
    /// exactly [`EmbeddingCache::hot_keys`]; for N shards it is the
    /// same order a single cache would have produced under the same
    /// touch sequence (`rust/tests/sharding.rs` proves the
    /// equivalence).  Shard locks are taken one at a time.
    pub fn hot_keys(&self, limit: usize) -> Vec<u64> {
        let mut pairs: Vec<(u64, u64)> = Vec::new();
        for i in 0..self.shards.len() {
            self.lock_at(i).touched(&mut pairs);
        }
        pairs.sort_unstable_by(|a, b| b.0.cmp(&a.0));
        pairs.truncate(limit);
        pairs.into_iter().map(|(_, k)| k).collect()
    }
}

/// A row provider behind the cache: `dist::EmbTable`, the inference
/// engine, or the offline shard store — anything that can produce the
/// canonical row for a node and report an update generation.
pub trait RowSource {
    fn row_dim(&self) -> usize;
    /// Update counter of the backing store; the cache adopts it so
    /// stale rows invalidate automatically.
    fn source_generation(&self) -> u64;
    fn fetch_row(&mut self, nt: u32, id: u32, out: &mut Vec<f32>) -> Result<()>;

    /// Batched fetch of **distinct** seeds into a row-major
    /// `[seeds.len(), row_dim]` buffer.  The default loops
    /// [`fetch_row`](Self::fetch_row); sources with a cheaper bulk
    /// path (one engine forward, one table lock) override it — the
    /// background refresher (`serve::refresh`) fetches through this.
    fn fetch_rows(&mut self, seeds: &[(u32, u32)], out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        let mut row = Vec::new();
        for &(nt, id) in seeds {
            self.fetch_row(nt, id, &mut row)?;
            out.extend_from_slice(&row);
        }
        Ok(())
    }
}

/// `dist::EmbTable` lookups routed through the cache trait, so
/// learnable-embedding models serve hot rows without taking the
/// table's read lock (GiGL-style embedding-table serving).  Gathers
/// are attributed to partition `worker` for traffic accounting.
pub struct EmbTableSource<'a> {
    pub table: &'a EmbTable,
    pub worker: u32,
}

impl RowSource for EmbTableSource<'_> {
    fn row_dim(&self) -> usize {
        self.table.dim
    }

    fn source_generation(&self) -> u64 {
        self.table.generation()
    }

    fn fetch_row(&mut self, _nt: u32, id: u32, out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        out.resize(self.table.dim, 0.0);
        self.table.row_into(self.worker, id, out);
        Ok(())
    }

    /// One gather (a single table read-lock) instead of a lock per row.
    fn fetch_rows(&mut self, seeds: &[(u32, u32)], out: &mut Vec<f32>) -> Result<()> {
        let ids: Vec<u32> = seeds.iter().map(|&(_, id)| id).collect();
        out.clear();
        out.resize(ids.len() * self.table.dim, 0.0);
        self.table.gather_into(self.worker, &ids, out);
        Ok(())
    }
}

impl EmbeddingCache {
    /// Read-through lookup: adopt the source's generation, then serve
    /// from cache or fetch + insert.  Returns whether it was a hit.
    pub fn get_through(
        &mut self,
        nt: u32,
        id: u32,
        src: &mut impl RowSource,
        out: &mut Vec<f32>,
    ) -> Result<bool> {
        self.set_generation(src.source_generation());
        let key = cache_key(nt, id);
        if let Some(row) = self.get(key) {
            out.clear();
            out.extend_from_slice(row);
            return Ok(true);
        }
        src.fetch_row(nt, id, out)?;
        self.put(key, out);
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::PartitionBook;
    use std::sync::Arc;

    #[test]
    fn lru_evicts_oldest_and_get_refreshes() {
        let mut c = EmbeddingCache::new(2);
        c.put(1, &[1.0]);
        c.put(2, &[2.0]);
        assert_eq!(c.get(1), Some(&[1.0f32][..])); // 1 is now MRU
        c.put(3, &[3.0]); // evicts 2
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(1), Some(&[1.0f32][..]));
        assert_eq!(c.get(3), Some(&[3.0f32][..]));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn overwrite_updates_value() {
        let mut c = EmbeddingCache::new(2);
        c.put(7, &[1.0, 2.0]);
        c.put(7, &[3.0]);
        assert_eq!(c.get(7), Some(&[3.0f32][..]));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn generation_bump_invalidates_everything() {
        let mut c = EmbeddingCache::new(4);
        c.put(1, &[1.0]);
        c.put(2, &[2.0]);
        c.bump_generation();
        assert_eq!(c.get(1), None);
        assert_eq!(c.get(2), None);
        // Slots are recycled after the lazy removal.
        c.put(3, &[3.0]);
        assert_eq!(c.get(3), Some(&[3.0f32][..]));
    }

    #[test]
    fn capacity_zero_disables() {
        let mut c = EmbeddingCache::new(0);
        c.put(1, &[1.0]);
        assert_eq!(c.get(1), None);
    }

    #[test]
    fn hot_keys_follow_recency() {
        let mut c = EmbeddingCache::new(4);
        for k in 1..=4u64 {
            c.put(k, &[k as f32]);
        }
        c.get(2); // 2 becomes MRU
        assert_eq!(c.hot_keys(3), vec![2, 4, 3]);
        assert_eq!(c.hot_keys(10), vec![2, 4, 3, 1]);
        assert_eq!(EmbeddingCache::new(4).hot_keys(5), Vec::<u64>::new());
    }

    #[test]
    fn put_if_current_rejects_stale_generation() {
        let mut c = EmbeddingCache::new(4);
        c.set_generation(3);
        assert!(!c.put_if_current(1, &[1.0], 2), "stale generation must be dropped");
        assert_eq!(c.get(1), None);
        assert!(c.put_if_current(1, &[1.0], 3));
        assert_eq!(c.get(1), Some(&[1.0f32][..]));
    }

    #[test]
    fn tinylfu_admission_resists_scans() {
        // Hot working set, touched often enough to build frequency.
        let mut c = EmbeddingCache::with_admission(8, Admission::TinyLfu);
        for _ in 0..10 {
            for k in 0..8u64 {
                if c.get(k).is_none() {
                    c.put(k, &[k as f32]);
                }
            }
        }
        // One-shot scan traffic: 100 distinct cold keys.
        for k in 1000..1100u64 {
            if c.get(k).is_none() {
                c.put(k, &[0.0]);
            }
        }
        let survivors = (0..8u64).filter(|&k| c.get(k).is_some()).count();
        assert!(survivors >= 6, "scan evicted the hot set ({survivors}/8 left)");

        // Baseline: plain LRU is flushed by the same scan.
        let mut lru = EmbeddingCache::new(8);
        for _ in 0..10 {
            for k in 0..8u64 {
                if lru.get(k).is_none() {
                    lru.put(k, &[k as f32]);
                }
            }
        }
        for k in 1000..1100u64 {
            if lru.get(k).is_none() {
                lru.put(k, &[0.0]);
            }
        }
        let lru_survivors = (0..8u64).filter(|&k| lru.get(k).is_some()).count();
        assert_eq!(lru_survivors, 0, "plain LRU should have been flushed");
    }

    #[test]
    fn tinylfu_still_admits_into_free_slots() {
        // Admission only gates evictions: generation-freed slots and
        // unfilled capacity always accept new rows.
        let mut c = EmbeddingCache::with_admission(2, Admission::TinyLfu);
        c.put(1, &[1.0]);
        c.put(2, &[2.0]);
        c.bump_generation();
        assert_eq!(c.get(1), None); // frees the slot
        c.put(3, &[3.0]);
        assert_eq!(c.get(3), Some(&[3.0f32][..]));
    }

    #[test]
    fn split_key_inverts_cache_key() {
        for (nt, id) in [(0u32, 0u32), (3, 17), (u32::MAX, u32::MAX)] {
            assert_eq!(split_key(cache_key(nt, id)), (nt, id));
        }
    }

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for key in [0u64, 1, 42, cache_key(3, 17), u64::MAX] {
            assert_eq!(shard_of(key, 1), 0);
            for n in [2usize, 4, 8] {
                let s = shard_of(key, n);
                assert!(s < n);
                assert_eq!(s, shard_of(key, n), "routing must be a pure function");
            }
        }
        // The hash actually spreads: 256 consecutive keys over 4
        // shards must not all land on one stripe.
        let mut seen = [false; 4];
        for k in 0..256u64 {
            seen[shard_of(k, 4)] = true;
        }
        assert!(seen.iter().all(|&b| b), "fxhash routing left a stripe empty");
    }

    #[test]
    fn sharded_cache_routes_and_aggregates() {
        let c = ShardedCache::new(64, 4);
        assert_eq!(c.num_shards(), 4);
        for k in 0..32u64 {
            c.put(k, &[k as f32]);
        }
        assert_eq!(c.len(), 32);
        for k in 0..32u64 {
            assert_eq!(c.get(k), Some(vec![k as f32]));
            // The row lives in exactly the stripe shard_of names.
            let i = c.shard_index(k);
            assert!(super::lock_shard(c.shard(i), i as u32).get(k).is_some());
        }
        c.bump_generation();
        for k in 0..32u64 {
            assert_eq!(c.get(k), None, "bump must invalidate every stripe");
        }
    }

    #[test]
    fn sharded_generation_is_min_over_stripes() {
        let c = ShardedCache::new(16, 4);
        c.set_generation(5);
        assert_eq!(c.generation(), 5);
        // One stripe lagging drags the aggregate down — the refresher
        // must see "not everyone has adopted gen 6 yet".
        c.lock_at(2).set_generation(6);
        assert_eq!(c.generation(), 5);
        c.set_generation(6);
        assert_eq!(c.generation(), 6);
    }

    #[test]
    fn merged_hot_keys_follow_global_recency() {
        // Same op sequence against 1 and 4 stripes: the merged view
        // must equal the single-cache recency order exactly.
        let ops: Vec<u64> = vec![11, 7, 3, 19, 7, 3, 42, 11];
        let single = ShardedCache::new(64, 1);
        let striped = ShardedCache::new(64, 4);
        for c in [&single, &striped] {
            for &k in &ops {
                if c.get(k).is_none() {
                    c.put(k, &[k as f32]);
                }
            }
        }
        assert_eq!(striped.hot_keys(16), single.hot_keys(16));
        assert_eq!(striped.hot_keys(3), single.hot_keys(3));
        assert_eq!(striped.hot_keys(16), vec![11, 42, 3, 7, 19]);
    }

    #[test]
    fn emb_table_reads_through_and_invalidates_on_update() {
        let book = Arc::new(PartitionBook::single(&[4]));
        let counters = Arc::new(crate::dist::TrafficCounters::new());
        let table = EmbTable::new(0, 4, 3, 7, book, counters);
        let mut src = EmbTableSource { table: &table, worker: 0 };
        let mut cache = EmbeddingCache::new(8);
        let mut row = Vec::new();

        let hit = cache.get_through(0, 2, &mut src, &mut row).unwrap();
        assert!(!hit);
        let snap = table.weights_snapshot();
        assert_eq!(row, &snap[6..9]);
        assert!(cache.get_through(0, 2, &mut src, &mut row).unwrap(), "second read must hit");
        assert_eq!(row, &snap[6..9]);

        // A sparse update bumps the table generation → cache misses
        // and refetches the new row.
        table.sparse_adam(&[2], &[1.0; 3], 1e-2);
        let hit = cache.get_through(0, 2, &mut src, &mut row).unwrap();
        assert!(!hit, "update must invalidate the cached row");
        let snap2 = table.weights_snapshot();
        assert_eq!(row, &snap2[6..9]);
        assert_ne!(row, &snap[6..9]);
    }
}
