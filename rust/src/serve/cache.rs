//! Generation-stamped LRU embedding/prediction cache.
//!
//! Serving traffic is power-law: a small set of hot nodes dominates
//! requests, so caching their decoded predictions (or embedding rows)
//! lets them skip K-hop sampling entirely.  Entries are stamped with a
//! generation; bumping the generation (model update, embedding-table
//! write) invalidates the whole cache in O(1) without touching any
//! entry.  Eviction reuses the evicted entry's row allocation, so a
//! full cache performs no steady-state allocation on `put` of
//! same-width rows.

use anyhow::Result;

use crate::dist::EmbTable;
use crate::util::FxHashMap;

/// Cache key for a `(ntype, node id)` pair.
#[inline]
pub fn cache_key(nt: u32, id: u32) -> u64 {
    ((nt as u64) << 32) | id as u64
}

const NIL: u32 = u32::MAX;

struct Entry {
    key: u64,
    gen: u64,
    val: Vec<f32>,
    prev: u32,
    next: u32,
}

/// Bounded LRU over f32 rows, keyed by [`cache_key`].  Capacity 0
/// disables the cache (every `get` misses, `put` is a no-op) — the
/// "uncached arm" of serve-bench.
pub struct EmbeddingCache {
    cap: usize,
    gen: u64,
    map: FxHashMap<u64, u32>,
    entries: Vec<Entry>,
    free: Vec<u32>,
    head: u32,
    tail: u32,
}

impl EmbeddingCache {
    pub fn new(cap: usize) -> EmbeddingCache {
        EmbeddingCache {
            cap,
            gen: 0,
            map: FxHashMap::default(),
            entries: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// Adopt an external generation (e.g. an `EmbTable`'s update
    /// counter); entries stamped with any other generation become
    /// misses.
    pub fn set_generation(&mut self, gen: u64) {
        self.gen = gen;
    }

    /// Invalidate every entry in O(1).
    pub fn bump_generation(&mut self) {
        self.gen = self.gen.wrapping_add(1);
    }

    fn detach(&mut self, i: u32) {
        let (prev, next) = {
            let e = &self.entries[i as usize];
            (e.prev, e.next)
        };
        if prev != NIL {
            self.entries[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.entries[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, i: u32) {
        let old = self.head;
        {
            let e = &mut self.entries[i as usize];
            e.prev = NIL;
            e.next = old;
        }
        if old != NIL {
            self.entries[old as usize].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Current-generation row for `key`, refreshing its recency.
    /// Stale-generation entries are removed lazily and report a miss.
    pub fn get(&mut self, key: u64) -> Option<&[f32]> {
        let &i = self.map.get(&key)?;
        if self.entries[i as usize].gen != self.gen {
            self.map.remove(&key);
            self.detach(i);
            self.free.push(i);
            return None;
        }
        self.detach(i);
        self.push_front(i);
        Some(&self.entries[i as usize].val)
    }

    /// Insert/overwrite `key` at the current generation, evicting the
    /// least-recently-used entry when full.
    pub fn put(&mut self, key: u64, val: &[f32]) {
        if self.cap == 0 {
            return;
        }
        if let Some(&i) = self.map.get(&key) {
            let e = &mut self.entries[i as usize];
            e.gen = self.gen;
            e.val.clear();
            e.val.extend_from_slice(val);
            self.detach(i);
            self.push_front(i);
            return;
        }
        let i = if let Some(i) = self.free.pop() {
            i
        } else if self.map.len() >= self.cap {
            let i = self.tail;
            debug_assert_ne!(i, NIL, "full cache must have a tail");
            self.detach(i);
            let old_key = self.entries[i as usize].key;
            self.map.remove(&old_key);
            i
        } else {
            self.entries.push(Entry { key: 0, gen: 0, val: Vec::new(), prev: NIL, next: NIL });
            (self.entries.len() - 1) as u32
        };
        {
            let e = &mut self.entries[i as usize];
            e.key = key;
            e.gen = self.gen;
            e.val.clear();
            e.val.extend_from_slice(val);
        }
        self.map.insert(key, i);
        self.push_front(i);
    }
}

/// A row provider behind the cache: `dist::EmbTable`, the inference
/// engine, or the offline shard store — anything that can produce the
/// canonical row for a node and report an update generation.
pub trait RowSource {
    fn row_dim(&self) -> usize;
    /// Update counter of the backing store; the cache adopts it so
    /// stale rows invalidate automatically.
    fn source_generation(&self) -> u64;
    fn fetch_row(&mut self, nt: u32, id: u32, out: &mut Vec<f32>) -> Result<()>;
}

/// `dist::EmbTable` lookups routed through the cache trait, so
/// learnable-embedding models serve hot rows without taking the
/// table's read lock (GiGL-style embedding-table serving).  Gathers
/// are attributed to partition `worker` for traffic accounting.
pub struct EmbTableSource<'a> {
    pub table: &'a EmbTable,
    pub worker: u32,
}

impl RowSource for EmbTableSource<'_> {
    fn row_dim(&self) -> usize {
        self.table.dim
    }

    fn source_generation(&self) -> u64 {
        self.table.generation()
    }

    fn fetch_row(&mut self, _nt: u32, id: u32, out: &mut Vec<f32>) -> Result<()> {
        out.clear();
        out.resize(self.table.dim, 0.0);
        self.table.row_into(self.worker, id, out);
        Ok(())
    }
}

impl EmbeddingCache {
    /// Read-through lookup: adopt the source's generation, then serve
    /// from cache or fetch + insert.  Returns whether it was a hit.
    pub fn get_through(
        &mut self,
        nt: u32,
        id: u32,
        src: &mut impl RowSource,
        out: &mut Vec<f32>,
    ) -> Result<bool> {
        self.set_generation(src.source_generation());
        let key = cache_key(nt, id);
        if let Some(row) = self.get(key) {
            out.clear();
            out.extend_from_slice(row);
            return Ok(true);
        }
        src.fetch_row(nt, id, out)?;
        self.put(key, out);
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::PartitionBook;
    use std::sync::Arc;

    #[test]
    fn lru_evicts_oldest_and_get_refreshes() {
        let mut c = EmbeddingCache::new(2);
        c.put(1, &[1.0]);
        c.put(2, &[2.0]);
        assert_eq!(c.get(1), Some(&[1.0f32][..])); // 1 is now MRU
        c.put(3, &[3.0]); // evicts 2
        assert_eq!(c.get(2), None);
        assert_eq!(c.get(1), Some(&[1.0f32][..]));
        assert_eq!(c.get(3), Some(&[3.0f32][..]));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn overwrite_updates_value() {
        let mut c = EmbeddingCache::new(2);
        c.put(7, &[1.0, 2.0]);
        c.put(7, &[3.0]);
        assert_eq!(c.get(7), Some(&[3.0f32][..]));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn generation_bump_invalidates_everything() {
        let mut c = EmbeddingCache::new(4);
        c.put(1, &[1.0]);
        c.put(2, &[2.0]);
        c.bump_generation();
        assert_eq!(c.get(1), None);
        assert_eq!(c.get(2), None);
        // Slots are recycled after the lazy removal.
        c.put(3, &[3.0]);
        assert_eq!(c.get(3), Some(&[3.0f32][..]));
    }

    #[test]
    fn capacity_zero_disables() {
        let mut c = EmbeddingCache::new(0);
        c.put(1, &[1.0]);
        assert_eq!(c.get(1), None);
    }

    #[test]
    fn emb_table_reads_through_and_invalidates_on_update() {
        let book = Arc::new(PartitionBook::single(&[4]));
        let counters = Arc::new(crate::dist::TrafficCounters::new());
        let table = EmbTable::new(0, 4, 3, 7, book, counters);
        let mut src = EmbTableSource { table: &table, worker: 0 };
        let mut cache = EmbeddingCache::new(8);
        let mut row = Vec::new();

        let hit = cache.get_through(0, 2, &mut src, &mut row).unwrap();
        assert!(!hit);
        let snap = table.weights_snapshot();
        assert_eq!(row, &snap[6..9]);
        assert!(cache.get_through(0, 2, &mut src, &mut row).unwrap(), "second read must hit");
        assert_eq!(row, &snap[6..9]);

        // A sparse update bumps the table generation → cache misses
        // and refetches the new row.
        table.sparse_adam(&[2], &[1.0; 3], 1e-2);
        let hit = cache.get_through(0, 2, &mut src, &mut row).unwrap();
        assert!(!hit, "update must invalidate the cached row");
        let snap2 = table.weights_snapshot();
        assert_eq!(row, &snap2[6..9]);
        assert_ne!(row, &snap[6..9]);
    }
}
