//! The inference engine: the forward-only path extracted from the
//! NC/LP trainers.
//!
//! One request = sample a K-hop block around the requested seeds →
//! assemble manifest-ordered inputs → execute the `*_infer` artifact →
//! decode per-target rows.  Two properties make this servable:
//!
//! * **Canonical sampling** — every destination draws its neighbors
//!   from `node_sample_seed(hop_base(engine seed, hop), node)`, so a
//!   node's sampled tree (and, since message passing only flows along
//!   block edges into a target's slot, its prediction) is independent
//!   of which other requests share the micro-batch, while per-hop
//!   redraws still match the training sampler's distribution.  Cached rows therefore stay
//!   bit-identical to any later recompute, and the offline writer's
//!   shards are valid warm-up data for the online cache.
//! * **Recycled buffers** — assembly writes into a double-buffer ring
//!   ([`ServeScratch`]), so steady-state sampling + assembly performs
//!   zero heap allocation (`benches/serve.rs` asserts this).
//!
//! Execution is artifact-gated like everywhere else: with a PJRT
//! session the real `*_infer` artifact runs; without one a
//! deterministic Rust *surrogate* (mean-aggregation message passing
//! over the sampled block + a fixed random projection) stands in, so
//! the serving stack — batching, caching, offline shards, benches —
//! runs end-to-end on any machine.

use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};

use super::error::lock_ranked;
use crate::util::lockorder::Rank;
use crate::dataloader::{BatchFactory, GsDataset, LembTouch};
use crate::runtime::{ArtifactSpec, InferSession, Runtime, Tensor};
use crate::sampling::{Block, BlockShape};
use crate::util::Rng;

/// Decode width of the surrogate backend when the spec declares no
/// outputs.
pub const SURROGATE_OUT_DIM: usize = 8;

enum Backend {
    /// Real AOT artifact through PJRT.
    Pjrt(InferSession),
    /// Deterministic in-Rust stand-in (no artifacts needed).
    Surrogate,
}

/// Reusable per-thread serving state: batch factory (sampler scratch +
/// block), the assembled-tensor double-buffer ring, and the surrogate
/// forward buffers.  One per serving thread; the engine itself is
/// shared immutably.  The ring's job is buffer *reuse* (zero
/// steady-state allocation); its two slots additionally keep the
/// previous batch's tensors intact across one more `forward` call for
/// callers that still hold them.
pub struct ServeScratch<'a> {
    pub factory: BatchFactory<'a>,
    ring: [(Vec<Tensor>, LembTouch); 2],
    cur: usize,
    sur: SurrogateScratch,
}

#[derive(Default)]
struct SurrogateScratch {
    h: Vec<f32>,
    h2: Vec<f32>,
    acc: Vec<f32>,
    deg: Vec<f32>,
    out: Vec<f32>,
}

pub struct InferenceEngine<'a> {
    pub ds: &'a GsDataset,
    pub spec: ArtifactSpec,
    pub shape: BlockShape,
    backend: Backend,
    /// Base seed for canonical per-node sampling.
    pub sample_seed: u64,
    /// Model/parameter generation; bump after refreshing params so
    /// caches stamped with the old generation invalidate.
    generation: AtomicU64,
    out_dim: usize,
    h_dim: usize,
    /// Surrogate decode projection, `[out_dim, h_dim]` row-major.
    proj: Vec<f32>,
}

impl<'a> InferenceEngine<'a> {
    fn build(
        ds: &'a GsDataset,
        spec: ArtifactSpec,
        backend: Backend,
        sample_seed: u64,
    ) -> Result<InferenceEngine<'a>> {
        let shape = BlockShape::from_spec(&spec)
            .ok_or_else(|| anyhow!("artifact '{}' has no block config", spec.file))?;
        let dim_of = |n: &str| spec.batch_spec(n).map(|t| t.shape[1]).unwrap_or(0);
        let h_dim = dim_of("feat").max(dim_of("text")).max(dim_of("lemb")).max(8);
        let out_dim = spec
            .outputs
            .first()
            .and_then(|t| t.shape.last().copied())
            .unwrap_or(SURROGATE_OUT_DIM);
        let mut rng = Rng::seed_from(sample_seed ^ 0x5e7e);
        let scale = 1.0 / (h_dim as f32).sqrt();
        let proj = (0..out_dim * h_dim).map(|_| rng.gen_normal() * scale).collect();
        Ok(InferenceEngine {
            ds,
            spec,
            shape,
            backend,
            sample_seed,
            generation: AtomicU64::new(0),
            out_dim,
            h_dim,
            proj,
        })
    }

    /// Engine over the deterministic surrogate backend — serves
    /// without AOT artifacts or PJRT.
    pub fn surrogate(ds: &'a GsDataset, spec: &ArtifactSpec, seed: u64) -> Result<InferenceEngine<'a>> {
        InferenceEngine::build(ds, spec.clone(), Backend::Surrogate, seed)
    }

    /// Engine over an existing PJRT inference session.
    pub fn with_session(
        ds: &'a GsDataset,
        sess: InferSession,
        seed: u64,
    ) -> Result<InferenceEngine<'a>> {
        let spec = sess.exe.spec.clone();
        InferenceEngine::build(ds, spec, Backend::Pjrt(sess), seed)
    }

    /// Engine over a named infer artifact with explicit parameters
    /// (e.g. `TrainState::params_host` after training).
    pub fn from_trained(
        rt: &Runtime,
        ds: &'a GsDataset,
        artifact: &str,
        params: &[(String, Tensor)],
        seed: u64,
    ) -> Result<InferenceEngine<'a>> {
        let sess = InferSession::new(rt, artifact, params)?;
        InferenceEngine::with_session(ds, sess, seed)
    }

    /// Default engine for the CLI/benches/examples: the
    /// `{arch}_nc_logits` artifact (from its init params) when PJRT
    /// can execute it, else the surrogate over the standard synthetic
    /// spec with an `out_dim`-wide logits output.  Returns the backend
    /// label for display.
    pub fn auto(
        ds: &'a GsDataset,
        arch: &str,
        out_dim: usize,
        seed: u64,
    ) -> Result<(InferenceEngine<'a>, &'static str)> {
        if let Some(rt) = crate::runtime::runtime_if_available() {
            let name = format!("{arch}_nc_logits");
            if rt.manifest.get(&name).is_ok() {
                if let Ok(sess) = InferSession::from_init(&rt, &name) {
                    return Ok((InferenceEngine::with_session(ds, sess, seed)?, "pjrt"));
                }
            }
        }
        let spec =
            ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
                .with_output("logits", &[64, out_dim]);
        Ok((InferenceEngine::surrogate(ds, &spec, seed)?, "surrogate"))
    }

    /// Row width of decoded predictions.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Max distinct seeds per forward call.
    pub fn capacity(&self) -> usize {
        self.spec
            .cfg_usize("batch")
            .unwrap_or(self.shape.num_targets())
            .min(self.shape.num_targets())
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Mark the model as updated; caches adopt the new generation and
    /// drop every stale prediction in O(1).
    pub fn bump_generation(&self) {
        self.generation.fetch_add(1, Ordering::AcqRel);
    }

    pub fn make_scratch(&self) -> ServeScratch<'a> {
        ServeScratch {
            factory: BatchFactory::new(self.ds, &self.shape),
            ring: [(Vec::new(), Vec::new()), (Vec::new(), Vec::new())],
            cur: 0,
            sur: SurrogateScratch::default(),
        }
    }

    /// Forward pass for **distinct** seeds; returns the row-major
    /// `[seeds.len(), out_dim]` prediction matrix, backed by `sc`
    /// (valid until the next call).
    pub fn forward<'s>(
        &self,
        sc: &'s mut ServeScratch<'a>,
        seeds: &[(u32, u32)],
    ) -> Result<&'s [f32]> {
        self.forward_inner(sc, seeds, None)
    }

    /// [`forward`](Self::forward) for engine-*pool* workers: sampling
    /// and assembly run unlocked in the caller's thread, but PJRT
    /// execution is serialized through `exec_lock` — a single PJRT
    /// session must never execute concurrently (the same contract the
    /// trainers keep by executing on one thread).  Callers may hold
    /// *different* locks for different sessions (`serve.sessions`
    /// hands worker `w` lock `w % sessions`), so forwards on distinct
    /// sessions run genuinely in parallel; which lock serializes a
    /// forward never changes its result.  The surrogate backend
    /// executes lock-free.
    pub fn forward_locked<'s>(
        &self,
        sc: &'s mut ServeScratch<'a>,
        seeds: &[(u32, u32)],
        exec_lock: &std::sync::Mutex<()>,
    ) -> Result<&'s [f32]> {
        self.forward_inner(sc, seeds, Some(exec_lock))
    }

    fn forward_inner<'s>(
        &self,
        sc: &'s mut ServeScratch<'a>,
        seeds: &[(u32, u32)],
        exec_lock: Option<&std::sync::Mutex<()>>,
    ) -> Result<&'s [f32]> {
        if seeds.len() > self.capacity() {
            bail!("{} seeds exceed engine capacity {}", seeds.len(), self.capacity());
        }
        sc.cur ^= 1;
        let cur = sc.cur;
        let ServeScratch { factory, ring, sur, .. } = sc;
        let (batch, touch) = &mut ring[cur];
        factory.sample_assemble_canonical_into(
            seeds,
            &self.shape,
            &self.spec,
            self.sample_seed,
            0,
            batch,
            touch,
        )?;
        let c = self.out_dim;
        match &self.backend {
            Backend::Pjrt(sess) => {
                // Poison-tolerant: the lock serializes execution, it
                // guards no data — a panicked previous holder doesn't
                // invalidate anything (error.rs policy).
                let _serial = exec_lock.map(|m| lock_ranked(m, Rank::Session));
                let outs = sess.infer_batch(batch)?;
                let rows = outs[0].as_f32()?;
                sur.out.clear();
                sur.out.extend_from_slice(&rows[..seeds.len() * c]);
            }
            Backend::Surrogate => {
                surrogate_forward(
                    &factory.block,
                    batch,
                    seeds.len(),
                    self.h_dim,
                    c,
                    &self.proj,
                    sur,
                );
            }
        }
        Ok(&sur.out[..seeds.len() * c])
    }

    /// Canonical prediction for one node (what the cache stores).
    pub fn predict_one(&self, sc: &mut ServeScratch<'a>, nt: u32, id: u32) -> Result<Vec<f32>> {
        let row = self.forward(sc, &[(nt, id)])?;
        Ok(row.to_vec())
    }

    /// Whether [`execute_block`](Self::execute_block) needs the
    /// sampled block (only the surrogate reads it — callers shipping
    /// batches across threads can skip the block clone for PJRT).
    pub fn needs_block(&self) -> bool {
        matches!(self.backend, Backend::Surrogate)
    }

    /// Execute the backend over an externally-assembled canonical
    /// batch and decode the first `n_real` target rows.  This is the
    /// consumer-thread half of the offline pipeline: workers sample +
    /// assemble (no backend access), this thread executes — the same
    /// split the trainers use, so a single PJRT session is never run
    /// concurrently.
    pub fn execute_block<'s>(
        &self,
        sc: &'s mut ServeScratch<'a>,
        block: Option<&Block>,
        batch: &[Tensor],
        n_real: usize,
    ) -> Result<&'s [f32]> {
        let c = self.out_dim;
        let sur = &mut sc.sur;
        match &self.backend {
            Backend::Pjrt(sess) => {
                let outs = sess.infer_batch(batch)?;
                let rows = outs[0].as_f32()?;
                sur.out.clear();
                sur.out.extend_from_slice(&rows[..n_real * c]);
            }
            Backend::Surrogate => {
                let block = block
                    .ok_or_else(|| anyhow!("surrogate execution needs the sampled block"))?;
                surrogate_forward(block, batch, n_real, self.h_dim, c, &self.proj, sur);
            }
        }
        Ok(&sur.out[..n_real * c])
    }

    /// Run the backend on an externally-assembled batch (the trainers'
    /// evaluation loops build their own batches with the shared-stream
    /// sampler, then execute through here).
    pub fn infer_raw(&self, batch: &[Tensor]) -> Result<Vec<Tensor>> {
        match &self.backend {
            Backend::Pjrt(sess) => sess.infer_batch(batch),
            Backend::Surrogate => bail!("surrogate backend decodes via forward(), not raw batches"),
        }
    }
}

/// Deterministic surrogate forward: sum the assembled feat/text/lemb
/// rows (plus a signed identity hash so featureless nodes still
/// separate), run one mean-aggregation pass per block layer, then
/// project the target rows with a fixed random matrix.  Every
/// target's output depends only on its own sampled tree, matching the
/// batch-independence contract of a masked GNN artifact.
fn surrogate_forward(
    block: &Block,
    batch: &[Tensor],
    n_real: usize,
    hd: usize,
    c: usize,
    proj: &[f32],
    s: &mut SurrogateScratch,
) {
    let sh = &block.shape;
    let n0 = sh.ns[0];
    s.h.clear();
    s.h.resize(n0 * hd, 0.0);
    for t in batch.iter().take(3) {
        if let Tensor::F32 { shape, data } = t {
            let dd = shape[1];
            let d = dd.min(hd);
            if d == 0 {
                continue;
            }
            for slot in 0..n0 {
                for j in 0..d {
                    s.h[slot * hd + j] += data[slot * dd + j];
                }
            }
        }
    }
    for (slot, &(nt, id)) in block.nodes.iter().enumerate() {
        if block.nmask[slot] == 0.0 {
            continue;
        }
        let hsh = crate::util::fxhash64(super::cache::cache_key(nt, id));
        let sign = if hsh >> 63 == 0 { 1.0 } else { -1.0 };
        s.h[slot * hd + (hsh as usize % hd)] += sign;
    }
    for (l, le) in block.layers.iter().enumerate() {
        let ndst = sh.ns[l + 1];
        s.acc.clear();
        s.acc.resize(ndst * hd, 0.0);
        s.deg.clear();
        s.deg.resize(ndst, 0.0);
        for e in 0..le.src.len() {
            if le.emask[e] > 0.0 {
                let sp = le.src[e] as usize;
                let dp = le.dst[e] as usize;
                for j in 0..hd {
                    s.acc[dp * hd + j] += s.h[sp * hd + j];
                }
                s.deg[dp] += 1.0;
            }
        }
        s.h2.clear();
        s.h2.resize(ndst * hd, 0.0);
        for dp in 0..ndst {
            let dg = s.deg[dp].max(1.0);
            for j in 0..hd {
                s.h2[dp * hd + j] = 0.5 * s.h[dp * hd + j] + 0.5 * s.acc[dp * hd + j] / dg;
            }
        }
        std::mem::swap(&mut s.h, &mut s.h2);
    }
    s.out.clear();
    s.out.resize(n_real * c, 0.0);
    for t in 0..n_real {
        for k in 0..c {
            let mut a = 0.0f32;
            for j in 0..hd {
                a += proj[k * hd + j] * s.h[t * hd + j];
            }
            s.out[t * c + k] = a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{self, mag};
    use crate::partition::PartitionBook;

    fn mag_ds(n: usize) -> GsDataset {
        let raw = mag::generate(&mag::MagConfig { n_papers: n, ..Default::default() });
        let book = PartitionBook::single(&raw.graph.num_nodes);
        let mut ds = datagen::build_dataset(raw, book, 64, 3);
        ds.ensure_text_features(64);
        ds
    }

    fn spec() -> ArtifactSpec {
        ArtifactSpec::synthetic_block(&[2304, 384, 64], &[1920, 320], 5, r#","batch":64"#)
            .with_output("logits", &[64, 8])
    }

    /// The serving contract: a node's prediction is identical whether
    /// served alone or micro-batched with arbitrary other nodes.
    #[test]
    fn predictions_are_batch_independent() {
        let ds = mag_ds(400);
        let engine = InferenceEngine::surrogate(&ds, &spec(), 11).unwrap();
        let mut sc = engine.make_scratch();
        let c = engine.out_dim();

        let solo = engine.predict_one(&mut sc, 0, 5).unwrap();
        assert_eq!(solo.len(), c);
        assert!(solo.iter().any(|&x| x != 0.0), "surrogate must produce signal");

        let seeds: Vec<(u32, u32)> = vec![(0, 17), (0, 5), (1, 3), (0, 200)];
        let rows = engine.forward(&mut sc, &seeds).unwrap().to_vec();
        assert_eq!(rows.len(), seeds.len() * c);
        assert_eq!(&rows[c..2 * c], &solo[..], "co-batched prediction differs from solo");

        // And stable across repeated calls (ring reuse must not leak
        // state between batches).
        let again = engine.forward(&mut sc, &seeds).unwrap().to_vec();
        assert_eq!(rows, again);
    }

    #[test]
    fn distinct_nodes_get_distinct_predictions() {
        let ds = mag_ds(400);
        let engine = InferenceEngine::surrogate(&ds, &spec(), 11).unwrap();
        let mut sc = engine.make_scratch();
        let a = engine.predict_one(&mut sc, 0, 1).unwrap();
        let b = engine.predict_one(&mut sc, 0, 2).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn duplicate_seeds_rejected() {
        let ds = mag_ds(300);
        let engine = InferenceEngine::surrogate(&ds, &spec(), 11).unwrap();
        let mut sc = engine.make_scratch();
        assert!(engine.forward(&mut sc, &[(0, 1), (0, 1)]).is_err());
    }

    #[test]
    fn generation_bumps() {
        let ds = mag_ds(300);
        let engine = InferenceEngine::surrogate(&ds, &spec(), 11).unwrap();
        assert_eq!(engine.generation(), 0);
        engine.bump_generation();
        assert_eq!(engine.generation(), 1);
    }
}
