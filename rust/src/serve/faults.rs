//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] maps micro-batch sequence numbers to faults, built
//! once before a run from a seed + a [`FaultSpec`] — the same
//! "derive everything from `(seed, index)`" convention as
//! `dataloader::batch_seed`, so two runs with the same seed inject the
//! *identical* fault schedule.  Workers consult the plan exactly once
//! per batch attempt ([`FaultPlan::take`] is one-shot per sequence
//! number): a planned worker panic fires on the first attempt and the
//! re-dispatched batch then runs clean, a transient error fails the
//! first attempt and the retry succeeds, a slow read sleeps once, a
//! fatal error fails its batch once.  That one-shot contract is what
//! makes the supervision counters (`restarts`, `retries`) match the
//! plan exactly, and — because recomputation is canonical per node —
//! replies stay bit-identical to a fault-free run.
//!
//! Wired into `gs serve-bench --faults` / the `serve.faults` config
//! key as a spec string, e.g. `"panics=2,transient=3,slow=1,slow_ms=5"`;
//! `tests/faults.rs` drives the plan directly.

use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Result};

use super::error::lock_clean;
use crate::dataloader::batch_seed;
use crate::util::{FxHashMap, FxHashSet, Rng};

/// What a planned fault does to the batch it lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The worker panics mid-batch; supervision restarts it and the
    /// coordinator re-dispatches the batch.
    WorkerPanic,
    /// The attempt fails with a retryable [`ServeError::Transient`]
    /// (`super::ServeError`); the bounded retry loop recovers.
    Transient,
    /// The attempt sleeps `slow_ms` before executing — deadline-miss
    /// fuel, never an error.
    SlowRead,
    /// The attempt fails with a non-retryable error: the batch's
    /// waiters get a typed failure and the worker scratch is rebuilt.
    Fatal,
}

/// Parsed `serve.faults` spec: how many of each fault to plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSpec {
    pub panics: usize,
    pub transient: usize,
    pub slow: usize,
    pub fatal: usize,
    /// Sleep injected by each [`FaultKind::SlowRead`], milliseconds.
    pub slow_ms: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec { panics: 0, transient: 0, slow: 0, fatal: 0, slow_ms: 5 }
    }
}

impl FaultSpec {
    /// Parse `"panics=2,transient=3,slow=1,fatal=0,slow_ms=5"`.  Every
    /// field is optional; the empty string is the all-zero spec.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((k, v)) = part.split_once('=') else {
                bail!("serve.faults: expected key=value, got '{part}'");
            };
            let v: u64 = v
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("serve.faults: '{k}' wants an integer, got '{v}'"))?;
            match k.trim() {
                "panics" => spec.panics = v as usize,
                "transient" => spec.transient = v as usize,
                "slow" => spec.slow = v as usize,
                "fatal" => spec.fatal = v as usize,
                "slow_ms" => spec.slow_ms = v,
                other => bail!(
                    "serve.faults: unknown field '{other}' \
                     (expected panics/transient/slow/fatal/slow_ms)"
                ),
            }
        }
        Ok(spec)
    }

    /// Total faults planned (one batch each).
    pub fn total(&self) -> usize {
        self.panics + self.transient + self.slow + self.fatal
    }
}

/// A seeded schedule of faults keyed by batch sequence number.  Shared
/// by reference with every pool worker; `take` is one-shot per seq so
/// a re-dispatched or retried batch runs clean.
#[derive(Debug)]
pub struct FaultPlan {
    by_seq: FxHashMap<u64, FaultKind>,
    fired: Mutex<FxHashSet<u64>>,
    /// Sleep for [`FaultKind::SlowRead`] injections.
    pub slow: Duration,
    /// The spec this plan was generated from (counter expectations).
    pub spec: FaultSpec,
}

impl FaultPlan {
    /// Plan `spec.total()` faults over batch sequence numbers
    /// `[0, horizon)`, each on a distinct batch, deterministically
    /// from `seed` (via the `batch_seed` convention).  `horizon` must
    /// be a *lower bound* on the number of batches the run will cut —
    /// the deadline clock can only split batches, never merge them —
    /// so every planned fault is guaranteed to fire.
    pub fn generate(seed: u64, horizon: u64, spec: &FaultSpec) -> Result<FaultPlan> {
        if (spec.total() as u64) > horizon {
            bail!(
                "fault plan wants {} faults but only {horizon} batches are guaranteed \
                 (lower the fault counts or raise the request count)",
                spec.total()
            );
        }
        // Partial Fisher-Yates over [0, horizon): the first `total()`
        // slots after shuffling are the fault indices, all distinct.
        let mut rng = Rng::seed_from(batch_seed(seed, 0xFA17, 0));
        let mut idx: Vec<u64> = (0..horizon).collect();
        let total = spec.total();
        for i in 0..total.min(idx.len().saturating_sub(1)) {
            let j = i + rng.gen_range(idx.len() - i);
            idx.swap(i, j);
        }
        let mut by_seq = FxHashMap::default();
        let mut it = idx.into_iter();
        let mut assign = |n: usize, kind: FaultKind| {
            for _ in 0..n {
                if let Some(s) = it.next() {
                    by_seq.insert(s, kind);
                }
            }
        };
        assign(spec.panics, FaultKind::WorkerPanic);
        assign(spec.transient, FaultKind::Transient);
        assign(spec.slow, FaultKind::SlowRead);
        assign(spec.fatal, FaultKind::Fatal);
        Ok(FaultPlan {
            by_seq,
            fired: Mutex::new(FxHashSet::default()),
            slow: Duration::from_millis(spec.slow_ms),
            spec: spec.clone(),
        })
    }

    /// Exact placement for tests: fault `kind` on each listed batch.
    pub fn precise(entries: &[(u64, FaultKind)], slow: Duration) -> FaultPlan {
        let mut spec = FaultSpec { slow_ms: slow.as_millis() as u64, ..FaultSpec::default() };
        let mut by_seq = FxHashMap::default();
        for &(seq, kind) in entries {
            if by_seq.insert(seq, kind).is_none() {
                match kind {
                    FaultKind::WorkerPanic => spec.panics += 1,
                    FaultKind::Transient => spec.transient += 1,
                    FaultKind::SlowRead => spec.slow += 1,
                    FaultKind::Fatal => spec.fatal += 1,
                }
            }
        }
        FaultPlan { by_seq, fired: Mutex::new(FxHashSet::default()), slow, spec }
    }

    /// The fault planned for batch `seq`, armed at most once: the
    /// first caller gets it, every later call (retry, re-dispatch)
    /// sees a clean batch.
    pub fn take(&self, seq: u64) -> Option<FaultKind> {
        let kind = *self.by_seq.get(&seq)?;
        if lock_clean(&self.fired).insert(seq) {
            Some(kind)
        } else {
            None
        }
    }

    /// How many planned faults have fired so far.
    pub fn fired(&self) -> usize {
        lock_clean(&self.fired).len()
    }

    /// Batches with a planned fault (for logs/tests).
    pub fn planned(&self) -> usize {
        self.by_seq.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_rejects() {
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
        let s = FaultSpec::parse("panics=2, transient=3,slow=1,fatal=1,slow_ms=20").unwrap();
        assert_eq!(
            s,
            FaultSpec { panics: 2, transient: 3, slow: 1, fatal: 1, slow_ms: 20 }
        );
        assert_eq!(s.total(), 7);
        assert!(FaultSpec::parse("panics=two").is_err());
        assert!(FaultSpec::parse("explosions=1").is_err());
        assert!(FaultSpec::parse("panics").is_err());
    }

    #[test]
    fn generate_is_deterministic_and_distinct() {
        let spec = FaultSpec::parse("panics=3,transient=4,slow=2,fatal=1").unwrap();
        let a = FaultPlan::generate(7, 64, &spec).unwrap();
        let b = FaultPlan::generate(7, 64, &spec).unwrap();
        assert_eq!(a.planned(), spec.total(), "distinct batches per fault");
        let mut av: Vec<_> = a.by_seq.iter().map(|(&s, &k)| (s, k)).collect();
        let mut bv: Vec<_> = b.by_seq.iter().map(|(&s, &k)| (s, k)).collect();
        av.sort_by_key(|&(s, _)| s);
        bv.sort_by_key(|&(s, _)| s);
        assert_eq!(av, bv, "same seed, same plan");
        assert!(av.iter().all(|&(s, _)| s < 64));
        let c = FaultPlan::generate(8, 64, &spec).unwrap();
        let mut cv: Vec<_> = c.by_seq.iter().map(|(&s, &k)| (s, k)).collect();
        cv.sort_by_key(|&(s, _)| s);
        assert_ne!(av, cv, "different seed, different plan");
    }

    #[test]
    fn generate_rejects_overfull_horizon() {
        let spec = FaultSpec::parse("panics=5").unwrap();
        assert!(FaultPlan::generate(1, 4, &spec).is_err());
        assert!(FaultPlan::generate(1, 5, &spec).is_ok());
    }

    #[test]
    fn take_is_one_shot() {
        let plan =
            FaultPlan::precise(&[(3, FaultKind::WorkerPanic)], Duration::from_millis(1));
        assert_eq!(plan.take(0), None);
        assert_eq!(plan.take(3), Some(FaultKind::WorkerPanic));
        assert_eq!(plan.take(3), None, "retry / re-dispatch runs clean");
        assert_eq!(plan.fired(), 1);
        assert_eq!(plan.spec.panics, 1);
    }
}
