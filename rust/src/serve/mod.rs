//! Online inference serving (the paper's missing third pillar).
//!
//! GraphStorm pitches graph construction, training **and inference**;
//! this module turns the pipelined mini-batch engine into a
//! request-driven serving layer, following the two industrial designs
//! in PAPERS.md: GiGL's decoupled offline embedding tables consumed by
//! low-latency lookups, and AGL's K-hop neighborhood extraction as the
//! unit of inference work.
//!
//! * [`engine::InferenceEngine`] — the forward-only path extracted
//!   from the NC/LP trainers: sample a K-hop block around the
//!   requested seeds (canonical per-node RNG, so predictions are
//!   batch-independent), assemble inputs through the recycled-buffer
//!   ring, execute the `*_infer` artifact (or the deterministic
//!   surrogate when PJRT is unavailable) and decode per-target rows.
//! * [`cache::EmbeddingCache`] — generation-stamped LRU so hot nodes
//!   (power-law traffic) skip sampling entirely; the same
//!   [`cache::RowSource`] read-through trait wraps `dist::EmbTable`
//!   lookups so learnable-embedding models serve too.
//!   [`cache::ShardedCache`] stripes it N ways (`serve.shards`) —
//!   per-stripe locks keyed by `shard_of(key)`, a merged `hot_keys`
//!   recency view for the refresher, replies and hit/miss accounting
//!   bit-identical for any shard count.
//! * [`batcher::MicroBatcher`] — coalesces concurrent single-node
//!   requests into size/deadline-bounded micro-batches.
//! * [`pool::EnginePool`] — N engine scratches draining one shared
//!   micro-batcher queue (coordinator/worker scoped threads), with
//!   replies bit-identical for any pool size.
//! * [`refresh`] — background hot-row re-read after a generation bump,
//!   so a model/embedding update doesn't turn into a miss storm.
//! * [`offline::OfflineInference`] — streams the full node set through
//!   the prefetch pipeline and writes sharded GSTF embedding files,
//!   the GiGL-style precompute the cache warms from.
//! * [`http`] — the HTTP/1.1 network front end (`gs serve`) putting a
//!   socket boundary in front of the engine pool, plus the closed-loop
//!   load generator (`gs load-bench`) that drives it.

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod error;
pub mod faults;
pub mod http;
pub mod offline;
pub mod pool;
pub mod refresh;

pub use batcher::{ClosedLoopStats, MicroBatcher, MicroBatcherCfg, ServeRequest};
pub use cache::{
    cache_key, shard_of, split_key, Admission, EmbTableSource, EmbeddingCache, RowSource,
    ShardedCache,
};
pub use engine::{InferenceEngine, ServeScratch};
pub use error::{lock_cache, lock_clean, lock_shard, ServeError};
pub use faults::{FaultKind, FaultPlan, FaultSpec};
pub use http::{
    run_load_bench, HttpReport, HttpServer, HttpServerCfg, LoadBenchCfg, LoadBenchReport,
    ShutdownHandle,
};
pub use offline::{read_shards, OfflineInference, OfflineReport};
pub use pool::{closed_loop, closed_loop_with_faults, EnginePool, EnginePoolCfg};
pub use refresh::{refresh_hot_rows, refresh_loop, EngineSource, RefreshCfg, RefreshStats};

use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::metrics;
use crate::util::{FxHashMap, FxHashSet, Rng};

/// Parameters for the canonical closed-loop serving benchmark
/// (`gs serve-bench` / the `serve` pipeline stage): a Zipf trace is
/// replayed uncached, then again over a warmed cache — and, with
/// `refresh > 0`, a third time after a mid-bench generation bump plus
/// a background-style hot-row refresh.  Predictions must be
/// bit-identical across every arm.
#[derive(Debug, Clone)]
pub struct ServeBenchParams {
    pub seed: u64,
    pub requests: usize,
    pub alpha: f64,
    pub clients: usize,
    /// Warmed-arm cache capacity (rows).
    pub cache: usize,
    /// Cache stripes (`serve.shards`): every arm's cache is a
    /// [`ShardedCache`] with this many independently locked shards.
    /// Replies and hit/miss accounting are bit-identical for any
    /// value — asserted by `tests/sharding.rs`.
    pub shards: usize,
    /// Admission policy of the warmed-arm cache.
    pub admission: Admission,
    /// Engine-pool size + micro-batching policy (all arms share it).
    pub pool: EnginePoolCfg,
    /// Hot rows to re-read after the mid-bench generation bump; 0
    /// skips the refreshed arm.
    pub refresh: usize,
    /// Deterministic fault schedule injected into the *uncached* arm
    /// (the one doing compute), from `serve.faults` /
    /// `gs serve-bench --faults`.  `None` or an all-zero spec runs
    /// clean.
    pub faults: Option<FaultSpec>,
}

#[derive(Debug, Clone, Default)]
pub struct ServeBenchReport {
    pub uncached: ClosedLoopStats,
    pub warmed: ClosedLoopStats,
    /// Third arm: replay after `bump_generation` + hot-row refresh
    /// (present iff `refresh > 0`).
    pub refreshed: Option<ClosedLoopStats>,
    /// Rows the refresh pass re-read before the third arm.
    pub refreshed_rows: usize,
    /// Distinct seeds in the trace (the warm-up working set).
    pub distinct: usize,
    /// Faults planned for the uncached arm (0 when running clean).
    pub planned_faults: usize,
    /// Every prediction identical across arms and repeats.
    pub identical: bool,
}

/// Run the closed-loop bench over `engine`'s dataset: Zipf traffic
/// over the target node type through the engine pool, one uncached
/// arm, then a warmed-cache arm over the same trace (the warm-up
/// stores the canonical prediction of every distinct node, batched to
/// engine capacity — canonical sampling makes those rows bit-identical
/// to per-node recompute).  With `refresh > 0` the engine generation
/// is bumped (simulating a model update), the hot rows are re-read
/// through [`EngineSource`], and the trace replays a third time — the
/// miss storm the background refresher exists to prevent.
pub fn run_serve_bench(
    engine: &InferenceEngine,
    p: &ServeBenchParams,
) -> Result<ServeBenchReport> {
    let ds = engine.ds;
    let nt = ds.target_ntype as u32;
    let n_nodes = ds.graph.num_nodes[nt as usize];
    let zipf = Zipf::new(n_nodes, p.alpha);
    let mut rng = Rng::seed_from(p.seed ^ 0x5e12);
    let trace: Vec<(u32, u32)> =
        (0..p.requests).map(|_| (nt, zipf.sample(&mut rng) as u32)).collect();
    let mut seen = FxHashSet::default();
    let distinct: Vec<(u32, u32)> = trace.iter().filter(|&&q| seen.insert(q)).copied().collect();

    // Faults go into the uncached arm: the one actually cutting
    // batches.  The plan horizon is the guaranteed lower bound on
    // batch count — every distinct key contributes at least one seed
    // to some batch, and batches hold at most `cap` seeds.
    let plan = match &p.faults {
        Some(spec) if spec.total() > 0 => {
            if spec.fatal > 0 {
                anyhow::bail!(
                    "serve.faults: fatal faults abort closed-loop replies by design; \
                     use panics/transient/slow here (tests/faults.rs exercises fatal)"
                );
            }
            let cap = p.pool.batcher.max_batch.min(engine.capacity()).max(1);
            let horizon = (distinct.len() as u64).div_ceil(cap as u64);
            Some(FaultPlan::generate(p.seed, horizon, spec)?)
        }
        _ => None,
    };

    let nocache = ShardedCache::new(0, p.shards);
    let (uncached, replies0) =
        closed_loop_with_faults(engine, p.pool.clone(), &nocache, &trace, p.clients, plan.as_ref())?;
    // Each arm publishes its ClosedLoopStats verbatim into the metrics
    // registry — `--stats` / `gs stats` counters match the bench report
    // by construction (asserted in tests/obs.rs).
    metrics::publish(metrics::closed_loop_snapshot("serve.uncached", &uncached));

    let cache = ShardedCache::with_admission(p.cache, p.shards, p.admission);
    {
        cache.set_generation(engine.generation());
        let mut sc = engine.make_scratch();
        let c = engine.out_dim();
        for chunk in distinct.chunks(engine.capacity()) {
            // Forward outside any stripe lock; each put locks only the
            // stripe owning its key.
            let rows = engine.forward(&mut sc, chunk)?;
            for (i, &(nt, id)) in chunk.iter().enumerate() {
                cache.put(cache_key(nt, id), &rows[i * c..(i + 1) * c]);
            }
        }
    }
    let (warmed, replies1) =
        closed_loop(engine, p.pool.clone(), &cache, &trace, p.clients)?;
    metrics::publish(metrics::closed_loop_snapshot("serve.warmed", &warmed));

    let mut refreshed = None;
    let mut refreshed_rows = 0usize;
    let mut replies2 = Vec::new();
    if p.refresh > 0 {
        // A model update lands mid-serve: every cached row goes stale
        // at once.  Re-read the hot set before replaying.
        engine.bump_generation();
        let mut src = EngineSource::new(engine);
        refreshed_rows = refresh_hot_rows(&cache, &mut src, p.refresh)?;
        let (r, rr) = closed_loop(engine, p.pool.clone(), &cache, &trace, p.clients)?;
        metrics::publish(metrics::closed_loop_snapshot("serve.refreshed", &r));
        metrics::counter_set("serve.refreshed.rows_refreshed", refreshed_rows as u64);
        refreshed = Some(r);
        replies2 = rr;
    }

    let mut expected: FxHashMap<(u32, u32), Vec<f32>> = Default::default();
    let mut identical = true;
    for (k, v) in replies0.into_iter().chain(replies1).chain(replies2) {
        identical &= expected.entry(k).or_insert_with(|| v.clone()) == &v;
    }
    Ok(ServeBenchReport {
        uncached,
        warmed,
        refreshed,
        refreshed_rows,
        distinct: distinct.len(),
        planned_faults: plan.as_ref().map(|pl| pl.planned()).unwrap_or(0),
        identical,
    })
}

/// Lock-free log₂-bucketed latency histogram (microsecond buckets:
/// bucket *i* holds durations in `[2^(i-1), 2^i) µs`).  Percentiles
/// report the bucket's upper bound, so p50/p99 are conservative within
/// a factor of two — plenty for serving dashboards.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; 64],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    pub fn record(&self, d: std::time::Duration) {
        let us = (d.as_micros() as u64).max(1);
        let idx = (64 - us.leading_zeros() as usize).min(63);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Upper bound (µs) of the bucket containing the p-th percentile.
    /// Total-order over edge cases: an empty histogram reports `0.0`,
    /// and any `p >= 1.0` (or a concurrent-count race that walks past
    /// the last populated bucket) reports the max-bucket upper bound —
    /// never an out-of-range index or `inf` leaking into dashboards.
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((p * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << i) as f64;
            }
        }
        // Unreachable when counts are stable (target <= total), but a
        // racing writer can move `count()` between the two reads —
        // answer with the top bucket's bound instead of infinity.
        (1u64 << 63) as f64
    }

    pub fn p50_us(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p99_us(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Per-request serving counters: latency histogram + cache hit/miss +
/// the robustness counters the supervised pool maintains.
/// `coalesced` is a *subset* of `hits`: requests that joined an
/// in-flight pool batch instead of triggering their own compute.
/// `restarts` counts supervision events that discarded a worker
/// scratch (panic or fatal batch error), `retries` counts re-executed
/// batch attempts after retryable errors, and `shed` /
/// `deadline_misses` count the two typed rejections.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub latency: LatencyHistogram,
    /// Per-stage breakdown of the pool path: time a batch spent queued
    /// (dispatch → worker dequeue) and executing (forward + decode).
    /// Always-on like `latency` — lock-free atomics, no tracing needed.
    pub queue_us: LatencyHistogram,
    pub exec_us: LatencyHistogram,
    batches: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    restarts: AtomicU64,
    retries: AtomicU64,
    shed: AtomicU64,
    deadline_misses: AtomicU64,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// One pool batch executed (any attempt outcome).
    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// A request whose key was already in flight: counted as a hit
    /// (no extra backend work) and tracked separately.  The hit/miss
    /// totals are pool-size invariant under a non-evicting cache; the
    /// hit/coalesced split depends on completion timing.
    pub fn record_coalesced(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.coalesced.fetch_add(1, Ordering::Relaxed);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    /// A worker scratch was discarded and rebuilt (panic or fatal
    /// batch error) — includes the final event that retires a worker
    /// whose restart budget is spent.
    pub fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// A batch attempt failed with a retryable error and was re-run.
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was rejected at the queue boundary
    /// ([`ServeError::Overloaded`]).  Shed requests count in neither
    /// `hits` nor `misses`: they never entered the serving path.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A request's deadline elapsed before its reply
    /// ([`ServeError::DeadlineExceeded`]).
    pub fn record_deadline_miss(&self) {
        self.deadline_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn deadline_misses(&self) -> u64 {
        self.deadline_misses.load(Ordering::Relaxed)
    }

    pub fn served(&self) -> u64 {
        self.hits() + self.misses()
    }

    pub fn hit_rate(&self) -> f64 {
        let s = self.served();
        if s == 0 {
            0.0
        } else {
            self.hits() as f64 / s as f64
        }
    }
}

/// Zipf-distributed rank sampler for synthetic serving traffic
/// (`P(rank r) ∝ 1/r^alpha`) — the power-law request mix the
/// embedding cache is designed for.
pub struct Zipf {
    cum: Vec<f64>,
    total: f64,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Zipf {
        assert!(n > 0);
        let mut cum = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 1..=n {
            acc += 1.0 / (r as f64).powf(alpha);
            cum.push(acc);
        }
        Zipf { cum, total: acc }
    }

    /// Sample a rank in `[0, n)` (rank 0 is the hottest).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.gen_f64() * self.total;
        self.cum.partition_point(|&c| c < x).min(self.cum.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn histogram_percentiles_bracket() {
        let h = LatencyHistogram::new();
        for _ in 0..99 {
            h.record(Duration::from_micros(100));
        }
        h.record(Duration::from_micros(100_000));
        assert_eq!(h.count(), 100);
        let p50 = h.p50_us();
        assert!((64.0..=256.0).contains(&p50), "p50={p50}");
        let p99 = h.percentile(0.99);
        assert!(p99 <= 256.0, "p99 bucket must exclude the single outlier, got {p99}");
        assert!(h.percentile(1.0) >= 100_000.0);
        assert_eq!(LatencyHistogram::new().p99_us(), 0.0);
    }

    #[test]
    fn histogram_empty_percentiles_are_zero() {
        // The HTTP load harness reports these on idle/error-only runs:
        // an empty histogram must be defined at every p, including the
        // edges.
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile(0.0), 0.0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.percentile(1.0), 0.0);
        assert_eq!(h.percentile(2.0), 0.0);
    }

    #[test]
    fn histogram_p_at_or_above_one_is_max_bucket_bound() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(3));
        h.record(Duration::from_micros(700));
        // p=1.0 and any overshoot clamp to the last recorded bucket's
        // upper bound — finite, never an out-of-range bucket index.
        let top = h.percentile(1.0);
        assert!((512.0..=2048.0).contains(&top), "top={top}");
        assert_eq!(h.percentile(1.5), top);
        assert_eq!(h.percentile(100.0), top);
        assert!(h.percentile(1.0).is_finite());
        // Max-bucket durations stay finite too.
        let big = LatencyHistogram::new();
        big.record(Duration::from_micros(u64::MAX));
        assert_eq!(big.percentile(1.0), (1u64 << 63) as f64);
    }

    #[test]
    fn zipf_is_head_heavy_and_in_range() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Rng::seed_from(3);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            let r = z.sample(&mut rng);
            assert!(r < 1000);
            if r < 10 {
                head += 1;
            }
        }
        // With alpha=1.1, the top-10 ranks carry a large share.
        assert!(head > n / 4, "head draws {head}/{n}");
    }

    #[test]
    fn metrics_hit_rate() {
        let m = ServeMetrics::new();
        m.record_hit();
        m.record_hit();
        m.record_miss();
        assert_eq!(m.served(), 3);
        assert!((m.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }
}
