//! Negative sampling for link prediction — the paper's Appendix A.2.1:
//! uniform, joint, local-joint and in-batch samplers.
//!
//! The samplers differ in *how many distinct negative nodes* enter the
//! mini-batch, which drives both the block size (seed slots) and the
//! cross-partition traffic — the mechanism behind Table 6's epoch-time
//! column.  Seed layout produced here:
//!
//!   [src_0 .. src_{B-1}, dst_0 .. dst_{B-1}, neg nodes ...]
//!
//! `neg_dst[b][k]` indexes into those seed slots.

use crate::partition::PartitionBook;
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NegSampler {
    /// K fresh uniform nodes per positive: B*K negative seeds.
    Uniform { k: usize },
    /// K nodes shared across the whole batch (DGL's joint sampling).
    Joint { k: usize },
    /// Joint, but drawn from the coordinator's own partition.
    LocalJoint { k: usize },
    /// Destinations of other positives in the batch; no extra seeds.
    InBatch { k: usize },
}

impl NegSampler {
    pub fn k(&self) -> usize {
        match *self {
            NegSampler::Uniform { k }
            | NegSampler::Joint { k }
            | NegSampler::LocalJoint { k }
            | NegSampler::InBatch { k } => k,
        }
    }

    /// Distinct negative seed nodes this sampler adds to a batch of B.
    pub fn extra_seeds(&self, batch: usize) -> usize {
        match *self {
            NegSampler::Uniform { k } => batch * k,
            NegSampler::Joint { k } | NegSampler::LocalJoint { k } => k,
            NegSampler::InBatch { .. } => 0,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            NegSampler::Uniform { k } => format!("uniform-{k}"),
            NegSampler::Joint { k } => format!("joint-{k}"),
            NegSampler::LocalJoint { k } => format!("local-joint-{k}"),
            NegSampler::InBatch { .. } => "in-batch".to_string(),
        }
    }
}

/// The sampled negatives for one batch of B positive edges.
#[derive(Debug, Clone)]
pub struct NegativeBatch {
    /// Extra seed nodes (dst-ntype local ids) appended after 2B slots.
    pub neg_nodes: Vec<u32>,
    /// [B][K] indices into the seed slot array.
    pub neg_dst: Vec<Vec<i32>>,
}

/// Sample negatives for B positives with destination type `dst_ntype`
/// of `n_dst` nodes.  `worker` matters for `LocalJoint` (its partition's
/// nodes) and is the partition counted against for traffic elsewhere.
pub fn sample_negatives(
    sampler: NegSampler,
    batch: usize,
    n_dst: usize,
    dst_ntype: usize,
    book: &PartitionBook,
    worker: u32,
    rng: &mut Rng,
) -> NegativeBatch {
    let k = sampler.k();
    match sampler {
        NegSampler::Uniform { .. } => {
            let mut neg_nodes = Vec::with_capacity(batch * k);
            let mut neg_dst = Vec::with_capacity(batch);
            for b in 0..batch {
                let mut row = Vec::with_capacity(k);
                for j in 0..k {
                    neg_nodes.push(rng.gen_range(n_dst) as u32);
                    row.push((2 * batch + b * k + j) as i32);
                }
                neg_dst.push(row);
            }
            NegativeBatch { neg_nodes, neg_dst }
        }
        NegSampler::Joint { .. } => {
            let neg_nodes: Vec<u32> = (0..k).map(|_| rng.gen_range(n_dst) as u32).collect();
            let row: Vec<i32> = (0..k).map(|j| (2 * batch + j) as i32).collect();
            NegativeBatch { neg_nodes, neg_dst: vec![row; batch] }
        }
        NegSampler::LocalJoint { .. } => {
            let local = book.nodes_of(dst_ntype, worker);
            let pool = if local.is_empty() {
                (0..n_dst as u32).collect::<Vec<_>>()
            } else {
                local
            };
            let neg_nodes: Vec<u32> =
                (0..k).map(|_| pool[rng.gen_range(pool.len())]).collect();
            let row: Vec<i32> = (0..k).map(|j| (2 * batch + j) as i32).collect();
            NegativeBatch { neg_nodes, neg_dst: vec![row; batch] }
        }
        NegSampler::InBatch { .. } => {
            // Exchange destinations between positives (Appendix A.2.1).
            let mut neg_dst = Vec::with_capacity(batch);
            for b in 0..batch {
                let mut row = Vec::with_capacity(k);
                if batch > 1 {
                    for _ in 0..k {
                        let mut other = rng.gen_range(batch - 1);
                        if other >= b {
                            other += 1;
                        }
                        row.push((batch + other) as i32); // other's dst slot
                    }
                } else {
                    row.resize(k, batch as i32);
                }
                neg_dst.push(row);
            }
            NegativeBatch { neg_nodes: vec![], neg_dst }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn book(n: usize, parts: usize) -> PartitionBook {
        PartitionBook::new(parts, vec![(0..n).map(|i| (i % parts) as u32).collect()])
    }

    #[test]
    fn seed_counts_match_sampler() {
        let bk = book(100, 4);
        let mut rng = Rng::seed_from(0);
        for (s, want) in [
            (NegSampler::Uniform { k: 8 }, 16 * 8),
            (NegSampler::Joint { k: 8 }, 8),
            (NegSampler::LocalJoint { k: 8 }, 8),
            (NegSampler::InBatch { k: 8 }, 0),
        ] {
            let nb = sample_negatives(s, 16, 100, 0, &bk, 0, &mut rng);
            assert_eq!(nb.neg_nodes.len(), want, "{}", s.label());
            assert_eq!(nb.neg_dst.len(), 16);
            assert!(nb.neg_dst.iter().all(|r| r.len() == 8));
        }
    }

    #[test]
    fn in_batch_never_uses_own_dst() {
        let bk = book(50, 1);
        let mut rng = Rng::seed_from(1);
        let nb = sample_negatives(NegSampler::InBatch { k: 4 }, 8, 50, 0, &bk, 0, &mut rng);
        for (b, row) in nb.neg_dst.iter().enumerate() {
            for &slot in row {
                assert!(slot >= 8 && slot < 16, "must point at a dst slot");
                assert_ne!(slot as usize, 8 + b, "positive {b} used its own dst");
            }
        }
    }

    #[test]
    fn local_joint_stays_on_partition() {
        let bk = book(100, 4);
        let mut rng = Rng::seed_from(2);
        let nb = sample_negatives(NegSampler::LocalJoint { k: 16 }, 4, 100, 0, &bk, 2, &mut rng);
        for &id in &nb.neg_nodes {
            assert_eq!(bk.part_of(0, id), 2);
        }
    }

    #[test]
    fn uniform_rows_are_private() {
        let bk = book(100, 1);
        let mut rng = Rng::seed_from(3);
        let nb = sample_negatives(NegSampler::Uniform { k: 3 }, 4, 100, 0, &bk, 0, &mut rng);
        // Each positive's slots are disjoint from the others'.
        let mut seen = std::collections::HashSet::new();
        for row in &nb.neg_dst {
            for &s in row {
                assert!(seen.insert(s));
            }
        }
    }
}
