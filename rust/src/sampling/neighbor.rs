//! On-the-fly inbound neighbor sampling over the partitioned graph.
//!
//! Degree-proportional across edge types: for each destination node the
//! per-hop budget is `fanout` edges *total*; if the combined in-degree
//! fits the budget all edges are taken, otherwise `fanout` distinct
//! positions are drawn from the concatenated neighbor ranges.  This
//! bounds every hop at `ns[l+1] * fanout` edges — exactly the padded
//! shape the AOT artifacts were lowered with.
//!
//! The hot path is allocation-free in steady state: callers thread a
//! reusable [`SamplerScratch`] (generation-stamped open-addressing slot
//! table + pick/position buffers) and a reusable [`Block`] through
//! [`NeighborSampler::sample_block_with`].  Edge exclusion is a sorted
//! slice lookup instead of a hash set, with the large val/test-edge
//! portion shared across batches behind an `Arc`.

use std::sync::Arc;

use crate::graph::HeteroGraph;
use crate::sampling::block::{Block, BlockShape};
use crate::util::{fxhash64, Rng};

/// Edges excluded from message passing: the batch's own target edges
/// (anti-overfitting) and validation/test edges (anti-leakage), per the
/// paper §3.3.4 / SpotTarget.  Stored as sorted `(etype, src, dst)`
/// slices: a shared, pre-sorted `base` (the per-dataset val/test edges,
/// built once) plus a small per-batch list.
#[derive(Default, Clone)]
pub struct EdgeExclusion {
    /// Pre-sorted, deduplicated; shared across batches.
    base: Option<Arc<Vec<(u32, u32, u32)>>>,
    /// Per-batch triples; sorted once `seal` has run.
    batch: Vec<(u32, u32, u32)>,
    sorted: bool,
}

impl EdgeExclusion {
    pub fn new() -> EdgeExclusion {
        EdgeExclusion { base: None, batch: vec![], sorted: true }
    }

    /// Sort + dedup a triple list into a shareable base exclusion.
    pub fn sorted_base(mut triples: Vec<(u32, u32, u32)>) -> Arc<Vec<(u32, u32, u32)>> {
        triples.sort_unstable();
        triples.dedup();
        Arc::new(triples)
    }

    /// Start from a shared pre-sorted base (e.g. all val/test edges).
    pub fn with_base(base: Arc<Vec<(u32, u32, u32)>>) -> EdgeExclusion {
        debug_assert!(base.windows(2).all(|w| w[0] < w[1]), "base must be sorted+deduped");
        EdgeExclusion { base: Some(base), batch: vec![], sorted: true }
    }

    pub fn insert(&mut self, etype: u32, src: u32, dst: u32) {
        self.batch.push((etype, src, dst));
        self.sorted = false;
    }

    /// Also exclude the reverse orientation under `rev_etype`.
    pub fn insert_with_reverse(&mut self, etype: u32, rev_etype: Option<u32>, src: u32, dst: u32) {
        self.insert(etype, src, dst);
        if let Some(re) = rev_etype {
            self.insert(re, dst, src);
        }
    }

    /// Sort the per-batch list so lookups binary-search.  Callers on
    /// the hot path should seal after the last `insert`; an unsealed
    /// list still works via linear scan (fine for a handful of edges).
    pub fn seal(&mut self) {
        if !self.sorted {
            self.batch.sort_unstable();
            self.batch.dedup();
            self.sorted = true;
        }
    }

    #[inline]
    pub fn contains(&self, etype: u32, src: u32, dst: u32) -> bool {
        if self.is_empty() {
            return false;
        }
        let key = (etype, src, dst);
        if let Some(base) = &self.base {
            if base.binary_search(&key).is_ok() {
                return true;
            }
        }
        if self.sorted {
            self.batch.binary_search(&key).is_ok()
        } else {
            self.batch.contains(&key)
        }
    }

    pub fn len(&self) -> usize {
        self.base.as_ref().map_or(0, |b| b.len()) + self.batch.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batch.is_empty() && self.base.as_ref().map_or(true, |b| b.is_empty())
    }
}

/// Generation-stamped open-addressing map from packed `(ntype, id)`
/// keys to node slots.  `begin` invalidates all entries in O(1), so
/// steady-state sampling never clears or reallocates.
#[derive(Default)]
struct SlotTable {
    keys: Vec<u64>,
    vals: Vec<i32>,
    stamp: Vec<u32>,
    gen: u32,
    mask: usize,
}

impl SlotTable {
    fn new() -> SlotTable {
        SlotTable { keys: vec![], vals: vec![], stamp: vec![], gen: 0, mask: 0 }
    }

    /// Start a fresh mapping with room for `n` keys at ≤ 0.5 load.
    fn begin(&mut self, n: usize) {
        let want = (2 * n.max(8)).next_power_of_two();
        if self.keys.len() < want {
            self.keys = vec![0; want];
            self.vals = vec![0; want];
            self.stamp = vec![0; want];
            self.mask = want - 1;
            self.gen = 0;
        }
        self.gen = self.gen.wrapping_add(1);
        if self.gen == 0 {
            // Stamp wrap-around: clear once every 2^32 batches.
            self.stamp.fill(0);
            self.gen = 1;
        }
    }

    /// Slot for `key`, inserting `make()`'s value on first sight.
    #[inline]
    fn get_or_insert_with(&mut self, key: u64, make: impl FnOnce() -> i32) -> i32 {
        let mut i = (fxhash64(key) as usize) & self.mask;
        loop {
            if self.stamp[i] != self.gen {
                let v = make();
                self.stamp[i] = self.gen;
                self.keys[i] = key;
                self.vals[i] = v;
                return v;
            }
            if self.keys[i] == key {
                return self.vals[i];
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Value for `key` if present in the current generation.
    #[inline]
    fn get(&self, key: u64) -> Option<i32> {
        if self.keys.is_empty() {
            return None;
        }
        let mut i = (fxhash64(key) as usize) & self.mask;
        loop {
            if self.stamp[i] != self.gen {
                return None;
            }
            if self.keys[i] == key {
                return Some(self.vals[i]);
            }
            i = (i + 1) & self.mask;
        }
    }
}

#[inline]
fn pack(nt: u32, id: u32) -> u64 {
    ((nt as u64) << 32) | id as u64
}

/// Deterministic per-node sampling seed: depends only on the engine
/// seed and the node identity, never on batch composition.  The serving
/// layer samples every destination's neighbors from this seed (with
/// the hop index mixed into `base`, see [`hop_base`]), so a node's
/// K-hop tree — and therefore its prediction — is identical whether it
/// is served alone, micro-batched with other nodes, or precomputed by
/// the offline inference writer.
#[inline]
pub fn node_sample_seed(base: u64, nt: u32, id: u32) -> u64 {
    let mut s = base ^ pack(nt, id).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    crate::util::splitmix64(&mut s)
}

/// Mix the hop index into the canonical base seed: a node expanded at
/// several hops (targets are destinations at every hop) draws an
/// independent neighbor subset per hop, matching the training
/// sampler's per-hop redraws, while each (hop, node) subset stays a
/// pure function of the base seed.
#[inline]
pub fn hop_base(base: u64, layer: usize) -> u64 {
    base ^ (layer as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03)
}

/// Where each destination's sampling randomness comes from.
enum HopRng<'r> {
    /// One shared stream advanced across destinations (training: the
    /// per-batch RNG derived from `batch_seed`).
    Shared(&'r mut Rng),
    /// A fresh stream per destination derived from
    /// [`node_sample_seed`] (serving: batch-independent trees).
    PerNode(u64),
}

/// Reusable first-seen index over `(ntype, id)` seed pairs, backed by
/// the same generation-stamped Fx slot table the sampler uses — dedup
/// and slot lookup are O(1) per key with zero steady-state allocation
/// (the ROADMAP's replacement for `Vec::contains` / `position()` in LP
/// evaluation).
#[derive(Default)]
pub struct SeedIndex {
    slots: SlotTable,
}

impl SeedIndex {
    pub fn new() -> SeedIndex {
        SeedIndex { slots: SlotTable::new() }
    }

    /// Invalidate all entries in O(1) and reserve room for `n` keys.
    pub fn begin(&mut self, n: usize) {
        self.slots.begin(n);
    }

    /// Slot of `(nt, id)`, assigning `next` on first sight; returns
    /// `(slot, inserted)`.
    pub fn get_or_insert(&mut self, nt: u32, id: u32, next: usize) -> (usize, bool) {
        let mut fresh = false;
        let s = self.slots.get_or_insert_with(pack(nt, id), || {
            fresh = true;
            next as i32
        });
        (s as usize, fresh)
    }

    /// Slot of `(nt, id)` if it was inserted this generation.
    pub fn get(&self, nt: u32, id: u32) -> Option<usize> {
        self.slots.get(pack(nt, id)).map(|s| s as usize)
    }
}

/// Reusable sampling buffers; one per worker thread.  After warm-up,
/// `sample_block_with` performs zero heap allocation per batch.
pub struct SamplerScratch {
    slots: SlotTable,
    /// Per-destination picks: (etype, src_ntype, src_id).
    picks: Vec<(u32, u32, u32)>,
    /// Distinct positions drawn for the current destination.
    pos: Vec<usize>,
    /// Real-node count per layer prefix.
    real_upto: Vec<usize>,
}

impl SamplerScratch {
    pub fn new() -> SamplerScratch {
        SamplerScratch { slots: SlotTable::new(), picks: vec![], pos: vec![], real_upto: vec![] }
    }
}

impl Default for SamplerScratch {
    fn default() -> Self {
        SamplerScratch::new()
    }
}

pub struct NeighborSampler<'g> {
    pub graph: &'g HeteroGraph,
    /// Per-ntype list of inbound edge types (cached).
    etypes_into: Vec<Vec<usize>>,
}

impl<'g> NeighborSampler<'g> {
    pub fn new(graph: &'g HeteroGraph) -> NeighborSampler<'g> {
        let etypes_into = (0..graph.schema.ntypes.len())
            .map(|nt| graph.etypes_into(nt))
            .collect();
        NeighborSampler { graph, etypes_into }
    }

    /// Sample a padded block for `seeds` (at most `shape.num_targets()`).
    /// Convenience wrapper that allocates fresh scratch + block; hot
    /// paths should use [`sample_block_with`](Self::sample_block_with).
    pub fn sample_block(
        &self,
        seeds: &[(u32, u32)],
        shape: &BlockShape,
        rng: &mut Rng,
        exclude: &EdgeExclusion,
    ) -> Block {
        let mut scratch = SamplerScratch::new();
        let mut block = Block::empty(shape);
        self.sample_block_with(seeds, shape, rng, exclude, &mut scratch, &mut block);
        block
    }

    /// Allocation-free sampling into a reusable `block` using `scratch`.
    pub fn sample_block_with(
        &self,
        seeds: &[(u32, u32)],
        shape: &BlockShape,
        rng: &mut Rng,
        exclude: &EdgeExclusion,
        scratch: &mut SamplerScratch,
        block: &mut Block,
    ) {
        self.sample_block_impl(seeds, shape, HopRng::Shared(rng), exclude, scratch, block)
    }

    /// Like [`sample_block_with`](Self::sample_block_with), but every
    /// destination draws its neighbors from its own
    /// [`node_sample_seed`]-derived stream: each node's sampled tree is
    /// a pure function of `(base_seed, node)`, independent of which
    /// other seeds share the block.  This is the serving contract — a
    /// cached prediction stays bit-identical to any later recompute.
    pub fn sample_block_canonical(
        &self,
        seeds: &[(u32, u32)],
        shape: &BlockShape,
        base_seed: u64,
        exclude: &EdgeExclusion,
        scratch: &mut SamplerScratch,
        block: &mut Block,
    ) {
        self.sample_block_impl(seeds, shape, HopRng::PerNode(base_seed), exclude, scratch, block)
    }

    fn sample_block_impl(
        &self,
        seeds: &[(u32, u32)],
        shape: &BlockShape,
        mut hop_rng: HopRng,
        exclude: &EdgeExclusion,
        scratch: &mut SamplerScratch,
        block: &mut Block,
    ) {
        let l_count = shape.num_layers();
        assert!(
            seeds.len() <= shape.num_targets(),
            "{} seeds exceed {} target slots",
            seeds.len(),
            shape.num_targets()
        );
        if block.shape != *shape {
            *block = Block::empty(shape);
        }
        let SamplerScratch { slots, picks, pos, real_upto } = scratch;
        let Block { nodes, nmask, layers, .. } = &mut *block;

        // Node slot table, seeded with targets; grows outward per hop.
        slots.begin(shape.ns[0]);
        nodes.clear();
        nmask.clear();
        nmask.resize(shape.ns[0], 0.0);
        for &(nt, id) in seeds {
            slots.get_or_insert_with(pack(nt, id), || {
                nodes.push((nt, id));
                nmask[nodes.len() - 1] = 1.0;
                (nodes.len() - 1) as i32
            });
        }
        let n_real_targets = nodes.len();
        real_upto.clear();
        real_upto.resize(l_count + 1, 0);
        real_upto[l_count] = n_real_targets;
        // Pad targets to ns[L].
        nodes.resize(shape.ns[l_count], (0, 0));

        // Hops from targets (layer L) outward to layer 0.
        for l in (0..l_count).rev() {
            let n_dst_real = real_upto[l + 1];
            let le = &mut layers[l];
            le.src.clear();
            le.src.resize(shape.es[l], 0);
            le.dst.clear();
            le.dst.resize(shape.es[l], 0);
            le.etype.clear();
            le.etype.resize(shape.es[l], 0);
            le.emask.clear();
            le.emask.resize(shape.es[l], 0.0);
            let mut cursor = 0usize;
            // New frontier nodes append after the current prefix.
            nodes.truncate(shape.ns[l + 1]); // drop padding before extending
            debug_assert_eq!(nodes.len(), shape.ns[l + 1]);
            for dslot in 0..n_dst_real {
                let (dnt, did) = nodes[dslot];
                let mut node_rng;
                let rng: &mut Rng = match &mut hop_rng {
                    HopRng::Shared(r) => &mut **r,
                    HopRng::PerNode(base) => {
                        node_rng =
                            Rng::seed_from(node_sample_seed(hop_base(*base, l), dnt, did));
                        &mut node_rng
                    }
                };
                self.pick_neighbors_into(dnt, did, shape.fanout, rng, exclude, picks, pos);
                for pi in 0..picks.len() {
                    let (et, snt, sid) = picks[pi];
                    let sslot = slots.get_or_insert_with(pack(snt, sid), || {
                        nodes.push((snt, sid));
                        nmask[nodes.len() - 1] = 1.0;
                        (nodes.len() - 1) as i32
                    });
                    le.src[cursor] = sslot;
                    le.dst[cursor] = dslot as i32;
                    le.etype[cursor] = et as i32;
                    le.emask[cursor] = 1.0;
                    cursor += 1;
                }
            }
            real_upto[l] = nodes.len();
            assert!(
                nodes.len() <= shape.ns[l],
                "hop {l} overflowed node slots: {} > {}",
                nodes.len(),
                shape.ns[l]
            );
            nodes.resize(shape.ns[l], (0, 0));
        }
        block.n_real_targets = n_real_targets;
        debug_assert_eq!(block.validate(), Ok(()));
    }

    /// Resolve position `p` in the concatenated inbound ranges of
    /// `did` to (etype, src_id).
    #[inline]
    fn pick_at(&self, ets: &[usize], did: u32, p: usize) -> (usize, u32) {
        let mut p = p;
        for &et in ets {
            let deg = self.graph.edges[et].in_csr.degree(did as usize);
            if p < deg {
                return (et, self.graph.edges[et].in_csr.neighbors(did as usize)[p]);
            }
            p -= deg;
        }
        unreachable!("position out of range");
    }

    /// Pick up to `fanout` non-excluded inbound neighbors of
    /// (dnt, did) into `out`, degree-proportional across inbound edge
    /// types; all edges if they fit.
    ///
    /// Excluded edges do NOT consume budget: positions that land on an
    /// excluded edge are redrawn (bounded retries), with a
    /// deterministic sweep fallback when exclusions are dense, so the
    /// effective fanout stays at budget whenever enough non-excluded
    /// neighbors exist.
    fn pick_neighbors_into(
        &self,
        dnt: u32,
        did: u32,
        fanout: usize,
        rng: &mut Rng,
        exclude: &EdgeExclusion,
        out: &mut Vec<(u32, u32, u32)>,
        pos: &mut Vec<usize>,
    ) {
        out.clear();
        let ets = &self.etypes_into[dnt as usize];
        let mut total = 0usize;
        for &et in ets {
            total += self.graph.edges[et].in_csr.degree(did as usize);
        }
        if total == 0 {
            return;
        }
        let snt_of = |et: usize| self.graph.schema.etypes[et].src_ntype as u32;
        if total <= fanout {
            for &et in ets {
                for &sid in self.graph.edges[et].in_csr.neighbors(did as usize) {
                    if !exclude.contains(et as u32, sid, did) {
                        out.push((et as u32, snt_of(et), sid));
                    }
                }
            }
            return;
        }
        // Rejection-sample distinct positions until the budget is full
        // of non-excluded edges (or positions run out).
        pos.clear();
        let max_attempts = 16 * fanout + 32;
        let mut attempts = 0usize;
        while out.len() < fanout && pos.len() < total && attempts < max_attempts {
            attempts += 1;
            let p = rng.gen_range(total);
            if pos.contains(&p) {
                continue;
            }
            pos.push(p);
            let (et, sid) = self.pick_at(ets, did, p);
            if !exclude.contains(et as u32, sid, did) {
                out.push((et as u32, snt_of(et), sid));
            }
        }
        if out.len() < fanout && pos.len() < total {
            // Dense exclusions: sweep every remaining position from a
            // random offset.  Only positions drawn above need the
            // membership check, so this stays O(total · drawn).
            let drawn = pos.len();
            let start = rng.gen_range(total);
            for k in 0..total {
                if out.len() >= fanout {
                    break;
                }
                let p = (start + k) % total;
                if pos[..drawn].contains(&p) {
                    continue;
                }
                let (et, sid) = self.pick_at(ets, did, p);
                if !exclude.contains(et as u32, sid, did) {
                    out.push((et as u32, snt_of(et), sid));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeTypeDef, Schema};
    use std::collections::HashSet;

    fn star_graph(leaves: usize) -> HeteroGraph {
        // node 0 is the hub; leaves point at it.
        let schema = Schema::new(
            vec!["v".into()],
            vec![EdgeTypeDef { name: "e".into(), src_ntype: 0, dst_ntype: 0 }],
        );
        let mut g = HeteroGraph::new(schema, vec![leaves + 1]);
        let src: Vec<u32> = (1..=leaves as u32).collect();
        let dst = vec![0u32; leaves];
        g.set_edges(0, src, dst);
        g
    }

    fn shape(batch: usize, fanout: usize, layers: usize) -> BlockShape {
        let rnd = |x: usize| x.div_ceil(8) * 8;
        let mut ns = vec![rnd(batch)];
        let mut es = vec![];
        for _ in 0..layers {
            es.push(ns.last().unwrap() * fanout);
            ns.push(rnd(ns.last().unwrap() * (fanout + 1)));
        }
        ns.reverse();
        es.reverse();
        BlockShape { ns, es, fanout }
    }

    #[test]
    fn respects_fanout_budget() {
        let g = star_graph(100);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 5, 1);
        let mut rng = Rng::seed_from(0);
        let block = s.sample_block(&[(0, 0)], &sh, &mut rng, &EdgeExclusion::new());
        block.validate().unwrap();
        let real: usize = block.layers[0].emask.iter().map(|&m| m as usize).sum();
        assert_eq!(real, 5, "hub with 100 in-neighbors must sample exactly fanout");
        // Sampled neighbors are distinct.
        let set: HashSet<i32> = block.layers[0]
            .src
            .iter()
            .zip(&block.layers[0].emask)
            .filter(|(_, &m)| m > 0.0)
            .map(|(&s, _)| s)
            .collect();
        assert_eq!(set.len(), 5);
    }

    #[test]
    fn takes_all_edges_when_degree_small() {
        let g = star_graph(3);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 5, 1);
        let mut rng = Rng::seed_from(1);
        let block = s.sample_block(&[(0, 0)], &sh, &mut rng, &EdgeExclusion::new());
        let real: usize = block.layers[0].emask.iter().map(|&m| m as usize).sum();
        assert_eq!(real, 3);
    }

    #[test]
    fn excluded_edges_never_sampled() {
        let g = star_graph(4);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 5, 1);
        let mut ex = EdgeExclusion::new();
        ex.insert(0, 2, 0); // leaf 2 -> hub excluded
        ex.seal();
        for seed in 0..20 {
            let mut rng = Rng::seed_from(seed);
            let block = s.sample_block(&[(0, 0)], &sh, &mut rng, &ex);
            for (i, &m) in block.layers[0].emask.iter().enumerate() {
                if m > 0.0 {
                    let slot = block.layers[0].src[i] as usize;
                    assert_ne!(block.nodes[slot], (0, 2), "excluded edge sampled");
                }
            }
        }
    }

    /// Regression: excluded edges must not silently shrink the
    /// effective fanout — the budget is refilled by redrawing.
    #[test]
    fn exclusion_refills_fanout_budget() {
        let g = star_graph(100);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 5, 1);
        // Exclude leaves 1..=80: only 20 valid neighbors remain, still
        // well above the budget of 5.
        let mut ex = EdgeExclusion::new();
        for leaf in 1..=80u32 {
            ex.insert(0, leaf, 0);
        }
        ex.seal();
        for seed in 0..30 {
            let mut rng = Rng::seed_from(seed);
            let block = s.sample_block(&[(0, 0)], &sh, &mut rng, &ex);
            let mut real = 0;
            for (i, &m) in block.layers[0].emask.iter().enumerate() {
                if m > 0.0 {
                    real += 1;
                    let (_, sid) = block.nodes[block.layers[0].src[i] as usize];
                    assert!(sid > 80, "excluded leaf {sid} sampled (seed {seed})");
                }
            }
            assert_eq!(real, 5, "under-sampled hub under exclusion (seed {seed})");
        }
    }

    /// With exclusions so dense that fewer than `fanout` neighbors
    /// remain, the sampler returns exactly the survivors.
    #[test]
    fn dense_exclusion_returns_all_survivors() {
        let g = star_graph(50);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 5, 1);
        let mut ex = EdgeExclusion::new();
        for leaf in 1..=47u32 {
            ex.insert(0, leaf, 0);
        }
        ex.seal();
        let mut rng = Rng::seed_from(3);
        let block = s.sample_block(&[(0, 0)], &sh, &mut rng, &ex);
        let survivors: HashSet<u32> = block.layers[0]
            .emask
            .iter()
            .enumerate()
            .filter(|(_, &m)| m > 0.0)
            .map(|(i, _)| block.nodes[block.layers[0].src[i] as usize].1)
            .collect();
        assert_eq!(survivors, HashSet::from([48, 49, 50]));
    }

    #[test]
    fn two_hop_subset_property() {
        let g = star_graph(50);
        let s = NeighborSampler::new(&g);
        let sh = shape(4, 3, 2);
        let mut rng = Rng::seed_from(2);
        let seeds = [(0u32, 0u32), (0, 1), (0, 2)];
        let block = s.sample_block(&seeds, &sh, &mut rng, &EdgeExclusion::new());
        block.validate().unwrap();
        assert_eq!(block.n_real_targets, 3);
        assert_eq!(&block.nodes[..3], &seeds);
        // Layer-1 dst slots must reference target prefix.
        for (i, &m) in block.layers[1].emask.iter().enumerate() {
            if m > 0.0 {
                assert!(block.layers[1].dst[i] < 3);
            }
        }
    }

    #[test]
    fn duplicate_seeds_dedup() {
        let g = star_graph(10);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 3, 1);
        let mut rng = Rng::seed_from(3);
        let block = s.sample_block(&[(0, 0), (0, 0), (0, 1)], &sh, &mut rng, &EdgeExclusion::new());
        assert_eq!(block.n_real_targets, 2);
    }

    /// Scratch + block reuse must give byte-identical results to fresh
    /// allocations, across many consecutive batches.
    #[test]
    fn scratch_reuse_is_identical_to_fresh() {
        let g = star_graph(60);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 4, 2);
        let mut scratch = SamplerScratch::new();
        let mut reused = Block::empty(&sh);
        for seed in 0..25u64 {
            let seeds = [(0u32, (seed % 30) as u32), (0, 0)];
            let mut r1 = Rng::seed_from(seed);
            let mut r2 = Rng::seed_from(seed);
            let fresh = s.sample_block(&seeds, &sh, &mut r1, &EdgeExclusion::new());
            s.sample_block_with(&seeds, &sh, &mut r2, &EdgeExclusion::new(), &mut scratch, &mut reused);
            assert_eq!(fresh.nodes, reused.nodes, "seed {seed}");
            assert_eq!(fresh.nmask, reused.nmask, "seed {seed}");
            assert_eq!(fresh.n_real_targets, reused.n_real_targets);
            for l in 0..fresh.layers.len() {
                assert_eq!(fresh.layers[l].src, reused.layers[l].src, "seed {seed} layer {l}");
                assert_eq!(fresh.layers[l].dst, reused.layers[l].dst);
                assert_eq!(fresh.layers[l].etype, reused.layers[l].etype);
                assert_eq!(fresh.layers[l].emask, reused.layers[l].emask);
            }
        }
    }

    /// Canonical sampling: a node's sampled tree must not depend on
    /// which other seeds share the block — the edges below each target
    /// are identical whether it is sampled alone or co-batched.
    #[test]
    fn canonical_sampling_is_batch_independent() {
        let g = star_graph(80);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 4, 2);
        let mut scratch = SamplerScratch::new();
        let base = 0xbeef_u64;

        // Sampled neighbor multiset of `target` at hop `l`, resolved to
        // (etype, src node, dst node) so slot numbering drops out.
        let tree_of = |block: &Block, dslot: usize, l: usize| -> Vec<(i32, (u32, u32))> {
            let le = &block.layers[l];
            let mut out = vec![];
            for i in 0..le.src.len() {
                if le.emask[i] > 0.0 && le.dst[i] as usize == dslot {
                    out.push((le.etype[i], block.nodes[le.src[i] as usize]));
                }
            }
            out
        };

        let mut solo = Block::empty(&sh);
        s.sample_block_canonical(&[(0, 0)], &sh, base, &EdgeExclusion::new(), &mut scratch, &mut solo);
        let solo_tree = tree_of(&solo, 0, 1);

        for other in [1u32, 5, 17, 33] {
            let mut both = Block::empty(&sh);
            s.sample_block_canonical(
                &[(0, other), (0, 0)],
                &sh,
                base,
                &EdgeExclusion::new(),
                &mut scratch,
                &mut both,
            );
            // Node 0 is the second target → dslot 1.
            assert_eq!(both.nodes[1], (0, 0));
            assert_eq!(tree_of(&both, 1, 1), solo_tree, "co-batched with {other}");
        }

        // Per-hop independence: the target is a destination at both
        // hops and must draw a *different* subset each hop (hop index
        // is mixed into the seed), matching the training sampler's
        // per-hop redraws.
        assert_ne!(
            tree_of(&solo, 0, 0),
            tree_of(&solo, 0, 1),
            "hub must not re-sample the identical subset at every hop"
        );

        // The shared-stream sampler, by contrast, is batch-dependent —
        // guard that the two modes really differ on a high-degree hub.
        let mut r = Rng::seed_from(base);
        let mut shared = Block::empty(&sh);
        s.sample_block_with(&[(0, 0)], &sh, &mut r, &EdgeExclusion::new(), &mut scratch, &mut shared);
        assert_eq!(shared.nodes[0], (0, 0));
    }

    #[test]
    fn seed_index_dedups_and_looks_up() {
        let mut idx = SeedIndex::new();
        idx.begin(8);
        let mut order: Vec<(u32, u32)> = vec![];
        for &(nt, id) in &[(0u32, 3u32), (1, 3), (0, 3), (0, 7), (1, 3)] {
            let (slot, fresh) = idx.get_or_insert(nt, id, order.len());
            if fresh {
                order.push((nt, id));
                assert_eq!(slot, order.len() - 1);
            }
        }
        assert_eq!(order, vec![(0, 3), (1, 3), (0, 7)]);
        assert_eq!(idx.get(1, 3), Some(1));
        assert_eq!(idx.get(2, 2), None);
        // begin() invalidates in O(1).
        idx.begin(4);
        assert_eq!(idx.get(0, 3), None);
        let (slot, fresh) = idx.get_or_insert(9, 9, 0);
        assert!(fresh);
        assert_eq!(slot, 0);
    }

    #[test]
    fn node_seed_spreads() {
        let mut seen = HashSet::new();
        for nt in 0..4u32 {
            for id in 0..256u32 {
                seen.insert(node_sample_seed(7, nt, id));
            }
        }
        assert_eq!(seen.len(), 4 * 256);
        assert_eq!(node_sample_seed(7, 1, 2), node_sample_seed(7, 1, 2));
        assert_ne!(node_sample_seed(7, 1, 2), node_sample_seed(8, 1, 2));
    }

    #[test]
    fn exclusion_base_and_batch_compose() {
        let base = EdgeExclusion::sorted_base(vec![(0, 5, 0), (0, 3, 0), (0, 5, 0)]);
        let mut ex = EdgeExclusion::with_base(base);
        assert!(ex.contains(0, 5, 0) && ex.contains(0, 3, 0));
        assert!(!ex.contains(0, 4, 0));
        ex.insert(0, 4, 0);
        assert!(ex.contains(0, 4, 0), "unsealed lookup must still work");
        ex.seal();
        assert!(ex.contains(0, 4, 0) && ex.contains(0, 5, 0));
        assert_eq!(ex.len(), 3);
    }
}
