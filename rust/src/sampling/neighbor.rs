//! On-the-fly inbound neighbor sampling over the partitioned graph.
//!
//! Degree-proportional across edge types: for each destination node the
//! per-hop budget is `fanout` edges *total*; if the combined in-degree
//! fits the budget all edges are taken, otherwise `fanout` distinct
//! positions are drawn from the concatenated neighbor ranges.  This
//! bounds every hop at `ns[l+1] * fanout` edges — exactly the padded
//! shape the AOT artifacts were lowered with.

use std::collections::{HashMap, HashSet};

use crate::graph::HeteroGraph;
use crate::sampling::block::{Block, BlockShape, LayerEdges};
use crate::util::Rng;

/// Edges excluded from message passing: the batch's own target edges
/// (anti-overfitting) and validation/test edges (anti-leakage), per the
/// paper §3.3.4 / SpotTarget.
#[derive(Default, Clone)]
pub struct EdgeExclusion {
    /// (etype, src, dst) triples to skip while sampling.
    set: HashSet<(u32, u32, u32)>,
}

impl EdgeExclusion {
    pub fn new() -> EdgeExclusion {
        EdgeExclusion::default()
    }

    pub fn insert(&mut self, etype: u32, src: u32, dst: u32) {
        self.set.insert((etype, src, dst));
    }

    /// Also exclude the reverse orientation under `rev_etype`.
    pub fn insert_with_reverse(&mut self, etype: u32, rev_etype: Option<u32>, src: u32, dst: u32) {
        self.insert(etype, src, dst);
        if let Some(re) = rev_etype {
            self.insert(re, dst, src);
        }
    }

    #[inline]
    pub fn contains(&self, etype: u32, src: u32, dst: u32) -> bool {
        !self.set.is_empty() && self.set.contains(&(etype, src, dst))
    }

    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.is_empty()
    }
}

pub struct NeighborSampler<'g> {
    pub graph: &'g HeteroGraph,
    /// Per-ntype list of inbound edge types (cached).
    etypes_into: Vec<Vec<usize>>,
}

impl<'g> NeighborSampler<'g> {
    pub fn new(graph: &'g HeteroGraph) -> NeighborSampler<'g> {
        let etypes_into = (0..graph.schema.ntypes.len())
            .map(|nt| graph.etypes_into(nt))
            .collect();
        NeighborSampler { graph, etypes_into }
    }

    /// Sample a padded block for `seeds` (at most `shape.num_targets()`).
    pub fn sample_block(
        &self,
        seeds: &[(u32, u32)],
        shape: &BlockShape,
        rng: &mut Rng,
        exclude: &EdgeExclusion,
    ) -> Block {
        let l_count = shape.num_layers();
        assert!(
            seeds.len() <= shape.num_targets(),
            "{} seeds exceed {} target slots",
            seeds.len(),
            shape.num_targets()
        );
        // Node slot table, seeded with targets; grows outward per hop.
        let mut nodes: Vec<(u32, u32)> = Vec::with_capacity(shape.ns[0]);
        let mut slot_of: HashMap<(u32, u32), i32> = HashMap::with_capacity(shape.ns[0]);
        for &s in seeds {
            if !slot_of.contains_key(&s) {
                slot_of.insert(s, nodes.len() as i32);
                nodes.push(s);
            }
        }
        let n_real_targets = nodes.len();
        let mut real_upto = vec![0usize; l_count + 1]; // real nodes per layer prefix
        real_upto[l_count] = n_real_targets;
        // Pad targets to ns[L].
        nodes.resize(shape.ns[l_count], (0, 0));

        // Hops from targets (layer L) outward to layer 0.
        let mut layers_rev: Vec<LayerEdges> = Vec::with_capacity(l_count);
        for l in (0..l_count).rev() {
            let n_dst_real = real_upto[l + 1];
            let mut le = LayerEdges {
                src: vec![0; shape.es[l]],
                dst: vec![0; shape.es[l]],
                etype: vec![0; shape.es[l]],
                emask: vec![0.0; shape.es[l]],
            };
            let mut cursor = 0usize;
            // New frontier nodes append after the current prefix.
            nodes.truncate(shape.ns[l + 1]); // drop padding before extending
            debug_assert_eq!(nodes.len(), shape.ns[l + 1]);
            for dslot in 0..n_dst_real {
                let (dnt, did) = nodes[dslot];
                let mut picks = self.pick_neighbors(dnt, did, shape.fanout, rng, exclude);
                for (et, snt, sid) in picks.drain(..) {
                    let key = (snt, sid);
                    let sslot = *slot_of.entry(key).or_insert_with(|| {
                        nodes.push(key);
                        (nodes.len() - 1) as i32
                    });
                    le.src[cursor] = sslot;
                    le.dst[cursor] = dslot as i32;
                    le.etype[cursor] = et as i32;
                    le.emask[cursor] = 1.0;
                    cursor += 1;
                }
            }
            real_upto[l] = nodes.len();
            assert!(
                nodes.len() <= shape.ns[l],
                "hop {l} overflowed node slots: {} > {}",
                nodes.len(),
                shape.ns[l]
            );
            nodes.resize(shape.ns[l], (0, 0));
            layers_rev.push(le);
        }
        layers_rev.reverse();

        // Node mask: real slots per the deepest layer they belong to.
        let mut nmask = vec![0.0f32; shape.ns[0]];
        // All slots < real_upto[0] that were ever real.  Because layers
        // share the prefix, a slot is real iff its index < real count of
        // the layer that introduced it; the union is simply [0, real_upto[0])
        // minus padded gaps — padded gaps only exist past each layer's
        // real count but before ns[l+1]... so mark from the slot table:
        for (i, &(nt, id)) in nodes.iter().enumerate() {
            // Padding slots are (0,0) duplicates; the genuine slot for
            // (0,0) is the one registered in slot_of.
            if slot_of.get(&(nt, id)) == Some(&(i as i32)) {
                nmask[i] = 1.0;
            }
        }

        let block = Block {
            shape: shape.clone(),
            nodes,
            nmask,
            layers: layers_rev,
            n_real_targets,
        };
        debug_assert_eq!(block.validate(), Ok(()));
        block
    }

    /// Pick up to `fanout` inbound neighbors of (dnt, did), degree-
    /// proportional across inbound edge types; all edges if they fit.
    fn pick_neighbors(
        &self,
        dnt: u32,
        did: u32,
        fanout: usize,
        rng: &mut Rng,
        exclude: &EdgeExclusion,
    ) -> Vec<(usize, u32, u32)> {
        let mut out = Vec::with_capacity(fanout);
        let ets = &self.etypes_into[dnt as usize];
        let mut total = 0usize;
        for &et in ets {
            total += self.graph.edges[et].in_csr.degree(did as usize);
        }
        if total == 0 {
            return out;
        }
        let push = |et: usize, sid: u32, out: &mut Vec<(usize, u32, u32)>| {
            if !exclude.contains(et as u32, sid, did) {
                let snt = self.graph.schema.etypes[et].src_ntype as u32;
                out.push((et, snt, sid));
            }
        };
        if total <= fanout {
            for &et in ets {
                for &sid in self.graph.edges[et].in_csr.neighbors(did as usize) {
                    push(et, sid, &mut out);
                }
            }
        } else {
            // Sample distinct positions in the concatenated ranges.
            for pos in rng.sample_distinct(total, fanout) {
                let mut p = pos;
                for &et in ets {
                    let deg = self.graph.edges[et].in_csr.degree(did as usize);
                    if p < deg {
                        push(et, self.graph.edges[et].in_csr.neighbors(did as usize)[p], &mut out);
                        break;
                    }
                    p -= deg;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeTypeDef, Schema};

    fn star_graph(leaves: usize) -> HeteroGraph {
        // node 0 is the hub; leaves point at it.
        let schema = Schema::new(
            vec!["v".into()],
            vec![EdgeTypeDef { name: "e".into(), src_ntype: 0, dst_ntype: 0 }],
        );
        let mut g = HeteroGraph::new(schema, vec![leaves + 1]);
        let src: Vec<u32> = (1..=leaves as u32).collect();
        let dst = vec![0u32; leaves];
        g.set_edges(0, src, dst);
        g
    }

    fn shape(batch: usize, fanout: usize, layers: usize) -> BlockShape {
        let rnd = |x: usize| x.div_ceil(8) * 8;
        let mut ns = vec![rnd(batch)];
        let mut es = vec![];
        for _ in 0..layers {
            es.push(ns.last().unwrap() * fanout);
            ns.push(rnd(ns.last().unwrap() * (fanout + 1)));
        }
        ns.reverse();
        es.reverse();
        BlockShape { ns, es, fanout }
    }

    #[test]
    fn respects_fanout_budget() {
        let g = star_graph(100);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 5, 1);
        let mut rng = Rng::seed_from(0);
        let block = s.sample_block(&[(0, 0)], &sh, &mut rng, &EdgeExclusion::new());
        block.validate().unwrap();
        let real: usize = block.layers[0].emask.iter().map(|&m| m as usize).sum();
        assert_eq!(real, 5, "hub with 100 in-neighbors must sample exactly fanout");
        // Sampled neighbors are distinct.
        let set: HashSet<i32> = block.layers[0]
            .src
            .iter()
            .zip(&block.layers[0].emask)
            .filter(|(_, &m)| m > 0.0)
            .map(|(&s, _)| s)
            .collect();
        assert_eq!(set.len(), 5);
    }

    #[test]
    fn takes_all_edges_when_degree_small() {
        let g = star_graph(3);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 5, 1);
        let mut rng = Rng::seed_from(1);
        let block = s.sample_block(&[(0, 0)], &sh, &mut rng, &EdgeExclusion::new());
        let real: usize = block.layers[0].emask.iter().map(|&m| m as usize).sum();
        assert_eq!(real, 3);
    }

    #[test]
    fn excluded_edges_never_sampled() {
        let g = star_graph(4);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 5, 1);
        let mut ex = EdgeExclusion::new();
        ex.insert(0, 2, 0); // leaf 2 -> hub excluded
        for seed in 0..20 {
            let mut rng = Rng::seed_from(seed);
            let block = s.sample_block(&[(0, 0)], &sh, &mut rng, &ex);
            for (i, &m) in block.layers[0].emask.iter().enumerate() {
                if m > 0.0 {
                    let slot = block.layers[0].src[i] as usize;
                    assert_ne!(block.nodes[slot], (0, 2), "excluded edge sampled");
                }
            }
        }
    }

    #[test]
    fn two_hop_subset_property() {
        let g = star_graph(50);
        let s = NeighborSampler::new(&g);
        let sh = shape(4, 3, 2);
        let mut rng = Rng::seed_from(2);
        let seeds = [(0u32, 0u32), (0, 1), (0, 2)];
        let block = s.sample_block(&seeds, &sh, &mut rng, &EdgeExclusion::new());
        block.validate().unwrap();
        assert_eq!(block.n_real_targets, 3);
        assert_eq!(&block.nodes[..3], &seeds);
        // Layer-1 dst slots must reference target prefix.
        for (i, &m) in block.layers[1].emask.iter().enumerate() {
            if m > 0.0 {
                assert!(block.layers[1].dst[i] < 3);
            }
        }
    }

    #[test]
    fn duplicate_seeds_dedup() {
        let g = star_graph(10);
        let s = NeighborSampler::new(&g);
        let sh = shape(8, 3, 1);
        let mut rng = Rng::seed_from(3);
        let block = s.sample_block(&[(0, 0), (0, 0), (0, 1)], &sh, &mut rng, &EdgeExclusion::new());
        assert_eq!(block.n_real_targets, 2);
    }
}
