//! Padded fixed-shape mini-batch blocks (the AOT contract).
//!
//! One shared node-slot array with the subset property: the first
//! `ns[l+1]` slots of layer *l* are exactly the nodes of layer *l+1*;
//! the first `ns[L]` slots are the batch targets.  Every array is
//! padded to the manifest's static shape; padding nodes carry
//! `nmask = 0`, padding edges `emask = 0` and point at slot 0.

use crate::runtime::ArtifactSpec;

/// Static block shape pulled from an artifact's manifest entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockShape {
    pub ns: Vec<usize>,
    pub es: Vec<usize>,
    pub fanout: usize,
}

impl BlockShape {
    pub fn from_spec(spec: &ArtifactSpec) -> Option<BlockShape> {
        let (ns, es) = spec.block()?;
        let fanout = spec.cfg_usize("fanout").unwrap_or(5);
        Some(BlockShape { ns, es, fanout })
    }

    pub fn num_layers(&self) -> usize {
        self.es.len()
    }

    /// Target-slot count (ns[L]).
    pub fn num_targets(&self) -> usize {
        *self.ns.last().unwrap()
    }
}

/// One hop's padded edge arrays.
#[derive(Debug, Clone, Default)]
pub struct LayerEdges {
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
    pub etype: Vec<i32>,
    pub emask: Vec<f32>,
}

/// A sampled, padded message-flow block.
#[derive(Debug, Clone)]
pub struct Block {
    pub shape: BlockShape,
    /// (ntype, local id) per slot; padding slots repeat (0, 0) with mask 0.
    pub nodes: Vec<(u32, u32)>,
    pub nmask: Vec<f32>,
    /// layers[l] connects src slots (< ns[l]) to dst slots (< ns[l+1]).
    pub layers: Vec<LayerEdges>,
    /// Number of real (unpadded) target nodes.
    pub n_real_targets: usize,
}

impl Block {
    /// An all-padding block with `shape`'s layer count; arrays are
    /// filled in by `NeighborSampler::sample_block_with`, which reuses
    /// the allocations on subsequent calls.
    pub fn empty(shape: &BlockShape) -> Block {
        Block {
            shape: shape.clone(),
            nodes: vec![],
            nmask: vec![],
            layers: vec![LayerEdges::default(); shape.es.len()],
            n_real_targets: 0,
        }
    }

    /// Real target nodes (first `n_real_targets` slots).
    pub fn targets(&self) -> &[(u32, u32)] {
        &self.nodes[..self.n_real_targets]
    }

    /// Consistency checks used by tests and debug builds.
    pub fn validate(&self) -> Result<(), String> {
        let s = &self.shape;
        if self.nodes.len() != s.ns[0] || self.nmask.len() != s.ns[0] {
            return Err("node arrays must have ns[0] slots".into());
        }
        if self.layers.len() != s.es.len() {
            return Err("layer count mismatch".into());
        }
        for (l, le) in self.layers.iter().enumerate() {
            if le.src.len() != s.es[l] {
                return Err(format!("layer {l}: edge arrays must have es[{l}] slots"));
            }
            for i in 0..le.src.len() {
                if le.emask[i] > 0.0 {
                    if le.src[i] as usize >= s.ns[l] {
                        return Err(format!("layer {l}: src slot out of range"));
                    }
                    if le.dst[i] as usize >= s.ns[l + 1] {
                        return Err(format!("layer {l}: dst slot out of range"));
                    }
                    if self.nmask[le.src[i] as usize] == 0.0 {
                        return Err(format!("layer {l}: edge from padding slot"));
                    }
                } else if le.src[i] != 0 || le.dst[i] != 0 {
                    return Err(format!("layer {l}: padding edge must point at slot 0"));
                }
            }
        }
        // Subset property: real targets are masked-in.
        for i in 0..self.n_real_targets {
            if self.nmask[i] == 0.0 {
                return Err("real target has zero mask".into());
            }
        }
        Ok(())
    }
}
