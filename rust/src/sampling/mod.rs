//! On-the-fly mini-batch sampling (DistDGL-style MFG blocks) plus the
//! paper's four negative samplers (Appendix A.2.1).

pub mod block;
pub mod negative;
pub mod neighbor;

pub use block::{Block, BlockShape, LayerEdges};
pub use negative::{NegSampler, NegativeBatch};
pub use neighbor::{
    hop_base, node_sample_seed, EdgeExclusion, NeighborSampler, SamplerScratch, SeedIndex,
};
