//! Evaluators: accuracy for classification, MRR for link prediction.

/// Argmax accuracy over row-major logits [n, c].
pub fn accuracy(logits: &[f32], c: usize, labels: &[i32], mask: &[f32]) -> (usize, usize) {
    let mut correct = 0;
    let mut total = 0;
    for (i, &l) in labels.iter().enumerate() {
        if mask[i] == 0.0 {
            continue;
        }
        let row = &logits[i * c..(i + 1) * c];
        let am = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap_or(0);
        if am as i32 == l {
            correct += 1;
        }
        total += 1;
    }
    (correct, total)
}

/// Index of the row's max element (ties → last, matching
/// `Iterator::max_by`), the logits decode shared by the NC evaluator
/// and the serving layer.
#[inline]
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(j, _)| j)
        .unwrap_or(0)
}

/// DistMult score: sum_i u[i] * r[i] * v[i] (paper eq. 3).
#[inline]
pub fn distmult(u: &[f32], r: &[f32], v: &[f32]) -> f32 {
    u.iter().zip(r).zip(v).map(|((a, b), c)| a * b * c).sum()
}

/// Reciprocal rank of `pos` among `negs` (rank 1 = best).
/// Ties count against the positive (pessimistic), so an untrained
/// all-equal scorer reports ~1/(K+1), not a fake 1.0.
pub fn reciprocal_rank(pos: f32, negs: &[f32]) -> f64 {
    let rank = 1 + negs.iter().filter(|&&n| n >= pos).count();
    1.0 / rank as f64
}

/// Running mean.
#[derive(Default, Debug, Clone)]
pub struct Mean {
    pub sum: f64,
    pub n: u64,
}

impl Mean {
    pub fn add(&mut self, x: f64) {
        self.sum += x;
        self.n += 1;
    }

    pub fn add_weighted(&mut self, sum: f64, n: u64) {
        self.sum += sum;
        self.n += n;
    }

    pub fn get(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        let logits = vec![1.0, 0.0, 0.0, 2.0, 0.5, 0.1];
        let (c, t) = accuracy(&logits, 2, &[0, 1, 0], &[1.0, 1.0, 1.0]);
        assert_eq!((c, t), (3, 3));
        // rows argmax to [0, 1, 0]; with labels [1,1,1] and row 1 masked
        // out, nothing matches.
        let (c, t) = accuracy(&logits, 2, &[1, 1, 1], &[1.0, 0.0, 1.0]);
        assert_eq!((c, t), (0, 2));
    }

    #[test]
    fn rr_ranks() {
        assert_eq!(reciprocal_rank(5.0, &[1.0, 2.0]), 1.0);
        assert_eq!(reciprocal_rank(1.5, &[1.0, 2.0]), 0.5);
        assert!((reciprocal_rank(0.0, &[1.0, 2.0]) - 1.0 / 3.0).abs() < 1e-12);
        // Ties are pessimistic.
        assert!((reciprocal_rank(1.0, &[1.0, 1.0]) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn distmult_matches_dot_with_unit_rel() {
        let u = [1.0, 2.0];
        let v = [3.0, 4.0];
        assert_eq!(distmult(&u, &[1.0, 1.0], &v), 11.0);
    }
}
