//! The `gs lint` rule set over the token stream of `tokens.rs`.
//!
//! Five rules, each guarding a contract the runtime sweeps can only
//! catch probabilistically (docs/LINTS.md is the user-facing catalog):
//!
//! * `determinism`  — no iteration-order-dependent std hash
//!   collections and no ambient clocks/RNG in the deterministic
//!   modules (`sampling/`, `dataloader/`, `partition/`, `trainer/`,
//!   `serve/`).  Timing-only sites carry `lint:allow` waivers.
//! * `panic-clean`  — no `.unwrap()` / `.expect()` in `serve/`,
//!   `obs/`, `dist/` production code (failures travel as typed
//!   `ServeError`s, docs/ROBUSTNESS.md).
//! * `lock-order`   — lock acquisitions inside one function must
//!   respect the declared DAG cache → session → rows → leaf, and
//!   `serve/` takes locks only through the ranked helpers.
//! * `salt-unique`  — every `*_SALT` RNG salt constant is distinct, so
//!   no two sub-streams of the run seed can collide.
//! * `name-registry`— every span/metric name the golden fixture and
//!   docs/OBSERVABILITY.md mention must trace to a real
//!   `span!`/`event!`/metrics call site.
//!
//! Plus the `waiver` meta-rule: a waiver with an unknown rule name or
//! no reason is itself a finding.

use super::tokens::{FileToks, Tok, TokKind};

/// Every rule name a waiver may reference.
pub const RULES: &[&str] =
    &["determinism", "panic-clean", "lock-order", "salt-unique", "name-registry"];

/// Directories (top-level module names under the lint root) whose
/// production code must be deterministic.
pub const DETERMINISM_DIRS: &[&str] = &["sampling", "dataloader", "partition", "trainer", "serve"];

/// Directories whose production code must be panic-clean.
pub const PANIC_DIRS: &[&str] = &["serve", "obs", "dist"];

/// One lint finding (pre- or post-waiver).
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// A `const *_SALT` definition site.
#[derive(Debug, Clone)]
pub struct SaltDef {
    pub name: String,
    pub value: u64,
    pub file: String,
    pub line: u32,
}

/// Everything a single-file scan produces: per-file findings plus the
/// raw material for the cross-file rules.
#[derive(Debug, Default)]
pub struct FileScan {
    pub findings: Vec<Finding>,
    pub salts: Vec<SaltDef>,
    /// Span/event/metric names this file emits.  Entries from
    /// `format!` strings have their `{..}` holes replaced by `*`.
    pub names: Vec<String>,
}

/// Does `rel` (a path relative to the lint root, `/`-separated) live
/// under one of `dirs`?
fn in_scope(rel: &str, dirs: &[&str]) -> bool {
    rel.split('/').rev().skip(1).any(|seg| dirs.contains(&seg))
}

/// Lock ranks of the declared order (docs/LINTS.md).  Lower acquires
/// earlier; an acquisition while a *higher* rank is held is a finding.
const RANK_NAMES: [&str; 4] =
    ["cache mutex", "PJRT session lock", "EmbTable row lock", "leaf mutex"];

/// Map an identifier call site to (rank, returns-a-guard).
/// `forward_locked` acquires and releases the session lock internally,
/// so it never holds past the call.
fn lock_marker(toks: &[Tok], i: usize) -> Option<(u8, bool)> {
    match toks[i].text.as_str() {
        // Cache stripes share rank 0: the static rule flags *any* two
        // held stripe guards, because ascending-shard nesting (the one
        // runtime-legal case, checked by lockorder::acquire_shard)
        // cannot be proven from tokens — serve code takes stripes
        // strictly one at a time.
        "lock_cache" | "lock_shard" | "lock_key" | "lock_at" => Some((0, true)),
        "forward_locked" => Some((1, false)),
        "read_inner" | "write_inner" | "read_shard" | "write_shard" => Some((2, true)),
        "lock_clean" => Some((3, true)),
        "lock_ranked" => {
            // Rank comes from the second argument: scan the call
            // parens for a `Rank::` variant name.
            let close = match_paren(toks, i + 1);
            let rank = toks[i + 1..close].iter().find_map(|t| match t.text.as_str() {
                "Cache" => Some(0),
                "Session" => Some(1),
                "EmbRows" => Some(2),
                "Leaf" => Some(3),
                _ => None,
            });
            Some((rank.unwrap_or(3), true))
        }
        _ => None,
    }
}

fn match_paren(toks: &[Tok], open_idx: usize) -> usize {
    let mut depth = 0i32;
    for (k, t) in toks.iter().enumerate().skip(open_idx) {
        match t.kind {
            TokKind::Punct('(') => depth += 1,
            TokKind::Punct(')') => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
    }
    toks.len()
}

/// Scan one file for the per-file rules and collect cross-file facts.
pub fn scan_file(rel: &str, ft: &FileToks) -> FileScan {
    let mut out = FileScan::default();
    let toks = &ft.toks;
    let n = toks.len();
    let det = in_scope(rel, DETERMINISM_DIRS);
    let panic_clean = in_scope(rel, PANIC_DIRS);
    let serve_scope = in_scope(rel, &["serve"]);

    // --- lock-order state -------------------------------------------------
    struct HeldLock {
        rank: u8,
        depth: i32,
        line: u32,
        var: String,
    }
    let mut held: Vec<HeldLock> = Vec::new();
    let mut depth = 0i32;
    // `Some(var)` while the current statement started with `let var`.
    let mut stmt_let: Option<String> = None;

    let mut finding = |line: u32, rule: &'static str, msg: String, sink: &mut Vec<Finding>| {
        sink.push(Finding { file: rel.to_string(), line, rule, msg });
    };

    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if t.in_test {
            // Test code still moves brace depth so production lock
            // scopes stay balanced around inline `#[cfg(test)]` items.
            match t.kind {
                TokKind::Punct('{') => depth += 1,
                TokKind::Punct('}') => {
                    depth -= 1;
                    held.retain(|h| h.depth <= depth);
                }
                _ => {}
            }
            i += 1;
            continue;
        }
        match t.kind {
            TokKind::Punct('{') => {
                depth += 1;
                stmt_let = None;
            }
            TokKind::Punct('}') => {
                depth -= 1;
                held.retain(|h| h.depth <= depth);
                stmt_let = None;
            }
            TokKind::Punct(';') => stmt_let = None,
            TokKind::Ident => {
                let prev_fn = i > 0 && toks[i - 1].is_ident("fn");
                let next_paren = i + 1 < n && toks[i + 1].is_punct('(');
                let next_bang_paren =
                    i + 2 < n && toks[i + 1].is_punct('!') && toks[i + 2].is_punct('(');

                match t.text.as_str() {
                    "let" => {
                        // Bound name: first ident after `let` / `let mut`.
                        let mut j = i + 1;
                        if j < n && toks[j].is_ident("mut") {
                            j += 1;
                        }
                        let var = toks
                            .get(j)
                            .filter(|t| t.kind == TokKind::Ident)
                            .map(|t| t.text.clone())
                            .unwrap_or_default();
                        stmt_let = Some(var);
                    }
                    // -------- determinism ---------------------------------
                    "HashMap" | "HashSet" if det => finding(
                        t.line,
                        "determinism",
                        format!(
                            "std::collections::{} has per-process-random iteration order; \
                             use util::Fx{}  (or a BTree/sorted structure) in deterministic modules",
                            t.text, t.text
                        ),
                        &mut out.findings,
                    ),
                    "RandomState" | "thread_rng" | "from_entropy" if det => finding(
                        t.line,
                        "determinism",
                        format!("ambient RNG `{}` in a deterministic module; derive from the run seed (util::Rng)", t.text),
                        &mut out.findings,
                    ),
                    "SystemTime" if det => finding(
                        t.line,
                        "determinism",
                        "wall-clock `SystemTime` read in a deterministic module".to_string(),
                        &mut out.findings,
                    ),
                    "Instant"
                        if det
                            && i + 2 < n
                            && toks[i + 1].is_punct(':')
                            && toks[i + 2].is_punct(':')
                            && toks.get(i + 3).is_some_and(|t| t.is_ident("now")) =>
                    {
                        finding(
                            t.line,
                            "determinism",
                            "ambient `Instant::now()` in a deterministic module; if the value only \
                             feeds latency metrics, waive with a reason"
                                .to_string(),
                            &mut out.findings,
                        )
                    }
                    // -------- panic-clean ---------------------------------
                    "unwrap" | "expect"
                        if panic_clean && next_paren && i > 0 && toks[i - 1].is_punct('.') =>
                    {
                        finding(
                            t.line,
                            "panic-clean",
                            format!(
                                ".{}() in panic-clean production code; return a typed ServeError \
                                 (docs/ROBUSTNESS.md) or use the unwrap_or* family",
                                t.text
                            ),
                            &mut out.findings,
                        )
                    }
                    // -------- lock-order: raw .lock() in serve/ -----------
                    "lock"
                        if serve_scope && next_paren && i > 0 && toks[i - 1].is_punct('.') =>
                    {
                        finding(
                            t.line,
                            "lock-order",
                            "raw `.lock()` in serve/; acquire through lock_cache/lock_clean/\
                             lock_ranked so poison recovery and the lock-order tracker apply"
                                .to_string(),
                            &mut out.findings,
                        )
                    }
                    // -------- salt collection -----------------------------
                    "const"
                        if toks
                            .get(i + 1)
                            .is_some_and(|t| t.kind == TokKind::Ident && t.text.ends_with("_SALT")) =>
                    {
                        let name_tok = &toks[i + 1];
                        // const NAME_SALT: u64 = <num>;
                        let val = toks[i + 2..(i + 12).min(n)]
                            .iter()
                            .skip_while(|t| !t.is_punct('='))
                            .find(|t| t.kind == TokKind::Num)
                            .and_then(|t| parse_int(&t.text));
                        if let Some(value) = val {
                            out.salts.push(SaltDef {
                                name: name_tok.text.clone(),
                                value,
                                file: rel.to_string(),
                                line: name_tok.line,
                            });
                        }
                    }
                    // -------- name collection -----------------------------
                    // All name-shaped string args, not just the first:
                    // `trace::instant(match level { .. => "log.debug", .. })`
                    // emits one of several literals from a single call.
                    "span" | "event" if next_bang_paren => {
                        for lit in name_args(toks, i + 2) {
                            out.names.push(lit_to_pattern(&lit));
                        }
                    }
                    "counter_add" | "counter_set" | "gauge_set" | "hist_record" | "instant"
                        if next_paren =>
                    {
                        for lit in name_args(toks, i + 1) {
                            out.names.push(lit_to_pattern(&lit));
                        }
                    }
                    "closed_loop_snapshot" if next_paren => {
                        if let Some(lit) = first_str_arg(toks, i + 1) {
                            // Publishes `<prefix>.<stat>` for every
                            // ClosedLoopStats field.
                            out.names.push(format!("{}.*", lit_to_pattern(&lit)));
                        }
                    }
                    _ => {}
                }

                // -------- lock-order acquisitions -------------------------
                if next_paren && !prev_fn {
                    if let Some((rank, returns_guard)) = lock_marker(toks, i) {
                        for h in &held {
                            if h.rank > rank || (h.rank == rank && rank <= 1) {
                                finding(
                                    t.line,
                                    "lock-order",
                                    format!(
                                        "acquires {} while already holding {} (line {}); declared \
                                         order is cache -> session -> rows -> leaf",
                                        RANK_NAMES[rank as usize],
                                        RANK_NAMES[h.rank as usize],
                                        h.line
                                    ),
                                    &mut out.findings,
                                );
                            }
                        }
                        // Held only when directly bound: `let g = marker(..);`
                        if returns_guard {
                            let close = match_paren(toks, i + 1);
                            let direct_bind = stmt_let.is_some()
                                && toks.get(close + 1).is_some_and(|t| t.is_punct(';'));
                            if direct_bind {
                                held.push(HeldLock {
                                    rank,
                                    depth,
                                    line: t.line,
                                    var: stmt_let.clone().unwrap_or_default(),
                                });
                            }
                        }
                    }
                    // Explicit early release: `drop(var)`.
                    if t.is_ident("drop") {
                        if let Some(v) = toks.get(i + 2).filter(|t| t.kind == TokKind::Ident) {
                            if toks.get(i + 3).is_some_and(|t| t.is_punct(')')) {
                                held.retain(|h| h.var != v.text);
                            }
                        }
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// First string literal inside the call/macro parens opened at
/// `open_idx` (bounded to the argument list).
fn first_str_arg(toks: &[Tok], open_idx: usize) -> Option<String> {
    let close = match_paren(toks, open_idx);
    toks[open_idx..close].iter().find(|t| t.kind == TokKind::Str).map(|t| t.text.clone())
}

/// Every *name-shaped* string literal inside the call/macro parens:
/// dotted lowercase, `{hole}`s allowed.  The shape filter keeps attr
/// values out of the name table.
fn name_args(toks: &[Tok], open_idx: usize) -> Vec<String> {
    let close = match_paren(toks, open_idx);
    toks[open_idx..close]
        .iter()
        .filter(|t| t.kind == TokKind::Str)
        .filter(|t| {
            t.text.contains('.')
                && t.text.chars().all(|c| {
                    c.is_ascii_lowercase()
                        || c.is_ascii_digit()
                        || matches!(c, '.' | '_' | '{' | '}' | '+' | '-')
                })
        })
        .map(|t| t.text.clone())
        .collect()
}

/// Turn a (possibly `format!`) literal into a name-table entry:
/// `{..}` holes become `*` wildcards.
fn lit_to_pattern(lit: &str) -> String {
    let mut out = String::with_capacity(lit.len());
    let mut chars = lit.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '{' => {
                if chars.peek() == Some(&'{') {
                    chars.next();
                    out.push('{');
                    continue;
                }
                for c2 in chars.by_ref() {
                    if c2 == '}' {
                        break;
                    }
                }
                out.push('*');
            }
            '}' => {
                if chars.peek() == Some(&'}') {
                    chars.next();
                }
                out.push('}');
            }
            c => out.push(c),
        }
    }
    out
}

/// Parse a Rust integer literal (decimal / 0x / 0o / 0b, `_` and type
/// suffixes tolerated).
pub fn parse_int(text: &str) -> Option<u64> {
    let t: String = text.chars().filter(|&c| c != '_').collect();
    let (radix, body) = if let Some(b) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        (16, b)
    } else if let Some(b) = t.strip_prefix("0o") {
        (8, b)
    } else if let Some(b) = t.strip_prefix("0b") {
        (2, b)
    } else {
        (10, t.as_str())
    };
    let digits: String = body.chars().take_while(|c| c.is_digit(radix)).collect();
    if digits.is_empty() {
        return None;
    }
    u64::from_str_radix(&digits, radix).ok()
}

/// Do two `*`-wildcard patterns admit a common concrete name?
/// (Concrete strings are patterns without `*`.)  Names are short, so
/// the exponential corner of the classic recursion is irrelevant.
pub fn patterns_compatible(a: &str, b: &str) -> bool {
    fn go(a: &[u8], b: &[u8]) -> bool {
        match (a.first(), b.first()) {
            (None, None) => true,
            (Some(b'*'), _) => go(&a[1..], b) || (!b.is_empty() && go(a, &b[1..])),
            (_, Some(b'*')) => go(a, &b[1..]) || (!a.is_empty() && go(&a[1..], b)),
            (Some(x), Some(y)) => x == y && go(&a[1..], &b[1..]),
            _ => false,
        }
    }
    go(a.as_bytes(), b.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::super::tokens::tokenize;
    use super::*;

    fn rules_of(rel: &str, src: &str) -> Vec<&'static str> {
        scan_file(rel, &tokenize(src)).findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn determinism_scoped_to_listed_dirs() {
        let src = "fn f() { let m: HashMap<u32, u32> = HashMap::new(); }";
        assert_eq!(rules_of("sampling/x.rs", src), ["determinism", "determinism"]);
        assert!(rules_of("eval/x.rs", src).is_empty(), "eval/ is out of scope");
        assert!(rules_of("sampling/x.rs", "fn f() { let m = FxHashMap::default(); }").is_empty());
    }

    #[test]
    fn instant_now_flagged_but_stored_elapsed_is_not() {
        assert_eq!(
            rules_of("trainer/x.rs", "fn f() { let t0 = Instant::now(); }"),
            ["determinism"]
        );
        assert!(rules_of("trainer/x.rs", "fn f(t0: Instant) { t0.elapsed(); }").is_empty());
    }

    #[test]
    fn panic_clean_token_accurate() {
        assert_eq!(rules_of("serve/x.rs", "fn f() { x.unwrap(); }"), ["panic-clean"]);
        assert!(rules_of("serve/x.rs", "fn f() { x.unwrap_or(0); }").is_empty());
        assert!(rules_of("serve/x.rs", "fn f() { let s = \".unwrap()\"; }").is_empty());
        assert!(rules_of("trainer/x.rs", "fn f() { x.unwrap(); }").is_empty(), "trainer not scoped");
    }

    #[test]
    fn lock_order_descending_flagged() {
        let bad = "fn f(t: &T, m: &M) { let g = t.read_inner(); let c = lock_cache(m); }";
        assert_eq!(rules_of("dist/x.rs", bad), ["lock-order"]);
        let good = "fn f(t: &T, m: &M) { let c = lock_cache(m); let g = t.read_inner(); }";
        assert!(rules_of("dist/x.rs", good).is_empty());
    }

    #[test]
    fn cache_stripes_share_rank_zero() {
        // Two stripe guards held at once is a finding — ascending-shard
        // nesting cannot be proven statically, so serve code takes
        // stripes one at a time.
        let bad = "fn f(c: &C) { let a = c.lock_key(k1); let b = c.lock_key(k2); }";
        assert_eq!(rules_of("serve/x.rs", bad), ["lock-order"]);
        let bad = "fn f(m: &M, n: &M) { let a = lock_shard(m, 0); let b = lock_shard(n, 1); }";
        assert_eq!(rules_of("serve/x.rs", bad), ["lock-order"]);
        // Scoped or sequential stripe access is clean.
        let ok = "fn f(c: &C) { { let a = c.lock_key(k1); } let b = c.lock_at(1); }";
        assert!(rules_of("serve/x.rs", ok).is_empty());
        let ok = "fn f(c: &C) { for i in 0..n { let g = c.lock_at(i); g.put(i, &row); } }";
        assert!(rules_of("serve/x.rs", ok).is_empty());
        // Session lock under a held stripe guard follows the declared
        // cache -> session order and stays clean.
        let ok = "fn f(c: &C, e: &E) { let g = c.lock_key(k); e.forward_locked(sc, s, l); }";
        assert!(rules_of("serve/x.rs", ok).is_empty(), "session after cache is in order");
        let bad2 = "fn f(t: &T, c: &C) { let g = t.read_shard(s); let a = c.lock_key(k); }";
        assert_eq!(rules_of("dist/x.rs", bad2), ["lock-order"]);
    }

    #[test]
    fn lock_order_scope_release() {
        // Guard released by its block before the lower-rank acquisition.
        let ok = "fn f(t: &T, m: &M) { { let g = t.read_inner(); } let c = lock_cache(m); }";
        assert!(rules_of("dist/x.rs", ok).is_empty());
        // Temporary guard (not let-bound to the guard itself) releases
        // within the statement.
        let tmp = "fn f(rx: &M, m: &M) { let j = lock_clean(rx).recv(); let c = lock_cache(m); }";
        assert!(rules_of("serve/x.rs", tmp).is_empty());
    }

    #[test]
    fn salts_collected_and_parsed() {
        let s = scan_file(
            "trainer/x.rs",
            &tokenize("const A_SALT: u64 = 0x6e63;\nconst B_SALT: u64 = 441;"),
        );
        assert_eq!(s.salts.len(), 2);
        assert_eq!(s.salts[0].value, 0x6e63);
        assert_eq!(s.salts[1].value, 441);
    }

    #[test]
    fn names_collected_with_patterns() {
        let src = r#"
            fn f() {
                let _s = crate::span!("serve.batch.forward", seq = seq);
                crate::obs::metrics::counter_set("dist.local_elems", 1);
                gauge_set(&format!("pipeline.stage_secs.{name}"), 0.0);
                metrics::publish(metrics::closed_loop_snapshot("serve.uncached", &s));
            }
        "#;
        let s = scan_file("config/x.rs", &tokenize(src));
        assert!(s.names.contains(&"serve.batch.forward".to_string()));
        assert!(s.names.contains(&"dist.local_elems".to_string()));
        assert!(s.names.contains(&"pipeline.stage_secs.*".to_string()));
        assert!(s.names.contains(&"serve.uncached.*".to_string()));
    }

    #[test]
    fn instant_match_collects_every_branch_name() {
        let src = r#"
            fn f(l: Level) {
                crate::obs::trace::instant(
                    match l { Level::Debug => "log.debug", Level::Warn => "log.warn" },
                    Vec::new(),
                );
            }
        "#;
        let s = scan_file("obs/x.rs", &tokenize(src));
        assert!(s.names.contains(&"log.debug".to_string()));
        assert!(s.names.contains(&"log.warn".to_string()));
    }

    #[test]
    fn pattern_compatibility() {
        assert!(patterns_compatible("serve.uncached.requests", "serve.uncached.*"));
        assert!(patterns_compatible("serve.*.*", "serve.uncached.*"));
        assert!(patterns_compatible("trainer.multi.*.loss", "trainer.multi.*.loss"));
        assert!(!patterns_compatible("serve.pool.batches", "serve.uncached.*"));
        assert!(!patterns_compatible("loader.build", "loader.consume"));
    }
}
