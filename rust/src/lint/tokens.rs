//! Rust tokenizer for the in-repo lint pass (`gs lint`).
//!
//! Deliberately small: the rules in `rules.rs` need identifier/punct
//! sequences with line numbers, string-literal *contents* (for the
//! span/metric name table), comment text (for `lint:allow` waivers)
//! and a per-token `in_test` flag — not a full parse tree.  The value
//! over the retired `awk` greps in scripts/test.sh is exactly the four
//! things a line-regex can't do:
//!
//! * comment and string contents never look like code (`// .unwrap()`
//!   in prose is not a finding),
//! * `#[cfg(test)]` / `#[test]` items are skipped *per item* by brace
//!   matching, not by truncating the file at the first attribute — a
//!   production `fn` after a test `mod` is still linted,
//! * raw strings, char literals and lifetimes don't confuse quoting,
//! * waivers are parsed with their rule name and reason, so an
//!   unreasoned or typo'd waiver is itself a finding.

/// Token kind.  `Punct` carries the single character; multi-char
/// operators arrive as consecutive puncts (`::` is `Punct(':')` twice),
/// which is all the rules need.
#[derive(Debug, Clone, PartialEq)]
pub enum TokKind {
    Ident,
    Num,
    /// String literal — `text` holds the raw *contents* (escapes not
    /// decoded; the name table only carries names that need none).
    Str,
    Char,
    Lifetime,
    Punct(char),
}

/// One token with its source line (1-based) and whether it sits inside
/// a `#[cfg(test)]` / `#[test]` item.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub in_test: bool,
}

impl Tok {
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
}

/// A `// lint:allow(<rule>): reason` waiver comment.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: String,
    /// Reason text after the colon; empty when the author omitted it
    /// (which the `waiver` meta-rule reports as a finding).
    pub reason: String,
    pub line: u32,
}

/// Tokenized file: token stream plus the waivers its comments declare.
#[derive(Debug, Default)]
pub struct FileToks {
    pub toks: Vec<Tok>,
    pub waivers: Vec<Waiver>,
}

/// Tokenize `src`, marking test-only regions and collecting waivers.
pub fn tokenize(src: &str) -> FileToks {
    let mut out = FileToks::default();
    let b: Vec<char> = src.chars().collect();
    let mut i = 0usize;
    let mut line = 1u32;
    let n = b.len();
    while i < n {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < n && b[i + 1] == '/' => {
                let start = i;
                while i < n && b[i] != '\n' {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                // Waivers are plain `//` comments only: doc comments
                // (`///`, `//!`) *describing* the waiver syntax must
                // not parse as waivers themselves.
                let is_doc = text.starts_with("///") || text.starts_with("//!");
                if !is_doc {
                    if let Some(w) = parse_waiver(&text, line) {
                        out.waivers.push(w);
                    }
                }
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                // Nested block comments, per the Rust grammar.
                let mut depth = 1;
                i += 2;
                while i < n && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            '"' => {
                let (content, ni, nl) = scan_string(&b, i + 1, line);
                out.toks.push(tok(TokKind::Str, content, line));
                line = nl;
                i = ni;
            }
            'r' | 'b' if starts_raw_or_byte_string(&b, i) => {
                let (kind, content, ni, nl) = scan_prefixed_string(&b, i, line);
                out.toks.push(tok(kind, content, line));
                line = nl;
                i = ni;
            }
            '\'' => {
                // Lifetime (`'a`) vs char literal (`'a'`, `'\n'`).
                let mut j = i + 1;
                if j < n && (b[j].is_alphabetic() || b[j] == '_') {
                    let mut k = j;
                    while k < n && (b[k].is_alphanumeric() || b[k] == '_') {
                        k += 1;
                    }
                    if k < n && b[k] == '\'' {
                        // 'a' — a char literal after all.
                        out.toks.push(tok(TokKind::Char, b[j..k].iter().collect(), line));
                        i = k + 1;
                    } else {
                        out.toks.push(tok(TokKind::Lifetime, b[j..k].iter().collect(), line));
                        i = k;
                    }
                } else {
                    // Escaped or punctuation char literal: '\n', '\'', '('.
                    let mut content = String::new();
                    while j < n && b[j] != '\'' {
                        if b[j] == '\\' {
                            content.push(b[j]);
                            j += 1;
                        }
                        if j < n {
                            content.push(b[j]);
                            j += 1;
                        }
                    }
                    out.toks.push(tok(TokKind::Char, content, line));
                    i = j + 1;
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.toks.push(tok(TokKind::Ident, b[start..i].iter().collect(), line));
            }
            c if c.is_ascii_digit() => {
                // Integer/float body without the dot (so `0..10` stays
                // three tokens); hex/binary digits and suffixes are
                // alphanumeric and come along.
                let start = i;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.toks.push(tok(TokKind::Num, b[start..i].iter().collect(), line));
            }
            c => {
                out.toks.push(tok(TokKind::Punct(c), c.to_string(), line));
                i += 1;
            }
        }
    }
    mark_test_items(&mut out.toks);
    out
}

fn tok(kind: TokKind, text: String, line: u32) -> Tok {
    Tok { kind, text, line, in_test: false }
}

fn starts_raw_or_byte_string(b: &[char], i: usize) -> bool {
    // r"...", r#"..."#, b"...", br"...", b'x' is handled as char-ish.
    let n = b.len();
    match b[i] {
        'r' => {
            let mut j = i + 1;
            while j < n && b[j] == '#' {
                j += 1;
            }
            j < n && b[j] == '"'
        }
        'b' => {
            if i + 1 < n && b[i + 1] == '"' {
                return true;
            }
            if i + 1 < n && b[i + 1] == 'r' {
                let mut j = i + 2;
                while j < n && b[j] == '#' {
                    j += 1;
                }
                return j < n && b[j] == '"';
            }
            false
        }
        _ => false,
    }
}

/// Scan a normal (escapable) string body starting after the opening
/// quote; returns (contents, next index, next line).
fn scan_string(b: &[char], mut i: usize, mut line: u32) -> (String, usize, u32) {
    let n = b.len();
    let mut content = String::new();
    while i < n && b[i] != '"' {
        if b[i] == '\\' && i + 1 < n {
            content.push(b[i]);
            content.push(b[i + 1]);
            if b[i + 1] == '\n' {
                line += 1;
            }
            i += 2;
            continue;
        }
        if b[i] == '\n' {
            line += 1;
        }
        content.push(b[i]);
        i += 1;
    }
    (content, i + 1, line)
}

/// Scan `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#` starting at the prefix.
fn scan_prefixed_string(b: &[char], mut i: usize, mut line: u32) -> (TokKind, String, usize, u32) {
    let n = b.len();
    let mut raw = false;
    if b[i] == 'b' {
        i += 1;
    }
    if i < n && b[i] == 'r' {
        raw = true;
        i += 1;
    }
    let mut hashes = 0usize;
    while i < n && b[i] == '#' {
        hashes += 1;
        i += 1;
    }
    i += 1; // opening quote
    if !raw {
        let (content, ni, nl) = scan_string(b, i, line);
        return (TokKind::Str, content, ni, nl);
    }
    let mut content = String::new();
    'scan: while i < n {
        if b[i] == '"' {
            // Need `"` followed by `hashes` hashes to close.
            let mut k = i + 1;
            let mut seen = 0usize;
            while k < n && b[k] == '#' && seen < hashes {
                k += 1;
                seen += 1;
            }
            if seen == hashes {
                i = k;
                break 'scan;
            }
        }
        if b[i] == '\n' {
            line += 1;
        }
        content.push(b[i]);
        i += 1;
    }
    (TokKind::Str, content, i, line)
}

/// Parse `lint:allow(rule)` / `lint:allow(rule): reason` out of a line
/// comment.
fn parse_waiver(comment: &str, line: u32) -> Option<Waiver> {
    let at = comment.find("lint:allow(")?;
    let rest = &comment[at + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':').map(|r| r.trim().to_string()).unwrap_or_default();
    Some(Waiver { rule, reason, line })
}

/// Mark every token belonging to a `#[cfg(test)]` or `#[test]` item
/// (attributes included, through the item's closing brace or `;`).
fn mark_test_items(toks: &mut [Tok]) {
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if toks[i].is_punct('#') && i + 1 < n && toks[i + 1].is_punct('[') {
            let close = match_group(toks, i + 1, '[', ']');
            let group = &toks[i + 2..close.min(n)];
            let is_test_attr = match group.first() {
                Some(t) if t.is_ident("test") => true,
                Some(t) if t.is_ident("cfg") => group.iter().any(|t| t.is_ident("test")),
                _ => false,
            };
            if !is_test_attr {
                i = close + 1;
                continue;
            }
            // Skip any further attributes, then span the item itself.
            let mut j = close + 1;
            while j + 1 < n && toks[j].is_punct('#') && toks[j + 1].is_punct('[') {
                j = match_group(toks, j + 1, '[', ']') + 1;
            }
            let end = item_end(toks, j);
            for t in toks[i..end.min(n)].iter_mut() {
                t.in_test = true;
            }
            i = end;
            continue;
        }
        i += 1;
    }
}

/// Index of the token closing the group opened at `open_idx`.
fn match_group(toks: &[Tok], open_idx: usize, open: char, close: char) -> usize {
    let mut depth = 0i32;
    for (k, t) in toks.iter().enumerate().skip(open_idx) {
        if t.is_punct(open) {
            depth += 1;
        } else if t.is_punct(close) {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
    }
    toks.len()
}

/// One past the end of the item starting at `start`: the first
/// top-level `;`, or the matching `}` of the first top-level `{`.
fn item_end(toks: &[Tok], start: usize) -> usize {
    let n = toks.len();
    let (mut par, mut brk) = (0i32, 0i32);
    let mut k = start;
    while k < n {
        match toks[k].kind {
            TokKind::Punct('(') => par += 1,
            TokKind::Punct(')') => par -= 1,
            TokKind::Punct('[') => brk += 1,
            TokKind::Punct(']') => brk -= 1,
            TokKind::Punct(';') if par == 0 && brk == 0 => return k + 1,
            TokKind::Punct('{') if par == 0 && brk == 0 => {
                return match_group(toks, k, '{', '}') + 1;
            }
            _ => {}
        }
        k += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_comments_and_chars_do_not_leak_tokens() {
        let src = r##"
            fn f() {
                let s = "a.unwrap() // not code";
                let r = r#"HashMap "quoted""#;
                let c = '\'';
                let lt: &'static str = s; // .expect( in prose
            }
        "##;
        let ft = tokenize(src);
        assert!(!ft.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "unwrap"));
        assert!(!ft.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "HashMap"));
        assert!(ft.toks.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "static"));
        let strs: Vec<&str> = ft
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, ["a.unwrap() // not code", "HashMap \"quoted\""]);
    }

    #[test]
    fn cfg_test_marks_only_its_item() {
        let src = r#"
            fn prod_before() { x.unwrap(); }
            #[cfg(test)]
            mod tests {
                fn t() { y.unwrap(); }
            }
            fn prod_after() { z.unwrap(); }
        "#;
        let ft = tokenize(src);
        let unwraps: Vec<bool> = ft
            .toks
            .iter()
            .filter(|t| t.is_ident("unwrap"))
            .map(|t| t.in_test)
            .collect();
        assert_eq!(unwraps, [false, true, false], "only the test-mod unwrap is test code");
    }

    #[test]
    fn test_attr_marks_fn() {
        let src = "#[test]\nfn check() { a.unwrap(); }\nfn prod() { b.unwrap(); }";
        let ft = tokenize(src);
        let unwraps: Vec<bool> =
            ft.toks.iter().filter(|t| t.is_ident("unwrap")).map(|t| t.in_test).collect();
        assert_eq!(unwraps, [true, false]);
    }

    #[test]
    fn waivers_parse_rule_and_reason() {
        let src = "fn f() {\n  x(); // lint:allow(determinism): timing only\n  // lint:allow(panic-clean)\n}\n";
        let ft = tokenize(src);
        assert_eq!(ft.waivers.len(), 2);
        assert_eq!(ft.waivers[0].rule, "determinism");
        assert_eq!(ft.waivers[0].reason, "timing only");
        assert_eq!(ft.waivers[0].line, 2);
        assert_eq!(ft.waivers[1].rule, "panic-clean");
        assert_eq!(ft.waivers[1].reason, "");
    }

    #[test]
    fn doc_comments_describing_waivers_are_not_waivers() {
        let src = "/// Use `// lint:allow(determinism): why` here.\n\
                   //! The `// lint:allow(<rule>)` syntax.\n\
                   fn f() {}\n";
        let ft = tokenize(src);
        assert!(ft.waivers.is_empty());
    }

    #[test]
    fn line_numbers_survive_multiline_strings() {
        let src = "let a = \"x\ny\";\nfn f() {}\n";
        let ft = tokenize(src);
        let f = ft.toks.iter().find(|t| t.is_ident("f")).unwrap();
        assert_eq!(f.line, 3);
    }
}
