//! `gs lint` — in-repo static analysis enforcing the determinism,
//! panic-safety, lock-order and observability contracts.
//!
//! The repo's headline invariant is bit-identity: replies and metrics
//! must be identical for any `--num-workers`, pool size or fault
//! schedule (docs/ARCHITECTURE.md).  The runtime sweeps in
//! scripts/test.sh catch a regression only when a particular workload
//! trips it; this pass makes the *classes* of regression unrepresentable
//! at review time — a reintroduced `std::collections::HashMap`
//! iteration, an ambient `Instant::now()` on a reply path, an
//! `.unwrap()` in `serve/`, a lock taken against the declared order, a
//! colliding RNG salt, or a renamed span/metric leaving docs and the
//! golden fixture stale.
//!
//! Zero-dependency by construction: `tokens.rs` is a small
//! comment/string/`#[cfg(test)]`-aware Rust tokenizer, `rules.rs` the
//! rule set over it.  Per-line waivers (`// lint:allow(<rule>): reason`)
//! are the escape hatch and are themselves linted — no rule name typos,
//! no reasonless waivers.  See docs/LINTS.md for the catalog; the pass
//! is wired as a blocking gate in scripts/test.sh, and
//! scripts/check_docs.sh reuses the extracted name table
//! (`gs lint --dump-names`) to validate doc-mentioned span/metric
//! names.

pub mod rules;
pub mod tokens;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use rules::Finding;

/// Result of linting a tree.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Findings that survived waivers, ordered by (file, line).
    pub findings: Vec<Finding>,
    /// Waivers that suppressed a finding.
    pub waivers_used: usize,
    /// `.rs` files scanned.
    pub files: usize,
}

/// Collect every `.rs` file under `root`, sorted for deterministic
/// output.
fn rust_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries =
            std::fs::read_dir(&dir).with_context(|| format!("read dir {}", dir.display()))?;
        for e in entries {
            let p = e?.path();
            let name = p.file_name().and_then(|s| s.to_str()).unwrap_or("");
            if p.is_dir() {
                if name != "target" && !name.starts_with('.') {
                    stack.push(p);
                }
            } else if name.ends_with(".rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// `/`-separated path of `p` relative to `root` (falls back to the
/// full path when `p` is outside `root`).
fn rel_path(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Repo context the cross-file `name-registry` rule checks against:
/// found by walking up from the lint root, so the pass works both on
/// the real tree (`rust/src` → `rust/tests/fixtures`, `docs/`) and on
/// test fixtures laid out the same way.
#[derive(Debug, Default)]
struct RepoCtx {
    golden: Option<PathBuf>,
    obs_doc: Option<PathBuf>,
}

fn find_repo_ctx(lint_root: &Path) -> RepoCtx {
    let mut ctx = RepoCtx::default();
    let start = lint_root.canonicalize().unwrap_or_else(|_| lint_root.to_path_buf());
    let mut dir = Some(start.as_path());
    while let Some(d) = dir {
        if ctx.golden.is_none() {
            let g = d.join("tests/fixtures/serve_metrics_names.golden.txt");
            if g.is_file() {
                ctx.golden = Some(g);
            }
        }
        if ctx.obs_doc.is_none() {
            let o = d.join("docs/OBSERVABILITY.md");
            if o.is_file() {
                ctx.obs_doc = Some(o);
            }
        }
        if ctx.golden.is_some() && ctx.obs_doc.is_some() {
            break;
        }
        dir = d.parent();
    }
    ctx
}

/// Instrumentation-name prefixes the docs cross-check recognizes.
/// (Config keys like `serve.pool_workers` are validated separately by
/// scripts/check_docs.sh against the config structs.)
const NAME_PREFIXES: &[&str] =
    &["serve.", "trainer.", "loader.", "pipeline.", "dist.", "alloc.", "log."];

/// Extract backticked instrumentation names from a markdown doc,
/// `<placeholder>` segments already converted to `*` wildcards.
fn doc_names(text: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let mut rest = line;
        while let Some(open) = rest.find('`') {
            let tail = &rest[open + 1..];
            let Some(close) = tail.find('`') else { break };
            let span = &tail[..close];
            rest = &tail[close + 1..];
            if !NAME_PREFIXES.iter().any(|p| span.starts_with(p)) {
                continue;
            }
            // Skip file paths and source files (`obs/log.rs` styles).
            if span.contains('/') || span.ends_with(".rs") || span.ends_with(".md") {
                continue;
            }
            if !span.chars().all(|c| {
                c.is_ascii_lowercase()
                    || c.is_ascii_digit()
                    || matches!(c, '.' | '_' | '*' | '<' | '>' | '+' | '-')
            }) {
                continue;
            }
            // `<arm>` placeholders become wildcards.
            let mut pat = String::new();
            let mut in_ph = false;
            for c in span.chars() {
                match c {
                    '<' => {
                        in_ph = true;
                        pat.push('*');
                    }
                    '>' => in_ph = false,
                    c if !in_ph => pat.push(c),
                    _ => {}
                }
            }
            out.push((pat, ln as u32 + 1));
        }
    }
    out
}

/// The extracted span/metric name table for a tree: every name (or
/// `*`-pattern, from `format!` call sites) the production code can
/// emit.  Sorted and deduplicated — `gs lint --dump-names`, consumed
/// by scripts/check_docs.sh.
pub fn name_table(root: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for p in rust_files(root)? {
        let src =
            std::fs::read_to_string(&p).with_context(|| format!("read {}", p.display()))?;
        let ft = tokens::tokenize(&src);
        names.extend(rules::scan_file(&rel_path(root, &p), &ft).names);
    }
    names.sort();
    names.dedup();
    Ok(names)
}

/// Run every rule over the tree at `root`.
pub fn lint_path(root: &Path) -> Result<LintReport> {
    let mut report = LintReport::default();
    let mut salts: Vec<rules::SaltDef> = Vec::new();
    let mut names: Vec<String> = Vec::new();

    for p in rust_files(root)? {
        let src =
            std::fs::read_to_string(&p).with_context(|| format!("read {}", p.display()))?;
        let rel = rel_path(root, &p);
        let ft = tokens::tokenize(&src);
        let scan = rules::scan_file(&rel, &ft);
        salts.extend(scan.salts);
        names.extend(scan.names);

        // Waiver application: a valid waiver on the finding's line (or
        // the line above, for waivers on their own line) suppresses it.
        let mut findings = scan.findings;
        for w in &ft.waivers {
            let known = rules::RULES.contains(&w.rule.as_str());
            if !known || w.reason.is_empty() {
                let msg = if known {
                    format!("waiver for `{}` has no reason; use // lint:allow({}): <why>", w.rule, w.rule)
                } else {
                    format!(
                        "waiver names unknown rule `{}` (rules: {})",
                        w.rule,
                        rules::RULES.join(", ")
                    )
                };
                findings.push(Finding { file: rel.clone(), line: w.line, rule: "waiver", msg });
                continue;
            }
            let before = findings.len();
            findings.retain(|f| {
                !(f.rule == w.rule && (f.line == w.line || f.line == w.line + 1))
            });
            if findings.len() < before {
                report.waivers_used += 1;
            }
        }
        report.findings.extend(findings);
        report.files += 1;
    }

    // --- salt-unique ------------------------------------------------------
    let mut by_value: BTreeMap<u64, Vec<&rules::SaltDef>> = BTreeMap::new();
    for s in &salts {
        by_value.entry(s.value).or_default().push(s);
    }
    for (v, defs) in &by_value {
        if defs.len() > 1 {
            let first = defs[0];
            for dup in &defs[1..] {
                report.findings.push(Finding {
                    file: dup.file.clone(),
                    line: dup.line,
                    rule: "salt-unique",
                    msg: format!(
                        "{} = {v:#x} collides with {} ({}:{}); RNG salts must be distinct so \
                         seed sub-streams never alias",
                        dup.name, first.name, first.file, first.line
                    ),
                });
            }
        }
    }

    // --- name-registry ----------------------------------------------------
    names.sort();
    names.dedup();
    let ctx = find_repo_ctx(root);
    let known = |name: &str| names.iter().any(|n| rules::patterns_compatible(name, n));
    if let Some(golden) = &ctx.golden {
        let text = std::fs::read_to_string(golden)
            .with_context(|| format!("read golden {}", golden.display()))?;
        for (ln, line) in text.lines().enumerate() {
            let name = line.trim();
            if name.is_empty() || known(name) {
                continue;
            }
            report.findings.push(Finding {
                file: golden.display().to_string(),
                line: ln as u32 + 1,
                rule: "name-registry",
                msg: format!(
                    "golden metric `{name}` matches no span!/event!/metrics call site in the tree"
                ),
            });
        }
    }
    if let Some(doc) = &ctx.obs_doc {
        let text = std::fs::read_to_string(doc)
            .with_context(|| format!("read doc {}", doc.display()))?;
        for (name, ln) in doc_names(&text) {
            if known(&name) {
                continue;
            }
            report.findings.push(Finding {
                file: doc.display().to_string(),
                line: ln,
                rule: "name-registry",
                msg: format!(
                    "documented name `{name}` matches no span!/event!/metrics call site; \
                     renamed instrumentation must update the docs"
                ),
            });
        }
    }

    report.findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

/// CLI driver for `gs lint [--dump-names] [PATH]` (main.rs adapter).
pub fn run_cli(args: &[String]) -> Result<()> {
    let mut path: Option<String> = None;
    let mut dump = false;
    for a in args {
        match a.as_str() {
            "--dump-names" => dump = true,
            s if s.starts_with('-') => {
                anyhow::bail!("gs lint: unknown flag {s} (usage: gs lint [--dump-names] [PATH])")
            }
            s => {
                if path.replace(s.to_string()).is_some() {
                    anyhow::bail!("gs lint: more than one PATH given");
                }
            }
        }
    }
    let root = match path {
        Some(p) => PathBuf::from(p),
        // Default to the production tree whether invoked from the repo
        // root or from rust/.
        None if Path::new("rust/src").is_dir() => PathBuf::from("rust/src"),
        None if Path::new("src").is_dir() => PathBuf::from("src"),
        None => anyhow::bail!("gs lint: no PATH given and no rust/src or src/ here"),
    };
    if dump {
        for n in name_table(&root)? {
            println!("{n}");
        }
        return Ok(());
    }
    let report = lint_path(&root)?;
    for f in &report.findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
    }
    if !report.findings.is_empty() {
        anyhow::bail!(
            "gs lint: {} finding(s) across {} file(s) — fix or waive with \
             // lint:allow(<rule>): reason  (docs/LINTS.md)",
            report.findings.len(),
            report.files
        );
    }
    println!(
        "gs lint: OK — {} files clean ({} waiver{} in effect)",
        report.files,
        report.waivers_used,
        if report.waivers_used == 1 { "" } else { "s" }
    );
    Ok(())
}
