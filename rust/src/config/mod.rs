//! Declarative run configuration (paper §2 / Appendix B).
//!
//! GraphStorm's headline property is "graph construction and model
//! training and inference with just a single command" driven by one
//! config file.  This module is that surface for graphstorm-rs: a
//! [`RunConfig`] parsed from JSON (via `util::json` — serde is
//! unavailable offline) declares the whole run as composable stages
//!
//! ```text
//! data → partition → [lm] → [task (nc|lp|distill)] → [infer] → [serve]
//! ```
//!
//! each a validated typed struct whose defaults live **here and only
//! here** — `main.rs` holds no literal stage defaults.  Parsing is
//! strict: unknown keys, type mismatches and inconsistent stage
//! combinations (e.g. an `lm` stage with an `lp` task) are hard
//! errors, and unknown keys come with a nearest-key suggestion so a
//! typo'd `"epcohs"` can never silently train with the default.
//!
//! [`cli`] adapts the `gs` subcommands onto this API (every flag is an
//! override over a config document, `--set stage.key=value` is the
//! generic escape hatch) and [`pipeline::Pipeline`] executes the
//! declared stages in order, threading one dataset through them.

pub mod cli;
pub mod pipeline;

pub use pipeline::{Pipeline, PipelineOutcome};

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

use crate::dataloader::autoscale_workers;
use crate::sampling::NegSampler;
use crate::serve::{Admission, EnginePoolCfg, FaultSpec, MicroBatcherCfg};
use crate::trainer::lp::LpLoss;
use crate::trainer::multi::{HeadKind, MultiTaskTrainer, TaskSpec};
use crate::trainer::TrainOptions;
use crate::util::json::{Json, obj};

// ------------------------------------------------------------------ keys

/// Levenshtein edit distance (small inputs: config keys / CLI flags).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The nearest valid key, for "did you mean" suggestions.
pub fn nearest_key<'a>(key: &str, valid: impl IntoIterator<Item = &'a str>) -> Option<&'a str> {
    valid.into_iter().min_by_key(|v| levenshtein(key, v))
}

/// `" (did you mean 'x'?)"` when a plausible neighbor exists, else "".
pub fn did_you_mean(key: &str, valid: &[&str]) -> String {
    match nearest_key(key, valid.iter().copied()) {
        Some(s) if levenshtein(key, s) <= (s.len() / 2).max(2) => {
            format!(" (did you mean '{s}'?)")
        }
        _ => String::new(),
    }
}

fn unknown_key(ctx: &str, key: &str, valid: &[&str]) -> anyhow::Error {
    anyhow!(
        "unknown key '{key}' in {ctx}{}; valid keys: {}",
        did_you_mean(key, valid),
        valid.join(", ")
    )
}

// ----------------------------------------------------------- typed reads

fn as_int(ctx: &str, key: &str, v: &Json) -> Result<i64> {
    match v.as_f64() {
        Some(f) if f.fract() == 0.0 && f.abs() < 9e15 => Ok(f as i64),
        Some(f) => bail!("{ctx}.{key} must be an integer, got {f}"),
        None => bail!("{ctx}.{key} must be a number, got {}", type_name(v)),
    }
}

fn take_usize(ctx: &str, key: &str, v: &Json) -> Result<usize> {
    let n = as_int(ctx, key, v)?;
    if n < 0 {
        bail!("{ctx}.{key} must be >= 0, got {n}");
    }
    Ok(n as usize)
}

fn take_u64(ctx: &str, key: &str, v: &Json) -> Result<u64> {
    let n = as_int(ctx, key, v)?;
    if n < 0 {
        bail!("{ctx}.{key} must be >= 0, got {n}");
    }
    Ok(n as u64)
}

fn take_f64(ctx: &str, key: &str, v: &Json) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("{ctx}.{key} must be a number, got {}", type_name(v)))
}

fn take_str<'j>(ctx: &str, key: &str, v: &'j Json) -> Result<&'j str> {
    v.as_str().ok_or_else(|| anyhow!("{ctx}.{key} must be a string, got {}", type_name(v)))
}

fn take_bool(ctx: &str, key: &str, v: &Json) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow!("{ctx}.{key} must be a bool, got {}", type_name(v)))
}

fn type_name(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "a bool",
        Json::Num(_) => "a number",
        Json::Str(_) => "a string",
        Json::Arr(_) => "an array",
        Json::Obj(_) => "an object",
    }
}

fn stage_obj<'j>(ctx: &str, v: &'j Json) -> Result<&'j BTreeMap<String, Json>> {
    v.as_obj().ok_or_else(|| anyhow!("{ctx} must be a JSON object, got {}", type_name(v)))
}

// --------------------------------------------------------------- loader

/// Loader worker count: a fixed thread count or `"auto"` (resolved
/// from `std::thread::available_parallelism`, clamped and logged).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workers {
    Auto,
    Fixed(usize),
}

/// Batch-building pipeline knobs (`loader` stage; CLI `--num-workers`
/// / `--prefetch`).  Output is bit-identical for any worker count.
#[derive(Debug, Clone, PartialEq)]
pub struct LoaderCfg {
    pub workers: Workers,
    pub prefetch: usize,
}

impl Default for LoaderCfg {
    fn default() -> Self {
        LoaderCfg { workers: Workers::Fixed(1), prefetch: 2 }
    }
}

impl LoaderCfg {
    const KEYS: &'static [&'static str] = &["workers", "prefetch"];

    fn from_json(v: &Json) -> Result<LoaderCfg> {
        let m = stage_obj("loader", v)?;
        let mut c = LoaderCfg::default();
        for (k, v) in m {
            match k.as_str() {
                "workers" => {
                    c.workers = match v {
                        Json::Str(s) if s == "auto" => Workers::Auto,
                        Json::Str(s) => bail!(
                            "loader.workers must be a thread count or \"auto\", got \"{s}\""
                        ),
                        v => Workers::Fixed(take_usize("loader", "workers", v)?),
                    }
                }
                "prefetch" => c.prefetch = take_usize("loader", "prefetch", v)?,
                _ => return Err(unknown_key("loader", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        let workers = match self.workers {
            Workers::Auto => Json::from("auto"),
            Workers::Fixed(n) => Json::from(n),
        };
        obj(vec![("workers", workers), ("prefetch", Json::from(self.prefetch))])
    }

    /// The concrete worker count (resolves `"auto"`, with a log line).
    pub fn resolve_workers(&self) -> usize {
        match self.workers {
            Workers::Fixed(n) => n,
            Workers::Auto => autoscale_workers(),
        }
    }

    /// These knobs as a prefetching-loader config.
    pub fn prefetch_cfg(&self) -> crate::dataloader::PrefetchConfig {
        crate::dataloader::PrefetchConfig {
            n_workers: self.resolve_workers(),
            depth: self.prefetch,
        }
    }

    fn validate(&self) -> Result<()> {
        if let Workers::Fixed(0) = self.workers {
            bail!("loader.workers must be >= 1 (use 1 for serial batch building)");
        }
        if self.prefetch == 0 {
            bail!("loader.prefetch must be >= 1");
        }
        Ok(())
    }
}

// ----------------------------------------------------------------- data

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    Mag,
    Amazon,
    ScaleFree,
}

impl Dataset {
    pub fn parse(s: &str) -> Result<Dataset> {
        Ok(match s {
            "mag" => Dataset::Mag,
            "amazon" => Dataset::Amazon,
            "scale-free" => Dataset::ScaleFree,
            other => {
                return Err(anyhow!(
                    "unknown dataset '{other}'{}; valid: mag, amazon, scale-free",
                    did_you_mean(other, &["mag", "amazon", "scale-free"])
                ))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Dataset::Mag => "mag",
            Dataset::Amazon => "amazon",
            Dataset::ScaleFree => "scale-free",
        }
    }

    /// Default generator size (papers / items / edges).
    pub fn default_size(self) -> usize {
        match self {
            Dataset::Mag => 4000,
            Dataset::Amazon => 3000,
            Dataset::ScaleFree => 100_000,
        }
    }
}

/// Where the graph comes from: a synthetic generator or gconstruct
/// over tabular files + a schema config.
#[derive(Debug, Clone, PartialEq)]
pub enum DataSource {
    Gen { dataset: Dataset, size: usize },
    GConstruct { conf: String, dir: String },
}

/// `data` stage: produce the raw graph (features, labels, tokens).
#[derive(Debug, Clone, PartialEq)]
pub struct DataCfg {
    pub source: DataSource,
    /// Learnable-embedding width for featureless node types.
    pub lemb_dim: usize,
    /// Hashed bag-of-tokens feature width for text nodes (pre-LM).
    pub text_dim: usize,
}

impl Default for DataCfg {
    fn default() -> Self {
        DataCfg {
            source: DataSource::Gen { dataset: Dataset::Mag, size: Dataset::Mag.default_size() },
            lemb_dim: 64,
            text_dim: 64,
        }
    }
}

impl DataCfg {
    const KEYS: &'static [&'static str] =
        &["source", "dataset", "size", "conf", "dir", "lemb_dim", "text_dim"];

    fn from_json(v: &Json) -> Result<DataCfg> {
        let m = stage_obj("data", v)?;
        let source = match m.get("source") {
            None => "gen",
            Some(v) => take_str("data", "source", v)?,
        };
        let mut c = DataCfg::default();
        match source {
            "gen" => {
                let dataset = match m.get("dataset") {
                    None => Dataset::Mag,
                    Some(v) => Dataset::parse(take_str("data", "dataset", v)?)?,
                };
                let mut size = dataset.default_size();
                for (k, v) in m {
                    match k.as_str() {
                        "source" | "dataset" => {}
                        "size" => size = take_usize("data", "size", v)?,
                        "lemb_dim" => c.lemb_dim = take_usize("data", "lemb_dim", v)?,
                        "text_dim" => c.text_dim = take_usize("data", "text_dim", v)?,
                        "conf" | "dir" => bail!(
                            "data.{k} is only valid for source \"gconstruct\" (current source \"gen\")"
                        ),
                        _ => return Err(unknown_key("data", k, Self::KEYS)),
                    }
                }
                c.source = DataSource::Gen { dataset, size };
            }
            "gconstruct" => {
                let mut conf = "schema.json".to_string();
                let mut dir = ".".to_string();
                for (k, v) in m {
                    match k.as_str() {
                        "source" => {}
                        "conf" => conf = take_str("data", "conf", v)?.to_string(),
                        "dir" => dir = take_str("data", "dir", v)?.to_string(),
                        "lemb_dim" => c.lemb_dim = take_usize("data", "lemb_dim", v)?,
                        "text_dim" => c.text_dim = take_usize("data", "text_dim", v)?,
                        "dataset" | "size" => bail!(
                            "data.{k} is only valid for source \"gen\" (current source \"gconstruct\")"
                        ),
                        _ => return Err(unknown_key("data", k, Self::KEYS)),
                    }
                }
                c.source = DataSource::GConstruct { conf, dir };
            }
            other => bail!(
                "data.source must be \"gen\" or \"gconstruct\", got \"{other}\"{}",
                did_you_mean(other, &["gen", "gconstruct"])
            ),
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        let mut pairs = match &self.source {
            DataSource::Gen { dataset, size } => vec![
                ("source", Json::from("gen")),
                ("dataset", Json::from(dataset.name())),
                ("size", Json::from(*size)),
            ],
            DataSource::GConstruct { conf, dir } => vec![
                ("source", Json::from("gconstruct")),
                ("conf", Json::from(conf.as_str())),
                ("dir", Json::from(dir.as_str())),
            ],
        };
        pairs.push(("lemb_dim", Json::from(self.lemb_dim)));
        pairs.push(("text_dim", Json::from(self.text_dim)));
        obj(pairs)
    }

    fn validate(&self) -> Result<()> {
        if let DataSource::Gen { size, .. } = self.source {
            if size == 0 {
                bail!("data.size must be >= 1");
            }
        }
        if self.lemb_dim == 0 || self.text_dim == 0 {
            bail!("data.lemb_dim and data.text_dim must be >= 1");
        }
        Ok(())
    }
}

// ------------------------------------------------------------ partition

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartMethod {
    Random,
    Metis,
}

impl PartMethod {
    pub fn name(self) -> &'static str {
        match self {
            PartMethod::Random => "random",
            PartMethod::Metis => "metis",
        }
    }
}

/// `partition` stage: split the graph into `parts` for the simulated
/// distributed engine.  `parts: 1` keeps a single partition.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionCfg {
    pub parts: usize,
    pub method: PartMethod,
}

impl Default for PartitionCfg {
    fn default() -> Self {
        PartitionCfg { parts: 1, method: PartMethod::Random }
    }
}

impl PartitionCfg {
    const KEYS: &'static [&'static str] = &["parts", "method"];

    fn from_json(v: &Json) -> Result<PartitionCfg> {
        let m = stage_obj("partition", v)?;
        let mut c = PartitionCfg::default();
        for (k, v) in m {
            match k.as_str() {
                "parts" => c.parts = take_usize("partition", "parts", v)?,
                "method" => {
                    c.method = match take_str("partition", "method", v)? {
                        "random" => PartMethod::Random,
                        "metis" => PartMethod::Metis,
                        other => bail!(
                            "partition.method must be \"random\" or \"metis\", got \"{other}\"{}",
                            did_you_mean(other, &["random", "metis"])
                        ),
                    }
                }
                _ => return Err(unknown_key("partition", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("parts", Json::from(self.parts)),
            ("method", Json::from(self.method.name())),
        ])
    }

    fn validate(&self) -> Result<()> {
        if self.parts == 0 {
            bail!("partition.parts must be >= 1");
        }
        Ok(())
    }
}

// ------------------------------------------------------------------- lm

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LmMode {
    /// MLM-pretrained text embeddings only.
    Pretrained,
    /// Pretrain, then fine-tune on the node-classification labels.
    Finetuned,
}

impl LmMode {
    pub fn name(self) -> &'static str {
        match self {
            LmMode::Pretrained => "pretrained",
            LmMode::Finetuned => "finetuned",
        }
    }
}

/// Optional `lm` stage: language-model text embeddings replacing the
/// hashed bag-of-tokens features before GNN training (paper §3.2).
#[derive(Debug, Clone, PartialEq)]
pub struct LmCfg {
    pub mode: LmMode,
    pub pretrain_epochs: usize,
    pub finetune_epochs: usize,
}

impl Default for LmCfg {
    fn default() -> Self {
        LmCfg { mode: LmMode::Pretrained, pretrain_epochs: 1, finetune_epochs: 2 }
    }
}

impl LmCfg {
    const KEYS: &'static [&'static str] = &["mode", "pretrain_epochs", "finetune_epochs"];

    fn from_json(v: &Json) -> Result<LmCfg> {
        let m = stage_obj("lm", v)?;
        let mut c = LmCfg::default();
        for (k, v) in m {
            match k.as_str() {
                "mode" => {
                    c.mode = match take_str("lm", "mode", v)? {
                        "pretrained" => LmMode::Pretrained,
                        "finetuned" => LmMode::Finetuned,
                        other => bail!(
                            "lm.mode must be \"pretrained\" or \"finetuned\", got \"{other}\"{} \
                             (drop the lm stage entirely for hashed-token features)",
                            did_you_mean(other, &["pretrained", "finetuned"])
                        ),
                    }
                }
                "pretrain_epochs" => c.pretrain_epochs = take_usize("lm", "pretrain_epochs", v)?,
                "finetune_epochs" => c.finetune_epochs = take_usize("lm", "finetune_epochs", v)?,
                _ => return Err(unknown_key("lm", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("mode", Json::from(self.mode.name())),
            ("pretrain_epochs", Json::from(self.pretrain_epochs)),
            ("finetune_epochs", Json::from(self.finetune_epochs)),
        ])
    }

    fn validate(&self) -> Result<()> {
        if self.pretrain_epochs == 0 {
            bail!("lm.pretrain_epochs must be >= 1");
        }
        if self.mode == LmMode::Finetuned && self.finetune_epochs == 0 {
            bail!("lm.finetune_epochs must be >= 1 for mode \"finetuned\"");
        }
        Ok(())
    }
}

// ----------------------------------------------------------------- task

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Nc,
    Lp,
    Distill,
}

impl TaskKind {
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Nc => "nc",
            TaskKind::Lp => "lp",
            TaskKind::Distill => "distill",
        }
    }
}

/// Parse a task `kind` value (shared by the single `task` object and
/// `tasks[i]` array entries — `ctx` names the reporting site).
fn parse_task_kind(ctx: &str, v: &Json) -> Result<TaskKind> {
    Ok(match take_str(ctx, "kind", v)? {
        "nc" => TaskKind::Nc,
        "lp" => TaskKind::Lp,
        "distill" => TaskKind::Distill,
        other => bail!(
            "{ctx}.kind must be \"nc\", \"lp\" or \"distill\", got \"{other}\"{}",
            did_you_mean(other, &["nc", "lp", "distill"])
        ),
    })
}

/// Parse an LP `loss` value (same sharing as [`parse_task_kind`]).
fn parse_lp_loss(ctx: &str, v: &Json) -> Result<LpLoss> {
    Ok(match take_str(ctx, "loss", v)? {
        "contrastive" => LpLoss::Contrastive,
        "ce" | "cross-entropy" => LpLoss::CrossEntropy,
        other => bail!(
            "{ctx}.loss must be \"contrastive\" or \"ce\", got \"{other}\"{}",
            did_you_mean(other, &["contrastive", "ce"])
        ),
    })
}

/// Parse a negative-sampler spec (`joint-32`, `local-joint-16`,
/// `uniform-8`, `in-batch`).
pub fn parse_neg(s: &str) -> Result<NegSampler> {
    if s == "in-batch" {
        return Ok(NegSampler::InBatch { k: 32 });
    }
    let (kind, k) = s
        .rsplit_once('-')
        .with_context(|| format!("task.neg must look like joint-32 / uniform-8 / in-batch, got '{s}'"))?;
    let k: usize = k.parse().with_context(|| format!("task.neg '{s}': bad count '{k}'"))?;
    Ok(match kind {
        "joint" => NegSampler::Joint { k },
        "local-joint" => NegSampler::LocalJoint { k },
        "uniform" => NegSampler::Uniform { k },
        other => {
            return Err(anyhow!(
                "unknown negative sampler '{other}'{}; valid: joint, local-joint, uniform, in-batch",
                did_you_mean(other, &["joint", "local-joint", "uniform", "in-batch"])
            ))
        }
    })
}

/// Canonical spelling of a negative sampler (inverse of [`parse_neg`]).
pub fn neg_name(s: NegSampler) -> String {
    match s {
        NegSampler::Joint { k } => format!("joint-{k}"),
        NegSampler::LocalJoint { k } => format!("local-joint-{k}"),
        NegSampler::Uniform { k } => format!("uniform-{k}"),
        NegSampler::InBatch { .. } => "in-batch".to_string(),
    }
}

/// `task` stage: the training loop.  `loss` / `neg` /
/// `max_edges_per_epoch` are link-prediction-only; `teacher_epochs` is
/// distillation-only — setting them for another kind is a hard error.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskCfg {
    pub kind: TaskKind,
    pub arch: String,
    pub epochs: usize,
    pub lr: f32,
    /// Save the trained model to this GSTF path (nc only).
    pub save_model: Option<String>,
    /// LP loss (lp only).
    pub loss: LpLoss,
    /// LP negative sampler (lp only).
    pub neg: NegSampler,
    /// LP per-epoch training-edge cap (lp only).
    pub max_edges_per_epoch: usize,
    /// GNN teacher epochs before distilling (distill only).
    pub teacher_epochs: usize,
}

impl Default for TaskCfg {
    fn default() -> Self {
        TaskCfg {
            kind: TaskKind::Nc,
            arch: "rgcn".to_string(),
            epochs: 3,
            lr: 3e-3,
            save_model: None,
            loss: LpLoss::Contrastive,
            neg: NegSampler::Joint { k: 32 },
            max_edges_per_epoch: 3200,
            teacher_epochs: 5,
        }
    }
}

impl TaskCfg {
    const KEYS: &'static [&'static str] = &[
        "kind",
        "arch",
        "epochs",
        "lr",
        "save_model",
        "loss",
        "neg",
        "max_edges_per_epoch",
        "teacher_epochs",
    ];

    fn from_json(v: &Json) -> Result<TaskCfg> {
        let m = stage_obj("task", v)?;
        let kind = match m.get("kind") {
            None => TaskKind::Nc,
            Some(v) => parse_task_kind("task", v)?,
        };
        let only = |key: &str, wanted: TaskKind| -> Result<()> {
            if kind != wanted {
                bail!(
                    "task.{key} is only valid for kind \"{}\" (current kind \"{}\")",
                    wanted.name(),
                    kind.name()
                );
            }
            Ok(())
        };
        let mut c = TaskCfg { kind, ..TaskCfg::default() };
        for (k, v) in m {
            match k.as_str() {
                "kind" => {}
                "arch" => c.arch = take_str("task", "arch", v)?.to_string(),
                "epochs" => c.epochs = take_usize("task", "epochs", v)?,
                "lr" => c.lr = take_f64("task", "lr", v)? as f32,
                "save_model" => {
                    only("save_model", TaskKind::Nc)?;
                    c.save_model = Some(take_str("task", "save_model", v)?.to_string());
                }
                "loss" => {
                    only("loss", TaskKind::Lp)?;
                    c.loss = parse_lp_loss("task", v)?;
                }
                "neg" => {
                    only("neg", TaskKind::Lp)?;
                    c.neg = parse_neg(take_str("task", "neg", v)?)?;
                }
                "max_edges_per_epoch" => {
                    only("max_edges_per_epoch", TaskKind::Lp)?;
                    c.max_edges_per_epoch = take_usize("task", "max_edges_per_epoch", v)?;
                }
                "teacher_epochs" => {
                    only("teacher_epochs", TaskKind::Distill)?;
                    c.teacher_epochs = take_usize("task", "teacher_epochs", v)?;
                }
                _ => return Err(unknown_key("task", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::from(self.kind.name())),
            ("arch", Json::from(self.arch.as_str())),
            ("epochs", Json::from(self.epochs)),
            ("lr", Json::from(self.lr as f64)),
        ];
        match self.kind {
            TaskKind::Nc => {
                if let Some(p) = &self.save_model {
                    pairs.push(("save_model", Json::from(p.as_str())));
                }
            }
            TaskKind::Lp => {
                pairs.push((
                    "loss",
                    Json::from(match self.loss {
                        LpLoss::Contrastive => "contrastive",
                        LpLoss::CrossEntropy => "ce",
                    }),
                ));
                pairs.push(("neg", Json::Str(neg_name(self.neg))));
                pairs.push(("max_edges_per_epoch", Json::from(self.max_edges_per_epoch)));
            }
            TaskKind::Distill => {
                pairs.push(("teacher_epochs", Json::from(self.teacher_epochs)));
            }
        }
        obj(pairs)
    }

    fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            bail!("task.epochs must be >= 1");
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("task.lr must be a positive finite number");
        }
        if self.kind == TaskKind::Distill && self.teacher_epochs == 0 {
            bail!("task.teacher_epochs must be >= 1");
        }
        if self.kind == TaskKind::Lp && self.max_edges_per_epoch == 0 {
            bail!("task.max_edges_per_epoch must be >= 1 (a zero cap trains nothing)");
        }
        Ok(())
    }
}

// ----------------------------------------------------------- multi-task

/// Shared-encoder settings for a multi-task run (top-level `encoder`
/// object; only valid together with a `tasks` array).  These are the
/// knobs every head shares: the trunk architecture and the joint
/// training loop's epochs / default learning rate.
#[derive(Debug, Clone, PartialEq)]
pub struct EncoderCfg {
    pub arch: String,
    pub epochs: usize,
    /// Default learning rate for heads that set none of their own.
    pub lr: f32,
}

impl Default for EncoderCfg {
    fn default() -> Self {
        EncoderCfg { arch: "rgcn".to_string(), epochs: 3, lr: 3e-3 }
    }
}

impl EncoderCfg {
    const KEYS: &'static [&'static str] = &["arch", "epochs", "lr"];

    fn from_json(v: &Json) -> Result<EncoderCfg> {
        let m = stage_obj("encoder", v)?;
        let mut c = EncoderCfg::default();
        for (k, v) in m {
            match k.as_str() {
                "arch" => c.arch = take_str("encoder", "arch", v)?.to_string(),
                "epochs" => c.epochs = take_usize("encoder", "epochs", v)?,
                "lr" => c.lr = take_f64("encoder", "lr", v)? as f32,
                _ => return Err(unknown_key("encoder", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("arch", Json::from(self.arch.as_str())),
            ("epochs", Json::from(self.epochs)),
            ("lr", Json::from(self.lr as f64)),
        ])
    }

    fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            bail!("encoder.epochs must be >= 1");
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("encoder.lr must be a positive finite number");
        }
        Ok(())
    }
}

/// One entry of the top-level `tasks` array: a task kind plus its
/// schedule weight and optional per-head learning rate.  LP-only
/// knobs (`loss` / `neg` / `max_edges_per_epoch`) are scoped exactly
/// like in the single `task` object; `epochs`/`arch` are *shared*
/// across the run and live under `encoder`, so setting them per entry
/// is a hard error (the unknown-key path reports the valid set).
#[derive(Debug, Clone, PartialEq)]
pub struct MultiTaskEntry {
    pub kind: TaskKind,
    /// Weighted-round-robin schedule weight (> 0).
    pub weight: f64,
    /// Per-head learning rate; `None` = `encoder.lr`.
    pub lr: Option<f32>,
    pub loss: LpLoss,
    pub neg: NegSampler,
    pub max_edges_per_epoch: usize,
}

impl MultiTaskEntry {
    const KEYS: &'static [&'static str] =
        &["kind", "weight", "lr", "loss", "neg", "max_edges_per_epoch"];

    fn from_json(i: usize, v: &Json) -> Result<MultiTaskEntry> {
        let ctx = format!("tasks[{i}]");
        let m = stage_obj(&ctx, v)?;
        let kind = match m.get("kind") {
            None => bail!("{ctx} must set 'kind' (\"nc\", \"lp\" or \"distill\")"),
            Some(v) => parse_task_kind(&ctx, v)?,
        };
        let only = |key: &str, wanted: TaskKind| -> Result<()> {
            if kind != wanted {
                bail!(
                    "{ctx}.{key} is only valid for kind \"{}\" (current kind \"{}\")",
                    wanted.name(),
                    kind.name()
                );
            }
            Ok(())
        };
        let mut c = MultiTaskEntry {
            kind,
            weight: 1.0,
            lr: None,
            loss: LpLoss::Contrastive,
            neg: NegSampler::Joint { k: 32 },
            max_edges_per_epoch: 3200,
        };
        for (k, v) in m {
            match k.as_str() {
                "kind" => {}
                "weight" => c.weight = take_f64(&ctx, "weight", v)?,
                "lr" => c.lr = Some(take_f64(&ctx, "lr", v)? as f32),
                "loss" => {
                    only("loss", TaskKind::Lp)?;
                    c.loss = parse_lp_loss(&ctx, v)?;
                }
                "neg" => {
                    only("neg", TaskKind::Lp)?;
                    c.neg = parse_neg(take_str(&ctx, "neg", v)?)?;
                }
                "max_edges_per_epoch" => {
                    only("max_edges_per_epoch", TaskKind::Lp)?;
                    c.max_edges_per_epoch = take_usize(&ctx, "max_edges_per_epoch", v)?;
                }
                _ => return Err(unknown_key(&ctx, k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::from(self.kind.name())),
            ("weight", Json::Num(self.weight)),
        ];
        if let Some(lr) = self.lr {
            pairs.push(("lr", Json::from(lr as f64)));
        }
        if self.kind == TaskKind::Lp {
            pairs.push((
                "loss",
                Json::from(match self.loss {
                    LpLoss::Contrastive => "contrastive",
                    LpLoss::CrossEntropy => "ce",
                }),
            ));
            pairs.push(("neg", Json::Str(neg_name(self.neg))));
            pairs.push(("max_edges_per_epoch", Json::from(self.max_edges_per_epoch)));
        }
        obj(pairs)
    }

}

/// The multi-task form of the training stage: shared-encoder settings
/// plus an array of weighted tasks, interleaved per mini-batch by the
/// deterministic weighted round-robin schedule
/// (`rust/src/trainer/multi.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct MultiTaskCfg {
    pub encoder: EncoderCfg,
    pub tasks: Vec<MultiTaskEntry>,
}

impl MultiTaskCfg {
    fn validate(&self) -> Result<()> {
        self.encoder.validate()?;
        for (i, t) in self.tasks.iter().enumerate() {
            if let Some(lr) = t.lr {
                if !(lr > 0.0 && lr.is_finite()) {
                    bail!("tasks[{i}].lr must be a positive finite number");
                }
            }
            if t.kind == TaskKind::Lp && t.max_edges_per_epoch == 0 {
                bail!("tasks[{i}].max_edges_per_epoch must be >= 1 (a zero cap trains nothing)");
            }
        }
        // The structural rules (non-empty, positive weights, one task
        // per kind, distill needs its nc teacher) live in exactly one
        // place — the trainer's validate — so the config and trainer
        // layers can't drift apart.
        MultiTaskTrainer::new(&self.encoder.arch, self.task_specs()).validate()
    }

    /// The trainer-level task specs this stage declares.
    pub fn task_specs(&self) -> Vec<TaskSpec> {
        self.tasks
            .iter()
            .map(|e| TaskSpec {
                head: match e.kind {
                    TaskKind::Nc => HeadKind::Nc,
                    TaskKind::Lp => HeadKind::Lp {
                        loss: e.loss,
                        sampler: e.neg,
                        max_edges: Some(e.max_edges_per_epoch),
                    },
                    TaskKind::Distill => HeadKind::Distill,
                },
                weight: e.weight,
                lr: e.lr,
            })
            .collect()
    }
}

// ---------------------------------------------------------------- infer

/// `infer` stage: offline full-graph inference, sharded GSTF output
/// (the precompute the serving cache warms from).
#[derive(Debug, Clone, PartialEq)]
pub struct InferCfg {
    pub out: String,
    pub shard_size: usize,
    /// Node type to infer over; `None` = the dataset's target type.
    pub ntype: Option<usize>,
    /// Engine architecture; `None` = the task's arch (or "rgcn").
    pub arch: Option<String>,
    pub out_dim: usize,
}

impl Default for InferCfg {
    fn default() -> Self {
        InferCfg {
            out: "offline_emb".to_string(),
            shard_size: 4096,
            ntype: None,
            arch: None,
            out_dim: 8,
        }
    }
}

impl InferCfg {
    const KEYS: &'static [&'static str] = &["out", "shard_size", "ntype", "arch", "out_dim"];

    fn from_json(v: &Json) -> Result<InferCfg> {
        let m = stage_obj("infer", v)?;
        let mut c = InferCfg::default();
        for (k, v) in m {
            match k.as_str() {
                "out" => c.out = take_str("infer", "out", v)?.to_string(),
                "shard_size" => c.shard_size = take_usize("infer", "shard_size", v)?,
                "ntype" => c.ntype = Some(take_usize("infer", "ntype", v)?),
                "arch" => c.arch = Some(take_str("infer", "arch", v)?.to_string()),
                "out_dim" => c.out_dim = take_usize("infer", "out_dim", v)?,
                _ => return Err(unknown_key("infer", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("out", Json::from(self.out.as_str())),
            ("shard_size", Json::from(self.shard_size)),
        ];
        if let Some(nt) = self.ntype {
            pairs.push(("ntype", Json::from(nt)));
        }
        if let Some(a) = &self.arch {
            pairs.push(("arch", Json::from(a.as_str())));
        }
        pairs.push(("out_dim", Json::from(self.out_dim)));
        obj(pairs)
    }

    fn validate(&self) -> Result<()> {
        if self.shard_size == 0 {
            bail!("infer.shard_size must be >= 1");
        }
        if self.out_dim == 0 {
            bail!("infer.out_dim must be >= 1");
        }
        Ok(())
    }
}

// ---------------------------------------------------------------- serve

/// `serve` stage: closed-loop Zipf traffic through the serving engine
/// *pool*, uncached arm then warmed-cache arm over the same trace
/// (plus a post-generation-bump refreshed arm when `refresh > 0`);
/// predictions must be bit-identical across arms.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeCfg {
    pub requests: usize,
    pub alpha: f64,
    pub clients: usize,
    pub cache: usize,
    /// Engine scratches draining the shared queue; `"auto"` resolves
    /// like `loader.workers`.  Replies are bit-identical for any value.
    pub pool_workers: Workers,
    /// Serving-cache stripes (`serve.shards`): the cache is split into
    /// this many independently locked shards, key-hash routed.
    /// Replies and hit/miss accounting are bit-identical for any
    /// value (`tests/sharding.rs`).
    pub shards: usize,
    /// Independent engine execution sessions (`serve.sessions`);
    /// `"auto"` resolves like `pool_workers`, and the resolved count
    /// clamps to the resolved pool size.  Worker `w` serializes
    /// backend execution behind session lock `w % sessions`, so
    /// forwards on distinct sessions run genuinely in parallel.
    /// Replies are bit-identical for any value.
    pub sessions: Workers,
    /// Cache admission policy: plain LRU or a TinyLFU frequency gate
    /// that keeps Zipf-tail scan traffic from evicting the hot set.
    pub admission: Admission,
    /// Hot rows to re-read after the bench's mid-run generation bump;
    /// 0 skips the refreshed arm.
    pub refresh: usize,
    pub max_batch: usize,
    pub deadline_us: u64,
    /// Engine architecture; `None` = the task's arch (or "rgcn").
    pub arch: Option<String>,
    pub out_dim: usize,
    /// Deterministic fault plan for the bench's uncached arm, as a
    /// `FaultSpec` string (`"panics=2,transient=3,slow=1,slow_ms=5"`);
    /// empty = no injection.
    pub faults: String,
    /// Per-request deadline in milliseconds; 0 = no deadline.
    pub deadline_ms: u64,
    /// Bounded retries (with exponential backoff) for retryable batch
    /// failures.
    pub max_retries: usize,
    /// Queue-boundary shedding: reject new misses once this many
    /// requests are pending; 0 = never shed.
    pub queue_depth: usize,
    /// Worker restarts (panic or fatal error) before the pool enters
    /// degraded single-scratch mode.
    pub max_worker_restarts: usize,
    /// The HTTP/1.1 network front end (`gs serve`): when present, the
    /// engine pool is fronted by `serve::http::HttpServer` instead of
    /// the closed-loop bench.  Version-4-only key.
    pub http: Option<HttpCfg>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            requests: 4000,
            alpha: 1.1,
            clients: 4,
            cache: 4096,
            pool_workers: Workers::Auto,
            shards: 1,
            sessions: Workers::Fixed(1),
            admission: Admission::Always,
            refresh: 0,
            max_batch: 32,
            deadline_us: 200,
            arch: None,
            out_dim: 8,
            faults: String::new(),
            deadline_ms: 0,
            max_retries: 2,
            queue_depth: 0,
            max_worker_restarts: 8,
            http: None,
        }
    }
}

/// `serve.http`: the hand-rolled HTTP/1.1 front end over the engine
/// pool (`rust/src/serve/http/`, docs/SERVING.md).  Requests enter
/// over real sockets instead of in-process function calls; the
/// [`crate::serve::ServeError`] taxonomy maps onto status codes at the
/// boundary (429 shed, 503 deadline/drain).  Present-iff-used: the
/// whole object is version-4-only.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpCfg {
    /// Bind address (`--listen`), e.g. `"127.0.0.1:8080"`; port 0
    /// binds an ephemeral port (printed at startup).
    pub listen: String,
    /// Connection-handling threads (the acceptor is separate).
    pub workers: usize,
    /// Request-body byte cap; a larger declared `Content-Length` is
    /// answered with 413 before the body is read.
    pub max_body: usize,
    /// Per-connection socket read timeout (ms).  Also bounds graceful
    /// shutdown: idle keep-alive connections notice the drain flag
    /// within one timeout tick.
    pub read_timeout_ms: u64,
    /// Per-connection socket write timeout (ms).
    pub write_timeout_ms: u64,
}

impl Default for HttpCfg {
    fn default() -> Self {
        HttpCfg {
            listen: "127.0.0.1:8080".to_string(),
            workers: 4,
            max_body: 65536,
            read_timeout_ms: 5000,
            write_timeout_ms: 5000,
        }
    }
}

impl HttpCfg {
    const KEYS: &'static [&'static str] =
        &["listen", "workers", "max_body", "read_timeout_ms", "write_timeout_ms"];

    fn from_json(v: &Json) -> Result<HttpCfg> {
        let m = stage_obj("serve.http", v)?;
        let mut c = HttpCfg::default();
        for (k, v) in m {
            match k.as_str() {
                "listen" => c.listen = take_str("serve.http", "listen", v)?.to_string(),
                "workers" => c.workers = take_usize("serve.http", "workers", v)?,
                "max_body" => c.max_body = take_usize("serve.http", "max_body", v)?,
                "read_timeout_ms" => {
                    c.read_timeout_ms = take_u64("serve.http", "read_timeout_ms", v)?
                }
                "write_timeout_ms" => {
                    c.write_timeout_ms = take_u64("serve.http", "write_timeout_ms", v)?
                }
                _ => return Err(unknown_key("serve.http", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("listen", Json::from(self.listen.as_str())),
            ("workers", Json::from(self.workers)),
            ("max_body", Json::from(self.max_body)),
            ("read_timeout_ms", Json::from(self.read_timeout_ms as usize)),
            ("write_timeout_ms", Json::from(self.write_timeout_ms as usize)),
        ])
    }

    fn validate(&self) -> Result<()> {
        if self.listen.is_empty() {
            bail!("serve.http.listen must be a non-empty bind address (host:port)");
        }
        if self.workers == 0 {
            bail!("serve.http.workers must be >= 1");
        }
        if self.max_body == 0 {
            bail!("serve.http.max_body must be >= 1");
        }
        if self.read_timeout_ms == 0 || self.write_timeout_ms == 0 {
            bail!(
                "serve.http.read_timeout_ms and serve.http.write_timeout_ms must be >= 1 \
                 (a zero socket timeout would block forever)"
            );
        }
        Ok(())
    }

    /// These knobs as the server's runtime config.
    pub fn server_cfg(&self) -> crate::serve::http::HttpServerCfg {
        crate::serve::http::HttpServerCfg {
            listen: self.listen.clone(),
            workers: self.workers,
            max_body: self.max_body,
            read_timeout: std::time::Duration::from_millis(self.read_timeout_ms),
            write_timeout: std::time::Duration::from_millis(self.write_timeout_ms),
        }
    }
}

impl ServeCfg {
    const KEYS: &'static [&'static str] = &[
        "requests",
        "alpha",
        "clients",
        "cache",
        "pool_workers",
        "shards",
        "sessions",
        "admission",
        "refresh",
        "max_batch",
        "deadline_us",
        "arch",
        "out_dim",
        "faults",
        "deadline_ms",
        "max_retries",
        "queue_depth",
        "max_worker_restarts",
        "http",
    ];

    fn from_json(v: &Json) -> Result<ServeCfg> {
        let m = stage_obj("serve", v)?;
        let mut c = ServeCfg::default();
        for (k, v) in m {
            match k.as_str() {
                "requests" => c.requests = take_usize("serve", "requests", v)?,
                "alpha" => c.alpha = take_f64("serve", "alpha", v)?,
                "clients" => c.clients = take_usize("serve", "clients", v)?,
                "cache" => c.cache = take_usize("serve", "cache", v)?,
                "pool_workers" => {
                    c.pool_workers = match v {
                        Json::Str(s) if s == "auto" => Workers::Auto,
                        Json::Str(s) => bail!(
                            "serve.pool_workers must be a thread count or \"auto\", got \"{s}\""
                        ),
                        v => Workers::Fixed(take_usize("serve", "pool_workers", v)?),
                    }
                }
                "shards" => c.shards = take_usize("serve", "shards", v)?,
                "sessions" => {
                    c.sessions = match v {
                        Json::Str(s) if s == "auto" => Workers::Auto,
                        Json::Str(s) => bail!(
                            "serve.sessions must be a session count or \"auto\", got \"{s}\""
                        ),
                        v => Workers::Fixed(take_usize("serve", "sessions", v)?),
                    }
                }
                "admission" => {
                    c.admission = match take_str("serve", "admission", v)? {
                        "always" => Admission::Always,
                        "tinylfu" => Admission::TinyLfu,
                        other => bail!(
                            "serve.admission must be \"always\" or \"tinylfu\", got \"{other}\"{}",
                            did_you_mean(other, &["always", "tinylfu"])
                        ),
                    }
                }
                "refresh" => c.refresh = take_usize("serve", "refresh", v)?,
                "max_batch" => c.max_batch = take_usize("serve", "max_batch", v)?,
                "deadline_us" => c.deadline_us = take_u64("serve", "deadline_us", v)?,
                "arch" => c.arch = Some(take_str("serve", "arch", v)?.to_string()),
                "out_dim" => c.out_dim = take_usize("serve", "out_dim", v)?,
                "faults" => c.faults = take_str("serve", "faults", v)?.to_string(),
                "deadline_ms" => c.deadline_ms = take_u64("serve", "deadline_ms", v)?,
                "max_retries" => c.max_retries = take_usize("serve", "max_retries", v)?,
                "queue_depth" => c.queue_depth = take_usize("serve", "queue_depth", v)?,
                "max_worker_restarts" => {
                    c.max_worker_restarts = take_usize("serve", "max_worker_restarts", v)?
                }
                "http" => c.http = Some(HttpCfg::from_json(v)?),
                _ => return Err(unknown_key("serve", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    fn to_json(&self) -> Json {
        let pool_workers = match self.pool_workers {
            Workers::Auto => Json::from("auto"),
            Workers::Fixed(n) => Json::from(n),
        };
        let sessions = match self.sessions {
            Workers::Auto => Json::from("auto"),
            Workers::Fixed(n) => Json::from(n),
        };
        let mut pairs = vec![
            ("requests", Json::from(self.requests)),
            ("alpha", Json::Num(self.alpha)),
            ("clients", Json::from(self.clients)),
            ("cache", Json::from(self.cache)),
            ("pool_workers", pool_workers),
            ("shards", Json::from(self.shards)),
            ("sessions", sessions),
            ("admission", Json::from(self.admission.name())),
            ("refresh", Json::from(self.refresh)),
            ("max_batch", Json::from(self.max_batch)),
            ("deadline_us", Json::from(self.deadline_us as usize)),
        ];
        if let Some(a) = &self.arch {
            pairs.push(("arch", Json::from(a.as_str())));
        }
        pairs.push(("out_dim", Json::from(self.out_dim)));
        // Like `arch`: only emitted when set, so round-trips of
        // fault-free configs stay byte-stable.
        if !self.faults.is_empty() {
            pairs.push(("faults", Json::from(self.faults.as_str())));
        }
        pairs.push(("deadline_ms", Json::from(self.deadline_ms as usize)));
        pairs.push(("max_retries", Json::from(self.max_retries)));
        pairs.push(("queue_depth", Json::from(self.queue_depth)));
        pairs.push(("max_worker_restarts", Json::from(self.max_worker_restarts)));
        // Present-iff-used, like `faults`: bench-only configs (and the
        // golden pipeline fixtures) round-trip byte-stable.
        if let Some(h) = &self.http {
            pairs.push(("http", h.to_json()));
        }
        obj(pairs)
    }

    /// The micro-batcher knobs this stage declares.
    pub fn batcher(&self) -> MicroBatcherCfg {
        MicroBatcherCfg {
            max_batch: self.max_batch,
            deadline: std::time::Duration::from_micros(self.deadline_us),
        }
    }

    /// The concrete pool size (resolves `"auto"`, with a log line).
    pub fn resolve_pool_workers(&self) -> usize {
        match self.pool_workers {
            Workers::Fixed(n) => n,
            Workers::Auto => autoscale_workers(),
        }
    }

    /// The concrete session count: resolves `"auto"` like
    /// `pool_workers`, then clamps to the resolved pool size — a
    /// session no worker maps onto would just be an idle lock.
    pub fn resolve_sessions(&self) -> usize {
        let w = self.resolve_pool_workers().max(1);
        let s = match self.sessions {
            Workers::Fixed(n) => n,
            Workers::Auto => autoscale_workers(),
        };
        s.clamp(1, w)
    }

    /// These knobs as an engine-pool config.
    pub fn pool(&self) -> EnginePoolCfg {
        EnginePoolCfg {
            workers: self.resolve_pool_workers(),
            sessions: self.resolve_sessions(),
            batcher: self.batcher(),
            request_deadline: std::time::Duration::from_millis(self.deadline_ms),
            max_retries: self.max_retries,
            queue_depth: self.queue_depth,
            max_worker_restarts: self.max_worker_restarts,
            ..EnginePoolCfg::default()
        }
    }

    /// The parsed fault plan spec, or `None` when `faults` is empty.
    pub fn fault_spec(&self) -> Result<Option<FaultSpec>> {
        if self.faults.is_empty() {
            return Ok(None);
        }
        Ok(Some(FaultSpec::parse(&self.faults)?))
    }

    fn validate(&self) -> Result<()> {
        if self.requests == 0 || self.clients == 0 || self.max_batch == 0 {
            bail!("serve.requests, serve.clients and serve.max_batch must be >= 1");
        }
        if let Workers::Fixed(0) = self.pool_workers {
            bail!("serve.pool_workers must be >= 1 (use 1 for a single engine scratch)");
        }
        if self.shards == 0 {
            bail!("serve.shards must be >= 1 (use 1 for a single cache stripe)");
        }
        if let Workers::Fixed(0) = self.sessions {
            bail!("serve.sessions must be >= 1 (use 1 for a single execution session)");
        }
        if let (Workers::Fixed(se), Workers::Fixed(pw)) = (&self.sessions, &self.pool_workers) {
            if se > pw {
                bail!(
                    "serve.sessions ({se}) exceeds serve.pool_workers ({pw}): each session \
                     needs a worker to drive it; lower serve.sessions or set it to \"auto\""
                );
            }
        }
        if !(self.alpha > 0.0 && self.alpha.is_finite()) {
            bail!("serve.alpha must be a positive finite number");
        }
        if self.out_dim == 0 {
            bail!("serve.out_dim must be >= 1");
        }
        // Fail fast on a malformed fault spec — at validation, not
        // mid-bench.
        self.fault_spec().map_err(|e| anyhow!("serve.faults: {e}"))?;
        if let Some(h) = &self.http {
            h.validate()?;
        }
        Ok(())
    }
}

// ------------------------------------------------------------------ obs

/// Observability knobs (`obs` top-level object; CLI `--trace`,
/// `--stats`, `--report`).  Not a pipeline stage — these never change
/// what a run computes, only what it records about itself
/// (docs/OBSERVABILITY.md).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ObsCfg {
    /// JSONL trace output path (`--trace PATH`); tracing stays
    /// disabled when unset.
    pub trace: Option<String>,
    /// chrome://tracing JSON-array export path.
    pub chrome_trace: Option<String>,
    /// Print the metrics-registry table at end of run (`--stats`).
    pub stats: bool,
    /// Write the `PipelineOutcome` report JSON here (`--report PATH`).
    pub report: Option<String>,
}

impl ObsCfg {
    const KEYS: &'static [&'static str] = &["trace", "chrome_trace", "stats", "report"];

    fn from_json(v: &Json) -> Result<ObsCfg> {
        let m = stage_obj("obs", v)?;
        let mut c = ObsCfg::default();
        for (k, v) in m {
            match k.as_str() {
                "trace" => c.trace = Some(take_str("obs", "trace", v)?.to_string()),
                "chrome_trace" => {
                    c.chrome_trace = Some(take_str("obs", "chrome_trace", v)?.to_string())
                }
                "stats" => c.stats = take_bool("obs", "stats", v)?,
                "report" => c.report = Some(take_str("obs", "report", v)?.to_string()),
                _ => return Err(unknown_key("obs", k, Self::KEYS)),
            }
        }
        Ok(c)
    }

    /// Only set keys are emitted, and `RunConfig::to_json` skips the
    /// whole object at defaults — so pre-obs configs and the golden
    /// pipeline fixtures round-trip byte-identically.
    fn to_json(&self) -> Json {
        let mut pairs = Vec::new();
        if let Some(t) = &self.trace {
            pairs.push(("trace", Json::from(t.as_str())));
        }
        if let Some(t) = &self.chrome_trace {
            pairs.push(("chrome_trace", Json::from(t.as_str())));
        }
        if self.stats {
            pairs.push(("stats", Json::Bool(true)));
        }
        if let Some(r) = &self.report {
            pairs.push(("report", Json::from(r.as_str())));
        }
        obj(pairs)
    }

    fn validate(&self) -> Result<()> {
        for (k, v) in
            [("trace", &self.trace), ("chrome_trace", &self.chrome_trace), ("report", &self.report)]
        {
            if let Some(p) = v {
                if p.is_empty() {
                    bail!("obs.{k} must be a non-empty path");
                }
            }
        }
        Ok(())
    }
}

/// The config schema version this build reads and writes.  Version 1
/// is the pre-fault-tolerance, pre-obs key set; version 2 added the
/// `serve` supervision keys (`deadline_ms`, `max_retries`,
/// `queue_depth`, `max_worker_restarts`, `faults`) and the `obs`
/// object; version 3 added the serving striping keys (`serve.shards`,
/// `serve.sessions`); version 4 added the HTTP front-end object
/// (`serve.http`).  Configs may omit `conf_version` (any-version
/// keys only), but a declared version is validated strictly: older
/// versions using newer keys get a migration error naming the
/// offending keys, and versions newer than this build are rejected
/// outright.
pub const CONF_VERSION: u64 = 4;

// ------------------------------------------------------------ RunConfig

/// A whole declared run: which stages execute and with what knobs.
/// This is the single source of truth for every stage default.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Declared schema version (see [`CONF_VERSION`]); `None` means
    /// "whatever this build reads" and is pinned by [`resolved`].
    pub conf_version: Option<u64>,
    pub seed: u64,
    pub loader: LoaderCfg,
    pub data: DataCfg,
    pub partition: PartitionCfg,
    pub lm: Option<LmCfg>,
    pub task: Option<TaskCfg>,
    /// The multi-task form of the training stage (top-level `tasks`
    /// array + `encoder` object); mutually exclusive with `task`.
    pub multi: Option<MultiTaskCfg>,
    pub infer: Option<InferCfg>,
    pub serve: Option<ServeCfg>,
    pub obs: ObsCfg,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            conf_version: None,
            seed: 7,
            loader: LoaderCfg::default(),
            data: DataCfg::default(),
            partition: PartitionCfg::default(),
            lm: None,
            task: None,
            multi: None,
            infer: None,
            serve: None,
            obs: ObsCfg::default(),
        }
    }
}

const TOP_KEYS: &[&str] = &[
    "conf_version",
    "seed",
    "loader",
    "data",
    "partition",
    "lm",
    "task",
    "tasks",
    "encoder",
    "infer",
    "serve",
    "obs",
];

impl RunConfig {
    pub fn from_json(doc: &Json) -> Result<RunConfig> {
        let m = stage_obj("run config", doc)?;
        let mut c = RunConfig::default();
        // `tasks` + `encoder` combine into one stage; collect both
        // before building it so key order can't matter.
        let mut enc_doc: Option<&Json> = None;
        let mut tasks_doc: Option<&Json> = None;
        for (k, v) in m {
            match k.as_str() {
                "conf_version" => {
                    c.conf_version = Some(take_u64("run config", "conf_version", v)?)
                }
                "seed" => c.seed = take_u64("run config", "seed", v)?,
                "loader" => c.loader = LoaderCfg::from_json(v)?,
                "data" => c.data = DataCfg::from_json(v)?,
                "partition" => c.partition = PartitionCfg::from_json(v)?,
                "lm" => c.lm = Some(LmCfg::from_json(v)?),
                "task" => c.task = Some(TaskCfg::from_json(v)?),
                "tasks" => tasks_doc = Some(v),
                "encoder" => enc_doc = Some(v),
                "infer" => c.infer = Some(InferCfg::from_json(v)?),
                "serve" => c.serve = Some(ServeCfg::from_json(v)?),
                "obs" => c.obs = ObsCfg::from_json(v)?,
                _ => return Err(unknown_key("run config", k, TOP_KEYS)),
            }
        }
        match (tasks_doc, enc_doc) {
            (Some(tv), enc) => {
                let arr = tv.as_arr().ok_or_else(|| {
                    anyhow!("tasks must be a JSON array of task objects, got {}", type_name(tv))
                })?;
                let tasks = arr
                    .iter()
                    .enumerate()
                    .map(|(i, v)| MultiTaskEntry::from_json(i, v))
                    .collect::<Result<Vec<_>>>()?;
                let encoder = match enc {
                    Some(e) => EncoderCfg::from_json(e)?,
                    None => EncoderCfg::default(),
                };
                c.multi = Some(MultiTaskCfg { encoder, tasks });
            }
            (None, Some(_)) => {
                bail!("encoder is only valid together with a tasks array (single-task runs set task.arch etc.)")
            }
            (None, None) => {}
        }
        c.validate()?;
        Ok(c)
    }

    pub fn parse_str(text: &str) -> Result<RunConfig> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn load(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read run config {}", path.display()))?;
        Self::parse_str(&text).with_context(|| format!("in run config {}", path.display()))
    }

    /// The version-2-only knobs this config actually uses: the `serve`
    /// supervision keys at non-default values, plus any `obs` key.
    /// Presence in the source document is gone by the time we have a
    /// typed config, so "uses" means "differs from the default" — the
    /// only case where declaring v1 would change behavior.
    fn v2_keys_in_use(&self) -> Vec<&'static str> {
        let mut used = Vec::new();
        if let Some(s) = &self.serve {
            let d = ServeCfg::default();
            for (key, differs) in [
                ("serve.faults", s.faults != d.faults),
                ("serve.deadline_ms", s.deadline_ms != d.deadline_ms),
                ("serve.max_retries", s.max_retries != d.max_retries),
                ("serve.queue_depth", s.queue_depth != d.queue_depth),
                ("serve.max_worker_restarts", s.max_worker_restarts != d.max_worker_restarts),
            ] {
                if differs {
                    used.push(key);
                }
            }
        }
        if self.obs != ObsCfg::default() {
            used.push("obs");
        }
        used
    }

    /// The version-3-only knobs this config actually uses: the serving
    /// striping keys at non-default values (same "uses" notion as
    /// [`v2_keys_in_use`](Self::v2_keys_in_use)).
    fn v3_keys_in_use(&self) -> Vec<&'static str> {
        let mut used = Vec::new();
        if let Some(s) = &self.serve {
            if s.shards != 1 {
                used.push("serve.shards");
            }
            if s.sessions != Workers::Fixed(1) {
                used.push("serve.sessions");
            }
        }
        used
    }

    fn check_v3_keys(&self, declared: u64) -> Result<()> {
        let used = self.v3_keys_in_use();
        if !used.is_empty() {
            bail!(
                "conf_version {declared} config uses version-3 keys: {}; migrate by setting \
                 \"conf_version\": 3 (the keys' semantics are unchanged — the version \
                 marker is the only edit)",
                used.join(", ")
            );
        }
        Ok(())
    }

    /// The version-4-only knobs this config actually uses: the HTTP
    /// front-end object.  `serve.http` has no pre-v4 default to
    /// compare against — presence *is* use.
    fn v4_keys_in_use(&self) -> Vec<&'static str> {
        match &self.serve {
            Some(s) if s.http.is_some() => vec!["serve.http"],
            _ => Vec::new(),
        }
    }

    fn check_v4_keys(&self, declared: u64) -> Result<()> {
        let used = self.v4_keys_in_use();
        if !used.is_empty() {
            bail!(
                "conf_version {declared} config uses version-4 keys: {}; migrate by setting \
                 \"conf_version\": 4 (the keys' semantics are unchanged — the version \
                 marker is the only edit)",
                used.join(", ")
            );
        }
        Ok(())
    }

    /// Cross-stage consistency checks (per-stage checks run too).
    pub fn validate(&self) -> Result<()> {
        match self.conf_version {
            None => {}
            Some(0) => bail!("conf_version must be >= 1 (this build writes {CONF_VERSION})"),
            Some(v) if v > CONF_VERSION => bail!(
                "conf_version {v} is newer than this build (supports {CONF_VERSION}); \
                 upgrade gs or lower conf_version"
            ),
            Some(1) => {
                let used = self.v2_keys_in_use();
                if !used.is_empty() {
                    bail!(
                        "conf_version 1 config uses version-2 keys: {}; migrate by setting \
                         \"conf_version\": 2 (the keys' semantics are unchanged — the version \
                         marker is the only edit)",
                        used.join(", ")
                    );
                }
                self.check_v3_keys(1)?;
                self.check_v4_keys(1)?;
            }
            Some(2) => {
                self.check_v3_keys(2)?;
                self.check_v4_keys(2)?;
            }
            Some(3) => self.check_v4_keys(3)?,
            Some(_) => {}
        }
        self.obs.validate()?;
        self.loader.validate()?;
        self.data.validate()?;
        self.partition.validate()?;
        if self.task.is_some() && self.multi.is_some() {
            bail!(
                "task and tasks are mutually exclusive: use the single task object or the \
                 multi-task tasks array, not both"
            );
        }
        if self.lm.is_some() && self.multi.is_some() {
            bail!(
                "lm stage is not supported with a tasks array yet (run lm with the single \
                 nc task form)"
            );
        }
        if let Some(m) = &self.multi {
            m.validate()?;
        }
        if let Some(lm) = &self.lm {
            lm.validate()?;
            match &self.task {
                Some(t) if t.kind == TaskKind::Nc => {}
                Some(t) => bail!(
                    "lm stage is incompatible with a \"{}\" task: LM fine-tuning and the \
                     embed pass are wired to node classification (use kind \"nc\" or drop \"lm\")",
                    t.kind.name()
                ),
                None => bail!("lm stage requires a task stage with kind \"nc\""),
            }
        }
        if let Some(t) = &self.task {
            t.validate()?;
        }
        if let Some(i) = &self.infer {
            i.validate()?;
        }
        if let Some(s) = &self.serve {
            s.validate()?;
        }
        Ok(())
    }

    /// The fully-resolved config: every default materialized, `"auto"`
    /// worker counts resolved, engine archs inherited from the task.
    pub fn resolved(&self) -> RunConfig {
        let mut c = self.clone();
        c.conf_version = Some(CONF_VERSION);
        c.loader.workers = Workers::Fixed(c.loader.resolve_workers());
        let task_arch = c
            .task
            .as_ref()
            .map(|t| t.arch.clone())
            .or_else(|| c.multi.as_ref().map(|m| m.encoder.arch.clone()))
            .unwrap_or_else(|| "rgcn".to_string());
        if let Some(i) = &mut c.infer {
            i.arch.get_or_insert_with(|| task_arch.clone());
        }
        if let Some(s) = &mut c.serve {
            s.arch.get_or_insert_with(|| task_arch.clone());
            // Sessions first: their clamp reads the *unresolved* pool
            // size through resolve_pool_workers, same as a direct run.
            s.sessions = Workers::Fixed(s.resolve_sessions());
            s.pool_workers = Workers::Fixed(s.resolve_pool_workers());
        }
        c
    }

    /// Serialize with every present stage fully spelled out, so
    /// `gs validate-conf` shows exactly what a run would use.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("seed", Json::from(self.seed as usize)),
            ("loader", self.loader.to_json()),
            ("data", self.data.to_json()),
            ("partition", self.partition.to_json()),
        ];
        if let Some(v) = self.conf_version {
            pairs.push(("conf_version", Json::from(v as usize)));
        }
        if let Some(lm) = &self.lm {
            pairs.push(("lm", lm.to_json()));
        }
        if let Some(t) = &self.task {
            pairs.push(("task", t.to_json()));
        }
        if let Some(m) = &self.multi {
            pairs.push(("encoder", m.encoder.to_json()));
            pairs.push(("tasks", Json::Arr(m.tasks.iter().map(|t| t.to_json()).collect())));
        }
        if let Some(i) = &self.infer {
            pairs.push(("infer", i.to_json()));
        }
        if let Some(s) = &self.serve {
            pairs.push(("serve", s.to_json()));
        }
        // Omitted entirely at defaults: pre-obs configs and the golden
        // pipeline fixtures round-trip byte-identically.
        if self.obs != ObsCfg::default() {
            pairs.push(("obs", self.obs.to_json()));
        }
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// The stage sequence this config declares, for display.
    pub fn stage_names(&self) -> Vec<String> {
        let mut s = vec!["data".to_string(), "partition".to_string()];
        if self.lm.is_some() {
            s.push("lm".to_string());
        }
        if let Some(t) = &self.task {
            s.push(format!("task({})", t.kind.name()));
        }
        if let Some(m) = &self.multi {
            let kinds: Vec<&str> = m.tasks.iter().map(|t| t.kind.name()).collect();
            s.push(format!("tasks({})", kinds.join("+")));
        }
        if self.infer.is_some() {
            s.push("infer".to_string());
        }
        if self.serve.is_some() {
            s.push("serve".to_string());
        }
        s
    }

    /// The `TrainOptions` this run's stages share — the ONE place CLI
    /// runs construct them.
    pub fn train_options(&self) -> TrainOptions {
        let t = self.task.clone().unwrap_or_default();
        // The multi-task stage shares epochs/lr across heads via the
        // encoder settings.
        let (epochs, lr) = match &self.multi {
            Some(m) => (m.encoder.epochs, m.encoder.lr),
            None => (t.epochs, t.lr),
        };
        TrainOptions {
            lr,
            epochs,
            seed: self.seed,
            n_workers: self.partition.parts.max(1),
            loader_workers: self.loader.resolve_workers(),
            prefetch: self.loader.prefetch,
            log_every: 0,
            verbose: true,
        }
    }
}

// ------------------------------------------------------------ overrides

/// Assign `value` (parsed as JSON if it parses, else a bare string) at
/// dot-separated `path` inside `doc`, creating intermediate objects.
/// Numeric segments index into existing arrays — `tasks.0.weight=2`
/// targets the first entry of the `tasks` array (out-of-range indices
/// are hard errors; arrays are never implicitly created or grown).
/// This backs `--set stage.key=value` and the per-flag CLI overrides.
pub fn set_path(doc: &mut Json, path: &str, raw: &str) -> Result<()> {
    let raw = raw.trim();
    let val = Json::parse(raw).unwrap_or_else(|_| Json::Str(raw.to_string()));
    let parts: Vec<&str> = path.trim().split('.').collect();
    if parts.iter().any(|p| p.is_empty()) {
        bail!("bad --set path '{path}': empty segment");
    }
    let mut cur = doc;
    for (i, p) in parts.iter().enumerate() {
        let last = i + 1 == parts.len();
        if let Json::Arr(a) = cur {
            let idx: usize = p.parse().map_err(|_| {
                anyhow!(
                    "--set {path}: '{}' is an array; '{p}' must be a numeric index",
                    parts[..i].join(".")
                )
            })?;
            if idx >= a.len() {
                bail!(
                    "--set {path}: index {idx} out of range ('{}' has {} entries)",
                    parts[..i].join("."),
                    a.len()
                );
            }
            if last {
                a[idx] = val;
                return Ok(());
            }
            cur = &mut a[idx];
            continue;
        }
        let Json::Obj(m) = cur else {
            bail!(
                "--set {path}: '{}' is not an object in the config document",
                parts[..i].join(".")
            );
        };
        if last {
            m.insert(p.to_string(), val);
            return Ok(());
        }
        cur = m.entry(p.to_string()).or_insert_with(|| Json::Obj(BTreeMap::new()));
    }
    unreachable!("split('.') yields at least one segment")
}

/// Apply one `--set stage.key=value` assignment to a config document.
pub fn apply_set(doc: &mut Json, assignment: &str) -> Result<()> {
    let (path, raw) = assignment
        .split_once('=')
        .with_context(|| format!("--set expects stage.key=value, got '{assignment}'"))?;
    set_path(doc, path, raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_from_empty_doc() {
        let c = RunConfig::parse_str("{}").unwrap();
        assert_eq!(c, RunConfig::default());
        assert_eq!(c.seed, 7);
        assert!(c.task.is_none() && c.lm.is_none() && c.infer.is_none() && c.serve.is_none());
        assert_eq!(c.stage_names(), vec!["data", "partition"]);
    }

    #[test]
    fn unknown_key_suggests_nearest() {
        let e = RunConfig::parse_str(r#"{"task": {"epcohs": 10}}"#).unwrap_err().to_string();
        assert!(e.contains("epcohs") && e.contains("did you mean 'epochs'"), "{e}");
        let e = RunConfig::parse_str(r#"{"sede": 3}"#).unwrap_err().to_string();
        assert!(e.contains("did you mean 'seed'"), "{e}");
    }

    #[test]
    fn type_errors_are_hard() {
        assert!(RunConfig::parse_str(r#"{"seed": "7"}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"task": {"epochs": 2.5}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"task": {"epochs": -1}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"loader": 3}"#).is_err());
    }

    #[test]
    fn kind_scoped_keys_rejected() {
        let e = RunConfig::parse_str(r#"{"task": {"kind": "nc", "loss": "ce"}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("only valid for kind \"lp\""), "{e}");
        assert!(RunConfig::parse_str(r#"{"task": {"kind": "lp", "teacher_epochs": 2}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"data": {"source": "gen", "conf": "x.json"}}"#).is_err());
    }

    #[test]
    fn lm_requires_nc_task() {
        let e = RunConfig::parse_str(
            r#"{"lm": {"mode": "finetuned"}, "task": {"kind": "lp"}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("lm stage is incompatible"), "{e}");
        assert!(RunConfig::parse_str(r#"{"lm": {"mode": "pretrained"}}"#).is_err());
        assert!(RunConfig::parse_str(
            r#"{"lm": {"mode": "finetuned"}, "task": {"kind": "nc"}}"#
        )
        .is_ok());
    }

    #[test]
    fn roundtrip_resolved() {
        let c = RunConfig::parse_str(
            r#"{"seed": 11,
                "loader": {"workers": 3, "prefetch": 4},
                "data": {"dataset": "amazon", "size": 500},
                "partition": {"parts": 2, "method": "metis"},
                "task": {"kind": "lp", "loss": "ce", "neg": "uniform-8", "epochs": 2},
                "serve": {"requests": 100, "deadline_us": 300}}"#,
        )
        .unwrap()
        .resolved();
        let back = RunConfig::parse_str(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(c, back);
        // And a second round through the resolver is a fixed point.
        assert_eq!(back.resolved(), back);
    }

    #[test]
    fn set_overrides_apply_in_order() {
        let mut doc = Json::parse(r#"{"task": {"kind": "nc", "epochs": 3}}"#).unwrap();
        apply_set(&mut doc, "task.epochs=4").unwrap();
        apply_set(&mut doc, "task.epochs=6").unwrap();
        apply_set(&mut doc, "seed=11").unwrap();
        apply_set(&mut doc, "lm.mode=finetuned").unwrap(); // creates the stage
        let c = RunConfig::from_json(&doc).unwrap();
        assert_eq!(c.task.as_ref().unwrap().epochs, 6);
        assert_eq!(c.seed, 11);
        assert_eq!(c.lm.as_ref().unwrap().mode, LmMode::Finetuned);
        assert!(apply_set(&mut doc, "no-equals-sign").is_err());
        // A typo'd --set path still dies in typed validation.
        apply_set(&mut doc, "task.epcohs=9").unwrap();
        assert!(RunConfig::from_json(&doc).is_err());
    }

    #[test]
    fn workers_auto_resolves_in_range() {
        let c = RunConfig::parse_str(r#"{"loader": {"workers": "auto"}}"#).unwrap();
        assert_eq!(c.loader.workers, Workers::Auto);
        let n = c.loader.resolve_workers();
        assert!((1..=crate::dataloader::MAX_AUTO_WORKERS).contains(&n), "auto -> {n}");
        let r = c.resolved();
        assert_eq!(r.loader.workers, Workers::Fixed(n));
        assert!(RunConfig::parse_str(r#"{"loader": {"workers": "many"}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"loader": {"workers": 0}}"#).is_err());
    }

    #[test]
    fn serve_pool_keys_parse_validate_and_resolve() {
        let c = RunConfig::parse_str(
            r#"{"serve": {"pool_workers": "auto", "admission": "tinylfu", "refresh": 256}}"#,
        )
        .unwrap();
        let s = c.serve.as_ref().unwrap();
        assert_eq!(s.pool_workers, Workers::Auto);
        assert_eq!(s.admission, Admission::TinyLfu);
        assert_eq!(s.refresh, 256);
        let r = c.resolved();
        let rs = r.serve.as_ref().unwrap();
        assert!(matches!(rs.pool_workers, Workers::Fixed(n) if n >= 1));
        assert!(rs.pool().workers >= 1);
        // Resolution round-trips through JSON and is a fixed point.
        let back = RunConfig::parse_str(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(r, back);
        assert_eq!(back.resolved(), back);

        assert!(RunConfig::parse_str(r#"{"serve": {"pool_workers": 0}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"serve": {"pool_workers": "many"}}"#).is_err());
        let e = RunConfig::parse_str(r#"{"serve": {"admission": "tinlyfu"}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("did you mean 'tinylfu'"), "{e}");
        let e = RunConfig::parse_str(r#"{"serve": {"pool_wokers": 2}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("did you mean 'pool_workers'"), "{e}");
    }

    #[test]
    fn serve_sharding_keys_parse_validate_and_resolve() {
        let c = RunConfig::parse_str(
            r#"{"serve": {"pool_workers": 4, "shards": 4, "sessions": 2}}"#,
        )
        .unwrap();
        let s = c.serve.as_ref().unwrap();
        assert_eq!(s.shards, 4);
        assert_eq!(s.sessions, Workers::Fixed(2));
        assert_eq!(s.pool().sessions, 2);
        assert_eq!(s.pool().workers, 4);
        // "auto" sessions clamp to the resolved pool size.
        let c =
            RunConfig::parse_str(r#"{"serve": {"pool_workers": 1, "sessions": "auto"}}"#).unwrap();
        assert_eq!(c.serve.as_ref().unwrap().resolve_sessions(), 1);
        let r = c.resolved();
        assert_eq!(r.serve.as_ref().unwrap().sessions, Workers::Fixed(1));
        // Resolution round-trips through JSON and is a fixed point.
        let back = RunConfig::parse_str(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(r, back);
        assert_eq!(back.resolved(), back);
        // Bad values are rejected with the key named.
        let e = RunConfig::parse_str(r#"{"serve": {"shards": 0}}"#).unwrap_err().to_string();
        assert!(e.contains("serve.shards must be >= 1"), "{e}");
        let e = RunConfig::parse_str(r#"{"serve": {"sessions": 0}}"#).unwrap_err().to_string();
        assert!(e.contains("serve.sessions must be >= 1"), "{e}");
        let e = RunConfig::parse_str(r#"{"serve": {"sessions": "many"}}"#).unwrap_err().to_string();
        assert!(e.contains("\"auto\""), "{e}");
        // Fixed sessions may not exceed a fixed pool size.
        let e = RunConfig::parse_str(r#"{"serve": {"pool_workers": 2, "sessions": 4}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("serve.sessions (4) exceeds serve.pool_workers (2)"), "{e}");
        // Either side "auto" is fine — the clamp happens at resolve
        // time instead of rejecting.
        assert!(
            RunConfig::parse_str(r#"{"serve": {"pool_workers": "auto", "sessions": 8}}"#).is_ok()
        );
        let c = RunConfig::parse_str(r#"{"serve": {"pool_workers": 2, "sessions": "auto"}}"#)
            .unwrap();
        assert!(c.serve.as_ref().unwrap().resolve_sessions() <= 2);
    }

    #[test]
    fn tasks_array_parses_and_validates() {
        let c = RunConfig::parse_str(
            r#"{"tasks": [{"kind": "nc", "weight": 2}, {"kind": "distill"}],
                "encoder": {"epochs": 2}}"#,
        )
        .unwrap();
        let m = c.multi.as_ref().unwrap();
        assert_eq!(m.tasks.len(), 2);
        assert_eq!(m.tasks[0].kind, TaskKind::Nc);
        assert!((m.tasks[0].weight - 2.0).abs() < 1e-12);
        assert_eq!(m.tasks[1].kind, TaskKind::Distill);
        assert_eq!(m.encoder.epochs, 2);
        assert_eq!(m.encoder.arch, "rgcn");
        assert_eq!(c.stage_names(), vec!["data", "partition", "tasks(nc+distill)"]);
        let o = c.train_options();
        assert_eq!(o.epochs, 2);
        assert_eq!(m.task_specs().len(), 2);

        // task and tasks are mutually exclusive.
        let e = RunConfig::parse_str(r#"{"task": {"kind": "nc"}, "tasks": [{"kind": "nc"}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("mutually exclusive"), "{e}");
        // encoder alone is rejected.
        assert!(RunConfig::parse_str(r#"{"encoder": {"arch": "rgcn"}}"#).is_err());
        // distill needs its nc teacher in the same run.
        let e = RunConfig::parse_str(r#"{"tasks": [{"kind": "distill"}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("teacher"), "{e}");
        // Duplicate kinds, missing kind, empty array: hard errors.
        assert!(RunConfig::parse_str(r#"{"tasks": [{"kind": "nc"}, {"kind": "nc"}]}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"tasks": [{}]}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"tasks": []}"#).is_err());
        // LP-only keys stay kind-scoped inside entries.
        let e = RunConfig::parse_str(r#"{"tasks": [{"kind": "nc", "loss": "ce"}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("only valid for kind \"lp\""), "{e}");
        // Unknown entry keys suggest, naming the entry.
        let e = RunConfig::parse_str(r#"{"tasks": [{"kind": "nc", "wieght": 2}]}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("tasks[0]") && e.contains("did you mean 'weight'"), "{e}");
        // Shared knobs live under encoder, not per entry.
        assert!(RunConfig::parse_str(r#"{"tasks": [{"kind": "nc", "epochs": 5}]}"#).is_err());
        // lm is incompatible with the multi-task form.
        assert!(RunConfig::parse_str(
            r#"{"lm": {"mode": "pretrained"}, "tasks": [{"kind": "nc"}]}"#
        )
        .is_err());
    }

    #[test]
    fn multi_roundtrips_and_inherits_arch() {
        // LP heads are wired to the rgcn artifacts: a non-rgcn shared
        // encoder with an lp task is rejected up front.
        let e = RunConfig::parse_str(
            r#"{"tasks": [{"kind": "nc"}, {"kind": "lp"}], "encoder": {"arch": "sage"}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("rgcn"), "{e}");

        let c = RunConfig::parse_str(
            r#"{"tasks": [{"kind": "nc", "weight": 2},
                          {"kind": "lp", "loss": "ce", "neg": "uniform-8"},
                          {"kind": "distill", "lr": 0.001}],
                "encoder": {"epochs": 4, "lr": 0.004},
                "infer": {}}"#,
        )
        .unwrap();
        let back = RunConfig::parse_str(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(c, back);
        let r = c.resolved();
        // infer inherits the shared encoder arch.
        assert_eq!(r.infer.as_ref().unwrap().arch.as_deref(), Some("rgcn"));
        let back = RunConfig::parse_str(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(r, back);
        assert_eq!(back.resolved(), back);
        let o = c.train_options();
        assert_eq!(o.epochs, 4);
        assert!((o.lr - 0.004).abs() < 1e-6);

        // A non-rgcn encoder arch is fine without lp, and inherits.
        let c = RunConfig::parse_str(
            r#"{"tasks": [{"kind": "nc"}], "encoder": {"arch": "sage"}, "infer": {}}"#,
        )
        .unwrap()
        .resolved();
        assert_eq!(c.infer.as_ref().unwrap().arch.as_deref(), Some("sage"));
    }

    #[test]
    fn set_path_indexes_arrays() {
        let mut doc =
            Json::parse(r#"{"tasks": [{"kind": "nc"}, {"kind": "distill"}]}"#).unwrap();
        apply_set(&mut doc, "tasks.0.weight=2.5").unwrap();
        apply_set(&mut doc, "tasks.1.weight=0.5").unwrap();
        let c = RunConfig::from_json(&doc).unwrap();
        let m = c.multi.as_ref().unwrap();
        assert!((m.tasks[0].weight - 2.5).abs() < 1e-12);
        assert!((m.tasks[1].weight - 0.5).abs() < 1e-12);
        // Out-of-range and non-numeric indices are hard errors.
        let e = apply_set(&mut doc, "tasks.5.weight=1").unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        let e = apply_set(&mut doc, "tasks.first.weight=1").unwrap_err().to_string();
        assert!(e.contains("numeric index"), "{e}");
        // Whole-entry replacement through an index.
        apply_set(&mut doc, r#"tasks.1={"kind": "lp", "neg": "uniform-8"}"#).unwrap();
        let c = RunConfig::from_json(&doc).unwrap();
        assert_eq!(c.multi.as_ref().unwrap().tasks[1].kind, TaskKind::Lp);
        // A typo'd entry key through --set still dies in validation.
        apply_set(&mut doc, "tasks.0.wieght=9").unwrap();
        assert!(RunConfig::from_json(&doc).is_err());
    }

    #[test]
    fn conf_version_gates_v2_keys() {
        // Unversioned and v2 configs accept the v2 keys.
        assert!(RunConfig::parse_str(r#"{"serve": {"deadline_ms": 5}}"#).is_ok());
        assert!(
            RunConfig::parse_str(r#"{"conf_version": 2, "serve": {"deadline_ms": 5}}"#).is_ok()
        );
        // A declared v1 config using v2-only keys gets a migration
        // error naming every offending key.
        let e = RunConfig::parse_str(
            r#"{"conf_version": 1, "serve": {"deadline_ms": 5, "queue_depth": 4}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("serve.deadline_ms") && e.contains("serve.queue_depth"), "{e}");
        assert!(e.contains("conf_version"), "{e}");
        let e = RunConfig::parse_str(r#"{"conf_version": 1, "obs": {"stats": true}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("version-2 keys: obs"), "{e}");
        // A clean v1 config still parses (v2 keys at defaults count as
        // unused — presence is gone after typing, values are what
        // matter).
        assert!(RunConfig::parse_str(r#"{"conf_version": 1, "serve": {"requests": 10}}"#).is_ok());
        assert!(RunConfig::parse_str(r#"{"conf_version": 1, "serve": {"max_retries": 2}}"#).is_ok());
        // Version 0 and future versions are rejected outright.
        assert!(RunConfig::parse_str(r#"{"conf_version": 0}"#).is_err());
        let e = RunConfig::parse_str(r#"{"conf_version": 9}"#).unwrap_err().to_string();
        assert!(e.contains("newer than this build"), "{e}");
        // v1/v2 configs using the version-3 striping keys get the same
        // migration treatment; a declared v3 config accepts them.
        let e = RunConfig::parse_str(r#"{"conf_version": 2, "serve": {"shards": 4}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("version-3 keys: serve.shards"), "{e}");
        let e = RunConfig::parse_str(r#"{"conf_version": 1, "serve": {"sessions": 2}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("serve.sessions"), "{e}");
        assert!(RunConfig::parse_str(
            r#"{"conf_version": 3, "serve": {"pool_workers": 2, "shards": 4, "sessions": 2}}"#
        )
        .is_ok());
        assert!(RunConfig::parse_str(r#"{"conf_version": 2, "serve": {"shards": 1}}"#).is_ok());
        // resolved() pins the current version; still a fixed point.
        let r = RunConfig::parse_str("{}").unwrap().resolved();
        assert_eq!(r.conf_version, Some(CONF_VERSION));
        let back = RunConfig::parse_str(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(r, back);
        assert_eq!(back.resolved(), back);
        // An unversioned config serializes without the field at all.
        assert!(RunConfig::default().to_json().get("conf_version").is_none());
    }

    #[test]
    fn conf_version_gates_v4_http_keys() {
        // Unversioned and v4 configs accept the serve.http object.
        assert!(RunConfig::parse_str(r#"{"serve": {"http": {}}}"#).is_ok());
        assert!(RunConfig::parse_str(
            r#"{"conf_version": 4, "serve": {"http": {"listen": "127.0.0.1:0"}}}"#
        )
        .is_ok());
        // Every older declared version gets the migration error.
        for v in [1, 2, 3] {
            let e = RunConfig::parse_str(&format!(
                r#"{{"conf_version": {v}, "serve": {{"http": {{}}}}}}"#
            ))
            .unwrap_err()
            .to_string();
            assert!(e.contains("version-4 keys: serve.http"), "v{v}: {e}");
        }
        // A v3 config without http still parses.
        assert!(RunConfig::parse_str(r#"{"conf_version": 3, "serve": {"shards": 2}}"#).is_ok());
    }

    #[test]
    fn serve_http_keys_parse_validate_and_roundtrip() {
        let c = RunConfig::parse_str(
            r#"{"serve": {"http": {"listen": "0.0.0.0:9090", "workers": 2,
                "max_body": 1024, "read_timeout_ms": 250, "write_timeout_ms": 250}}}"#,
        )
        .unwrap();
        let h = c.serve.as_ref().unwrap().http.as_ref().unwrap();
        assert_eq!(h.listen, "0.0.0.0:9090");
        assert_eq!(h.workers, 2);
        assert_eq!(h.max_body, 1024);
        assert_eq!(h.read_timeout_ms, 250);
        let back = RunConfig::parse_str(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(c, back);
        // Absent http is invisible in the serialized form.
        let c = RunConfig::parse_str(r#"{"serve": {}}"#).unwrap();
        assert!(c.to_json().get("serve").unwrap().get("http").is_none());
        // Typos suggest; value errors are hard.
        let e = RunConfig::parse_str(r#"{"serve": {"http": {"lisen": "x"}}}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("did you mean 'listen'"), "{e}");
        assert!(RunConfig::parse_str(r#"{"serve": {"http": {"listen": ""}}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"serve": {"http": {"workers": 0}}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"serve": {"http": {"max_body": 0}}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"serve": {"http": {"read_timeout_ms": 0}}}"#).is_err());
        // The strict Json::as_usize path: fractional counts are type
        // errors, not silent truncations.
        assert!(RunConfig::parse_str(r#"{"serve": {"http": {"workers": 2.7}}}"#).is_err());
    }

    #[test]
    fn obs_keys_parse_and_roundtrip() {
        let c = RunConfig::parse_str(r#"{"obs": {"trace": "t.jsonl", "stats": true}}"#).unwrap();
        assert_eq!(c.obs.trace.as_deref(), Some("t.jsonl"));
        assert!(c.obs.stats);
        assert!(c.obs.chrome_trace.is_none() && c.obs.report.is_none());
        let back = RunConfig::parse_str(&c.to_json().to_string_pretty()).unwrap();
        assert_eq!(c, back);
        // Default obs is invisible in the serialized form — golden
        // fixtures and pre-obs configs stay byte-identical.
        assert!(RunConfig::default().to_json().get("obs").is_none());
        // Typos suggest; type and value errors are hard.
        let e = RunConfig::parse_str(r#"{"obs": {"trce": "x"}}"#).unwrap_err().to_string();
        assert!(e.contains("did you mean 'trace'"), "{e}");
        assert!(RunConfig::parse_str(r#"{"obs": {"stats": "yes"}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"obs": {"trace": ""}}"#).is_err());
        assert!(RunConfig::parse_str(r#"{"obs": 3}"#).is_err());
    }

    #[test]
    fn neg_roundtrip() {
        for s in ["joint-32", "local-joint-16", "uniform-8", "in-batch"] {
            assert_eq!(neg_name(parse_neg(s).unwrap()), s);
        }
        assert!(parse_neg("jiont-32").is_err());
    }

    #[test]
    fn train_options_come_from_config() {
        let c = RunConfig::parse_str(
            r#"{"seed": 5, "partition": {"parts": 3},
                "loader": {"workers": 2, "prefetch": 4},
                "task": {"kind": "nc", "epochs": 9, "lr": 0.01}}"#,
        )
        .unwrap();
        let o = c.train_options();
        assert_eq!(o.epochs, 9);
        assert_eq!(o.seed, 5);
        assert_eq!(o.n_workers, 3);
        assert_eq!(o.loader_workers, 2);
        assert_eq!(o.prefetch, 4);
        assert!((o.lr - 0.01).abs() < 1e-9);
    }
}
